"""Schedule simulation sweep: analytic roofline vs discrete-event replay
(the ``repro.sim`` tentpole artifact + CI gate).

For every ``repro.core.hw`` preset (now including the NPU-equipped
``rv32_npu``) this lowers the paper's ViT-MLP benchmark op (GEMM→GeLU,
int8) — fused and layer-per-layer — into the tile-level schedule IR and
replays it through the DMA/engine simulator, reporting simulated
runtime, the sim/analytic ratio, overlap efficiency and per-resource
busy/stall time.  A zoo transformer block is swept the same way so the
simulator is exercised on multi-segment chains with per-head repeats.

Writes ``BENCH_schedule.json`` (uploaded by the CI bench-smoke job).

**CI gates** (every preset, or the run fails):

* *fused-sim*: the fused schedule's **simulated** runtime must not
  exceed the unfused schedule's — the paper's claim re-checked on the
  event timeline, not just the closed-form max();
* *floor*: simulated ≥ analytic runtime (the DES only adds real
  serialization; a sim below the roofline floor is a simulator bug).
"""
from __future__ import annotations

import json
import time

from repro import sim
from repro.core import hw
from repro.core.ftl import graph, partition

from ._smoke import smoke

OUT = "BENCH_schedule.json"

# paper ViT-Base MLP first half: d=768, d_ff=3072, int8
D_MODEL, D_FF = 768, 3072
DTYPE = "int8"


def _m() -> int:
    return 512 if smoke() else 3072


def _row(chain) -> dict:
    rep = sim.compare_plan(chain)
    rep["n_segments"] = len(chain.segments)
    return rep


def target_row(target: hw.Target, m: int) -> dict:
    g = graph.gemm_act_graph(m=m, k=D_MODEL, n=D_FF, dtype=DTYPE)
    t0 = time.perf_counter()
    fused = _row(partition.plan_fixed(g, (), target=target))
    unfused = _row(partition.plan_fixed(g, partition.all_cuts(g),
                                        target=target))
    sim_ms = round(1e3 * (time.perf_counter() - t0), 1)
    gate_fused = (hw.round_time(fused["sim_runtime_ms"])
                  <= hw.round_time(unfused["sim_runtime_ms"]))
    gate_floor = (
        fused["sim_runtime_ms"]
        >= fused["analytic_runtime_ms"] * (1 - 1e-9)
        and unfused["sim_runtime_ms"]
        >= unfused["analytic_runtime_ms"] * (1 - 1e-9))
    return {
        "target": target.name,
        "engines": [{"name": e.name, "rates": dict(e.rates)}
                    for e in target.engines],
        "paper_op": {"m": m, "d_model": D_MODEL, "d_ff": D_FF,
                     "dtype": DTYPE, "fused": fused, "unfused": unfused,
                     "sim_runtime_red_%": round(
                         100 * (1 - fused["sim_runtime_ms"]
                                / unfused["sim_runtime_ms"]), 1)},
        "lower_and_sim_ms": sim_ms,
        "gate_fused_sim_ok": gate_fused,
        "gate_floor_ok": gate_floor,
        "gate_ok": gate_fused and gate_floor,
    }


def block_rows(m: int) -> list[dict]:
    """One zoo block per preset: multi-segment chains with repeats."""
    import dataclasses

    from repro import configs
    from repro.core.ftl import registry
    cfg = dataclasses.replace(configs.get_config("llama3.2-3b").reduced(),
                              dtype="float32", remat=False)
    rows = []
    for target in hw.presets():
        bp = registry.plan_block(cfg, m=m, dtype="float32", target=target)
        rows.append({"arch": cfg.name, "m": m, **sim.compare_plan(bp)})
    return rows


def run() -> dict:
    m = _m()
    return {
        "smoke": smoke(),
        "m": m,
        "gate": "simulated fused runtime <= simulated unfused AND "
                "simulated >= analytic on every preset",
        "targets": [target_row(t, m) for t in hw.presets()],
        "zoo_block": block_rows(32 if smoke() else 128),
    }


def main() -> None:
    result = run()
    for row in result["targets"]:
        op = row["paper_op"]
        print(f"{row['target']}: fused sim "
              f"{op['fused']['sim_runtime_ms']:.3f} ms "
              f"(x{op['fused']['sim_over_analytic']:.3f} analytic, "
              f"overlap eff {op['fused']['overlap_efficiency']:.2f}) vs "
              f"unfused sim {op['unfused']['sim_runtime_ms']:.3f} ms "
              f"({op['sim_runtime_red_%']}% red), "
              f"lower+sim {row['lower_and_sim_ms']} ms")
    with open(OUT, "w") as f:
        json.dump(result, f, indent=2)
    print(f"# wrote {OUT}")
    bad = [r["target"] for r in result["targets"] if not r["gate_ok"]]
    if bad:
        raise RuntimeError(
            f"schedule-sim gate FAILED on {bad}: simulated fused must "
            f"not exceed simulated unfused, and simulated runtime must "
            f"never undercut the analytic floor")


if __name__ == "__main__":
    main()
