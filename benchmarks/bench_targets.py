"""Target sweep: the same planning problem priced on every memory-
hierarchy preset (tentpole artifact + CI gate).

For each ``repro.core.hw`` preset (tpu_v5e, cpu_cache, and the paper's
Siracusa-like rv32_l1_l2) this prices the paper's ViT-MLP benchmark op
(GEMM→GeLU, int8) fused vs layer-per-layer — reporting *per-level*
modeled traffic, DMA counts and modeled transfer time — and measures a
real wall-clock: the fp32 MLP executed through the XLA scan executor at
the token tile each target's plan picked (the tile differs per target,
so the measurement is target-sensitive even on one host).

Writes ``BENCH_targets.json`` (uploaded by the CI bench-smoke job).

**CI gates** (both must hold on every preset, or the run fails):

* *traffic*: the fused plan's modeled backing-store traffic must not
  exceed the unfused schedule's — the paper's qualitative result
  (fusion removes the intermediate round trip);
* *runtime*: the fused plan's modeled runtime
  (Σ_segment max(compute, transfer)) must not exceed the unfused
  schedule's — fusion must never cost time under the planner's own
  roofline objective, on any hierarchy we claim to plan for.

Each schedule row reports ``modeled_runtime_ms`` with its compute /
transfer split and a ``compute_bound`` flag, so a preset where fusion
"wins" only because the op is compute-bound anyway is visible at a
glance.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp

from repro.core import hw
from repro.core.ftl import executor_xla, graph, partition, registry
from repro.core.ftl.solver import InfeasibleError

from ._smoke import smoke

MB = 1 << 20
OUT = "BENCH_targets.json"

# paper ViT-Base MLP first half: d=768, d_ff=3072, int8
D_MODEL, D_FF = 768, 3072
DTYPE = "int8"


def _m() -> int:
    return 512 if smoke() else 3072


def _chain_stats(chain) -> dict:
    return {
        "schedule": chain.schedule,
        "traffic_bytes": chain.traffic_bytes,
        "per_level_traffic_bytes": chain.per_level_traffic,
        "dma_transfers": chain.dma_transfers,
        "transfer_time_ms": round(1e3 * chain.transfer_time_s, 4),
        "compute_time_ms": round(1e3 * chain.compute_time_s, 4),
        "modeled_runtime_ms": round(1e3 * chain.modeled_runtime_s, 4),
        "compute_bound": chain.compute_bound,
    }


def _measured_mlp_ms(target: hw.Target, m: int) -> dict:
    """Wall-clock of the fp32 MLP through the scan executor, tiled the
    way *this target's* plan says (registry._scan_tile — the exact
    runtime hook run_block uses)."""
    d, f = 256, 1024
    tile = registry._scan_tile(m, d, f, "float32", False, "gelu", target)
    k = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(k[0], (m, d), jnp.float32)
    w1 = jax.random.normal(k[1], (d, f), jnp.float32) * d ** -0.5
    w2 = jax.random.normal(k[2], (f, d), jnp.float32) * f ** -0.5

    fn = jax.jit(lambda xx: executor_xla.mlp_scan(
        xx, w1, w2, None, None, None, act="gelu", tile_m=tile))
    fn(x).block_until_ready()          # compile
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        fn(x).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return {"tile_m": tile, "wall_ms": round(1e3 * best, 3)}


def target_row(target: hw.Target, m: int) -> dict:
    g = graph.gemm_act_graph(m=m, k=D_MODEL, n=D_FF, dtype=DTYPE)
    t0 = time.perf_counter()
    chosen = partition.plan_chain(g, target=target)
    solve_ms = round(1e3 * (time.perf_counter() - t0), 1)
    fused = partition.plan_fixed(g, (), target=target)
    unfused = partition.plan_fixed(g, partition.all_cuts(g), target=target)
    gate_traffic = fused.traffic_bytes <= unfused.traffic_bytes
    # runtimes compared through the objective's own tie canonicalization
    # (hw.round_time) so an exact compute-bound tie never trips the gate
    gate_runtime = (hw.round_time(fused.modeled_runtime_s)
                    <= hw.round_time(unfused.modeled_runtime_s))
    return {
        "target": target.name,
        "levels": [
            {"name": lv.name, "capacity_bytes": lv.capacity_bytes,
             "bw_bytes_per_s": lv.bw_bytes_per_s,
             "dma_setup_s": lv.dma_setup_s,
             "buffer_depth": lv.buffer_depth}
            for lv in target.levels
        ],
        "paper_op": {
            "m": m, "d_model": D_MODEL, "d_ff": D_FF, "dtype": DTYPE,
            "chosen": _chain_stats(chosen),
            "fused": _chain_stats(fused),
            "unfused": _chain_stats(unfused),
            "traffic_red_%": round(
                100 * (1 - fused.traffic_bytes / unfused.traffic_bytes), 1),
            "runtime_red_%": round(
                100 * (1 - fused.modeled_runtime_s
                       / unfused.modeled_runtime_s), 1),
        },
        "solve_ms": solve_ms,
        "measured_mlp": _measured_mlp_ms(target, m),
        "gate_traffic_ok": gate_traffic,
        "gate_runtime_ok": gate_runtime,
        "gate_ok": gate_traffic and gate_runtime,
    }


def run() -> dict:
    m = _m()
    rows = []
    for target in hw.presets():
        try:
            rows.append(target_row(target, m))
        except InfeasibleError as e:
            rows.append({"target": target.name, "error": str(e),
                         "gate_traffic_ok": False,
                         "gate_runtime_ok": False,
                         "gate_ok": False})
    return {
        "smoke": smoke(),
        "m": m,
        "gate": "fused modeled backing-store traffic AND modeled runtime "
                "<= unfused on every preset",
        "targets": rows,
    }


def main() -> None:
    result = run()
    for row in result["targets"]:
        if "error" in row:
            print(f"{row['target']}: INFEASIBLE — {row['error']}")
            continue
        op = row["paper_op"]
        bound = ("compute" if op["chosen"]["compute_bound"]
                 else "transfer")
        print(f"{row['target']}: {op['chosen']['schedule']} chosen "
              f"({bound}-bound), "
              f"fused {op['fused']['traffic_bytes'] / MB:.1f} MiB "
              f"{op['fused']['per_level_traffic_bytes']} vs unfused "
              f"{op['unfused']['traffic_bytes'] / MB:.1f} MiB "
              f"({op['traffic_red_%']}% red), runtime "
              f"{op['fused']['modeled_runtime_ms']} ms vs "
              f"{op['unfused']['modeled_runtime_ms']} ms "
              f"({op['runtime_red_%']}% red), "
              f"solve {row['solve_ms']} ms, "
              f"exec tile_m={row['measured_mlp']['tile_m']} "
              f"{row['measured_mlp']['wall_ms']} ms")
    with open(OUT, "w") as f:
        json.dump(result, f, indent=2)
    print(f"# wrote {OUT}")
    bad_traffic = [r["target"] for r in result["targets"]
                   if not r.get("gate_traffic_ok")]
    bad_runtime = [r["target"] for r in result["targets"]
                   if not r.get("gate_runtime_ok")]
    if bad_traffic or bad_runtime:
        raise RuntimeError(
            f"target gate FAILED (or planning infeasible): traffic gate "
            f"on {bad_traffic}, runtime gate on {bad_runtime}")


if __name__ == "__main__":
    main()
