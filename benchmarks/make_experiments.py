"""Generate the data tables for EXPERIMENTS.md from dry-run artifacts.

Usage: PYTHONPATH=src python -m benchmarks.make_experiments [section]
sections: dryrun | roofline | perf
"""
from __future__ import annotations

import glob
import json
import os
import sys

HERE = os.path.dirname(__file__)
BASE = os.path.join(HERE, "..", "results", "dryrun")
OPT = os.path.join(HERE, "..", "results", "dryrun_opt")


def load(d):
    out = {}
    for fn in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(fn) as f:
            rec = json.load(f)
        out[(rec["arch"], rec["shape"], rec["mesh"])] = rec
    return out


def md_table(rows: list[dict], keys: list[str]) -> str:
    out = ["| " + " | ".join(keys) + " |",
           "|" + "|".join("---" for _ in keys) + "|"]
    for r in rows:
        out.append("| " + " | ".join(str(r.get(k, "–")) for k in keys) + " |")
    return "\n".join(out)


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def dryrun_section():
    base = load(BASE)
    rows = []
    for (arch, shape, mesh), rec in sorted(base.items()):
        ok = rec["status"] == "ok"
        row = {"arch": arch, "shape": shape, "mesh": mesh,
               "status": "ok" if ok else rec["status"][:46]}
        if ok:
            m = rec["memory"]
            c = rec["collectives"]
            row.update({
                "compile_s": rec["compile_s"],
                "args_GiB/chip": fmt_bytes(m["argument_size_in_bytes"]),
                "temp_GiB/chip": fmt_bytes(m["temp_size_in_bytes"]),
                "HLO_GFLOPs/chip": round(
                    rec["cost"]["flops_per_chip"] / 1e9, 1),
                "coll_GiB/chip": fmt_bytes(c["total_bytes"]),
                "coll_ops": c["count"],
            })
        rows.append(row)
    print(md_table(rows, ["arch", "shape", "mesh", "status", "compile_s",
                          "args_GiB/chip", "temp_GiB/chip",
                          "HLO_GFLOPs/chip", "coll_GiB/chip", "coll_ops"]))


def roofline_section():
    base = load(BASE)
    rows = []
    for (arch, shape, mesh), rec in sorted(base.items()):
        if mesh != "16x16" or rec["status"] != "ok":
            continue
        r = rec["roofline"]
        rows.append({
            "arch": arch, "shape": shape,
            "t_compute_s": r["t_compute_s"], "t_memory_s": r["t_memory_s"],
            "t_collective_s": r["t_collective_s"],
            "dominant": r["dominant"],
            "MODEL_FLOPS": r["model_flops"],
            "useful_ratio": r["useful_flops_ratio"],
            "mfu_bound": r["mfu_bound"],
        })
    print(md_table(rows, ["arch", "shape", "t_compute_s", "t_memory_s",
                          "t_collective_s", "dominant", "MODEL_FLOPS",
                          "useful_ratio", "mfu_bound"]))


def perf_section():
    base = load(BASE)
    opt = load(OPT)
    rows = []
    for key, orec in sorted(opt.items()):
        arch, shape, mesh = key
        brec = base.get(key)
        if not brec or brec["status"] != "ok" or orec["status"] != "ok":
            continue
        b, o = brec["roofline"], orec["roofline"]

        def bound(r):
            return max(r["t_compute_s"], r["t_memory_s"],
                       r["t_collective_s"])

        rows.append({
            "arch": arch, "shape": shape, "mesh": mesh,
            "base mem/coll (s)": f"{b['t_memory_s']:.2f} / "
                                 f"{b['t_collective_s']:.2f}",
            "opt mem/coll (s)": f"{o['t_memory_s']:.2f} / "
                                f"{o['t_collective_s']:.2f}",
            "bound speedup": f"{bound(b)/max(1e-9, bound(o)):.1f}x",
            "mfu": f"{b['mfu_bound']:.3f} → {o['mfu_bound']:.3f}",
        })
    print(md_table(rows, ["arch", "shape", "mesh", "base mem/coll (s)",
                          "opt mem/coll (s)", "bound speedup", "mfu"]))


if __name__ == "__main__":
    sec = sys.argv[1] if len(sys.argv) > 1 else "roofline"
    {"dryrun": dryrun_section, "roofline": roofline_section,
     "perf": perf_section}[sec]()
