"""Observability benchmark: telemetry overhead + the online drift
monitor (the obs tentpole's CI artifact).

Two sections, both gated:

* **overhead** — two identical ServeEngines (telemetry off vs on:
  spans, per-step gauges/histograms, the sampled drift monitor) decode
  the same fixed slot population for ``steps`` steps, interleaved over
  ``reps`` repeats (best-of throughput on each side, so a noisy CI
  neighbour hurts both equally).  **Gate**: instrumented decode
  throughput must stay ≥ ``OVERHEAD_FLOOR`` of bare.
* **drift** — fit a calibrated target from the quick microbench sweep
  (the same shapes ``bench_calibrate`` uses in smoke), then drive a
  live serve run plus repeated whole-block executions through an
  obs-enabled engine whose :class:`repro.obs.DriftMonitor` prices every
  ``block_exec`` span against that calibrated target.  **Gates**: the
  rolling geomean modeled/measured over the block rows sits inside the
  calibration band, and the monitor's online geomean exactly reproduces
  the offline ``exp(mean(log(modeled/measured)))`` over its retained
  :class:`repro.calib.Measurement` rows — the streaming estimator is
  the batch estimator, not an approximation of it.

Writes ``BENCH_obs.json`` (uploaded by the CI bench-obs job).
"""
from __future__ import annotations

import dataclasses
import json
import math
import time

import jax
import numpy as np

from repro import calib, configs, obs
from repro.core import hw
from repro.launch.serve import Request, ServeEngine

from ._smoke import smoke

OUT = "BENCH_obs.json"

ARCH = "llama3.2-3b"

# instrumented decode must keep ≥ 97% of bare throughput — telemetry
# that costs more than 3% is not "always-on"
OVERHEAD_FLOOR = 0.97

# same band as bench_calibrate: the calibrated model should track this
# host within ~3x either way even on shared runners
BAND = (0.3, 10 / 3)


def _params():
    if smoke():
        return {
            "slots": 4, "max_seq": 128, "prompt_len": 8,
            "steps": 24, "reps": 4,
            "serve_requests": 6, "serve_max_new": 6,
            "block_reps": 4,
            # bench_calibrate's smoke sweep — the drift section must
            # reproduce its regime, not invent a new one
            "gemm_shapes": ((256, 256, 256), (512, 512, 512)),
            "elementwise_sizes": (1 << 20, 1 << 22),
            "dma_sizes": (1 << 21, 1 << 23, 1 << 25),
            "repeats": 3,
        }
    return {
        "slots": 8, "max_seq": 256, "prompt_len": 16,
        "steps": 44, "reps": 5,
        "serve_requests": 16, "serve_max_new": 16,
        "block_reps": 8,
        "gemm_shapes": ((256, 256, 256), (512, 512, 512),
                        (1024, 512, 1024)),
        "elementwise_sizes": (1 << 20, 1 << 22, 1 << 23),
        "dma_sizes": (1 << 21, 1 << 23, 1 << 25, 1 << 26),
        "repeats": 5,
    }


def _cfg():
    cfg = configs.get_config(ARCH).reduced()
    return dataclasses.replace(cfg, dtype="float32", remat=False,
                               ftl_mode="auto")


def _requests(cfg, n: int, prompt_len: int, max_new: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(2, cfg.vocab_size, size=prompt_len)
                    .astype(np.int32), max_new)
            for i in range(n)]


# ----------------------------------------------------------------------
# section 1: overhead
# ----------------------------------------------------------------------

def _fill_slots(eng: ServeEngine, cfg, p) -> None:
    # max_new far beyond the timed horizon + eos_id=-1: no slot ever
    # evicts mid-measurement, so both engines decode identical work
    for slot, req in enumerate(_requests(cfg, eng.slots, p["prompt_len"],
                                         10_000)):
        assert eng._admit(req, slot, {})
    eng.step()                      # compile the decode fn off the clock


def _timed_steps(eng: ServeEngine, steps: int) -> list[float]:
    """Per-step wall-clock.  Per-step (not per-window) samples let the
    comparison use a median: a scheduler hiccup lands on one step, not
    on a whole 50-step window, so it cannot shift the estimate."""
    out = []
    for _ in range(steps):
        t0 = time.perf_counter()
        eng.step()
        out.append(time.perf_counter() - t0)
    return out


def overhead_section(cfg, params, p) -> dict:
    engines = {}
    for name, with_obs in (("bare", False), ("obs", True)):
        eng = ServeEngine(cfg, params, batch_slots=p["slots"],
                          max_seq=p["max_seq"], eos_id=-1, obs=with_obs)
        eng.warmup_compile()
        _fill_slots(eng, cfg, p)
        engines[name] = eng

    # interleaved best-of: each rep times both engines back to back, so
    # machine-wide noise (another CI job waking up) cannot land on only
    # one side; best-of-reps is the least-noisy estimate of each
    # the decode positions advance p["steps"] per rep on each side; keep
    # prompt + 1 (warm step) + reps*steps inside max_seq
    assert p["prompt_len"] + 1 + p["reps"] * p["steps"] <= p["max_seq"]
    samples: dict[str, list[float]] = {"bare": [], "obs": []}
    spans_seen = 0
    for rep in range(p["reps"]):
        order = ("bare", "obs") if rep % 2 == 0 else ("obs", "bare")
        for name in order:
            # span recording is a process-global switch (the obs engine
            # enabled it); flip it per side so "bare" really is bare —
            # and alternate the order so drift in machine load cannot
            # systematically favor one side
            (obs.enable if name == "obs" else obs.disable)()
            samples[name] += _timed_steps(engines[name], p["steps"])
            if name == "obs":                 # disable() drops the buffer
                spans_seen = max(spans_seen, len(obs.recorder() or []))
    obs.enable()

    tput = {name: p["slots"] / float(np.median(dts))
            for name, dts in samples.items()}
    ratio = tput["obs"] / tput["bare"]
    return {
        "steps_per_rep": p["steps"],
        "reps": p["reps"],
        "slots": p["slots"],
        "estimator": "median per-step wall-clock, interleaved "
                     "alternating reps",
        "bare_tokens_per_s": round(tput["bare"], 1),
        "obs_tokens_per_s": round(tput["obs"], 1),
        "obs_over_bare": round(ratio, 4),
        "floor": OVERHEAD_FLOOR,
        "spans_recorded": spans_seen,
        "gate_overhead_ok": ratio >= OVERHEAD_FLOOR,
    }


# ----------------------------------------------------------------------
# section 2: drift
# ----------------------------------------------------------------------

def drift_section(cfg, params, p) -> dict:
    base = hw.default_target()
    ms = calib.microbench_sweep(
        base=base,
        gemm_shapes=p["gemm_shapes"],
        elementwise_sizes=p["elementwise_sizes"],
        dma_sizes=p["dma_sizes"],
        repeats=p["repeats"],
    )
    calibrated = calib.calibrate(ms, base=base).target

    eng = ServeEngine(cfg, params, batch_slots=p["slots"],
                      max_seq=p["max_seq"], eos_id=-1,
                      obs=True, drift_target=calibrated, drift_band=BAND)
    eng.warmup_compile()
    # live serve run: decode-step spans feed the monitor's sampled
    # (report-only) rows; the gated feed is the whole-block executions
    eng.run(_requests(cfg, p["serve_requests"], p["prompt_len"],
                      p["serve_max_new"]), {})
    for _ in range(p["block_reps"]):
        eng.execute_block_plan()

    mon = eng.drift
    online = mon.geomean_ratio("block_exec")
    rows = [m for m in mon.measurements() if m.name == "block_exec"]
    offline = math.exp(sum(
        math.log(calib.modeled_measurement_s(calibrated, m) / m.measured_s)
        for m in rows) / len(rows))
    status = mon.status()
    return {
        "base_target": base.name,
        "calibrated_target": calibrated.name,
        "band": list(BAND),
        "block_reps": p["block_reps"],
        "block_exec_geomean_ratio": round(online, 4),
        "offline_geomean_ratio": round(offline, 4),
        "decode_step_geomean_ratio": (
            round(status["per_segment"]["decode_step"]["geomean_ratio"], 4)
            if "decode_step" in status["per_segment"] else None),
        "n_observed": status["n_observed"],
        "gate_drift_in_band": mon.in_band("block_exec"),
        # the online estimator must *be* the offline one — same rows,
        # same math — so any future windowing bug trips this, not just
        # nudges the band gate
        "gate_online_matches_offline":
            abs(math.log(online) - math.log(offline)) < 1e-9,
    }


def run() -> dict:
    from repro.models import model as M

    p = _params()
    cfg = _cfg()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    overhead = overhead_section(cfg, params, p)
    drift = drift_section(cfg, params, p)
    return {
        "smoke": smoke(),
        "arch": cfg.name,
        "gate": f"instrumented decode throughput >= {OVERHEAD_FLOOR} of "
                f"bare AND block_exec drift geomean inside {BAND} on the "
                f"calibrated target AND online geomean == offline "
                f"exp-mean-log over the retained measurement rows",
        "overhead": overhead,
        "drift": drift,
    }


def main() -> None:
    result = run()
    o, d = result["overhead"], result["drift"]
    print(f"overhead: bare {o['bare_tokens_per_s']} tok/s vs obs "
          f"{o['obs_tokens_per_s']} tok/s (ratio {o['obs_over_bare']}, "
          f"floor {o['floor']}); {o['spans_recorded']} spans recorded")
    print(f"drift: block_exec geomean {d['block_exec_geomean_ratio']} "
          f"(offline {d['offline_geomean_ratio']}) on "
          f"{d['calibrated_target']}, band {d['band']}; "
          f"decode_step (report-only) {d['decode_step_geomean_ratio']}")
    with open(OUT, "w") as f:
        json.dump(result, f, indent=2)
    print(f"# wrote {OUT}")

    if not o["gate_overhead_ok"]:
        raise SystemExit(
            f"OBS OVERHEAD GATE FAILED: instrumented/bare throughput "
            f"{o['obs_over_bare']} below floor {o['floor']}")
    if not d["gate_drift_in_band"]:
        raise SystemExit(
            f"OBS DRIFT GATE FAILED: block_exec geomean "
            f"{d['block_exec_geomean_ratio']} outside band {d['band']} "
            f"on calibrated target {d['calibrated_target']}")
    if not d["gate_online_matches_offline"]:
        raise SystemExit(
            f"OBS DRIFT GATE FAILED: online geomean "
            f"{d['block_exec_geomean_ratio']} != offline "
            f"{d['offline_geomean_ratio']} over the same rows")
    print(f"# gates OK: overhead ratio {o['obs_over_bare']} >= "
          f"{o['floor']}, drift {d['block_exec_geomean_ratio']} in "
          f"{d['band']} (online == offline)")


if __name__ == "__main__":
    main()
