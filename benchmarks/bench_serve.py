"""Continuous-batching serving benchmark: sustained tokens/s and request
latency under synthetic open-loop arrivals (the serving tentpole's CI
artifact + gates).

Per target preset this builds a :class:`repro.launch.serve.ServeEngine`
(paged KV cache, AOT-warmed bucket ladder, split prefill/decode plans),
pre-compiles every bucket's prefill step plus the decode step, then
serves a Poisson arrival stream of mixed-length prompts and reports
sustained tokens/s, p50/p99 request latency (arrival → completion,
queueing included) and the plan-cache counters.

Writes ``BENCH_serve.json`` (uploaded by the CI bench-serve job).

**CI gates** (every preset, or the run fails):

* *zero-replan*: steady-state decode never replans — the decode plan is
  fetched every step and must hit the warmed cache (``replans == 0`` and
  a 100% plan-cache hit rate after warmup);
* *bucket-reuse*: the bucketed prefill plan is planned once per rung and
  reused across every request admitted into that bucket (≥ 2 admissions
  share a bucket, with no post-warmup planning).
"""
from __future__ import annotations

import dataclasses
import json
import time

import jax
import numpy as np

from repro import configs
from repro.core import hw
from repro.launch.serve import Request, ServeEngine, poisson_arrivals
from repro.models import model as M

from ._smoke import smoke

OUT = "BENCH_serve.json"

ARCH = "llama3.2-3b"


def _params():
    if smoke():
        return {
            "targets": ("cpu_cache", "rv32_npu"),
            "requests": 10, "slots": 4, "max_seq": 64,
            "prompt_lens": (4, 24), "max_new": 6, "rate": 50.0,
        }
    return {
        "targets": ("cpu_cache", "rv32_npu", "tpu_v5e"),
        "requests": 64, "slots": 8, "max_seq": 256,
        "prompt_lens": (8, 96), "max_new": 32, "rate": 20.0,
    }


def _percentile(xs: list[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else 0.0


def serve_row(cfg, params, target: hw.Target, p: dict, seed: int = 0
              ) -> dict:
    rng = np.random.default_rng(seed)
    lens = rng.integers(p["prompt_lens"][0], p["prompt_lens"][1] + 1,
                        size=p["requests"])
    reqs = [Request(i, rng.integers(2, cfg.vocab_size, size=int(n))
                    .astype(np.int32), p["max_new"])
            for i, n in enumerate(lens)]
    arrivals = poisson_arrivals(p["requests"], p["rate"], seed)

    eng = ServeEngine(cfg, params, batch_slots=p["slots"],
                      max_seq=p["max_seq"], eos_id=-1, target=target)
    eng.warmup_compile()
    warm = dict(eng.plans.counters())           # post-warmup snapshot

    t0 = time.perf_counter()
    done = eng.run(reqs, {}, arrivals=arrivals)
    wall = time.perf_counter() - t0

    lat = [r.latency_s for r in done]
    after = eng.plans.counters()
    buckets_reused = [b for b, n in eng.stats["bucket_admissions"].items()
                      if n >= 2]
    gate_zero_replan = (eng.stats["replans"] == 0
                        and after["misses"] == warm["misses"]
                        and after["misses_after_warmup"] == 0)
    gate_bucket_reuse = (bool(buckets_reused)
                         and after["misses"] == warm["misses"])
    report = eng.plan_report()
    return {
        "target": target.name,
        "paged_kv": eng.paged,
        "buckets": list(eng.buckets),
        "requests": len(done),
        "tokens": eng.stats["tokens"],
        "decode_steps": eng.stats["decode_steps"],
        "prefills": eng.stats["prefills"],
        "wall_s": round(wall, 3),
        "tokens_per_s": round(eng.stats["tokens"] / max(wall, 1e-9), 1),
        "latency_p50_ms": round(1e3 * _percentile(lat, 50), 1),
        "latency_p99_ms": round(1e3 * _percentile(lat, 99), 1),
        "bucket_admissions": {str(k): v for k, v
                              in sorted(eng.stats["bucket_admissions"]
                                        .items())},
        "plan_cache": after,
        "replans": eng.stats["replans"],
        "decode_cuts": report["decode"]["cuts"] if report["decode"] else [],
        "prefill_cuts": (report["prefill"]["cuts"]
                         if report["prefill"] else []),
        "decode_differs_from_prefill":
            report["decode_differs_from_prefill"],
        "gate_zero_replan_ok": gate_zero_replan,
        "gate_bucket_reuse_ok": gate_bucket_reuse,
        "gate_ok": gate_zero_replan and gate_bucket_reuse,
    }


def run() -> dict:
    p = _params()
    cfg = configs.get_config(ARCH).reduced()
    cfg = dataclasses.replace(cfg, remat=False)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return {
        "smoke": smoke(),
        "arch": cfg.name,
        "open_loop": {"rate_req_per_s": p["rate"],
                      "prompt_lens": list(p["prompt_lens"]),
                      "max_new": p["max_new"], "slots": p["slots"]},
        "gate": "zero replans during steady-state decode AND bucketed "
                "prefill plan reused across requests within a bucket, "
                "on every preset",
        "targets": [serve_row(cfg, params, hw.get_target(t), p)
                    for t in p["targets"]],
    }


def main() -> None:
    result = run()
    for row in result["targets"]:
        print(f"{row['target']}: {row['tokens']} tokens in "
              f"{row['wall_s']}s ({row['tokens_per_s']} tok/s), "
              f"p50 {row['latency_p50_ms']} ms / "
              f"p99 {row['latency_p99_ms']} ms, "
              f"{row['prefills']} prefills over buckets "
              f"{row['bucket_admissions']}, "
              f"{row['replans']} replans, plan cache {row['plan_cache']}, "
              f"decode!=prefill cuts: {row['decode_differs_from_prefill']}")
    with open(OUT, "w") as f:
        json.dump(result, f, indent=2)
    print(f"# wrote {OUT}")
    bad = [r["target"] for r in result["targets"] if not r["gate_ok"]]
    if bad:
        raise RuntimeError(
            f"serve gate FAILED on {bad}: steady-state decode must never "
            f"replan (100% plan-cache hits after warmup) and prefill "
            f"plans must be reused across requests within a bucket")


if __name__ == "__main__":
    main()
