"""FTL applied to attention (DESIGN.md §5): fused-tiled QKᵀ→softmax→PV
(flash) vs materialized scores, across sequence lengths.

The (Tq, Tk) score matrix is the intermediate; at 32 k it is 4 GiB fp32
per head — the "exceeds L2" regime of the paper, at TPU scale.  Reports
traffic and the HBM-bound speedup per (seq, head_dim)."""
from __future__ import annotations

from repro.core import ftl, hw
from repro.core.ftl import graph, partition

from ._smoke import smoke

MB = 1 << 20


def run() -> list[dict]:
    seqs = (1024,) if smoke() else (4096, 16384, 32768)
    dhs = (128,) if smoke() else (128, 256)
    target = hw.TPU_V5E
    rows = []
    for seq in seqs:
        for dh in dhs:
            ag = graph.attention_graph(q_len=seq, kv_len=seq, head_dim=dh)
            fused = partition.plan_fixed(
                ag, (), target=target).segments[0].plan
            groups = ftl.fusion.attention(q_len=seq, kv_len=seq,
                                          head_dim=dh, fuse=False)
            unfused = []
            feasible = True
            for g in groups:
                try:
                    unfused.append(ftl.solve(g, target=target))
                except ftl.InfeasibleError:
                    feasible = False
            score_bytes = seq * seq * 4
            row = {
                "seq": seq, "head_dim": dh,
                "fused_MiB": round(fused.traffic_bytes / MB, 1),
                "score_matrix_MiB": round(score_bytes / MB, 1),
                "block_q": fused.tile("Tq"),
                "block_k": fused.tile("Tk"),
            }
            if feasible:
                unf = sum(p.traffic_bytes for p in unfused)
                row["unfused_MiB"] = round(unf / MB, 1)
                row["traffic_red_%"] = round(
                    100 * (1 - fused.traffic_bytes / unf), 1)
            else:
                row["unfused_MiB"] = "infeasible"
                row["traffic_red_%"] = "-"
            rows.append(row)
    return rows


def main() -> None:
    rows = run()
    keys = list(rows[0].keys())
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r[k]) for k in keys))


if __name__ == "__main__":
    main()
