"""FTL solver performance: wall time + nodes explored across problem
sizes (the paper's step-4 'solve' must be fast enough to run per layer at
deployment time — Deeploy does this offline, we do it at trace time)."""
from __future__ import annotations

import time

from repro.core import ftl

from ._smoke import smoke

MB = 1 << 20


CASES = [
    ("vit-mlp-fused", lambda: ftl.fusion.mlp(
        m=3072, d_model=768, d_ff=3072, fuse=True)),
    ("qwen72b-mlp-shard", lambda: ftl.fusion.mlp(
        m=65536, d_model=8192, d_ff=29568 // 16, gated=True, fuse=True)),
    ("attention-32k", lambda: ftl.fusion.attention(
        q_len=32768, kv_len=32768, head_dim=128, fuse=True)),
    ("gemm-chain-4", lambda: ftl.fusion.gemm_chain(
        m=8192, dims_kn=[4096, 4096, 4096, 4096], fuse=True)),
]


def run() -> list[dict]:
    cases = [CASES[0], CASES[3]] if smoke() else CASES
    rows = []
    for name, make in cases:
        g = make()
        t0 = time.perf_counter()
        plan = ftl.solve(g, vmem_budget=96 * MB)
        dt = time.perf_counter() - t0
        rows.append({
            "case": name,
            "dims": len(g.dims),
            "solve_ms": round(1e3 * dt, 1),
            "nodes": plan.nodes_explored,
            "traffic_MiB": round(plan.traffic_bytes / MB, 1),
            "vmem_MiB": round(plan.vmem_bytes / MB, 1),
        })
    return rows


def main() -> None:
    rows = run()
    keys = list(rows[0].keys())
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r[k]) for k in keys))


if __name__ == "__main__":
    main()
