"""FTL solver performance: wall time + nodes explored across problem
sizes and memory-hierarchy targets (the paper's step-4 'solve' must be
fast enough to run per layer at deployment time — Deeploy does this
offline, we do it at trace time).  Swept over ≥2 Target presets so the
branch-and-bound cost is known on both the VMEM-scale and the KiB-scale
hierarchy."""
from __future__ import annotations

import time

from repro.core import ftl, hw

from ._smoke import smoke

MB = 1 << 20


CASES = [
    ("vit-mlp-fused", lambda: ftl.fusion.mlp(
        m=3072, d_model=768, d_ff=3072, fuse=True)),
    ("qwen72b-mlp-shard", lambda: ftl.fusion.mlp(
        m=65536, d_model=8192, d_ff=29568 // 16, gated=True, fuse=True)),
    ("attention-32k", lambda: ftl.fusion.attention(
        q_len=32768, kv_len=32768, head_dim=128, fuse=True)),
    ("gemm-chain-4", lambda: ftl.fusion.gemm_chain(
        m=8192, dims_kn=[4096, 4096, 4096, 4096], fuse=True)),
]

TARGETS = (hw.TPU_V5E, hw.RV32_L1_L2)


def run() -> list[dict]:
    cases = [CASES[0], CASES[3]] if smoke() else CASES
    rows = []
    for name, make in cases:
        for target in TARGETS:
            g = make()
            t0 = time.perf_counter()
            try:
                plan = ftl.solve(g, target=target)
            except ftl.InfeasibleError:
                rows.append({"case": name, "target": target.name,
                             "dims": len(g.dims),
                             "solve_ms": round(
                                 1e3 * (time.perf_counter() - t0), 1),
                             "nodes": "-", "traffic_MiB": "infeasible",
                             "vmem_MiB": "-", "transfer_ms": "-",
                             "compute_ms": "-", "runtime_ms": "-",
                             "bound": "-"})
                continue
            dt = time.perf_counter() - t0
            rows.append({
                "case": name,
                "target": target.name,
                "dims": len(g.dims),
                "solve_ms": round(1e3 * dt, 1),
                "nodes": plan.nodes_explored,
                "traffic_MiB": round(plan.traffic_bytes / MB, 1),
                "vmem_MiB": round(plan.vmem_bytes / MB, 2),
                "transfer_ms": round(1e3 * plan.transfer_time_s, 3),
                "compute_ms": round(1e3 * plan.compute_time_s, 3),
                "runtime_ms": round(1e3 * plan.modeled_runtime_s, 3),
                "bound": "compute" if plan.compute_bound else "transfer",
            })
    return rows


def main() -> None:
    rows = run()
    keys = list(rows[0].keys())
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r[k]) for k in keys))


if __name__ == "__main__":
    main()
