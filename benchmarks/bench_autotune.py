"""Simulator-in-the-loop autotuning sweep (the ``repro.tune`` tentpole
artifact + CI gate).

For every ``repro.core.hw`` preset this plans the paper's ViT-MLP
benchmark op (GEMM→GeLU, int8) and a zoo transformer block twice: the
analytic argmin (``partition.plan_chain``) and the DES-scored autotuner
(``repro.tune.autotune_chain`` — beam search over the analytic top-k
shortlist × tile sizes × per-level buffer depths × engine assignment,
every candidate replayed through the discrete-event simulator).  Rows
report both simulated runtimes, the improvement, the replay budget
spent, and what the tuner changed (target depth suffix, cuts).

Writes ``BENCH_autotune.json`` (uploaded by the CI bench-smoke job).

**CI gates** (or the run fails, naming the offending preset):

* *tuned-never-worse*: on **every** preset × workload the tuned plan's
  simulated runtime must be ≤ the analytic-best plan's simulated
  runtime (the analytic plan is a search seed, so a regression means
  the tuner lost a plan it was handed);
* *strictly-better-somewhere*: at least one preset × workload must
  improve strictly — the search must actually buy something, otherwise
  the simulator scoring is dead weight.
"""
from __future__ import annotations

import json
import time

from repro.core import hw
from repro.core.ftl import graph
from repro.tune import AutotuneConfig, autotune_chain

from ._smoke import smoke

OUT = "BENCH_autotune.json"

# paper ViT-Base MLP first half: d=768, d_ff=3072, int8
D_MODEL, D_FF = 768, 3072
DTYPE = "int8"


def _m() -> int:
    return 256 if smoke() else 3072


def _config() -> AutotuneConfig:
    if smoke():
        return AutotuneConfig(top_k_partitions=2, top_k_tiles=2,
                              beam_width=3, max_rounds=2, max_sims=96)
    return AutotuneConfig()


def _tune_row(g, target: hw.Target, config: AutotuneConfig) -> dict:
    t0 = time.perf_counter()
    res = autotune_chain(g, target=target, config=config)
    wall_ms = round(1e3 * (time.perf_counter() - t0), 1)
    gate = (hw.round_time(res.sim_runtime_s)
            <= hw.round_time(res.baseline_sim_runtime_s))
    return {
        "graph": g.name,
        "analytic_best_sim_ms": 1e3 * res.baseline_sim_runtime_s,
        "tuned_sim_ms": 1e3 * res.sim_runtime_s,
        "tuned_analytic_ms": 1e3 * res.chain.modeled_runtime_s,
        "improvement_%": round(100 * res.improvement, 3),
        "improved": res.improved,
        "n_scored": res.n_scored,
        "n_feasible": res.n_feasible,
        "tuned_target": res.chain.target.name,
        "baseline_cuts": list(res.baseline_chain.cuts()),
        "tuned_cuts": list(res.chain.cuts()),
        "tune_wall_ms": wall_ms,
        "gate_tuned_ok": gate,
    }


def target_row(target: hw.Target, m: int, config: AutotuneConfig) -> dict:
    g = graph.gemm_act_graph(m=m, k=D_MODEL, n=D_FF, dtype=DTYPE)
    row = _tune_row(g, target, config)
    return {"target": target.name, "paper_op": {"m": m, "d_model": D_MODEL,
                                                "d_ff": D_FF, "dtype": DTYPE,
                                                **row}}


def block_rows(m: int, config: AutotuneConfig) -> list[dict]:
    """One zoo block per preset: multi-segment chains with repeats."""
    import dataclasses

    from repro import configs
    cfg = dataclasses.replace(configs.get_config("llama3.2-3b").reduced(),
                              dtype="float32", remat=False)
    g = graph.block_graph(cfg, m=m, dtype="float32")
    return [{"arch": cfg.name, "m": m, "target": t.name,
             **_tune_row(g, t, config)}
            for t in hw.presets()]


def run() -> dict:
    m = _m()
    config = _config()
    return {
        "smoke": smoke(),
        "m": m,
        "config": {
            "top_k_partitions": config.top_k_partitions,
            "top_k_tiles": config.top_k_tiles,
            "beam_width": config.beam_width,
            "max_rounds": config.max_rounds,
            "max_sims": config.max_sims,
            "depth_candidates": list(config.depth_candidates),
        },
        "gate": "tuned simulated runtime <= analytic-best simulated "
                "runtime on every preset x workload, strictly better on "
                "at least one",
        "targets": [target_row(t, m, config) for t in hw.presets()],
        "zoo_block": block_rows(32 if smoke() else 128, config),
    }


def main() -> None:
    result = run()
    rows = ([(r["target"], r["paper_op"]) for r in result["targets"]]
            + [(f"{r['target']}/{r['arch']}", r)
               for r in result["zoo_block"]])
    for label, r in rows:
        print(f"{label}: tuned sim {r['tuned_sim_ms']:.3f} ms vs "
              f"analytic-best sim {r['analytic_best_sim_ms']:.3f} ms "
              f"({r['improvement_%']:+.2f}%, {r['n_scored']} replays, "
              f"target {r['tuned_target']}, "
              f"tune {r['tune_wall_ms']} ms)")
    with open(OUT, "w") as f:
        json.dump(result, f, indent=2)
    print(f"# wrote {OUT}")
    bad = [label for label, r in rows if not r["gate_tuned_ok"]]
    if bad:
        raise RuntimeError(
            f"autotune gate FAILED on {bad}: the tuned plan's simulated "
            f"runtime exceeds the analytic-best plan's — the analytic "
            f"plan is a search seed, so the tuner lost a plan it was "
            f"handed")
    if not any(r["improved"] for _, r in rows):
        raise RuntimeError(
            "autotune gate FAILED: no preset/workload improved strictly "
            "over the analytic plan — the DES-scored search bought "
            "nothing anywhere")


if __name__ == "__main__":
    main()
