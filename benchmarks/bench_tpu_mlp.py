"""FTL at production scale on the TPU target: the graph partitioner's
fusion-partition choice vs the layer-per-layer baseline for every assigned
architecture's MLP dims (the paper's technique as deployed).

Each arch's MLP chain goes through ``partition.plan_chain`` (the DP over
contiguous cuts) at the per-shard sizes the 16×16 mesh actually sees (the
FTL *sharding constraint* family, DESIGN.md §2); the canonical fused /
partial / unfused schedules are priced alongside via ``plan_fixed``.  The
whole-block plan (projections + attention core + MLP through one
partitioner, executors bound by the registry) is reported per arch too."""
from __future__ import annotations

from repro import configs
from repro.core import hw
from repro.core.ftl import InfeasibleError, graph, partition, registry

from ._smoke import smoke

MB = 1 << 20
TOKENS = 8192                  # per-device microbatch tokens (train_4k-ish)
TP = 16                        # model-axis shards


def _tokens() -> int:
    return 512 if smoke() else TOKENS


def arch_mlp_dims(cfg):
    if cfg.is_moe:
        return cfg.d_model, cfg.moe_d_ff, cfg.mlp_gated   # per-expert FFN
    if cfg.family == "ssm":
        return None                                       # no classic MLP
    return cfg.d_model, cfg.d_ff, cfg.mlp_gated


def run() -> list[dict]:
    tokens = _tokens()
    rows = []
    for arch in configs.ARCHS:
        cfg = configs.get_config(arch)
        dims = arch_mlp_dims(cfg)
        if dims is None:
            rows.append({"arch": arch, "note": "no MLP (xLSTM block owns "
                         "its projections) — FTL applies to up/down proj"})
            continue
        d, f, gated = dims
        f_shard = f // TP if f % TP == 0 else f
        g = graph.mlp_graph(m=tokens, d_model=d, d_ff=f_shard, gated=gated,
                            act=cfg.mlp_act)
        chosen = partition.plan_chain(g, target=hw.TPU_V5E)
        unfused = partition.plan_fixed(g, partition.all_cuts(g),
                                       target=hw.TPU_V5E)
        try:
            fused = partition.plan_fixed(g, (), target=hw.TPU_V5E)
        except InfeasibleError:
            fused = None
        try:
            partial = partition.plan_fixed(g, (g.n_ops - 1,),
                                           target=hw.TPU_V5E)
        except InfeasibleError:
            partial = None
        try:
            block = registry.plan_block(cfg, m=tokens, target=hw.TPU_V5E)
            block_sched = block.schedule
        except (ValueError, InfeasibleError):
            block_sched = "-"
        unf_t = unfused.traffic_bytes
        fused_seg = fused.segments[0].plan if fused else None
        rows.append({
            "arch": arch,
            "mlp": f"{d}x{f_shard}" + ("(g)" if gated else ""),
            "schedule": chosen.schedule,
            "block_schedule": block_sched,
            "unfused_MiB": round(unf_t / MB, 1),
            "partial_MiB": round(partial.traffic_bytes / MB, 1)
            if partial else "-",
            "fused_MiB": round(fused.traffic_bytes / MB, 1)
            if fused else "-",
            "traffic_red_%": round(
                100 * (1 - chosen.traffic_bytes / unf_t), 1),
            "hbm_bound_speedup": round(unf_t / chosen.traffic_bytes, 2),
            "vmem_MiB": round(fused_seg.vmem_bytes / MB, 1)
            if fused_seg else "-",
            "tile_m": fused_seg.tile("M") if fused_seg else "-",
            "tile_f": fused_seg.tile("F") if fused_seg else "-",
        })
    return rows


def main() -> None:
    rows = run()
    keys = ["arch", "mlp", "schedule", "block_schedule", "unfused_MiB",
            "partial_MiB", "fused_MiB", "traffic_red_%",
            "hbm_bound_speedup", "vmem_MiB", "tile_m", "tile_f"]
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r.get(k, r.get("note", ""))) for k in keys))


if __name__ == "__main__":
    main()
