"""FTL at production scale on the TPU target: fused vs layer-per-layer
MLP traffic for every assigned architecture's MLP dims (the paper's
technique as deployed by this framework).

Reports the auto-fusion decision, HBM traffic both ways, the modeled
speedup at v5e bandwidth, and the VMEM footprint the plan claims — per
arch, at the per-shard sizes the 16×16 mesh actually sees (the FTL
*sharding constraint* family, DESIGN.md §2)."""
from __future__ import annotations

from repro import configs
from repro.core import ftl

from .hw_profiles import TPU_V5E

MB = 1 << 20
TOKENS = 8192                  # per-device microbatch tokens (train_4k-ish)
TP = 16                        # model-axis shards


def arch_mlp_dims(cfg):
    if cfg.is_moe:
        return cfg.d_model, cfg.moe_d_ff, cfg.mlp_gated   # per-expert FFN
    if cfg.family == "ssm":
        return None                                       # no classic MLP
    return cfg.d_model, cfg.d_ff, cfg.mlp_gated


def run() -> list[dict]:
    rows = []
    for arch in configs.ARCHS:
        cfg = configs.get_config(arch)
        dims = arch_mlp_dims(cfg)
        if dims is None:
            rows.append({"arch": arch, "note": "no MLP (xLSTM block owns "
                         "its projections) — FTL applies to up/down proj"})
            continue
        d, f, gated = dims
        f_shard = f // TP if f % TP == 0 else f
        out = ftl.plan_mlp(m=TOKENS, d_model=d, d_ff=f_shard,
                           gated=gated, act=cfg.mlp_act,
                           vmem_budget=96 * MB)
        fused_t = out.fused.traffic_bytes if out.fused else None
        part_t = (sum(p.traffic_bytes for p in out.partial)
                  if out.partial else None)
        unf_t = sum(p.traffic_bytes for p in out.unfused)
        chosen = out.chosen_traffic
        rows.append({
            "arch": arch,
            "mlp": f"{d}x{f_shard}" + ("(g)" if gated else ""),
            "schedule": out.schedule,
            "unfused_MiB": round(unf_t / MB, 1),
            "partial_MiB": round(part_t / MB, 1) if part_t else "-",
            "fused_MiB": round(fused_t / MB, 1) if fused_t else "-",
            "traffic_red_%": round(100 * (1 - chosen / unf_t), 1),
            "hbm_bound_speedup": round(unf_t / chosen, 2),
            "vmem_MiB": round(out.fused.vmem_bytes / MB, 1)
            if out.fused else "-",
            "tile_m": out.fused.tile("M") if out.fused else "-",
            "tile_f": out.fused.tile("F") if out.fused else "-",
        })
    return rows


def main() -> None:
    rows = run()
    keys = ["arch", "mlp", "schedule", "unfused_MiB", "partial_MiB",
            "fused_MiB", "traffic_red_%", "hbm_bound_speedup", "vmem_MiB",
            "tile_m", "tile_f"]
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r.get(k, r.get("note", ""))) for k in keys))


if __name__ == "__main__":
    main()
