"""Paper Fig. 3 reproduction: ViT MLP (GEMM -> GeLU), layer-per-layer vs
FTL, on Siracusa-like profiles (cluster-only and cluster+NPU).

Paper's reported numbers: -47.1 % DMA transfers; runtime -28.8 % (8-core
cluster), -60.1 % (cluster + NPU).

Two comparisons:

* **matched-tiling** — unfused schedule evaluated at the fused plan's tile
  sizes; isolates the pure fusion effect (what the paper measures: same
  kernels, intermediate round trip removed).
* **re-tiled** — each schedule gets its own optimal plan from the solver
  (what our framework actually deploys; fusion constraints can force
  smaller tiles, so DMA *count* may not drop even when bytes do).

Runtime model: GEMM on cluster or NPU; GeLU always on the cluster; fused
schedules overlap the epilogue with NPU GEMMs, unfused schedules serialize
a whole extra kernel + the intermediate's L2/L3 round trip (spill when it
exceeds free L2).  Platform constants are literature estimates — we report
the mechanism and our modeled numbers next to the paper's.
"""
from __future__ import annotations

from repro.core import ftl
from repro.core.ftl import graph, partition
from repro.core.ftl.cost import evaluate

from ._smoke import smoke
from .hw_profiles import (SIRACUSA_CLUSTER, SIRACUSA_NPU, TwoTierHW,
                          runtime_model_fused, runtime_model_unfused)

KB, MB = 1 << 10, 1 << 20

# ViT-Base MLP first half (the paper's benchmark): d=768, d_ff=3072, int8.
# M = token count; the headline row uses M=3072 (a throughput batch),
# where the int8 intermediate (M x 3072 = 9 MiB) exceeds free L2 -> the
# paper's L3-spill regime.
D_MODEL, D_FF = 768, 3072
DTYPE = "int8"


def plans(m: int, target):
    """Fused / unfused / matched-tiling plans via the graph partitioner,
    priced on a first-class memory-hierarchy ``Target``."""
    g = graph.gemm_act_graph(m=m, k=D_MODEL, n=D_FF, dtype=DTYPE)
    fused = partition.plan_fixed(g, (), target=target).segments[0].plan
    unfused = [
        s.plan
        for s in partition.plan_fixed(g, partition.all_cuts(g),
                                      target=target).segments
    ]
    # matched tiling: evaluate each unfused op at the fused plan's tiles
    matched = []
    for i in range(g.n_ops):
        og = g.group(i, i + 1)
        cons = ftl.build_dim_constraints(og)
        tiles = {d: min(fused.tiles[d], cons[d].size) for d in og.dims}
        matched.append(evaluate(og, tiles, cons, target=target))
    # the partitioner's own choice for this chain (reported per row)
    chosen = partition.plan_chain(g, target=target)
    return fused, unfused, matched, chosen


def bench_row(m: int, hw: TwoTierHW) -> dict:
    fused, unfused, matched, chosen = plans(m, hw.target())
    macs = m * D_MODEL * D_FF
    ew = m * D_FF
    inter = m * D_FF                           # int8 bytes

    gemm_p, ew_p = unfused
    rt_u = runtime_model_unfused(
        hw, macs=macs, ew_elems=ew,
        gemm_traffic=gemm_p.traffic_bytes, gemm_dma=gemm_p.dma_transfers,
        ew_traffic=ew_p.traffic_bytes, ew_dma=ew_p.dma_transfers,
        intermediate_bytes=inter)
    rt_f = runtime_model_fused(
        hw, macs=macs, ew_elems=ew,
        traffic=fused.traffic_bytes, dma=fused.dma_transfers)

    cmp_opt = ftl.compare(fused, unfused)
    m_traffic = sum(r.traffic_bytes for r in matched)
    m_dma = sum(r.dma_transfers for r in matched)
    per_level = chosen.per_level_traffic
    return {
        "M": m,
        "hw": hw.name,
        "auto_schedule": chosen.schedule,
        "plan_l2_MiB": round(per_level.get("l2", 0) / MB, 1),
        "plan_l3_MiB": round(per_level.get("l3", 0) / MB, 1),
        "plan_runtime_ms": round(1e3 * chosen.modeled_runtime_s, 2),
        "plan_bound": "compute" if chosen.compute_bound else "transfer",
        "traffic_red_matched_%": round(
            100 * (1 - fused.traffic_bytes / m_traffic), 1),
        "dma_red_matched_%": round(
            100 * (1 - fused.dma_transfers / m_dma), 1),
        "traffic_red_retiled_%": round(100 * cmp_opt.traffic_reduction, 1),
        "runtime_red_%": round(
            100 * (1 - rt_f["t_total_s"] / rt_u["t_total_s"]), 1),
        "unfused_ms": round(1e3 * rt_u["t_total_s"], 2),
        "fused_ms": round(1e3 * rt_f["t_total_s"], 2),
        "l3_spill_MiB": round(rt_u["l3_bytes"] / MB, 1),
    }


def run() -> list[dict]:
    rows = []
    for hw in (SIRACUSA_CLUSTER, SIRACUSA_NPU):
        rows.append(bench_row(512 if smoke() else 3072, hw))
    # L2-overflow cliff sweep on the NPU profile (spill starts ~M=683)
    sweep = (256, 1024) if smoke() else (256, 512, 1024, 3072, 12288)
    for m in sweep:
        rows.append(bench_row(m, SIRACUSA_NPU))
    return rows


PAPER = {"dma_reduction_%": 47.1,
         "runtime_reduction_cluster_%": 28.8,
         "runtime_reduction_npu_%": 60.1}


def main() -> None:
    rows = run()
    keys = list(rows[0].keys())
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r[k]) for k in keys))
    print(f"# paper: {PAPER}")


if __name__ == "__main__":
    main()
