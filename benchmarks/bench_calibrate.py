"""Measured-calibration benchmark: fit Target constants from this host's
wall-clock runs and gate modeled-vs-measured drift (the calibration
tentpole's CI artifact).

Sweeps the isolated microbenchmarks (GEMM / elementwise / DMA-proxy at
several shapes, ``repro.calib.microbench_sweep``) plus a
``bench_block``-style whole-block ref-vs-plan measurement, fits
effective per-level bandwidth / DMA setup and per-engine FLOP/s by NNLS
over the shared roofline model (``repro.calib.calibrate``), and writes
``BENCH_calibrate.json``: the fitted constants, per-measurement
residuals (base and calibrated side by side), and the drift-gate
verdict.  It also writes ``BENCH_calibrate_trace.json`` — the planned
block replayed through the DES with the measured span overlaid as a
second track (open at https://ui.perfetto.dev to eyeball the residual).

**CI gates** (or the run fails):

* *drift*: on the calibrated target the geometric-mean modeled/measured
  ratio over every measurement sits inside the band — the model tracks
  this host, it doesn't just rank plans;
* *tighter-than-base*: the calibrated target's mean |log residual| is
  strictly below the uncalibrated preset's — calibration must *improve*
  the fit, never ride on a lucky preset.
"""
from __future__ import annotations

import json

from repro import calib, sim
from repro.core import hw

from ._smoke import smoke

OUT = "BENCH_calibrate.json"
TRACE_OUT = "BENCH_calibrate_trace.json"

ARCH = "llama3.2-3b"

# the drift band: effective constants fitted on the same host should
# model it well within ~3x either way even on noisy shared CI runners;
# a model off by more than that is mispricing plans outright.
BAND = (0.3, 10 / 3)


def _params():
    if smoke():
        return {
            "gemm_shapes": ((256, 256, 256), (512, 512, 512)),
            "elementwise_sizes": (1 << 20, 1 << 22),
            "dma_sizes": (1 << 21, 1 << 23, 1 << 25),
            "block_m": 64,
            "repeats": 3,
        }
    return {
        "gemm_shapes": ((256, 256, 256), (512, 512, 512),
                        (1024, 512, 1024), (2048, 1024, 2048)),
        "elementwise_sizes": (1 << 20, 1 << 22, 1 << 23, 1 << 24),
        "dma_sizes": (1 << 21, 1 << 23, 1 << 25, 1 << 26, 1 << 27),
        "block_m": 128,
        "repeats": 7,
    }


def _residual_row(r: calib.Residual) -> dict:
    return {
        "name": r.name,
        "kind": r.kind,
        "in_fit": r.in_fit,
        "measured_ms": round(1e3 * r.measured_s, 4),
        "base_modeled_ms": round(1e3 * r.base_modeled_s, 4),
        "calibrated_modeled_ms": round(1e3 * r.calibrated_modeled_s, 4),
        "base_ratio": round(r.base_ratio, 4),
        "calibrated_ratio": round(r.calibrated_ratio, 4),
    }


def run(base: hw.Target | None = None) -> dict:
    base = base if base is not None else hw.default_target()
    p = _params()

    print(f"# calibrating against {base.name} "
          f"({'smoke' if smoke() else 'full'} sweep)")
    ms = calib.microbench_sweep(
        base=base,
        gemm_shapes=p["gemm_shapes"],
        elementwise_sizes=p["elementwise_sizes"],
        dma_sizes=p["dma_sizes"],
        repeats=p["repeats"],
    )
    ms += calib.measure_block(ARCH, p["block_m"], base=base,
                              repeats=p["repeats"])

    result = calib.calibrate(ms, base=base)
    gate = calib.drift_gate(result, band=BAND)
    print(result.summary())

    # Perfetto residual view: the planned block's simulated timeline with
    # its measured wall-clock span as a second track
    from repro.core.ftl import registry
    import dataclasses as _dc

    from repro import configs
    cfg = configs.get_config(ARCH).reduced()
    cfg = _dc.replace(cfg, dtype="float32", remat=False, ftl_mode="auto")
    plan = registry.plan_block(cfg, m=p["block_m"], dtype="float32",
                               target=base)
    block_ms = [m for m in ms if m.kind == "block"]
    sim.write_chrome_trace(plan, TRACE_OUT, measured=block_ms)
    print(f"# wrote {TRACE_OUT} (measured-vs-simulated residual view)")

    return {
        "base_target": base.name,
        "calibrated_target": result.target.name,
        "calibrated_describe": result.target.describe(),
        "n_iter": result.n_iter,
        "fitted": dict(result.fitted),
        "inherited": list(result.inherited),
        "residuals": [_residual_row(r) for r in result.residuals],
        "gate": gate,
        "params": {k: (list(v) if isinstance(v, tuple) else v)
                   for k, v in p.items()},
    }


def main() -> None:
    row = run()
    with open(OUT, "w") as f:
        json.dump({"smoke": smoke(), **row}, f, indent=2)
    print(f"# wrote {OUT}")

    g = row["gate"]
    if not g["in_band"]:
        raise SystemExit(
            f"CALIBRATION DRIFT GATE FAILED: geomean modeled/measured "
            f"{g['geomean_ratio']:.3f} outside band {g['band']}")
    if not g["residual_tighter_than_base"]:
        raise SystemExit(
            f"CALIBRATION GATE FAILED: calibrated residual "
            f"{g['mean_abs_log_residual']:.3f} not tighter than "
            f"uncalibrated base {g['base_mean_abs_log_residual']:.3f}")
    print(f"# gates OK: geomean ratio {g['geomean_ratio']:.3f} in "
          f"{g['band']}, residual {g['mean_abs_log_residual']:.3f} < "
          f"base {g['base_mean_abs_log_residual']:.3f}")


if __name__ == "__main__":
    main()
