"""Benchmark harness: one module per paper figure/claim + the roofline
table.  ``python -m benchmarks.run`` prints everything as CSV sections and
writes ``BENCH_sections.json`` (per-section status/timings, uploaded by
the CI bench-smoke job next to ``BENCH_block.json``).  ``BENCH_SMOKE=1``
runs every section on tiny shapes."""
from __future__ import annotations

import json
import sys
import time

from ._smoke import smoke

SECTIONS_OUT = "BENCH_sections.json"


def _write_status(results: list[dict]) -> None:
    with open(SECTIONS_OUT, "w") as f:
        json.dump({
            "smoke": smoke(),
            "sections": results,
        }, f, indent=2)


def main() -> None:
    from . import (bench_attention, bench_autotune, bench_block,
                   bench_calibrate, bench_mesh, bench_obs,
                   bench_paper_mlp, bench_roofline, bench_schedule,
                   bench_serve, bench_solver, bench_targets,
                   bench_tpu_mlp)

    sections = [
        ("targets: per-level traffic across memory hierarchies + gate",
         bench_targets.main),
        ("schedule-sim: tile-level DES replay vs analytic roofline + gate",
         bench_schedule.main),
        ("autotune: DES-scored search vs analytic argmin + gate",
         bench_autotune.main),
        ("paper-fig3: ViT MLP layer-per-layer vs FTL (Siracusa profiles)",
         bench_paper_mlp.main),
        ("ftl-at-scale: fused-vs-unfused MLP per assigned arch (TPU v5e)",
         bench_tpu_mlp.main),
        ("ftl-attention: fused-tiled attention traffic", bench_attention.main),
        ("ftl-solver: branch-and-bound performance", bench_solver.main),
        ("block-exec: layer-per-layer vs BlockPlan-driven whole block",
         bench_block.main),
        ("serve: continuous batching tokens/s + latency, open-loop + gate",
         bench_serve.main),
        ("mesh: collective-aware 1->N scaling + multi-port overlap + gate",
         bench_mesh.main),
        ("calibrate: fitted Target constants + modeled-vs-measured "
         "drift gate", bench_calibrate.main),
        ("obs: telemetry overhead + online drift monitor + gates",
         bench_obs.main),
        ("roofline: dry-run artifacts (per arch x shape x mesh)",
         bench_roofline.main),
    ]
    results: list[dict] = []
    for title, fn in sections:
        print(f"\n### {title}")
        t0 = time.time()
        try:
            fn()
        except Exception as e:                  # noqa: BLE001
            print(f"FAILED: {type(e).__name__}: {e}")
            results.append({"section": title, "ok": False,
                            "error": f"{type(e).__name__}: {e}"})
            _write_status(results)
            raise
        dt = time.time() - t0
        results.append({"section": title, "ok": True,
                        "seconds": round(dt, 1)})
        print(f"# section took {dt:.1f}s", file=sys.stderr)
    _write_status(results)


if __name__ == "__main__":
    main()
