"""Benchmark harness: one module per paper figure/claim + the roofline
table.  ``python -m benchmarks.run`` prints everything as CSV sections."""
from __future__ import annotations

import sys
import time


def main() -> None:
    from . import (bench_attention, bench_paper_mlp, bench_roofline,
                   bench_solver, bench_tpu_mlp)

    sections = [
        ("paper-fig3: ViT MLP layer-per-layer vs FTL (Siracusa profiles)",
         bench_paper_mlp.main),
        ("ftl-at-scale: fused-vs-unfused MLP per assigned arch (TPU v5e)",
         bench_tpu_mlp.main),
        ("ftl-attention: fused-tiled attention traffic", bench_attention.main),
        ("ftl-solver: branch-and-bound performance", bench_solver.main),
        ("roofline: dry-run artifacts (per arch x shape x mesh)",
         bench_roofline.main),
    ]
    for title, fn in sections:
        print(f"\n### {title}")
        t0 = time.time()
        try:
            fn()
        except Exception as e:                  # noqa: BLE001
            print(f"FAILED: {type(e).__name__}: {e}")
            raise
        print(f"# section took {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
