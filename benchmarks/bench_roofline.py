"""Roofline table from the dry-run artifacts (results/dryrun/*.json) —
the §Roofline section of EXPERIMENTS.md is generated from this."""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")

COLS = ["arch", "shape", "mesh", "t_compute_s", "t_memory_s",
        "t_collective_s", "dominant", "useful_flops_ratio", "mfu_bound"]


def load() -> list[dict]:
    rows = []
    for fn in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        with open(fn) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": rec["mesh"],
                         "dominant": rec.get("status", "?")})
            continue
        r = dict(rec["roofline"])
        r["temp_GiB"] = round(
            rec["memory"].get("temp_size_in_bytes", 0) / 2**30, 2)
        r["args_GiB"] = round(
            rec["memory"].get("argument_size_in_bytes", 0) / 2**30, 2)
        r["coll_MiB"] = round(
            rec["collectives"]["total_bytes"] / 2**20, 1)
        rows.append(r)
    return rows


def main() -> None:
    rows = load()
    if not rows:
        print("no dry-run artifacts found — run: "
              "python -m repro.launch.dryrun --all --both-meshes")
        return
    keys = COLS + ["temp_GiB", "args_GiB", "coll_MiB"]
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r.get(k, "-")) for k in keys))


if __name__ == "__main__":
    main()
