"""Whole-block execution: layer-per-layer vs BlockPlan-driven (tentpole).

Two comparisons per arch:

* **measured** — one transformer block executed for real on this host,
  reference path (``models/layers.block_layer`` with ``plan=None``,
  ``ftl_mode='off'``) vs plan-driven (``registry.run_block`` dispatching
  every planned segment to its bound executor).  Reduced configs so the
  wall-clock numbers are honest on CPU; on TPU the same harness times the
  Pallas kernels the registry binds there.
* **modeled** — the partitioner's HBM traffic and roofline runtime
  (Σ_segment max(compute, transfer), with a ``compute_bound`` flag) for
  the plan's schedule vs the all-unfused partition at production dims
  (the numbers the measured speedup should track on HBM-bound shapes —
  a compute-bound row predicts no speedup from fusion).

Writes ``BENCH_block.json`` (consumed by the CI bench-smoke artifact) and
prints both tables as CSV.  ``BENCH_SMOKE=1`` shrinks shapes/iterations.
"""

from __future__ import annotations

import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.core import hw
from repro.core.ftl import InfeasibleError, executor_block, partition, registry
from repro.models import layers

from . import _smoke

MB = 1 << 20
OUT = "BENCH_block.json"

# memory-hierarchy targets the modeled-traffic table sweeps: the serving
# TPU plus the paper's Siracusa-like RV32 hierarchy
TARGETS = (hw.TPU_V5E, hw.RV32_L1_L2)

# knob overrides (tests monkeypatch these); None resolves from the
# BENCH_SMOKE env at call time like every other section
ARCHS = None
EXEC_TOKENS = None
MODEL_TOKENS = None
ITERS = None


def _archs():
    if ARCHS is not None:
        return ARCHS
    if _smoke.smoke():
        return ("llama3.2-3b", "yi-6b")
    return ("llama3.2-3b", "yi-6b", "granite-20b")


def _exec_tokens():
    if EXEC_TOKENS is not None:
        return EXEC_TOKENS
    return (64,) if _smoke.smoke() else (128, 512)


def _model_tokens():
    if MODEL_TOKENS is not None:
        return MODEL_TOKENS
    return 512 if _smoke.smoke() else 8192


def _iters():
    if ITERS is not None:
        return ITERS
    return 2 if _smoke.smoke() else 10


def _layer_params(cfg, key):
    ks = jax.random.split(key, 2)
    dt = jnp.dtype(cfg.dtype)
    return {
        "ln1": layers.init_norm(cfg.d_model, cfg.norm, dt),
        "attn": layers.init_attention(cfg, ks[0]),
        "ln2": layers.init_norm(cfg.d_model, cfg.norm, dt),
        "mlp": layers.init_mlp(cfg, ks[1]),
    }


WARMUP = 1


def _best_ms(fn, x, iters, warmup=None):
    """min wall-clock ms over ``iters`` timed runs.  One untimed call
    compiles; ``warmup`` further *timed-path* iterations follow before
    the measured loop, so plan-cache lookups / dispatch setup that only
    the first post-compile call pays never land in a sample (the
    calibration loop consumes these numbers as ground truth)."""
    warmup = WARMUP if warmup is None else warmup
    fn(x).block_until_ready()  # compile
    for _ in range(warmup):
        fn(x).block_until_ready()
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(x).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return round(1e3 * best, 3)


def exec_rows() -> list[dict]:
    """Measured: reference vs plan-driven execution of one block."""
    rows = []
    for arch in _archs():
        base = configs.get_config(arch).reduced()
        base = dataclasses.replace(base, dtype="float32", remat=False)
        cfg_auto = dataclasses.replace(base, ftl_mode="auto")
        cfg_off = dataclasses.replace(base, ftl_mode="off")
        p = _layer_params(base, jax.random.PRNGKey(0))
        for m in _exec_tokens():
            plan = registry.plan_block(cfg_auto, m=m, dtype="float32")
            positions = jnp.arange(m)
            key = jax.random.PRNGKey(1)
            x = jax.random.normal(key, (1, m, base.d_model), jnp.float32)

            def plan_fn(xx, plan=plan, p=p, positions=positions):
                return registry.run_block(plan, p, xx, positions=positions)

            def ref_fn(xx, cfg=cfg_off, p=p, positions=positions):
                return layers.block_layer(cfg, p, xx, positions=positions)

            ms_plan = _best_ms(jax.jit(plan_fn), x, _iters())
            ms_ref = _best_ms(jax.jit(ref_fn), x, _iters())
            row = {
                "arch": arch,
                "m": m,
                "target": plan.target.name,
                "schedule": plan.schedule,
                "executors": executor_block.resolved_executors(
                    plan,
                    m=m,
                    dtype="float32",
                ),
                "ref_ms": ms_ref,
                "plan_ms": ms_plan,
                "speedup": round(ms_ref / ms_plan, 3) if ms_plan else "-",
                "n_repeats": _iters(),
                "warmup": WARMUP,
            }
            rows.append(row)
    return rows


def traffic_rows() -> list[dict]:
    """Modeled: planned vs all-unfused backing-store traffic at production
    dims, swept over memory-hierarchy targets (per-level bytes)."""
    rows = []
    m = _model_tokens()
    for arch in _archs():
        cfg = configs.get_config(arch)
        for target in TARGETS:
            try:
                plan = registry.plan_block(cfg, m=m, target=target)
            except (ValueError, InfeasibleError):
                continue
            g = plan.graph
            try:
                unf = partition.plan_fixed(
                    g,
                    partition.all_cuts(g),
                    target=target,
                )
            except InfeasibleError:
                unf = None
            row = {
                "arch": arch,
                "m": m,
                "target": target.name,
                "schedule": plan.schedule,
                "plan_MiB": round(plan.traffic_bytes / MB, 1),
                "plan_per_level_MiB": {
                    name: round(b / MB, 1)
                    for name, b in plan.per_level_traffic.items()
                },
                "plan_transfer_ms": round(
                    1e3 * plan.chain.transfer_time_s, 3
                ),
                "plan_compute_ms": round(1e3 * plan.chain.compute_time_s, 3),
                "plan_runtime_ms": round(
                    1e3 * plan.chain.modeled_runtime_s, 3
                ),
                "compute_bound": plan.chain.compute_bound,
            }
            if unf:
                row["unfused_MiB"] = round(unf.traffic_bytes / MB, 1)
                row["unfused_runtime_ms"] = round(
                    1e3 * unf.modeled_runtime_s, 3
                )
                row["traffic_red_%"] = round(
                    100 * (1 - plan.traffic_bytes / unf.traffic_bytes), 1
                )
            else:
                row["unfused_MiB"] = "infeasible"
                row["unfused_runtime_ms"] = "-"
                row["traffic_red_%"] = "-"
            rows.append(row)
    return rows


def _print_csv(rows: list[dict]) -> None:
    if not rows:
        print("(no rows)")
        return
    keys = list(rows[0].keys())
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r.get(k, "-")).replace(",", ";") for k in keys))


def main() -> None:
    ex = exec_rows()
    tr = traffic_rows()
    print("# measured: one block, reference vs plan-driven")
    _print_csv(ex)
    print("# modeled: planned vs unfused traffic at production dims")
    _print_csv(tr)
    result = {
        "platform": registry.platform(),
        "smoke": _smoke.smoke(),
        "measured": ex,
        "modeled_traffic": tr,
    }
    with open(OUT, "w") as f:
        json.dump(result, f, indent=2)
    print(f"# wrote {OUT}")


if __name__ == "__main__":
    main()
