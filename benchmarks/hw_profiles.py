"""Hardware profiles for the benchmark runtime models.

``SIRACUSA_*`` approximates the paper's evaluation platform (Siracusa
RISC-V SoC [Prasad et al., JSSC]): 8×RV32 cluster + N-EUREKA NPU, 256 KiB
L1 TCDM (software-managed, DMA-fed), on-chip L2 SRAM, off-chip L3 RAM
behind a HyperBus-class link.  Constants are order-of-magnitude estimates
from the Siracusa/PULP literature — the benchmark reports *relative*
runtime reductions (the paper's Fig. 3 metric), which are insensitive to
the absolute scale.

``TPU_V5E`` is the repo's target (task-specified constants).
"""
from __future__ import annotations

import dataclasses

from repro.core import hw as hwlib

KB = 1 << 10
MB = 1 << 20


@dataclasses.dataclass(frozen=True)
class TwoTierHW:
    """Software-managed scratchpad + two backing tiers (L2 on-chip, L3
    off-chip overflow) — the paper's memory system shape.

    ``gemm_on_accel``: GEMMs run on the accelerator while elementwise ops
    (GeLU) stay on the scalar cluster — the Siracusa NPU split.  A fused
    schedule can then overlap the cluster's epilogue with the NPU's next
    tile; an unfused schedule serializes a whole extra kernel + its DMA
    round trip (the paper's Fig. 3 asymmetry)."""
    name: str
    scratch_bytes: int          # L1 TCDM / VMEM (double-buffered by planner)
    l2_bytes: int               # on-chip L2 capacity *free for activations*
    l2_bw: float                # bytes/s L1<->L2 DMA
    l3_bw: float                # bytes/s L1<->L3 (off-chip overflow)
    macs_per_s: float           # peak MAC/s of the GEMM engine
    ew_per_s: float             # elementwise (GeLU-class) elems/s, cluster
    gemm_on_accel: bool = False
    dma_setup_s: float = 2e-6   # per-transfer setup cost (drives DMA count)

    def target(self) -> hwlib.Target:
        """This profile as a planning :class:`repro.core.hw.Target`:
        DMA-fed (double-buffered) scratchpad fast level, L2 +
        (unbounded-above) L3 backing — the same machine description the
        solver, partitioner and registry consume, so the runtime model
        and the planner agree.

        The ``macs_per_s``/``ew_per_s`` split is expressed as
        :class:`repro.core.hw.Engine`\\s (no private rate model left):
        with ``gemm_on_accel`` the GEMM engine and the elementwise
        cluster overlap (``compute_time_by_kind`` takes the max); on a
        cluster-only profile one engine runs both kinds serialized."""
        if self.gemm_on_accel:
            engines = (
                hwlib.Engine("npu", (("gemm", 2.0 * self.macs_per_s),)),
                hwlib.Engine("cluster", (("*", self.ew_per_s),)),
            )
        else:
            engines = (
                hwlib.Engine("cluster", (("gemm", 2.0 * self.macs_per_s),
                                         ("*", self.ew_per_s))),
            )
        return hwlib.Target(
            name=self.name,
            levels=(
                hwlib.MemoryLevel("l1", self.scratch_bytes, 8e9,
                                  buffer_depth=2),
                hwlib.MemoryLevel("l2", self.l2_bytes, self.l2_bw,
                                  dma_setup_s=self.dma_setup_s),
                hwlib.MemoryLevel("l3", 1 << 50, self.l3_bw,
                                  dma_setup_s=self.dma_setup_s),
            ),
            flops=2.0 * self.macs_per_s,
            engines=engines,
        )


# 8 RV32 cores, 2 int8 MACs/cycle/core SIMD @ ~370 MHz, ~50 % kernel
# efficiency -> ~3 GMAC/s; int8 GeLU ≈ LUT+requant ~10 cycles/elem.
SIRACUSA_CLUSTER = TwoTierHW(
    name="siracusa-cluster",
    scratch_bytes=256 * KB, l2_bytes=2 * MB,
    l2_bw=2.0e9, l3_bw=0.35e9, macs_per_s=3.0e9, ew_per_s=0.3e9)

# + N-EUREKA NPU: ~64 GMAC/s int8; GeLU still on the cluster.
SIRACUSA_NPU = TwoTierHW(
    name="siracusa-cluster+npu",
    scratch_bytes=256 * KB, l2_bytes=2 * MB,
    l2_bw=2.0e9, l3_bw=0.35e9, macs_per_s=64.0e9, ew_per_s=0.3e9,
    gemm_on_accel=True)

# TPU v5e: VMEM-centric view of the same model.  bf16 MXU: 197 TFLOP/s =
# 98.5 TMAC/s; HBM plays the L2 role; "L3" = remote chip HBM over ICI.
TPU_V5E = TwoTierHW(
    name="tpu-v5e",
    scratch_bytes=96 * MB, l2_bytes=16 * (1 << 30),
    l2_bw=819e9, l3_bw=50e9, macs_per_s=98.5e12, ew_per_s=0.9e12,
    gemm_on_accel=True, dma_setup_s=1e-6)


def _dma_time(hw: TwoTierHW, bytes_l2: float, bytes_l3: float,
              transfers: int) -> float:
    """DMA time via the shared per-level formula
    (``Target.transfer_time``: Σ bytes/bw + transfers·setup) on this
    profile's own planning target — no second bandwidth model."""
    return hw.target().transfer_time(
        {"l2": bytes_l2, "l3": bytes_l3}, {"l2": transfers})


def runtime_model_unfused(hw: TwoTierHW, *, macs: int, ew_elems: int,
                          gemm_traffic: int, gemm_dma: int,
                          ew_traffic: int, ew_dma: int,
                          intermediate_bytes: int) -> dict:
    """Layer-per-layer: GEMM kernel then a separate elementwise kernel,
    each overlapping its own DMA (double buffering) under the shared
    ``hw.modeled_runtime`` rule; the intermediate spills to L3 when it
    exceeds free L2 (the paper's ViT-MLP case).

    Both compute terms route through the shared per-engine model
    (``Target.compute_time_by_kind`` over this profile's engines) — the
    MAC/elementwise split is no longer a private refinement."""
    t = hw.target()
    spill = intermediate_bytes > hw.l2_bytes
    # gemm writes the intermediate; ew reads+writes it
    l3_g = intermediate_bytes if spill else 0
    l3_e = 2 * intermediate_bytes if spill else 0
    t_gemm = hwlib.modeled_runtime(
        t.compute_time_by_kind({"gemm": 2.0 * macs}),
        _dma_time(hw, gemm_traffic - l3_g, l3_g, gemm_dma))
    t_ew = hwlib.modeled_runtime(
        t.compute_time_by_kind({"elementwise": ew_elems}),
        _dma_time(hw, ew_traffic - l3_e, l3_e, ew_dma))
    return {"t_total_s": t_gemm + t_ew, "t_gemm_s": t_gemm, "t_ew_s": t_ew,
            "l3_bytes": l3_g + l3_e}


def runtime_model_fused(hw: TwoTierHW, *, macs: int, ew_elems: int,
                        traffic: int, dma: int) -> dict:
    """Fused: epilogue applied on the L1 tile.  With the NPU doing GEMMs
    the cluster's epilogue overlaps (``compute_time_by_kind`` takes the
    per-engine max); cluster-only serializes epilogue cycles onto the
    one engine.  No intermediate, no spill — then the shared
    ``hw.modeled_runtime`` overlap rule against the DMA time."""
    t_compute = hw.target().compute_time_by_kind(
        {"gemm": 2.0 * macs, "elementwise": ew_elems})
    t = hwlib.modeled_runtime(t_compute, _dma_time(hw, traffic, 0, dma))
    return {"t_total_s": t, "t_compute_s": t_compute}
