"""Mesh-scaling sweep: collective-aware planning + multi-port overlap
(the mesh-planning tentpole artifact + CI gate).

For ``tpu_v5e`` (ici port) and the multi-cluster ``rv32_mesh`` preset
(noc port) this captures a tensor-parallel transformer block at mesh
sizes 1→8 (:func:`repro.distributed.mesh_capture.capture_block` — the
per-chip shard with its all-reduces as first-class graph ops), plans it
with the collective-aware partition DP, and replays the plan through
the discrete-event simulator three ways:

* **aware** — the collective-aware plan on the multi-port DES (the
  interconnect stream overlaps memory DMA);
* **shared-port** — the *same* schedule replayed with every transfer
  serialized on one DMA cursor (the pre-multi-port model; the
  counterfactual that prices the overlap win);
* **blind** — the plan a collective-ignorant DP picks (cuts chosen on
  the stripped graph, then re-costed with the collectives restored).

Writes ``BENCH_mesh.json`` with modeled + simulated scaling curves and
an overlap-efficiency column (uploaded by the CI bench-mesh job).

**CI gates** (or the run fails):

* *overlap*: at mesh=2 on **every** preset, the simulated runtime with
  multi-port overlap must not exceed the serialized single-port
  replay's prediction — splitting the collective stream onto its own
  port can only help;
* *aware-beats-blind*: on **≥ 1** preset/mesh point the collective-aware
  DP must pick different cuts than the collective-blind DP *and* win on
  simulated runtime — the reason collectives are in the cost model at
  all.
"""
from __future__ import annotations

import json
import time

from repro import configs
from repro.core import hw
from repro.core.ftl import partition
from repro.distributed import mesh_capture
from repro.sim import lower_chain, simulate_chain

from ._smoke import smoke

OUT = "BENCH_mesh.json"

ARCH = "llama3.2-3b"
MESHES = (1, 2, 4, 8)
PRESETS = ("tpu_v5e", "rv32_mesh")


def _cfg():
    cfg = configs.get_config(ARCH)
    return cfg.reduced() if smoke() else cfg


def _m(target: hw.Target) -> int:
    if smoke():
        return 1024
    # full mode: big enough that segments tile into multi-step grids on
    # the TPU (overlap needs a pipeline); the rv32 mesh tiles at any m
    return 2048 if target.name == "tpu_v5e" else 1024


def mesh_row(cfg, target: hw.Target, m: int, n: int) -> dict:
    t0 = time.perf_counter()
    g = mesh_capture.capture_block(cfg, m=m, mesh_size=n)
    aware = partition.plan_chain(g, target=target)
    blind = mesh_capture.plan_collective_blind(g, target=target)
    lowered = lower_chain(aware)
    sim = simulate_chain(lowered)
    shared = simulate_chain(lowered, share_ports=True)
    sim_blind = simulate_chain(lower_chain(blind))
    plan_ms = round(1e3 * (time.perf_counter() - t0), 1)
    cuts_differ = aware.cuts() != blind.cuts()
    return {
        "mesh": n,
        "sharded": mesh_capture.shard_spec(cfg, n).any,
        "cuts": list(aware.cuts()),
        "modeled_runtime_ms": 1e3 * aware.modeled_runtime_s,
        "sim_runtime_ms": 1e3 * sim.runtime_s,
        "sim_shared_port_ms": 1e3 * shared.runtime_s,
        "overlap_win_%": round(
            100 * (1 - sim.runtime_s / shared.runtime_s), 2)
        if shared.runtime_s > 0 else 0.0,
        "overlap_efficiency": sim.overlap_efficiency,
        "busy_ms": {k: 1e3 * v for k, v in sim.busy_s.items()},
        "blind_cuts": list(blind.cuts()),
        "blind_sim_runtime_ms": 1e3 * sim_blind.runtime_s,
        "cuts_differ": cuts_differ,
        "aware_beats_blind": bool(
            cuts_differ
            and sim.runtime_s < sim_blind.runtime_s),
        "plan_and_sim_ms": plan_ms,
    }


def target_rows(cfg, target: hw.Target) -> dict:
    m = _m(target)
    rows = [mesh_row(cfg, target, m, n) for n in MESHES]
    base_model = rows[0]["modeled_runtime_ms"]
    base_sim = rows[0]["sim_runtime_ms"]
    for r in rows:
        r["modeled_speedup_vs_1"] = round(
            base_model / r["modeled_runtime_ms"], 3)
        r["sim_speedup_vs_1"] = round(base_sim / r["sim_runtime_ms"], 3)
    at2 = next(r for r in rows if r["mesh"] == 2)
    gate_overlap = (hw.round_time(at2["sim_runtime_ms"])
                    <= hw.round_time(at2["sim_shared_port_ms"]))
    return {
        "target": target.name,
        "interconnect": target.interconnect.name,
        "interconnect_port": target.interconnect.dma_port,
        "m": m,
        "mesh_sweep": rows,
        "gate_overlap_ok": gate_overlap,
        "aware_beats_blind": any(r["aware_beats_blind"] for r in rows),
    }


def run() -> dict:
    cfg = _cfg()
    targets = [target_rows(cfg, hw.get_target(p)) for p in PRESETS]
    return {
        "smoke": smoke(),
        "arch": cfg.name,
        "meshes": list(MESHES),
        "gate": "sim with multi-port overlap <= serialized single-port "
                "replay at mesh=2 on every preset AND collective-aware "
                "cuts beat collective-blind cuts somewhere",
        "targets": targets,
        "gate_overlap_ok": all(t["gate_overlap_ok"] for t in targets),
        "gate_aware_ok": any(t["aware_beats_blind"] for t in targets),
    }


def main() -> None:
    result = run()
    for t in result["targets"]:
        print(f"{t['target']} (link {t['interconnect']}"
              f"/{t['interconnect_port']}, m={t['m']}):")
        for r in t["mesh_sweep"]:
            mark = " <-- aware wins" if r["aware_beats_blind"] else ""
            print(f"  mesh {r['mesh']}: sim {r['sim_runtime_ms']:9.3f} ms "
                  f"(x{r['sim_speedup_vs_1']:.2f} vs mesh=1, overlap eff "
                  f"{r['overlap_efficiency']:.2f}, win "
                  f"{r['overlap_win_%']:+.2f}% vs single port), "
                  f"blind {r['blind_sim_runtime_ms']:9.3f} ms{mark}")
    with open(OUT, "w") as f:
        json.dump(result, f, indent=2)
    print(f"# wrote {OUT}")
    bad = [t["target"] for t in result["targets"]
           if not t["gate_overlap_ok"]]
    if bad:
        raise RuntimeError(
            f"mesh overlap gate FAILED on {bad}: multi-port simulated "
            f"runtime at mesh=2 must not exceed the serialized "
            f"single-port replay")
    if not result["gate_aware_ok"]:
        raise RuntimeError(
            "mesh planning gate FAILED: collective-aware cuts never "
            "beat collective-blind cuts on any preset/mesh point")


if __name__ == "__main__":
    main()
