"""Shared BENCH_SMOKE gate: one truthiness rule for every section."""

import os


def smoke() -> bool:
    """True when the CI bench-smoke job (or a user) sets BENCH_SMOKE."""
    return os.environ.get("BENCH_SMOKE", "") not in ("", "0")
