"""Checkpoint tests: roundtrip, async, retention, crash-safety, elastic
mesh-shape-agnostic restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.ckpt import CheckpointManager
from repro.train import steps as S


def small_state():
    cfg = configs.get_config("whisper-base").reduced()
    return S.init_train_state(cfg, jax.random.PRNGKey(0))


def assert_tree_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_roundtrip(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    state = small_state()
    cm.save(state, 7)
    like = jax.eval_shape(lambda: state)
    restored, step = cm.restore(like)
    assert step == 7
    assert_tree_equal(state, restored)


def test_async_save_then_restore(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    state = small_state()
    cm.save(state, 3, blocking=False)
    cm.wait()
    restored, step = cm.restore(jax.eval_shape(lambda: state))
    assert step == 3
    assert_tree_equal(state, restored)


def test_latest_step_and_retention(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep_n=2)
    state = small_state()
    for s in (1, 2, 3, 4):
        cm.save(state, s)
    assert cm.latest_step() == 4
    kept = sorted(os.listdir(tmp_path))
    assert kept == ["step_3", "step_4"]


def test_partial_write_invisible(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    state = small_state()
    cm.save(state, 5)
    # a crashed write leaves a .tmp dir — must not be visible as latest
    os.makedirs(tmp_path / "step_9.tmp")
    assert cm.latest_step() == 5
    # nor a dir without manifest
    os.makedirs(tmp_path / "step_8")
    assert cm.latest_step() == 5


def test_elastic_restore_with_shardings(tmp_path):
    """Restore under explicit (single-device) shardings — the same code
    path re-shards onto any mesh the restarted job brings up."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    cm = CheckpointManager(str(tmp_path))
    state = small_state()
    cm.save(state, 11)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    like = jax.eval_shape(lambda: state)
    shardings = jax.tree.map(
        lambda l: NamedSharding(mesh, P(*([None] * len(l.shape)))), like)
    restored, step = cm.restore(like, shardings=shardings)
    assert step == 11
    assert_tree_equal(state, restored)
    for leaf in jax.tree.leaves(restored):
        assert leaf.sharding.mesh.shape["data"] == 1


def test_restore_missing_raises(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        cm.restore({"x": jax.ShapeDtypeStruct((1,), jnp.float32)})
