"""Hypothesis property tests on the FTL solver's invariants."""
import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import ftl, hw
from repro.core.ftl.ir import aligned_divisors
from repro.core.ftl.solver import InfeasibleError

MB = 1 << 20


def T(budget: int) -> hw.Target:
    return hw.TPU_V5E.with_fast_capacity(budget)

dim = st.sampled_from([128, 256, 384, 512, 768, 1024, 2048, 4096])
budget = st.sampled_from([2 * MB, 8 * MB, 32 * MB, 96 * MB])


@settings(max_examples=40, deadline=None)
@given(m=dim, k=dim, n=dim, b=budget,
       gated=st.booleans(), dtype=st.sampled_from(["bfloat16", "float32"]))
def test_mlp_plan_invariants(m, k, n, b, gated, dtype):
    g = ftl.fusion.mlp(m=m, d_model=k, d_ff=n, dtype=dtype, gated=gated,
                       fuse=True)
    try:
        plan = ftl.solve(g, target=T(b))
    except InfeasibleError:
        return
    # 1. every tile divides its dim
    for d, t in plan.tiles.items():
        assert plan.constraints[d].size % t == 0
    # 2. VMEM constraint holds
    assert plan.vmem_bytes <= b
    # 3. traffic >= one-pass floor
    sizes = {d: c.size for d, c in plan.constraints.items()}
    floor = sum(t.bytes_full(sizes) for t in g.hbm_tensors())
    assert plan.traffic_bytes >= floor
    # 4. intermediates carry no HBM traffic
    for t in g.intermediate_tensors():
        assert t.name not in plan.report.per_tensor_traffic
    # 5. alignment lattice respected (or whole dim)
    for d, t in plan.tiles.items():
        c = plan.constraints[d]
        assert t % c.alignment == 0 or t == c.size


@settings(max_examples=30, deadline=None)
@given(m=dim, k=dim, n=dim, b=budget)
def test_fused_beats_or_equals_unfused_when_chosen(m, k, n, b):
    """The auto planner's decision is consistent with its own cost model."""
    out = ftl.plan_mlp(m=m, d_model=k, d_ff=n, target=T(b))
    unfused_traffic = sum(p.traffic_bytes for p in out.unfused)
    if out.use_fused:
        assert out.fused.traffic_bytes <= unfused_traffic
    assert out.chosen_traffic <= unfused_traffic


@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 1 << 16), align=st.sampled_from([1, 8, 16, 128]))
def test_aligned_divisors_props(n, align):
    cands = aligned_divisors(n, align)
    assert n in cands                       # whole dim always legal
    for c in cands:
        assert n % c == 0
        assert c % align == 0 or c == n


@settings(max_examples=20, deadline=None)
@given(m=dim, dims=st.lists(dim, min_size=2, max_size=4), b=budget)
def test_gemm_chain_invariants(m, dims, b):
    g = ftl.fusion.gemm_chain(m=m, dims_kn=dims, fuse=True)
    try:
        plan = ftl.solve(g, target=T(b))
    except InfeasibleError:
        return
    assert plan.vmem_bytes <= b
    for d, t in plan.tiles.items():
        assert plan.constraints[d].size % t == 0


@settings(max_examples=20, deadline=None)
@given(q=st.sampled_from([256, 1024, 4096]),
       kv=st.sampled_from([256, 1024, 8192]),
       dh=st.sampled_from([64, 128, 256]))
def test_attention_plan_invariants(q, kv, dh):
    plan = ftl.plan_attention(q_len=q, kv_len=kv, head_dim=dh)
    assert plan.tile("Dh") == dh            # contract_whole kernel policy
    assert plan.vmem_bytes <= plan.vmem_budget
