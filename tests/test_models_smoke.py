"""Per-architecture smoke tests (deliverable f): REDUCED same-family
configs, one forward + train step on CPU, asserting shapes + no NaNs.
Also checks prefill→decode consistency against the full forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as M
from repro.optim import OptConfig
from repro.train import steps as S

ARCHS = list(configs.ARCHS)


def make_inputs(cfg, b=2, s=32, seed=0):
    key = jax.random.PRNGKey(seed)
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["image_embeds"] = 0.01 * jax.random.normal(
            key, (b, cfg.n_image_tokens, cfg.d_model)).astype(cfg.dtype)
    if cfg.is_encoder_decoder:
        batch["frames"] = 0.01 * jax.random.normal(
            key, (b, cfg.encoder_seq, cfg.d_model)).astype(cfg.dtype)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = configs.get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 32
    batch = make_inputs(cfg, b, s)
    logits, aux = M.forward(cfg, params, batch)
    assert logits.shape == (b, s, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_runs_and_updates(arch):
    cfg = configs.get_config(arch).reduced()
    state = S.init_train_state(cfg, jax.random.PRNGKey(0))
    # warmup_steps=0: step 0 must apply a non-zero lr so params move
    step = jax.jit(S.make_train_step(cfg, None, OptConfig(warmup_steps=0)))
    batch = make_inputs(cfg)
    new_state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(new_state.step) == 1
    # at least one parameter changed
    changed = jax.tree.map(
        lambda a, b: bool(jnp.any(a != b)), state.params, new_state.params)
    assert any(jax.tree.leaves(changed))


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """decode(prefill(x[:s]), x[s]) logits == forward(x[:s+1]) last logits.

    MoE archs run with a large capacity factor: capacity-based token
    dropping is batch-dependent, so train-vs-serve parity only holds in
    the no-drop regime (a known property of GShard-style routing,
    DESIGN.md §7)."""
    cfg = configs.get_config(arch).reduced()
    cfg = dataclasses.replace(cfg, remat=False, capacity_factor=64.0)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    b, s = 2, 16
    batch = make_inputs(cfg, b, s + 1, seed=3)
    full = dict(batch)
    prompt = dict(batch, tokens=batch["tokens"][:, :s])

    logits_full, _ = M.forward(cfg, params, full)
    lp, cache = M.prefill(cfg, params, prompt, max_seq=s + 1)

    # prefill's last-position logits == forward at position s-1
    np.testing.assert_allclose(
        np.asarray(lp[:, 0], np.float32),
        np.asarray(logits_full[:, s - 1], np.float32), rtol=2e-3, atol=2e-3)

    # one decode step with token s
    tok = batch["tokens"][:, s:s + 1]
    ld, _ = M.decode_step(cfg, params, tok, cache, jnp.int32(s))
    np.testing.assert_allclose(
        np.asarray(ld[:, 0], np.float32),
        np.asarray(logits_full[:, s], np.float32), rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("arch", ["yi-6b", "recurrentgemma-9b",
                                  "xlstm-1.3b"])
def test_bf16_decode_path(arch):
    """bf16 configs exercise the decode dtype discipline (regression: the
    f32 carry bug only appeared at bf16)."""
    cfg = dataclasses.replace(configs.get_config(arch).reduced(),
                              dtype="bfloat16")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 16
    batch = make_inputs(cfg, b, s)
    _, cache = M.prefill(cfg, params, batch, max_seq=s + 2)
    tok = jnp.ones((b, 1), jnp.int32)
    logits, cache2 = M.decode_step(cfg, params, tok, cache, jnp.int32(s))
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    # cache dtypes preserved
    for a, bb in zip(jax.tree.leaves(cache), jax.tree.leaves(cache2)):
        assert a.dtype == bb.dtype


def test_multi_step_decode_matches_forward():
    """Greedy 4-token rollout: stepwise logits match teacher-forced fwd."""
    cfg = dataclasses.replace(configs.get_config("llama3.2-3b").reduced(),
                              remat=False)
    params = M.init_params(cfg, jax.random.PRNGKey(2))
    b, s, extra = 1, 8, 4
    batch = make_inputs(cfg, b, s + extra, seed=5)
    logits_full, _ = M.forward(cfg, params, batch)
    _, cache = M.prefill(cfg, params,
                         dict(batch, tokens=batch["tokens"][:, :s]),
                         max_seq=s + extra)
    for i in range(extra):
        tok = batch["tokens"][:, s + i:s + i + 1]
        ld, cache = M.decode_step(cfg, params, tok, cache, jnp.int32(s + i))
        np.testing.assert_allclose(
            np.asarray(ld[:, 0], np.float32),
            np.asarray(logits_full[:, s + i], np.float32),
            rtol=5e-3, atol=5e-3)


def test_local_window_ring_buffer_decode():
    """recurrentgemma's ring-buffered local-attention cache: decode beyond
    the window must match the full forward."""
    cfg = dataclasses.replace(
        configs.get_config("recurrentgemma-9b").reduced(), remat=False)
    w = cfg.local_window
    params = M.init_params(cfg, jax.random.PRNGKey(3))
    b = 1
    total = w + 8                     # cross the ring-buffer wrap point
    batch = make_inputs(cfg, b, total, seed=7)
    logits_full, _ = M.forward(cfg, params, batch)
    s = w + 2
    _, cache = M.prefill(cfg, params,
                         dict(batch, tokens=batch["tokens"][:, :s]),
                         max_seq=total)
    for i in range(3):
        tok = batch["tokens"][:, s + i:s + i + 1]
        ld, cache = M.decode_step(cfg, params, tok, cache, jnp.int32(s + i))
        np.testing.assert_allclose(
            np.asarray(ld[:, 0], np.float32),
            np.asarray(logits_full[:, s + i], np.float32),
            rtol=1e-2, atol=1e-2)


def test_param_shapes_no_allocation_matches_init():
    cfg = configs.get_config("whisper-base").reduced()
    shapes = M.param_shapes(cfg)
    real = M.init_params(cfg, jax.random.PRNGKey(0))
    assert jax.tree.map(lambda s: (s.shape, s.dtype), shapes) == \
        jax.tree.map(lambda a: (a.shape, a.dtype), real)


def test_init_cache_structure_matches_decode_output():
    cfg = configs.get_config("qwen2-moe-a2.7b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    cache = M.init_cache(cfg, 2, 32)
    tok = jnp.ones((2, 1), jnp.int32)
    _, cache2 = M.decode_step(cfg, params, tok, cache, jnp.int32(4))
    assert jax.tree.map(lambda a: a.shape, cache) == \
        jax.tree.map(lambda a: a.shape, cache2)


@pytest.mark.parametrize("arch", ["xlstm-1.3b", "recurrentgemma-9b"])
def test_sub_quadratic_flags(arch):
    assert configs.get_config(arch).sub_quadratic()


@pytest.mark.parametrize("arch", ["yi-6b", "qwen2-72b", "whisper-base",
                                  "llama-3.2-vision-90b"])
def test_quadratic_flags(arch):
    assert not configs.get_config(arch).sub_quadratic()
