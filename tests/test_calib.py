"""Calibration-loop tests: NNLS units, synthetic round-trip recovery
(hypothesis-fuzzed + deterministic ladder), structure preservation,
residual bookkeeping, the drift gate, and the measured-track trace
overlay.

The round-trip property is the fitter's contract: measurements
synthesized from a *known* target (optionally with bounded noise) must
let ``Target.calibrated`` recover bandwidth/FLOP-rate constants within
tolerance, with residuals strictly tighter than the uncalibrated base's.
No jax needed — synthesis prices features on the truth target through
the same shared roofline formula the fitter inverts.
"""
import dataclasses

import numpy as np
import pytest

from repro.calib import (COMPUTE, TRANSFER, CalibrationResult,
                         Measurement, SegmentFeatures, calibrate,
                         drift_gate, modeled_measurement_s, nnls)
from repro.core import hw

KB, MB = 1 << 10, 1 << 20


# ---------------------------------------------------------------------------
# NNLS
# ---------------------------------------------------------------------------

def test_nnls_exact_on_nonnegative_system():
    A = np.array([[1.0, 0.0], [0.0, 2.0], [1.0, 1.0]])
    x_true = np.array([2.0, 3.0])
    x = nnls(A, A @ x_true)
    assert np.allclose(x, x_true, atol=1e-8)


def test_nnls_clamps_negative_least_squares_solution():
    # unconstrained LS would want x[1] < 0; NNLS must keep it at 0
    A = np.array([[1.0, 1.0], [1.0, 1.0], [1.0, 0.0]])
    b = np.array([1.0, 1.0, 2.0])
    x = nnls(A, b)
    assert (x >= 0).all()
    assert x[1] == pytest.approx(0.0, abs=1e-12)
    # and beats the all-zero fit
    assert np.linalg.norm(A @ x - b) < np.linalg.norm(b)


def test_nnls_zero_rhs_gives_zero():
    assert np.allclose(nnls(np.eye(3), np.zeros(3)), 0.0)


# ---------------------------------------------------------------------------
# synthesis helpers
# ---------------------------------------------------------------------------

def _truth(llc_bw, dram_bw, rate, llc_setup=2e-7, dram_setup=1e-6):
    base = hw.CPU_CACHE
    return dataclasses.replace(
        base,
        levels=(
            base.levels[0],
            dataclasses.replace(base.levels[1], bw_bytes_per_s=llc_bw,
                                dma_setup_s=llc_setup),
            dataclasses.replace(base.levels[2], bw_bytes_per_s=dram_bw,
                                dma_setup_s=dram_setup),
        ),
        flops=rate,
    )


def _synth(truth, base, noise=None):
    """Measurement set priced on ``truth`` with ``base``-shaped features:
    compute rows (gemm + elementwise), transfer rows at sizes straddling
    the llc capacity, one mixed whole-'block' validation row."""
    rng = np.random.default_rng(0)

    def jitter(t):
        if noise is None:
            return t
        return t * float(1.0 + rng.uniform(-noise, noise))

    ms = []
    for m, k, n in ((256, 256, 256), (512, 512, 512), (1024, 512, 1024)):
        f = SegmentFeatures(flops_by_kind=(("gemm", 2.0 * m * k * n),))
        ms.append(Measurement(f"g{m}x{k}x{n}", "gemm",
                              jitter(f.compute_s(truth)), (f,),
                              branch=COMPUTE))
    for n in (1 << 20, 1 << 22, 1 << 23):
        f = SegmentFeatures(flops_by_kind=(("elementwise", float(n)),))
        ms.append(Measurement(f"e{n}", "elementwise",
                              jitter(f.compute_s(truth)), (f,),
                              branch=COMPUTE))
    for nbytes in (1 << 21, 1 << 23, 1 << 25, 1 << 26):
        homes = base.assign_homes({"src": nbytes, "dst": nbytes})
        by, nl = {}, {}
        for t in ("src", "dst"):
            lv = homes[t].name
            by[lv] = by.get(lv, 0) + nbytes
            nl[lv] = nl.get(lv, 0) + 1
        f = SegmentFeatures(bytes_by_level=tuple(sorted(by.items())),
                            transfers_by_level=tuple(sorted(nl.items())))
        ms.append(Measurement(f"d{nbytes}", "dma",
                              jitter(f.transfer_s(truth)), (f,),
                              branch=TRANSFER))
    blk = SegmentFeatures(flops_by_kind=(("gemm", 1e9),),
                          bytes_by_level=(("dram", 1 << 26),),
                          transfers_by_level=(("dram", 4),))
    ms.append(Measurement("blk", "block",
                          jitter(max(blk.compute_s(truth),
                                     blk.transfer_s(truth))), (blk,)))
    return ms


def _level_bw(target, name):
    return {lv.name: lv.bw_bytes_per_s for lv in target.backing}[name]


def _check_roundtrip(llc_bw, dram_bw, rate, noise=None, rtol=1e-3):
    truth = _truth(llc_bw, dram_bw, rate)
    base = hw.CPU_CACHE
    result = calibrate(_synth(truth, base, noise=noise), base=base)
    cal = result.target
    assert _level_bw(cal, "llc") == pytest.approx(llc_bw, rel=rtol)
    assert _level_bw(cal, "dram") == pytest.approx(dram_bw, rel=rtol)
    # engine-less base grew a single 'core' engine with the fitted rates
    assert [e.name for e in cal.engines] == ["core"]
    assert cal.engine_rate("gemm")[1] == pytest.approx(rate, rel=rtol)
    assert cal.engine_rate("elementwise")[1] == pytest.approx(rate,
                                                             rel=rtol)
    # residuals shrink vs the uncalibrated base (strictly, unless the
    # base already fit perfectly — it never does at these constants)
    assert result.mean_abs_log_residual < result.base_mean_abs_log_residual
    return result


# ---------------------------------------------------------------------------
# round-trip recovery
# ---------------------------------------------------------------------------

BW_LADDER = (5e9, 2e10, 1e11)
RATE_LADDER = (1e10, 3e11, 5e12)


def test_roundtrip_exact_recovery():
    """Noise-free synthesis: the fit inverts the roofline exactly."""
    result = _check_roundtrip(4e10, 1.2e10, 3e11, rtol=1e-6)
    assert result.geomean_ratio == pytest.approx(1.0, rel=1e-6)
    # the whole-block validation row is modeled right too: truth and
    # calibrated agree on a measurement the fit never saw
    blk = result.residuals_of("block")
    assert len(blk) == 1 and not blk[0].in_fit
    assert blk[0].calibrated_ratio == pytest.approx(1.0, rel=1e-3)


def test_roundtrip_with_bounded_noise():
    """±10% multiplicative noise: constants recovered within ~25% and
    residuals still shrink vs the uncalibrated base."""
    result = _check_roundtrip(4e10, 1.2e10, 3e11, noise=0.10, rtol=0.25)
    assert 0.7 < result.geomean_ratio < 1.4


def test_roundtrip_ladder():
    """Deterministic sweep of the property hypothesis fuzzes below."""
    for llc_bw in BW_LADDER:
        for rate in RATE_LADDER:
            _check_roundtrip(llc_bw, llc_bw / 4, rate)


try:
    import hypothesis.strategies as st
    from hypothesis import given, settings

    @settings(max_examples=20, deadline=None)
    @given(llc_bw=st.sampled_from(BW_LADDER),
           dram_over_llc=st.sampled_from((0.1, 0.25, 0.5)),
           rate=st.sampled_from(RATE_LADDER),
           noise=st.sampled_from((None, 0.02, 0.10)))
    def test_roundtrip_fuzz(llc_bw, dram_over_llc, rate, noise):
        _check_roundtrip(llc_bw, llc_bw * dram_over_llc, rate,
                         noise=noise, rtol=0.3 if noise else 1e-3)
except ImportError:  # pragma: no cover - hypothesis optional locally
    pass


def test_target_calibrated_staticmethod():
    truth = _truth(4e10, 1.2e10, 3e11)
    cal = hw.Target.calibrated(_synth(truth, hw.CPU_CACHE),
                               base=hw.CPU_CACHE)
    assert isinstance(cal, hw.Target)
    assert cal.name == "cpu_cache@calib"
    assert _level_bw(cal, "llc") == pytest.approx(4e10, rel=1e-3)


# ---------------------------------------------------------------------------
# structure preservation + inheritance
# ---------------------------------------------------------------------------

def test_calibrated_target_preserves_structure():
    truth = _truth(4e10, 1.2e10, 3e11)
    base = hw.CPU_CACHE
    cal = calibrate(_synth(truth, base), base=base).target
    assert [lv.name for lv in cal.levels] == [lv.name for lv in base.levels]
    assert [lv.capacity_bytes for lv in cal.levels] \
        == [lv.capacity_bytes for lv in base.levels]
    assert [lv.dma_port for lv in cal.levels] \
        == [lv.dma_port for lv in base.levels]
    assert [lv.buffer_depth for lv in cal.levels] \
        == [lv.buffer_depth for lv in base.levels]
    hash(cal)                                    # plan-cache key material


def test_unmeasured_constants_inherited_from_base():
    """No transfer rows at all: every level keeps the base's bandwidth
    and the result names the inherited constants."""
    truth = _truth(4e10, 1.2e10, 3e11)
    base = hw.CPU_CACHE
    compute_only = [m for m in _synth(truth, base) if m.branch == COMPUTE]
    result = calibrate(compute_only, base=base)
    for lv in ("llc", "dram"):
        assert _level_bw(result.target, lv) == _level_bw(base, lv)
    assert "bw:llc" in result.inherited
    assert "bw:dram" in result.inherited
    assert any(name.startswith("rate:") for name, _ in result.fitted)


def test_engine_base_keeps_engines_and_grafts_rates():
    """Calibrating an engine-carrying base (rv32_npu) fits the rate on
    the engine that routes the kind, leaves other engines alone."""
    base = hw.get_target("rv32_npu")
    truth_gemm, truth_ew = 9e10, 4.5e8     # vs preset 128e9 / 0.3e9
    ms = []
    for m, k, n in ((128, 128, 128), (256, 256, 256)):
        f = SegmentFeatures(flops_by_kind=(("gemm", 2.0 * m * k * n),))
        ms.append(Measurement(f"g{m}", "gemm",
                              2.0 * m * k * n / truth_gemm, (f,),
                              branch=COMPUTE))
    for n in (1 << 18, 1 << 20):
        f = SegmentFeatures(flops_by_kind=(("elementwise", float(n)),))
        ms.append(Measurement(f"e{n}", "elementwise", n / truth_ew, (f,),
                              branch=COMPUTE))
    cal = calibrate(ms, base=base).target
    assert {e.name for e in cal.engines} == {"npu", "cluster"}
    assert cal.engine_rate("gemm") == ("npu", pytest.approx(truth_gemm,
                                                            rel=1e-6))
    assert cal.engine_rate("elementwise")[1] == pytest.approx(truth_ew,
                                                              rel=1e-6)
    # the cluster's catch-all survives for kinds never measured
    assert cal.engine_rate("softmax")[0] == "cluster"
    # level constants untouched — no transfer rows
    assert [lv.bw_bytes_per_s for lv in cal.levels] \
        == [lv.bw_bytes_per_s for lv in base.levels]


# ---------------------------------------------------------------------------
# records + shared formula
# ---------------------------------------------------------------------------

def test_modeled_measurement_uses_shared_roofline():
    """Σ_seg repeat·max(compute, transfer) — hw.modeled_runtime, never a
    restated formula."""
    t = hw.CPU_CACHE
    seg = SegmentFeatures(flops_by_kind=(("gemm", 1e9),),
                          bytes_by_level=(("dram", 1 << 24),),
                          transfers_by_level=(("dram", 2),), repeat=3)
    m = Measurement("x", "block", 1.0, (seg, seg))
    expect = 2 * 3 * hw.modeled_runtime(
        t.compute_time_by_kind({"gemm": 1e9}),
        t.transfer_time({"dram": 1 << 24}, {"dram": 2}))
    assert modeled_measurement_s(t, m) == pytest.approx(expect)


def test_measurement_validation():
    seg = SegmentFeatures(flops_by_kind=(("gemm", 1.0),))
    with pytest.raises(ValueError):
        Measurement("x", "gemm", 0.0, (seg,))
    with pytest.raises(ValueError):
        Measurement("x", "gemm", 1.0, (seg,), branch="bogus")
    with pytest.raises(ValueError):
        Measurement("x", "gemm", 1.0, ())


def test_calibrate_requires_fit_rows():
    seg = SegmentFeatures(flops_by_kind=(("gemm", 1.0),))
    with pytest.raises(ValueError, match="branch hint"):
        calibrate([Measurement("x", "block", 1.0, (seg,))],
                  base=hw.CPU_CACHE)


def test_drift_gate_verdicts():
    truth = _truth(4e10, 1.2e10, 3e11)
    result = calibrate(_synth(truth, hw.CPU_CACHE), base=hw.CPU_CACHE)
    ok = drift_gate(result)
    assert ok["ok"] and ok["in_band"] and ok["residual_tighter_than_base"]
    assert ok["n_fit"] == len(result.residuals) - 1   # block row held out
    # a band the perfect fit cannot sit in fails the gate
    bad = drift_gate(result, band=(5.0, 10.0))
    assert not bad["ok"] and not bad["in_band"]


def test_calibration_result_summary_mentions_constants():
    truth = _truth(4e10, 1.2e10, 3e11)
    result = calibrate(_synth(truth, hw.CPU_CACHE), base=hw.CPU_CACHE)
    assert isinstance(result, CalibrationResult)
    text = result.summary()
    assert "bw:llc" in text and "rate:core:gemm" in text
    assert "geomean" in text


# ---------------------------------------------------------------------------
# measured-track trace overlay
# ---------------------------------------------------------------------------

def test_chrome_trace_measured_track():
    from repro import sim
    from repro.core.ftl import graph, partition

    g = graph.mlp_graph(m=512, d_model=256, d_ff=512)
    chain = partition.plan_chain(g, target=hw.CPU_CACHE)
    seg = SegmentFeatures(flops_by_kind=(("gemm", 1e9),))
    ms = [Measurement("blk_measured", "block", 2.5e-3, (seg,)),
          ("ref_measured", 1.5e-3)]
    trace = sim.to_chrome_trace(chain, measured=ms)
    names = {e["args"]["name"] for e in trace["traceEvents"]
             if e.get("ph") == "M" and e["name"] == "thread_name"}
    assert "measured" in names
    spans = [e for e in trace["traceEvents"]
             if e.get("cat") == "measured"]
    assert [s["name"] for s in spans] == ["blk_measured", "ref_measured"]
    assert spans[0]["dur"] == pytest.approx(2.5e3)   # µs
    # laid out sequentially
    assert spans[1]["ts"] == pytest.approx(spans[0]["dur"])
    # without measured= the track does not exist (back-compat)
    base_trace = sim.to_chrome_trace(chain)
    names = {e["args"]["name"] for e in base_trace["traceEvents"]
             if e.get("ph") == "M" and e["name"] == "thread_name"}
    assert "measured" not in names
