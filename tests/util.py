"""Test helpers: run a python snippet in a subprocess with N host devices
(multi-device tests must not pollute the main test process's jax)."""
from __future__ import annotations

import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 560
                     ) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_devices}")
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, "-c", code], env=env, timeout=timeout,
        capture_output=True, text=True)


def check(proc: subprocess.CompletedProcess) -> None:
    assert proc.returncode == 0, (
        f"subprocess failed\nSTDOUT:\n{proc.stdout[-3000:]}\n"
        f"STDERR:\n{proc.stderr[-3000:]}")
