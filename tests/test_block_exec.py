"""BlockPlan-driven execution (registry.run_block) vs the layer-per-layer
reference path: numerical equivalence in fp32 on CPU across gated/ungated
MLPs, causal/non-causal attention and multiple zoo configs; runtime
requalification fallback; the enriched registry.find diagnostics; and the
bench_block artifact shape."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.ftl import executor_block, registry
from repro.models import layers
from repro.models import model as M

TOL = dict(rtol=2e-5, atol=2e-5)


def _fp32(arch, **over):
    return dataclasses.replace(configs.get_config(arch).reduced(),
                               dtype="float32", remat=False, **over)


def _layer_params(cfg, seed=0):
    # the single-block param builder lives with the benchmark so the
    # equivalence tests exercise exactly the params the bench times
    bench_block = pytest.importorskip("benchmarks.bench_block")
    return bench_block._layer_params(cfg, jax.random.PRNGKey(seed))


def _x(cfg, m=32, b=2, seed=1):
    return jax.random.normal(jax.random.PRNGKey(seed),
                             (b, m, cfg.d_model), jnp.float32)


# ---------------------------------------------------------------------------
# numerical equivalence: plan-driven == layer-per-layer
# ---------------------------------------------------------------------------

class TestRunBlockEquivalence:
    # two zoo configs with opposite MLP/norm conventions: llama3.2-3b is
    # gated-silu/rmsnorm/no-bias, granite-20b is plain-gelu/layernorm
    # with qkv+mlp biases
    @pytest.mark.parametrize("arch", ["llama3.2-3b", "granite-20b"])
    @pytest.mark.parametrize("gated", [False, True])
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_reference(self, arch, gated, causal):
        cfg = _fp32(arch, mlp_gated=gated)
        p = _layer_params(cfg)
        x = _x(cfg)
        pos = jnp.arange(x.shape[1])
        plan = registry.plan_block(
            dataclasses.replace(cfg, ftl_mode="auto"),
            m=x.shape[1], dtype="float32")
        y_plan = registry.run_block(plan, p, x, positions=pos,
                                    causal=causal)
        y_ref = layers.block_layer(cfg, p, x, positions=pos, plan=None,
                                   causal=causal)
        np.testing.assert_allclose(y_plan, y_ref, **TOL)

    def test_matches_under_jit(self):
        cfg = _fp32("llama3.2-3b")
        p = _layer_params(cfg)
        x = _x(cfg)
        pos = jnp.arange(x.shape[1])
        plan = registry.plan_block(
            dataclasses.replace(cfg, ftl_mode="auto"),
            m=x.shape[1], dtype="float32")
        y_jit = jax.jit(
            lambda xx: registry.run_block(plan, p, xx, positions=pos))(x)
        y_ref = layers.block_layer(cfg, p, x, positions=pos, plan=None)
        np.testing.assert_allclose(y_jit, y_ref, **TOL)

    def test_stale_tpu_bindings_fall_back_per_segment(self):
        """A plan whose bindings were made on TPU must requalify at run
        time and fall back to the XLA executors segment by segment."""
        cfg = _fp32("llama3.2-3b", ftl_mode="auto")
        p = _layer_params(cfg)
        x = _x(cfg)
        pos = jnp.arange(x.shape[1])
        plan = registry.plan_block(cfg, m=x.shape[1], dtype="float32")
        pallas = {"gemm": "pallas_gemm",
                  "attention": "pallas_flash_attention",
                  "mlp": "pallas_fused_mlp"}
        stale = dataclasses.replace(
            plan,
            platform="tpu",
            bindings=tuple(dataclasses.replace(b, executor=pallas[b.kind])
                           for b in plan.bindings))
        y = registry.run_block(stale, p, x, positions=pos)
        y_ref = layers.block_layer(
            dataclasses.replace(cfg, ftl_mode="off"), p, x,
            positions=pos, plan=None)
        np.testing.assert_allclose(y, y_ref, **TOL)
        execs = executor_block.resolved_executors(stale, dtype="float32")
        assert all(not name.startswith("pallas") for name in execs.values())

    def test_ftl_mode_off_pins_baseline_executors(self):
        """ftl_mode='off' is the full escape hatch: even with (stale)
        Pallas bindings in the plan, every stage runs the baseline
        executors and the output matches the hand-sequenced path."""
        cfg = _fp32("llama3.2-3b", ftl_mode="off")
        p = _layer_params(cfg)
        x = _x(cfg)
        pos = jnp.arange(x.shape[1])
        plan = registry.plan_block(cfg, m=x.shape[1], dtype="float32")
        pallas = {"gemm": "pallas_gemm",
                  "attention": "pallas_flash_attention",
                  "mlp": "pallas_fused_mlp"}
        stale = dataclasses.replace(
            plan,
            platform="tpu",
            bindings=tuple(dataclasses.replace(b, executor=pallas[b.kind])
                           for b in plan.bindings))
        y = registry.run_block(stale, p, x, positions=pos)
        y_ref = layers.block_layer(cfg, p, x, positions=pos, plan=None)
        np.testing.assert_allclose(y, y_ref, **TOL)

    def test_mlp_only_plan_runs_local_attention_fallback(self):
        """Hybrid config: the plannable block is MLP-only (leading 'rec'
        kind); run_block must still execute the local-attention stage via
        the runtime-fallback executor, matching the reference."""
        cfg = _fp32("recurrentgemma-9b", ftl_mode="auto")
        p = _layer_params(cfg)
        x = _x(cfg, m=64)
        pos = jnp.arange(64)
        plan = registry.plan_block(cfg, m=64, dtype="float32")
        assert plan.attention_schedule == "none"
        y = registry.run_block(plan, p, x, positions=pos,
                               window=cfg.local_window)
        y_ref = layers.block_layer(cfg, p, x, positions=pos, plan=None,
                                   window=cfg.local_window)
        np.testing.assert_allclose(y, y_ref, **TOL)


# ---------------------------------------------------------------------------
# forward: the plan path is the execution authority, and it matches the
# hand-sequenced path end to end
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["llama3.2-3b", "recurrentgemma-9b"])
def test_forward_plan_vs_handsequenced(arch, monkeypatch):
    cfg = _fp32(arch, ftl_mode="auto")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.arange(2 * 16).reshape(2, 16) % cfg.vocab_size}
    assert M._block_plan(cfg, 16, cfg.dtype) is not None
    y_plan, _ = M.forward(cfg, params, batch)
    monkeypatch.setattr(M, "_block_plan", lambda *a, **k: None)
    y_ref, _ = M.forward(cfg, params, batch)
    np.testing.assert_allclose(y_plan, y_ref, **TOL)


def test_forward_skips_planning_when_ftl_off():
    """ftl_mode='off' is the zero-cost escape hatch: no plan is built
    (no trace-time solver work) and the hand-sequenced path runs."""
    cfg = _fp32("llama3.2-3b")
    assert cfg.ftl_mode == "off"
    assert M._block_plan(cfg, 16, cfg.dtype) is None


def test_serve_engine_executes_block_plan():
    from repro.launch.serve import ServeEngine
    cfg = _fp32("llama3.2-3b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch_slots=1, max_seq=32)
    entry = eng.execute_block_plan()
    assert entry is not None
    assert entry["finite"]
    assert entry["ms"] > 0
    assert set(entry["executors"]) == {"gemm", "attention", "mlp"}
    # default ftl_mode='off' must report the baseline executors it ran,
    # not the plan's bindings
    assert entry["executors"]["mlp"] == "xla_unfused_mlp"
    assert eng.stats["block_exec"] is entry


def test_serve_engine_executes_block_plan_hybrid():
    """Hybrid configs (leading 'rec' positions) still execute their
    stored plan through the first local-attention layer."""
    from repro.launch.serve import ServeEngine
    cfg = _fp32("recurrentgemma-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch_slots=1, max_seq=32)
    entry = eng.execute_block_plan()
    assert entry is not None
    assert entry["finite"]


# ---------------------------------------------------------------------------
# registry.find diagnostics (satellite fix)
# ---------------------------------------------------------------------------

class TestFindDiagnostics:
    def test_unknown_kind_message_carries_context(self):
        ctx = registry.ExecContext(kind="conv", platform="cpu",
                                   schedule="fused", m=128)
        with pytest.raises(LookupError) as ei:
            registry.find("conv", ctx)
        msg = str(ei.value)
        assert "kind='conv'" in msg
        assert "platform='cpu'" in msg
        assert "schedule='fused'" in msg
        assert "m=128" in msg
        assert "none registered" in msg

    def test_message_lists_considered_executors(self):
        ex = registry.Executor(name="never_qualifies_test", kind="testkind",
                               backend="xla", priority=7,
                               qualifies=lambda c: False)
        registry.register(ex)
        try:
            ctx = registry.ExecContext(kind="testkind", platform="cpu",
                                       schedule="fused")
            with pytest.raises(LookupError) as ei:
                registry.find("testkind", ctx)
            assert "never_qualifies_test (backend=xla, priority=7)" in \
                str(ei.value)
        finally:
            del registry._REGISTRY["never_qualifies_test"]


# ---------------------------------------------------------------------------
# bench_block artifact (consumed by the CI bench-smoke job)
# ---------------------------------------------------------------------------

def test_bench_block_writes_wellformed_json(tmp_path, monkeypatch):
    bench_block = pytest.importorskip("benchmarks.bench_block")
    # knob overrides resolve at call time (None = BENCH_SMOKE default)
    monkeypatch.setattr(bench_block, "ARCHS", ("llama3.2-3b",))
    monkeypatch.setattr(bench_block, "EXEC_TOKENS", (32,))
    monkeypatch.setattr(bench_block, "MODEL_TOKENS", 128)
    monkeypatch.setattr(bench_block, "ITERS", 1)
    monkeypatch.chdir(tmp_path)
    bench_block.main()
    data = json.loads((tmp_path / "BENCH_block.json").read_text())
    assert data["measured"] and data["modeled_traffic"]
    for row in data["measured"]:
        assert {"arch", "m", "schedule", "executors", "ref_ms",
                "plan_ms"} <= set(row)
        assert row["ref_ms"] > 0 and row["plan_ms"] > 0
    for row in data["modeled_traffic"]:
        assert {"arch", "m", "schedule", "plan_MiB"} <= set(row)
