"""Serving-engine integration tests: continuous batching, cache splicing,
greedy parity with the raw model loop."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.launch.serve import Request, ServeEngine
from repro.models import model as M


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(configs.get_config("llama3.2-3b").reduced(),
                              remat=False)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def greedy_reference(cfg, params, prompt, n_new):
    """Raw prefill+decode greedy loop (no engine)."""
    toks = jnp.asarray(prompt)[None]
    logits, cache = M.prefill(cfg, params, {"tokens": toks},
                              max_seq=len(prompt) + n_new + 1)
    out = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    for _ in range(n_new - 1):
        tok = jnp.asarray([[out[-1]]], jnp.int32)
        logits, cache = M.decode_step(cfg, params, tok, cache,
                                      jnp.int32(pos))
        out.append(int(jnp.argmax(logits[0, 0])))
        pos += 1
    return out


def test_engine_single_request_matches_reference(setup):
    cfg, params = setup
    rng = np.random.default_rng(0)
    prompt = rng.integers(2, cfg.vocab_size, size=8).astype(np.int32)
    n_new = 6
    ref = greedy_reference(cfg, params, prompt, n_new)

    eng = ServeEngine(cfg, params, batch_slots=1, max_seq=32,
                      eos_id=-1)  # never EOS
    done = eng.run([Request(0, prompt, n_new)], {})
    assert done[0].out[:n_new] == ref


def test_engine_serves_more_requests_than_slots(setup):
    cfg, params = setup
    rng = np.random.default_rng(1)
    reqs = [Request(i, rng.integers(2, cfg.vocab_size, size=6)
                    .astype(np.int32), 4) for i in range(5)]
    eng = ServeEngine(cfg, params, batch_slots=2, max_seq=32, eos_id=-1)
    done = eng.run(list(reqs), {})
    assert sorted(r.rid for r in done) == [0, 1, 2, 3, 4]
    assert all(len(r.out) == 4 for r in done)
    assert eng.stats["prefills"] == 5


def test_engine_batched_equals_single(setup):
    """Tokens produced with 2 concurrent slots == served alone."""
    cfg, params = setup
    rng = np.random.default_rng(2)
    prompts = [rng.integers(2, cfg.vocab_size, size=8).astype(np.int32)
               for _ in range(2)]

    solo = []
    for i, pr in enumerate(prompts):
        eng = ServeEngine(cfg, params, batch_slots=1, max_seq=32, eos_id=-1)
        solo.append(eng.run([Request(i, pr, 4)], {})[0].out)

    eng = ServeEngine(cfg, params, batch_slots=2, max_seq=32, eos_id=-1)
    done = eng.run([Request(i, pr, 4) for i, pr in enumerate(prompts)], {})
    by_rid = {r.rid: r.out for r in done}
    assert by_rid[0] == solo[0]
    assert by_rid[1] == solo[1]
