"""Serving-engine integration tests: continuous batching, cache splicing,
greedy parity with the raw model loop."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.launch.serve import Request, ServeEngine
from repro.models import model as M


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(configs.get_config("llama3.2-3b").reduced(),
                              remat=False)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def greedy_reference(cfg, params, prompt, n_new):
    """Raw prefill+decode greedy loop (no engine)."""
    toks = jnp.asarray(prompt)[None]
    logits, cache = M.prefill(cfg, params, {"tokens": toks},
                              max_seq=len(prompt) + n_new + 1)
    out = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    for _ in range(n_new - 1):
        tok = jnp.asarray([[out[-1]]], jnp.int32)
        logits, cache = M.decode_step(cfg, params, tok, cache,
                                      jnp.int32(pos))
        out.append(int(jnp.argmax(logits[0, 0])))
        pos += 1
    return out


def test_engine_single_request_matches_reference(setup):
    cfg, params = setup
    rng = np.random.default_rng(0)
    prompt = rng.integers(2, cfg.vocab_size, size=8).astype(np.int32)
    n_new = 6
    ref = greedy_reference(cfg, params, prompt, n_new)

    eng = ServeEngine(cfg, params, batch_slots=1, max_seq=32,
                      eos_id=-1)  # never EOS
    done = eng.run([Request(0, prompt, n_new)], {})
    assert done[0].out[:n_new] == ref


def test_engine_serves_more_requests_than_slots(setup):
    cfg, params = setup
    rng = np.random.default_rng(1)
    reqs = [Request(i, rng.integers(2, cfg.vocab_size, size=6)
                    .astype(np.int32), 4) for i in range(5)]
    eng = ServeEngine(cfg, params, batch_slots=2, max_seq=32, eos_id=-1)
    done = eng.run(list(reqs), {})
    assert sorted(r.rid for r in done) == [0, 1, 2, 3, 4]
    assert all(len(r.out) == 4 for r in done)
    assert eng.stats["prefills"] == 5


def test_engine_batched_equals_single(setup):
    """Tokens produced with 2 concurrent slots == served alone."""
    cfg, params = setup
    rng = np.random.default_rng(2)
    prompts = [rng.integers(2, cfg.vocab_size, size=8).astype(np.int32)
               for _ in range(2)]

    solo = []
    for i, pr in enumerate(prompts):
        eng = ServeEngine(cfg, params, batch_slots=1, max_seq=32, eos_id=-1)
        solo.append(eng.run([Request(i, pr, 4)], {})[0].out)

    eng = ServeEngine(cfg, params, batch_slots=2, max_seq=32, eos_id=-1)
    done = eng.run([Request(i, pr, 4) for i, pr in enumerate(prompts)], {})
    by_rid = {r.rid: r.out for r in done}
    assert by_rid[0] == solo[0]
    assert by_rid[1] == solo[1]


# ---------------------------------------------------------------------------
# continuous batching on planned schedules: mixed lengths, paged KV,
# bucket ladder, split prefill/decode plans
# ---------------------------------------------------------------------------

from repro.core import hw                                   # noqa: E402
from repro.core.ftl import registry as ftl_registry          # noqa: E402
from repro.launch import kv_cache as KV                      # noqa: E402
from repro.launch.serve import poisson_arrivals              # noqa: E402


def test_engine_mixed_lengths_match_reference(setup):
    """Two slots at different positions (5- and 11-token prompts) decode
    together; each must match its solo no-engine greedy loop — the
    per-slot position vector plus bucket padding at work."""
    cfg, params = setup
    rng = np.random.default_rng(3)
    prompts = [rng.integers(2, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 11)]
    n_new = 5
    refs = [greedy_reference(cfg, params, p, n_new) for p in prompts]

    eng = ServeEngine(cfg, params, batch_slots=2, max_seq=32, eos_id=-1)
    done = eng.run([Request(i, p, n_new) for i, p in enumerate(prompts)],
                   {})
    by_rid = {r.rid: r.out for r in done}
    assert by_rid[0][:n_new] == refs[0]
    assert by_rid[1][:n_new] == refs[1]
    # the two prompts landed in different prefill buckets
    assert sorted(eng.stats["bucket_admissions"]) == [8, 16]


def test_paged_equals_dense(setup):
    """The paged KV cache (block pool + tables + gather/scatter) is a
    pure layout change: token streams must match the dense cache."""
    cfg, params = setup
    assert KV.paged_supported(cfg)
    rng = np.random.default_rng(4)
    prompts = [rng.integers(2, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 11, 8, 3)]
    reqs = lambda: [Request(i, p, 4) for i, p in enumerate(prompts)]  # noqa: E731

    eng_p = ServeEngine(cfg, params, batch_slots=2, max_seq=32,
                        block_size=8, eos_id=-1)
    assert eng_p.paged
    out_p = {r.rid: r.out for r in eng_p.run(reqs(), {})}
    eng_d = ServeEngine(cfg, params, batch_slots=2, max_seq=32,
                        paged=False, eos_id=-1)
    out_d = {r.rid: r.out for r in eng_d.run(reqs(), {})}
    assert out_p == out_d


def test_eviction_returns_pages_and_refills(setup):
    """EOS/max-len eviction frees a slot *and* its pages; queued requests
    refill the slot and the pool drains back to full when idle."""
    cfg, params = setup
    rng = np.random.default_rng(5)
    reqs = [Request(i, rng.integers(2, cfg.vocab_size, size=6 + 3 * i)
                    .astype(np.int32), 3) for i in range(5)]
    eng = ServeEngine(cfg, params, batch_slots=2, max_seq=32,
                      block_size=8, eos_id=-1)
    total = eng.kv.free_blocks
    done = eng.run(reqs, {})
    assert sorted(r.rid for r in done) == [0, 1, 2, 3, 4]
    assert eng.stats["prefills"] == 5
    assert all(r is None for r in eng.active)
    assert eng.kv.free_blocks == total          # every page returned


def test_kv_admission_control_under_pressure(setup):
    """A pool too small for every slot at once defers admission instead
    of corrupting state; all requests still finish."""
    cfg, params = setup
    rng = np.random.default_rng(6)
    reqs = [Request(i, rng.integers(2, cfg.vocab_size, size=10)
                    .astype(np.int32), 3) for i in range(4)]
    # 3 slots x 4 blocks/slot = 12 wanted; give 6 -> at most ~2 active
    eng = ServeEngine(cfg, params, batch_slots=3, max_seq=32,
                      block_size=8, kv_blocks=6, eos_id=-1)
    done = eng.run(reqs, {})
    assert sorted(r.rid for r in done) == [0, 1, 2, 3]
    assert eng.kv.free_blocks == 6


def test_open_loop_arrivals_and_latency(setup):
    """Open-loop arrivals: requests are only admissible after their
    arrival time, and latency covers queueing (monotone stamps)."""
    cfg, params = setup
    rng = np.random.default_rng(7)
    reqs = [Request(i, rng.integers(2, cfg.vocab_size, size=6)
                    .astype(np.int32), 3) for i in range(4)]
    arr = poisson_arrivals(4, 100.0, seed=1)
    assert arr == sorted(arr) and len(arr) == 4
    eng = ServeEngine(cfg, params, batch_slots=2, max_seq=32, eos_id=-1)
    done = eng.run(reqs, {}, arrivals=arr)
    assert len(done) == 4
    for r in done:
        assert r.t_admitted >= r.t_arrival
        assert r.t_done > r.t_admitted
        assert r.latency_s > 0


def test_decode_plan_differs_from_prefill_on_rv32_npu():
    """The m=1 decode shape runs through the same partition DP and, being
    memory-bound, picks different cuts than prefill on the NPU-equipped
    RISC-V hierarchy — the split-plan tentpole, pinned."""
    cfg = configs.get_config("llama3.2-3b").reduced()
    tgt = hw.get_target("rv32_npu")
    _, pre = M.serve_plan(cfg, m=64, target=tgt, phase="prefill")
    _, dec = M.serve_plan(cfg, m=1, target=tgt, phase="decode")
    assert pre is not None and dec is not None
    assert pre.phase == "prefill" and dec.phase == "decode"
    assert pre.m == 64 and dec.m == 1
    assert pre.chain.cuts() != dec.chain.cuts()


def test_serve_plan_cache_keys_bucket_ladder():
    """serve_plan is keyed (cfg, bucketed m, dtype, target, phase):
    requests inside one bucket share a plan object, bucket/phase/target
    changes never serve a stale plan (mirrors the _block_plan target and
    autotune key regressions)."""
    cfg = configs.get_config("llama3.2-3b").reduced()
    assert M.bucket_m(1) == 8 and M.bucket_m(8) == 8
    assert M.bucket_m(9) == 16 and M.bucket_m(16) == 16
    with pytest.raises(ValueError):
        M.bucket_m(0)
    with pytest.raises(ValueError):
        M.bucket_m(M.PREFILL_BUCKETS[-1] + 1)

    tgt = hw.get_target("cpu_cache")
    m10 = M.serve_plan(cfg, m=10, target=tgt, phase="prefill")
    m16 = M.serve_plan(cfg, m=16, target=tgt, phase="prefill")
    assert m10[0] == m16[0] == 16
    assert m10[1] is m16[1]                     # same bucket -> same plan
    m17 = M.serve_plan(cfg, m=17, target=tgt, phase="prefill")
    assert m17[0] == 32 and m17[1] is not m16[1]
    # decode is its own key at m=1 regardless of the requested m
    d = M.serve_plan(cfg, m=16, target=tgt, phase="decode")
    assert d[0] == 1 and d[1] is not m16[1] and d[1].phase == "decode"
    # a different hierarchy never reuses the cpu_cache plan
    other = M.serve_plan(cfg, m=16, target=hw.get_target("rv32_l1_l2"),
                         phase="prefill")
    assert other[1] is not m16[1]


def test_decode_phase_disqualifies_pallas():
    """Decode-shape qualification: at phase='decode' (m=1) the Pallas
    kernels drop out even on a TPU-class context and the registry binds
    the XLA executors; the identical prefill context keeps Pallas."""
    tgt = hw.get_target("tpu_v5e")

    def names(phase, m):
        mk = lambda kind, **kw: ftl_registry.ExecContext(    # noqa: E731
            kind=kind, platform="tpu", schedule="fused", m=m,
            d_model=768, d_ff=3072, dtype="bfloat16", target=tgt,
            phase=phase, **kw)
        return (ftl_registry.find("mlp", mk("mlp")).name,
                ftl_registry.find("attention",
                                  mk("attention", head_dim=64)).name,
                ftl_registry.find("gemm", mk("gemm")).name)

    assert names("prefill", 512) == ("pallas_fused_mlp",
                                     "pallas_flash_attention",
                                     "pallas_gemm")
    assert all(n.startswith("xla_") for n in names("decode", 1))
    with pytest.raises(ValueError):
        ftl_registry.plan_block(configs.get_config("llama3.2-3b").reduced(),
                                m=1, phase="bogus")


def test_zero_replans_and_both_phase_executors(setup):
    """Steady-state decode never replans (100% plan-cache hits after
    warmup) and the engine reports resolved executors for BOTH serving
    regimes, mirroring what train logs for its one shape."""
    cfg, params = setup
    rng = np.random.default_rng(8)
    reqs = [Request(i, rng.integers(2, cfg.vocab_size, size=4 + 5 * i)
                    .astype(np.int32), 4) for i in range(6)]
    eng = ServeEngine(cfg, params, batch_slots=3, max_seq=32, eos_id=-1)
    eng.warmup_compile()
    warm_misses = eng.plans.counters()["misses"]
    eng.run(reqs, {})
    after = eng.plans.counters()
    assert eng.stats["replans"] == 0
    assert after["misses"] == warm_misses
    assert after["misses_after_warmup"] == 0
    assert after["hits"] > 0

    report = eng.plan_report()
    for phase in ("prefill", "decode"):
        entry = report[phase]
        assert entry is not None
        assert set(entry["executors"]) == {"gemm", "attention", "mlp"}
    assert report["prefill"]["m"] == max(eng.buckets)
    assert report["decode"]["m"] == 1


def test_clear_plan_caches_resets_serve_counters(setup):
    """Regression: ``registry.clear_plan_caches()`` used to drop the 13
    lru caches but leave the engine's PlanCache counters and replan stat
    standing, so ``plan_report`` claimed reuse of plans the clear had
    invalidated.  A clear must reset hits/misses/warmth/replans with the
    caches — and the engine must still serve afterwards (replanning,
    and saying so)."""
    cfg, params = setup
    rng = np.random.default_rng(9)
    eng = ServeEngine(cfg, params, batch_slots=1, max_seq=32, eos_id=-1)
    assert eng.plans.counters()["plans"] > 0    # warmed at construction
    assert eng.plans.warmed

    ftl_registry.clear_plan_caches()
    c = eng.plans.counters()
    assert c == {"plans": 0, "hits": 0, "misses": 0,
                 "misses_after_warmup": 0}
    assert not eng.plans.warmed
    assert eng.stats["replans"] == 0
    # the ledger itself reset too
    for stats in ftl_registry.plan_cache_stats().values():
        assert stats["hits"] == 0 and stats["misses"] == 0

    # serving after a clear replans cleanly: fresh plan objects, honest
    # miss counters (not misses_after_warmup — warmth was reset too)
    prompt = rng.integers(2, cfg.vocab_size, size=6).astype(np.int32)
    done = eng.run([Request(0, prompt, 3)], {})
    assert len(done) == 1 and len(done[0].out) == 3
    c = eng.plans.counters()
    assert c["plans"] > 0 and c["misses"] > 0
    assert c["misses_after_warmup"] == 0
