"""Multi-device distribution tests, each in a subprocess with 8 host
devices (so the main test process keeps 1 device)."""
import pytest

from util import check, run_with_devices


@pytest.mark.slow
def test_mesh_and_param_sharding_apply():
    check(run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding
from repro import configs
from repro.launch.mesh import make_mesh
from repro.models import model as M
from repro.distributed.sharding import param_pspecs, param_shardings

cfg = configs.get_config('llama3.2-3b').reduced()
mesh = make_mesh((2, 4), ('data', 'model'))
params = M.init_params(cfg, jax.random.PRNGKey(0))
sh = param_shardings(jax.eval_shape(lambda: params), mesh, cfg)
placed = jax.device_put(params, sh)
# every leaf addressable + sharded per spec
for leaf in jax.tree.leaves(placed):
    assert leaf.sharding.mesh.devices.size == 8
print('OK')
"""))


@pytest.mark.slow
def test_pjit_train_step_on_mesh():
    check(run_with_devices("""
import jax, jax.numpy as jnp
from repro import configs
from repro.launch.mesh import make_mesh
from repro.optim import OptConfig
from repro.train import steps as S

cfg = configs.get_config('yi-6b').reduced()
mesh = make_mesh((2, 4), ('data', 'model'))
state = S.init_train_state(cfg, jax.random.PRNGKey(0))
state_sds = jax.eval_shape(lambda: state)
batch = {'tokens': jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                      cfg.vocab_size)}
batch_sds = jax.eval_shape(lambda: batch)
step = S.make_train_step(cfg, mesh, OptConfig(), accum=2)
in_sh, out_sh = S.train_step_shardings(cfg, mesh, state_sds, batch_sds)
state = jax.device_put(state, in_sh[0])
batch = jax.device_put(batch, in_sh[1])
jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
new_state, metrics = jitted(state, batch)
assert bool(jnp.isfinite(metrics['loss'])), metrics
# second step: shardings stable (no recompile-triggering mismatch)
new_state, metrics = jitted(new_state, batch)
print('OK loss', float(metrics['loss']))
"""))


@pytest.mark.slow
def test_pjit_vs_single_device_loss_parity():
    check(run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.launch.mesh import make_mesh
from repro.optim import OptConfig
from repro.train import steps as S

cfg = configs.get_config('llama3.2-3b').reduced()
batch = {'tokens': jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                      cfg.vocab_size)}
# single-device reference
state0 = S.init_train_state(cfg, jax.random.PRNGKey(0))
_, m_ref = jax.jit(S.make_train_step(cfg, None, OptConfig()))(state0, batch)

# 2x4 mesh
mesh = make_mesh((2, 4), ('data', 'model'))
state = S.init_train_state(cfg, jax.random.PRNGKey(0))
in_sh, out_sh = S.train_step_shardings(
    cfg, mesh, jax.eval_shape(lambda: state),
    jax.eval_shape(lambda: batch))
state = jax.device_put(state, in_sh[0])
batchp = jax.device_put(batch, in_sh[1])
step = S.make_train_step(cfg, mesh, OptConfig())
_, m = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)(state, batchp)
np.testing.assert_allclose(float(m['loss']), float(m_ref['loss']),
                           rtol=1e-3)
print('OK parity', float(m['loss']), float(m_ref['loss']))
"""))


@pytest.mark.slow
def test_compressed_psum_multi_device():
    check(run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.distributed import compression as C, shard_map

mesh = Mesh(np.array(jax.devices()).reshape(8), ('data',))
xs = jax.random.normal(jax.random.PRNGKey(0), (8, 64))

out = shard_map(lambda v: C.compressed_psum(v[0], 'data'), mesh=mesh,
                in_specs=P('data'), out_specs=P())(xs)
exact = xs.mean(0)
err = float(jnp.abs(out - exact).max())
amax = float(jnp.abs(xs).max())
assert err <= amax / 127.0 + 1e-6, (err, amax)
print('OK err', err)
"""))


@pytest.mark.slow
def test_pipeline_parallel_matches_sequential():
    check(run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_mesh
from repro.distributed.pipeline import pipeline_forward, stage_params

mesh = make_mesh((4,), ('pipe',))
n_layers, d = 8, 16
keys = jax.random.split(jax.random.PRNGKey(0), n_layers)
layers = [{'w': jax.random.normal(k, (d, d)) * 0.2} for k in keys]

def layer_fn(p, x):
    return jnp.tanh(x @ p['w'])

def stage_fn(sp, x):
    def body(h, p):
        return layer_fn(p, h), None
    h, _ = jax.lax.scan(body, x, sp)
    return h

staged = stage_params(layers, 4)
m, mb = 8, 4
x = jax.random.normal(jax.random.PRNGKey(1), (m, mb, d))
out = pipeline_forward(stage_fn, staged, x, mesh=mesh)

# sequential reference
ref = x
for p in layers:
    ref = layer_fn(p, ref)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                           rtol=1e-5, atol=1e-5)
print('OK pipeline')
"""))


@pytest.mark.slow
def test_decode_step_on_mesh():
    check(run_with_devices("""
import jax, jax.numpy as jnp
from repro import configs
from repro.launch.mesh import make_mesh
from repro.models import model as M
from repro.train import steps as S

cfg = configs.get_config('recurrentgemma-9b').reduced()
mesh = make_mesh((2, 4), ('data', 'model'))
params = M.init_params(cfg, jax.random.PRNGKey(0))
cache = M.init_cache(cfg, 4, 64)
in_sh = S.decode_shardings(cfg, mesh, jax.eval_shape(lambda: params),
                           jax.eval_shape(lambda: cache), 4)
params = jax.device_put(params, in_sh[0])
cache = jax.device_put(cache, in_sh[1])
step = jax.jit(S.make_decode_step(cfg, mesh), in_shardings=in_sh)
logits, cache = step(params, cache, jnp.ones((4, 1), jnp.int32),
                     jnp.int32(3))
assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
print('OK decode on mesh')
"""))


@pytest.mark.slow
def test_act_sharding_parity_two_device_mesh():
    """Sharded forward (activation policy + param shardings on a 1x2
    mesh) must match the unsharded single-device forward bit-for-bit up
    to float tolerance — the policy only annotates placement."""
    pytest.importorskip("jax")
    check(run_with_devices("""
import jax, numpy as np
from repro import configs
from repro.launch.mesh import make_mesh
from repro.models import model as M
from repro.distributed import act_sharding
from repro.distributed.sharding import make_activation_policy, \
    param_shardings

cfg = configs.get_config('llama3.2-3b').reduced()
params = M.init_params(cfg, jax.random.PRNGKey(0))
batch = {'tokens': jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                      cfg.vocab_size)}
ref, _ = jax.jit(lambda p, b: M.forward(cfg, p, b))(params, batch)

mesh = make_mesh((1, 2), ('data', 'model'))
placed = jax.device_put(params,
                        param_shardings(jax.eval_shape(lambda: params),
                                        mesh, cfg))
with act_sharding.use_policy(make_activation_policy(mesh, cfg)):
    out, _ = jax.jit(lambda p, b: M.forward(cfg, p, b))(placed, batch)
np.testing.assert_allclose(np.asarray(ref, dtype=np.float32),
                           np.asarray(out, dtype=np.float32),
                           rtol=2e-3, atol=2e-5)
print('OK act-sharding parity')
""", n_devices=2))
