"""Gradient compression tests: quantization error bounds, error-feedback
convergence parity, compressed training step."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.distributed import compression as C
from repro.optim import OptConfig
from repro.train import steps as S


def test_quantize_roundtrip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (1024,)) * 3.0
    q, s = C.quantize(x)
    err = jnp.abs(C.dequantize(q, s) - x)
    assert float(err.max()) <= float(s) * 0.5 + 1e-6   # half-step bound


def test_quantize_zero_tensor():
    q, s = C.quantize(jnp.zeros((16,)))
    np.testing.assert_array_equal(C.dequantize(q, s), np.zeros(16))


def test_error_feedback_preserves_signal():
    """Sum of applied grads + residual == sum of true grads (no leakage)."""
    key = jax.random.PRNGKey(1)
    true = [jax.random.normal(jax.random.fold_in(key, i), (256,))
            for i in range(20)]
    err = {"g": jnp.zeros((256,))}
    applied_sum = jnp.zeros((256,))
    for g in true:
        out, err = C.ef_compress({"g": g}, err)
        applied_sum = applied_sum + out["g"]
    total_true = sum(true)
    np.testing.assert_allclose(np.asarray(applied_sum + err["g"]),
                               np.asarray(total_true), rtol=1e-4, atol=1e-4)


def test_ef_convergence_parity_quadratic():
    """SGD on a quadratic: int8+EF tracks the uncompressed trajectory."""
    A = jnp.diag(jnp.linspace(0.5, 2.0, 16))
    b = jnp.ones((16,))

    def grad(w):
        return A @ w - b

    w_ref = jnp.zeros((16,))
    w_c = jnp.zeros((16,))
    err = {"w": jnp.zeros((16,))}
    lr = 0.3
    for _ in range(200):
        w_ref = w_ref - lr * grad(w_ref)
        g, err = C.ef_compress({"w": grad(w_c)}, err)
        w_c = w_c - lr * g["w"]
    sol = jnp.linalg.solve(A, b)
    assert float(jnp.linalg.norm(w_ref - sol)) < 1e-3
    assert float(jnp.linalg.norm(w_c - sol)) < 1e-2


def test_compressed_train_step_learns():
    cfg = configs.get_config("llama3.2-3b").reduced()
    state = S.init_train_state(cfg, jax.random.PRNGKey(0), compress=True)
    assert state.ef_error is not None
    step = jax.jit(S.make_train_step(
        cfg, None, OptConfig(peak_lr=5e-3, warmup_steps=2, decay_steps=30),
        compress=True))
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab_size)}
    losses = []
    for _ in range(15):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 1.0


def test_compressed_psum_single_shard_identity():
    """With axis size 1, compressed_psum == plain quantize roundtrip."""
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.distributed import shard_map

    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    x = jax.random.normal(jax.random.PRNGKey(0), (128,))

    f = shard_map(lambda v: C.compressed_psum(v, "data"), mesh=mesh,
                  in_specs=P(), out_specs=P())
    out = f(x)
    q, s = C.quantize(x)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(C.dequantize(q, s)),
                               rtol=1e-6, atol=1e-6)
