"""Test config: CPU-only, 1 device (the dry-run's 512-device flag must NOT
leak here — launch/dryrun.py sets it in its own process only)."""
import os

# fail fast if someone set the dry-run flag globally
assert "xla_force_host_platform_device_count=512" not in \
    os.environ.get("XLA_FLAGS", ""), \
    "dry-run XLA_FLAGS leaked into the test environment"


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test")
