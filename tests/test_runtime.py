"""Fault-tolerance runtime tests: auto-resume, preemption, stragglers."""
import time

import jax
import jax.numpy as jnp

from repro.runtime import LoopConfig, TrainLoop
from repro.runtime.monitor import HeartbeatMonitor, StragglerMonitor


def toy_step(state, batch):
    new = {"w": state["w"] + batch["x"].sum(), "step": state["step"] + 1}
    return new, {"loss": jnp.float32(1.0) / (1.0 + state["step"])}


def make_batch(i):
    return {"x": jnp.full((2,), float(i))}


def init_state():
    return {"w": jnp.float32(0.0), "step": jnp.int32(0)}


def test_loop_runs_to_completion(tmp_path):
    loop = TrainLoop(LoopConfig(total_steps=10, ckpt_dir=str(tmp_path),
                                ckpt_every=4, ckpt_async=False),
                     jax.jit(toy_step), make_batch, init_state())
    state = loop.run()
    assert int(state["step"]) == 10
    assert len(loop.metrics_log) == 10


def test_auto_resume_from_checkpoint(tmp_path):
    # run 1: stops at 6 (simulated preemption via total_steps)
    loop1 = TrainLoop(LoopConfig(total_steps=6, ckpt_dir=str(tmp_path),
                                 ckpt_every=3, ckpt_async=False),
                      jax.jit(toy_step), make_batch, init_state())
    s1 = loop1.run()
    # run 2: fresh init state, must RESUME from step 6, not restart
    loop2 = TrainLoop(LoopConfig(total_steps=10, ckpt_dir=str(tmp_path),
                                 ckpt_every=3, ckpt_async=False),
                      jax.jit(toy_step), make_batch, init_state())
    s2 = loop2.run()
    assert int(s2["step"]) == 10
    # deterministic data ⇒ same result as an uninterrupted 10-step run
    loop3 = TrainLoop(LoopConfig(total_steps=10, ckpt_dir=None),
                      jax.jit(toy_step), make_batch, init_state())
    s3 = loop3.run()
    assert float(s2["w"]) == float(s3["w"])


def test_preemption_checkpoints_and_exits(tmp_path):
    loop = TrainLoop(LoopConfig(total_steps=100, ckpt_dir=str(tmp_path),
                                ckpt_every=1000, ckpt_async=False),
                     jax.jit(toy_step), make_batch, init_state())
    # preempt after 5 steps via the signal flag
    orig = loop.step_fn

    def step_with_preempt(state, batch):
        if int(state["step"]) == 5:
            loop._preempted = True
        return orig(state, batch)

    loop.step_fn = step_with_preempt
    loop.run()
    assert loop.ckpt.latest_step() == 6
    # resume completes the run
    loop2 = TrainLoop(LoopConfig(total_steps=10, ckpt_dir=str(tmp_path),
                                 ckpt_every=1000, ckpt_async=False),
                      jax.jit(toy_step), make_batch, init_state())
    s2 = loop2.run()
    assert int(s2["step"]) == 10


def test_straggler_monitor_flags_slow_step():
    mon = StragglerMonitor(threshold=3.0, warmup=3)
    for i in range(6):
        mon.start_step()
        time.sleep(0.01)
        mon.end_step(i)
    mon.start_step()
    time.sleep(0.2)                      # 20x slower
    stat = mon.end_step(6)
    assert stat.flagged
    assert [s.step for s in mon.flagged_steps] == [6]
    # EMA not poisoned by the outlier
    assert mon.ema < 0.05


def test_heartbeat_stale_detection(tmp_path):
    h0 = HeartbeatMonitor(str(tmp_path), 0, timeout=0.2)
    h1 = HeartbeatMonitor(str(tmp_path), 1, timeout=0.2)
    h0.stamp()
    h1.stamp()
    assert h0.stale_peers() == []
    time.sleep(0.3)
    h0.stamp()                           # proc 0 alive, proc 1 silent
    assert h0.stale_peers() == [1]
