"""Unified runtime telemetry (repro.obs): span ring buffer, metrics
registry + Prometheus exposition, merged live/modeled Chrome traces, the
online drift monitor — plus the serving/planner integration pins
(plan_report key schema, the full plan-cache ledger, counter-reset
interplay)."""
import dataclasses
import math
import threading

import pytest

from repro import obs
from repro.core import hw
from repro.calib.measure import SegmentFeatures
from repro.obs.spans import SpanRecorder


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------

def test_span_recorder_nesting_and_order():
    rec = SpanRecorder(capacity=16)
    rec.begin("outer", "t")
    rec.begin("inner", "t")
    rec.end()
    rec.end()
    rows = rec.snapshot()
    # inner ends first, so it commits first
    assert [s.name for s in rows] == ["inner", "outer"]
    assert rows[0].depth == 1 and rows[1].depth == 0
    assert all(s.t1 >= s.t0 for s in rows)
    inner, outer = rows
    assert outer.t0 <= inner.t0 and inner.t1 <= outer.t1


def test_span_recorder_ring_overflow_counts_dropped():
    rec = SpanRecorder(capacity=4)
    for i in range(10):
        with rec.span(f"s{i}", "t"):
            pass
    rows = rec.snapshot()
    assert len(rows) == 4
    assert [s.name for s in rows] == ["s6", "s7", "s8", "s9"]
    assert rec.dropped == 6


def test_span_recorder_drain_resets():
    rec = SpanRecorder(capacity=8)
    with rec.span("a", "t"):
        pass
    assert len(rec.drain()) == 1
    assert len(rec) == 0 and rec.snapshot() == []


def test_span_recorder_unbalanced_end_is_safe():
    rec = SpanRecorder(capacity=8)
    rec.end()                    # underflow: no-op, no exception
    assert rec.snapshot() == []


def test_spans_per_thread_ids():
    rec = SpanRecorder(capacity=16)

    def work():
        with rec.span("worker", "t"):
            pass

    t = threading.Thread(target=work)
    with rec.span("main", "t"):
        t.start()
        t.join()
    tids = {s.name: s.tid for s in rec.snapshot()}
    assert tids["worker"] != tids["main"]


def test_module_level_span_respects_enable():
    obs.disable()
    try:
        with obs.span("ignored", "t"):
            pass
        assert obs.recorder() is None
        rec = obs.enable(capacity=8)
        with obs.span("kept", "t"):
            pass
        assert [s.name for s in rec.snapshot()] == ["kept"]
        # disabling mid-span must not unbalance: the cm pinned `rec`
        with obs.span("pinned", "t"):
            obs.disable()
        assert "pinned" in [s.name for s in rec.snapshot()]
    finally:
        obs.enable()


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_counter_labels_and_monotonicity():
    reg = obs.MetricsRegistry()
    c = reg.counter("hits_total", "h", ("kind",))
    c.labels(kind="a").inc()
    c.labels(kind="a").inc(2)
    c.labels(kind="b").inc()
    samples = dict((tuple(sorted(lbl.items())), v)
                   for lbl, v in reg.collect()["hits_total"]["samples"])
    assert samples[(("kind", "a"),)] == 3
    assert samples[(("kind", "b"),)] == 1
    with pytest.raises(ValueError):
        c.labels(kind="a").inc(-1)


def test_gauge_set_inc_dec():
    reg = obs.MetricsRegistry()
    g = reg.gauge("depth", "d")
    g.set(5)
    g.inc()
    g.dec(2)
    ((_, v),) = reg.collect()["depth"]["samples"]
    assert v == 4


def test_histogram_cumulative_buckets_sum_count():
    reg = obs.MetricsRegistry()
    h = reg.histogram("lat", "l", buckets=(0.1, 1.0, float("inf")))
    for x in (0.05, 0.5, 0.5, 3.0):
        h.observe(x)
    rows = {}
    for lbl, v in reg.collect()["lat"]["samples"]:
        if "le" in lbl:
            rows[lbl["le"]] = v
        elif "__count__" in lbl:
            rows["count"] = v
        elif "__sum__" in lbl:
            rows["sum"] = v
    assert rows["0.1"] == 1          # cumulative
    assert rows["1.0"] == 3
    assert rows["+Inf"] == 4
    assert rows["count"] == 4 and rows["sum"] == pytest.approx(4.05)
    text = obs.prometheus_text(reg)
    assert 'lat_bucket{le="1.0"} 3' in text
    assert "lat_sum" in text and "lat_count 4" in text


def test_registry_rejects_type_and_label_conflicts():
    reg = obs.MetricsRegistry()
    reg.counter("x_total", "x")
    with pytest.raises(ValueError):
        reg.gauge("x_total", "x")
    with pytest.raises(ValueError):
        reg.counter("x_total", "x", ("k",))


def test_registry_reset_zeroes_but_keeps_registrations():
    reg = obs.MetricsRegistry()
    c = reg.counter("n_total", "n")
    c.inc(7)
    reg.reset()
    ((_, v),) = reg.collect()["n_total"]["samples"]
    assert v == 0
    assert reg.counter("n_total", "n") is c


def test_prometheus_text_exposition_shape():
    reg = obs.MetricsRegistry()
    reg.counter("req_total", "requests served", ("code",)) \
       .labels(code="200").inc(3)
    text = obs.prometheus_text(reg)
    assert "# HELP req_total requests served" in text
    assert "# TYPE req_total counter" in text
    assert 'req_total{code="200"} 3' in text


# ---------------------------------------------------------------------------
# drift monitor (jax-free: hand-priced features on a preset target)
# ---------------------------------------------------------------------------

_SEG = (SegmentFeatures(flops_by_kind=(("gemm", 1e9),)),)


def _modeled(target):
    return _SEG[0].modeled_s(target)


def test_drift_monitor_online_geomean_matches_offline():
    t = hw.get_target("cpu_cache")
    mon = obs.DriftMonitor(target=t, registry=obs.MetricsRegistry(),
                           window=3)
    modeled = _modeled(t)
    measured = [modeled * f for f in (0.5, 0.8, 1.0, 1.5, 2.0)]
    for ms in measured:
        mon.observe("seg", ms, _SEG)
    # rolling window: only the last 3 observations count
    want = math.exp(sum(math.log(modeled / ms)
                        for ms in measured[-3:]) / 3)
    assert mon.geomean_ratio("seg") == pytest.approx(want, rel=1e-12)
    # ...and the retained rows reprice to the same per-row ratios
    rows = mon.measurements()
    assert len(rows) == 5
    assert rows[0].measured_s == measured[0]


def test_drift_monitor_band_flags_out_of_band():
    t = hw.get_target("cpu_cache")
    reg = obs.MetricsRegistry()
    mon = obs.DriftMonitor(target=t, registry=reg, band=(0.5, 2.0))
    modeled = _modeled(t)
    r = mon.observe("seg", modeled * 10, _SEG)   # ratio 0.1: way low
    assert r == pytest.approx(0.1, rel=1e-9)
    assert not mon.in_band("seg")
    oob = reg.collect()["drift_out_of_band_total"]["samples"]
    assert any(v == 1 for _, v in oob)
    mon2 = obs.DriftMonitor(target=t, registry=obs.MetricsRegistry(),
                            band=(0.5, 2.0))
    mon2.observe("seg", modeled, _SEG)           # ratio 1.0
    assert mon2.in_band("seg")


def test_drift_monitor_scale_multiplies_modeled_side():
    t = hw.get_target("cpu_cache")
    mon = obs.DriftMonitor(target=t, registry=obs.MetricsRegistry())
    modeled = _modeled(t)
    r = mon.observe("step", modeled * 4, _SEG, scale=4.0)
    assert r == pytest.approx(1.0, rel=1e-9)


# ---------------------------------------------------------------------------
# merged trace + planner/serving integration (jax below this line)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    import jax

    from repro import configs
    from repro.models import model as M

    cfg = dataclasses.replace(configs.get_config("llama3.2-3b").reduced(),
                              remat=False)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_merged_trace_has_modeled_and_live_pids(setup):
    from repro.core.ftl import registry

    cfg, _ = setup
    plan = registry.plan_block(cfg, m=32)
    rec = SpanRecorder(capacity=16)
    with rec.span("live_work", "t"):
        pass
    trace = obs.merged_chrome_trace(spans=rec, chain=plan)
    pids = {e["pid"] for e in trace["traceEvents"] if e.get("ph") == "X"}
    assert pids == {0, 1}
    live = [e for e in trace["traceEvents"]
            if e.get("pid") == 1 and e.get("ph") == "X"]
    assert [e["name"] for e in live] == ["live_work"]
    assert "metrics" in trace["otherData"]


def test_plan_cache_gauges_follow_clear(setup):
    from repro.core.ftl import clear_plan_caches, registry

    cfg, _ = setup
    registry.plan_block(cfg, m=32)
    snap = obs.collect()["ftl_plan_cache_size"]["samples"]
    assert any(v > 0 for _, v in snap)
    # the ledger reset empties every cache; the gauges must follow on
    # the next collect — while monotone counters (plan_block calls) keep
    # counting across the reset
    before = sum(v for _, v
                 in obs.collect()["ftl_plan_block_total"]["samples"])
    clear_plan_caches()
    snap = obs.collect()["ftl_plan_cache_size"]["samples"]
    assert all(v == 0 for _, v in snap)
    registry.plan_block(cfg, m=32)
    after = sum(v for _, v
                in obs.collect()["ftl_plan_block_total"]["samples"])
    assert after == before + 1


def test_plan_cache_stats_covers_every_memoized_planner(setup):
    """The full ledger: all 13 plan caches across the planning stack."""
    import repro.models.model  # noqa: F401  — registers model caches
    import repro.tune.autotune  # noqa: F401  — registers the tune cache
    from repro.core.ftl import plan_cache_stats

    stats = plan_cache_stats()
    assert sorted(stats) == [
        "ftl._plan_attention_cached",
        "ftl._plan_mlp_cached",
        "model._block_plan_cached",
        "model._serve_plan_cached",
        "partition._plan_chain_cached",
        "partition._plan_chain_top_k_cached",
        "registry._attention_kernel_footprint_fits",
        "registry._mlp_executor_cached",
        "registry._mlp_kernel_footprint_fits",
        "registry._partial_mlp_footprint_fits",
        "registry._plan_block_cached",
        "registry._scan_tile",
        "tune._autotune_cached",
    ]
    for name, s in stats.items():
        assert {"hits", "misses", "size", "maxsize"} <= set(s), name


def test_serve_engine_obs_spans_gauges_and_report_schema(setup):
    import numpy as np

    from repro.launch.serve import Request, ServeEngine

    cfg, params = setup
    # fresh full-size buffer: an earlier test may have left a tiny one
    obs.disable()
    obs.enable(capacity=1024)
    rng = np.random.default_rng(0)
    eng = ServeEngine(cfg, params, batch_slots=2, max_seq=32, eos_id=-1,
                      obs=True)
    reqs = [Request(i, rng.integers(2, cfg.vocab_size, size=6)
                    .astype(np.int32), 3) for i in range(2)]
    eng.run(list(reqs), {})

    names = {s.name for s in obs.recorder().snapshot()}
    assert "serve:decode_step" in names
    assert "serve:admit" in names
    assert any(n.startswith("serve:prefill:m") for n in names)

    snap = obs.collect()
    ((_, count),) = [(lbl, v) for lbl, v
                     in snap["serve_decode_step_seconds"]["samples"]
                     if "__count__" in lbl]
    assert count >= 2                 # first token comes from prefill
    assert "serve_active_slots" in snap and "serve_queue_depth" in snap

    # plan_report key schema pin (the serving dashboard contract)
    report = eng.plan_report()
    assert set(report) == {"target", "buckets", "prefill", "decode",
                           "decode_differs_from_prefill", "plan_caches"}
    for regime in ("prefill", "decode"):
        entry = report[regime]
        assert set(entry) == {"m", "schedule", "cuts", "executors"}
        assert set(entry["executors"]) == {"gemm", "attention", "mlp"}
    assert isinstance(report["decode_differs_from_prefill"], bool)


def test_monitor_metrics_emit(tmp_path):
    from repro.runtime.monitor import HeartbeatMonitor, StragglerMonitor

    def _val(name):
        ((_, v),) = obs.collect()[name]["samples"]
        return v

    flagged0 = _val("train_straggler_flagged_total")
    mon = StragglerMonitor(threshold=1e-9, warmup=0)
    mon.start_step()
    mon.end_step(0)                 # first step seeds the EMA, no flag
    mon.start_step()
    stat = mon.end_step(1)          # threshold ~0: certainly flagged
    assert stat.flagged
    assert _val("train_straggler_flagged_total") == flagged0 + 1
    assert _val("train_step_seconds") == stat.seconds

    stamps0 = _val("train_heartbeat_stamps_total")
    hb = HeartbeatMonitor(str(tmp_path), 0, timeout=1e6)
    hb.stamp()
    assert hb.stale_peers() == []
    assert _val("train_heartbeat_stamps_total") == stamps0 + 1
    assert _val("train_heartbeat_oldest_age_seconds") >= 0
