"""Mesh-aware planning: per-port transfer model, interconnect spill
exclusion, collective capture, multi-port DES overlap, autotune on
collective graphs, and the plan-cache ledger.

Single-chip bit-identity is the load-bearing invariant: with one DMA
port in play the max-over-ports transfer model must degenerate to the
old Σ-over-levels model exactly (every pre-mesh golden value in
tests/test_targets.py / test_objective.py doubles as a regression on
this), and ``capture_block`` at mesh_size=1 must return the plain
``block_graph`` unchanged.
"""
import pytest

from repro.configs import get_config
from repro.core import hw
from repro.core.ftl import partition
from repro.core.ftl.graph import (CollectiveNode, OpGraph, block_graph,
                                  collective)
from repro.core.ftl.ir import Dim, Role, TensorSpec
from repro.distributed import mesh_capture as mc
from repro.sim import lower_chain, simulate_chain

CFG = get_config("llama3.2-3b").reduced()


# ---------------------------------------------------------------------------
# per-port transfer model
# ---------------------------------------------------------------------------

def test_single_port_max_degenerates_to_sum():
    """With every level on the default port, max-over-ports IS the old
    Σ-over-levels serialization — single-chip plans stay bit-identical."""
    t = hw.get_target("rv32_npu")
    assert all(lv.dma_port == "dma" for lv in t.backing)
    by = {lv.name: 1 << 20 for lv in t.backing}
    tr = {lv.name: 4 for lv in t.backing}
    assert t.transfer_time(by, tr) == t.transfer_time_serialized(by, tr)


def test_multi_port_transfer_is_max_over_ports():
    t = hw.get_target("tpu_v5e")
    hbm = next(lv for lv in t.backing if lv.name == "hbm")
    ici = next(lv for lv in t.backing if lv.name == "ici")
    assert ici.dma_port == "ici" and hbm.dma_port == "dma"
    by = {"hbm": 8 << 20, "ici": 64 << 20}
    tr = {"hbm": 2, "ici": 3}
    per = t.transfer_time_by_port(by, tr)
    assert set(per) == {"dma", "ici"}
    assert t.transfer_time(by, tr) == pytest.approx(max(per.values()))
    assert t.transfer_time_serialized(by, tr) == pytest.approx(
        sum(per.values()))
    # with only default-port traffic the two agree (bit-identity leg)
    assert t.transfer_time({"hbm": 8 << 20}, {"hbm": 2}) == \
        t.transfer_time_serialized({"hbm": 8 << 20}, {"hbm": 2})


def test_interconnect_classification_and_presets():
    tpu = hw.get_target("tpu_v5e")
    assert tpu.interconnect is not None
    assert tpu.interconnect.name == "ici"
    assert tpu.interconnect.is_interconnect
    assert not tpu.fast.is_interconnect
    mesh = hw.get_target("rv32_mesh")
    assert mesh.interconnect.name == "noc"
    assert mesh.interconnect.dma_port == "noc"
    assert hw.get_target("rv32_npu").interconnect is None


def test_spill_never_lands_on_interconnect():
    """Regression: the ici level's 1<<50 sentinel capacity must never
    win the first-fit — an hbm-overflowing tensor spills to hbm, not to
    the interconnect."""
    t = hw.get_target("tpu_v5e")
    hbm = next(lv for lv in t.backing if lv.name == "hbm")
    too_big = {"w": hbm.capacity_bytes * 2, "x": 1 << 10}
    homes = t.assign_homes(too_big)
    assert homes["w"].name == "hbm"
    assert all(not lv.is_interconnect for lv in homes.values())
    # same on the rv32 mesh preset: spills land on l3, never the noc
    m = hw.get_target("rv32_mesh")
    deepest_mem = [lv for lv in m.backing if not lv.is_interconnect][-1]
    homes = m.assign_homes({"w": deepest_mem.capacity_bytes * 2})
    assert homes["w"].name == deepest_mem.name


# ---------------------------------------------------------------------------
# CollectiveNode + capture
# ---------------------------------------------------------------------------

def _sharded_graph(m=128, n=2):
    return mc.capture_block(CFG, m=m, mesh_size=n)


def test_capture_mesh1_is_plain_block_graph():
    assert mc.capture_block(CFG, m=128, mesh_size=1) == \
        block_graph(CFG, m=128)


def test_capture_inserts_all_reduces():
    g = _sharded_graph()
    colls = [op for op in g.ops if isinstance(op, CollectiveNode)]
    assert [c.comm for c in colls] == ["all_reduce", "all_reduce"]
    assert {c.name for c in colls} == {"comm.proj.wo", "comm.mlp.gemm2"}
    # consumers downstream read the reduced tensor, not the partial
    names = [op.name for op in g.ops]
    red = next(op for op in g.ops if op.name == "comm.proj.wo")
    assert red.output.name == red.inputs[0].name + "_red"
    for op in g.ops[names.index("comm.proj.wo") + 1:]:
        assert red.inputs[0].name not in {t.name for t in op.inputs}


def test_collective_ring_formulas():
    g = _sharded_graph(n=4)
    sizes = {d.name: d.size for d in g.dims}
    red = next(op for op in g.ops if op.name == "comm.proj.wo")
    payload = red.inputs[0].bytes_full(sizes)
    # ring all-reduce: 2 phases x (n-1)/n of the payload, (n-1) msgs each
    assert red.comm_bytes(sizes) == 2 * payload * 3 // 4
    assert red.comm_transfers(sizes) == 2 * 3
    # builder sanity: all_gather prices the (bigger) output
    sz = {"m": 32, "d": 16}
    x = TensorSpec("x", ("m", "d"), "float32", Role.INPUT)
    out = TensorSpec("xg", ("m", "d"), "float32", Role.OUTPUT)
    ag = collective("ag", "all_gather", x, out, mesh_size=4)
    assert ag.comm_bytes(sz) == out.bytes_full(sz) * 3 // 4
    assert ag.mesh_size == 4 and Dim("m", 32).size == 32
    with pytest.raises(ValueError):
        collective("bad", "all_to_nowhere", x, out, mesh_size=2)


def test_shard_spec_divisibility():
    assert mc.shard_spec(CFG, 1).any is False
    s2 = mc.shard_spec(CFG, 2)
    assert s2.heads and s2.d_ff
    # a mesh that divides d_ff but not the kv heads shards only the MLP
    big = mc.shard_spec(CFG, 8)
    assert not big.heads and big.d_ff
    assert big.any


def test_strip_and_map_cuts_roundtrip():
    g = _sharded_graph()
    stripped = mc.strip_collectives(g)
    assert not any(isinstance(op, CollectiveNode) for op in stripped.ops)
    assert stripped.n_ops == g.n_ops - 2
    cuts = mc.map_cuts(g, stripped, partition.all_cuts(stripped))
    # every mapped cut is a valid boundary of the full graph
    assert all(0 < c < g.n_ops for c in cuts)
    assert mc.strip_collectives(stripped) is stripped


# ---------------------------------------------------------------------------
# planning with collectives
# ---------------------------------------------------------------------------

def test_plan_prices_collectives_on_interconnect_port():
    g = _sharded_graph()
    p = partition.plan_chain(g, target=hw.get_target("tpu_v5e"))
    colls = [cc for s in p.segments for cc in s.plan.report.collectives]
    assert len(colls) == 2
    assert all(cc.level == "ici" for cc in colls)
    assert all(cc.comm == "all_reduce" for cc in colls)
    # the all-reduced partial is produced in-segment when fused with its
    # producer; the cost report records the dependency for the DES
    for cc in colls:
        if cc.producer:
            assert not cc.pre


def test_plan_collectives_require_interconnect():
    g = _sharded_graph()
    with pytest.raises(ValueError, match="interconnect"):
        partition.plan_chain(g, target=hw.get_target("rv32_npu"))


def test_blind_plan_same_graph_different_knowledge():
    g = _sharded_graph(m=1024)
    t = hw.get_target("rv32_mesh")
    aware = partition.plan_chain(g, target=t)
    blind = mc.plan_collective_blind(g, target=t)
    # both plan the FULL graph (collectives priced in both reports) —
    # only the cut decision was made blind
    assert blind.graph == g
    assert sum(len(s.plan.report.collectives)
               for s in blind.segments) == 2
    # the aware DP must never model worse than the blind one
    assert aware.modeled_runtime_s <= blind.modeled_runtime_s + 1e-12


# ---------------------------------------------------------------------------
# multi-port DES
# ---------------------------------------------------------------------------

def test_comm_chunks_sum_to_analytic_totals():
    g = _sharded_graph(m=512)
    p = partition.plan_chain(g, target=hw.get_target("rv32_mesh"))
    lowered = lower_chain(p)
    assert len(lowered) == len(p.segments)
    seen = 0
    for (sched, _rep), seg in zip(lowered, p.segments):
        by_op: dict[str, int] = {}
        setups: dict[str, int] = {}
        for e in sched.comm_events():
            by_op[e.op] = by_op.get(e.op, 0) + e.bytes
            setups[e.op] = setups.get(e.op, 0) + e.setups
        for cc in seg.plan.report.collectives:
            assert by_op[cc.name] == cc.bytes
            assert setups[cc.name] == cc.transfers
            seen += 1
    assert seen == 2


def test_multi_port_sim_never_loses_to_shared_port():
    for preset in ("tpu_v5e", "rv32_mesh"):
        t = hw.get_target(preset)
        g = _sharded_graph(m=512)
        p = partition.plan_chain(g, target=t)
        lowered = lower_chain(p)
        split = simulate_chain(lowered)
        shared = simulate_chain(lowered, share_ports=True)
        assert split.runtime_s <= shared.runtime_s + 1e-12
        # the interconnect port shows up as its own busy track
        key = f"dma:{t.interconnect.dma_port}"
        assert key in split.busy_s and split.busy_s[key] > 0
        assert key not in shared.busy_s
        # the DES only ever adds real serialization over the roofline
        assert split.runtime_s >= split.analytic_runtime_s * (1 - 1e-9)


def test_chrome_trace_has_collective_track():
    from repro.sim import to_chrome_trace
    g = _sharded_graph(m=256)
    p = partition.plan_chain(g, target=hw.get_target("tpu_v5e"))
    tr = to_chrome_trace(p)
    tracks = {e["args"]["name"] for e in tr["traceEvents"]
              if e.get("ph") == "M" and e["name"] == "thread_name"}
    assert "dma:ici" in tracks
    comm = [e for e in tr["traceEvents"]
            if e.get("ph") == "X" and e["name"].startswith("all_reduce:")]
    assert comm and all(e["cat"] == "dma" for e in comm)


# ---------------------------------------------------------------------------
# autotune accepts collective graphs
# ---------------------------------------------------------------------------

def test_autotune_on_collective_graph():
    from repro.tune import autotune_chain
    g = _sharded_graph(m=256)
    res = autotune_chain(g, target=hw.get_target("rv32_mesh"))
    assert res.sim_runtime_s <= res.baseline_sim_runtime_s + 1e-12
    colls = [cc for s in res.chain.segments
             for cc in s.plan.report.collectives]
    assert len(colls) == 2


# ---------------------------------------------------------------------------
# plan-cache ledger
# ---------------------------------------------------------------------------

def test_plan_cache_stats_and_clear():
    from repro.core.ftl import clear_plan_caches, plan_cache_stats
    import repro.models.model  # noqa: F401  (registers its two caches)
    stats = plan_cache_stats()
    for name in ("partition._plan_chain_cached",
                 "registry._plan_block_cached",
                 "model._block_plan_cached",
                 "model._serve_plan_cached"):
        assert name in stats, sorted(stats)
    before = plan_cache_stats()["partition._plan_chain_cached"]["misses"]
    g = block_graph(CFG, m=96)
    partition.plan_chain(g, target=hw.get_target("tpu_v5e"))
    mid = plan_cache_stats()["partition._plan_chain_cached"]
    assert mid["misses"] == before + 1
    partition.plan_chain(g, target=hw.get_target("tpu_v5e"))
    after = plan_cache_stats()["partition._plan_chain_cached"]
    assert after["hits"] == mid["hits"] + 1
    clear_plan_caches()
    cleared = plan_cache_stats()
    assert all(s["size"] == 0 for s in cleared.values())


def test_graph_exports():
    from repro.core.ftl import graph as graph_mod
    assert "CollectiveNode" in graph_mod.__all__
    assert "collective" in graph_mod.__all__
    assert isinstance(_sharded_graph(), OpGraph)
