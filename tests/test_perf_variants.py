"""Correctness of the §Perf optimized execution variants against their
paper-faithful baselines (the hillclimb must not change semantics)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.kernels import ref
from repro.models import model as M


@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_blockwise_attention_matches_naive(causal, window, dtype):
    q = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 256, 64), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 2, 256, 64), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 2, 256, 64), dtype)
    a = ref.attention(q, k, v, causal=causal, window=window)
    b = ref.attention_blockwise(q, k, v, causal=causal, window=window,
                                block_k=64)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(a.astype(jnp.float32),
                               b.astype(jnp.float32), rtol=tol, atol=tol)


def test_blockwise_attention_q_offset():
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 2, 64, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 256, 32))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 2, 256, 32))
    a = ref.attention(q, k, v, causal=True, q_offset=192)
    b = ref.attention_blockwise(q, k, v, causal=True, q_offset=192,
                                block_k=64)
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)


def test_blockwise_gradients_match():
    """The scan schedule must be differentiable and match naive grads."""
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 2, 128, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 128, 32))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 2, 128, 32))

    def loss_naive(q):
        return ref.attention(q, k, v, causal=True).sum()

    def loss_blk(q):
        return ref.attention_blockwise(q, k, v, causal=True,
                                       block_k=32).sum()

    ga = jax.grad(loss_naive)(q)
    gb = jax.grad(loss_blk)(q)
    np.testing.assert_allclose(ga, gb, rtol=1e-4, atol=1e-4)


def test_mlstm_chunked_matches_plain():
    q = jax.random.normal(jax.random.PRNGKey(3), (1, 2, 128, 32)) * 0.3
    k = jax.random.normal(jax.random.PRNGKey(4), (1, 2, 128, 32)) * 0.3
    v = jax.random.normal(jax.random.PRNGKey(5), (1, 2, 128, 32)) * 0.3
    ip = jax.random.normal(jax.random.PRNGKey(6), (1, 2, 128))
    fp = jax.random.normal(jax.random.PRNGKey(7), (1, 2, 128)) + 3
    a = ref.mlstm_scan(q, k, v, ip, fp)
    b = ref.mlstm_scan_chunked(q, k, v, ip, fp, chunk=32)
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)


def test_mlstm_chunked_state_matches():
    q = jax.random.normal(jax.random.PRNGKey(3), (1, 1, 64, 16)) * 0.3
    k = jax.random.normal(jax.random.PRNGKey(4), (1, 1, 64, 16)) * 0.3
    v = jax.random.normal(jax.random.PRNGKey(5), (1, 1, 64, 16)) * 0.3
    ip = jax.random.normal(jax.random.PRNGKey(6), (1, 1, 64))
    fp = jax.random.normal(jax.random.PRNGKey(7), (1, 1, 64)) + 3
    _, sa = ref.mlstm_scan(q, k, v, ip, fp, return_state=True)
    _, sb = ref.mlstm_scan_chunked(q, k, v, ip, fp, chunk=16,
                                   return_state=True)
    for key in ("C", "n", "m"):
        np.testing.assert_allclose(sa[key], sb[key], rtol=1e-6, atol=1e-6)


def test_grouped_moe_matches_scatter_no_drop():
    cfg0 = configs.get_config("moonshot-v1-16b-a3b").reduced()
    cfg_s = dataclasses.replace(cfg0, moe_dispatch="scatter",
                                capacity_factor=64.0)
    cfg_g = dataclasses.replace(cfg0, moe_dispatch="grouped", moe_groups=2,
                                capacity_factor=64.0)
    params = M.init_params(cfg_s, jax.random.PRNGKey(2))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32),
                                          0, cfg0.vocab_size)}
    la, aux_a = M.forward(cfg_s, params, batch)
    lb, aux_b = M.forward(cfg_g, params, batch)
    np.testing.assert_allclose(np.asarray(la, np.float32),
                               np.asarray(lb, np.float32),
                               rtol=1e-5, atol=1e-5)
    # aux loss: scatter computes load-balance stats globally, grouped
    # per-group-then-mean (GShard semantics) — close but not identical
    np.testing.assert_allclose(float(aux_a), float(aux_b), rtol=0.15)


def test_grouped_moe_gradients_flow():
    cfg = dataclasses.replace(
        configs.get_config("qwen2-moe-a2.7b").reduced(),
        moe_dispatch="grouped", moe_groups=2)
    from repro.optim import OptConfig
    from repro.train import steps as S
    st = S.init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(S.make_train_step(cfg, None,
                                     OptConfig(peak_lr=5e-3,
                                               warmup_steps=0)))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32),
                                          0, cfg.vocab_size)}
    losses = []
    for _ in range(6):
        st, m = step(st, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    # expert weights actually received gradient
    w1_0 = jax.tree.leaves(M.init_params(cfg, jax.random.PRNGKey(0)))
    assert any(bool(jnp.any(a != b)) for a, b in
               zip(w1_0, jax.tree.leaves(st.params)))


def test_grouped_moe_group_fallback():
    """moe_groups falls back to a divisor of the token count."""
    from repro.models.moe import _n_groups
    cfg = dataclasses.replace(
        configs.get_config("qwen2-moe-a2.7b").reduced(), moe_groups=16)
    assert _n_groups(cfg, 24) == 8          # 16 -> 8 divides 24
    assert _n_groups(cfg, 7) == 1


def test_opt_level_cfg_rewrites():
    import subprocess
    import sys

    from util import SRC
    # apply_opt_level touches jax device state indirectly -> subprocess
    code = """
from repro.launch.dryrun import apply_opt_level
from repro.configs import get_config
cfg = apply_opt_level(get_config('moonshot-v1-16b-a3b'), True)
assert cfg.moe_dispatch == 'grouped', cfg.moe_dispatch
cfg2 = apply_opt_level(get_config('xlstm-1.3b'), True)
assert cfg2.mlstm_chunk == 256
cfg3 = apply_opt_level(get_config('yi-6b'), False)
assert cfg3.moe_dispatch == 'scatter'
from repro.kernels.ops import _XLA_ATTN
assert _XLA_ATTN['mode'] == 'blockwise' and _XLA_ATTN['min_len'] == 8192
print('OK')
"""
    import os
    env = dict(os.environ,
               PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr[-2000:]
