"""Roofline analysis tests: HLO parsing, trip-count awareness, collective
accounting, model-FLOPs sanity — on hand-written HLO and on a real
compiled module."""
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.configs import get_shape
from repro.roofline import analysis, hlo_cost

TINY_HLO = """
HloModule test, num_partitions=4

%fused_computation (param_0.1: f32[128,256], param_1.2: f32[16,128,256]) -> f32[128,256] {
  %param_1.2 = f32[16,128,256]{2,1,0} parameter(1)
  %param_0.1 = f32[128,256]{1,0} parameter(0)
  %dynamic-slice.1 = f32[1,128,256]{2,1,0} dynamic-slice(%param_1.2, %c, %c, %c), dynamic_slice_sizes={1,128,256}
  %bitcast.1 = f32[128,256]{1,0} bitcast(%dynamic-slice.1)
  ROOT %add.1 = f32[128,256]{1,0} add(%param_0.1, %bitcast.1)
}

%body (p: (s32[], f32[128,256], f32[16,128,256])) -> (s32[], f32[128,256], f32[16,128,256]) {
  %p = (s32[], f32[128,256]{1,0}, f32[16,128,256]{2,1,0}) parameter(0)
  %gte.0 = s32[] get-tuple-element(%p), index=0
  %gte.1 = f32[128,256]{1,0} get-tuple-element(%p), index=1
  %gte.2 = f32[16,128,256]{2,1,0} get-tuple-element(%p), index=2
  %fusion.1 = f32[128,256]{1,0} fusion(%gte.1, %gte.2), kind=kLoop, calls=%fused_computation
  %dot.1 = f32[128,256]{1,0} dot(%fusion.1, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %all-reduce.1 = f32[128,256]{1,0} all-reduce(%dot.1), channel_id=1, replica_groups=[4,1]<=[4]
  %tuple.1 = (s32[], f32[128,256]{1,0}, f32[16,128,256]{2,1,0}) tuple(%gte.0, %all-reduce.1, %gte.2)
  ROOT %t = (s32[], f32[128,256]{1,0}, f32[16,128,256]{2,1,0}) tuple(%gte.0, %all-reduce.1, %gte.2)
}

%cond (p: (s32[], f32[128,256], f32[16,128,256])) -> pred[] {
  %p = (s32[], f32[128,256]{1,0}, f32[16,128,256]{2,1,0}) parameter(0)
  ROOT %lt = pred[] compare(%gte, %c16), direction=LT
}

ENTRY %main (a: f32[128,256], s: f32[16,128,256]) -> f32[128,256] {
  %a = f32[128,256]{1,0} parameter(0)
  %s = f32[16,128,256]{2,1,0} parameter(1)
  %w = f32[256,256]{1,0} parameter(2)
  %tuple.0 = (s32[], f32[128,256]{1,0}, f32[16,128,256]{2,1,0}) tuple(%c0, %a, %s)
  %while.1 = (s32[], f32[128,256]{1,0}, f32[16,128,256]{2,1,0}) while(%tuple.0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"16"}}
  ROOT %out = f32[128,256]{1,0} get-tuple-element(%while.1), index=1
}
"""


class TestHloParsing:
    def test_shapes_and_computations(self):
        comps, entry = hlo_cost.parse_module(TINY_HLO)
        assert entry == "main"
        assert set(comps) >= {"main", "body", "cond", "fused_computation"}
        dot = comps["body"].instrs["dot.1"]
        assert dot.shape.dims == (128, 256)
        assert dot.contracting() == (1,)

    def test_trip_count_multiplies(self):
        model = hlo_cost.HloCostModel(TINY_HLO)
        c = model.entry_cost()
        # dot flops = 2*128*256*256 per iteration × 16 trips
        expected_dot = 2 * 128 * 256 * 256 * 16
        # plus the fused add: 128*256 per trip
        assert c.flops == expected_dot + 128 * 256 * 16

    def test_collective_bytes_trip_aware(self):
        model = hlo_cost.HloCostModel(TINY_HLO)
        c = model.entry_cost()
        ar = 128 * 256 * 4 * 16                   # f32 operand × 16 trips
        assert c.coll_bytes["all-reduce"] == ar
        assert c.coll_count == 16

    def test_fusion_slice_classification(self):
        """The (16,128,256) stacked buffer is only dynamic-sliced inside
        the fusion → boundary counts the slice, not the full buffer."""
        model = hlo_cost.HloCostModel(TINY_HLO)
        body = model.comps["body"]
        fus = body.instrs["fusion.1"]
        b = model._instr_cost(body, fus, False).bytes
        slice_b = 128 * 256 * 4
        # operand a (full) + stacked (slice) + result
        assert b == pytest.approx(slice_b * 3, rel=0.01)


class TestRealCompiledModule:
    def test_hlo_cost_matches_known_matmul(self):
        """Compile a real jit matmul and check dot flops exactly."""

        @jax.jit
        def f(a, b):
            return a @ b

        a = jax.ShapeDtypeStruct((256, 512), jnp.float32)
        b = jax.ShapeDtypeStruct((512, 128), jnp.float32)
        compiled = f.lower(a, b).compile()
        res = hlo_cost.analyze(compiled.as_text())
        assert res["flops"] >= 2 * 256 * 512 * 128
        assert res["flops"] < 2.2 * 256 * 512 * 128

    def test_scan_trip_count_counted(self):
        """A scanned matmul must report trips × flops (the XLA built-in
        cost analysis under-reports this — the reason hlo_cost exists)."""

        def f(x, w):
            def body(h, _):
                return jnp.tanh(h @ w), None
            h, _ = jax.lax.scan(body, x, None, length=10)
            return h

        x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        compiled = jax.jit(f).lower(x, w).compile()
        res = hlo_cost.analyze(compiled.as_text())
        per_iter = 2 * 128 * 128 * 128
        assert res["flops"] >= 10 * per_iter
        xla = hlo_cost.xla_cost_analysis(compiled)["flops"]
        assert xla < 2.5 * per_iter            # demonstrates the undercount


class TestModelFlops:
    def test_dense_train_flops_ballpark(self):
        cfg = configs.get_config("qwen2-72b")
        shape = get_shape("train_4k")
        mf = analysis.model_flops(cfg, shape)
        n = 72.7e9
        d = 256 * 4096
        assert mf == pytest.approx(6 * n * d, rel=0.15)

    def test_moe_counts_active_params_only(self):
        cfg = configs.get_config("moonshot-v1-16b-a3b")
        act = analysis.active_params(cfg)
        from repro.models.model import count_params
        total = count_params(cfg)
        assert act < 0.35 * total              # 64 experts, top-6

    def test_decode_flops_linear_in_batch(self):
        cfg = configs.get_config("yi-6b")
        d32 = analysis.model_flops(cfg, get_shape("decode_32k"))
        assert d32 > 2 * analysis.active_params(cfg) * 128

    def test_roofline_report_terms(self):
        rep = analysis.roofline(
            arch="x", shape=get_shape("train_4k"), mesh_shape=(16, 16),
            cost={"flops": 197e12, "bytes accessed": 819e9},
            hlo_text=None, coll_bytes=int(50e9), model_flops_total=1e15)
        assert rep.t_compute == pytest.approx(1.0)
        assert rep.t_memory == pytest.approx(1.0)
        assert rep.t_collective == pytest.approx(1.0)
        assert rep.chips == 256


def test_dryrun_cell_enumeration():
    from repro.launch.dryrun import all_cells, cell_status
    cells = all_cells()
    assert len(cells) == 40                    # 10 archs × 4 shapes
    runs = [c for c in cells if c[2] == "run"]
    skips = [c for c in cells if c[2] != "run"]
    assert len(runs) == 32                     # 8 archs skip long_500k
    assert all(c[1] == "long_500k" for c in skips)
    assert cell_status("xlstm-1.3b", "long_500k") == "run"
    assert cell_status("qwen2-72b", "long_500k").startswith("skip")
