"""FTL core tests: the paper's 4-step pipeline (ir → constraints → fusion
→ solver) and the headline fused-vs-unfused comparison."""
import dataclasses

import pytest

from repro.core import ftl, hw
from repro.core.ftl.cost import n_tiles, vmem_usage
from repro.core.ftl.solver import InfeasibleError

MB = 1 << 20


def T(budget: int) -> hw.Target:
    """The TPU preset with its fast level resized to ``budget`` bytes."""
    return hw.TPU_V5E.with_fast_capacity(budget)


# ---------------------------------------------------------------------------
# solver basics
# ---------------------------------------------------------------------------

class TestSolveBasics:
    def test_tiles_divide_dims(self):
        g = ftl.fusion.gemm_act(m=2048, k=768, n=3072, fuse=True)
        plan = ftl.solve(g, target=T(8 * MB))
        for d, t in plan.tiles.items():
            assert plan.constraints[d].size % t == 0, (d, t)

    def test_fast_capacity_respected(self):
        for budget in (2 * MB, 8 * MB, 64 * MB):
            g = ftl.fusion.gemm_act(m=4096, k=4096, n=4096, fuse=True)
            plan = ftl.solve(g, target=T(budget))
            assert plan.vmem_bytes <= budget
            assert plan.vmem_budget == budget

    def test_infeasible_raises(self):
        g = ftl.fusion.gemm_act(m=4096, k=4096, n=4096, fuse=True)
        with pytest.raises(InfeasibleError):
            ftl.solve(g, target=T(1024))   # 1 KiB: nothing fits

    def test_larger_budget_never_worse(self):
        g = lambda: ftl.fusion.mlp(m=8192, d_model=1024, d_ff=4096,
                                   fuse=True)
        t_small = ftl.solve(g(), target=T(4 * MB)).traffic_bytes
        t_big = ftl.solve(g(), target=T(64 * MB)).traffic_bytes
        assert t_big <= t_small

    def test_whole_dims_pinned(self):
        g = ftl.fusion.mlp(m=8192, d_model=1024, d_ff=4096, fuse=True)
        plan = ftl.solve(g, target=T(64 * MB),
                         whole_dims=frozenset({"K", "N"}))
        assert plan.tile("K") == 1024
        assert plan.tile("N") == 1024

    def test_alignment_respected(self):
        g = ftl.fusion.gemm_act(m=2048, k=1024, n=4096, fuse=True)
        plan = ftl.solve(g, target=T(16 * MB))
        for d, t in plan.tiles.items():
            c = plan.constraints[d]
            assert t % c.alignment == 0 or t == c.size, (d, t, c.alignment)


@pytest.mark.parametrize("target", [
    # transfer-bound at full TPU rate; compute-bound with the rate cut
    # 10^6x — the compute-bound regime is where runtime ties everywhere
    # and the prune must still return the exact (traffic, dma) optimum.
    T(2 * MB),
    dataclasses.replace(T(2 * MB), name="tpu_slow", flops=197e6),
], ids=["transfer-bound", "compute-bound"])
def test_pruned_search_matches_exhaustive_optimum(target):
    """Pin for the optimality prune (solver.py): the pruned
    branch-and-bound must return the same optimum — modeled runtime with
    (traffic, DMA, steps) tie-breaks — as brute force over the full
    candidate lattice."""
    import itertools

    from repro.core.ftl.cost import evaluate

    g = ftl.fusion.mlp(m=512, d_model=256, d_ff=512, fuse=True)
    budget = target.fast_capacity
    plan = ftl.solve(g, target=target)

    cons = ftl.build_dim_constraints(g)
    names = sorted(cons)
    best_key = None
    for combo in itertools.product(*(cons[n].candidates for n in names)):
        tiles = dict(zip(names, combo))
        rep = evaluate(g, tiles, cons, target=target)
        if rep.vmem_bytes > budget:
            continue
        steps = 1
        for _, c in rep.grid:
            steps *= c
        key = (rep.modeled_runtime_s, rep.traffic_bytes, rep.dma_transfers,
               steps)
        if best_key is None or key < best_key:
            best_key = key
    steps = 1
    for _, c in plan.report.grid:
        steps *= c
    assert (plan.report.modeled_runtime_s, plan.traffic_bytes,
            plan.dma_transfers, steps) == best_key


# ---------------------------------------------------------------------------
# the paper's benchmark: GEMM+GeLU fusion wins
# ---------------------------------------------------------------------------

class TestPaperBenchmark:
    def test_gemm_gelu_fusion_reduces_traffic(self):
        """ViT-base MLP first half: fusing the activation removes the
        intermediate round trip (paper Fig. 3: -47.1% transfers; our byte
        model gives 42-53% depending on budget).  The DMA *count* may rise
        (smaller fused tiles → more, cheaper transfers) — the paper's
        L2-overflow cliff is modeled in benchmarks/bench_paper_mlp.py."""
        kw = dict(m=3072, k=768, n=3072)
        fused = ftl.solve(ftl.fusion.gemm_act(fuse=True, **kw),
                          target=T(8 * MB))
        unfused = [ftl.solve(g, target=T(8 * MB))
                   for g in ftl.fusion.gemm_act(fuse=False, **kw)]
        cmp = ftl.compare(fused, unfused)
        assert 0.30 < cmp.traffic_reduction < 0.70, cmp.summary()

    def test_full_mlp_fusion_wins_at_large_budget(self):
        out = ftl.plan_mlp(m=16384, d_model=1024, d_ff=4096,
                           target=hw.TPU_V5E)
        assert out.use_fused
        assert out.comparison.traffic_reduction > 0.2

    def test_fusion_not_always_wins(self):
        """At tiny VMEM the joint constraints force weight revisits that
        exceed the intermediate savings — the auto planner must fall back
        (beyond-paper extension, DESIGN.md §4)."""
        out = ftl.plan_mlp(m=1024, d_model=768, d_ff=3072,
                           target=T(1 * MB))
        assert not out.use_fused

    def test_intermediate_never_in_hbm_traffic(self):
        g = ftl.fusion.mlp(m=8192, d_model=1024, d_ff=4096, fuse=True)
        plan = ftl.solve(g, target=T(64 * MB))
        inter = {t.name for t in g.intermediate_tensors()}
        assert inter == {"h1", "h"}
        for name in inter:
            assert name not in plan.report.per_tensor_traffic


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------

class TestCostModel:
    def test_traffic_lower_bound_is_tensor_sizes(self):
        g = ftl.fusion.gemm_act(m=1024, k=512, n=1024, fuse=True)
        plan = ftl.solve(g, target=T(128 * MB))
        sizes = {d: c.size for d, c in plan.constraints.items()}
        floor = sum(t.bytes_full(sizes) for t in g.hbm_tensors())
        assert plan.traffic_bytes >= floor

    def test_single_block_traffic_equals_floor(self):
        # everything fits in VMEM -> each tensor moved exactly once
        g = ftl.fusion.gemm_act(m=256, k=256, n=256, fuse=True)
        plan = ftl.solve(g, target=T(128 * MB))
        sizes = {d: c.size for d, c in plan.constraints.items()}
        floor = sum(t.bytes_full(sizes) for t in g.hbm_tensors())
        assert plan.traffic_bytes == floor

    def test_vmem_usage_buffer_depth_factor(self):
        """Streamed tensors are charged the fast level's pipeline depth;
        intermediates/accumulators are depth-independent, so footprint
        is strictly increasing (but sub-linear) in depth."""
        g = ftl.fusion.gemm_act(m=1024, k=512, n=1024, fuse=True)
        cons = ftl.build_dim_constraints(g)
        tiles = {d: c.candidates[0] for d, c in cons.items()}
        v1 = vmem_usage(g, tiles, cons, buffer_depth=1)
        v2 = vmem_usage(g, tiles, cons, buffer_depth=2)
        v3 = vmem_usage(g, tiles, cons, buffer_depth=3)
        assert v1 < v2 < v3
        # streamed share doubles exactly: v2 - v1 == the streamed bytes
        assert v3 - v2 == v2 - v1
        with pytest.raises(ValueError):
            vmem_usage(g, tiles, cons, buffer_depth=0)

    def test_n_tiles(self):
        assert n_tiles(1024, 256) == 4
        assert n_tiles(1000, 256) == 4


# ---------------------------------------------------------------------------
# sharding constraint family (DESIGN.md §2 extension)
# ---------------------------------------------------------------------------

class TestShardingConstraints:
    def test_sharded_problem_plans_per_shard(self):
        g = ftl.fusion.mlp(m=65536, d_model=8192, d_ff=28672, fuse=True)
        plan = ftl.solve(g, target=hw.TPU_V5E,
                         sharded_sizes={"M": 65536 // 16, "F": 28672 // 16})
        assert plan.constraints["M"].size == 4096
        assert plan.constraints["F"].size == 1792
        assert plan.vmem_bytes <= 96 * MB

    def test_bad_shard_size_rejected(self):
        g = ftl.fusion.mlp(m=1000, d_model=512, d_ff=2048, fuse=True)
        with pytest.raises(ValueError):
            ftl.solve(g, sharded_sizes={"M": 7})


# ---------------------------------------------------------------------------
# attention-as-FTL (DESIGN.md §5)
# ---------------------------------------------------------------------------

def test_attention_group_fuses_scores_away():
    plan = ftl.plan_attention(q_len=4096, kv_len=4096, head_dim=128)
    g = plan.group
    inter = {t.name for t in g.intermediate_tensors()}
    assert "s" in inter and "p" in inter    # score matrices never hit HBM
    # head_dim contraction must stay whole (kernel-policy)
    assert plan.tile("Dh") == 128


# ---------------------------------------------------------------------------
# partial fusion — 3-way auto schedule (beyond paper)
# ---------------------------------------------------------------------------

class TestPartialFusion:
    def test_partial_wins_where_full_fusion_loses(self):
        """qwen2-72b-class dims at 96 MiB: full fusion's joint tiling
        costs +88 % traffic, but fusing only the activation epilogue
        (the paper's exact op) still beats layer-per-layer."""
        out = ftl.plan_mlp(m=8192, d_model=8192, d_ff=29568 // 16,
                           gated=True, act="silu", target=hw.TPU_V5E)
        assert out.schedule == "partial"
        unf = sum(p.traffic_bytes for p in out.unfused)
        par = sum(p.traffic_bytes for p in out.partial)
        assert par < unf
        assert out.fused.traffic_bytes > unf       # full fusion loses

    def test_full_fusion_still_chosen_when_best(self):
        out = ftl.plan_mlp(m=8192, d_model=4096, d_ff=11008 // 16,
                           gated=True, act="silu", target=hw.TPU_V5E)
        assert out.schedule == "fused"
        assert out.chosen_traffic == out.fused.traffic_bytes

    def test_chosen_traffic_is_min_of_schedules(self):
        out = ftl.plan_mlp(m=4096, d_model=1024, d_ff=4096,
                           target=T(8 * MB))
        cands = [sum(p.traffic_bytes for p in out.unfused)]
        if out.partial:
            cands.append(sum(p.traffic_bytes for p in out.partial))
        if out.fused:
            cands.append(out.fused.traffic_bytes)
        assert out.chosen_traffic == min(cands)

    def test_partial_groups_structure(self):
        g1, g2 = ftl.fusion.mlp_partial(m=1024, d_model=512, d_ff=2048,
                                        gated=True)
        # up group fuses gemm1+gate+act: h1/hg are intermediates, h is out
        inter = {t.name for t in g1.intermediate_tensors()}
        assert inter == {"h1", "hg"}
        assert g1.tensors["h"].role.value == "output"
        # down group consumes h from HBM
        assert g2.tensors["h"].role.value == "input"
