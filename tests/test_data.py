"""Data pipeline tests: determinism, host sharding, learnable structure."""
import numpy as np

from repro.data.pipeline import DataConfig, SyntheticLM, synth_tokens


def test_batches_deterministic():
    cfg = DataConfig(vocab_size=1000, global_batch=8, seq_len=32, seed=7)
    a = synth_tokens(cfg, 5, 0, 8)
    b = synth_tokens(cfg, 5, 0, 8)
    np.testing.assert_array_equal(a, b)
    c = synth_tokens(cfg, 6, 0, 8)
    assert not np.array_equal(a, c)


def test_host_shards_are_disjoint_slices():
    cfg = DataConfig(vocab_size=1000, global_batch=8, seq_len=16, seed=3)
    full = synth_tokens(cfg, 2, 0, 8)
    h0 = SyntheticLM(cfg, process_index=0, process_count=2)
    h1 = SyntheticLM(cfg, process_index=1, process_count=2)
    b0 = h0.batch_at(2)["tokens"]
    b1 = h1.batch_at(2)["tokens"]
    np.testing.assert_array_equal(np.concatenate([b0, b1]), full)


def test_bigram_structure_learnable():
    """Next token is a deterministic affine map + small noise."""
    cfg = DataConfig(vocab_size=997, global_batch=4, seq_len=256, seed=1,
                     kind="bigram", noise=4)
    toks = synth_tokens(cfg, 0, 0, 4).astype(np.int64)
    a = (cfg.seed * 2 + 1) % cfg.vocab_size
    b = (cfg.seed * 7 + 3) % cfg.vocab_size
    x, y = toks[:, :-1], toks[:, 1:]
    eps = (y - (a * x + b)) % cfg.vocab_size
    assert eps.max() < cfg.noise       # every transition explained


def test_prefetch_iterator_matches_batch_at():
    cfg = DataConfig(vocab_size=100, global_batch=2, seq_len=8, seed=0,
                     prefetch=2)
    ds = SyntheticLM(cfg, process_index=0, process_count=1)
    it = ds.iterate(start_step=3)
    for i in range(3, 6):
        got = next(it)["tokens"]
        np.testing.assert_array_equal(got, ds.batch_at(i)["tokens"])


def test_tokens_in_range():
    cfg = DataConfig(vocab_size=51, global_batch=4, seq_len=64, seed=2)
    t = synth_tokens(cfg, 0, 0, 4)
    assert t.min() >= 0 and t.max() < 51
    cfg2 = DataConfig(vocab_size=51, global_batch=4, seq_len=64, seed=2,
                      kind="random")
    t2 = synth_tokens(cfg2, 0, 0, 4)
    assert t2.min() >= 0 and t2.max() < 51
