"""Roofline-objective property suite (the PR-4 tentpole's harness).

Pins the planner's ``max(compute_time, transfer_time)`` objective to the
roofline model instead of trusting it:

* planner and roofline compute identical compute-time for the same
  (op, target) — both route through ``hw.compute_time``;
* hypothesis properties: modeled runtime is monotone non-increasing in
  ``Target.flops`` and in fast-level capacity, and ``max(compute, dma)``
  dominates each of its terms — across all three presets;
* compute-bound chains (tiny dims against a huge FLOP/s deficit) yield
  the unfused partition when fusion costs bytes: runtime ties, and the
  traffic tie-break refuses to pay the joint-tiling penalty;
* the paper-qualitative pin: the ViT-MLP op stays fusion-favorable on
  the Siracusa-like ``rv32_l1_l2`` preset under the new objective;
* per-level buffer depth: depth 1 on the cache-backed ``cpu_cache``
  reproduces the depth-2 plans where they were already feasible, a
  depth-3 VMEM level strictly shrinks the max feasible tile size, and
  depth changes invalidate the model-level plan cache.
"""
import dataclasses

import pytest

from repro import configs
from repro.core import ftl, hw
from repro.core.ftl import graph, partition
from repro.core.ftl.cost import vmem_usage
from repro.core.ftl.solver import InfeasibleError
from repro.roofline.analysis import HW

KB, MB = 1 << 10, 1 << 20

PRESETS = list(hw.presets())
PRESET_IDS = [t.name for t in PRESETS]


def _flat(budget: int, flops: float = 1e12) -> hw.Target:
    """Single-backing-level target with zero DMA setup: transfer time is
    traffic-proportional, so capacity monotonicity is exact."""
    return hw.Target(
        name=f"flat@{budget}",
        levels=(hw.MemoryLevel("fast", budget, 1e12),
                hw.MemoryLevel("back", 1 << 50, 100e9)),
        flops=flops,
    )


# ---------------------------------------------------------------------------
# planner and roofline price compute from the SAME Target
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("target", PRESETS, ids=PRESET_IDS)
def test_planner_and_roofline_agree_on_compute_time(target):
    """For the same (op, target) the FTL cost model and the roofline's HW
    view must report the *identical* compute time — both delegate to the
    shared ``hw`` formulas, and this test keeps them from ever diverging
    again.  On an engine-carrying target (rv32_npu) the planner prices
    the per-engine split (``compute_time_by_kind``); the single-rate
    roofline view then lower-bounds it (the busiest engine can only be
    slower than everything-at-peak)."""
    g = graph.mlp_graph(m=512, d_model=256, d_ff=1024, dtype="int8")
    group = g.group(0, g.n_ops)
    try:
        plan = ftl.solve(group, target=target)
    except InfeasibleError:
        pytest.skip("op infeasible on this preset")
    flops = group.total_flops()
    assert flops == g.total_flops()
    roof = HW.from_target(target)
    assert plan.report.flops == flops
    # this shape's lane dims are all MXU-aligned: no utilization discount
    assert all(oc.utilization == 1.0 for oc in plan.report.op_compute)
    by_kind: dict[str, int] = {}
    for op in group.ops:
        sizes = {d: c.size for d, c in plan.constraints.items()}
        by_kind[op.kind] = by_kind.get(op.kind, 0) + op.flops(sizes)
    assert plan.report.compute_time_s == pytest.approx(
        target.compute_time_by_kind(by_kind), rel=1e-12)
    if not target.engines:
        assert plan.report.compute_time_s == target.compute_time_s(flops)
        assert plan.report.compute_time_s == roof.compute_time_s(flops)
    else:
        # per-engine times partition the work across declared engines
        assert set(plan.report.per_engine_compute_s) <= {
            e.name for e in target.engines}
        assert plan.report.compute_time_s == pytest.approx(
            max(plan.report.per_engine_compute_s.values()))
    assert roof.peak_flops == target.flops


def test_sharded_compute_term_prices_per_shard_work():
    """Under the sharding constraint family the solver prices the
    per-shard problem; the compute term must cover the same per-shard
    FLOPs the transfer term does, or every sharded plan would look
    spuriously compute-bound (regression: evaluate once priced the full
    unsharded chain's FLOPs)."""
    g_full = ftl.fusion.mlp(m=4096, d_model=1024, d_ff=4096, fuse=True)
    g_shard = ftl.fusion.mlp(m=4096, d_model=1024, d_ff=4096, fuse=True)
    full = ftl.solve(g_full, target=hw.TPU_V5E)
    shard = ftl.solve(g_shard, target=hw.TPU_V5E,
                      sharded_sizes={"M": 4096 // 4, "F": 4096 // 4})
    # per-shard work: both M and F cut 4x -> gemm FLOPs drop 16x is
    # wrong (each gemm has only one of M/F... M in both, F in one), so
    # just pin the exact per-op sum at the sharded sizes
    sizes = {d: c.size for d, c in shard.constraints.items()}
    assert shard.report.flops == sum(
        op.flops(sizes) for op in shard.group.ops)
    assert shard.report.flops < full.report.flops
    assert shard.report.compute_time_s == hw.TPU_V5E.compute_time_s(
        shard.report.flops)


def test_per_op_flop_counts():
    """GEMMs at 2·M·K·N FLOPs, elementwise at 1 FLOP/element; the chain
    total is multiplicity-weighted and partition-invariant."""
    g = graph.gemm_act_graph(m=64, k=32, n=128, dtype="int8")
    sizes = {d.name: d.size for d in g.dims}
    gemm_op, act_op = g.ops
    assert gemm_op.flops(sizes) == 2 * 64 * 32 * 128
    assert act_op.flops(sizes) == 64 * 128
    assert g.total_flops() == 2 * 64 * 32 * 128 + 64 * 128
    # partition-invariant: every segmentation covers the same arithmetic
    assert (g.group(0, 1).total_flops() + g.group(1, 2).total_flops()
            == g.group(0, 2).total_flops())


# ---------------------------------------------------------------------------
# hypothesis properties (deterministic fallbacks when not installed)
# ---------------------------------------------------------------------------

DIMS = [128, 256, 512, 1024]
FLOPS_LADDER = (1e6, 1e9, 1e12, 1e15)
BUDGETS = (256 * KB, 1 * MB, 8 * MB, 96 * MB)


def _chain_runtime(m, k, n, target):
    g = graph.mlp_graph(m=m, d_model=k, d_ff=n, dtype="int8")
    try:
        return partition.plan_chain(g, target=target).modeled_runtime_s
    except InfeasibleError:
        return None


def _check_monotone_in_flops(m, k, n, budget, f_lo, f_hi):
    lo = _chain_runtime(m, k, n, _flat(budget, flops=f_lo))
    hi = _chain_runtime(m, k, n, _flat(budget, flops=f_hi))
    if lo is None or hi is None:
        return
    # same machine, faster compute: the optimum can only improve
    # (per-assignment runtime is non-increasing in FLOP/s)
    assert hi <= lo * (1 + 1e-9)


def _check_monotone_in_capacity(m, k, n, flops, b_lo, b_hi):
    lo = _chain_runtime(m, k, n, _flat(b_lo, flops=flops))
    hi = _chain_runtime(m, k, n, _flat(b_hi, flops=flops))
    if lo is None:
        return
    assert hi is not None          # feasible set only grows with capacity
    assert hi <= lo * (1 + 1e-9)


try:
    import hypothesis.strategies as st
    from hypothesis import given, settings

    dim = st.sampled_from(DIMS)
    fl = st.sampled_from(FLOPS_LADDER)
    budget = st.sampled_from(BUDGETS)

    @settings(max_examples=25, deadline=None)
    @given(m=dim, k=dim, n=dim, b=budget, f1=fl, f2=fl)
    def test_runtime_monotone_in_flops_fuzz(m, k, n, b, f1, f2):
        _check_monotone_in_flops(m, k, n, b, min(f1, f2), max(f1, f2))

    @settings(max_examples=25, deadline=None)
    @given(m=dim, k=dim, n=dim, f=fl, b1=budget, b2=budget)
    def test_runtime_monotone_in_capacity_fuzz(m, k, n, f, b1, b2):
        _check_monotone_in_capacity(m, k, n, f, min(b1, b2), max(b1, b2))
except ImportError:  # pragma: no cover - hypothesis optional locally
    pass


def test_runtime_monotone_in_flops_ladder():
    """Deterministic sweep of the same property hypothesis fuzzes."""
    for f_lo, f_hi in zip(FLOPS_LADDER, FLOPS_LADDER[1:]):
        _check_monotone_in_flops(512, 256, 1024, 1 * MB, f_lo, f_hi)


def test_runtime_monotone_in_capacity_ladder():
    for b_lo, b_hi in zip(BUDGETS, BUDGETS[1:]):
        _check_monotone_in_capacity(512, 256, 1024, 1e12, b_lo, b_hi)


@pytest.mark.parametrize("target", PRESETS, ids=PRESET_IDS)
def test_runtime_dominates_each_term_on_presets(target):
    """max(compute, dma) >= each term, on every preset and schedule."""
    g = graph.mlp_graph(m=512, d_model=256, d_ff=1024, dtype="int8")
    for cuts in [(), partition.all_cuts(g), None]:
        try:
            chain = (partition.plan_chain(g, target=target) if cuts is None
                     else partition.plan_fixed(g, cuts, target=target))
        except InfeasibleError:
            continue
        for s in chain.segments:
            rep = s.plan.report
            assert rep.modeled_runtime_s >= rep.compute_time_s
            assert rep.modeled_runtime_s >= rep.transfer_time_s
            assert rep.modeled_runtime_s == max(rep.compute_time_s,
                                                rep.transfer_time_s)
            assert rep.compute_bound == (
                rep.compute_time_s >= rep.transfer_time_s)
        # chain-level aggregates are segment sums
        assert chain.modeled_runtime_s == pytest.approx(
            sum(s.modeled_runtime_s for s in chain.segments))
        assert chain.modeled_runtime_s >= chain.compute_time_s - 1e-15
        assert chain.modeled_runtime_s >= chain.transfer_time_s - 1e-15


@pytest.mark.parametrize("target", PRESETS, ids=PRESET_IDS)
def test_dp_runtime_never_exceeds_canonical_schedules(target):
    """Feasibility/optimality across all three presets: the DP's chosen
    runtime is <= both the fused and the all-unfused schedule's."""
    g = graph.gemm_act_graph(m=3072, k=768, n=3072, dtype="int8")
    chain = partition.plan_chain(g, target=target)
    for cuts in [(), partition.all_cuts(g)]:
        try:
            fixed = partition.plan_fixed(g, cuts, target=target)
        except InfeasibleError:
            continue
        assert chain.modeled_runtime_s <= fixed.modeled_runtime_s * (1 + 1e-9)


# ---------------------------------------------------------------------------
# compute-bound chains: fusion that buys no runtime must not cost bytes
# ---------------------------------------------------------------------------

def _slow_small() -> hw.Target:
    """128 KiB fast level (joint tiling hurts: the ViT-MLP op only fits
    fused with heavy weight revisits) against a 10^4x FLOP/s deficit
    (1 MFLOP/s vs the ~14.5 GFLOP op): every partition is compute-bound."""
    return hw.Target(
        name="slow_small",
        levels=(hw.MemoryLevel("fast", 128 * KB, 8e9),
                hw.MemoryLevel("back", 1 << 50, 2e9, dma_setup_s=2e-6)),
        flops=1e6,
    )


def test_compute_bound_chain_yields_unfused_partition():
    """Old objective (transfer time only) vs new: on a compute-bound
    chain where the fused segment's joint tiling moves MORE bytes than
    layer-per-layer, the runtimes tie at the compute floor and the
    traffic tie-break must pick the unfused partition — fusing would buy
    zero runtime and cost real bytes."""
    t = _slow_small()
    g = graph.gemm_act_graph(m=3072, k=768, n=3072, dtype="int8")
    fused = partition.plan_fixed(g, (), target=t)
    unfused = partition.plan_fixed(g, partition.all_cuts(g), target=t)
    # the regime the test needs: compute-bound everywhere, fusion costs
    # bytes (joint tiling forces weight revisits in the 128 KiB fast level)
    assert fused.compute_bound and unfused.compute_bound
    assert fused.traffic_bytes > unfused.traffic_bytes
    # runtimes tie at the compute floor...
    assert fused.modeled_runtime_s == pytest.approx(
        unfused.modeled_runtime_s, rel=1e-9)
    # ...so the DP must refuse the fusion
    chain = partition.plan_chain(g, target=t)
    assert chain.schedule == "unfused"
    # whereas with the FLOP deficit removed the transfer term decides
    fast = dataclasses.replace(t, name="fast_flops", flops=1e18)
    assert partition.plan_chain(g, target=fast).schedule == "unfused"
    # (this op is transfer-unfavorable to fuse at 128 KiB either way; at
    # a VMEM-class budget the same op fuses — the paper's regime)
    roomy = hw.TPU_V5E.with_fast_capacity(8 * MB)
    assert partition.plan_chain(g, target=roomy).schedule == "fused"


def test_rv32_mlp_stays_fusion_favorable():
    """Paper-qualitative pin under the runtime objective: on the
    Siracusa-like preset the ViT-MLP op still fuses, and the full MLP
    chain still beats layer-per-layer on runtime AND bytes."""
    t = hw.get_target("rv32_l1_l2")
    # the paper's Fig. 3 op: GEMM→GeLU fuses outright
    g = graph.gemm_act_graph(m=3072, k=768, n=3072, dtype="int8")
    assert partition.plan_chain(g, target=t).schedule == "fused"
    # the full MLP chain: fusion-favorable (never layer-per-layer)
    gm = graph.mlp_graph(m=512, d_model=256, d_ff=1024, dtype="int8")
    chain = partition.plan_chain(gm, target=t)
    unfused = partition.plan_fixed(gm, partition.all_cuts(gm), target=t)
    assert chain.schedule != "unfused"
    assert chain.modeled_runtime_s <= unfused.modeled_runtime_s * (1 + 1e-9)
    assert chain.traffic_bytes < unfused.traffic_bytes


# ---------------------------------------------------------------------------
# utilization-discounted compute (MXU lane-utilization factor)
# ---------------------------------------------------------------------------

class TestLaneUtilization:
    def test_aligned_tiles_price_at_peak(self):
        """The pin the ROADMAP item demands: for lane-aligned tiles the
        discount is exactly 1 — modeled runtime is bit-identical to the
        undiscounted formula on every preset."""
        g = graph.gemm_act_graph(m=1024, k=512, n=2048, dtype="int8")
        for target in hw.presets():
            try:
                chain = partition.plan_chain(g, target=target)
            except InfeasibleError:
                continue
            for s in chain.segments:
                rep = s.plan.report
                assert all(oc.utilization == 1.0 for oc in rep.op_compute)
                assert rep.mxu_utilization == 1.0
                if not target.engines:
                    assert rep.compute_time_s == \
                        target.compute_time_s(rep.flops)

    def test_narrow_lane_discounts_compute(self):
        """A head-dim-64 output lane feeds half a 128-wide MXU: the PV
        GEMM's compute time doubles, and the discount can only increase
        modeled runtime, never decrease it."""
        from repro.core.ftl.cost import lane_utilization
        g = graph.attention_graph(q_len=1024, kv_len=1024, head_dim=64,
                                  dtype="bfloat16")
        chain = partition.plan_fixed(g, (), target=hw.TPU_V5E)
        rep = chain.segments[0].plan.report
        by_name = {oc.name: oc for oc in rep.op_compute}
        # qk's output lane is Tk (1024-tile, aligned); pv's is Dh=64
        assert by_name["pv"].utilization == pytest.approx(0.5)
        assert by_name["pv"].seconds == pytest.approx(
            2 * by_name["pv"].flops / hw.TPU_V5E.flops)
        assert rep.compute_time_s >= hw.TPU_V5E.compute_time_s(rep.flops)
        assert rep.mxu_utilization < 1.0
        # direct check of the factor's shape
        pv = next(op for op in chain.segments[0].plan.group.ops
                  if op.name == "pv")
        assert lane_utilization(pv, {"Dh": 64}) == 0.5
        assert lane_utilization(pv, {"Dh": 128}) == 1.0
        assert lane_utilization(pv, {"Dh": 256}) == 1.0

    def test_utilization_monotone_in_lane_tile(self):
        """min(1, tile/preferred) is monotone non-decreasing — the
        property the solver's optimistic full-size prune needs."""
        from repro.core.ftl.cost import lane_utilization
        from repro.core.ftl.ir import KernelPolicy, TensorSpec, gemm
        op = gemm("g",
                  TensorSpec("x", ("M", "K")), TensorSpec("w", ("K", "N")),
                  TensorSpec("y", ("M", "N")), contract="K",
                  policy=KernelPolicy())
        prev = 0.0
        for tile in (8, 16, 32, 64, 128, 192, 256):
            u = lane_utilization(op, {"N": tile})
            assert u >= prev
            prev = u

    def test_elementwise_never_discounted(self):
        from repro.core.ftl.cost import lane_utilization
        from repro.core.ftl.ir import TensorSpec, elementwise
        op = elementwise("e", [TensorSpec("x", ("M", "N"))],
                         TensorSpec("y", ("M", "N")))
        assert lane_utilization(op, {"N": 8}) == 1.0


# ---------------------------------------------------------------------------
# per-level buffer depth
# ---------------------------------------------------------------------------

class TestBufferDepth:
    def test_preset_depths(self):
        """cpu_cache is cache-backed (no software staging copies); the
        DMA-fed VMEM / L1 TCDM fast levels double-buffer."""
        assert hw.CPU_CACHE.fast.buffer_depth == 1
        assert hw.TPU_V5E.fast.buffer_depth == 2
        assert hw.RV32_L1_L2.fast.buffer_depth == 2

    def test_depth_must_be_positive(self):
        with pytest.raises(ValueError, match="buffer_depth"):
            hw.MemoryLevel("x", 1 * MB, 1e9, buffer_depth=0)

    def test_with_buffer_depth_is_distinct_cache_key(self):
        t3 = hw.TPU_V5E.with_buffer_depth(3)
        assert t3.fast.buffer_depth == 3
        assert t3 != hw.TPU_V5E
        assert hash(t3) != hash(hw.TPU_V5E)

    def test_depth1_cpu_cache_reproduces_depth2_plans_when_feasible(self):
        """Regression: on cpu_cache (now depth 1) a problem whose depth-2
        (the old hard-coded ×2) optimum already fit keeps the identical
        tiles — relaxing the staging charge cannot change a plan the
        capacity constraint never bound."""
        legacy = hw.CPU_CACHE.with_buffer_depth(2)   # yesterday's model
        for mk in [(256, 256, 256), (512, 256, 256)]:
            m, k, n = mk
            g1 = ftl.fusion.mlp(m=m, d_model=k, d_ff=n, dtype="int8",
                                fuse=True)
            g2 = ftl.fusion.mlp(m=m, d_model=k, d_ff=n, dtype="int8",
                                fuse=True)
            p1 = ftl.solve(g1, target=hw.CPU_CACHE)
            p2 = ftl.solve(g2, target=legacy)
            assert p2.vmem_bytes <= legacy.fast_capacity   # was feasible
            # depth-2 optimum is unconstrained (full-size tiles) here, so
            # relaxing the charge must reproduce it bit-for-bit
            assert all(p2.tile(d) == p2.size(d) for d in p2.tiles), mk
            assert p1.tiles == p2.tiles, mk
            assert p1.traffic_bytes == p2.traffic_bytes, mk
            assert p1.modeled_runtime_s == p2.modeled_runtime_s, mk

    def test_depth1_never_worse_than_depth2(self):
        """The depth-1 feasible set contains the depth-2 one, so the
        solved runtime can only improve."""
        legacy = hw.CPU_CACHE.with_buffer_depth(2)
        g = lambda: ftl.fusion.mlp(m=2048, d_model=1024, d_ff=4096,  # noqa
                                   fuse=True)
        r1 = ftl.solve(g(), target=hw.CPU_CACHE).modeled_runtime_s
        r2 = ftl.solve(g(), target=legacy).modeled_runtime_s
        assert r1 <= r2 * (1 + 1e-9)

    def test_depth3_vmem_strictly_shrinks_max_feasible_tile(self):
        """A depth-3 VMEM pipeline charges every streamed tile 3 buffers:
        the largest M tile that fits an 8 MiB fast level strictly drops
        (4096 → 2048 on this op), and the full solve stays within budget
        at the inflated charge."""
        budget = 8 * MB
        g = ftl.fusion.gemm_act(m=8192, k=4096, n=4096, fuse=True)
        cons = ftl.build_dim_constraints(g)

        def max_feasible_m(depth):
            best = None
            for c in cons["M"].candidates:
                tiles = {d: (c if d == "M" else cons[d].candidates[0])
                         for d in cons}
                if vmem_usage(g, tiles, cons, buffer_depth=depth) <= budget:
                    best = c
            return best

        assert max_feasible_m(3) < max_feasible_m(2)
        t3 = hw.TPU_V5E.with_fast_capacity(budget).with_buffer_depth(3)
        g3 = ftl.fusion.gemm_act(m=8192, k=4096, n=4096, fuse=True)
        plan = ftl.solve(g3, target=t3)
        assert plan.vmem_bytes <= budget
        # the reported footprint already charges the ×3 pipeline
        assert plan.vmem_bytes == vmem_usage(plan.group, plan.tiles,
                                             plan.constraints,
                                             buffer_depth=3)

    def test_model_plan_cache_invalidated_by_depth_change(self):
        """models/model.py keys its per-block plan cache on the resolved
        target; a buffer-depth change is a different machine and must
        produce a distinct plan object (never a stale ×2-era plan)."""
        from repro.models import model as M
        cfg = dataclasses.replace(
            configs.get_config("llama3.2-3b").reduced(),
            dtype="float32", remat=False, ftl_mode="auto")
        base = M._block_plan(cfg, 32, "float32", target=hw.TPU_V5E)
        deep = M._block_plan(cfg, 32, "float32",
                             target=hw.TPU_V5E.with_buffer_depth(3))
        assert base is not None and deep is not None
        assert deep is not base
        assert deep.target.fast.buffer_depth == 3
        # same depth resolves back to the same cached object
        assert M._block_plan(cfg, 32, "float32",
                             target=hw.TPU_V5E) is base
