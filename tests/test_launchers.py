"""Launcher smoke tests (subprocess): the end-to-end train driver with
checkpoint resume, and the example scripts' entry points."""
import os
import subprocess
import sys

import pytest

from util import SRC


def run_py(args, timeout=540):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run([sys.executable] + args, env=env,
                          capture_output=True, text=True, timeout=timeout)


@pytest.mark.slow
def test_train_launcher_runs_and_resumes(tmp_path):
    base = ["-m", "repro.launch.train", "--arch", "llama3.2-3b",
            "--reduced", "--batch", "4", "--seq", "32", "--accum", "2",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "5",
            "--log-every", "5"]
    p1 = run_py(base + ["--steps", "8"])
    assert p1.returncode == 0, p1.stderr[-2000:]
    assert "final: step 8" in p1.stdout
    # resume: continues from the checkpoint, not from scratch
    p2 = run_py(base + ["--steps", "12"])
    assert p2.returncode == 0, p2.stderr[-2000:]
    assert "final: step 12" in p2.stdout


@pytest.mark.slow
def test_quickstart_example():
    p = run_py([os.path.join(os.path.dirname(__file__), "..", "examples",
                             "quickstart.py")])
    assert p.returncode == 0, p.stderr[-2000:]
    assert "OK" in p.stdout
