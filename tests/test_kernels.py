"""Pallas kernel validation: interpret-mode vs the pure-jnp oracles,
swept over shapes, dtypes and block sizes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.fused_mlp import fused_mlp
from repro.kernels.gemm import gemm
from repro.kernels.gemm_gelu import gemm_act
from repro.kernels.mlstm import mlstm_scan
from repro.kernels.rg_lru import rg_lru_scan


def rnd(key, shape, dtype, scale=1.0):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
    return (x * scale).astype(dtype)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


DTYPES = [jnp.float32, jnp.bfloat16]


# ---------------------------------------------------------------------------
# GEMM family
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (256, 128, 256, 128, 128, 128),
    (512, 512, 256, 256, 128, 256),
    (128, 384, 640, 128, 128, 128),
])
def test_gemm_matches_ref(m, k, n, bm, bn, bk, dtype):
    x, w = rnd(0, (m, k), dtype, 0.1), rnd(1, (k, n), dtype, 0.1)
    out = gemm(x, w, block_m=bm, block_n=bn, block_k=bk, interpret=True)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.gemm(x, w).astype(jnp.float32),
                               **tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("act", ["gelu", "relu", "silu"])
@pytest.mark.parametrize("bias", [False, True])
def test_gemm_act_matches_ref(act, bias, dtype):
    """The paper's exact benchmark op (GEMM + activation fused)."""
    m, k, n = 256, 384, 512
    x, w = rnd(0, (m, k), dtype, 0.1), rnd(1, (k, n), dtype, 0.1)
    b = rnd(2, (n,), dtype) if bias else None
    out = gemm_act(x, w, b, act=act, block_m=128, block_n=128, block_k=128,
                   interpret=True)
    expect = ref.gemm_act(x, w, b, act=act)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               expect.astype(jnp.float32), **tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("gated", [False, True])
@pytest.mark.parametrize("m,k,f,n,bm,bf", [
    (256, 128, 512, 128, 128, 256),
    (512, 256, 1024, 256, 256, 256),
])
def test_fused_mlp_matches_ref(m, k, f, n, bm, bf, gated, dtype):
    """The FTL flagship: full MLP in one kernel, hidden never leaves VMEM."""
    x = rnd(0, (m, k), dtype, 0.1)
    w1 = rnd(1, (k, f), dtype, 0.05)
    w2 = rnd(2, (f, n), dtype, 0.05)
    wg = rnd(3, (k, f), dtype, 0.05) if gated else None
    out = fused_mlp(x, w1, w2, wg, act="gelu", block_m=bm, block_f=bf,
                    interpret=True)
    expect = ref.mlp(x, w1, w2, wg, act="gelu")
    np.testing.assert_allclose(out.astype(jnp.float32),
                               expect.astype(jnp.float32), **tol(dtype))


def test_fused_mlp_with_biases():
    m, k, f, n = 256, 128, 512, 128
    x = rnd(0, (m, k), jnp.float32, 0.1)
    w1, w2 = rnd(1, (k, f), jnp.float32, 0.05), rnd(2, (f, n), jnp.float32, 0.05)
    b1, b2 = rnd(3, (f,), jnp.float32), rnd(4, (n,), jnp.float32)
    out = fused_mlp(x, w1, w2, None, b1, b2, act="gelu",
                    block_m=128, block_f=256, interpret=True)
    expect = ref.mlp(x, w1, w2, None, b1, b2, act="gelu")
    np.testing.assert_allclose(out, expect, rtol=2e-5, atol=2e-5)


def test_fused_mlp_rejects_nondividing_blocks():
    x = rnd(0, (100, 128), jnp.float32)
    w1, w2 = rnd(1, (128, 512), jnp.float32), rnd(2, (512, 128), jnp.float32)
    with pytest.raises(ValueError):
        fused_mlp(x, w1, w2, block_m=64, block_f=256, interpret=True)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("hq,hk", [(4, 4), (8, 2), (4, 1)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_gqa(hq, hk, causal, dtype):
    b, t, dh = 2, 256, 64
    q = rnd(0, (b, hq, t, dh), dtype)
    k = rnd(1, (b, hk, t, dh), dtype)
    v = rnd(2, (b, hk, t, dh), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=128, block_k=128,
                          interpret=True)
    expect = ref.attention(q, k, v, causal=causal)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               expect.astype(jnp.float32),
                               rtol=3e-2 if dtype == jnp.bfloat16 else 2e-5,
                               atol=3e-2 if dtype == jnp.bfloat16 else 2e-5)


def test_flash_attention_local_window():
    b, h, t, dh = 1, 2, 512, 64
    q = rnd(0, (b, h, t, dh), jnp.float32)
    k = rnd(1, (b, h, t, dh), jnp.float32)
    v = rnd(2, (b, h, t, dh), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=128,
                          block_q=128, block_k=128, interpret=True)
    expect = ref.attention(q, k, v, causal=True, window=128)
    np.testing.assert_allclose(out, expect, rtol=2e-5, atol=2e-5)


def test_flash_attention_cross_q_offset():
    """decode-style: q block at an offset into the kv sequence."""
    b, h, dh = 1, 2, 64
    q = rnd(0, (b, h, 128, dh), jnp.float32)
    k = rnd(1, (b, h, 512, dh), jnp.float32)
    v = rnd(2, (b, h, 512, dh), jnp.float32)
    out = flash_attention(q, k, v, causal=True, q_offset=384,
                          block_q=128, block_k=128, interpret=True)
    expect = ref.attention(q, k, v, causal=True, q_offset=384)
    np.testing.assert_allclose(out, expect, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# recurrent kernels
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bt,bd", [(64, 128), (256, 256)])
def test_rg_lru_matches_ref(bt, bd):
    b, t, d = 2, 256, 256
    x = rnd(0, (b, t, d), jnp.float32, 0.5)
    a = jax.nn.sigmoid(rnd(1, (b, t, d), jnp.float32)) * 0.2 + 0.79
    h, hT = rg_lru_scan(x, a, block_t=bt, block_d=bd, interpret=True)
    h_ref, hT_ref = ref.rg_lru_scan(x, a)
    np.testing.assert_allclose(h, h_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(hT, hT_ref, rtol=1e-4, atol=1e-4)


def test_rg_lru_carries_initial_state():
    b, t, d = 1, 128, 128
    x = rnd(0, (b, t, d), jnp.float32, 0.5)
    a = jnp.full((b, t, d), 0.9, jnp.float32)
    h0 = jnp.ones((b, d), jnp.float32)
    h, _ = rg_lru_scan(x, a, h0, block_t=64, block_d=128, interpret=True)
    h_ref, _ = ref.rg_lru_scan(x, a, h0)
    np.testing.assert_allclose(h, h_ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("block_t", [64, 128])
def test_mlstm_matches_ref(block_t):
    b, h, t, dh = 1, 2, 128, 64
    q = rnd(0, (b, h, t, dh), jnp.float32, 0.3)
    k = rnd(1, (b, h, t, dh), jnp.float32, 0.3)
    v = rnd(2, (b, h, t, dh), jnp.float32, 0.3)
    i_pre = rnd(3, (b, h, t), jnp.float32)
    f_pre = rnd(4, (b, h, t), jnp.float32) + 3.0
    out = mlstm_scan(q, k, v, i_pre, f_pre, block_t=block_t, interpret=True)
    expect = ref.mlstm_scan(q, k, v, i_pre, f_pre)
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-4)


def test_mlstm_state_continuity_across_chunks():
    """Chunked kernel must be bit-consistent with the one-chunk kernel."""
    b, h, t, dh = 1, 1, 256, 64
    q = rnd(0, (b, h, t, dh), jnp.float32, 0.3)
    k = rnd(1, (b, h, t, dh), jnp.float32, 0.3)
    v = rnd(2, (b, h, t, dh), jnp.float32, 0.3)
    ip = rnd(3, (b, h, t), jnp.float32)
    fp = rnd(4, (b, h, t), jnp.float32) + 3.0
    one = mlstm_scan(q, k, v, ip, fp, block_t=256, interpret=True)
    many = mlstm_scan(q, k, v, ip, fp, block_t=64, interpret=True)
    np.testing.assert_allclose(one, many, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# FTL-planned dispatch (ops.py)
# ---------------------------------------------------------------------------

def test_ops_plan_blocks_are_legal():
    # the Pallas kernels plan against the TPU target explicitly: the
    # auto-detected process default is the cache-blocked CPU preset on
    # the test host, whose 1 MiB fast level cannot hold these kernels'
    # whole-K/N weight panels
    from repro.core import hw
    from repro.kernels import ops
    bm, bf = ops.plan_mlp_blocks(4096, 768, 3072, "bfloat16", False, "gelu",
                                 target=hw.TPU_V5E)
    assert 4096 % bm == 0 and 3072 % bf == 0
    bq, bk = ops.plan_attention_blocks(4096, 4096, 128, "bfloat16",
                                       target=hw.TPU_V5E)
    assert 4096 % bq == 0 and 4096 % bk == 0
