"""Graph-level FTL planner tests: OpGraph capture, the fusion-partition
DP, the executor registry, and the XLA executors' gated/bias paths."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import ftl, hw
from repro.core.ftl import executor_xla, graph, partition, registry
from repro.core.ftl.solver import InfeasibleError

MB = 1 << 20


def T(budget: int) -> hw.Target:
    return hw.TPU_V5E.with_fast_capacity(budget)


def _key(chain):
    """The partition DP's objective key: modeled runtime with
    (traffic, DMA, segment count) tie-breaks.  The runtime component
    goes through the DP's own tie canonicalization (hw.round_time) —
    compute-bound partitions tie up to a float ulp, and comparing raw
    floats would make these assertions ulp-fragile."""
    return (hw.round_time(chain.modeled_runtime_s), chain.traffic_bytes,
            chain.dma_transfers, len(chain.segments))


# Paper ViT-Base MLP dims (Fig. 3 benchmark).
VIT_M, VIT_D, VIT_F = 3072, 768, 3072


# ---------------------------------------------------------------------------
# partitioner vs the seed's three-way auto planner
# ---------------------------------------------------------------------------

class TestPartitionVsAuto:
    @pytest.mark.parametrize("budget", [2 * MB, 8 * MB, 96 * MB])
    def test_vit_mlp_matches_auto_plan_mlp(self, budget):
        """Acceptance pin: on the paper's ViT-MLP shapes the DP selects the
        same schedule as auto.plan_mlp, with modeled traffic within 1%."""
        out = ftl.plan_mlp(m=VIT_M, d_model=VIT_D, d_ff=VIT_F,
                           target=T(budget))
        g = graph.mlp_graph(m=VIT_M, d_model=VIT_D, d_ff=VIT_F)
        chain = partition.plan_chain(g, target=T(budget))
        assert chain.schedule == out.schedule
        assert abs(chain.traffic_bytes - out.chosen_traffic) <= \
            0.01 * out.chosen_traffic

    def test_dp_never_beats_itself_inconsistently(self):
        """DP objective key <= every canonical schedule it subsumes:
        modeled runtime first, then bytes — so on runtime ties (the
        compute-bound regime) the DP's choice still moves no more
        traffic than any canonical partition."""
        g = graph.mlp_graph(m=4096, d_model=1024, d_ff=4096)
        chain = partition.plan_chain(g, target=T(8 * MB))
        for cuts in [(), (g.n_ops - 1,), partition.all_cuts(g)]:
            try:
                fixed = partition.plan_fixed(g, cuts, target=T(8 * MB))
            except InfeasibleError:
                continue
            assert _key(chain) <= _key(fixed)
            assert chain.modeled_runtime_s <= \
                fixed.modeled_runtime_s * (1 + 1e-9)

    def test_gated_mlp_partition(self):
        """qwen2-72b-class dims where the seed's planner picked partial:
        the DP must do at least as well and never pick full fusion."""
        g = graph.mlp_graph(m=8192, d_model=8192, d_ff=29568 // 16,
                            gated=True, act="silu")
        chain = partition.plan_chain(g, target=hw.TPU_V5E)
        unf = partition.plan_fixed(g, partition.all_cuts(g),
                                   target=hw.TPU_V5E)
        fused = partition.plan_fixed(g, (), target=hw.TPU_V5E)
        assert chain.traffic_bytes < unf.traffic_bytes
        assert chain.traffic_bytes < fused.traffic_bytes
        assert chain.modeled_runtime_s <= unf.modeled_runtime_s * (1 + 1e-9)
        assert chain.modeled_runtime_s <= \
            fused.modeled_runtime_s * (1 + 1e-9)
        assert chain.schedule == "partial"

    def test_gemm_chain_4op_never_exceeds_unfused(self):
        """Satellite pin: a 4-GEMM chain's DP schedule must never exceed
        the all-unfused runtime — nor, on runtime ties, its traffic —
        at any budget."""
        for budget in (2 * MB, 8 * MB, 32 * MB, 96 * MB):
            g = graph.gemm_chain_graph(
                m=2048, dims_kn=[512, 1024, 512, 1024])
            chain = partition.plan_chain(g, target=T(budget))
            unf = partition.plan_fixed(g, partition.all_cuts(g),
                                       target=T(budget))
            assert _key(chain) <= _key(unf), budget
            assert chain.modeled_runtime_s <= \
                unf.modeled_runtime_s * (1 + 1e-9), budget

    def test_plan_attention_unchanged(self):
        plan = ftl.plan_attention(q_len=4096, kv_len=4096, head_dim=128)
        assert plan.tile("Dh") == 128
        inter = {t.name for t in plan.group.intermediate_tensors()}
        assert inter == {"s", "p"}


# ---------------------------------------------------------------------------
# OpGraph structure
# ---------------------------------------------------------------------------

class TestOpGraph:
    def test_segment_roles(self):
        g = graph.mlp_graph(m=1024, d_model=512, d_ff=2048, gated=True)
        up = g.group(0, 3)              # gemm1 + gate + act_mul
        inter = {t.name for t in up.intermediate_tensors()}
        assert inter == {"h1", "hg"}
        assert up.tensors["h"].role is ftl.Role.OUTPUT
        down = g.group(3, 4)
        assert down.tensors["h"].role is ftl.Role.INPUT

    def test_cross_segment_consumer_keeps_hbm_write(self):
        """A tensor read by a later segment must stay OUTPUT (its HBM
        write counted) even when a consumer exists inside the segment —
        e.g. the gated block's attn_out read by both mlp.gemm1 (inside)
        and mlp.gemm_gate (outside) under a cut between them."""
        cfg = configs.get_config("llama3.2-3b").reduced()   # gated MLP
        g = graph.block_graph(cfg, m=128)
        i_wo = next(i for i, op in enumerate(g.ops)
                    if op.name == "proj.wo")
        i_gate = next(i for i, op in enumerate(g.ops)
                      if op.name == "mlp.gemm_gate")
        seg = g.group(i_wo, i_gate)       # proj.wo + mlp.gemm1 only
        assert seg.tensors["attn_out"].role is ftl.Role.OUTPUT
        # whereas with all consumers inside, it fuses away
        full = g.group(i_wo, g.n_ops)
        assert full.tensors["attn_out"].role is ftl.Role.INTERMEDIATE

    def test_validate_rejects_use_before_production(self):
        from repro.core.ftl.ir import Role, TensorSpec, elementwise
        a = TensorSpec("a", ("M",), "float32", Role.INPUT)
        b = TensorSpec("b", ("M",), "float32", Role.OUTPUT)
        c = TensorSpec("c", ("M",), "float32", Role.OUTPUT)
        op1 = elementwise("uses_c", [c], b)      # c produced later
        op2 = elementwise("makes_c", [a], c)
        g = graph.OpGraph(name="bad", ops=(op1, op2),
                          dims=(ftl.Dim("M", 8),))
        with pytest.raises(ValueError, match="before it is produced"):
            g.validate()

    def test_residual_epilogue(self):
        g = graph.mlp_graph(m=1024, d_model=512, d_ff=2048, residual=True)
        assert g.ops[-1].name == "residual"
        chain = partition.plan_chain(g, target=hw.TPU_V5E)
        # residual fuses for free into the last segment
        last = chain.segments[-1]
        assert "residual" in last.op_names()

    def test_barrier_segment_rejected(self):
        cfg = configs.get_config("llama3.2-3b").reduced()
        g = graph.block_graph(cfg, m=128)
        b = min(g.barriers)
        with pytest.raises(ValueError):
            g.group(b - 1, b + 1)

    def test_block_graph_repeats_and_barriers(self):
        cfg = configs.get_config("llama3.2-3b").reduced()
        g = graph.block_graph(cfg, m=128)
        h = cfg.n_heads
        core = [i for i, op in enumerate(g.ops)
                if op.name.startswith("attn.")]
        assert all(g.repeats[i] == h for i in core)
        chain = partition.plan_chain(g, target=hw.TPU_V5E)
        for s in chain.segments:
            assert not g.crosses_barrier(s.lo, s.hi)
        # traffic accounts per-head multiplicity
        attn_seg = chain.segment_of("attn.qk")
        assert attn_seg.repeat == h
        assert attn_seg.traffic_bytes == attn_seg.plan.traffic_bytes * h

    def test_block_graph_ssm_raises_without_mlp(self):
        cfg = configs.get_config("xlstm-1.3b")
        if cfg.d_ff == 0:
            with pytest.raises(ValueError):
                graph.block_graph(cfg, m=128)

    @pytest.mark.parametrize("arch", [a for a in configs.ARCHS])
    def test_block_graph_covers_config_zoo(self, arch):
        """Any config with attention or an MLP lowers and partitions."""
        cfg = configs.get_config(arch).reduced()
        try:
            g = graph.block_graph(cfg, m=64)
        except ValueError:
            pytest.skip("no plannable block for this family")
        chain = partition.plan_chain(g, target=hw.TPU_V5E)
        names = [n for s in chain.segments for n in s.op_names()]
        assert names == [op.name for op in g.ops]     # covers whole chain


# ---------------------------------------------------------------------------
# executor registry
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_plan_block_bindings_off_tpu(self):
        cfg = configs.get_config("llama3.2-3b").reduced()
        bp = registry.plan_block(cfg, m=128)
        assert bp.platform == jax.default_backend()
        if bp.platform != "tpu":
            assert all(registry.get(b.executor).backend == "xla"
                       for b in bp.bindings)
        kinds = {b.kind for b in bp.bindings}
        assert "mlp" in kinds
        assert bp.summary()

    def test_registry_rejects_duplicates(self):
        ex = registry.executors("mlp")[0]
        with pytest.raises(ValueError):
            registry.register(ex)

    def test_find_prefers_priority(self):
        ctx = registry.ExecContext(kind="mlp", platform="tpu",
                                   schedule="fused")
        assert registry.find("mlp", ctx).name == "pallas_fused_mlp"
        ctx = registry.ExecContext(kind="mlp", platform="cpu",
                                   schedule="fused")
        assert registry.find("mlp", ctx).name == "xla_scan_mlp"
        ctx = registry.ExecContext(kind="mlp", platform="cpu",
                                   schedule="unfused")
        assert registry.find("mlp", ctx).name == "xla_unfused_mlp"

    def test_find_respects_planned_schedule(self):
        """The fully-fused Pallas kernel must NOT be bound when the
        planner chose a partial schedule (its joint tiling may be
        infeasible there); the partial kernels/executors are."""
        ctx = registry.ExecContext(kind="mlp", platform="tpu",
                                   schedule="partial", gated=False)
        assert registry.find("mlp", ctx).name == "pallas_partial_mlp"
        ctx = registry.ExecContext(kind="mlp", platform="tpu",
                                   schedule="partial", gated=True)
        assert registry.find("mlp", ctx).name == "xla_partial_scan_mlp"
        ctx = registry.ExecContext(kind="mlp", platform="tpu",
                                   schedule="unfused")
        assert registry.find("mlp", ctx).name == "xla_unfused_mlp"

    def test_mlp_executor_modes_numerics(self):
        """off / scan / auto agree bitwise-closely on CPU."""
        from repro.models import layers
        cfg = dataclasses.replace(
            configs.get_config("llama3.2-3b").reduced(), mlp_bias=True)
        p = layers.init_mlp(cfg, jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1),
                              (2, 32, cfg.d_model), jnp.float32)
        y_off = layers.mlp_layer(cfg, p, x, ftl_mode="off")
        y_scan = layers.mlp_layer(cfg, p, x, ftl_mode="scan")
        y_auto = layers.mlp_layer(cfg, p, x, ftl_mode="auto")
        np.testing.assert_allclose(y_off, y_scan, rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(y_off, y_auto, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# XLA executors: gated / bias branches (satellite coverage)
# ---------------------------------------------------------------------------

def _ref_mlp(x, w1, w2, wg, b1, b2, act="gelu"):
    h = x @ w1
    if b1 is not None:
        h = h + b1
    h = executor_xla.activation(act)(h)
    if wg is not None:
        h = h * (x @ wg)
    y = h @ w2
    if b2 is not None:
        y = y + b2
    return y


@pytest.fixture()
def mlp_arrays():
    k = jax.random.split(jax.random.PRNGKey(0), 6)
    m, d, f = 64, 32, 48
    x = jax.random.normal(k[0], (m, d), jnp.float32)
    w1 = jax.random.normal(k[1], (d, f), jnp.float32) * d ** -0.5
    w2 = jax.random.normal(k[2], (f, d), jnp.float32) * f ** -0.5
    wg = jax.random.normal(k[3], (d, f), jnp.float32) * d ** -0.5
    b1 = jax.random.normal(k[4], (f,), jnp.float32)
    b2 = jax.random.normal(k[5], (d,), jnp.float32)
    return x, w1, w2, wg, b1, b2


class TestScanExecutorGated:
    def test_mlp_scan_gated_with_biases(self, mlp_arrays):
        x, w1, w2, wg, b1, b2 = mlp_arrays
        y = executor_xla.mlp_scan(x, w1, w2, wg, b1, b2, act="silu",
                                  tile_m=16)
        ref = _ref_mlp(x, w1, w2, wg, b1, b2, act="silu")
        np.testing.assert_allclose(y, ref, rtol=1e-5, atol=1e-5)

    def test_mlp_scan_gated_no_bias(self, mlp_arrays):
        x, w1, w2, wg, _, _ = mlp_arrays
        y = executor_xla.mlp_scan(x, w1, w2, wg, act="silu", tile_m=32)
        ref = _ref_mlp(x, w1, w2, wg, None, None, act="silu")
        np.testing.assert_allclose(y, ref, rtol=1e-5, atol=1e-5)

    def test_mlp_partial_scan_matches(self, mlp_arrays):
        x, w1, w2, wg, b1, b2 = mlp_arrays
        y = executor_xla.mlp_partial_scan(x, w1, w2, wg, b1, b2,
                                          act="silu", tile_m=16)
        ref = _ref_mlp(x, w1, w2, wg, b1, b2, act="silu")
        np.testing.assert_allclose(y, ref, rtol=1e-5, atol=1e-5)

    def test_mlp_from_plan_gated(self, mlp_arrays):
        x, w1, w2, wg, b1, b2 = mlp_arrays
        m, d = x.shape
        f = w1.shape[1]
        g = ftl.fusion.mlp(m=m, d_model=d, d_ff=f, dtype="float32",
                           gated=True, fuse=True)
        plan = ftl.solve(g, target=hw.TPU_V5E)
        y = executor_xla.mlp_from_plan(plan, x, w1, w2, wg, b1, b2,
                                       act="silu")
        ref = _ref_mlp(x, w1, w2, wg, b1, b2, act="silu")
        np.testing.assert_allclose(y, ref, rtol=1e-5, atol=1e-5)

    def test_bad_tile_rejected(self, mlp_arrays):
        x, w1, w2, *_ = mlp_arrays
        with pytest.raises(ValueError):
            executor_xla.mlp_scan(x, w1, w2, tile_m=7)
