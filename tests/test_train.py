"""Training substrate tests: losses, optimizer, grad accumulation, and the
end-to-end convergence integration test."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.optim import OptConfig, adamw_update, init_opt_state, lr_schedule
from repro.train import steps as S
from repro.train.losses import cross_entropy


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def test_cross_entropy_matches_manual():
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (2, 5, 11))
    labels = jax.random.randint(key, (2, 5), 0, 11)
    loss, aux = cross_entropy(logits, labels)
    p = jax.nn.log_softmax(logits, -1)
    manual = -jnp.take_along_axis(p, labels[..., None], -1).mean()
    np.testing.assert_allclose(loss, manual, rtol=1e-5)
    assert 0.0 <= float(aux["accuracy"]) <= 1.0


def test_cross_entropy_mask():
    logits = jnp.zeros((1, 4, 7))
    labels = jnp.zeros((1, 4), jnp.int32)
    mask = jnp.array([[1, 1, 0, 0]], jnp.float32)
    loss, aux = cross_entropy(logits, labels, mask)
    np.testing.assert_allclose(loss, np.log(7), rtol=1e-5)
    assert float(aux["tokens"]) == 2.0


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_lr_schedule_shape():
    cfg = OptConfig(peak_lr=1e-3, warmup_steps=10, decay_steps=100,
                    min_lr_ratio=0.1)
    lrs = [float(lr_schedule(jnp.int32(s), cfg)) for s in range(0, 120, 5)]
    assert lrs[0] == 0.0
    assert abs(max(lrs) - 1e-3) < 1e-9
    assert abs(lrs[-1] - 1e-4) < 1e-6            # floor
    assert all(a >= b - 1e-12 for a, b in zip(lrs[2:], lrs[3:]))  # decays


def test_adamw_decay_mask_spares_norms():
    params = {"layers": {"ln1": {"scale": jnp.ones(4)},
                         "mlp": {"w1": {"w": jnp.ones((4, 4))}}}}
    opt = init_opt_state(params)
    grads = jax.tree.map(jnp.zeros_like, params)
    cfg = OptConfig(peak_lr=1.0, warmup_steps=0, decay_steps=1,
                    weight_decay=0.5)
    new_params, _, _ = adamw_update(grads, opt, params, jnp.int32(5), cfg)
    # zero grad + decay: weights shrink, norm scales don't
    assert float(new_params["layers"]["ln1"]["scale"][0]) == 1.0
    assert float(new_params["layers"]["mlp"]["w1"]["w"][0, 0]) < 1.0


def test_grad_clip_bounds_update():
    params = {"w": jnp.zeros((4,))}
    opt = init_opt_state(params)
    grads = {"w": jnp.full((4,), 1e6)}
    cfg = OptConfig(peak_lr=1e-3, warmup_steps=0, decay_steps=1,
                    grad_clip=1.0, weight_decay=0.0)
    _, _, metrics = adamw_update(grads, opt, params, jnp.int32(5), cfg)
    assert float(metrics["grad_norm"]) > 1e5   # reported pre-clip


# ---------------------------------------------------------------------------
# grad accumulation
# ---------------------------------------------------------------------------

def test_accum_equivalent_to_full_batch():
    cfg = configs.get_config("llama3.2-3b").reduced()
    import dataclasses
    cfg = dataclasses.replace(cfg, remat=False)
    opt = OptConfig(peak_lr=1e-3, warmup_steps=0, decay_steps=10)
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(0), (8, 32), 0, cfg.vocab_size)}
    s0 = S.init_train_state(cfg, jax.random.PRNGKey(1))
    s1 = S.init_train_state(cfg, jax.random.PRNGKey(1))
    st_a, m_a = jax.jit(S.make_train_step(cfg, None, opt, accum=1))(s0, batch)
    st_b, m_b = jax.jit(S.make_train_step(cfg, None, opt, accum=4))(s1, batch)
    np.testing.assert_allclose(float(m_a["loss"]), float(m_b["loss"]),
                               rtol=1e-4)
    for a, b in zip(jax.tree.leaves(st_a.params),
                    jax.tree.leaves(st_b.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-3, atol=5e-5)


# ---------------------------------------------------------------------------
# end-to-end convergence (integration)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_bigram_convergence_toward_floor():
    cfg = configs.get_config("yi-6b").reduced()
    dc = DataConfig(vocab_size=cfg.vocab_size, global_batch=8, seq_len=64,
                    kind="bigram", noise=4)
    ds = SyntheticLM(dc, process_index=0, process_count=1)
    st = S.init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(S.make_train_step(
        cfg, None, OptConfig(peak_lr=1e-2, warmup_steps=5, decay_steps=60)))
    losses = []
    for i in range(60):
        batch = {k: jnp.asarray(v) for k, v in ds.batch_at(i).items()}
        st, m = step(st, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 2.0, (losses[0], losses[-1])
    assert losses[-1] < 4.0             # approaching log(noise)=1.386


def test_moe_aux_loss_reported():
    cfg = configs.get_config("moonshot-v1-16b-a3b").reduced()
    st = S.init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(S.make_train_step(cfg, None, OptConfig()))
    batch = {"tokens": jnp.ones((2, 32), jnp.int32)}
    _, metrics = step(st, batch)
    assert "moe_aux" in metrics
    # balanced-ish routing at init: aux ~ 1 for E·Σ me·ce with uniform
    assert 0.1 < float(metrics["moe_aux"]) < 10.0
