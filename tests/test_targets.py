"""Memory-hierarchy Target tests: preset validity, planner monotonicity
in fast-level capacity, cross-preset feasibility on the zoo configs, the
paper's qualitative result on the Siracusa-like preset, the plan-cache
target-keying regression, and target-aware executor qualification."""
import dataclasses

import pytest

from repro import configs
from repro.core import ftl, hw
from repro.core.ftl import graph, partition, registry
from repro.core.ftl.solver import InfeasibleError

KB, MB = 1 << 10, 1 << 20


# a single-backing-level target with zero DMA setup: the modeled-time
# objective reduces to traffic/bw, so traffic-vs-capacity monotonicity is
# exact (with setup cost, a bigger scratchpad may legitimately trade a
# few bytes for far fewer transfers)
def _flat(budget: int) -> hw.Target:
    return hw.Target(
        name=f"flat@{budget}",
        levels=(hw.MemoryLevel("fast", budget, 1e12),
                hw.MemoryLevel("back", 1 << 50, 100e9)),
        flops=1e12,
    )


# ---------------------------------------------------------------------------
# Target construction / presets
# ---------------------------------------------------------------------------

class TestTargetBasics:
    def test_presets_well_formed(self):
        for t in hw.presets():
            assert len(t.levels) >= 2
            assert t.fast is t.levels[0]
            assert t.fast_capacity == t.levels[0].capacity_bytes
            caps = [lv.capacity_bytes for lv in t.levels]
            assert caps == sorted(caps)
        assert {"tpu_v5e", "cpu_cache", "rv32_l1_l2"} <= set(hw.PRESETS)

    def test_rv32_preset_is_two_backing_levels(self):
        t = hw.get_target("rv32_l1_l2")
        assert [lv.name for lv in t.levels] == ["l1", "l2", "l3"]
        assert t.fast_capacity == 256 * KB

    def test_needs_backing_level(self):
        with pytest.raises(ValueError, match="backing"):
            hw.Target(name="x",
                      levels=(hw.MemoryLevel("only", 1 * MB, 1e9),),
                      flops=1e9)

    def test_rejects_shrinking_capacities(self):
        with pytest.raises(ValueError, match="smaller"):
            hw.Target(name="x",
                      levels=(hw.MemoryLevel("fast", 2 * MB, 1e9),
                              hw.MemoryLevel("back", 1 * MB, 1e9)),
                      flops=1e9)

    def test_with_fast_capacity(self):
        t = hw.TPU_V5E.with_fast_capacity(8 * MB)
        assert t.fast_capacity == 8 * MB
        assert t != hw.TPU_V5E              # distinct plan-cache key
        assert hash(t) != hash(hw.TPU_V5E)

    def test_default_target_override(self):
        # no override: auto-detected from the process's JAX devices
        # (cpu_cache on the CPU-only test host)
        detected = hw.detect_target()
        assert hw.default_target() == detected
        try:
            hw.set_default_target("rv32_l1_l2")
            assert hw.default_target().name == "rv32_l1_l2"
        finally:
            hw.set_default_target(None)
        assert hw.default_target() == detected

    def test_assign_homes_spills_big_tensors_deeper(self):
        t = hw.get_target("rv32_l1_l2")
        homes = t.assign_homes({"small": 512 * KB, "big": 9 * MB})
        assert homes["small"].name == "l2"
        assert homes["big"].name == "l3"     # exceeds free L2 -> spill


# ---------------------------------------------------------------------------
# target auto-detection from the JAX device list
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _FakeDev:
    platform: str
    device_kind: str = ""


class TestDetectTarget:
    def test_cpu_host_maps_to_cache_blocked_preset(self):
        assert hw.detect_target([_FakeDev("cpu", "cpu")]) is hw.CPU_CACHE

    def test_tpu_v5e_maps_to_preset(self):
        for kind in ("TPU v5 lite", "TPU v5e"):
            assert hw.detect_target([_FakeDev("tpu", kind)]) is hw.TPU_V5E

    def test_tpu_generations_scale_flops(self):
        v4 = hw.detect_target([_FakeDev("tpu", "TPU v4")])
        v5p = hw.detect_target([_FakeDev("tpu", "TPU v5p")])
        v6 = hw.detect_target([_FakeDev("tpu", "TPU v6 lite")])
        assert v4.name == "tpu_v4" and v4.flops == 275e12
        assert v5p.name == "tpu_v5p" and v5p.flops > v4.flops
        assert v6.name == "tpu_v6e" and v6.flops > v5p.flops
        # all well-formed planning targets (fast + backing, DMA-fed VMEM)
        for t in (v4, v5p, v6):
            assert t.fast.name == "vmem" and t.fast.buffer_depth == 2
            assert len(t.levels) == 3

    def test_unknown_platform_falls_back_to_v5e(self):
        assert hw.detect_target([_FakeDev("gpu", "NVIDIA H100")]) \
            is hw.TPU_V5E
        assert hw.detect_target([]) is hw.TPU_V5E

    def test_default_target_uses_detection(self, monkeypatch):
        """default_target resolution: set_default_target override, then
        FTL_TARGET, then the (memoized) device detection."""
        detected = hw.detect_target([_FakeDev("tpu", "TPU v4")])
        monkeypatch.setattr(hw, "_RESOLVED", {None: detected})
        monkeypatch.setattr(hw, "_DEFAULT", [None])
        monkeypatch.delenv("FTL_TARGET", raising=False)
        assert hw.default_target().name == "tpu_v4"
        monkeypatch.setenv("FTL_TARGET", "rv32_l1_l2")
        assert hw.default_target().name == "rv32_l1_l2"
        hw.set_default_target("cpu_cache")
        try:
            assert hw.default_target().name == "cpu_cache"
        finally:
            hw.set_default_target(None)

    def test_detection_memoized_once(self, monkeypatch):
        calls = []

        def fake_detect(devices=None):
            calls.append(1)
            return hw.CPU_CACHE

        monkeypatch.setattr(hw, "_RESOLVED", {})
        monkeypatch.setattr(hw, "detect_target", fake_detect)
        monkeypatch.delenv("FTL_TARGET", raising=False)
        hw.default_target()
        hw.default_target()
        assert len(calls) == 1

    def test_env_flip_mid_process_takes_effect(self, monkeypatch):
        """Regression: the resolution memo must be keyed by the env
        state — flipping FTL_TARGET after the first lookup (or clearing
        it back to detection) must change the answer, not be shadowed by
        the first memoized resolution."""
        monkeypatch.setattr(hw, "_RESOLVED", {})
        monkeypatch.setattr(hw, "_DEFAULT", [None])
        monkeypatch.setattr(hw, "detect_target",
                            lambda devices=None: hw.CPU_CACHE)
        monkeypatch.delenv("FTL_TARGET", raising=False)
        assert hw.default_target() is hw.CPU_CACHE   # memoizes detection
        monkeypatch.setenv("FTL_TARGET", "rv32_npu")
        assert hw.default_target().name == "rv32_npu"
        monkeypatch.setenv("FTL_TARGET", "tpu_v5e")
        assert hw.default_target().name == "tpu_v5e"
        monkeypatch.delenv("FTL_TARGET")
        assert hw.default_target() is hw.CPU_CACHE   # back to detection
        # set_default_target clears the memo: a later un-override
        # re-resolves rather than serving the pre-override memo entry
        hw.set_default_target("rv32_l1_l2")
        try:
            assert hw.default_target().name == "rv32_l1_l2"
        finally:
            hw.set_default_target(None)
        assert hw.default_target() is hw.CPU_CACHE


# ---------------------------------------------------------------------------
# engines: per-op-kind compute rates
# ---------------------------------------------------------------------------

class TestEngines:
    def test_rv32_npu_preset(self):
        t = hw.get_target("rv32_npu")
        assert [lv.name for lv in t.levels] == ["l1", "l2", "l3"]
        assert {e.name for e in t.engines} == {"npu", "cluster"}
        assert t.engine_rate("gemm") == ("npu", 128e9)
        assert t.engine_rate("elementwise") == ("cluster", 0.3e9)

    def test_engineless_target_runs_everything_on_core(self):
        assert hw.TPU_V5E.engine_rate("gemm") == ("core", hw.TPU_V5E.flops)
        assert hw.TPU_V5E.compute_time_by_kind({"gemm": 2e12, "x": 1e12}) \
            == hw.TPU_V5E.compute_time_s(3e12)

    def test_engines_overlap_one_engine_serializes(self):
        t = hw.get_target("rv32_npu")
        mix = {"gemm": 128e9, "elementwise": 0.3e9}
        # one second of work per engine: overlapped => 1 s, not 2
        assert t.compute_time_by_kind(mix) == pytest.approx(1.0)
        times = t.engine_times(mix)
        assert times["npu"] == pytest.approx(1.0)
        assert times["cluster"] == pytest.approx(1.0)

    def test_unroutable_kind_raises_without_catch_all(self):
        t = dataclasses.replace(
            hw.RV32_NPU,
            engines=(hw.Engine("npu", (("gemm", 128e9),)),))
        with pytest.raises(ValueError, match="catch-all"):
            t.engine_rate("elementwise")

    def test_engines_survive_derived_targets(self):
        t = hw.RV32_NPU.with_fast_capacity(512 * KB).with_buffer_depth(3)
        assert {e.name for e in t.engines} == {"npu", "cluster"}

    def test_hw_profiles_collapse_onto_engines(self):
        """The benchmark profiles' macs/ew split is the shared Engine
        model now: NPU profiles overlap the two kinds, cluster-only
        profiles serialize them on one engine."""
        from benchmarks import hw_profiles as hp
        npu = hp.SIRACUSA_NPU.target()
        clu = hp.SIRACUSA_CLUSTER.target()
        mix = {"gemm": 2.0 * 64e9, "elementwise": 0.3e9}   # 1 s each
        assert npu.compute_time_by_kind(mix) == pytest.approx(1.0)
        mix_c = {"gemm": 2.0 * 3e9, "elementwise": 0.3e9}
        assert clu.compute_time_by_kind(mix_c) == pytest.approx(2.0)


# ---------------------------------------------------------------------------
# property: solved traffic monotone non-increasing in fast capacity
# ---------------------------------------------------------------------------

BUDGET_LADDER = (1 * MB, 2 * MB, 8 * MB, 32 * MB, 96 * MB)


def _monotone_check(m, k, n, lo, hi):
    g = lambda: ftl.fusion.mlp(m=m, d_model=k, d_ff=n, fuse=True)  # noqa
    try:
        t_lo = ftl.solve(g(), target=_flat(lo)).traffic_bytes
    except InfeasibleError:
        return
    t_hi = ftl.solve(g(), target=_flat(hi)).traffic_bytes
    assert t_hi <= t_lo


@pytest.mark.parametrize("m,k,n", [(512, 256, 1024), (3072, 768, 3072),
                                   (2048, 2048, 2048)])
def test_traffic_monotone_in_fast_capacity(m, k, n):
    """Growing the fast level never increases the solved traffic: the
    feasible tile set only grows with capacity and the (zero-setup)
    objective is traffic-proportional.  Deterministic ladder sweep; the
    hypothesis variant below fuzzes shapes when hypothesis is installed."""
    for lo, hi in zip(BUDGET_LADDER, BUDGET_LADDER[1:]):
        _monotone_check(m, k, n, lo, hi)


try:
    import hypothesis.strategies as st
    from hypothesis import given, settings

    dim = st.sampled_from([256, 512, 768, 1024, 2048])
    budget = st.sampled_from(BUDGET_LADDER)

    @settings(max_examples=30, deadline=None)
    @given(m=dim, k=dim, n=dim, b1=budget, b2=budget)
    def test_traffic_monotone_in_fast_capacity_fuzz(m, k, n, b1, b2):
        _monotone_check(m, k, n, min(b1, b2), max(b1, b2))
except ImportError:  # pragma: no cover - hypothesis optional locally
    pass


# ---------------------------------------------------------------------------
# every preset plans the zoo configs test_block_exec executes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch",
                         ["llama3.2-3b", "granite-20b", "recurrentgemma-9b"])
@pytest.mark.parametrize("target", list(hw.presets()),
                         ids=lambda t: t.name)
def test_presets_feasible_on_zoo_configs(arch, target):
    cfg = dataclasses.replace(configs.get_config(arch).reduced(),
                              dtype="float32", remat=False, ftl_mode="auto")
    bp = registry.plan_block(cfg, m=32, dtype="float32", target=target)
    assert bp.target == target
    assert bp.traffic_bytes > 0
    # per-level accounting covers the whole boundary traffic
    assert sum(bp.per_level_traffic.values()) == bp.traffic_bytes


# ---------------------------------------------------------------------------
# the paper's qualitative result on the Siracusa-like hierarchy
# ---------------------------------------------------------------------------

def test_rv32_fused_moves_less_backing_traffic_than_unfused():
    """ViT-MLP GEMM→GeLU (the paper's Fig. 3 op, int8) on rv32_l1_l2:
    the fused segment must move fewer backing-store bytes than the
    layer-per-layer schedule — the paper's core claim."""
    t = hw.get_target("rv32_l1_l2")
    g = graph.gemm_act_graph(m=3072, k=768, n=3072, dtype="int8")
    fused = partition.plan_fixed(g, (), target=t)
    unfused = partition.plan_fixed(g, partition.all_cuts(g), target=t)
    assert fused.traffic_bytes < unfused.traffic_bytes
    assert fused.transfer_time_s < unfused.transfer_time_s
    # and the DP agrees fusion is the right schedule on this machine
    assert partition.plan_chain(g, target=t).schedule == "fused"


def test_full_mlp_segment_on_rv32_beats_unfused_when_feasible():
    """The whole (reduced-size) MLP chain on the 256 KiB L1: whatever the
    DP picks must not exceed the unfused schedule's backing traffic."""
    t = hw.get_target("rv32_l1_l2")
    g = graph.mlp_graph(m=512, d_model=256, d_ff=1024, dtype="int8")
    chain = partition.plan_chain(g, target=t)
    unfused = partition.plan_fixed(g, partition.all_cuts(g), target=t)
    assert chain.traffic_bytes <= unfused.traffic_bytes


# ---------------------------------------------------------------------------
# regression: the model-level plan cache is keyed by target
# ---------------------------------------------------------------------------

def test_model_block_plan_cache_keys_target():
    """Changing the planning target (default or explicit) must never serve
    a stale cached plan made for a different hierarchy."""
    from repro.models import model as M
    cfg = dataclasses.replace(configs.get_config("llama3.2-3b").reduced(),
                              dtype="float32", remat=False, ftl_mode="auto")
    plan_default = M._block_plan(cfg, 32, "float32")
    assert plan_default is not None
    assert plan_default.target == hw.default_target()
    # explicit target: distinct plan object for a distinct machine
    plan_rv32 = M._block_plan(cfg, 32, "float32",
                              target=hw.get_target("rv32_l1_l2"))
    assert plan_rv32 is not None
    assert plan_rv32.target.name == "rv32_l1_l2"
    assert plan_rv32 is not plan_default
    # default-target switch reaches the cache key too
    try:
        hw.set_default_target("rv32_l1_l2")
        plan_switched = M._block_plan(cfg, 32, "float32")
        assert plan_switched is not None
        assert plan_switched.target.name == "rv32_l1_l2"
        assert plan_switched is not plan_default
    finally:
        hw.set_default_target(None)
    # and with the default restored, the original plan is served again
    assert M._block_plan(cfg, 32, "float32") is plan_default


# ---------------------------------------------------------------------------
# target-aware executor qualification
# ---------------------------------------------------------------------------

class TestTargetQualification:
    def test_pallas_requires_vmem_class_target(self):
        """A plan made for a KiB-scale scratchpad must not bind the Pallas
        kernels even on a TPU host — its tiles assume another machine.
        (Shape-less contexts fall back to the capacity-class check.)"""
        ctx = registry.ExecContext(kind="mlp", platform="tpu",
                                   schedule="fused",
                                   target=hw.get_target("rv32_l1_l2"))
        assert registry.find("mlp", ctx).name == "xla_scan_mlp"
        ctx = registry.ExecContext(kind="mlp", platform="tpu",
                                   schedule="fused", target=hw.TPU_V5E)
        assert registry.find("mlp", ctx).name == "pallas_fused_mlp"

    def test_pallas_mlp_qualifies_by_kernel_tile_footprint(self):
        """With shapes in the context, qualification solves the kernel's
        own dataflow (K/N whole) on the plan target: a weight panel that
        cannot fit the fast level disqualifies the kernel even on a
        VMEM-class target — where the old capacity-class check would
        have happily bound it."""
        small_vmem = hw.TPU_V5E.with_fast_capacity(8 * MB)
        assert registry._vmem_class(small_vmem)       # old check: fine
        big = registry.ExecContext(
            kind="mlp", platform="tpu", schedule="fused",
            m=8192, d_model=8192, d_ff=32768, dtype="bfloat16",
            target=small_vmem)
        # w1 alone is 8192*32768*2 B = 512 MiB >> 8 MiB: must fall back
        assert registry.find("mlp", big).name == "xla_scan_mlp"
        ok = registry.ExecContext(
            kind="mlp", platform="tpu", schedule="fused",
            m=4096, d_model=256, d_ff=1024, dtype="bfloat16",
            target=small_vmem)
        assert registry.find("mlp", ok).name == "pallas_fused_mlp"
        # and the rv32 scratchpad fails the footprint probe with shapes
        rv = registry.ExecContext(
            kind="mlp", platform="tpu", schedule="fused",
            m=4096, d_model=768, d_ff=3072, dtype="bfloat16",
            target=hw.get_target("rv32_l1_l2"))
        assert registry.find("mlp", rv).name == "xla_scan_mlp"

    def test_partial_mlp_probes_per_gemm_footprint(self):
        """The partial Pallas path runs its two GEMM kernels
        sequentially, one weight panel each: shapes whose *fused*
        whole-K/N solve cannot fit must still qualify the partial
        executor when each GEMM alone is plannable."""
        small_vmem = hw.TPU_V5E.with_fast_capacity(8 * MB)
        ctx = registry.ExecContext(
            kind="mlp", platform="tpu", schedule="partial",
            m=4096, d_model=16384, d_ff=16384, dtype="bfloat16",
            target=small_vmem)
        # fused probe fails (whole-K weight columns alone overflow)...
        assert not registry._mlp_kernel_footprint_fits(
            4096, 16384, 16384, "bfloat16", False, "gelu", small_vmem)
        # ...but the per-GEMM partial probe qualifies the kernel
        assert registry.find("mlp", ctx).name == "pallas_partial_mlp"

    def test_kernel_block_planning_survives_cpu_default_target(self):
        """ops.plan_*_blocks with target=None must not solve against the
        auto-detected cpu_cache default (whose 1 MiB fast level cannot
        hold the kernels' weight panels): a non-VMEM-class process
        default falls back to the TPU preset."""
        from repro.kernels import ops
        assert ops._kernel_target(None).fast.capacity_bytes >= 4 * MB
        try:
            hw.set_default_target("cpu_cache")
            assert ops._kernel_target(None) is hw.TPU_V5E
            assert ops.plan_mlp_blocks(
                4096, 768, 3072, "bfloat16", False, "gelu") == \
                ops.plan_mlp_blocks(4096, 768, 3072, "bfloat16", False,
                                    "gelu", target=hw.TPU_V5E)
            hw.set_default_target("rv32_npu")
            assert ops._kernel_target(None) is hw.TPU_V5E
            hw.set_default_target("tpu_v5e")
            assert ops._kernel_target(None) is hw.TPU_V5E
        finally:
            hw.set_default_target(None)

    def test_pallas_attention_qualifies_by_kernel_tile_footprint(self):
        rv = registry.ExecContext(
            kind="attention", platform="tpu", schedule="fused",
            m=4096, head_dim=128, dtype="bfloat16",
            target=hw.get_target("rv32_l1_l2"))
        assert registry.find("attention", rv).name == "xla_ref_attention"
        tpu = registry.ExecContext(
            kind="attention", platform="tpu", schedule="fused",
            m=4096, head_dim=128, dtype="bfloat16", target=hw.TPU_V5E)
        assert registry.find("attention", tpu).name == \
            "pallas_flash_attention"

    def test_plan_block_context_carries_head_dim(self):
        cfg = dataclasses.replace(configs.get_config("llama3.2-3b")
                                  .reduced(),
                                  dtype="float32", remat=False,
                                  ftl_mode="auto")
        plan = registry.plan_block(cfg, m=32, dtype="float32",
                                   target=hw.TPU_V5E)
        # requalification context exposes the head dim for the probe
        from repro.core.ftl import executor_block as eb
        ctx = eb._runtime_ctx(plan, "attention", "fused", 32, "float32")
        assert ctx.head_dim == cfg.resolved_head_dim

    def test_run_block_executors_bound_to_plan_target(self):
        """Every resolved stage executor must run pinned to the plan's own
        target — the Pallas kernels' block-size planning and the scan
        executors' token tile would otherwise silently re-plan against
        whatever the process default is at run time."""
        from repro.core.ftl import executor_block as eb
        cfg = dataclasses.replace(
            configs.get_config("llama3.2-3b").reduced(),
            dtype="float32", remat=False, ftl_mode="auto")
        plan = registry.plan_block(cfg, m=32, dtype="float32",
                                   target=hw.get_target("rv32_l1_l2"))
        for resolver in (eb._resolve_gemm, eb._resolve_attention,
                         eb._resolve_mlp):
            ex = resolver(plan, "auto", 32, "float32")
            assert ex.run.keywords["target"] == plan.target, resolver

    def test_with_fast_capacity_drops_outgrown_backing_levels(self):
        t = hw.get_target("rv32_l1_l2").with_fast_capacity(8 * MB)
        # the 2 MiB L2 cannot back an 8 MiB scratchpad: dropped, spill
        # reprices at L3; the deepest level is always kept
        assert [lv.name for lv in t.levels] == ["l1", "l3"]

    def test_roofline_hw_derives_from_same_target(self):
        from repro.roofline.analysis import DEFAULT_HW, HW
        rebuilt = HW.from_target(hw.TPU_V5E)
        assert rebuilt == DEFAULT_HW
        assert rebuilt.vmem_bytes == hw.TPU_V5E.fast_capacity
        assert rebuilt.hbm_bw == hw.TPU_V5E.levels[1].bw_bytes_per_s
        assert rebuilt.peak_flops == hw.TPU_V5E.flops
        assert rebuilt.target_name == "tpu_v5e"
