"""Sharding-rule unit tests — pure PartitionSpec logic, no devices needed.

Uses an abstract mesh-shaped stand-in so the 16×16 production rules are
testable on a 1-device box."""
import jax
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro import configs
from repro.distributed.sharding import (
    _div, axis_size, batch_pspecs, cache_pspecs, dp_axes, param_pspecs)
from repro.models import model as M

MESH = AbstractMesh((("data", 16), ("model", 16)))
MESH3 = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))


def test_div_guards_divisibility():
    assert _div(MESH, 64000, "model") == "model"
    assert _div(MESH, 51865, "model") is None       # whisper vocab: odd
    assert _div(MESH, 1, ("pod", "data")) is None


def test_dp_axes():
    assert dp_axes(MESH) == ("data",)
    assert dp_axes(MESH3) == ("pod", "data")
    assert axis_size(MESH3, ("pod", "data")) == 32


@pytest.mark.parametrize("arch", list(configs.ARCHS))
def test_param_specs_valid_for_all_archs(arch):
    """Every leaf gets a spec with rank == leaf rank and sharded dims
    divisible by their axis product (GSPMD hard requirement)."""
    cfg = configs.get_config(arch)
    shapes = M.param_shapes(cfg)
    specs = param_pspecs(shapes, MESH, cfg)
    flat_s = jax.tree_util.tree_leaves_with_path(shapes)
    flat_p = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_s) == len(flat_p)
    for (path, leaf), spec in zip(flat_s, flat_p):
        assert len(spec) <= len(leaf.shape), (path, spec, leaf.shape)
        for dim, ax in zip(leaf.shape, spec):
            if ax is None:
                continue
            assert dim % axis_size(MESH, ax) == 0, (path, spec, leaf.shape)


def test_whisper_vocab_not_sharded():
    cfg = configs.get_config("whisper-base")
    shapes = M.param_shapes(cfg)
    specs = param_pspecs(shapes, MESH, cfg)
    assert specs["embed"]["tok"][0] is None         # 51865 % 16 != 0
    assert specs["lm_head"]["w"][1] is None


def test_dense_2d_layout():
    """FSDP on d_model, TP on heads/d_ff; transposed for the output mats."""
    cfg = configs.get_config("qwen2-72b")
    specs = param_pspecs(M.param_shapes(cfg), MESH, cfg)
    lyr = specs["layers"]["pos0"]
    assert lyr["attn"]["wq"]["w"] == P(None, "data", "model")
    assert lyr["attn"]["wo"]["w"] == P(None, "model", "data")
    assert lyr["mlp"]["w1"]["w"] == P(None, "data", "model")
    assert lyr["mlp"]["w2"]["w"] == P(None, "model", "data")
    assert lyr["ln1"]["scale"] == P(None, None)


def test_moe_expert_parallel_when_divisible():
    cfg = configs.get_config("moonshot-v1-16b-a3b")        # E=64
    specs = param_pspecs(M.param_shapes(cfg), MESH, cfg)
    moe = specs["layers"]["pos0"]["moe"]
    assert moe["w1"] == P(None, "model", "data", None)     # EP
    cfg2 = configs.get_config("qwen2-moe-a2.7b")           # E=60
    specs2 = param_pspecs(M.param_shapes(cfg2), MESH, cfg2)
    moe2 = specs2["layers"]["pos0"]["moe"]
    assert moe2["w1"] == P(None, None, "data", "model")    # TP inside expert
    assert moe2["w2"] == P(None, None, "model", "data")


def test_multipod_pod_axis_is_pure_dp():
    """Params must NOT shard over 'pod' (pod-replicated, DESIGN.md §6)."""
    cfg = configs.get_config("yi-6b")
    specs = param_pspecs(M.param_shapes(cfg), MESH3, cfg)
    for spec in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)):
        for ax in spec:
            axes = (ax,) if isinstance(ax, str) else (ax or ())
            assert "pod" not in axes
    # but the batch DOES shard over pod
    import jax.numpy as jnp
    b = batch_pspecs({"tokens": jax.ShapeDtypeStruct((256, 4096),
                                                     jnp.int32)}, MESH3)
    assert b["tokens"][0] == ("pod", "data")


def test_cache_specs_shard_seq_over_model():
    cfg = configs.get_config("qwen2-72b")
    cache = jax.eval_shape(lambda: M.init_cache(cfg, 128, 32768))
    specs = cache_pspecs(cache, MESH, cfg)
    kspec = specs["layers"]["pos0"]["k"]
    assert kspec == P(None, "data", "model", None, None)


def test_cache_specs_batch1_not_sharded():
    cfg = configs.get_config("xlstm-1.3b")
    cache = jax.eval_shape(lambda: M.init_cache(cfg, 1, 524288))
    specs = cache_pspecs(cache, MESH, cfg)
    for spec in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)):
        if len(spec) >= 2:
            assert spec[1] is None or spec[1] == "model"   # B=1 → no dp
