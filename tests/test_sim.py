"""repro.sim test harness: schedule-lowering invariants (the event
timeline reproduces the cost model's traffic/DMA/compute totals event by
event), simulated-vs-analytic agreement (floor, upper bound, 10 %
convergence when transfer-bound and pipelined, equality when one
resource dominates at depth ≥ 2), the buffer-depth monotonicity property
(hypothesis-fuzzed), the rv32_npu overlap regime, zoo coverage on every
preset, and the bench_schedule artifact + gate."""
import dataclasses
import json

import pytest

from repro import configs, sim
from repro.core import hw
from repro.core.ftl import graph, partition, registry
from repro.core.ftl.solver import InfeasibleError

KB, MB = 1 << 10, 1 << 20

PRESETS = list(hw.presets())
PRESET_IDS = [t.name for t in PRESETS]
ZOO = ["llama3.2-3b", "granite-20b", "recurrentgemma-9b"]


def _flat(budget: int, flops: float = 1e12, bw: float = 100e9) -> hw.Target:
    return hw.Target(
        name=f"flat@{budget}@{flops:g}",
        levels=(hw.MemoryLevel("fast", budget, 1e12),
                hw.MemoryLevel("back", 1 << 50, bw)),
        flops=flops,
    )


def _chain(m=3072, k=768, n=3072, dtype="int8", *, target, cuts=()):
    g = graph.gemm_act_graph(m=m, k=k, n=n, dtype=dtype)
    return partition.plan_fixed(g, cuts, target=target)


# ---------------------------------------------------------------------------
# lowering invariants: the schedule IS the cost model, event by event
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("target", PRESETS, ids=PRESET_IDS)
def test_lowering_reproduces_cost_totals(target):
    chain = _chain(target=target)
    for seg, (sched, rep) in zip(chain.segments, sim.lower_chain(chain)):
        report = seg.plan.report
        assert rep == seg.repeat
        assert sched.n_steps == seg.n_steps
        dmas = sched.dma_events()
        # DMA count and per-level bytes match the analytic report exactly
        assert len(dmas) == report.dma_transfers
        by_level: dict[str, int] = {}
        for e in dmas:
            by_level[e.level] = by_level.get(e.level, 0) + e.bytes
        assert by_level == report.per_level_traffic
        assert sum(by_level.values()) == report.traffic_bytes
        # per-tensor fetch bytes match too
        per_tensor: dict[str, int] = {}
        for e in dmas:
            per_tensor[e.tensor] = per_tensor.get(e.tensor, 0) + e.bytes
        assert per_tensor == report.per_tensor_traffic
        # total engine busy time == analytic per-engine compute
        busy: dict[str, float] = {}
        for e in sched.compute_events():
            busy[e.engine] = busy.get(e.engine, 0.0) + e.seconds
        for eng, t in report.per_engine_compute_s.items():
            assert busy[eng] == pytest.approx(t, rel=1e-9)
        # homes: every DMA event targets the tensor's assigned level
        for e in dmas:
            assert e.level == report.tensor_homes[e.tensor]


def test_buffer_slots_cycle_through_depth():
    t = hw.get_target("rv32_l1_l2")          # depth-2 DMA-fed L1
    sched = sim.lower_plan(_chain(target=t).segments[0].plan)
    assert sched.buffer_depth == 2
    per_tensor_slots: dict[str, list[int]] = {}
    for e in sched.dma_events():
        if isinstance(e, sim.DmaIn):
            per_tensor_slots.setdefault(e.tensor, []).append(e.slot)
            assert e.slot == e.fetch % 2
    # at least one streamed tensor actually ping-pongs
    assert any(set(s) == {0, 1} for s in per_tensor_slots.values())


def _nondivisor_plan(target, tiles):
    """A TilePlan with hand-forced (non-divisor) tiles: re-evaluated
    through the cost model exactly like the autotuner's nudge move."""
    from repro.core.ftl import cost
    g = graph.gemm_act_graph(m=384, k=768, n=512, dtype="int8")
    plan0 = partition.plan_fixed(g, (), target=target).segments[0].plan
    rep = cost.evaluate(plan0.group, tiles, plan0.constraints, target=target)
    return dataclasses.replace(plan0, tiles=dict(tiles), report=rep)


@pytest.mark.parametrize("target", PRESETS, ids=PRESET_IDS)
def test_edge_tiles_reproduce_cost_totals_exactly(target):
    """Non-divisor tiles: remainder steps carry truly smaller DMA bytes and
    compute seconds, and the events still sum to the cost model's totals
    event by event — ints exactly, engine seconds to float rounding."""
    tiles = {"M": 160, "K": 768, "F": 192}      # 384 % 160 != 0, 512 % 192 != 0
    plan = _nondivisor_plan(target, tiles)
    rep = plan.report
    sched = sim.lower_plan(plan)
    assert sched.n_steps == rep.n_steps
    dmas = sched.dma_events()
    assert len(dmas) == rep.dma_transfers
    per_tensor: dict[str, int] = {}
    by_level: dict[str, int] = {}
    for e in dmas:
        per_tensor[e.tensor] = per_tensor.get(e.tensor, 0) + e.bytes
        by_level[e.level] = by_level.get(e.level, 0) + e.bytes
    # exact int equality — no float slack anywhere in the byte accounting
    assert per_tensor == rep.per_tensor_traffic
    assert by_level == rep.per_level_traffic
    assert sum(by_level.values()) == rep.traffic_bytes
    # edge steps really are smaller: distinct event sizes per tensor
    in_sizes = {e.bytes for e in dmas if isinstance(e, sim.DmaIn)
                and e.tensor == "x"}
    assert len(in_sizes) > 1
    busy: dict[str, float] = {}
    for e in sched.compute_events():
        busy[e.engine] = busy.get(e.engine, 0.0) + e.seconds
    for eng, t in rep.per_engine_compute_s.items():
        assert busy[eng] == pytest.approx(t, rel=1e-9)
    # and the replay stays within the usual analytic bounds
    r = sim.simulate(sched)
    assert r.runtime_s >= sched.modeled_runtime_s * (1 - 1e-9)
    assert r.runtime_s <= (sum(sched.per_engine_compute_s.values())
                           + sched.transfer_time_s) * (1 + 1e-9)


def test_backing_level_depth_deepens_staging():
    """with_level_buffer_depth on a *backing* level must raise the
    staging depth of tensors homed there (max(fast, home)), show up in
    the lowered slots, and never slow the replay down."""
    base = hw.get_target("cpu_cache")           # every level depth 1
    deep = base.with_level_buffer_depth("llc", 3)
    assert deep.name == "cpu_cache@llcd3"
    g = graph.gemm_act_graph(m=3072, k=768, n=3072, dtype="int8")
    s_base = sim.lower_plan(
        partition.plan_fixed(g, (), target=base).segments[0].plan)
    s_deep = sim.lower_plan(
        partition.plan_fixed(g, (), target=deep).segments[0].plan)
    llc_tensors = {e.tensor for e in s_deep.dma_events()
                   if e.level == "llc"}
    assert llc_tensors
    for t in llc_tensors:
        assert s_base.tensor_depths[t] == 1
        assert s_deep.tensor_depths[t] == 3
    slots = {e.slot for e in s_deep.dma_events()
             if isinstance(e, sim.DmaIn) and e.tensor in llc_tensors}
    assert slots == {0, 1, 2} or len(slots) > 1
    r_base = sim.simulate(s_base).runtime_s
    r_deep = sim.simulate(s_deep).runtime_s
    assert r_deep <= r_base * (1 + 1e-9)


# ---------------------------------------------------------------------------
# simulated vs analytic: floor, ceiling, convergence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("target", PRESETS, ids=PRESET_IDS)
@pytest.mark.parametrize("cuts", ["fused", "unfused"])
def test_sim_bounded_by_analytic_floor_and_busy_sum(target, cuts):
    """analytic max() <= simulated <= compute + transfer: the DES adds
    only real serialization, and some resource is always active."""
    chain = _chain(target=target,
                   cuts=() if cuts == "fused" else (1,))
    res = sim.simulate_chain(sim.lower_chain(chain))
    assert res.runtime_s >= chain.modeled_runtime_s * (1 - 1e-9)
    for (sched, _), (r, _) in zip(sim.lower_chain(chain), res.segments):
        # ceiling = every resource fully serialized (engines are summed:
        # compute_time_s is the *max* engine, not the total busy time)
        ceiling = (sum(sched.per_engine_compute_s.values())
                   + sched.transfer_time_s)
        assert r.runtime_s <= ceiling * (1 + 1e-9)
        assert 0.0 < r.overlap_efficiency <= 1.0 + 1e-9


@pytest.mark.parametrize("target", PRESETS, ids=PRESET_IDS)
def test_transfer_bound_pipelined_sim_within_10pct(target):
    """The acceptance pin: wherever a segment is transfer-bound and the
    pipeline is deep enough to matter (depth >= 2, >= 16 steps), the
    replayed timeline lands within 10% of the analytic roofline."""
    checked = 0
    for cuts in [(), "all"]:
        chain = _chain(target=target,
                       cuts=() if cuts == () else (1,))
        for seg, (sched, _) in zip(chain.segments, sim.lower_chain(chain)):
            rep = seg.plan.report
            if (rep.transfer_time_s >= rep.compute_time_s
                    and sched.n_steps >= 16
                    and sched.buffer_depth >= 2):
                r = sim.simulate(sched)
                assert r.sim_over_analytic <= 1.10, sched.name
                checked += 1
    if target.name.startswith("rv32"):
        assert checked          # the paper's platform is transfer-bound


def test_pure_transfer_bound_converges_tightly():
    """Compute ~ 0: the DMA port must stay saturated end to end."""
    t = _flat(512 * KB, flops=1e18)
    chain = _chain(m=2048, k=512, n=2048, target=t)
    sched = sim.lower_plan(chain.segments[0].plan)
    assert sched.n_steps >= 16
    r = sim.simulate(sched)
    assert r.sim_over_analytic == pytest.approx(1.0, abs=2e-2)
    assert r.overlap_efficiency == pytest.approx(1.0, abs=2e-2)


def test_pure_compute_bound_converges_tightly():
    """Transfer ~ 0 (absurd bandwidth): engines must never starve."""
    t = _flat(512 * KB, flops=1e9, bw=1e18)
    chain = _chain(m=2048, k=512, n=2048, target=t)
    sched = sim.lower_plan(chain.segments[0].plan)
    r = sim.simulate(sched)
    assert r.sim_over_analytic == pytest.approx(1.0, abs=2e-2)


# ---------------------------------------------------------------------------
# buffer depth: deeper staging never slows the replay
# ---------------------------------------------------------------------------

DEPTHS = (1, 2, 3, 4)


def _depth_monotone_check(m, k, n, budget, d_lo, d_hi):
    t = _flat(budget)
    try:
        chain = _chain(m=m, k=k, n=n, target=t)
    except InfeasibleError:
        return
    sched = sim.lower_plan(chain.segments[0].plan)
    lo = sim.simulate(sched, buffer_depth=d_lo).runtime_s
    hi = sim.simulate(sched, buffer_depth=d_hi).runtime_s
    assert hi <= lo * (1 + 1e-9)


def test_depth_monotone_ladder():
    for d_lo, d_hi in zip(DEPTHS, DEPTHS[1:]):
        _depth_monotone_check(2048, 512, 2048, 1 * MB, d_lo, d_hi)
        _depth_monotone_check(3072, 768, 3072, 256 * KB, d_lo, d_hi)


try:
    import hypothesis.strategies as st
    from hypothesis import given, settings

    dim = st.sampled_from([256, 512, 1024, 2048])
    budget = st.sampled_from((256 * KB, 1 * MB, 8 * MB))
    depth = st.integers(min_value=1, max_value=5)

    @settings(max_examples=25, deadline=None)
    @given(m=dim, k=dim, n=dim, b=budget, d1=depth, d2=depth)
    def test_depth_monotone_fuzz(m, k, n, b, d1, d2):
        """Adding buffer depth never increases simulated runtime."""
        _depth_monotone_check(m, k, n, b, min(d1, d2), max(d1, d2))
except ImportError:  # pragma: no cover - hypothesis optional locally
    pass


def test_depth1_serializes_load_and_compute():
    """With a single buffer the DMA cannot run ahead: simulated runtime
    approaches compute + transfer; depth 2 strictly beats it whenever
    both terms are non-trivial."""
    t = _flat(1 * MB, flops=2e11)
    chain = _chain(m=2048, k=512, n=2048, target=t)
    sched = sim.lower_plan(chain.segments[0].plan)
    r1 = sim.simulate(sched, buffer_depth=1)
    r2 = sim.simulate(sched, buffer_depth=2)
    assert r2.runtime_s < r1.runtime_s
    assert r1.runtime_s == pytest.approx(
        sched.compute_time_s + sched.transfer_time_s, rel=0.1)


# ---------------------------------------------------------------------------
# the paper's overlap regime: NPU + cluster engines
# ---------------------------------------------------------------------------

class TestNpuOverlap:
    def test_fused_overlaps_engines(self):
        """On rv32_npu the fused schedule's replay must beat the sum of
        its engine busy times (true overlap) and the unfused replay."""
        t = hw.get_target("rv32_npu")
        fused = _chain(target=t)
        unfused = _chain(target=t, cuts=(1,))
        rf = sim.simulate_chain(sim.lower_chain(fused))
        ru = sim.simulate_chain(sim.lower_chain(unfused))
        assert rf.runtime_s < ru.runtime_s
        busy = rf.busy_s
        engine_total = sum(v for k, v in busy.items()
                           if k.startswith("engine:"))
        assert {"engine:npu", "engine:cluster"} <= set(busy)
        assert rf.runtime_s < engine_total + busy["dma"]

    def test_npu_split_beats_single_rate_cluster(self):
        """The same chain replayed on the NPU-split target must beat the
        cluster-only Siracusa preset — the cross-engine pipeline is the
        paper's −60.1% mechanism."""
        r_npu = sim.simulate_chain(sim.lower_chain(
            _chain(target=hw.get_target("rv32_npu"))))
        r_clu = sim.simulate_chain(sim.lower_chain(
            _chain(target=hw.get_target("rv32_l1_l2"))))
        assert r_npu.runtime_s < r_clu.runtime_s

    def test_compute_events_tagged_with_engines(self):
        t = hw.get_target("rv32_npu")
        sched = sim.lower_plan(_chain(target=t).segments[0].plan)
        engines = {e.engine for e in sched.compute_events()}
        assert engines == {"npu", "cluster"}
        # within a step the chain keeps op order: gemm (npu) first
        first = [e for e in sched.compute_events() if e.step == 0]
        assert first[0].engine == "npu" and first[0].seq == 0
        assert first[1].engine == "cluster" and first[1].seq == 1


# ---------------------------------------------------------------------------
# acceptance: every zoo block plan lowers + replays on every preset
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ZOO)
@pytest.mark.parametrize("target", PRESETS, ids=PRESET_IDS)
def test_zoo_block_plans_lower_and_simulate(arch, target):
    cfg = dataclasses.replace(configs.get_config(arch).reduced(),
                              dtype="float32", remat=False, ftl_mode="auto")
    bp = registry.plan_block(cfg, m=32, dtype="float32", target=target)
    lowered = sim.lower_block(bp)
    assert len(lowered) == len(bp.chain.segments)
    res = sim.simulate_chain(lowered)
    # floor on the whole chain...
    assert res.runtime_s >= bp.chain.modeled_runtime_s * (1 - 1e-9)
    for seg, ((sched, rep), (r, _)) in zip(bp.chain.segments,
                                           zip(lowered, res.segments)):
        # ...and per segment: floor, busy-sum ceiling, exact DMA replay
        assert r.runtime_s >= seg.plan.modeled_runtime_s * (1 - 1e-9)
        assert r.runtime_s <= (sum(sched.per_engine_compute_s.values())
                               + sched.transfer_time_s) * (1 + 1e-9)
        assert len(sched.dma_events()) == seg.plan.report.dma_transfers
        # transfer-bound + pipelined segments agree within 10%
        if (seg.plan.report.transfer_time_s
                >= seg.plan.report.compute_time_s
                and sched.n_steps >= 16 and sched.buffer_depth >= 2):
            assert r.sim_over_analytic <= 1.10


# ---------------------------------------------------------------------------
# reporting + bench artifact
# ---------------------------------------------------------------------------

def test_timeline_renders_events():
    t = hw.get_target("rv32_npu")
    sched = sim.lower_plan(_chain(m=512, k=768, n=3072, target=t)
                           .segments[0].plan)
    text = sim.timeline(sched, max_steps=2)
    assert "DmaIn" in text and "DmaOut" in text
    assert "[npu]" in text and "[cluster]" in text
    assert "rv32_npu" in text


def test_compare_plan_rows_are_json_ready():
    row = sim.compare_plan(_chain(m=512, target=hw.TPU_V5E))
    json.dumps(row)          # must serialize as-is
    assert row["sim_runtime_ms"] >= row["analytic_runtime_ms"] * (1 - 1e-9)
    assert row["segments"] and "overlap_efficiency" in row


def test_bench_schedule_writes_wellformed_json(tmp_path, monkeypatch):
    bench = pytest.importorskip("benchmarks.bench_schedule")
    monkeypatch.setenv("BENCH_SMOKE", "1")
    monkeypatch.chdir(tmp_path)
    bench.main()
    data = json.loads((tmp_path / "BENCH_schedule.json").read_text())
    assert data["smoke"] is True
    assert {t["target"] for t in data["targets"]} == set(PRESET_IDS)
    for row in data["targets"]:
        assert row["gate_ok"], row["target"]
        for sched in ("fused", "unfused"):
            r = row["paper_op"][sched]
            assert r["sim_runtime_ms"] > 0
            assert r["sim_over_analytic"] >= 1 - 1e-9
    assert data["zoo_block"]
