"""repro.tune test harness: the tuned-never-worse invariant (tuned
simulated runtime ≤ analytic-best simulated runtime on every preset ×
workload, hypothesis-fuzzed over shapes), determinism (no RNG: same
inputs → the identical chosen plan), the k-best shortlist's exactness
pins (entry 0 == the argmin ``solve``/``plan_chain`` return), the
plan-cache key regression (a tuned plan never aliases the analytic plan
for the same shapes), and the Chrome-trace export."""
import dataclasses
import json

import pytest

from repro import configs, sim
from repro.core import hw
from repro.core.ftl import graph, partition, registry, solver
from repro.tune import AutotuneConfig, autotune_chain, tile_ladder
from repro.tune.autotune import _Search

PRESETS = list(hw.presets())
PRESET_IDS = [t.name for t in PRESETS]

# small shapes + tight budget: each search is a few dozen replays
FAST = AutotuneConfig(top_k_partitions=2, top_k_tiles=2, beam_width=3,
                      max_rounds=2, max_sims=64)


def _paper_op(m=256, k=768, n=3072, dtype="int8"):
    return graph.gemm_act_graph(m=m, k=k, n=n, dtype=dtype)


def _zoo_block(m=32):
    cfg = dataclasses.replace(configs.get_config("llama3.2-3b").reduced(),
                              dtype="float32", remat=False, ftl_mode="auto")
    return cfg, graph.block_graph(cfg, m=m, dtype="float32")


# ---------------------------------------------------------------------------
# analytic shortlist: k-best extensions stay exact at k=1 / entry 0
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("target", PRESETS, ids=PRESET_IDS)
def test_solve_top_k_entry0_is_solve(target):
    g = _paper_op()
    group = g.group(0, g.n_ops)
    best = solver.solve(group, target=target)
    top = solver.solve_top_k(group, target=target, k=3)
    assert 1 <= len(top) <= 3
    assert top[0].tiles == best.tiles
    # ranked: analytically non-decreasing modeled runtime
    times = [hw.round_time(p.modeled_runtime_s) for p in top]
    assert times == sorted(times)
    # distinct assignments
    assert len({tuple(sorted(p.tiles.items())) for p in top}) == len(top)


@pytest.mark.parametrize("target", PRESETS, ids=PRESET_IDS)
def test_plan_chain_top_k_entry0_is_plan_chain(target):
    _, g = _zoo_block()
    best = partition.plan_chain(g, target=target)
    top = partition.plan_chain_top_k(g, target=target, k=3)
    assert top[0].cuts() == best.cuts()
    assert top[0].modeled_runtime_s == best.modeled_runtime_s
    times = [hw.round_time(c.modeled_runtime_s) for c in top]
    assert times == sorted(times)
    assert len({c.cuts() for c in top}) == len(top)


def test_top_k_rejects_bad_k():
    g = _paper_op()
    with pytest.raises(ValueError):
        solver.solve_top_k(g.group(0, g.n_ops), k=0)
    with pytest.raises(ValueError):
        partition.plan_chain_top_k(g, k=0)


def test_tile_ladder_adds_aligned_midpoints():
    g = _paper_op()
    plan = solver.solve(g.group(0, g.n_ops), target=hw.TPU_V5E)
    for d, c in plan.constraints.items():
        ladder = tile_ladder(c)
        assert set(c.candidates) <= set(ladder)
        assert all(x % max(c.alignment, 1) == 0 for x in ladder)
        assert list(ladder) == sorted(ladder)
        if len(c.candidates) == 1:
            assert ladder == c.candidates


# ---------------------------------------------------------------------------
# the invariant: tuned simulated runtime <= analytic-best simulated runtime
# ---------------------------------------------------------------------------

def _check_never_worse(g, target, config=FAST):
    res = autotune_chain(g, target=target, config=config)
    baseline = sim.simulate_chain(
        sim.lower_chain(partition.plan_chain(g, target=target))).runtime_s
    assert baseline == pytest.approx(res.baseline_sim_runtime_s, rel=1e-12)
    assert (hw.round_time(res.sim_runtime_s)
            <= hw.round_time(res.baseline_sim_runtime_s))
    assert res.improved == (hw.round_time(res.sim_runtime_s)
                            < hw.round_time(res.baseline_sim_runtime_s))
    # the winning chain replays to exactly the reported runtime
    replay = sim.simulate_chain(sim.lower_chain(res.chain)).runtime_s
    assert replay == pytest.approx(res.sim_runtime_s, rel=1e-12)
    return res


@pytest.mark.parametrize("target", PRESETS, ids=PRESET_IDS)
def test_tuned_never_worse_paper_op(target):
    _check_never_worse(_paper_op(), target)


@pytest.mark.parametrize("target", PRESETS, ids=PRESET_IDS)
def test_tuned_never_worse_zoo_block(target):
    _, g = _zoo_block()
    _check_never_worse(g, target)


def test_tuner_improves_somewhere():
    """The strict half of the CI gate: across the presets the DES-scored
    search must beat the analytic argmin at least once (fill/drain
    stalls, depth headroom and analytic near-ties guarantee slack)."""
    assert any(_check_never_worse(_paper_op(), t).improved for t in PRESETS)


try:
    import hypothesis.strategies as st
    from hypothesis import given, settings

    dim = st.sampled_from([128, 256, 512])

    @settings(max_examples=8, deadline=None)
    @given(m=dim, k=dim, n=dim)
    def test_tuned_never_worse_fuzz(m, k, n):
        tiny = AutotuneConfig(top_k_partitions=2, top_k_tiles=2,
                              beam_width=2, max_rounds=1, max_sims=24)
        _check_never_worse(_paper_op(m=m, k=k, n=n),
                           hw.get_target("rv32_l1_l2"), config=tiny)
except ImportError:  # pragma: no cover - hypothesis optional locally
    pass


# ---------------------------------------------------------------------------
# determinism: no RNG anywhere — same inputs, same chosen plan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("target", PRESETS, ids=PRESET_IDS)
def test_autotune_is_deterministic(target):
    g = _paper_op()
    # two fresh searches, bypassing the lru cache
    a = _Search(g, target, FAST, None).run()
    b = _Search(g, target, FAST, None).run()
    assert a.sim_runtime_s == b.sim_runtime_s
    assert a.n_scored == b.n_scored
    assert a.chain.target.name == b.chain.target.name
    assert a.chain.cuts() == b.chain.cuts()
    for sa, sb in zip(a.chain.segments, b.chain.segments):
        assert sa.plan.tiles == sb.plan.tiles
        assert sa.plan.report.op_compute == sb.plan.report.op_compute
    # and the cached entry point returns one object for one key
    assert autotune_chain(g, target=target, config=FAST) is \
        autotune_chain(g, target=target, config=FAST)


def test_autotune_config_validates():
    with pytest.raises(ValueError):
        AutotuneConfig(top_k_tiles=0)
    with pytest.raises(ValueError):
        AutotuneConfig(beam_width=0)
    with pytest.raises(ValueError):
        AutotuneConfig(max_sims=0)
    with pytest.raises(ValueError):
        AutotuneConfig(depth_candidates=(0, 2))


def test_tuned_candidates_respect_budget_and_capacity():
    """Every feasible scored candidate fits the (possibly re-depthed)
    fast level, and the replay budget is honored."""
    g = _paper_op()
    s = _Search(g, hw.get_target("cpu_cache"), FAST, None)
    res = s.run()
    assert s.n_scored <= FAST.max_sims
    assert res.n_feasible <= res.n_scored
    for _, runtime, chain in s.scored.values():
        if runtime is None:
            continue
        for seg in chain.segments:
            assert seg.plan.report.vmem_bytes <= chain.target.fast_capacity


# ---------------------------------------------------------------------------
# regression: plan caches key on the autotune config
# ---------------------------------------------------------------------------

def test_model_block_plan_cache_keys_autotune():
    """Mirror of test_model_block_plan_cache_keys_target: requesting a
    DES-tuned plan must never serve the cached analytic plan (or vice
    versa) for the same (cfg, m, dtype, target)."""
    from repro.models import model as M
    cfg, _ = _zoo_block()
    t = hw.TPU_V5E
    plan_plain = M._block_plan(cfg, 32, "float32", target=t)
    assert plan_plain is not None
    assert plan_plain.tune is None
    plan_tuned = M._block_plan(cfg, 32, "float32", target=t, autotune=FAST)
    assert plan_tuned is not None
    assert plan_tuned is not plan_plain
    assert plan_tuned.tune is not None
    assert plan_tuned.tune.config == FAST
    assert (hw.round_time(plan_tuned.tune.sim_runtime_s)
            <= hw.round_time(plan_tuned.tune.baseline_sim_runtime_s))
    # a different tuning config is a different key too
    other = dataclasses.replace(FAST, max_sims=32)
    plan_other = M._block_plan(cfg, 32, "float32", target=t, autotune=other)
    assert plan_other is not plan_tuned
    # and the untuned entry is still served untouched
    assert M._block_plan(cfg, 32, "float32", target=t) is plan_plain


def test_registry_plan_block_binds_tuned_chain():
    """plan_block(autotune=...) must bind executors against the tuned
    chain's (possibly depth-modified) target, not the request's."""
    cfg, _ = _zoo_block()
    bp = registry.plan_block(cfg, m=32, dtype="float32", target=hw.TPU_V5E,
                             autotune=FAST)
    assert bp.tune is not None
    assert bp.chain is bp.tune.chain
    assert bp.target == bp.chain.target
    assert len(bp.bindings) == len(bp.chain.segments)


# ---------------------------------------------------------------------------
# Chrome-trace export
# ---------------------------------------------------------------------------

def test_chrome_trace_structure(tmp_path):
    g = _paper_op()
    chain = partition.plan_chain(g, target=hw.get_target("rv32_npu"))
    trace = sim.to_chrome_trace(chain)
    json.dumps(trace)                       # serializable as-is
    evs = trace["traceEvents"]
    names = {e["args"]["name"] for e in evs
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert "dma" in names
    assert {"engine:npu", "engine:cluster"} <= names
    xs = [e for e in evs if e["ph"] == "X"]
    assert xs
    for e in xs:
        assert e["ts"] >= 0 and e["dur"] >= 0
        assert e["cat"] in ("dma", "engine")
    # one complete-event per schedule event, laid out per track
    lowered = sim.lower_chain(chain)
    assert len(xs) == sum(len(s.events) for s, _ in lowered)
    # round-trips through the file writer
    out = tmp_path / "trace.json"
    sim.write_chrome_trace(chain, out)
    assert json.loads(out.read_text())["traceEvents"]


def test_bench_autotune_writes_wellformed_json(tmp_path, monkeypatch):
    bench = pytest.importorskip("benchmarks.bench_autotune")
    monkeypatch.setenv("BENCH_SMOKE", "1")
    monkeypatch.chdir(tmp_path)
    bench.main()
    data = json.loads((tmp_path / "BENCH_autotune.json").read_text())
    assert data["smoke"] is True
    assert {t["target"] for t in data["targets"]} == set(PRESET_IDS)
    rows = [t["paper_op"] for t in data["targets"]] + data["zoo_block"]
    for r in rows:
        assert r["gate_tuned_ok"]
        assert r["tuned_sim_ms"] > 0
    assert any(r["improved"] for r in rows)
