"""Measurement records + wall-clock harness for target calibration.

Every planning decision in the stack is priced by :class:`~repro.core.hw.
Target` constants that were, until now, hand-typed presets.  This module
measures what this host actually does — isolated GEMM / elementwise
microbenchmarks, DMA-proxy copies at several working-set sizes, and
whole-block ref-vs-plan wall-clock in the ``bench_block`` style — and
records each run as a :class:`Measurement`: the observed seconds next to
the *model features* the roofline prices it with (per-level bytes and
transfer counts, per-kind FLOPs).

A measurement is deliberately self-contained: :func:`modeled_measurement_s`
re-prices it on any :class:`Target` through the repo's one shared formula
(``Target.compute_time_by_kind`` / ``Target.transfer_time`` composed by
``hw.modeled_runtime``), so the fitter (:mod:`repro.calib.fit`) and the
drift gate never restate the cost model.

Feature attribution uses the *base* target's level structure
(``Target.assign_homes`` over the same footprints the cost model would
see).  Calibration never changes capacities or level names — only
bandwidth / DMA-setup / FLOP-rate constants — so features extracted
against the base stay valid for the calibrated target.

Timing discipline: one untimed compile call, then ``warmup`` timed-path
iterations (plan-cache and dispatch cost must not land in the first
sample — the bench_block skew this PR also fixes), then ``min`` over
``repeats``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterable, Mapping, Sequence

from repro.core import hw as hwlib

# branch hints: which side of the roofline max() a microbenchmark was
# designed to isolate.  The fitter only fits hinted single-segment
# measurements; unhinted ones (whole blocks) are validation-only.
COMPUTE = "compute"
TRANSFER = "transfer"

DEFAULT_REPEATS = 5
DEFAULT_WARMUP = 1


def _freeze(d: Mapping) -> tuple:
    return tuple(sorted(d.items()))


@dataclasses.dataclass(frozen=True)
class SegmentFeatures:
    """Roofline features of one sequential segment of a measured run.

    Mirrors exactly what :meth:`Target.transfer_time` and
    :meth:`Target.compute_time_by_kind` consume, so re-pricing a
    measurement on a candidate target is a pure lookup — no re-planning,
    no shape knowledge."""

    flops_by_kind: tuple[tuple[str, float], ...] = ()
    bytes_by_level: tuple[tuple[str, int], ...] = ()
    transfers_by_level: tuple[tuple[str, int], ...] = ()
    repeat: int = 1

    def compute_s(self, target: hwlib.Target) -> float:
        return target.compute_time_by_kind(dict(self.flops_by_kind))

    def transfer_s(self, target: hwlib.Target) -> float:
        return target.transfer_time(dict(self.bytes_by_level),
                                    dict(self.transfers_by_level))

    def modeled_s(self, target: hwlib.Target) -> float:
        """``hw.modeled_runtime`` of this segment — the one shared
        overlap rule, times the segment's multiplicity."""
        return self.repeat * hwlib.modeled_runtime(
            self.compute_s(target), self.transfer_s(target))


@dataclasses.dataclass(frozen=True)
class Measurement:
    """One wall-clock observation plus the features that model it.

    ``branch`` is the microbenchmark's design hint (:data:`COMPUTE` /
    :data:`TRANSFER`): which side of the roofline ``max`` the run was
    built to isolate, hence which linear subsystem of the fit its row
    belongs to.  ``None`` (whole-block measurements) means the
    measurement only validates the fit — mixed segments cannot be
    attributed to a single branch."""

    name: str
    kind: str                    # gemm | elementwise | dma | block
    measured_s: float
    segments: tuple[SegmentFeatures, ...]
    branch: str | None = None
    meta: tuple[tuple[str, float | int | str], ...] = ()

    def __post_init__(self):
        if self.measured_s <= 0:
            raise ValueError(
                f"measurement {self.name}: measured_s must be positive, "
                f"got {self.measured_s}")
        if self.branch not in (None, COMPUTE, TRANSFER):
            raise ValueError(
                f"measurement {self.name}: unknown branch {self.branch!r}")
        if not self.segments:
            raise ValueError(f"measurement {self.name}: no segments")


def modeled_measurement_s(target: hwlib.Target, m: Measurement) -> float:
    """Modeled seconds of ``m`` on ``target``: segments run sequentially,
    each overlapping its own DMA — ``Σ_seg max(compute, transfer)``,
    the same objective ``ChainPlan.modeled_runtime_s`` sums."""
    return sum(seg.modeled_s(target) for seg in m.segments)


# ---------------------------------------------------------------------------
# feature extraction
# ---------------------------------------------------------------------------

def measurement_from_chain(name: str, chain, measured_s: float, *,
                           kind: str = "block",
                           meta: tuple = ()) -> Measurement:
    """Wrap a live wall-clock observation of a planned chain (or
    ``BlockPlan``) as a validation :class:`Measurement` — the record
    the online drift monitor (:mod:`repro.obs.drift`) feeds from."""
    return Measurement(name=name, kind=kind, measured_s=measured_s,
                       segments=features_from_chain(chain), meta=meta)


def features_from_chain(chain) -> tuple[SegmentFeatures, ...]:
    """Per-segment roofline features of a planned chain (``ChainPlan`` or
    a ``BlockPlan`` via ``.chain``) — what a whole-block wall-clock
    measurement is modeled with."""
    chain = getattr(chain, "chain", chain)
    feats = []
    for seg in chain.segments:
        rep = seg.repeat
        flops: dict[str, float] = {}
        for oc in seg.plan.report.op_compute:
            # effective FLOPs: rate-discount by MXU lane utilization the
            # same way compute_costs prices the op
            flops[oc.kind] = flops.get(oc.kind, 0.0) \
                + oc.flops / oc.utilization
        feats.append(SegmentFeatures(
            flops_by_kind=_freeze(flops),
            bytes_by_level=_freeze(seg.plan.report.per_level_traffic),
            transfers_by_level=_freeze(seg.plan.report.per_level_transfers),
            repeat=rep,
        ))
    return tuple(feats)


def _streamed_features(
    base: hwlib.Target,
    footprints: Mapping[str, int],
    flops_by_kind: Mapping[str, float],
) -> SegmentFeatures:
    """Single-block features: every tensor moved exactly once between its
    home backing level and the fast memory (the min-traffic bound), homes
    assigned by the *base* structure exactly as the cost model would."""
    homes = base.assign_homes(dict(footprints))
    by_level: dict[str, int] = {}
    n_level: dict[str, int] = {}
    for name, b in footprints.items():
        lv = homes[name].name
        by_level[lv] = by_level.get(lv, 0) + int(b)
        n_level[lv] = n_level.get(lv, 0) + 1
    return SegmentFeatures(
        flops_by_kind=_freeze(dict(flops_by_kind)),
        bytes_by_level=_freeze(by_level),
        transfers_by_level=_freeze(n_level),
    )


# ---------------------------------------------------------------------------
# wall-clock harness
# ---------------------------------------------------------------------------

def wallclock_s(fn: Callable, *args, repeats: int = DEFAULT_REPEATS,
                warmup: int = DEFAULT_WARMUP) -> float:
    """``min`` wall-clock seconds of ``fn(*args)`` over ``repeats`` timed
    iterations, after one untimed compile call plus ``warmup`` timed-path
    iterations (dispatch/plan-cache cost stays out of the samples)."""
    out = fn(*args)
    _block(out)
    for _ in range(warmup):
        _block(fn(*args))
    best = float("inf")
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        _block(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def _block(out):
    if hasattr(out, "block_until_ready"):
        out.block_until_ready()
    elif isinstance(out, (tuple, list)):
        for o in out:
            _block(o)
    return out


# ---------------------------------------------------------------------------
# microbenchmarks
# ---------------------------------------------------------------------------

def measure_gemms(
    shapes: Iterable[tuple[int, int, int]],
    *,
    base: hwlib.Target | None = None,
    repeats: int = DEFAULT_REPEATS,
    warmup: int = DEFAULT_WARMUP,
) -> list[Measurement]:
    """Isolated f32 GEMMs at several (m, k, n): the compute-branch rows
    that pin the effective ``gemm`` FLOP/s of this host."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import ref

    base = base if base is not None else hwlib.default_target()
    fn = jax.jit(ref.gemm)
    out = []
    for m, k, n in shapes:
        key = jax.random.PRNGKey(m + k + n)
        x = jax.random.normal(key, (m, k), jnp.float32)
        w = jax.random.normal(key, (k, n), jnp.float32)
        secs = wallclock_s(fn, x, w, repeats=repeats, warmup=warmup)
        feats = _streamed_features(
            base,
            {"x": 4 * m * k, "w": 4 * k * n, "y": 4 * m * n},
            {"gemm": 2.0 * m * k * n},
        )
        out.append(Measurement(
            name=f"gemm_m{m}_k{k}_n{n}", kind="gemm", measured_s=secs,
            segments=(feats,), branch=COMPUTE,
            meta=(("m", m), ("k", k), ("n", n)),
        ))
    return out


def measure_elementwise(
    sizes: Iterable[int],
    *,
    act: str = "gelu",
    base: hwlib.Target | None = None,
    repeats: int = DEFAULT_REPEATS,
    warmup: int = DEFAULT_WARMUP,
) -> list[Measurement]:
    """Isolated activation sweeps: the rows that pin the effective
    ``elementwise`` rate (the planner prices an elementwise op at one
    FLOP per output element — ``flops_per_macs=1`` — so the fitted rate
    absorbs the real per-element cost of the activation)."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import ref

    base = base if base is not None else hwlib.default_target()
    fn = jax.jit(ref.act_fn(act))
    out = []
    for n in sizes:
        x = jax.random.normal(jax.random.PRNGKey(n % (1 << 30)),
                              (n,), jnp.float32)
        secs = wallclock_s(fn, x, repeats=repeats, warmup=warmup)
        feats = _streamed_features(
            base, {"x": 4 * n, "y": 4 * n}, {"elementwise": float(n)})
        out.append(Measurement(
            name=f"{act}_n{n}", kind="elementwise", measured_s=secs,
            segments=(feats,), branch=COMPUTE, meta=(("n", n),),
        ))
    return out


def measure_dma_proxy(
    sizes_bytes: Iterable[int],
    *,
    base: hwlib.Target | None = None,
    repeats: int = DEFAULT_REPEATS,
    warmup: int = DEFAULT_WARMUP,
) -> list[Measurement]:
    """Copy-through sweeps at several working-set sizes: the
    transfer-branch rows that pin effective per-level bandwidth and DMA
    setup.  Each run reads + writes its buffer once (``x + 1``: the
    cheapest op XLA will not elide); sizes straddling the base target's
    level capacities land on different home levels via the same
    first-fit the cost model uses, which is what makes per-level
    constants identifiable from one host."""
    import jax
    import jax.numpy as jnp

    base = base if base is not None else hwlib.default_target()
    fn = jax.jit(lambda x: x + jnp.float32(1.0))
    out = []
    for b in sizes_bytes:
        n = max(1, int(b) // 4)
        x = jax.random.normal(jax.random.PRNGKey(n % (1 << 30)),
                              (n,), jnp.float32)
        secs = wallclock_s(fn, x, repeats=repeats, warmup=warmup)
        feats = _streamed_features(
            base, {"src": 4 * n, "dst": 4 * n}, {"elementwise": float(n)})
        out.append(Measurement(
            name=f"dma_{4 * n}B", kind="dma", measured_s=secs,
            segments=(feats,), branch=TRANSFER, meta=(("bytes", 4 * n),),
        ))
    return out


def microbench_sweep(
    *,
    base: hwlib.Target | None = None,
    gemm_shapes: Sequence[tuple[int, int, int]] = (
        (256, 256, 256), (512, 512, 512), (1024, 512, 1024),
    ),
    elementwise_sizes: Sequence[int] = (1 << 20, 1 << 22, 1 << 23),
    dma_sizes: Sequence[int] = (1 << 21, 1 << 23, 1 << 25, 1 << 26),
    repeats: int = DEFAULT_REPEATS,
    warmup: int = DEFAULT_WARMUP,
) -> list[Measurement]:
    """The standard isolated-microbenchmark sweep the fitter consumes:
    GEMMs + activations (compute branch) and copy-throughs at sizes
    straddling the backing-level capacities (transfer branch)."""
    base = base if base is not None else hwlib.default_target()
    ms = measure_gemms(gemm_shapes, base=base, repeats=repeats,
                       warmup=warmup)
    ms += measure_elementwise(elementwise_sizes, base=base,
                              repeats=repeats, warmup=warmup)
    ms += measure_dma_proxy(dma_sizes, base=base, repeats=repeats,
                            warmup=warmup)
    return ms


# ---------------------------------------------------------------------------
# whole-block validation measurements (bench_block-style ref vs plan)
# ---------------------------------------------------------------------------

def measure_block(
    arch: str,
    m: int,
    *,
    base: hwlib.Target | None = None,
    repeats: int = DEFAULT_REPEATS,
    warmup: int = DEFAULT_WARMUP,
) -> list[Measurement]:
    """Whole-transformer-block wall-clock, reference (all-unfused
    features) and plan-driven (planned-chain features) — the held-out
    measurements the drift gate validates the fitted constants against.
    Mirrors ``benchmarks/bench_block.exec_rows`` at reduced config."""
    import dataclasses as _dc

    import jax
    import jax.numpy as jnp

    from repro import configs
    from repro.core.ftl import partition, registry
    from repro.models import layers

    base = base if base is not None else hwlib.default_target()
    cfg = configs.get_config(arch).reduced()
    cfg = _dc.replace(cfg, dtype="float32", remat=False)
    cfg_auto = _dc.replace(cfg, ftl_mode="auto")
    cfg_off = _dc.replace(cfg, ftl_mode="off")
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 2)
    dt = jnp.dtype(cfg.dtype)
    params = {
        "ln1": layers.init_norm(cfg.d_model, cfg.norm, dt),
        "attn": layers.init_attention(cfg, ks[0]),
        "ln2": layers.init_norm(cfg.d_model, cfg.norm, dt),
        "mlp": layers.init_mlp(cfg, ks[1]),
    }
    plan = registry.plan_block(cfg_auto, m=m, dtype="float32", target=base)
    positions = jnp.arange(m)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, m, cfg.d_model),
                          jnp.float32)

    def plan_fn(xx):
        return registry.run_block(plan, params, xx, positions=positions)

    def ref_fn(xx):
        return layers.block_layer(cfg_off, params, xx, positions=positions)

    plan_s = wallclock_s(jax.jit(plan_fn), x, repeats=repeats,
                         warmup=warmup)
    ref_s = wallclock_s(jax.jit(ref_fn), x, repeats=repeats,
                        warmup=warmup)
    unfused = partition.plan_fixed(plan.graph,
                                   partition.all_cuts(plan.graph),
                                   target=base)
    return [
        Measurement(
            name=f"block_{arch}_m{m}_plan", kind="block",
            measured_s=plan_s, segments=features_from_chain(plan),
            meta=(("arch", arch), ("m", m), ("schedule", plan.schedule)),
        ),
        Measurement(
            name=f"block_{arch}_m{m}_ref", kind="block",
            measured_s=ref_s, segments=features_from_chain(unfused),
            meta=(("arch", arch), ("m", m), ("schedule", "unfused")),
        ),
    ]


__all__ = [
    "COMPUTE", "TRANSFER", "SegmentFeatures", "Measurement",
    "modeled_measurement_s", "features_from_chain", "wallclock_s",
    "measure_gemms", "measure_elementwise", "measure_dma_proxy",
    "microbench_sweep", "measure_block",
]
