"""Fit effective Target constants from measurements (NNLS roofline fit).

The roofline model the whole planning stack prices with is piecewise
linear in the *reciprocal* hardware constants:

    transfer = max_port Σ_level  bytes·(1/bw) + transfers·dma_setup
    compute  = max_engine Σ_kind flops·(1/rate)
    runtime  = max(compute, transfer)            (hw.modeled_runtime)

Each isolated microbenchmark (:mod:`repro.calib.measure`) is designed to
sit on one branch of each ``max`` (its ``branch`` hint), so its row is a
plain linear equation in the unknowns ``1/bw``, ``dma_setup`` and
``1/rate`` — all physically non-negative.  :func:`calibrate` stacks the
rows (weighted by ``1/measured`` so the fit minimizes *relative* error,
the quantity the drift gate means by "ratio") and solves each branch by
non-negative least squares (:func:`nnls`, Lawson–Hanson), re-resolving
the busiest-engine / busiest-port assignment between passes for targets
where those inner maxima matter.

The result is a preset-shaped :class:`~repro.core.hw.Target` — same
level names, capacities, ports, buffer depths and engine structure as
the base; only the bandwidth / setup / rate constants move (an
engine-less base grows a single ``core`` engine carrying the fitted
per-kind rates).  Constants no measurement touched are inherited from
the base and reported as such.  Residuals (modeled vs measured, base and
calibrated side by side) are computed for *every* measurement, including
the unhinted whole-block ones the fit never saw — those are the
validation set :func:`drift_gate` checks.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from repro.core import hw as hwlib

from .measure import COMPUTE, TRANSFER, Measurement, modeled_measurement_s

_TINY = 1e-30


def nnls(A, b, max_iter: int | None = None) -> np.ndarray:
    """Solve ``min ||Ax - b||`` s.t. ``x >= 0`` (Lawson–Hanson active
    set).  Small dense systems only — the calibration fit has a handful
    of unknowns."""
    A = np.asarray(A, dtype=float)
    b = np.asarray(b, dtype=float)
    m, n = A.shape
    x = np.zeros(n)
    passive = np.zeros(n, dtype=bool)
    w = A.T @ (b - A @ x)
    tol = 1e-10 * max(1.0, float(np.abs(A).max(initial=0.0)))
    max_iter = max_iter if max_iter is not None else 3 * max(n, 1)
    it = 0
    while (~passive).any() and it < max_iter:
        masked = np.where(~passive, w, -np.inf)
        j = int(np.argmax(masked))
        if masked[j] <= tol:
            break
        passive[j] = True
        while True:
            s = np.zeros(n)
            s[passive] = np.linalg.lstsq(A[:, passive], b, rcond=None)[0]
            neg = passive & (s <= 0.0)
            if not neg.any():
                break
            with np.errstate(divide="ignore", invalid="ignore"):
                steps = x[neg] / (x[neg] - s[neg])
            alpha = float(np.min(steps))
            x = x + alpha * (s - x)
            passive = passive & (x > tol)
        x = s
        w = A.T @ (b - A @ x)
        it += 1
    return np.clip(x, 0.0, None)


# ---------------------------------------------------------------------------
# results
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Residual:
    """Modeled-vs-measured of one measurement, base and calibrated."""

    name: str
    kind: str
    measured_s: float
    base_modeled_s: float
    calibrated_modeled_s: float
    in_fit: bool

    @property
    def base_ratio(self) -> float:
        return self.base_modeled_s / self.measured_s

    @property
    def calibrated_ratio(self) -> float:
        return self.calibrated_modeled_s / self.measured_s

    @property
    def base_log_residual(self) -> float:
        return abs(math.log(max(self.base_ratio, _TINY)))

    @property
    def calibrated_log_residual(self) -> float:
        return abs(math.log(max(self.calibrated_ratio, _TINY)))


def _geomean(vals: Sequence[float]) -> float:
    vals = [max(v, _TINY) for v in vals]
    if not vals:
        return 1.0
    return math.exp(sum(math.log(v) for v in vals) / len(vals))


@dataclasses.dataclass(frozen=True)
class CalibrationResult:
    """The fitted target plus everything needed to judge the fit."""

    base: hwlib.Target
    target: hwlib.Target
    fitted: tuple[tuple[str, float], ...]      # constant name -> value
    inherited: tuple[str, ...]                 # kept from the base
    residuals: tuple[Residual, ...]
    n_iter: int

    # -- aggregate quality -------------------------------------------------
    @property
    def geomean_ratio(self) -> float:
        """Geometric-mean modeled/measured on the calibrated target —
        the drift-gate statistic (1.0 = unbiased model)."""
        return _geomean([r.calibrated_ratio for r in self.residuals])

    @property
    def base_geomean_ratio(self) -> float:
        return _geomean([r.base_ratio for r in self.residuals])

    @property
    def mean_abs_log_residual(self) -> float:
        """Mean |ln(modeled/measured)| on the calibrated target — the
        spread statistic 'residuals shrink' refers to."""
        rs = self.residuals
        return sum(r.calibrated_log_residual for r in rs) / max(1, len(rs))

    @property
    def base_mean_abs_log_residual(self) -> float:
        rs = self.residuals
        return sum(r.base_log_residual for r in rs) / max(1, len(rs))

    def residuals_of(self, kind: str) -> tuple[Residual, ...]:
        return tuple(r for r in self.residuals if r.kind == kind)

    def summary(self) -> str:
        lines = [
            f"calibrated '{self.base.name}' -> '{self.target.name}' "
            f"({self.n_iter} pass(es), {len(self.residuals)} measurements)",
            f"  geomean modeled/measured: {self.base_geomean_ratio:.3f} "
            f"(base) -> {self.geomean_ratio:.3f} (calibrated)",
            f"  mean |log residual|:      "
            f"{self.base_mean_abs_log_residual:.3f} (base) -> "
            f"{self.mean_abs_log_residual:.3f} (calibrated)",
            "  fitted constants:",
        ]
        for name, val in self.fitted:
            lines.append(f"    {name:<28} {val:.4g}")
        if self.inherited:
            lines.append(f"  inherited from base: "
                         f"{', '.join(self.inherited)}")
        per = {}
        for r in self.residuals:
            per.setdefault(r.kind, []).append(r.calibrated_ratio)
        for kind, ratios in sorted(per.items()):
            lines.append(f"  {kind:<12} geomean ratio "
                         f"{_geomean(ratios):.3f}  (n={len(ratios)})")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# the fit
# ---------------------------------------------------------------------------

def _engine_of(target: hwlib.Target, kind: str) -> str:
    return target.engine_rate(kind)[0]


def _busiest_engine(target: hwlib.Target, flops: dict[str, float]) -> str:
    times = target.engine_times(flops)
    return max(times, key=lambda e: times[e])


def _busiest_port(target: hwlib.Target, by_level: dict[str, int],
                  n_level: dict[str, int]) -> str:
    per_port = target.transfer_time_by_port(by_level, n_level)
    return max(per_port, key=lambda p: per_port[p])


def _fit_rows(measurements: Sequence[Measurement]):
    """Fit inputs: hinted, single-segment measurements only.  Multi-
    segment (whole-block) measurements mix compute- and transfer-bound
    segments, so they validate the fit instead of entering it."""
    return [m for m in measurements
            if m.branch is not None and len(m.segments) == 1]


def _solve_branch(rows: list[tuple[dict, float]], keys: list):
    """Weighted NNLS of ``Σ_k feat[k]·x[k] ≈ measured`` over ``rows``.
    Rows are weighted ``1/measured`` (relative error — the drift gate's
    ratio statistic); columns are rescaled to unit peak for
    conditioning.  Returns ``{key: value}`` for keys any row touched."""
    touched = [k for k in keys
               if any(feat.get(k, 0.0) > 0.0 for feat, _ in rows)]
    if not touched:
        return {}
    A = np.array([[feat.get(k, 0.0) / meas for k in touched]
                  for feat, meas in rows])
    b = np.ones(len(rows))
    scale = np.maximum(np.abs(A).max(axis=0), _TINY)
    x = nnls(A / scale, b) / scale
    return dict(zip(touched, x))


def calibrate(
    measurements: Sequence[Measurement],
    base: hwlib.Target | None = None,
    *,
    max_iter: int = 4,
) -> CalibrationResult:
    """Fit effective per-level bandwidth/dma_setup and per-engine-kind
    FLOP/s from ``measurements`` and emit a preset-shaped calibrated
    target (see module docstring).  ``base`` defaults to the process
    default target; its structure (levels, capacities, ports, engines)
    is preserved — only constants move."""
    base = base if base is not None else hwlib.default_target()
    fit_set = _fit_rows(measurements)
    if not fit_set:
        raise ValueError(
            "calibrate() needs at least one single-segment measurement "
            "with a branch hint (see repro.calib.measure.microbench_sweep)")

    backing = {lv.name for lv in base.backing}
    cur = base
    rates: dict[tuple[str, str], float] = {}
    bw: dict[str, float] = {}
    setup: dict[str, float] = {}
    n_iter = 0
    for n_iter in range(1, max_iter + 1):
        # --- build branch rows under the current assignment ---------------
        c_rows: list[tuple[dict, float]] = []
        t_rows: list[tuple[dict, float]] = []
        for m in fit_set:
            seg = m.segments[0]
            if m.branch == COMPUTE:
                flops = dict(seg.flops_by_kind)
                eng = _busiest_engine(cur, flops)
                feat = {("rate", eng, k): f for k, f in flops.items()
                        if _engine_of(cur, k) == eng and f > 0.0}
                if feat:
                    c_rows.append((feat, m.measured_s / seg.repeat))
            else:
                by_level = {lv: b for lv, b in seg.bytes_by_level
                            if lv in backing}
                n_level = {lv: n for lv, n in seg.transfers_by_level
                           if lv in backing}
                if not by_level and not n_level:
                    continue
                port = _busiest_port(cur, by_level, n_level)
                on_port = {lv.name for lv in base.backing
                           if lv.dma_port == port}
                feat: dict = {}
                for lv, b in by_level.items():
                    if lv in on_port and b > 0:
                        feat[("bw", lv)] = float(b)
                for lv, n in n_level.items():
                    if lv in on_port and n > 0:
                        feat[("setup", lv)] = float(n)
                if feat:
                    t_rows.append((feat, m.measured_s / seg.repeat))

        rate_keys = sorted({k for feat, _ in c_rows for k in feat})
        lvl_keys = sorted({k for feat, _ in t_rows for k in feat})
        inv_rates = _solve_branch(c_rows, rate_keys)
        lvl_consts = _solve_branch(t_rows, lvl_keys)

        new_rates = {(e, k): float(1.0 / v)
                     for (_, e, k), v in inv_rates.items() if v > _TINY}
        new_bw = {lv: float(1.0 / v)
                  for (tag, lv), v in lvl_consts.items()
                  if tag == "bw" and v > _TINY}
        new_setup = {lv: float(v) for (tag, lv), v in lvl_consts.items()
                     if tag == "setup"}
        nxt = _build_target(base, new_rates, new_bw, new_setup)
        converged = (new_rates.keys() == rates.keys()
                     and new_bw.keys() == bw.keys()
                     and all(_close(new_rates[k], rates[k])
                             for k in new_rates)
                     and all(_close(new_bw[k], bw[k]) for k in new_bw)
                     and all(_close(new_setup.get(k, 0.0),
                                    setup.get(k, 0.0), absolute=1e-12)
                             for k in new_setup))
        rates, bw, setup, cur = new_rates, new_bw, new_setup, nxt
        if converged:
            break

    fitted = tuple(sorted(
        [(f"rate:{e}:{k}", v) for (e, k), v in rates.items()]
        + [(f"bw:{lv}", v) for lv, v in bw.items()]
        + [(f"dma_setup:{lv}", v) for lv, v in setup.items()]
    ))
    fitted_names = {n for n, _ in fitted}
    inherited = tuple(sorted(
        [f"bw:{lv.name}" for lv in base.backing
         if f"bw:{lv.name}" not in fitted_names]
        + [f"dma_setup:{lv.name}" for lv in base.backing
           if f"dma_setup:{lv.name}" not in fitted_names]
    ))
    fit_names = {m.name for m in fit_set}
    residuals = tuple(
        Residual(
            name=m.name, kind=m.kind, measured_s=m.measured_s,
            base_modeled_s=modeled_measurement_s(base, m),
            calibrated_modeled_s=modeled_measurement_s(cur, m),
            in_fit=m.name in fit_names,
        )
        for m in measurements
    )
    return CalibrationResult(base=base, target=cur, fitted=fitted,
                             inherited=inherited, residuals=residuals,
                             n_iter=n_iter)


def _close(a: float, b: float, rel: float = 1e-6,
           absolute: float = 0.0) -> bool:
    return abs(a - b) <= max(absolute, rel * max(abs(a), abs(b)))


def _build_target(
    base: hwlib.Target,
    rates: dict[tuple[str, str], float],
    bw: dict[str, float],
    setup: dict[str, float],
) -> hwlib.Target:
    """The calibrated target: base structure, fitted constants.

    Levels keep name/capacity/port/depth; fitted levels get new
    bandwidth and DMA setup.  An engine-carrying base keeps its engines
    with fitted exact-kind rates grafted in; an engine-less base grows a
    single ``core`` engine with the fitted per-kind rates (plus a
    conservative ``'*'`` catch-all), which is strictly more expressive
    than the old single-rate model and exactly how the fit priced it.
    """
    levels = []
    for lv in base.levels:
        if lv.name in bw or lv.name in setup:
            levels.append(dataclasses.replace(
                lv,
                bw_bytes_per_s=bw.get(lv.name, lv.bw_bytes_per_s),
                dma_setup_s=setup.get(lv.name, lv.dma_setup_s),
            ))
        else:
            levels.append(lv)

    flops = base.flops
    if base.engines:
        engines = []
        for e in base.engines:
            mine = {k: r for (en, k), r in rates.items() if en == e.name}
            if mine:
                kept = tuple((k, r) for k, r in e.rates if k not in mine)
                engines.append(hwlib.Engine(
                    e.name, kept + tuple(sorted(mine.items()))))
            else:
                engines.append(e)
        engines = tuple(engines)
    elif rates:
        by_kind = dict(sorted(
            (k, r) for (_, k), r in rates.items()))
        fallback = min(by_kind.values())
        engines = (hwlib.Engine(
            "core", tuple(by_kind.items()) + (("*", fallback),)),)
    else:
        engines = base.engines
    gemm_route = next((r for (_, k), r in rates.items() if k == "gemm"),
                      None)
    if gemm_route is not None:
        flops = gemm_route
    name = base.name.split("@calib")[0] + "@calib"
    return dataclasses.replace(base, name=name, levels=tuple(levels),
                               flops=flops, engines=engines)


# ---------------------------------------------------------------------------
# CI drift gate
# ---------------------------------------------------------------------------

def drift_gate(
    result: CalibrationResult,
    *,
    band: tuple[float, float] = (0.3, 10 / 3),
    require_tighter: bool = True,
) -> dict:
    """The CI modeled-vs-measured gate: on the *calibrated* target the
    geometric-mean modeled/measured ratio must sit inside ``band``, and
    (``require_tighter``) the calibrated residual spread must be
    strictly tighter than the uncalibrated base's.  Returns a JSON-ready
    verdict; callers raise on ``ok == False``."""
    g = result.geomean_ratio
    in_band = band[0] <= g <= band[1]
    tighter = (result.mean_abs_log_residual
               < result.base_mean_abs_log_residual)
    ok = in_band and (tighter or not require_tighter)
    return {
        "ok": bool(ok),
        "band": list(band),
        "geomean_ratio": g,
        "in_band": bool(in_band),
        "base_geomean_ratio": result.base_geomean_ratio,
        "mean_abs_log_residual": result.mean_abs_log_residual,
        "base_mean_abs_log_residual": result.base_mean_abs_log_residual,
        "residual_tighter_than_base": bool(tighter),
        "n_measurements": len(result.residuals),
        "n_fit": sum(1 for r in result.residuals if r.in_fit),
    }


__all__ = ["nnls", "Residual", "CalibrationResult", "calibrate",
           "drift_gate"]
