"""Measured calibration of :class:`~repro.core.hw.Target` constants.

The loop the ROADMAP asks for: measure what this host actually does
(:mod:`repro.calib.measure` — isolated GEMM / elementwise / DMA-proxy
microbenchmarks plus bench_block-style whole-block wall-clock), fit
effective per-level bandwidth / DMA setup and per-engine FLOP/s by
non-negative least squares over the shared roofline model
(:mod:`repro.calib.fit`), and emit a preset-shaped calibrated target —
``Target.calibrated(measurements, base=...)`` — with per-measurement
residuals and a CI drift gate (:func:`drift_gate`).

Typical use::

    from repro.calib import microbench_sweep, measure_block, calibrate

    ms = microbench_sweep() + measure_block("llama3.2-3b", m=256)
    result = calibrate(ms)          # or hw.Target.calibrated(ms)
    print(result.summary())
    target = result.target          # plan with the calibrated machine
"""
from .fit import (CalibrationResult, Residual, calibrate, drift_gate,
                  nnls)
from .measure import (COMPUTE, TRANSFER, Measurement, SegmentFeatures,
                      features_from_chain, measure_block,
                      measure_dma_proxy, measure_elementwise,
                      measure_gemms, measurement_from_chain,
                      microbench_sweep, modeled_measurement_s,
                      wallclock_s)

__all__ = [
    "COMPUTE", "TRANSFER", "Measurement", "SegmentFeatures",
    "modeled_measurement_s", "features_from_chain",
    "measurement_from_chain", "wallclock_s",
    "measure_gemms", "measure_elementwise", "measure_dma_proxy",
    "microbench_sweep", "measure_block",
    "nnls", "Residual", "CalibrationResult", "calibrate", "drift_gate",
]
