"""Roofline analysis from compiled dry-run artifacts."""
from .analysis import (
    HW,
    CollectiveStats,
    RooflineReport,
    collective_bytes,
    model_flops,
    roofline,
)

__all__ = ["HW", "CollectiveStats", "RooflineReport", "collective_bytes",
           "model_flops", "roofline"]
