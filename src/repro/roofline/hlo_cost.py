"""Trip-count-aware cost model over compiled HLO text.

Why this exists: ``compiled.cost_analysis()`` visits every computation
ONCE — ``while`` bodies (every ``lax.scan``: layers, grad-accum, remat,
time chunks) are not multiplied by their trip counts, so a scanned
transformer under-reports FLOPs by ~n_layers × accum, and collectives
inside scanned bodies are invisible to a flat text scan.  The compiled
CPU/TPU HLO carries ``backend_config={"known_trip_count":{"n":...}}`` on
each while; this module parses the module text into a computation graph and
walks it multiplying by trip counts.

Counted:
  * FLOPs   — dot: 2·|result|·K (K = product of contracting dims);
              arithmetic elementwise: 1·|result|; transcendentals tracked
              separately.
  * bytes   — per instruction: operand + result bytes (fusion nodes count
              their boundary only, like XLA's bytes-accessed), whiles
              multiply bodies.
  * collectives — operand bytes per kind (all-gather / all-reduce /
              reduce-scatter / all-to-all / collective-permute), trip-aware.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "token": 0, "opaque": 0,
}

_ARITH_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "compare", "select", "and", "or", "xor", "not", "clamp",
    "floor", "ceil", "round-nearest-afz", "round-nearest-even", "sign",
    "remainder", "atan2", "popcnt", "shift-left", "shift-right-logical",
    "shift-right-arithmetic",
}
_TRANSCENDENTAL_OPS = {
    "exponential", "log", "log-plus-one", "exponential-minus-one", "tanh",
    "rsqrt", "sqrt", "cbrt", "power", "sine", "cosine", "tan", "logistic",
    "erf", "expm1",
}
_REDUCE_OPS = {"reduce", "reduce-window"}
_ZERO_COST = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "reshape", "broadcast", "transpose", "copy", "slice", "dynamic-slice",
    "dynamic-update-slice", "concatenate", "iota", "convert", "pad",
    "reverse", "gather", "scatter", "after-all", "partition-id",
    "replica-id", "optimization-barrier", "copy-start", "copy-done",
    "bitcast-convert", "rng-bit-generator", "reduce-precision", "sort",
    "custom-call", "infeed", "outfeed", "domain", "send", "recv",
    "send-done", "recv-done", "add-dependency",
}
COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")


@dataclasses.dataclass
class Shape:
    dtype: str
    dims: tuple[int, ...]
    is_tuple: bool = False
    elements: tuple["Shape", ...] = ()

    @property
    def n_elem(self) -> int:
        n = 1
        for d in self.dims:
            n *= d
        return n

    @property
    def bytes(self) -> int:
        if self.is_tuple:
            return sum(e.bytes for e in self.elements)
        return self.n_elem * _DTYPE_BYTES.get(self.dtype, 0)


_ARRAY_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")


def _parse_array(s: str) -> Shape | None:
    m = _ARRAY_RE.match(s.strip())
    if not m:
        return None
    dims = tuple(int(d) for d in m.group(2).split(",")) if m.group(2) else ()
    return Shape(m.group(1), dims)


def _split_top(s: str) -> list[str]:
    """Split on commas at paren/brace depth 0."""
    parts, depth, cur = [], 0, []
    for ch in s:
        if ch in "({[":
            depth += 1
        elif ch in ")}]":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur))
    return parts


def _parse_type(s: str) -> tuple[Shape | None, str]:
    """Parse a type at the start of ``s``; returns (shape, rest)."""
    s = s.lstrip()
    if s.startswith("("):
        depth = 0
        for i, ch in enumerate(s):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                inner = s[1:i]
                elems = []
                for part in _split_top(inner):
                    e, _ = _parse_type(part)
                    if e:
                        elems.append(e)
                return (Shape("tuple", (), True, tuple(elems)), s[i + 1:])
        return None, s
    m = _ARRAY_RE.match(s)
    if not m:
        return None, s
    rest = s[m.end():]
    # skip layout '{...}' suffix
    if rest.startswith("{"):
        rest = rest[rest.index("}") + 1:]
    dims = tuple(int(d) for d in m.group(2).split(",")) if m.group(2) else ()
    return Shape(m.group(1), dims), rest


@dataclasses.dataclass
class Instr:
    name: str
    shape: Shape
    opcode: str
    operands: tuple[str, ...]
    attrs: str
    raw_operands: str = ""

    def param_index(self) -> int | None:
        if self.opcode != "parameter":
            return None
        m = re.match(r"\s*(\d+)", self.raw_operands)
        return int(m.group(1)) if m else None

    def attr_calls(self) -> str | None:
        m = re.search(r"calls=%?([\w.\-]+)", self.attrs)
        return m.group(1) if m else None

    def attr_body(self) -> str | None:
        m = re.search(r"body=%?([\w.\-]+)", self.attrs)
        return m.group(1) if m else None

    def trip_count(self) -> int:
        m = re.search(r'known_trip_count\\?":{\\?"n\\?":\\?"(\d+)', self.attrs)
        return int(m.group(1)) if m else 1

    def contracting(self) -> tuple[int, ...]:
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", self.attrs)
        if not m or not m.group(1):
            return ()
        return tuple(int(d) for d in m.group(1).split(","))


_OPERAND_NAME_RE = re.compile(r"%([\w.\-]+)\s*$")

_INSTR_LINE_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->.*\{")


@dataclasses.dataclass
class Computation:
    name: str
    instrs: dict[str, Instr]
    is_entry: bool = False


def parse_module(text: str) -> tuple[dict[str, Computation], str]:
    """Parse compiled HLO text; returns (computations, entry_name)."""
    comps: dict[str, Computation] = {}
    entry = ""
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HEADER_RE.match(line.strip())
            if m and "=" not in line.split("(")[0]:
                cur = Computation(m.group(1), {},
                                  line.lstrip().startswith("ENTRY"))
                if cur.is_entry:
                    entry = cur.name
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_LINE_RE.match(line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        shape, rest = _parse_type(rest)
        if shape is None:
            continue
        rest = rest.strip()
        om = re.match(r"([\w\-]+)\((.*)$", rest)
        if not om:
            continue
        opcode = om.group(1)
        # operand list: up to matching close paren
        tail = om.group(2)
        depth = 1
        for i, ch in enumerate(tail):
            depth += ch in "("
            depth -= ch in ")"
            if depth == 0:
                ops_raw, attrs = tail[:i], tail[i + 1:]
                break
        else:
            ops_raw, attrs = tail, ""
        operands = []
        for part in _split_top(ops_raw):
            mo = _OPERAND_NAME_RE.search(part.strip())
            if mo:
                operands.append(mo.group(1))
        cur.instrs[name] = Instr(name, shape, opcode, tuple(operands), attrs,
                                 ops_raw)
    return comps, entry


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    transcendentals: float = 0.0
    bytes: float = 0.0
    coll_bytes: dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_OPS})
    coll_count: int = 0

    def __iadd__(self, o: "Cost") -> "Cost":
        self.flops += o.flops
        self.transcendentals += o.transcendentals
        self.bytes += o.bytes
        for k in self.coll_bytes:
            self.coll_bytes[k] += o.coll_bytes[k]
        self.coll_count += o.coll_count
        return self

    def scaled(self, f: float) -> "Cost":
        return Cost(self.flops * f, self.transcendentals * f, self.bytes * f,
                    {k: v * f for k, v in self.coll_bytes.items()},
                    int(self.coll_count * f))

    @property
    def total_coll_bytes(self) -> float:
        return sum(self.coll_bytes.values())


class HloCostModel:
    def __init__(self, text: str):
        self.comps, self.entry = parse_module(text)
        self._memo: dict[tuple[str, bool], Cost] = {}

    # ------------------------------------------------------------------
    def _operand_shape(self, comp: Computation, name: str) -> Shape | None:
        ins = comp.instrs.get(name)
        return ins.shape if ins else None

    def _instr_cost(self, comp: Computation, ins: Instr, fused: bool) -> Cost:
        c = Cost()
        op = ins.opcode
        base = op.replace("-start", "").replace("-done", "")

        if base in COLLECTIVE_OPS:
            if op.endswith("-done"):
                return c
            b = 0.0
            for o in ins.operands:
                sh = self._operand_shape(comp, o)
                if sh is not None and not sh.is_tuple:
                    b += sh.bytes
            if b == 0.0 and not ins.shape.is_tuple:
                b = ins.shape.bytes
            c.coll_bytes[base] += b
            c.coll_count += 1
            c.bytes += b + (0 if ins.shape.is_tuple else ins.shape.bytes)
            return c

        if op == "while":
            body = ins.attr_body()
            trips = ins.trip_count()
            if body in self.comps:
                c += self.comp_cost(body).scaled(trips)
            return c

        if op in ("fusion", "call"):
            callee = ins.attr_calls()
            if callee in self.comps:
                inner = self.comp_cost(callee, fused=(op == "fusion"))
                c += inner
            if op == "fusion" and callee in self.comps:
                c.bytes += self._fusion_boundary_bytes(comp, ins, callee)
            elif op == "fusion":
                b = sum(sh.bytes for o in ins.operands
                        if (sh := self._operand_shape(comp, o)) is not None
                        and not sh.is_tuple)
                c.bytes += b + ins.shape.bytes
            return c

        if op == "conditional":
            # count the most expensive branch (upper bound)
            best = Cost()
            for m in re.finditer(r"(?:branch_computations=\{([^}]*)\}|"
                                 r"(?:true|false)_computation=%?([\w.\-]+))",
                                 ins.attrs):
                names = []
                if m.group(1):
                    names = [n.strip().lstrip("%")
                             for n in m.group(1).split(",")]
                elif m.group(2):
                    names = [m.group(2)]
                for n in names:
                    if n in self.comps:
                        bc = self.comp_cost(n)
                        if bc.flops >= best.flops:
                            best = bc
            c += best
            return c

        if op == "dot":
            k = 1
            lhs = (self._operand_shape(comp, ins.operands[0])
                   if ins.operands else None)
            for d in ins.contracting():
                if lhs is not None and d < len(lhs.dims):
                    k *= lhs.dims[d]
            c.flops += 2.0 * ins.shape.n_elem * k
        elif base in _TRANSCENDENTAL_OPS:
            c.transcendentals += ins.shape.n_elem
            c.flops += ins.shape.n_elem
        elif base in _ARITH_OPS:
            c.flops += ins.shape.n_elem
        elif base in _REDUCE_OPS:
            # elements reduced ~ operand size
            for o in ins.operands[:1]:
                sh = self._operand_shape(comp, o)
                if sh is not None and not sh.is_tuple:
                    c.flops += sh.n_elem

        # memory-level bytes only for non-fused instructions
        if fused:
            return c
        if op == "dynamic-slice":
            c.bytes += 2.0 * (0 if ins.shape.is_tuple else ins.shape.bytes)
        elif op == "dynamic-update-slice":
            upd = (self._operand_shape(comp, ins.operands[1])
                   if len(ins.operands) > 1 else None)
            c.bytes += 2.0 * (upd.bytes if upd is not None else 0.0)
        elif (op == "dot" or base in _ARITH_OPS
              or base in _TRANSCENDENTAL_OPS or base in _REDUCE_OPS
              or base in ("copy", "convert", "gather", "scatter",
                          "concatenate", "broadcast", "transpose")):
            b = ins.shape.bytes if not ins.shape.is_tuple else 0.0
            for o in ins.operands:
                sh = self._operand_shape(comp, o)
                if sh is not None and not sh.is_tuple:
                    b += sh.bytes
            c.bytes += b
        return c

    # ------------------------------------------------------------------
    def _param_utilized_bytes(self, callee: Computation, index: int,
                              full: Shape) -> float:
        """Bytes a fusion actually touches of parameter ``index``.

        XLA's bytes-accessed counts *slice* sizes for dynamic-slice /
        dynamic-update-slice — crucial for scan-stacked buffers (params,
        saved residuals) that each iteration only slices one layer out of.
        """
        if full.is_tuple:
            return 0.0
        # associate the fusion operand with the fused computation's
        # parameter by position; fall back to unique shape match.
        params = [i for i in callee.instrs.values()
                  if i.opcode == "parameter"]
        cands = [i for i in params if i.param_index() == index]
        if not cands:
            cands = [i for i in params if i.shape.dims == full.dims
                     and i.shape.dtype == full.dtype]
        if len(cands) != 1:
            return full.bytes
        pname = cands[0].name
        return min(self._utilized(callee, pname, full), full.bytes)

    def _utilized(self, callee: Computation, vname: str, full: Shape,
                  depth: int = 0) -> float:
        """Bytes touched of value ``vname`` given its consumers.

        dtype converts are transparent (a TPU's native-bf16 pipeline has no
        materialized legalization converts — the CPU backend's whole-buffer
        bf16↔f32 maintenance is excluded by design; DESIGN.md §9)."""
        consumers = [i for i in callee.instrs.values()
                     if vname in i.operands]
        if not consumers or depth > 3:
            return 0.0 if not consumers else full.bytes
        total = 0.0
        for cons in consumers:
            if cons.opcode in ("dynamic-slice", "slice"):
                total += cons.shape.bytes
            elif (cons.opcode == "dynamic-update-slice"
                  and cons.operands and cons.operands[0] == vname):
                # read-modify-write of the update region only
                upd = (self._operand_shape(callee, cons.operands[1])
                       if len(cons.operands) > 1 else None)
                total += upd.bytes if upd is not None else full.bytes
            elif cons.opcode in ("convert", "bitcast", "copy"):
                total += self._utilized(callee, cons.name, full, depth + 1)
            else:
                return full.bytes
        return total

    def _root_written_bytes(self, callee: Computation, full: float) -> float:
        """Bytes a fusion's root actually writes.

        If the root (through elementwise convert/copy/bitcast wrappers) is a
        dynamic-update-slice into a parameter, only the update region is
        written — the rest aliases the carried buffer (XLA in-place DUS).
        """
        root = None
        for i in callee.instrs.values():
            root = i        # printed HLO lists the root last
        cur = root
        hops = 0
        while (cur is not None and hops < 4
               and cur.opcode in ("convert", "copy", "bitcast", "reshape")
               and cur.operands):
            cur = callee.instrs.get(cur.operands[0])
            hops += 1
        if (cur is not None and cur.opcode == "dynamic-update-slice"
                and len(cur.operands) > 1):
            tgt = callee.instrs.get(cur.operands[0])
            upd = callee.instrs.get(cur.operands[1])
            hops = 0
            while (tgt is not None and hops < 4
                   and tgt.opcode in ("convert", "copy", "bitcast")
                   and tgt.operands):
                tgt = callee.instrs.get(tgt.operands[0])
                hops += 1
            if tgt is not None and tgt.opcode == "parameter" \
                    and upd is not None:
                return float(upd.shape.bytes)
        return full

    def _fusion_boundary_bytes(self, comp: Computation, ins: Instr,
                               callee_name: str) -> float:
        callee = self.comps[callee_name]
        b = 0.0
        for idx, o in enumerate(ins.operands):
            sh = self._operand_shape(comp, o)
            if sh is None or sh.is_tuple:
                continue
            b += self._param_utilized_bytes(callee, idx, sh)
        full = (ins.shape.bytes if not ins.shape.is_tuple
                else sum(e.bytes for e in ins.shape.elements))
        b += self._root_written_bytes(callee, float(full))
        return b

    def comp_cost(self, name: str, fused: bool = False) -> Cost:
        key = (name, fused)
        if key in self._memo:
            return self._memo[key]
        comp = self.comps[name]
        total = Cost()
        for ins in comp.instrs.values():
            total += self._instr_cost(comp, ins, fused)
        self._memo[key] = total
        return total

    def entry_cost(self) -> Cost:
        return self.comp_cost(self.entry)


def analyze(text: str) -> dict[str, Any]:
    """Full-module cost: flops / bytes / collective bytes, trip-aware."""
    model = HloCostModel(text)
    c = model.entry_cost()
    return {
        "flops": c.flops,
        "transcendentals": c.transcendentals,
        "bytes": c.bytes,
        "collective_bytes": c.total_coll_bytes,
        "collectives_by_kind": dict(c.coll_bytes),
        "collective_count": c.coll_count,
    }


def xla_cost_analysis(compiled) -> dict[str, Any]:
    """XLA's own ``compiled.cost_analysis()``, normalized across jax
    versions: the pinned jax 0.4.37 returns a one-element *list* of
    per-program dicts, newer jax returns the dict directly, and some
    backends return None.  Always a (possibly empty) dict — the
    comparison baseline for this module's trip-aware numbers."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else None
    return dict(ca) if ca else {}
