"""Three-term roofline from the dry-run's compiled artifact.

    compute term    = HLO_FLOPs_per_chip / peak_FLOP/s
    memory term     = HLO_bytes_per_chip / HBM_bw
    collective term = collective_bytes_per_chip / link_bw

``compiled.cost_analysis()`` reports the **per-device** SPMD program
(post-partitioning), so its flops/bytes are already per-chip.  Collective
bytes are NOT in cost_analysis: we parse the compiled HLO text and sum the
operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instruction.

Hardware model (TPU v5e class, task-specified constants):
  197 TFLOP/s bf16 per chip; 819 GB/s HBM; ~50 GB/s/link ICI.

MODEL_FLOPS uses the classic 6·N·D training estimate (2·N·D forward-only),
with N = *active* params for MoE — the MODEL_FLOPS/HLO_FLOPs ratio then
exposes remat recompute and redundant work in the compiled program.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Any

import numpy as np

from repro.core import hw as hw_targets

# ---------------------------------------------------------------------------
# hardware constants — derived from the same repro.core.hw.Target the FTL
# planner prices plans against, so roofline and FTL can never disagree
# about the machine.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12          # bf16 FLOP/s per chip
    hbm_bw: float = 819e9               # bytes/s per chip
    ici_bw: float = 50e9                # bytes/s per link
    hbm_bytes: float = 16e9             # capacity per chip
    vmem_bytes: float = 96 * 2**20
    target_name: str = "tpu_v5e"

    @classmethod
    def from_target(cls, t: hw_targets.Target) -> "HW":
        """Roofline view of a planning Target: the first backing level
        plays the HBM role, the deepest level's link the collective role
        (remote HBM over ICI on tpu_v5e)."""
        backing = t.levels[1]
        deep = t.levels[-1]
        return cls(
            peak_flops=t.flops,
            hbm_bw=backing.bw_bytes_per_s,
            ici_bw=deep.bw_bytes_per_s if deep is not backing
            else backing.bw_bytes_per_s,
            hbm_bytes=float(backing.capacity_bytes),
            vmem_bytes=float(t.fast.capacity_bytes),
            target_name=t.name,
        )

    def compute_time_s(self, flops: float) -> float:
        """The same compute-time formula the FTL planner prices with:
        ``hw.compute_time`` — the roofline derives both its peak rate
        (``from_target``) and the formula from the one Target, so the
        planner and the roofline cannot disagree about an op's compute
        time (pinned by tests/test_objective.py)."""
        return hw_targets.compute_time(flops, self.peak_flops)


DEFAULT_HW = HW.from_target(hw_targets.TPU_V5E)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

# 'bf16[8,128,4096]{2,1,0}' or 'f32[]'
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# e.g.  '%ag = bf16[...] all-gather(bf16[...] %x), ...'
_INSTR_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\s*\(([^)]*)\)")


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    if not dims:
        return b
    n = 1
    for d in dims.split(","):
        n *= int(d)
    return n * b


@dataclasses.dataclass
class CollectiveStats:
    total_bytes: int
    by_kind: dict[str, int]
    count: int

    def summary(self) -> str:
        per = ", ".join(f"{k}={v/2**20:.1f}MiB"
                        for k, v in sorted(self.by_kind.items()) if v)
        return f"{self.total_bytes/2**20:.1f} MiB over {self.count} ops ({per})"


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Sum operand bytes of every collective in (compiled or stable-HLO) text.

    ``-start`` variants are counted; matching ``-done`` ops carry no
    operands of their own shape class (their operand is the start token),
    so double counting is avoided by skipping '-done'.
    """
    by_kind: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    count = 0
    for m in _INSTR_RE.finditer(hlo_text):
        kind, operands = m.group(1), m.group(2)
        if "-done" in m.group(0).split("(")[0]:
            continue
        b = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(operands))
        if b:
            by_kind[kind] += b
            count += 1
    return CollectiveStats(sum(by_kind.values()), by_kind, count)


# ---------------------------------------------------------------------------
# MODEL_FLOPS
# ---------------------------------------------------------------------------

def active_params(cfg) -> int:
    """Parameter count weighted by activation fraction (MoE top-k/E)."""
    from repro.models.model import count_params, param_shapes

    total = count_params(cfg)
    if not cfg.is_moe:
        return total
    # routed expert weight fraction
    shapes = param_shapes(cfg)
    import jax

    routed = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        names = [str(k.key) for k in path
                 if hasattr(k, "key")]
        if "moe" in names and any(n in ("w1", "w2", "wg") for n in names):
            routed += math.prod(leaf.shape)
    frac = cfg.n_experts_per_token / max(1, cfg.n_experts)
    return total - routed + int(routed * frac)


def _mixer_flops_fwd(cfg, shape) -> int:
    """Forward FLOPs of the temporal mixers (not captured by 2·N·D):
    attention score/value matmuls (causal halved, local capped at the
    window, cross against the context length) and recurrent state updates.
    An estimate — documented as such in EXPERIMENTS.md §Roofline."""
    b, s = shape.global_batch, shape.seq_len
    h, dh = cfg.n_heads, cfg.resolved_head_dim
    decode = shape.kind == "decode"
    total = 0
    for i in range(cfg.n_layers):
        kind = cfg.block_kind(i)
        if kind == "attn":
            ctx = s if decode else s / 2
            tok = 1 if decode else s
            total += int(4 * b * h * dh * tok * ctx)
        elif kind == "local":
            w = cfg.local_window or s
            ctx = min(s, w)
            tok = 1 if decode else s
            total += int(4 * b * h * dh * tok * ctx)
        elif kind == "cross":
            tok = 1 if decode else s
            total += 4 * b * h * dh * tok * cfg.n_image_tokens
        elif kind == "mlstm":
            e = cfg.xlstm_expand * cfg.d_model
            dhe = e // cfg.n_heads
            tok = 1 if decode else s
            # C update (Dh²) + numerator matvec (Dh²) per step per head
            total += 6 * b * cfg.n_heads * dhe * dhe * tok
        elif kind == "slstm":
            d = cfg.d_model
            dhh = d // cfg.n_heads
            tok = 1 if decode else s
            total += 8 * b * d * dhh * tok
        elif kind == "rec":
            w = cfg.lru_width or cfg.d_model
            tok = 1 if decode else s
            total += 12 * b * w * tok
    if cfg.is_encoder_decoder and not decode:
        f = cfg.encoder_seq
        total += cfg.n_encoder_layers * 4 * b * h * dh * f * f // 2
        total += cfg.n_layers * 4 * b * h * dh * s * f      # cross-attn
    return total


def model_flops(cfg, shape) -> int:
    """6·N_active·D (train) / 2·N_active·D (forward), plus mixer terms."""
    n = active_params(cfg)
    mix = _mixer_flops_fwd(cfg, shape)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6 * n * tokens + 3 * mix
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2 * n * tokens + mix
    # decode: one token per sequence
    return 2 * n * shape.global_batch + mix


# ---------------------------------------------------------------------------
# report
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: tuple[int, ...]
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    coll_stats: CollectiveStats | None
    model_flops_total: float
    hw: HW = DEFAULT_HW

    @property
    def t_compute(self) -> float:
        return self.hw.compute_time_s(self.flops_per_chip)

    @property
    def t_memory(self) -> float:
        return self.bytes_per_chip / self.hw.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_chip / self.hw.ici_bw

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        """Roofline step time: overlapped terms → max() — the same
        overlap rule the FTL objective uses (``hw.modeled_runtime``),
        with the collective term folded in."""
        return max(hw_targets.modeled_runtime(self.t_compute, self.t_memory),
                   self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO flops × chips) — remat/redundancy waste."""
        hlo_total = self.flops_per_chip * self.chips
        return self.model_flops_total / max(1.0, hlo_total)

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilization at the roofline bound (the score)."""
        ideal = self.model_flops_total / (self.chips * self.hw.peak_flops)
        return ideal / max(1e-12, self.t_bound)

    def row(self) -> dict[str, Any]:
        return {
            "arch": self.arch, "shape": self.shape,
            "target": self.hw.target_name,
            "mesh": "x".join(map(str, self.mesh)), "chips": self.chips,
            "t_compute_s": round(self.t_compute, 6),
            "t_memory_s": round(self.t_memory, 6),
            "t_collective_s": round(self.t_collective, 6),
            "dominant": self.dominant,
            "model_flops": f"{self.model_flops_total:.3e}",
            "useful_flops_ratio": round(self.useful_flops_ratio, 3),
            "mfu_bound": round(self.mfu_bound, 3),
        }


def roofline(
    *, arch: str, shape, mesh_shape: tuple[int, ...],
    cost: dict[str, Any], hlo_text: str | None,
    model_flops_total: float, hw: HW = DEFAULT_HW,
    coll_bytes: int | None = None,
) -> RooflineReport:
    chips = int(np.prod(mesh_shape))
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    if coll_bytes is None:
        stats = collective_bytes(hlo_text or "")
        coll_bytes = stats.total_bytes
    else:
        stats = None
    return RooflineReport(
        arch=arch, shape=shape.name if hasattr(shape, "name") else str(shape),
        mesh=mesh_shape, chips=chips,
        flops_per_chip=flops, bytes_per_chip=byts,
        coll_bytes_per_chip=float(coll_bytes),
        coll_stats=stats, model_flops_total=model_flops_total, hw=hw)
