"""repro — Fused-Tiled Layers (FTL) on TPU: a multi-pod JAX framework.

Reproduction + extension of "Fused-Tiled Layers: Minimizing Data Movement
on RISC-V SoCs with Software-Managed Caches" (Jung et al., 2025), adapted
to the TPU memory hierarchy (HBM -> VMEM) per DESIGN.md.
"""

__version__ = "0.1.0"
