"""Deterministic synthetic LM data pipeline.

Design goals that carry over to a real pipeline 1:1:

* **Counter-based determinism** — batch ``i`` is a pure function of
  ``(seed, i)`` via Philox counters, so a restarted/resharded job resumes
  bit-identically at any step without replaying the stream (the property
  the checkpoint/restart tests assert).
* **Host sharding** — each process materializes only its
  ``global_batch / process_count`` slice; ``jax.make_array_from_callback``
  assembles the global array for pjit.
* **Prefetch** — a daemon thread keeps ``prefetch`` batches ahead so host
  data generation overlaps device compute.

Two token distributions:

* ``random``  — uniform tokens (dry-run / shape tests).
* ``bigram``  — x_{t+1} = (a·x_t + b + ε) mod V with ε ∈ [0, noise):
  a learnable structure whose optimal NLL is log(noise), giving
  integration tests a strict convergence target.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Iterator

import jax
import numpy as np

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 0
    kind: str = "bigram"          # random | bigram
    noise: int = 4                # bigram branching factor
    prefetch: int = 2


def _rng(seed: int, step: int, lane: int = 0) -> np.random.Generator:
    return np.random.Generator(
        np.random.Philox(key=np.uint64(seed), counter=[0, 0, lane, step]))


def synth_tokens(cfg: DataConfig, step: int, lo: int, hi: int) -> np.ndarray:
    """Rows [lo, hi) of global batch ``step`` — pure function of inputs."""
    n = hi - lo
    v = cfg.vocab_size
    if cfg.kind == "random":
        g = _rng(cfg.seed, step, 1)
        all_rows = g.integers(0, v, size=(cfg.global_batch, cfg.seq_len),
                              dtype=np.int32)
        return all_rows[lo:hi]
    # bigram: per-row generator keyed by (step, row) so any slice is cheap
    a = (cfg.seed * 2 + 1) % v or 1
    b = (cfg.seed * 7 + 3) % v
    out = np.empty((n, cfg.seq_len), np.int32)
    for i, row in enumerate(range(lo, hi)):
        g = _rng(cfg.seed, step, 2 + row)
        x0 = g.integers(0, v)
        eps = g.integers(0, cfg.noise, size=cfg.seq_len).astype(np.int64)
        xs = np.empty(cfg.seq_len, np.int64)
        cur = int(x0)
        for t in range(cfg.seq_len):
            cur = (a * cur + b + int(eps[t])) % v
            xs[t] = cur
        out[i] = xs.astype(np.int32)
    return out


class SyntheticLM:
    """Restartable host-sharded batch iterator.

    ``batch_at(step)`` returns this process's slice as numpy; ``iterate``
    yields prefetched batches starting at ``start_step``.
    """

    def __init__(self, cfg: DataConfig, *,
                 process_index: int | None = None,
                 process_count: int | None = None):
        self.cfg = cfg
        self.pi = (jax.process_index()
                   if process_index is None else process_index)
        self.pc = (jax.process_count()
                   if process_count is None else process_count)
        assert cfg.global_batch % self.pc == 0
        self.per_host = cfg.global_batch // self.pc

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        lo = self.pi * self.per_host
        return {"tokens": synth_tokens(self.cfg, step, lo,
                                       lo + self.per_host)}

    def iterate(self, start_step: int = 0) -> Iterator[dict[str, np.ndarray]]:
        q: queue.Queue = queue.Queue(maxsize=self.cfg.prefetch)
        stop = threading.Event()

        def producer():
            s = start_step
            while not stop.is_set():
                try:
                    q.put(self.batch_at(s), timeout=0.5)
                    s += 1
                except queue.Full:
                    continue

        th = threading.Thread(target=producer, daemon=True)
        th.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()

    def optimal_nll(self) -> float:
        """Entropy floor of the bigram stream."""
        if self.cfg.kind == "bigram":
            return float(np.log(self.cfg.noise))
        return float(np.log(self.cfg.vocab_size))


def make_batch_shapes(cfg, shape, *, dtype="int32") -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of a shape cell
    (dry-run: weak-type-correct, shardable, no allocation)."""
    import jax.numpy as jnp

    b, s = shape.global_batch, shape.seq_len
    batch: dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.n_image_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype))
    return batch
