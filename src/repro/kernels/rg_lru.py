"""RG-LRU gated linear recurrence (Griffin / RecurrentGemma) as a
fused-tiled Pallas kernel.

``h_t = a_t * h_{t-1} + x_t`` over time, per channel.  The FTL view: time is
chunked (grid dim, innermost) and channels tiled; the recurrent state is the
VMEM-resident intermediate carried across time chunks — the full (B, T, D)
state trajectory streams out, but the *carry* never bounces through HBM
between chunks (the layer-per-layer analogue would run chunk-sized scans and
materialize the carry in HBM each time).

Grid (B, d_tiles, t_chunks), t innermost; state scratch (1, block_d) f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, a_ref, h0_ref, h_ref, hT_ref, state_ref):
    tc = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(tc == 0)
    def _init():
        state_ref[...] = h0_ref[...].astype(jnp.float32)

    block_t = x_ref.shape[1]

    def step(i, h):
        xt = x_ref[0, i, :].astype(jnp.float32)
        at = a_ref[0, i, :].astype(jnp.float32)
        h = at * h + xt
        h_ref[0, i, :] = h.astype(h_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_t, step, state_ref[0])
    state_ref[0] = h

    @pl.when(tc == nt - 1)
    def _final():
        hT_ref[...] = state_ref[...]


@functools.partial(
    jax.jit, static_argnames=("block_t", "block_d", "interpret")
)
def rg_lru_scan(
    x: jax.Array,    # (B, T, D) pre-gated input
    a: jax.Array,    # (B, T, D) decay gates in (0, 1)
    h0: jax.Array | None = None,   # (B, D)
    *,
    block_t: int = 256,
    block_d: int = 512,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    b, t, d = x.shape
    if h0 is None:
        h0 = jnp.zeros((b, d), jnp.float32)
    block_t = min(block_t, t)
    block_d = min(block_d, d)
    if t % block_t or d % block_d:
        raise ValueError(f"blocks must divide dims {(t, d)}")
    grid = (b, d // block_d, t // block_t)

    h, hT = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, block_d), lambda bb, dd, tt: (bb, tt, dd)),
            pl.BlockSpec((1, block_t, block_d), lambda bb, dd, tt: (bb, tt, dd)),
            pl.BlockSpec((1, block_d), lambda bb, dd, tt: (bb, dd)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_t, block_d), lambda bb, dd, tt: (bb, tt, dd)),
            pl.BlockSpec((1, block_d), lambda bb, dd, tt: (bb, dd)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, t, d), x.dtype),
            jax.ShapeDtypeStruct((b, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, block_d), jnp.float32)],
        interpret=interpret,
    )(x, a, h0.astype(jnp.float32))
    return h, hT
