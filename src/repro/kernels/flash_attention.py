"""Fused-tiled attention (flash-style) with GQA, causal and local-window
masking.

Attention is the second FTL instance in this framework (DESIGN.md §5): the
(Tq, Tk) score matrix is the intermediate fused away; the online-softmax
rescale is the kernel-policy that lets the Tk contraction tile with a VMEM
accumulator.  Grid (batch*heads, q_tiles, kv_tiles), kv innermost.

Numerics: masking uses a large negative constant (not -inf) and explicit
zero-guards so fully-masked rows (local windows) produce zeros, matching
ref.attention.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _make_kernel(*, causal: bool, window: int | None, scale: float,
                 block_q: int, block_k: int, q_offset: int):
    def kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref):
        iq = pl.program_id(1)
        jk = pl.program_id(2)
        nk = pl.num_programs(2)

        @pl.when(jk == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)
            m_ref[...] = jnp.full_like(m_ref, _NEG)
            l_ref[...] = jnp.zeros_like(l_ref)

        q = q_ref[0].astype(jnp.float32)          # (bq, dh)
        k = k_ref[0].astype(jnp.float32)          # (bk, dh)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

        qpos = iq * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0) + q_offset
        kpos = jk * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), dtype=jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, _NEG)

        m_prev = m_ref[...]                        # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        # guard: rows with nothing unmasked stay at _NEG -> p = 0
        p = jnp.where(s > _NEG / 2, jnp.exp(s - m_new), 0.0)
        alpha = jnp.where(m_prev > _NEG / 2, jnp.exp(m_prev - m_new), 0.0)

        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p.astype(v_ref.dtype), v_ref[0],
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

        @pl.when(jk == nk - 1)
        def _flush():
            l = l_ref[...]
            o_ref[0] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)).astype(
                o_ref.dtype
            )

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "q_offset", "block_q", "block_k", "interpret"
    ),
)
def flash_attention(
    q: jax.Array,   # (B, Hq, Tq, Dh)
    k: jax.Array,   # (B, Hk, Tk, Dh)
    v: jax.Array,   # (B, Hk, Tk, Dh)
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    block_q: int = 256,
    block_k: int = 256,
    interpret: bool = False,
) -> jax.Array:
    b, hq, tq, dh = q.shape
    _, hk, tk, _ = k.shape
    assert hq % hk == 0
    group = hq // hk
    block_q = min(block_q, tq)
    block_k = min(block_k, tk)
    if tq % block_q or tk % block_k:
        raise ValueError(f"blocks must divide seq lens {(tq, tk)}")
    scale = dh ** -0.5

    qf = q.reshape(b * hq, tq, dh)
    kf = k.reshape(b * hk, tk, dh)
    vf = v.reshape(b * hk, tk, dh)

    grid = (b * hq, tq // block_q, tk // block_k)

    def kv_index(bh, iq, jk):
        # map flat q-head index -> flat kv-head index (GQA)
        bb = bh // hq
        h = bh % hq
        return (bb * hk + h // group, jk, 0)

    out = pl.pallas_call(
        _make_kernel(
            causal=causal, window=window, scale=scale,
            block_q=block_q, block_k=block_k, q_offset=q_offset,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda bh, iq, jk: (bh, iq, 0)),
            pl.BlockSpec((1, block_k, dh), kv_index),
            pl.BlockSpec((1, block_k, dh), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda bh, iq, jk: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, tq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, dh), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, tq, dh)
