"""Public kernel API: FTL-planned, backend-dispatching wrappers.

Every op here:
  * asks the FTL solver for block sizes (kernel-policy constraints of the
    specific Pallas dataflow are passed as ``whole_dims``),
  * runs the Pallas kernel on TPU, or in interpret mode elsewhere,
  * can be forced onto the jnp reference path (``backend='ref'``) — that is
    the layer-per-layer baseline used across benchmarks.

The plan lookup is cached (static shapes → static schedule, like Deeploy).
"""
from __future__ import annotations

import functools
from typing import Literal

import jax

from repro.core import ftl
from repro.core import hw as hwlib

from . import ref as _ref
from .flash_attention import flash_attention as _flash
from .fused_mlp import fused_mlp as _fused_mlp
from .gemm import gemm as _gemm
from .gemm_gelu import gemm_act as _gemm_act
from .mlstm import mlstm_scan as _mlstm
from .rg_lru import rg_lru_scan as _rg_lru

Backend = Literal["auto", "pallas", "ref"]


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _resolve(backend: Backend) -> str:
    if backend == "auto":
        # Pallas on TPU; the jnp path elsewhere (interpret mode is for
        # validation, not production CPU execution).
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return backend


# ---------------------------------------------------------------------------
# planned block sizes
# ---------------------------------------------------------------------------

def _kernel_target(target: hwlib.Target | None) -> hwlib.Target:
    """Planning target for the Pallas TPU kernels' block sizes.

    An explicit target wins.  ``None`` resolves through the process
    default *only when* that default is a VMEM-class machine: the
    auto-detected default on a CPU host is the cache-blocked
    ``cpu_cache`` preset, whose 1 MiB fast level cannot hold these
    kernels' whole-K/N weight panels — planning TPU kernels against it
    would raise ``InfeasibleError`` (or pick nonsense blocks) in
    interpret mode.  Such hosts plan the kernels for :data:`TPU_V5E`.
    """
    if target is not None:
        return target
    default = hwlib.default_target()
    if default.fast.capacity_bytes >= 4 * (1 << 20):
        return default
    return hwlib.TPU_V5E


@functools.lru_cache(maxsize=512)
def _plan_mlp_blocks(m: int, k: int, f: int, dtype: str, gated: bool,
                     act: str, target: hwlib.Target) -> tuple[int, int]:
    group = ftl.fusion.mlp(
        m=m, d_model=k, d_ff=f, dtype=dtype, gated=gated, act=act, fuse=True
    )
    plan = ftl.solve(group, target=target, whole_dims=frozenset({"K", "N"}))
    return plan.tile("M"), plan.tile("F")


def plan_mlp_blocks(
    m: int, k: int, f: int, dtype: str, gated: bool, act: str,
    target: hwlib.Target | None = None,
) -> tuple[int, int]:
    """(block_m, block_f) for the fused_mlp kernel from the FTL solver."""
    return _plan_mlp_blocks(m, k, f, dtype, gated, act,
                            _kernel_target(target))


@functools.lru_cache(maxsize=512)
def _plan_gemm_blocks(m: int, k: int, n: int, dtype: str, act: str | None,
                      target: hwlib.Target) -> tuple[int, int, int]:
    if act is None:
        group = ftl.fusion.gemm_chain(m=m, dims_kn=[k, n], dtype=dtype)
    else:
        group = ftl.fusion.gemm_act(m=m, k=k, n=n, dtype=dtype, act=act)
    plan = ftl.solve(group, target=target)
    dims = plan.tiles
    bm = dims.get("M", m)
    bk = dims.get("K", dims.get("K0", k))
    bn = dims.get("F", dims.get("K1", n))
    return bm, bn, bk


def plan_gemm_blocks(
    m: int, k: int, n: int, dtype: str, act: str | None,
    target: hwlib.Target | None = None,
) -> tuple[int, int, int]:
    """(block_m, block_n, block_k) for gemm / gemm_act kernels."""
    return _plan_gemm_blocks(m, k, n, dtype, act, _kernel_target(target))


@functools.lru_cache(maxsize=512)
def _plan_attention_blocks(tq: int, tk: int, dh: int, dtype: str,
                           target: hwlib.Target) -> tuple[int, int]:
    g = ftl.attention_graph(q_len=tq, kv_len=tk, head_dim=dh, dtype=dtype)
    plan = ftl.plan_fixed(g, (), target=target).segments[0].plan
    bq = plan.tile("Tq")
    bk = min(plan.tile("Tk"), max(512, bq))
    while tk % bk:
        bk //= 2
    return bq, max(bk, 1)


def plan_attention_blocks(
    tq: int, tk: int, dh: int, dtype: str,
    target: hwlib.Target | None = None,
) -> tuple[int, int]:
    """(block_q, block_k) for flash attention; Tk is re-tiled if the solver
    kept it whole (its VMEM model allows a whole-row S tile; the kernel
    streams Tk for the online softmax)."""
    return _plan_attention_blocks(tq, tk, dh, dtype,
                                  _kernel_target(target))


# ---------------------------------------------------------------------------
# ops
# ---------------------------------------------------------------------------

def gemm(x, w, *, backend: Backend = "auto",
         target: hwlib.Target | None = None):
    if _resolve(backend) == "ref":
        return _ref.gemm(x, w)
    bm, bn, bk = plan_gemm_blocks(x.shape[0], x.shape[1], w.shape[1],
                                  str(x.dtype), None, target)
    return _gemm(x, w, block_m=bm, block_n=bn, block_k=bk,
                 interpret=_interpret())


def gemm_act(x, w, b=None, *, act: str = "gelu", backend: Backend = "auto",
             target: hwlib.Target | None = None):
    """The paper's benchmark op."""
    if _resolve(backend) == "ref":
        return _ref.gemm_act(x, w, b, act=act)
    bm, bn, bk = plan_gemm_blocks(x.shape[0], x.shape[1], w.shape[1],
                                  str(x.dtype), act, target)
    return _gemm_act(x, w, b, act=act, block_m=bm, block_n=bn, block_k=bk,
                     interpret=_interpret())


def fused_mlp(x, w1, w2, wg=None, b1=None, b2=None, *, act: str = "gelu",
              backend: Backend = "auto",
              target: hwlib.Target | None = None):
    """Full fused MLP; x may have leading batch dims (flattened internally)."""
    if _resolve(backend) == "ref":
        return _ref.mlp(x, w1, w2, wg, b1, b2, act=act)
    *lead, m, k = x.shape
    xf = x.reshape(-1, k)
    bm, bf = plan_mlp_blocks(xf.shape[0], k, w1.shape[1], str(x.dtype),
                             wg is not None, act, target)
    y = _fused_mlp(xf, w1, w2, wg, b1, b2, act=act, block_m=bm, block_f=bf,
                   interpret=_interpret())
    return y.reshape(*lead, m, w2.shape[1])


# XLA-path attention schedule: 'naive' materializes the (Tq, Tk) scores
# (the layer-per-layer baseline); 'blockwise' runs the FTL schedule via
# lax.scan (ref.attention_blockwise) above the length threshold.  §Perf
# toggles this to measure the fused-tiled schedule's effect on the
# compiled dry-run.
_XLA_ATTN = {"mode": "naive", "min_len": 2048}


def set_xla_attention(mode: str, *, min_len: int = 2048) -> None:
    assert mode in ("naive", "blockwise"), mode
    _XLA_ATTN["mode"] = mode
    _XLA_ATTN["min_len"] = min_len


def attention(q, k, v, *, causal: bool = True, window: int | None = None,
              q_offset: int = 0, backend: Backend = "auto",
              target: hwlib.Target | None = None):
    if _resolve(backend) == "ref":
        tk = k.shape[2]
        if _XLA_ATTN["mode"] == "blockwise" and tk >= _XLA_ATTN["min_len"]:
            _, bk = plan_attention_blocks(q.shape[2], tk, q.shape[3],
                                          str(q.dtype), target)
            return _ref.attention_blockwise(
                q, k, v, causal=causal, window=window, q_offset=q_offset,
                block_k=max(bk, 1024))
        return _ref.attention(q, k, v, causal=causal, window=window,
                              q_offset=q_offset)
    bq, bk = plan_attention_blocks(q.shape[2], k.shape[2], q.shape[3],
                                   str(q.dtype), target)
    return _flash(q, k, v, causal=causal, window=window, q_offset=q_offset,
                  block_q=bq, block_k=bk, interpret=_interpret())


def rg_lru(x, a, h0=None, *, backend: Backend = "auto"):
    if _resolve(backend) == "ref":
        return _ref.rg_lru_scan(x, a, h0)
    return _rg_lru(x, a, h0, interpret=_interpret())


def mlstm(q, k, v, i_pre, f_pre, *, backend: Backend = "auto",
          return_state: bool = False):
    if return_state:
        # prefill handoff needs the final (C, n, m); the scan ref provides it
        # (kernel extension tracked as a §Perf item).
        return _ref.mlstm_scan(q, k, v, i_pre, f_pre, return_state=True)
    if _resolve(backend) == "ref":
        return _ref.mlstm_scan(q, k, v, i_pre, f_pre)
    return _mlstm(q, k, v, i_pre, f_pre, interpret=_interpret())
