"""Fused-Tiled MLP — the paper's flagship fusion, extended to the FULL MLP.

One Pallas kernel computes ``y = act(x@w1 + b1)[⊙ (x@wg)] @ w2 + b2`` with
the (M, d_ff) hidden tensor living only as a (block_m, block_f) VMEM tile.
Dataflow (FTL kernel-policy constraints — the solver is told these):

  * K (d_model in)  : whole  — gemm1 is computed output-stationary per tile;
  * N (d_model out) : whole  — the y tile accumulates across F in fp32 VMEM;
  * grid (m, f), f innermost — contraction of gemm2 accumulates in VMEM, so
    y is written to HBM exactly once (cost.py's model of this kernel).

Block sizes come from the FTL solver (ops.py); the kernel asserts the
solver's VMEM accounting by construction (block shapes == plan tiles).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import act_fn


def _make_kernel(act: str, gated: bool, has_b1: bool, has_b2: bool):
    fn = act_fn(act)

    def kernel(*refs):
        refs = list(refs)
        x_ref = refs.pop(0)
        w1_ref = refs.pop(0)
        wg_ref = refs.pop(0) if gated else None
        w2_ref = refs.pop(0)
        b1_ref = refs.pop(0) if has_b1 else None
        b2_ref = refs.pop(0) if has_b2 else None
        o_ref = refs.pop(0)
        acc_ref = refs.pop(0)

        f = pl.program_id(1)
        nf = pl.num_programs(1)

        @pl.when(f == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        h = jnp.dot(x_ref[...], w1_ref[...], preferred_element_type=jnp.float32)
        if has_b1:
            h = h + b1_ref[...].astype(jnp.float32)
        h = fn(h)
        if gated:
            h = h * jnp.dot(
                x_ref[...], wg_ref[...], preferred_element_type=jnp.float32
            )
        # The hidden tile is consumed immediately — never leaves VMEM.
        acc_ref[...] += jnp.dot(
            h.astype(x_ref.dtype), w2_ref[...], preferred_element_type=jnp.float32
        )

        @pl.when(f == nf - 1)
        def _flush():
            y = acc_ref[...]
            if has_b2:
                y = y + b2_ref[...].astype(jnp.float32)
            o_ref[...] = y.astype(o_ref.dtype)

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=("act", "block_m", "block_f", "interpret"),
)
def fused_mlp(
    x: jax.Array,                 # (M, K)
    w1: jax.Array,                # (K, F)
    w2: jax.Array,                # (F, N)
    wg: jax.Array | None = None,  # (K, F) — gate (SwiGLU-style)
    b1: jax.Array | None = None,  # (F,)
    b2: jax.Array | None = None,  # (N,)
    *,
    act: str = "gelu",
    block_m: int = 256,
    block_f: int = 512,
    interpret: bool = False,
) -> jax.Array:
    m, k = x.shape
    kf, f = w1.shape
    f2, n = w2.shape
    assert k == kf and f == f2, (x.shape, w1.shape, w2.shape)
    block_m = min(block_m, m)
    block_f = min(block_f, f)
    if m % block_m or f % block_f:
        raise ValueError(f"blocks must divide dims: M={m}%{block_m}, F={f}%{block_f}")
    grid = (m // block_m, f // block_f)

    gated = wg is not None
    has_b1 = b1 is not None
    has_b2 = b2 is not None

    in_specs = [
        pl.BlockSpec((block_m, k), lambda i, j: (i, 0)),
        pl.BlockSpec((k, block_f), lambda i, j: (0, j)),
    ]
    args = [x, w1]
    if gated:
        in_specs.append(pl.BlockSpec((k, block_f), lambda i, j: (0, j)))
        args.append(wg)
    in_specs.append(pl.BlockSpec((block_f, n), lambda i, j: (j, 0)))
    args.append(w2)
    if has_b1:
        in_specs.append(pl.BlockSpec((1, block_f), lambda i, j: (0, j)))
        args.append(b1.reshape(1, f))
    if has_b2:
        in_specs.append(pl.BlockSpec((1, n), lambda i, j: (0, 0)))
        args.append(b2.reshape(1, n))

    return pl.pallas_call(
        _make_kernel(act, gated, has_b1, has_b2),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, n), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, n), jnp.float32)],
        interpret=interpret,
    )(*args)
