"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth the kernels are validated
against (tests/test_kernels.py sweeps shapes & dtypes with allclose).
They are also the layer-per-layer *execution* baseline: e.g. ``mlp`` here
materializes the hidden tensor exactly like the paper's unfused schedule.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

_ACTS = {
    "gelu": partial(jax.nn.gelu, approximate=True),
    "gelu_exact": partial(jax.nn.gelu, approximate=False),
    "silu": jax.nn.silu,
    "relu": jax.nn.relu,
    "identity": lambda x: x,
}


def act_fn(name: str):
    return _ACTS[name]


# ---------------------------------------------------------------------------
# GEMM family
# ---------------------------------------------------------------------------

def gemm(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.matmul(
        x, w, preferred_element_type=jnp.float32
    ).astype(x.dtype)


def gemm_act(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array | None = None,
    *,
    act: str = "gelu",
) -> jax.Array:
    """The paper's benchmark op: ``act(x @ w + b)``."""
    h = jnp.matmul(x, w, preferred_element_type=jnp.float32)
    if b is not None:
        h = h + b.astype(h.dtype)
    return act_fn(act)(h).astype(x.dtype)


def mlp(
    x: jax.Array,
    w1: jax.Array,
    w2: jax.Array,
    wg: jax.Array | None = None,
    b1: jax.Array | None = None,
    b2: jax.Array | None = None,
    *,
    act: str = "gelu",
) -> jax.Array:
    """Layer-per-layer MLP (materializes the hidden tensor)."""
    h = jnp.matmul(x, w1, preferred_element_type=jnp.float32)
    if b1 is not None:
        h = h + b1.astype(h.dtype)
    h = act_fn(act)(h)
    if wg is not None:
        h = h * jnp.matmul(x, wg, preferred_element_type=jnp.float32)
    y = jnp.matmul(h.astype(x.dtype), w2, preferred_element_type=jnp.float32)
    if b2 is not None:
        y = y + b2.astype(y.dtype)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (supports GQA + causal + local window)
# ---------------------------------------------------------------------------

def attention(
    q: jax.Array,   # (B, Hq, Tq, Dh)
    k: jax.Array,   # (B, Hk, Tk, Dh)
    v: jax.Array,   # (B, Hk, Tk, Dh)
    *,
    causal: bool = True,
    window: int | None = None,     # local attention window (recurrentgemma)
    q_offset: int = 0,             # absolute position of q[0] (decode)
) -> jax.Array:
    b, hq, tq, dh = q.shape
    hk = k.shape[1]
    assert hq % hk == 0, (hq, hk)
    group = hq // hk
    # GQA via reshape (no materialized jnp.repeat of K/V); f32 accumulation
    # via preferred_element_type, not input casts (which would materialize
    # f32 copies of Q/K/V — measured in the dry-run, see §Perf).
    qg = q.reshape(b, hk, group, tq, dh)
    scale = dh ** -0.5
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k,
                   preferred_element_type=jnp.float32) * scale
    qpos = jnp.arange(tq)[:, None] + q_offset
    kpos = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((tq, k.shape[2]), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    # rows fully masked (can happen with windows) -> zeros, not NaN
    p = jnp.where(jnp.isnan(p), 0.0, p)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, hq, tq, dh).astype(q.dtype)


def attention_blockwise(
    q: jax.Array,   # (B, Hq, Tq, Dh)
    k: jax.Array,   # (B, Hk, Tk, Dh)
    v: jax.Array,   # (B, Hk, Tk, Dh)
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    block_k: int = 1024,
) -> jax.Array:
    """FTL-scheduled attention on the XLA path: ``lax.scan`` over KV blocks
    with an online softmax, so the (Tq, Tk) score matrix exists only as a
    (Tq, block_k) tile — the same schedule the Pallas flash kernel runs,
    executed by XLA (executor_xla.py's role, applied to attention).

    Numerically identical to :func:`attention` (same fp32 accumulation);
    peak memory drops from O(Tq·Tk) to O(Tq·block_k) per head.  §Perf
    measures the effect on the compiled dry-run.
    """
    b, hq, tq, dh = q.shape
    hk, tk = k.shape[1], k.shape[2]
    group = hq // hk
    if tk % block_k:
        block_k = tk            # fall back to one block
    nblk = tk // block_k
    qg = q.reshape(b, hk, group, tq, dh)
    scale = dh ** -0.5
    qpos = jnp.arange(tq) + q_offset

    kb = jnp.moveaxis(k.reshape(b, hk, nblk, block_k, dh), 2, 0)
    vb = jnp.moveaxis(v.reshape(b, hk, nblk, block_k, dh), 2, 0)

    def body(carry, blk):
        acc, m_run, l_run, j = carry
        kj, vj = blk
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kj,
                       preferred_element_type=jnp.float32) * scale
        kpos = j * block_k + jnp.arange(block_k)
        mask = jnp.ones((tq, block_k), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask[None, None, None], s, -1e30)
        m_new = jnp.maximum(m_run, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)
        alpha = jnp.exp(m_run - m_new)
        l_new = l_run * alpha + p.sum(-1)
        pv = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(vj.dtype), vj,
                        preferred_element_type=jnp.float32)
        acc = acc * alpha[..., None] + pv
        return (acc, m_new, l_new, j + 1), None

    acc0 = jnp.zeros((b, hk, group, tq, dh), jnp.float32)
    m0 = jnp.full((b, hk, group, tq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hk, group, tq), jnp.float32)
    (acc, _, l, _), _ = jax.lax.scan(
        body, (acc0, m0, l0, jnp.int32(0)), (kb, vb))
    out = acc / jnp.where(l == 0.0, 1.0, l)[..., None]
    return out.reshape(b, hq, tq, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# RG-LRU (Griffin / RecurrentGemma) — gated linear recurrence
# ---------------------------------------------------------------------------

def rg_lru_scan(
    x: jax.Array,   # (B, T, D) gated input u_t (already multiplied by input gate)
    a: jax.Array,   # (B, T, D) per-step decay in (0, 1)
    h0: jax.Array | None = None,   # (B, D) initial state
) -> tuple[jax.Array, jax.Array]:
    """h_t = a_t * h_{t-1} + x_t ;  returns (all h, final h)."""
    b, t, d = x.shape
    if h0 is None:
        h0 = jnp.zeros((b, d), jnp.float32)

    def step(h, inp):
        xt, at = inp
        h = at * h + xt
        return h, h

    xs = (
        jnp.moveaxis(x.astype(jnp.float32), 1, 0),
        jnp.moveaxis(a.astype(jnp.float32), 1, 0),
    )
    hT, hs = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return jnp.moveaxis(hs, 0, 1).astype(x.dtype), hT


# ---------------------------------------------------------------------------
# mLSTM (xLSTM) — matrix-memory recurrence, stabilized
# ---------------------------------------------------------------------------

def mlstm_scan(
    q: jax.Array,   # (B, H, T, Dh)
    k: jax.Array,   # (B, H, T, Dh)
    v: jax.Array,   # (B, H, T, Dh)
    i_pre: jax.Array,   # (B, H, T) input-gate preactivation
    f_pre: jax.Array,   # (B, H, T) forget-gate preactivation
    *,
    return_state: bool = False,
):
    """Stabilized mLSTM recurrence (xLSTM eqs. 19-27).

    C_t = f'_t C_{t-1} + i'_t v_t k_tᵀ ;  n_t = f'_t n_{t-1} + i'_t k_t
    h_t = C_t q̃_t / max(|n_tᵀ q̃_t|, exp(-m_t))      with log-space stabilizer m.
    """
    b, h, t, dh = q.shape
    scale = dh ** -0.5

    def head_scan(qh, kh, vh, ih, fh):
        def step(carry, inp):
            C, n, m = carry
            qt, kt, vt, it, ft = inp
            logf = jax.nn.log_sigmoid(ft)
            m_new = jnp.maximum(logf + m, it)
            i_ = jnp.exp(it - m_new)
            f_ = jnp.exp(logf + m - m_new)
            C = f_ * C + i_ * jnp.outer(vt, kt)
            n = f_ * n + i_ * kt
            qs = qt * scale
            num = C @ qs
            den = jnp.maximum(jnp.abs(jnp.dot(n, qs)), jnp.exp(-m_new))
            return (C, n, m_new), num / den

        C0 = jnp.zeros((dh, dh), jnp.float32)
        n0 = jnp.zeros((dh,), jnp.float32)
        m0 = jnp.float32(0.0)
        carry, hs = jax.lax.scan(
            step,
            (C0, n0, m0),
            (
                qh.astype(jnp.float32),
                kh.astype(jnp.float32),
                vh.astype(jnp.float32),
                ih.astype(jnp.float32),
                fh.astype(jnp.float32),
            ),
        )
        return hs, carry

    fn = jax.vmap(jax.vmap(head_scan))
    out, (C, n, m) = fn(q, k, v, i_pre, f_pre)
    if return_state:
        return out.astype(q.dtype), {"C": C, "n": n, "m": m}
    return out.astype(q.dtype)


def mlstm_scan_chunked(
    q: jax.Array,       # (B, H, T, Dh)
    k: jax.Array,
    v: jax.Array,
    i_pre: jax.Array,   # (B, H, T)
    f_pre: jax.Array,
    *,
    chunk: int = 256,
    return_state: bool = False,
):
    """mLSTM with time-chunked rematerialization (§Perf lever).

    The plain scan's backward pass saves the (Dh×Dh) matrix memory at
    EVERY step — O(T·Dh²) bytes (xlstm-1.3b @4k: ~64 GiB/device).  Here
    the outer scan carries state across chunks and the inner per-chunk
    scan is ``jax.checkpoint``-ed, so only chunk boundaries are saved:
    O(T/chunk·Dh²), recomputing inside chunks on the backward pass.
    Bit-identical forward to :func:`mlstm_scan`.
    """
    b, h, t, dh = q.shape
    while t % chunk:
        chunk //= 2
    nc = t // chunk
    scale = dh ** -0.5

    def chunk_body(carry, inp):
        C, n, m = carry
        qc, kc, vc, ic, fc = inp        # (chunk, Dh)/(chunk,)

        def step(cr, xs):
            Ci, ni, mi = cr
            qt, kt, vt, it, ft = xs
            logf = jax.nn.log_sigmoid(ft)
            m_new = jnp.maximum(logf + mi, it)
            i_ = jnp.exp(it - m_new)
            f_ = jnp.exp(logf + mi - m_new)
            Ci = f_ * Ci + i_ * jnp.outer(vt, kt)
            ni = f_ * ni + i_ * kt
            qs = qt * scale
            num = Ci @ qs
            den = jnp.maximum(jnp.abs(jnp.dot(ni, qs)), jnp.exp(-m_new))
            return (Ci, ni, m_new), num / den

        return jax.lax.scan(step, (C, n, m), (qc, kc, vc, ic, fc))

    chunk_body = jax.checkpoint(
        chunk_body, policy=jax.checkpoint_policies.nothing_saveable)

    def head_scan(qh, kh, vh, ih, fh):
        def resh(x):
            return x.reshape(nc, chunk, *x.shape[1:])
        carry0 = (jnp.zeros((dh, dh), jnp.float32),
                  jnp.zeros((dh,), jnp.float32), jnp.float32(0.0))
        carry, hs = jax.lax.scan(
            chunk_body, carry0,
            (resh(qh.astype(jnp.float32)), resh(kh.astype(jnp.float32)),
             resh(vh.astype(jnp.float32)), resh(ih.astype(jnp.float32)),
             resh(fh.astype(jnp.float32))))
        return hs.reshape(t, dh), carry

    out, (C, n, m) = jax.vmap(jax.vmap(head_scan))(q, k, v, i_pre, f_pre)
    if return_state:
        return out.astype(q.dtype), {"C": C, "n": n, "m": m}
    return out.astype(q.dtype)
