"""Pallas TPU kernels for the FTL hot spots, with jnp oracles.

Layout (per the repo convention):
  <name>.py — pl.pallas_call + BlockSpec kernels
  ops.py    — jit'd public wrappers (FTL-planned block sizes, backend dispatch)
  ref.py    — pure-jnp oracles (also the layer-per-layer baseline)
"""
from . import ops, ref

__all__ = ["ops", "ref"]
