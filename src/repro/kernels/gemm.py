"""Baseline tiled GEMM Pallas kernel (the layer-per-layer building block).

Grid (m, n, k) with k innermost; fp32 VMEM accumulator (FTL kernel-policy:
``contract_accumulate``).  Block shapes come from an FTL ``TilePlan`` — see
ops.py — or are passed explicitly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gemm_kernel(x_ref, w_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def gemm(
    x: jax.Array,            # (M, K)
    w: jax.Array,            # (K, N)
    *,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    if m % block_m or n % block_n or k % block_k:
        raise ValueError(f"blocks must divide dims: {(m, n, k)} vs "
                         f"{(block_m, block_n, block_k)}")
    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        _gemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, w)
