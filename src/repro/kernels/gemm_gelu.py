"""GEMM + activation — the paper's exact fused benchmark op.

Fuses ``act(x @ w + b)`` into one Pallas kernel: the pre-activation tensor
lives only as a VMEM accumulator tile and never reaches HBM (on Siracusa:
never reaches L2/L3).  Grid (m, n, k), k innermost, fp32 accumulator,
activation applied as the epilogue of the final k step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import act_fn


def _make_kernel(act: str, has_bias: bool):
    fn = act_fn(act)

    def kernel(*refs):
        if has_bias:
            x_ref, w_ref, b_ref, o_ref, acc_ref = refs
        else:
            x_ref, w_ref, o_ref, acc_ref = refs

        @pl.when(pl.program_id(2) == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        acc_ref[...] += jnp.dot(
            x_ref[...], w_ref[...], preferred_element_type=jnp.float32
        )

        @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
        def _epilogue():
            h = acc_ref[...]
            if has_bias:
                h = h + b_ref[...].astype(jnp.float32)
            o_ref[...] = fn(h).astype(o_ref.dtype)

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=("act", "block_m", "block_n", "block_k", "interpret"),
)
def gemm_act(
    x: jax.Array,              # (M, K)
    w: jax.Array,              # (K, N)
    b: jax.Array | None = None,  # (N,)
    *,
    act: str = "gelu",
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    m, k = x.shape
    _, n = w.shape
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    if m % block_m or n % block_n or k % block_k:
        raise ValueError("blocks must divide dims")
    grid = (m // block_m, n // block_n, k // block_k)

    in_specs = [
        pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
    ]
    args = [x, w]
    if b is not None:
        in_specs.append(pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)))
        args.append(b.reshape(1, n))

    return pl.pallas_call(
        _make_kernel(act, b is not None),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(*args)
