"""mLSTM matrix-memory recurrence (xLSTM) as a fused-tiled Pallas kernel.

State per head: C (Dh×Dh) matrix memory, n (Dh) normalizer, m scalar
stabilizer — all VMEM-resident scratch carried across time chunks (grid dim,
innermost).  The (T × Dh × Dh) state trajectory that a layer-per-layer
schedule would materialize never exists: only h_t streams out.  This is the
paper's fusion argument applied to a recurrence instead of a GEMM chain.

Grid (B*H, t_chunks).  Within a chunk the recurrence is stepped with
``fori_loop`` (sequential dependence); the TPU-native chunkwise-parallel
formulation (matmul within chunk, recurrence across chunks) is implemented
as `mlstm_chunkwise` — see §Perf in EXPERIMENTS.md for the comparison.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _make_kernel(scale: float):
    def kernel(q_ref, k_ref, v_ref, i_ref, f_ref, h_ref, C_ref, n_ref, m_ref):
        tc = pl.program_id(1)

        @pl.when(tc == 0)
        def _init():
            C_ref[...] = jnp.zeros_like(C_ref)
            n_ref[...] = jnp.zeros_like(n_ref)
            m_ref[...] = jnp.zeros_like(m_ref)

        block_t = q_ref.shape[1]

        def step(t, carry):
            C, n, m = carry
            qt = q_ref[0, t, :].astype(jnp.float32) * scale
            kt = k_ref[0, t, :].astype(jnp.float32)
            vt = v_ref[0, t, :].astype(jnp.float32)
            it = i_ref[0, t].astype(jnp.float32)
            ft = f_ref[0, t].astype(jnp.float32)

            logf = jax.nn.log_sigmoid(ft)
            m_new = jnp.maximum(logf + m, it)
            i_ = jnp.exp(it - m_new)
            f_ = jnp.exp(logf + m - m_new)

            C = f_ * C + i_ * (vt[:, None] * kt[None, :])
            n = f_ * n + i_ * kt

            num = C @ qt
            den = jnp.maximum(jnp.abs(jnp.dot(n, qt)), jnp.exp(-m_new))
            h_ref[0, t, :] = (num / den).astype(h_ref.dtype)
            return C, n, m_new

        C, n, m = jax.lax.fori_loop(
            0, block_t, step, (C_ref[...], n_ref[0], m_ref[0, 0])
        )
        C_ref[...] = C
        n_ref[0] = n
        m_ref[0, 0] = m

    return kernel


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def mlstm_scan(
    q: jax.Array,      # (B, H, T, Dh)
    k: jax.Array,
    v: jax.Array,
    i_pre: jax.Array,  # (B, H, T)
    f_pre: jax.Array,  # (B, H, T)
    *,
    block_t: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, h, t, dh = q.shape
    block_t = min(block_t, t)
    if t % block_t:
        raise ValueError(f"block_t must divide T={t}")
    scale = dh ** -0.5

    qf = q.reshape(b * h, t, dh)
    kf = k.reshape(b * h, t, dh)
    vf = v.reshape(b * h, t, dh)
    if_ = i_pre.reshape(b * h, t)
    ff = f_pre.reshape(b * h, t)

    grid = (b * h, t // block_t)
    out = pl.pallas_call(
        _make_kernel(scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, dh), lambda bh, tt: (bh, tt, 0)),
            pl.BlockSpec((1, block_t, dh), lambda bh, tt: (bh, tt, 0)),
            pl.BlockSpec((1, block_t, dh), lambda bh, tt: (bh, tt, 0)),
            pl.BlockSpec((1, block_t), lambda bh, tt: (bh, tt)),
            pl.BlockSpec((1, block_t), lambda bh, tt: (bh, tt)),
        ],
        out_specs=pl.BlockSpec((1, block_t, dh), lambda bh, tt: (bh, tt, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, t, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((dh, dh), jnp.float32),
            pltpu.VMEM((1, dh), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf, if_, ff)
    return out.reshape(b, h, t, dh)
