"""Fault-tolerant training loop.

Wires together: data pipeline (restartable at any step), checkpoint manager
(async saves, auto-resume), straggler monitor, and a preemption handler
(SIGTERM → synchronous checkpoint → clean exit, the TPU/GCE maintenance
protocol).  Elasticity: restore() re-shards the checkpoint onto whatever
mesh the restarted job brings up (ckpt/manager.py), and the data pipeline
resumes at the restored step — so a job can lose a pod and continue on the
survivors (tests/test_runtime.py simulates exactly this).
"""
from __future__ import annotations

import dataclasses
import logging
import signal
from typing import Any, Callable

import jax

from repro import obs
from repro.ckpt import CheckpointManager
from repro.runtime.monitor import StragglerMonitor

log = logging.getLogger("repro.runtime")


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_dir: str | None = None
    ckpt_every: int = 100
    ckpt_async: bool = True
    keep_n: int = 3
    log_every: int = 10
    straggler_threshold: float = 2.0


class TrainLoop:
    """``run()`` drives step_fn over the data stream with fault tolerance.

    ``step_fn(state, batch) -> (state, metrics)`` — already jitted/pjitted.
    ``make_batch(step) -> batch`` — pure function of the step index
    (counter-based pipeline), so resume needs no stream replay.
    """

    def __init__(
        self,
        cfg: LoopConfig,
        step_fn: Callable,
        make_batch: Callable[[int], Any],
        init_state: Any,
        *,
        state_shardings: Any | None = None,
        on_metrics: Callable[[int, dict], None] | None = None,
    ):
        self.cfg = cfg
        self.step_fn = step_fn
        self.make_batch = make_batch
        self.state = init_state
        self.state_shardings = state_shardings
        self.on_metrics = on_metrics
        self.monitor = StragglerMonitor(threshold=cfg.straggler_threshold)
        self.ckpt = (CheckpointManager(cfg.ckpt_dir, keep_n=cfg.keep_n)
                     if cfg.ckpt_dir else None)
        self._preempted = False
        self.metrics_log: list[dict] = []

    # ------------------------------------------------------------------
    def _install_signal_handlers(self):
        def handler(signum, frame):
            log.warning("signal %s: checkpoint-and-exit requested", signum)
            self._preempted = True

        self._prev = {
            s: signal.signal(s, handler)
            for s in (signal.SIGTERM, signal.SIGINT)
        }

    def _restore_signal_handlers(self):
        for s, h in getattr(self, "_prev", {}).items():
            signal.signal(s, h)

    # ------------------------------------------------------------------
    def _resume(self) -> int:
        if self.ckpt is None or self.ckpt.latest_step() is None:
            return 0
        self.state, step = self.ckpt.restore(
            jax.eval_shape(lambda: self.state),
            shardings=self.state_shardings)
        log.info("resumed from checkpoint step %d", step)
        return step

    def _save(self, step: int, *, blocking: bool) -> None:
        if self.ckpt is None:
            return
        self.ckpt.save(self.state, step, blocking=blocking)

    # ------------------------------------------------------------------
    def run(self) -> Any:
        self._install_signal_handlers()
        try:
            start = self._resume()
            step = start
            while step < self.cfg.total_steps and not self._preempted:
                batch = self.make_batch(step)
                self.monitor.start_step()
                with obs.span(f"train_step:{step}", "train"):
                    self.state, metrics = self.step_fn(self.state, batch)
                    # block on the loss so wall time covers the step
                    metrics = {k: float(v) for k, v in metrics.items()}
                stat = self.monitor.end_step(step)
                if stat.flagged:
                    log.warning("straggler: step %d took %.3fs (ema %.3fs)",
                                step, stat.seconds, self.monitor.ema)
                step += 1
                if self.on_metrics and (step % self.cfg.log_every == 0):
                    self.on_metrics(step, metrics)
                self.metrics_log.append({"step": step, **metrics})
                if step % self.cfg.ckpt_every == 0:
                    self._save(step, blocking=not self.cfg.ckpt_async)
            # final/preemption checkpoint is synchronous — must complete
            if self.ckpt is not None and step > start:
                self._save(step, blocking=True)
                self.ckpt.wait()
            return self.state
        finally:
            self._restore_signal_handlers()
