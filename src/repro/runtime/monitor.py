"""Straggler & liveness monitoring.

``StragglerMonitor`` — per-step wall-time EMA + deviation tracking; flags
steps slower than ``threshold ×`` the running median (hardware degradation,
thermal throttling, a slow host in the data-parallel group).  On a real
pod the flagged signal feeds the controller, which can evict the host and
trigger an elastic restart (runtime/loop.py handles the restart half).

``HeartbeatMonitor`` — file-based process heartbeats: every process stamps
``<dir>/proc_<i>`` each step; any process can list peers whose stamp is
older than ``timeout``.  File-based so it works on any shared filesystem
without a side-channel service; swap ``stamp``/``stale_peers`` for your
RPC of choice on clusters with a coordinator.

Both monitors emit through :mod:`repro.obs.metrics`: flagged-step
counter + per-step seconds gauge (straggler), stamp counter +
oldest-peer-heartbeat-age gauge (liveness) — so a scrape of the metrics
registry shows cluster health next to the serving/planning telemetry.
"""
from __future__ import annotations

import dataclasses
import os
import time

from repro import obs

_C_FLAGGED = obs.counter(
    "train_straggler_flagged_total",
    "steps flagged slower than threshold x the EMA")
_G_STEP = obs.gauge(
    "train_step_seconds", "wall-clock of the last training step")
_G_EMA = obs.gauge(
    "train_step_seconds_ema", "EMA of unflagged step wall-clock")
_C_STAMPS = obs.counter(
    "train_heartbeat_stamps_total", "heartbeats written by this process")
_G_HB_AGE = obs.gauge(
    "train_heartbeat_oldest_age_seconds",
    "age of the oldest peer heartbeat at the last stale_peers() scan")


@dataclasses.dataclass
class StepStat:
    step: int
    seconds: float
    flagged: bool


class StragglerMonitor:
    def __init__(self, *, threshold: float = 2.0, warmup: int = 5):
        self.threshold = threshold
        self.warmup = warmup
        self.ema: float | None = None
        self.history: list[StepStat] = []
        self._t0: float | None = None

    def start_step(self) -> None:
        self._t0 = time.monotonic()

    def end_step(self, step: int) -> StepStat:
        assert self._t0 is not None, "start_step not called"
        dt = time.monotonic() - self._t0
        self._t0 = None
        flagged = False
        if len(self.history) >= self.warmup and self.ema is not None:
            flagged = dt > self.threshold * self.ema
        # EMA excludes flagged outliers so one straggler doesn't poison it
        if self.ema is None:
            self.ema = dt
        elif not flagged:
            self.ema = 0.9 * self.ema + 0.1 * dt
        stat = StepStat(step, dt, flagged)
        self.history.append(stat)
        _G_STEP.set(dt)
        if self.ema is not None:
            _G_EMA.set(self.ema)
        if flagged:
            _C_FLAGGED.inc()
        return stat

    @property
    def flagged_steps(self) -> list[StepStat]:
        return [s for s in self.history if s.flagged]


class HeartbeatMonitor:
    def __init__(self, directory: str, process_index: int, *,
                 timeout: float = 60.0):
        self.dir = directory
        self.pi = process_index
        self.timeout = timeout
        os.makedirs(directory, exist_ok=True)

    def stamp(self) -> None:
        path = os.path.join(self.dir, f"proc_{self.pi}")
        with open(path, "w") as f:
            f.write(str(time.time()))
        _C_STAMPS.inc()

    def stale_peers(self) -> list[int]:
        now = time.time()
        stale = []
        oldest_age = 0.0
        for name in os.listdir(self.dir):
            if not name.startswith("proc_"):
                continue
            try:
                with open(os.path.join(self.dir, name)) as f:
                    t = float(f.read().strip())
            except (OSError, ValueError):
                continue
            oldest_age = max(oldest_age, now - t)
            if now - t > self.timeout:
                stale.append(int(name.split("_")[1]))
        _G_HB_AGE.set(oldest_age)
        return sorted(stale)
