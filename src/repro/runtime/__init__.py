"""Fault-tolerant training runtime: auto-resume loop, preemption handling,
straggler monitoring."""
from .loop import LoopConfig, TrainLoop
from .monitor import HeartbeatMonitor, StragglerMonitor

__all__ = ["TrainLoop", "LoopConfig", "StragglerMonitor", "HeartbeatMonitor"]
