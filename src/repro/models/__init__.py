"""Model zoo: shared layers + per-family blocks + full model assembly."""
from . import layers, model, moe, recurrent
from .model import (
    count_params,
    decode_step,
    forward,
    init_cache,
    init_params,
    param_shapes,
    prefill,
)

__all__ = [
    "layers", "model", "moe", "recurrent",
    "init_params", "forward", "prefill", "decode_step", "init_cache",
    "param_shapes", "count_params",
]
