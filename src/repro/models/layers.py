"""Shared layers for the architecture zoo: norms, RoPE, GQA attention
(training + cached decode, full/local/cross), and the MLP with FTL as a
first-class execution mode.

Parameters are plain nested dicts of jnp arrays; every layer is a pure
function ``f(cfg, params, x, ...)`` so the zoo composes under pjit/remat
without a framework dependency.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.ftl import registry
from repro.distributed.act_sharding import constrain
from repro.kernels import ops

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def init_linear(key, d_in: int, d_out: int, *, bias: bool, dtype,
                scale: float | None = None) -> Params:
    scale = scale if scale is not None else d_in ** -0.5
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
               ).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p: Params, x: jax.Array) -> jax.Array:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def init_norm(d: int, kind: str, dtype) -> Params:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def norm(p: Params, x: jax.Array, kind: str, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    else:
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.mean((xf - mu) ** 2, -1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, Dh); positions: (S,) or (B, S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        ang = positions.astype(jnp.float32)[None, :, None] * freqs[None, None]
    else:
        ang = positions.astype(jnp.float32)[:, :, None] * freqs[None, None]
    cos = jnp.cos(ang)[:, :, None, :]     # (B, S, 1, half)
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def init_attention(cfg, key, *, cross: bool = False) -> Params:
    d, h, hk, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    return {
        "wq": init_linear(ks[0], d, h * dh, bias=cfg.qkv_bias, dtype=dt),
        "wk": init_linear(ks[1], d, hk * dh, bias=cfg.qkv_bias, dtype=dt),
        "wv": init_linear(ks[2], d, hk * dh, bias=cfg.qkv_bias, dtype=dt),
        "wo": init_linear(ks[3], h * dh, d, bias=cfg.mlp_bias, dtype=dt,
                          scale=(h * dh) ** -0.5 / math.sqrt(2 * cfg.n_layers)),
    }


def _split_heads(x: jax.Array, n: int) -> jax.Array:
    b, s, _ = x.shape
    return x.reshape(b, s, n, -1)


def attention_layer(
    cfg,
    p: Params,
    x: jax.Array,                    # (B, S, D)
    *,
    positions: jax.Array,            # (S,)
    causal: bool = True,
    window: int | None = None,
    kv_source: jax.Array | None = None,   # cross-attention context (B, Sk, D)
    use_rope: bool = True,
) -> jax.Array:
    """Full-sequence attention (training / prefill)."""
    h, hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = _split_heads(linear(p["wq"], x), h)
    src = x if kv_source is None else kv_source
    k = _split_heads(linear(p["wk"], src), hk)
    v = _split_heads(linear(p["wv"], src), hk)
    if use_rope and kv_source is None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q = constrain(q.transpose(0, 2, 1, 3), "heads_q")
    k = constrain(k.transpose(0, 2, 1, 3), "heads_kv")
    v = constrain(v.transpose(0, 2, 1, 3), "heads_kv")
    o = ops.attention(
        q, k, v,
        causal=causal and kv_source is None,
        window=window,
        backend="ref" if jax.default_backend() != "tpu" else "auto",
    )
    o = o.transpose(0, 2, 1, 3).reshape(x.shape[0], x.shape[1], h * dh)
    return linear(p["wo"], o)


def attention_prefill(
    cfg,
    p: Params,
    x: jax.Array,
    *,
    positions: jax.Array,
    causal: bool = True,
    window: int | None = None,
    kv_source: jax.Array | None = None,
    use_rope: bool = True,
    pad_to: int | None = None,
) -> tuple[jax.Array, Params]:
    """Full-sequence attention that also returns the decode cache.

    Cache layout (B, S, Hk, Dh); for local windows a ring buffer of the
    last ``window`` positions keyed by ``pos % window``.  ``pad_to``
    right-pads the cache seq dim so decode steps can append in place
    (decode DUS clamps out-of-range starts — an unpadded cache would
    silently corrupt its last slot).
    """
    h, hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = _split_heads(linear(p["wq"], x), h)
    src = x if kv_source is None else kv_source
    k = _split_heads(linear(p["wk"], src), hk)
    v = _split_heads(linear(p["wv"], src), hk)
    if use_rope and kv_source is None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    o = ops.attention(
        constrain(q.transpose(0, 2, 1, 3), "heads_q"),
        constrain(k.transpose(0, 2, 1, 3), "heads_kv"),
        constrain(v.transpose(0, 2, 1, 3), "heads_kv"),
        causal=causal and kv_source is None,
        window=window,
        backend="ref" if jax.default_backend() != "tpu" else "auto",
    )
    o = o.transpose(0, 2, 1, 3).reshape(x.shape[0], x.shape[1], h * dh)
    out = linear(p["wo"], o)
    s = k.shape[1]
    if window is not None and s >= window:
        tail_k, tail_v = k[:, -window:], v[:, -window:]
        tail_pos = positions[-window:] % window
        order = jnp.argsort(tail_pos)
        cache = {"k": tail_k[:, order], "v": tail_v[:, order]}
    else:
        cache = {"k": k, "v": v}
        target = pad_to
        if window is not None:
            # ring buffer must be exactly window-sized for decode
            target = window
        if target is not None and target > s:
            pad = [(0, 0), (0, target - s), (0, 0), (0, 0)]
            cache = {kk: jnp.pad(vv, pad) for kk, vv in cache.items()}
    return out, cache


def masked_decode_attention(
    q: jax.Array,        # (B, H, 1, Dh)
    k: jax.Array,        # (B, S, Hk, Dh)
    v: jax.Array,        # (B, S, Hk, Dh)
    mask: jax.Array,     # (S,) or (B, S) bool — valid cache slots
) -> jax.Array:
    b, hq = q.shape[0], q.shape[1]
    hk = k.shape[2]
    group = hq // hk
    dh = q.shape[-1]
    # keep K/V in storage dtype; accumulate in f32 via the MXU's
    # preferred_element_type — casting inputs would materialize f32 copies
    # of the whole cache (measured: 2.8 GB/step hoisted converts, §Perf).
    qg = q.reshape(b, hk, group, dh)
    kf = k.transpose(0, 2, 1, 3)                       # (B, Hk, S, Dh)
    vf = v.transpose(0, 2, 1, 3)
    s = jnp.einsum("bhgd,bhsd->bhgs", qg, kf,
                   preferred_element_type=jnp.float32) * dh ** -0.5
    # (B, S) masks carry per-row validity (continuous batching decodes
    # slots at mixed sequence lengths); (S,) is the uniform-length case
    mvalid = mask[None, None, None, :] if mask.ndim == 1 \
        else mask[:, None, None, :]
    s = jnp.where(mvalid, s, -1e30)
    pmax = s.max(-1, keepdims=True)
    e = jnp.exp(s - pmax)
    o = jnp.einsum("bhgs,bhsd->bhgd", e.astype(v.dtype), vf,
                   preferred_element_type=jnp.float32)
    o = o / e.sum(-1, keepdims=True)
    return o.reshape(b, hq, 1, dh).astype(q.dtype)


def attention_decode(
    cfg,
    p: Params,
    x: jax.Array,                  # (B, 1, D)
    cache: Params,                 # {"k": (B, S, Hk, Dh), "v": ..., ["cross_k"/"cross_v"]}
    pos: jax.Array,                # () or (B,) int32 — absolute position(s)
    *,
    window: int | None = None,
    cross: bool = False,
    use_rope: bool = True,
) -> tuple[jax.Array, Params]:
    """One-token decode with KV cache (full or ring-buffered local).

    ``pos`` may be a scalar (every row appends at the same position — the
    uniform-length path) or a ``(B,)`` vector for continuous batching at
    mixed sequence lengths: each row writes its new KV at its own
    position and masks its own prefix."""
    h, hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    b = x.shape[0]
    q = _split_heads(linear(p["wq"], x), h)          # (B, 1, H, Dh)

    if cross:
        # cross-attention: K/V precomputed at prefill, no rope, no update
        k, v = cache["k"], cache["v"]
        mask = jnp.ones((k.shape[1],), bool)
        o = masked_decode_attention(q.transpose(0, 2, 1, 3), k, v, mask)
        o = o.transpose(0, 2, 1, 3).reshape(b, 1, h * dh)
        return linear(p["wo"], o), cache

    k_new = _split_heads(linear(p["wk"], x), hk)     # (B, 1, Hk, Dh)
    v_new = _split_heads(linear(p["wv"], x), hk)
    pos = jnp.asarray(pos)
    vec = pos.ndim == 1
    if use_rope:
        # (B, 1) positions rope each row at its own absolute position
        posv = pos[:, None] if vec else pos[None]
        q = rope(q, posv, cfg.rope_theta)
        k_new = rope(k_new, posv, cfg.rope_theta)

    def _dus_rows(full, upd, starts):
        # per-row dynamic update: row i writes its (1, Hk, Dh) slice at
        # its own seq position starts[i]
        return jax.vmap(
            lambda f, u, s: jax.lax.dynamic_update_slice_in_dim(f, u, s, 0)
        )(full, upd, starts)

    s_max = cache["k"].shape[1]
    if window is not None and s_max == window:
        # ring buffer: slot j holds the latest position p ≤ pos with p%W==j
        slot = pos % window
        if vec:
            k = _dus_rows(cache["k"], k_new, slot)
            v = _dus_rows(cache["v"], v_new, slot)
            j = jnp.arange(window)
            mask = (pos[:, None] - ((pos[:, None] - j[None, :]) % window)
                    ) >= 0
        else:
            k = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k_new, slot, 1)
            v = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v_new, slot, 1)
            j = jnp.arange(window)
            slot_pos = pos - ((pos - j) % window)
            mask = slot_pos >= 0
    else:
        if vec:
            k = _dus_rows(cache["k"], k_new, pos)
            v = _dus_rows(cache["v"], v_new, pos)
            kpos = jnp.arange(s_max)
            mask = kpos[None, :] <= pos[:, None]
            if window is not None:
                mask &= kpos[None, :] > pos[:, None] - window
        else:
            k = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k_new, pos, 1)
            v = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v_new, pos, 1)
            kpos = jnp.arange(s_max)
            mask = kpos <= pos
            if window is not None:
                mask &= kpos > pos - window
    k = constrain(k, "kv_cache")
    v = constrain(v, "kv_cache")
    o = masked_decode_attention(q.transpose(0, 2, 1, 3), k, v, mask)
    o = o.transpose(0, 2, 1, 3).reshape(b, 1, h * dh)
    return linear(p["wo"], o), {"k": k, "v": v}


def init_kv_cache(cfg, batch: int, seq: int, dtype, *, window: int | None = None
                  ) -> Params:
    hk, dh = cfg.n_kv_heads, cfg.resolved_head_dim
    s = min(seq, window) if window is not None else seq
    return {
        "k": jnp.zeros((batch, s, hk, dh), dtype),
        "v": jnp.zeros((batch, s, hk, dh), dtype),
    }


# ---------------------------------------------------------------------------
# MLP — FTL integration point (DESIGN.md §5)
# ---------------------------------------------------------------------------

def init_mlp(cfg, key, d_ff: int | None = None) -> Params:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    p = {
        "w1": init_linear(ks[0], d, f, bias=cfg.mlp_bias, dtype=dt),
        "w2": init_linear(ks[1], f, d, bias=cfg.mlp_bias, dtype=dt,
                          scale=f ** -0.5 / math.sqrt(2 * cfg.n_layers)),
    }
    if cfg.mlp_gated:
        p["wg"] = init_linear(ks[2], d, f, bias=False, dtype=dt)
    return p


def mlp_layer(cfg, p: Params, x: jax.Array, *, ftl_mode: str | None = None,
              plan=None) -> jax.Array:
    """MLP dispatched through the FTL executor registry.

    off   — layer-per-layer jnp: the hidden tensor is materialized (XLA
            fuses the activation epilogue but not GEMM→GEMM).  Baseline.
    fused — the fused_mlp Pallas kernel (FTL plan → BlockSpecs).
    scan  — portable FTL schedule via lax.scan token tiling.
    auto  — plan-driven: the fusion partitioner's chosen schedule picks
            the executor (Pallas fused kernel on TPU, scan executor for a
            fused/partial schedule elsewhere, baseline when the planner
            rejects fusion).

    ``plan`` (a :class:`~repro.core.ftl.registry.BlockPlan`) makes the
    plan's own MLP binding authoritative under 'auto' — the serving path
    threads its phase-specific prefill/decode plans here so the MLP runs
    through the executor the plan bound (requalified at the runtime
    shape), instead of re-planning an MLP-only graph.  Override modes
    ('off'/'fused'/'scan') keep their meaning either way.
    """
    mode = ftl_mode if ftl_mode is not None else cfg.ftl_mode
    wg = p.get("wg", {}).get("w")
    b1 = p["w1"].get("b")
    b2 = p["w2"].get("b")
    w1, w2 = p["w1"]["w"], p["w2"]["w"]
    if plan is not None:
        from repro.core.ftl import executor_block  # lazy: no cycle
        exe = executor_block.resolve_mlp(
            plan, mode, x.shape[-2], str(x.dtype),
            d_model=w1.shape[0], d_ff=w1.shape[1], gated=wg is not None,
        )
    else:
        exe = registry.mlp_executor(
            mode,
            m=x.shape[-2], d_model=w1.shape[0], d_ff=w1.shape[1],
            dtype=str(x.dtype), gated=wg is not None, act=cfg.mlp_act,
        )
    return exe.run(x, w1, w2, wg, b1, b2, act=cfg.mlp_act)


# ---------------------------------------------------------------------------
# whole-block execution — BlockPlan as the execution authority
# ---------------------------------------------------------------------------

def block_layer(
    cfg,
    p: Params,
    x: jax.Array,                    # (B, S, D)
    *,
    positions: jax.Array,
    plan=None,                       # registry.BlockPlan | None
    causal: bool = True,
    window: int | None = None,
    use_rope: bool = True,
) -> jax.Array:
    """One pre-norm attention+MLP block, plan-driven when ``plan`` is set.

    With a :class:`~repro.core.ftl.registry.BlockPlan` this replaces the
    hand-sequenced attention+MLP calls: ``registry.run_block`` walks the
    planned segments and dispatches each to its bound executor, falling
    back per segment when a binding does not qualify at runtime.  With
    ``plan=None`` it is the layer-per-layer reference path (the baseline
    the equivalence tests and benchmarks compare against).
    """
    if plan is not None:
        # the caller's cfg stays authoritative for the execution mode even
        # if the plan was made from a differently-moded config
        return registry.run_block(
            plan, p, x, positions=positions, causal=causal, window=window,
            use_rope=use_rope, ftl_mode=cfg.ftl_mode)
    h = norm(p["ln1"], x, cfg.norm)
    o = attention_layer(cfg, p["attn"], h, positions=positions,
                        causal=causal, window=window, use_rope=use_rope)
    x = constrain(x + o, "residual")
    if "mlp" in p:
        h = norm(p["ln2"], x, cfg.norm)
        x = constrain(x + mlp_layer(cfg, p["mlp"], h), "residual")
    return x
