"""Model assembly for the architecture zoo.

One module builds every assigned architecture from the shared blocks:

  dense   — embed → scan[attn + MLP] → norm → lm_head
  moe     — MLP replaced by capacity-routed MoE (+ aux loss)
  vlm     — every ``cross_attn_every``-th layer cross-attends to stub
            image embeddings (llama-3.2-vision)
  ssm     — xLSTM: mLSTM blocks with every ``slstm_every``-th an sLSTM;
            no separate MLP (projections live inside the block)
  hybrid  — recurrentgemma: (rec, rec, local-attn) pattern + MLP each layer
  audio   — whisper: encoder (bidirectional attn over stub frame
            embeddings) + decoder (causal self-attn + cross-attn)

Compile-efficiency: layers are grouped into *pattern periods* (dense: 1
layer; vlm: 5; hybrid: 3; ssm: 8).  Params of each position-in-period are
stacked across periods and the stack is consumed by ``lax.scan`` — the HLO
contains one period body regardless of depth (38..100 layers), which keeps
the 512-device dry-run compiles tractable.  Layers that do not fill a whole
period (recurrentgemma: 38 = 12×3 + 2) are applied unrolled after the scan.

Everything is a pure function of (cfg, params, inputs) so the same code
runs under pjit, remat, eval_shape (dry-run) and CPU smoke tests.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import hw
from repro.core.ftl import InfeasibleError
from repro.core.ftl import registry as ftl_registry
from repro.distributed.act_sharding import constrain
from repro.models import recurrent
from repro.models.layers import (
    attention_decode,
    attention_layer,
    attention_prefill,
    block_layer,
    init_attention,
    init_kv_cache,
    init_linear,
    init_mlp,
    init_norm,
    linear,
    mlp_layer,
    norm,
)
from repro.models.moe import init_moe, moe_layer

Params = dict[str, Any]

# kinds whose mixer handles its own input norm (recurrent blocks do)
_SELF_NORMED = {"mlstm", "slstm", "rec"}
# kinds that keep a decode cache of KV type
_KV_KINDS = {"attn", "local", "cross"}


# ===========================================================================
# per-layer init
# ===========================================================================

def _init_layer(cfg, key, kind: str) -> Params:
    """One transformer/recurrent layer of mixer ``kind`` (+ MLP/MoE)."""
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    p: Params = {"kind_": kind}
    if kind == "attn" or kind == "local":
        p["ln1"] = init_norm(cfg.d_model, cfg.norm, dt)
        p["attn"] = init_attention(cfg, ks[0])
    elif kind == "cross":
        p["ln1"] = init_norm(cfg.d_model, cfg.norm, dt)
        p["attn"] = init_attention(cfg, ks[0], cross=True)
        # gating scalar per llama-3.2 cross-attn layers
        p["xgate"] = jnp.zeros((1,), jnp.float32)
    elif kind == "mlstm":
        p["mix"] = recurrent.init_mlstm_block(cfg, ks[0])
    elif kind == "slstm":
        p["mix"] = recurrent.init_slstm_block(cfg, ks[0])
    elif kind == "rec":
        p["mix"] = recurrent.init_rec_block(cfg, ks[0])
    else:  # pragma: no cover
        raise ValueError(f"unknown block kind {kind!r}")

    if cfg.is_moe:
        p["ln2"] = init_norm(cfg.d_model, cfg.norm, dt)
        p["moe"] = init_moe(cfg, ks[1])
    elif cfg.d_ff:
        p["ln2"] = init_norm(cfg.d_model, cfg.norm, dt)
        p["mlp"] = init_mlp(cfg, ks[1])
    return p


def _strip_kind(p: Params) -> Params:
    return {k: v for k, v in p.items() if k != "kind_"}


# ===========================================================================
# per-layer apply (train / full-sequence forward)
# ===========================================================================

def _apply_mixer(cfg, p: Params, kind: str, x, *, positions, ctx):
    if kind in ("attn", "local"):
        h = norm(p["ln1"], x, cfg.norm)
        window = cfg.local_window if kind == "local" else None
        return attention_layer(cfg, p["attn"], h, positions=positions,
                               causal=True, window=window)
    if kind == "cross":
        h = norm(p["ln1"], x, cfg.norm)
        o = attention_layer(cfg, p["attn"], h, positions=positions,
                            causal=False, kv_source=ctx, use_rope=False)
        return jnp.tanh(p["xgate"]).astype(x.dtype) * o
    if kind == "mlstm":
        return recurrent.mlstm_block(cfg, p["mix"], x)
    if kind == "slstm":
        return recurrent.slstm_block(cfg, p["mix"], x)
    if kind == "rec":
        return recurrent.rec_block(cfg, p["mix"], x)
    raise ValueError(kind)


def _apply_ffn(cfg, p: Params, x, plan=None):
    """Returns (delta, aux).  ``plan`` routes the MLP through its
    BlockPlan binding (serving's phase-split plans); None re-resolves."""
    if "moe" in p:
        h = norm(p["ln2"], x, cfg.norm)
        y, aux = moe_layer(cfg, p["moe"], h)
        return y, aux
    if "mlp" in p:
        h = norm(p["ln2"], x, cfg.norm)
        return mlp_layer(cfg, p["mlp"], h, plan=plan), jnp.float32(0.0)
    return jnp.zeros_like(x), jnp.float32(0.0)


def _apply_layer(cfg, p: Params, kind: str, x, *, positions, ctx, plan=None):
    """Pre-norm residual layer.  Returns (x, aux)."""
    if plan is not None and kind in ("attn", "local") and "mlp" in p:
        # BlockPlan-driven execution: the planned segments (QKV/output
        # projections, attention core, MLP) dispatch through their bound
        # executors; norms and residuals are stitched by run_block.
        window = cfg.local_window if kind == "local" else None
        x = block_layer(cfg, p, x, positions=positions, plan=plan,
                        window=window)
        return x, jnp.float32(0.0)
    x = x + _apply_mixer(cfg, p, kind, x, positions=positions, ctx=ctx)
    x = constrain(x, "residual")
    d, aux = _apply_ffn(cfg, p, x)
    x = constrain(x + d, "residual")
    return x, aux


# ===========================================================================
# period/stack machinery
# ===========================================================================

def period_kinds(cfg) -> list[str]:
    """Mixer kinds of the positions inside one pattern period."""
    if cfg.family == "hybrid":
        return list(cfg.block_pattern)
    if cfg.family == "vlm" and cfg.cross_attn_every:
        k = cfg.cross_attn_every
        return ["attn"] * (k - 1) + ["cross"]
    if cfg.family == "ssm":
        if cfg.slstm_every:
            k = cfg.slstm_every
            return ["mlstm"] * (k - 1) + ["slstm"]
        return ["mlstm"]
    return ["attn"]


def _layer_split(cfg) -> tuple[list[str], int, list[str]]:
    """(period kinds, n_full_periods, remainder kinds)."""
    kinds = period_kinds(cfg)
    per = len(kinds)
    n_full = cfg.n_layers // per
    rem = cfg.n_layers % per
    return kinds, n_full, kinds[:rem]


def _init_stack(cfg, key, kinds: list[str], n: int) -> Params:
    """Stacked params: one entry per position-in-period, leaves (n, ...)."""
    keys = jax.random.split(key, n)

    def one(k):
        ks = jax.random.split(k, len(kinds))
        return {f"pos{i}": _strip_kind(_init_layer(cfg, ks[i], kinds[i]))
                for i in range(len(kinds))}

    return jax.vmap(one)(keys)


def _scan_layers(cfg, stack: Params, kinds: list[str], x, *, positions, ctx,
                 plan=None):
    """lax.scan over periods; returns (x, aux_sum)."""

    def body(carry, pp):
        h, aux = carry
        for i, kind in enumerate(kinds):
            h, a = _apply_layer(cfg, pp[f"pos{i}"], kind, h,
                                positions=positions, ctx=ctx, plan=plan)
            aux = aux + a
        return (h, aux), None

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable
        )
    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), stack)
    return x, aux


@functools.lru_cache(maxsize=256)
def _block_plan_cached(cfg, m: int, dtype: str, target, autotune):
    if cfg.is_moe or cfg.ftl_mode == "off":
        return None
    try:
        return ftl_registry.plan_block(cfg, m=m, dtype=dtype, target=target,
                                       autotune=autotune)
    except (ValueError, InfeasibleError):
        return None


def _block_plan(cfg, m: int, dtype: str, target=None, autotune=None):
    """Cached per-(cfg, m, dtype, target, autotune) whole-block FTL plan,
    or None.

    The one plan every block of the forward pass executes through
    (``registry.plan_block`` additionally caches per platform).  The
    planning target is resolved *before* the cache lookup so changing the
    default target (hw.set_default_target / FTL_TARGET) can never serve a
    plan made for a different hierarchy — the Target hashes over its
    full level description, so editing any level field (capacity,
    bandwidth, ``buffer_depth``) is a new cache key (regression-pinned
    in tests/test_objective.py).  ``autotune`` (a
    :class:`repro.tune.AutotuneConfig`) is likewise part of the key:
    a DES-tuned plan and the analytic plan for the same shapes never
    alias (regression-pinned in tests/test_tune.py).  None — and the
    hand-sequenced path — when there is nothing to plan:
    ``ftl_mode='off'`` is the full escape hatch (run_block would pin the
    baseline executors anyway, so skipping the solver at trace time gives
    the identical compute graph for free), pure SSM stacks have no
    plannable block, and MoE FFNs route (not a chain).
    """
    target = target if target is not None else hw.default_target()
    return _block_plan_cached(cfg, m, dtype, target, autotune)


# ---------------------------------------------------------------------------
# serving plan cache: bucketed prefill shapes + phase-split plans
# ---------------------------------------------------------------------------

# The prefill bucket ladder: prompts are padded up to the next rung so the
# number of distinct prefill plans (and jit compilations) is bounded by
# the ladder length, not by the number of distinct prompt lengths.
PREFILL_BUCKETS: tuple[int, ...] = (8, 16, 32, 64, 128, 256, 512, 1024,
                                    2048, 4096)


def bucket_m(m: int, buckets: tuple[int, ...] = PREFILL_BUCKETS) -> int:
    """Smallest bucket ≥ ``m``.  Raises when ``m`` exceeds the ladder —
    serving must reject (or truncate) prompts longer than its max bucket
    rather than silently compiling an unbounded set of shapes."""
    if m <= 0:
        raise ValueError(f"bucket_m needs m >= 1, got {m}")
    for b in buckets:
        if b >= m:
            return b
    raise ValueError(
        f"m={m} exceeds the largest prefill bucket {max(buckets)}")


@functools.lru_cache(maxsize=512)
def _serve_plan_cached(cfg, m: int, dtype: str, target, phase: str):
    try:
        return ftl_registry.plan_block(cfg, m=m, dtype=dtype, target=target,
                                       phase=phase)
    except (ValueError, InfeasibleError):
        return None


ftl_registry.register_plan_cache("model._block_plan_cached",
                                 _block_plan_cached)
ftl_registry.register_plan_cache("model._serve_plan_cached",
                                 _serve_plan_cached)


def serve_plan(cfg, *, m: int, dtype: str | None = None, target=None,
               phase: str = "prefill",
               buckets: tuple[int, ...] = PREFILL_BUCKETS):
    """(bucketed m, BlockPlan-or-None) for one serving regime.

    The plan cache is keyed ``(cfg, bucketed m, dtype, target, phase)``:
    prefill shapes bucket through the ladder so every request in a bucket
    reuses one plan; decode always plans at ``m=1`` through the same
    partition DP — memory-bound, so it generally cuts differently than
    prefill (pinned on ``rv32_npu`` in tests/test_serve.py).  Unlike
    :func:`_block_plan` this does not gate on ``cfg.ftl_mode`` — serving
    always wants the plan for reporting/qualification, and the executors
    honor the mode at dispatch.  None when nothing is plannable (pure
    SSM, MoE)."""
    target = target if target is not None else hw.default_target()
    dtype = dtype if dtype is not None else cfg.dtype
    mb = 1 if phase == "decode" else bucket_m(m, buckets)
    return mb, _serve_plan_cached(cfg, mb, dtype, target, phase)


# ===========================================================================
# embeddings
# ===========================================================================

def _init_embed(cfg, key) -> Params:
    dt = jnp.dtype(cfg.dtype)
    p = {"tok": (jax.random.normal(key, (cfg.vocab_size, cfg.d_model),
                                   jnp.float32) * cfg.d_model ** -0.5
                 ).astype(dt)}
    return p


def _embed(cfg, p: Params, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["tok"], tokens, axis=0)


def _sinusoid(seq: int, d: int, offset=0) -> jax.Array:
    """Whisper-style sinusoidal positions (computed, never stored)."""
    pos = jnp.arange(seq)[:, None] + offset
    div = jnp.exp(-jnp.log(10000.0) * jnp.arange(0, d, 2) / d)
    ang = pos * div[None]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _unembed(cfg, params: Params, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["tok"].T
    else:
        logits = linear(params["lm_head"], x)
    return constrain(logits, "logits")


# ===========================================================================
# public API — decoder-only families
# ===========================================================================

def init_params(cfg, key) -> Params:
    """Full parameter tree (works under jax.eval_shape for the dry-run)."""
    if cfg.is_encoder_decoder:
        return _init_params_encdec(cfg, key)
    ks = jax.random.split(key, 5)
    kinds, n_full, rem_kinds = _layer_split(cfg)
    params: Params = {
        "embed": _init_embed(cfg, ks[0]),
        "layers": _init_stack(cfg, ks[1], kinds, n_full),
        "final_norm": init_norm(cfg.d_model, cfg.norm, jnp.dtype(cfg.dtype)),
    }
    if rem_kinds:
        rks = jax.random.split(ks[2], len(rem_kinds))
        params["rem"] = {
            f"rem{i}": _strip_kind(_init_layer(cfg, rks[i], k))
            for i, k in enumerate(rem_kinds)
        }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_linear(
            ks[3], cfg.d_model, cfg.vocab_size, bias=False,
            dtype=jnp.dtype(cfg.dtype))
    return params


def forward(cfg, params: Params, batch: dict[str, jax.Array]
            ) -> tuple[jax.Array, jax.Array]:
    """Training/eval forward: ``batch['tokens']`` (B, S) → (logits, aux).

    Extra inputs: ``image_embeds`` (vlm), ``frames`` (audio).
    """
    if cfg.is_encoder_decoder:
        return _forward_encdec(cfg, params, batch)
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = jnp.arange(s)
    ctx = batch.get("image_embeds")
    kinds, _, rem_kinds = _layer_split(cfg)

    x = constrain(_embed(cfg, params["embed"], tokens), "residual")
    plan = _block_plan(cfg, s, cfg.dtype)
    x, aux = _scan_layers(cfg, params["layers"], kinds, x,
                          positions=positions, ctx=ctx, plan=plan)
    for i, kind in enumerate(rem_kinds):
        x, a = _apply_layer(cfg, params["rem"][f"rem{i}"], kind, x,
                            positions=positions, ctx=ctx, plan=plan)
        aux = aux + a
    x = norm(params["final_norm"], x, cfg.norm)
    return _unembed(cfg, params, x), aux


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------

def _layer_prefill(cfg, p: Params, kind: str, x, *, positions, ctx,
                   max_seq: int | None = None, plan=None):
    """Returns (x, cache)."""
    if kind in ("attn", "local"):
        h = norm(p["ln1"], x, cfg.norm)
        window = cfg.local_window if kind == "local" else None
        o, cache = attention_prefill(cfg, p["attn"], h, positions=positions,
                                     causal=True, window=window,
                                     pad_to=max_seq)
        x = x + o
    elif kind == "cross":
        h = norm(p["ln1"], x, cfg.norm)
        o, cache = attention_prefill(cfg, p["attn"], h, positions=positions,
                                     causal=False, kv_source=ctx,
                                     use_rope=False)
        x = x + jnp.tanh(p["xgate"]).astype(x.dtype) * o
    elif kind == "mlstm":
        o, st = recurrent.mlstm_block(cfg, p["mix"], x, return_state=True)
        cache = {"C": st["C"], "n": st["n"], "m": st["m"]}
        x = x + o
    elif kind == "slstm":
        o, cache = recurrent.slstm_block(cfg, p["mix"], x, return_state=True)
        x = x + o
    elif kind == "rec":
        o, cache = recurrent.rec_block(cfg, p["mix"], x, return_state=True)
        x = x + o
    else:
        raise ValueError(kind)
    d, _ = _apply_ffn(cfg, p, x, plan=plan)
    return constrain(x + d, "residual"), cache


def _layer_decode(cfg, p: Params, kind: str, x, cache: Params, pos,
                  plan=None):
    """One-token step.  Returns (x, new_cache)."""
    if kind in ("attn", "local"):
        h = norm(p["ln1"], x, cfg.norm)
        window = cfg.local_window if kind == "local" else None
        o, cache = attention_decode(cfg, p["attn"], h, cache, pos,
                                    window=window)
        x = x + o
    elif kind == "cross":
        h = norm(p["ln1"], x, cfg.norm)
        o, cache = attention_decode(cfg, p["attn"], h, cache, pos, cross=True)
        x = x + jnp.tanh(p["xgate"]).astype(x.dtype) * o
    elif kind == "mlstm":
        o, cache = recurrent.mlstm_block_decode(cfg, p["mix"], x, cache)
        x = x + o
    elif kind == "slstm":
        o, cache = recurrent.slstm_block_decode(cfg, p["mix"], x, cache)
        x = x + o
    elif kind == "rec":
        o, cache = recurrent.rec_block_decode(cfg, p["mix"], x, cache)
        x = x + o
    else:
        raise ValueError(kind)
    d, _ = _apply_ffn(cfg, p, x, plan=plan)
    return constrain(x + d, "residual"), cache


def _init_layer_cache(cfg, kind: str, batch: int, seq: int, *,
                      ctx_len: int = 0) -> Params:
    dt = jnp.dtype(cfg.dtype)
    if kind == "attn":
        return init_kv_cache(cfg, batch, seq, dt)
    if kind == "local":
        return init_kv_cache(cfg, batch, seq, dt, window=cfg.local_window)
    if kind == "cross":
        hk, dh = cfg.n_kv_heads, cfg.resolved_head_dim
        return {"k": jnp.zeros((batch, ctx_len, hk, dh), dt),
                "v": jnp.zeros((batch, ctx_len, hk, dh), dt)}
    if kind == "mlstm":
        return recurrent.init_mlstm_state(cfg, batch)
    if kind == "slstm":
        return recurrent.init_slstm_state(cfg, batch)
    if kind == "rec":
        return recurrent.init_rec_state(cfg, batch)
    raise ValueError(kind)


def init_cache(cfg, batch: int, seq: int) -> Params:
    """Zero decode state for a ``seq``-long context (dry-run decode entry).

    Mirrors init_params' stack structure so pjit shardings line up.
    """
    if cfg.is_encoder_decoder:
        return _init_cache_encdec(cfg, batch, seq)
    kinds, n_full, rem_kinds = _layer_split(cfg)

    def one(_):
        return {f"pos{i}": _init_layer_cache(cfg, k, batch, seq,
                                             ctx_len=cfg.n_image_tokens)
                for i, k in enumerate(kinds)}

    cache: Params = {"layers": jax.vmap(one)(jnp.arange(n_full))}
    if rem_kinds:
        cache["rem"] = {
            f"rem{i}": _init_layer_cache(cfg, k, batch, seq,
                                         ctx_len=cfg.n_image_tokens)
            for i, k in enumerate(rem_kinds)
        }
    return cache


def prefill(cfg, params: Params, batch: dict[str, jax.Array],
            max_seq: int | None = None, *, plan=None,
            last_pos: jax.Array | None = None) -> tuple[jax.Array, Params]:
    """Process the full prompt; returns (last-token logits, decode cache).

    ``max_seq`` right-pads KV caches so subsequent decode steps append in
    place (required whenever decoding continues past the prompt).

    ``plan`` threads a (bucketed) prefill BlockPlan into every layer's
    MLP dispatch — the serving path's plan-cache entry for this shape.
    ``last_pos`` (traced scalar) returns the logits at that token index
    instead of the final one: bucketed serving right-pads prompts up to
    the bucket, so the prompt's true last token sits at
    ``len(prompt) - 1``, not at ``bucket - 1``."""
    if cfg.is_encoder_decoder:
        return _prefill_encdec(cfg, params, batch, max_seq,
                               last_pos=last_pos)
    tokens = batch["tokens"]
    s = tokens.shape[1]
    positions = jnp.arange(s)
    ctx = batch.get("image_embeds")
    kinds, _, rem_kinds = _layer_split(cfg)

    x = constrain(_embed(cfg, params["embed"], tokens), "residual")

    def body(h, pp):
        caches = {}
        for i, kind in enumerate(kinds):
            h, c = _layer_prefill(cfg, pp[f"pos{i}"], kind, h,
                                  positions=positions, ctx=ctx,
                                  max_seq=max_seq, plan=plan)
            caches[f"pos{i}"] = c
        return h, caches

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable
        )
    x, layer_caches = jax.lax.scan(body, x, params["layers"])
    cache: Params = {"layers": layer_caches}
    if rem_kinds:
        cache["rem"] = {}
        for i, kind in enumerate(rem_kinds):
            x, c = _layer_prefill(cfg, params["rem"][f"rem{i}"], kind, x,
                                  positions=positions, ctx=ctx,
                                  max_seq=max_seq, plan=plan)
            cache["rem"][f"rem{i}"] = c
    x = norm(params["final_norm"], x, cfg.norm)
    logits = _unembed(cfg, params, _last_tokens(x, last_pos))
    return logits, cache


def _last_tokens(x: jax.Array, last_pos: jax.Array | None) -> jax.Array:
    """(B, S, D) → (B, 1, D) at ``last_pos`` (None → the final position)."""
    if last_pos is None:
        return x[:, -1:]
    return jax.lax.dynamic_slice_in_dim(x, last_pos, 1, axis=1)


def decode_step(cfg, params: Params, token: jax.Array, cache: Params,
                pos: jax.Array, *, plan=None) -> tuple[jax.Array, Params]:
    """One decode step: ``token`` (B, 1) + cache @ ``pos`` → (logits, cache).

    ``pos`` is a scalar (uniform batch) or a ``(B,)`` vector — continuous
    batching decodes slots at mixed sequence lengths, each row appending
    and masking at its own position (encoder-decoder configs are
    scalar-only: their sinusoidal offset is uniform).  ``plan`` threads
    the m=1 decode BlockPlan into every layer's MLP dispatch."""
    if cfg.is_encoder_decoder:
        return _decode_encdec(cfg, params, token, cache, pos)
    kinds, _, rem_kinds = _layer_split(cfg)
    x = constrain(_embed(cfg, params["embed"], token), "residual")

    def body(h, inp):
        pp, cc = inp
        new = {}
        for i, kind in enumerate(kinds):
            h, c = _layer_decode(cfg, pp[f"pos{i}"], kind, h,
                                 cc[f"pos{i}"], pos, plan=plan)
            new[f"pos{i}"] = c
        return h, new

    x, layer_caches = jax.lax.scan(
        body, x, (params["layers"], cache["layers"]))
    new_cache: Params = {"layers": layer_caches}
    if rem_kinds:
        new_cache["rem"] = {}
        for i, kind in enumerate(rem_kinds):
            x, c = _layer_decode(cfg, params["rem"][f"rem{i}"], kind, x,
                                 cache["rem"][f"rem{i}"], pos, plan=plan)
            new_cache["rem"][f"rem{i}"] = c
    x = norm(params["final_norm"], x, cfg.norm)
    return _unembed(cfg, params, x), new_cache


# ===========================================================================
# encoder-decoder (whisper)
# ===========================================================================
#
# The conv frontend is a stub per the task spec: inputs are precomputed
# frame embeddings (B, encoder_seq, d_model).  Positions are sinusoidal for
# both stacks (whisper's decoder uses a learned table capped at 448; the
# assigned 4k/32k decoder cells are exercised mechanically with sinusoids —
# documented in DESIGN.md §7).

def _init_dec_layer(cfg, key) -> Params:
    """Decoder layer: self-attn + cross-attn + MLP."""
    ks = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.dtype)
    p = _init_layer(cfg, ks[0], "attn")      # ln1 + self-attn (+ln2/mlp)
    p["lnx"] = init_norm(cfg.d_model, cfg.norm, dt)
    p["xattn"] = init_attention(cfg, ks[1], cross=True)
    return p


def _init_params_encdec(cfg, key) -> Params:
    ks = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.dtype)

    def enc_one(k):
        return {"pos0": _strip_kind(_init_layer(cfg, k, "attn"))}

    def dec_one(k):
        return {"pos0": _strip_kind(_init_dec_layer(cfg, k))}

    return {
        "embed": _init_embed(cfg, ks[0]),
        "enc_layers": jax.vmap(enc_one)(
            jax.random.split(ks[1], cfg.n_encoder_layers)),
        "enc_norm": init_norm(cfg.d_model, cfg.norm, dt),
        "layers": jax.vmap(dec_one)(jax.random.split(ks[2], cfg.n_layers)),
        "final_norm": init_norm(cfg.d_model, cfg.norm, dt),
        "lm_head": init_linear(ks[3], cfg.d_model, cfg.vocab_size,
                               bias=False, dtype=dt),
    }


def _encode(cfg, params: Params, frames: jax.Array) -> jax.Array:
    """frames: (B, F, D) stub embeddings → encoder output (B, F, D)."""
    s = frames.shape[1]
    positions = jnp.arange(s)
    x = frames + _sinusoid(s, cfg.d_model).astype(frames.dtype)[None]
    x = constrain(x, "residual")

    def body(h, pp):
        p = pp["pos0"]
        hh = norm(p["ln1"], h, cfg.norm)
        h = h + attention_layer(cfg, p["attn"], hh, positions=positions,
                                causal=False, use_rope=False)
        d, _ = _apply_ffn(cfg, p, h)
        return constrain(h + d, "residual"), None

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable
        )
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return norm(params["enc_norm"], x, cfg.norm)


def _dec_layer_full(cfg, p: Params, x, enc_out, positions):
    h = norm(p["ln1"], x, cfg.norm)
    x = x + attention_layer(cfg, p["attn"], h, positions=positions,
                            causal=True, use_rope=False)
    h = norm(p["lnx"], x, cfg.norm)
    x = x + attention_layer(cfg, p["xattn"], h, positions=positions,
                            causal=False, kv_source=enc_out, use_rope=False)
    d, _ = _apply_ffn(cfg, p, x)
    return constrain(x + d, "residual")


def _forward_encdec(cfg, params: Params, batch) -> tuple[jax.Array, jax.Array]:
    enc_out = _encode(cfg, params, batch["frames"])
    tokens = batch["tokens"]
    s = tokens.shape[1]
    positions = jnp.arange(s)
    x = _embed(cfg, params["embed"], tokens)
    x = x + _sinusoid(s, cfg.d_model).astype(x.dtype)[None]
    x = constrain(x, "residual")

    def body(h, pp):
        return _dec_layer_full(cfg, pp["pos0"], h, enc_out, positions), None

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable
        )
    x, _ = jax.lax.scan(body, x, params["layers"])
    x = norm(params["final_norm"], x, cfg.norm)
    return _unembed(cfg, params, x), jnp.float32(0.0)


def _init_cache_encdec(cfg, batch: int, seq: int) -> Params:
    dt = jnp.dtype(cfg.dtype)
    hk, dh = cfg.n_kv_heads, cfg.resolved_head_dim

    def one(_):
        return {"pos0": {
            "self": init_kv_cache(cfg, batch, seq, dt),
            "cross": {"k": jnp.zeros((batch, cfg.encoder_seq, hk, dh), dt),
                      "v": jnp.zeros((batch, cfg.encoder_seq, hk, dh), dt)},
        }}

    return {"layers": jax.vmap(one)(jnp.arange(cfg.n_layers))}


def _prefill_encdec(cfg, params: Params, batch, max_seq: int | None = None,
                    *, last_pos: jax.Array | None = None
                    ) -> tuple[jax.Array, Params]:
    enc_out = _encode(cfg, params, batch["frames"])
    tokens = batch["tokens"]
    s = tokens.shape[1]
    positions = jnp.arange(s)
    x = _embed(cfg, params["embed"], tokens)
    x = x + _sinusoid(s, cfg.d_model).astype(x.dtype)[None]

    def body(h, pp):
        p = pp["pos0"]
        hh = norm(p["ln1"], h, cfg.norm)
        o, self_c = attention_prefill(cfg, p["attn"], hh,
                                      positions=positions, causal=True,
                                      use_rope=False, pad_to=max_seq)
        h = h + o
        hh = norm(p["lnx"], h, cfg.norm)
        o, cross_c = attention_prefill(cfg, p["xattn"], hh,
                                       positions=positions, causal=False,
                                       kv_source=enc_out, use_rope=False)
        h = h + o
        d, _ = _apply_ffn(cfg, p, h)
        return constrain(h + d, "residual"), {
            "pos0": {"self": self_c, "cross": cross_c}}

    x, caches = jax.lax.scan(body, x, params["layers"])
    x = norm(params["final_norm"], x, cfg.norm)
    return _unembed(cfg, params, _last_tokens(x, last_pos)), {
        "layers": caches}


def _decode_encdec(cfg, params: Params, token, cache, pos
                   ) -> tuple[jax.Array, Params]:
    x = _embed(cfg, params["embed"], token)
    x = x + _sinusoid(1, cfg.d_model, offset=pos).astype(x.dtype)[None]

    def body(h, inp):
        pp, cc = inp
        p, c = pp["pos0"], cc["pos0"]
        hh = norm(p["ln1"], h, cfg.norm)
        o, self_c = attention_decode(cfg, p["attn"], hh, c["self"], pos,
                                     use_rope=False)
        h = h + o
        hh = norm(p["lnx"], h, cfg.norm)
        o, _ = attention_decode(cfg, p["xattn"], hh, c["cross"], pos,
                                cross=True)
        h = h + o
        d, _ = _apply_ffn(cfg, p, h)
        return h + d, {"pos0": {"self": self_c, "cross": c["cross"]}}

    x, caches = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
    x = norm(params["final_norm"], x, cfg.norm)
    return _unembed(cfg, params, x), {"layers": caches}


# ===========================================================================
# shape-level helpers (dry-run / tests)
# ===========================================================================

def param_shapes(cfg) -> Params:
    """Parameter ShapeDtypeStructs without allocating (dry-run entry)."""
    return jax.eval_shape(
        functools.partial(init_params, cfg), jax.random.PRNGKey(0))


def count_params(cfg) -> int:
    import math

    shapes = param_shapes(cfg)
    return sum(math.prod(l.shape) for l in jax.tree.leaves(shapes))
