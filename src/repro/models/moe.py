"""Capacity-based Mixture-of-Experts (GShard-style routing, scatter
dispatch) with optional shared experts.

Dispatch is sort-free rank-within-expert scatter into a static
(E, capacity, D) buffer (differentiable, GSPMD-shardable); overflow tokens
are dropped (capacity_factor).  Per-expert FFNs run as batched einsums so
HLO FLOPs reflect *active* compute — the MODEL_FLOPS/HLO_FLOPs roofline
ratio stays honest.

FTL note (DESIGN.md §7): the per-expert FFN is a GEMM→act→GEMM chain and
is FTL-fusable per expert tile; the routing scatter/gather itself is
data-dependent data movement and NOT fusable — a documented inapplicability.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.act_sharding import constrain
from repro.kernels import ref

from .layers import init_linear, init_mlp, mlp_layer

Params = dict[str, Any]


def init_moe(cfg, key) -> Params:
    d = cfg.d_model
    f = cfg.moe_d_ff
    e = cfg.n_experts
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    scale_in = d ** -0.5
    scale_out = f ** -0.5 / math.sqrt(2 * cfg.n_layers)
    p: Params = {
        "router": init_linear(ks[0], d, e, bias=False, dtype=jnp.float32),
        "w1": (jax.random.normal(ks[1], (e, d, f), jnp.float32) * scale_in
               ).astype(dt),
        "w2": (jax.random.normal(ks[2], (e, f, d), jnp.float32) * scale_out
               ).astype(dt),
    }
    if cfg.mlp_gated:
        p["wg"] = (jax.random.normal(ks[3], (e, d, f), jnp.float32) * scale_in
                   ).astype(dt)
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(cfg, ks[4],
                               d_ff=cfg.shared_d_ff * 1)
    return p


def capacity(n_tokens: int, cfg) -> int:
    c = int(math.ceil(n_tokens * cfg.n_experts_per_token / cfg.n_experts
                      * cfg.capacity_factor))
    return max(8, min(n_tokens, -(-c // 8) * 8))


def moe_layer(cfg, p: Params, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (y, aux_loss).  Dispatch per cfg.moe_dispatch."""
    if cfg.moe_dispatch == "grouped":
        return moe_layer_grouped(cfg, p, x)
    return moe_layer_scatter(cfg, p, x)


def moe_layer_scatter(cfg, p: Params, x: jax.Array
                      ) -> tuple[jax.Array, jax.Array]:
    """Global rank-within-expert scatter dispatch (baseline).

    The expert-rank cumsum runs over ALL tokens — a cross-data-shard
    sequential dependence that GSPMD can only honor by gathering; the
    dry-run measures the resulting collective blow-up (§Perf)."""
    b, s, d = x.shape
    n = b * s
    e = cfg.n_experts
    k = cfg.n_experts_per_token
    c = capacity(n, cfg)

    xf = x.reshape(n, d)
    logits = (xf.astype(jnp.float32) @ p["router"]["w"])           # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)                # (N, k)
    gate_vals = gate_vals / jnp.clip(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- rank-within-expert (k slots per token, priority by k order) ----
    flat_expert = expert_idx.reshape(-1)                           # (N*k,)
    onehot = jax.nn.one_hot(flat_expert, e, dtype=jnp.int32)       # (N*k, E)
    rank = jnp.cumsum(onehot, axis=0) - 1                          # rank per expert
    flat_rank = jnp.take_along_axis(rank, flat_expert[:, None], 1)[:, 0]
    keep = flat_rank < c

    token_idx = jnp.repeat(jnp.arange(n), k)
    dest_e = jnp.where(keep, flat_expert, e)      # e -> dropped (scatter mode=drop)
    dest_c = jnp.where(keep, flat_rank, 0)

    buf = jnp.zeros((e, c, d), x.dtype)
    buf = buf.at[dest_e, dest_c].set(xf[token_idx], mode="drop")
    buf = constrain(buf, "moe_buf")

    # ---- per-expert FFN (batched einsum == grouped GEMM) ----------------
    h = jnp.einsum("ecd,edf->ecf", buf, p["w1"])
    h = ref.act_fn(cfg.mlp_act)(h.astype(jnp.float32)).astype(x.dtype)
    if "wg" in p:
        h = h * jnp.einsum("ecd,edf->ecf", buf, p["wg"])
    h = constrain(h, "moe_hidden")
    y_e = jnp.einsum("ecf,efd->ecd", h, p["w2"])

    # ---- combine ---------------------------------------------------------
    contrib = y_e[dest_e.clip(0, e - 1), dest_c]                   # (N*k, D)
    contrib = jnp.where(keep[:, None], contrib, 0)
    weighted = contrib * gate_vals.reshape(-1)[:, None].astype(x.dtype)
    y = jnp.zeros((n, d), x.dtype).at[token_idx].add(weighted)

    # ---- shared experts ----------------------------------------------------
    if "shared" in p:
        y = y + mlp_layer(cfg, p["shared"], xf[None]).reshape(n, d)

    # ---- load-balance aux loss (Switch/GShard) -----------------------------
    me = probs.mean(0)                                             # (E,)
    ce = jnp.zeros((e,), jnp.float32).at[flat_expert].add(
        jnp.ones_like(flat_expert, jnp.float32)) / (n * k)
    aux = e * jnp.sum(me * ce)

    return y.reshape(b, s, d), aux


def _n_groups(cfg, n_tokens: int) -> int:
    g = cfg.moe_groups or 16
    while n_tokens % g:
        g //= 2
    return max(1, g)


def moe_layer_grouped(cfg, p: Params, x: jax.Array
                      ) -> tuple[jax.Array, jax.Array]:
    """GShard-style grouped dispatch (beyond-baseline, §Perf).

    Tokens are split into G groups aligned with the data shards; routing
    ranks are computed *within* each group, so no cross-shard cumsum
    exists.  The (G, E, C, D) dispatch buffer is data-sharded on G and the
    expert einsum consumes it expert-sharded on E — a (G ↔ E) resharding
    GSPMD lowers to an all-to-all instead of all-gathers.
    """
    b, s, d = x.shape
    n = b * s
    e = cfg.n_experts
    k = cfg.n_experts_per_token
    g = _n_groups(cfg, n)
    sg = n // g                                       # tokens per group
    c = capacity(sg, cfg)

    xg = x.reshape(g, sg, d)
    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32),
                        p["router"]["w"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)   # (G, Sg, k)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- rank within (group, expert) — local to the group ---------------
    flat_e = expert_idx.reshape(g, sg * k)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)       # (G, Sg*k, E)
    rank = jnp.cumsum(onehot, axis=1) - 1
    flat_rank = jnp.take_along_axis(rank, flat_e[..., None], 2)[..., 0]
    keep = flat_rank < c
    dest_e = jnp.where(keep, flat_e, e)               # E -> dropped
    dest_c = jnp.where(keep, flat_rank, 0)

    tok = jnp.repeat(jnp.arange(sg), k)[None].repeat(g, 0)    # (G, Sg*k)
    gi = jnp.arange(g)[:, None].repeat(sg * k, 1)

    buf = jnp.zeros((g, e, c, d), x.dtype)
    buf = buf.at[gi, dest_e, dest_c].set(
        jnp.take_along_axis(xg, tok[..., None], 1), mode="drop")
    buf = constrain(buf, "moe_gbuf")

    # ---- per-expert FFN: (G↔E) resharding is an all-to-all ---------------
    h = jnp.einsum("gecd,edf->gecf", buf, p["w1"])
    h = ref.act_fn(cfg.mlp_act)(h.astype(jnp.float32)).astype(x.dtype)
    if "wg" in p:
        h = h * jnp.einsum("gecd,edf->gecf", buf, p["wg"])
    h = constrain(h, "moe_ghidden")
    y_e = jnp.einsum("gecf,efd->gecd", h, p["w2"])
    y_e = constrain(y_e, "moe_gout")

    # ---- combine (group-local gather) ------------------------------------
    contrib = y_e[gi, dest_e.clip(0, e - 1), dest_c]          # (G, Sg*k, D)
    contrib = jnp.where(keep[..., None], contrib, 0)
    wts = gate_vals.reshape(g, sg * k)[..., None].astype(x.dtype)
    y = jnp.zeros((g, sg, d), x.dtype).at[gi, tok].add(contrib * wts)

    if "shared" in p:
        y = y + mlp_layer(cfg, p["shared"], xg).reshape(g, sg, d)

    # ---- aux loss (per group, averaged) -----------------------------------
    me = probs.mean(1)                                        # (G, E)
    ce = jnp.zeros((g, e), jnp.float32).at[gi, flat_e].add(
        1.0 / (sg * k))
    aux = e * jnp.mean(jnp.sum(me * ce, axis=-1))

    return y.reshape(b, s, d), aux
