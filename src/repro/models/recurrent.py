"""Recurrent temporal-mixing blocks: mLSTM + sLSTM (xLSTM) and the RG-LRU
recurrent block (Griffin / RecurrentGemma).

Sequence paths use the fused-tiled Pallas kernels (kernels/mlstm.py,
kernels/rg_lru.py) on TPU and the jnp scan refs elsewhere; decode paths are
single-step jnp updates on constant-size state — these architectures carry
O(1)/O(window) decode state, which is why they run the long_500k cell.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

from .layers import init_linear, init_norm, linear, norm

Params = dict[str, Any]


def _backend() -> str:
    return "auto" if jax.default_backend() == "tpu" else "ref"


# ===========================================================================
# mLSTM (xLSTM) block
# ===========================================================================

def init_mlstm_block(cfg, key) -> Params:
    d = cfg.d_model
    e = cfg.xlstm_expand * d
    h = cfg.n_heads
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    dh = e // h
    # q/k/v are block-diagonal per head (xLSTM eq. 24's head-wise
    # projections): (H, dh, dh) instead of dense (e, e).
    def blockdiag(k):
        return {"w": (jax.random.normal(k, (h, dh, dh), jnp.float32)
                      * dh ** -0.5).astype(dt)}

    return {
        "norm": init_norm(d, cfg.norm, dt),
        "up": init_linear(ks[0], d, 2 * e, bias=False, dtype=dt),
        "wq": blockdiag(ks[1]),
        "wk": blockdiag(ks[2]),
        "wv": blockdiag(ks[3]),
        "wi": init_linear(ks[4], e, h, bias=True, dtype=jnp.float32),
        "wf": init_linear(ks[5], e, h, bias=True, dtype=jnp.float32),
        "head_norm": init_norm(e, "rmsnorm", dt),
        "down": init_linear(ks[6], e, d, bias=False, dtype=dt,
                            scale=e ** -0.5 / math.sqrt(2 * cfg.n_layers)),
    }


def _mlstm_qkvif(cfg, p, xin):
    b, s, e = xin.shape
    h = cfg.n_heads
    dh = e // h
    xh = xin.reshape(b, s, h, dh)
    q = jnp.einsum("bshd,hde->bhse", xh, p["wq"]["w"])
    k = jnp.einsum("bshd,hde->bhse", xh, p["wk"]["w"])
    v = jnp.einsum("bshd,hde->bhse", xh, p["wv"]["w"])
    i_pre = linear(p["wi"], xin.astype(jnp.float32)).transpose(0, 2, 1)
    f_pre = linear(p["wf"], xin.astype(jnp.float32)).transpose(0, 2, 1) + 3.0
    return q, k, v, i_pre, f_pre


def mlstm_block(cfg, p: Params, x: jax.Array, *, return_state: bool = False):
    """x: (B, S, D) -> (B, S, D); residual added by caller."""
    xn = norm(p["norm"], x, cfg.norm)
    up = linear(p["up"], xn)
    xin, z = jnp.split(up, 2, axis=-1)                # (B, S, E) each
    q, k, v, i_pre, f_pre = _mlstm_qkvif(cfg, p, xin)
    state = None
    if cfg.mlstm_chunk and not return_state:
        # time-chunked remat (§Perf): O(T/chunk) saved state in backward
        hcell = ref.mlstm_scan_chunked(q, k, v, i_pre, f_pre,
                                       chunk=cfg.mlstm_chunk)
    elif return_state:
        hcell, state = ops.mlstm(q, k, v, i_pre, f_pre, backend=_backend(),
                                 return_state=True)
    else:
        hcell = ops.mlstm(q, k, v, i_pre, f_pre, backend=_backend())
    b, s = x.shape[0], x.shape[1]
    hcell = hcell.transpose(0, 2, 1, 3).reshape(b, s, -1)
    hcell = norm(p["head_norm"], hcell, "rmsnorm")
    out = hcell * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = linear(p["down"], out)
    return (y, state) if return_state else y


def init_mlstm_state(cfg, batch: int) -> Params:
    e = cfg.xlstm_expand * cfg.d_model
    h = cfg.n_heads
    dh = e // h
    return {
        "C": jnp.zeros((batch, h, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, h, dh), jnp.float32),
        "m": jnp.zeros((batch, h), jnp.float32),
    }


def mlstm_block_decode(cfg, p: Params, x: jax.Array, state: Params
                       ) -> tuple[jax.Array, Params]:
    """x: (B, 1, D); constant-size state update."""
    xn = norm(p["norm"], x, cfg.norm)
    up = linear(p["up"], xn)
    xin, z = jnp.split(up, 2, axis=-1)
    q, k, v, i_pre, f_pre = _mlstm_qkvif(cfg, p, xin)
    # single step (S=1): squeeze time
    qt = q[:, :, 0].astype(jnp.float32)               # (B, H, Dh)
    kt = k[:, :, 0].astype(jnp.float32)
    vt = v[:, :, 0].astype(jnp.float32)
    it = i_pre[:, :, 0]
    ft = f_pre[:, :, 0]
    dh = qt.shape[-1]
    scale = dh ** -0.5

    logf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(logf + state["m"], it)
    i_ = jnp.exp(it - m_new)[..., None]
    f_ = jnp.exp(logf + state["m"] - m_new)[..., None]
    C = f_[..., None] * state["C"] + i_[..., None] * (
        vt[..., :, None] * kt[..., None, :])
    nvec = f_ * state["n"] + i_ * kt
    qs = qt * scale
    num = jnp.einsum("bhij,bhj->bhi", C, qs)
    den = jnp.maximum(
        jnp.abs(jnp.einsum("bhj,bhj->bh", nvec, qs)), jnp.exp(-m_new)
    )[..., None]
    hcell = (num / den).reshape(x.shape[0], 1, -1).astype(x.dtype)
    hcell = norm(p["head_norm"], hcell, "rmsnorm")
    out = hcell * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return linear(p["down"], out), {"C": C, "n": nvec, "m": m_new}


# ===========================================================================
# sLSTM block (scalar memory, per-head recurrent weights)
# ===========================================================================

def init_slstm_block(cfg, key) -> Params:
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 10)
    p = {"norm": init_norm(d, cfg.norm, dt)}
    for i, g in enumerate(("z", "i", "f", "o")):
        p[f"w{g}"] = init_linear(ks[i], d, d, bias=True, dtype=dt)
        # block-diagonal recurrent weights: (H, dh, dh)
        p[f"r{g}"] = (jax.random.normal(ks[4 + i], (h, dh, dh), jnp.float32)
                      * dh ** -0.5).astype(jnp.float32)
    p["down"] = init_linear(ks[8], d, d, bias=False, dtype=dt,
                            scale=d ** -0.5 / math.sqrt(2 * cfg.n_layers))
    return p


def init_slstm_state(cfg, batch: int) -> Params:
    d = cfg.d_model
    return {k: jnp.zeros((batch, d), jnp.float32) for k in ("h", "c", "n", "m")}


def _slstm_step(cfg, p, state, xt):
    """One sLSTM step; xt: (B, D) pre-activations input (already W x + b)."""
    h_prev = state["h"]
    b, d = h_prev.shape
    hh = h_prev.reshape(b, cfg.n_heads, -1)

    def rec(g):
        return jnp.einsum("bhi,hij->bhj", hh, p[f"r{g}"]).reshape(b, d)

    zt = jnp.tanh(xt["z"] + rec("z"))
    it = xt["i"] + rec("i")
    ft = xt["f"] + rec("f")
    ot = jax.nn.sigmoid(xt["o"] + rec("o"))

    logf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(logf + state["m"], it)
    i_ = jnp.exp(it - m_new)
    f_ = jnp.exp(logf + state["m"] - m_new)
    c = f_ * state["c"] + i_ * zt
    n = f_ * state["n"] + i_
    h = ot * c / jnp.maximum(n, 1.0)
    return {"h": h, "c": c, "n": n, "m": m_new}


def slstm_block(cfg, p: Params, x: jax.Array, *, return_state: bool = False):
    xn = norm(p["norm"], x, cfg.norm).astype(jnp.float32)
    pre = {g: linear(p[f"w{g}"], xn) for g in ("z", "i", "f", "o")}
    b, s, d = x.shape
    state0 = init_slstm_state(cfg, b)

    def step(state, xt):
        new = _slstm_step(cfg, p, state, xt)
        return new, new["h"]

    xs = {g: jnp.moveaxis(v, 1, 0) for g, v in pre.items()}
    stateT, hs = jax.lax.scan(step, state0, xs)
    out = jnp.moveaxis(hs, 0, 1).astype(x.dtype)
    y = linear(p["down"], out)
    return (y, stateT) if return_state else y


def slstm_block_decode(cfg, p: Params, x: jax.Array, state: Params
                       ) -> tuple[jax.Array, Params]:
    xn = norm(p["norm"], x, cfg.norm).astype(jnp.float32)[:, 0]
    pre = {g: linear(p[f"w{g}"], xn) for g in ("z", "i", "f", "o")}
    new = _slstm_step(cfg, p, state, pre)
    out = linear(p["down"], new["h"][:, None].astype(x.dtype))
    return out, new


# ===========================================================================
# RG-LRU recurrent block (Griffin / RecurrentGemma)
# ===========================================================================

_LRU_C = 8.0


def init_rec_block(cfg, key) -> Params:
    d = cfg.d_model
    w = cfg.lru_width or d
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 7)
    # Λ init so a = exp(-c·softplus(Λ)·r) lands in (0.9, 0.999) at r≈0.5
    lam = jnp.log(jnp.expm1(
        -jnp.log(jnp.linspace(0.9, 0.999, w)) * 2.0 / _LRU_C))
    return {
        "norm": init_norm(d, cfg.norm, dt),
        "wx": init_linear(ks[0], d, w, bias=False, dtype=dt),
        "wy": init_linear(ks[1], d, w, bias=False, dtype=dt),
        "conv": (jax.random.normal(ks[2], (cfg.conv_width, w), jnp.float32)
                 * cfg.conv_width ** -0.5).astype(dt),
        "conv_b": jnp.zeros((w,), dt),
        "wr": init_linear(ks[3], w, w, bias=True, dtype=dt),
        "wi": init_linear(ks[4], w, w, bias=True, dtype=dt),
        "lam": lam.astype(jnp.float32),
        "out": init_linear(ks[5], w, d, bias=False, dtype=dt,
                           scale=w ** -0.5 / math.sqrt(2 * cfg.n_layers)),
    }


def _causal_conv(xt: jax.Array, w: jax.Array, b: jax.Array,
                 prev: jax.Array | None = None) -> jax.Array:
    """Depthwise causal conv along time.  xt: (B, T, W); w: (K, W)."""
    kw = w.shape[0]
    if prev is None:
        pad = jnp.zeros((xt.shape[0], kw - 1, xt.shape[2]), xt.dtype)
    else:
        pad = prev.astype(xt.dtype)
    xp = jnp.concatenate([pad, xt], axis=1)
    out = sum(
        xp[:, i:i + xt.shape[1]] * w[i][None, None] for i in range(kw)
    )
    return out + b[None, None]


def _lru_gates(p, xc):
    r = jax.nn.sigmoid(linear(p["wr"], xc).astype(jnp.float32))
    i = jax.nn.sigmoid(linear(p["wi"], xc).astype(jnp.float32))
    log_a = -_LRU_C * jax.nn.softplus(p["lam"])[None, None] * r
    a = jnp.exp(log_a)
    # input normalization: sqrt(1 - a^2), from the Griffin paper
    u = jnp.sqrt(jnp.clip(1.0 - a * a, 1e-12)) * i * xc.astype(jnp.float32)
    return a, u


def rec_block(cfg, p: Params, x: jax.Array, *, return_state: bool = False):
    xn = norm(p["norm"], x, cfg.norm)
    xb = linear(p["wx"], xn)                                   # (B, T, W)
    xc = _causal_conv(xb, p["conv"], p["conv_b"])
    a, u = _lru_gates(p, xc)
    h, hT = ops.rg_lru(u.astype(x.dtype), a.astype(x.dtype),
                       backend=_backend())
    gate = jax.nn.gelu(linear(p["wy"], xn).astype(jnp.float32))
    out = (h.astype(jnp.float32) * gate).astype(x.dtype)
    y = linear(p["out"], out)
    if return_state:
        state = {
            "h": hT,
            "conv": xb[:, -(cfg.conv_width - 1):].astype(jnp.float32),
        }
        return y, state
    return y


def init_rec_state(cfg, batch: int) -> Params:
    w = cfg.lru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), jnp.float32),
    }


def rec_block_decode(cfg, p: Params, x: jax.Array, state: Params
                     ) -> tuple[jax.Array, Params]:
    xn = norm(p["norm"], x, cfg.norm)
    xb = linear(p["wx"], xn)                                   # (B, 1, W)
    xc = _causal_conv(xb, p["conv"], p["conv_b"], prev=state["conv"])
    a, u = _lru_gates(p, xc)
    h = a[:, 0] * state["h"] + u[:, 0]
    conv_new = jnp.concatenate(
        [state["conv"][:, 1:], xb.astype(jnp.float32)], axis=1)
    gate = jax.nn.gelu(linear(p["wy"], xn).astype(jnp.float32))
    out = (h[:, None] * gate).astype(x.dtype)
    return linear(p["out"], out), {"h": h, "conv": conv_new}
