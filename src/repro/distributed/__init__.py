"""Distribution layer: sharding rules (FSDP×TP), activation policy,
gradient compression, pipeline parallelism."""
from . import act_sharding, compression, pipeline, sharding

__all__ = ["act_sharding", "compression", "pipeline", "sharding"]
