"""Distribution layer: sharding rules (FSDP×TP), activation policy,
gradient compression, pipeline parallelism.

``shard_map`` is re-exported here with a version shim: the pinned
jax==0.4.37 only ships it as ``jax.experimental.shard_map.shard_map``
(``jax.shard_map`` landed later), and every shard_map consumer in the
repo (``pipeline.py``, ``compression.py``'s collective building blocks,
the tests) must resolve it through this one spot instead of re-deciding
per call site.
"""
import jax

try:  # newer jax: public top-level API
    shard_map = jax.shard_map
except AttributeError:  # pinned jax 0.4.37: still experimental
    from jax.experimental.shard_map import shard_map

from . import act_sharding, compression, pipeline, sharding

__all__ = ["act_sharding", "compression", "pipeline", "sharding",
           "shard_map"]
