"""Activation-sharding hook.

Model code calls ``constrain(x, kind)`` at layer boundaries; a sharding
*policy* (installed by the train/serve step builders via ``use_policy``)
maps the semantic kind to a ``with_sharding_constraint``.  Outside any
policy (CPU smoke tests, examples) it is a no-op, keeping model code
mesh-agnostic.

Kinds used by the model zoo:
  residual    (B, S, D)      ffn_hidden (B, S, F)      logits   (B, S, V)
  heads_q     (B, H, S, Dh)  heads_kv   (B, Hk, S, Dh) kv_cache (B, S, Hk, Dh)
  moe_buf     (E, C, D)      moe_hidden (E, C, F)      rec_state (B, D)
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Callable

import jax

Policy = Callable[[jax.Array, str], jax.Array]

_POLICY: contextvars.ContextVar[Policy | None] = contextvars.ContextVar(
    "repro_act_sharding_policy", default=None
)


@contextlib.contextmanager
def use_policy(policy: Policy | None):
    token = _POLICY.set(policy)
    try:
        yield
    finally:
        _POLICY.reset(token)


def constrain(x: jax.Array, kind: str) -> jax.Array:
    policy = _POLICY.get()
    if policy is None:
        return x
    return policy(x, kind)
