"""GPipe-style pipeline parallelism over a ``pipe`` mesh axis (opt-in).

For deployments where TP×FSDP leaves too little per-device memory (very
deep models at small world sizes), layers split into S stages placed on the
``pipe`` axis; microbatches stream through with ``lax.ppermute`` rotations.
Classic GPipe schedule: S + M - 1 ticks for M microbatches, bubble fraction
(S-1)/(S+M-1).

Implemented with shard_map: every device runs its stage each tick, then
activations rotate one stage forward.  Finished microbatches accumulate on
the last stage; a final psum broadcasts them (all other stages contribute
zeros).  Self-contained — used by tests and launch/train.py ``--pipeline``;
the production layout for the assigned cells is TP×FSDP (DESIGN.md §6).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed import shard_map  # version shim (jax 0.4.37)

Params = dict[str, Any]


def stage_params(params_per_layer: list[Params], n_stages: int) -> Params:
    """Stack per-layer param trees into (S, layers_per_stage, ...) leaves."""
    n = len(params_per_layer)
    assert n % n_stages == 0, (n, n_stages)
    per = n // n_stages
    stages = []
    for s in range(n_stages):
        chunk = params_per_layer[s * per:(s + 1) * per]
        stages.append(jax.tree.map(lambda *xs: jnp.stack(xs), *chunk))
    return jax.tree.map(lambda *xs: jnp.stack(xs), *stages)


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_stages + n_micro - 1)


def pipeline_forward(
    stage_fn: Callable[[Params, jax.Array], jax.Array],
    staged_params: Params,      # leaves (S, per_stage, ...), sharded over pipe
    x: jax.Array,               # (M, micro_batch, ...) microbatched input
    *,
    mesh: Mesh,
    axis: str = "pipe",
) -> jax.Array:
    """Run the GPipe schedule; returns (M, micro_batch, ...) outputs.

    ``stage_fn(stage_params, act) -> act`` applies one stage's layers;
    activations must keep a fixed shape across stages.
    """
    n_stages = mesh.shape[axis]
    m = x.shape[0]

    def body(params, xs):
        params = jax.tree.map(lambda l: l[0], params)   # drop stage dim
        stage = jax.lax.axis_index(axis)
        queue = jax.lax.all_gather(xs, axis, tiled=True)    # (M, mb, ...)
        fwd = [(i, (i + 1) % n_stages) for i in range(n_stages)]
        zero = jnp.zeros_like(queue[0])
        out0 = jnp.zeros_like(queue)

        def tick(t, carry):
            cur, out = carry
            feed = jax.lax.dynamic_index_in_dim(
                queue, jnp.clip(t, 0, m - 1), keepdims=False)
            cur = jnp.where(stage == 0, feed, cur)
            y = stage_fn(params, cur)
            done = t - (n_stages - 1)
            write = (stage == n_stages - 1) & (done >= 0)
            out = jnp.where(
                write,
                jax.lax.dynamic_update_index_in_dim(
                    out, y, jnp.clip(done, 0, m - 1), 0),
                out)
            cur = jax.lax.ppermute(y, axis, fwd)
            return cur, out

        _, out = jax.lax.fori_loop(0, m + n_stages - 1, tick, (zero, out0))
        return jax.lax.psum(out, axis)   # only the last stage wrote

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(axis), staged_params), P(axis)),
        out_specs=P(),
    )
    return fn(staged_params, x)
