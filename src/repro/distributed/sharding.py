"""Parameter & activation sharding rules (FSDP × TP 2-D layout).

Layout on the production mesh (DESIGN.md §6):

  * ``model`` axis (16-way): tensor parallelism — attention heads, d_ff,
    vocab, expert dim (EP) where divisible.
  * ``data`` axis (16-way): FSDP — parameters sharded on the *other*
    matrix dim; GSPMD inserts all-gather on use, reduce-scatter on grads.
  * ``pod`` axis (multi-pod): pure data parallelism — params replicated
    across pods (cross-pod traffic is grad all-reduce only), batch sharded.

Dims are sharded **only when divisible** by the axis size (`_div`): e.g.
whisper's vocab 51865 stays replicated, Hk=1 MQA kv-heads never shard over
``model`` — the FTL *sharding constraint family* (DESIGN.md §2) expressed
at the framework level.

The rule engine is name-based over the parameter pytree paths produced by
``models.model.init_params`` — stacked layer params carry a leading
period-count dim which is never sharded.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    """Axes the batch is sharded over (pod included when present)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape.get(a, 1)     # absent axes don't shard
    return n


def _div(mesh: Mesh, dim: int, axes) -> Any:
    """``axes`` if ``dim`` divides evenly over them, else None (replicate).

    Singleton axis tuples collapse to the bare name — identical meaning to
    GSPMD, but keeps specs comparable to hand-written ``P("data", ...)``."""
    if dim % axis_size(mesh, axes) != 0:
        return None
    if isinstance(axes, tuple) and len(axes) == 1:
        return axes[0]
    return axes


def _path_names(path) -> tuple[str, ...]:
    names = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            names.append(str(k.key))
        elif isinstance(k, jax.tree_util.GetAttrKey):  # pragma: no cover
            names.append(k.name)
    return tuple(names)


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------

def _param_spec(names: tuple[str, ...], shape: tuple[int, ...],
                mesh: Mesh, cfg) -> P:
    """PartitionSpec for one parameter leaf.

    ``names``: pytree path, e.g. ('layers', 'pos0', 'attn', 'wq', 'w').
    Stacked leaves have a leading period dim (never sharded) — detected by
    the 'layers'/'enc_layers' prefix.
    """
    fsdp = "data"           # FSDP axis: params replicated across pods
    tp = "model"
    stacked = names[0] in ("layers", "enc_layers")
    lead: tuple = (None,) if stacked else ()
    body = shape[1:] if stacked else shape
    leaf = names[-1]
    parent = names[-2] if len(names) >= 2 else ""
    grand = names[-3] if len(names) >= 3 else ""

    def spec(*ax):
        return P(*lead, *ax)

    # ---- embeddings / head -------------------------------------------------
    if names[0] == "embed":
        return P(_div(mesh, shape[0], tp), None)            # (V, D)
    if names[0] == "lm_head":
        if leaf == "w":
            return P(_div(mesh, shape[0], fsdp), _div(mesh, shape[1], tp))
        return P(_div(mesh, shape[0], tp))                  # bias (V,)

    # ---- norms & scalars ---------------------------------------------------
    if parent in ("ln1", "ln2", "lnx", "norm", "head_norm", "final_norm",
                  "enc_norm") or names[-1] in ("xgate", "lam", "conv_b"):
        return spec(*([None] * len(body)))
    if leaf == "conv":                                       # (K, W) depthwise
        return spec(None, _div(mesh, body[-1], tp))

    # ---- MoE ----------------------------------------------------------------
    if grand == "moe" or parent == "moe":
        if parent == "router" or grand == "router":
            return spec(*([None] * len(body)))
        if leaf in ("w1", "wg", "w2") and len(body) == 3:    # (E, D, F)/(E, F, D)
            e = body[0]
            if e % axis_size(mesh, tp) == 0:                 # expert parallel
                return spec(tp, _div(mesh, body[1], fsdp), None)
            # TP inside each expert: shard d_ff (F); FSDP on d_model (D)
            if leaf == "w2":                                 # (E, F, D)
                return spec(None, tp, _div(mesh, body[2], fsdp))
            return spec(None, _div(mesh, body[1], fsdp), tp)

    # ---- generic 2-D matrices ----------------------------------------------
    if leaf == "w" and len(body) == 2:
        d_in, d_out = body
        # contraction-side matrices (wo, w2, down, out): TP on input dim
        if parent in ("wo", "w2", "down", "out"):
            return spec(_div(mesh, d_in, tp), _div(mesh, d_out, fsdp))
        return spec(_div(mesh, d_in, fsdp), _div(mesh, d_out, tp))
    if leaf == "w" and len(body) == 3:                       # blockdiag (H,dh,dh)
        return spec(None, None, _div(mesh, body[-1], tp))
    if leaf == "b":
        return spec(*([None] * (len(body) - 1)), _div(mesh, body[-1], tp))

    # fallback: replicate
    return spec(*([None] * len(body)))


def param_pspecs(params_shape: Params, mesh: Mesh, cfg) -> Params:
    """PartitionSpec pytree matching ``params_shape`` (ShapeDtypeStructs)."""

    def one(path, leaf):
        return _param_spec(_path_names(path), tuple(leaf.shape), mesh, cfg)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def param_shardings(params_shape: Params, mesh: Mesh, cfg) -> Params:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_pspecs(params_shape, mesh, cfg))


# ---------------------------------------------------------------------------
# activation policy (plugged into distributed.act_sharding.use_policy)
# ---------------------------------------------------------------------------

def make_activation_policy(mesh: Mesh, cfg):
    """Maps semantic activation kinds to sharding constraints."""
    dp = dp_axes(mesh)
    tp = "model"

    def policy(x: jax.Array, kind: str) -> jax.Array:
        sh = x.shape
        if kind == "residual":              # (B, S, D)
            spec = P(_div(mesh, sh[0], dp), None, None)
        elif kind == "ffn_hidden":          # (B, S, F)
            spec = P(_div(mesh, sh[0], dp), None, _div(mesh, sh[2], tp))
        elif kind == "logits":              # (B, S, V)
            spec = P(_div(mesh, sh[0], dp), None, _div(mesh, sh[2], tp))
        elif kind in ("heads_q", "heads_kv"):   # (B, H, S, Dh)
            spec = P(_div(mesh, sh[0], dp), _div(mesh, sh[1], tp), None, None)
        elif kind == "kv_cache":            # (B, S, Hk, Dh): seq over model
            spec = P(_div(mesh, sh[0], dp), _div(mesh, sh[1], tp), None, None)
        elif kind == "moe_buf":             # (E, C, D)
            spec = P(_div(mesh, sh[0], tp), _div(mesh, sh[1], dp), None)
        elif kind == "moe_hidden":          # (E, C, F)
            e_sharded = sh[0] % axis_size(mesh, tp) == 0
            spec = P(_div(mesh, sh[0], tp), _div(mesh, sh[1], dp),
                     None if e_sharded else _div(mesh, sh[2], tp))
        elif kind == "moe_gbuf":            # (G, E, C, D): dispatch buffer
            # G over dp ONLY — the scatter/gather stays shard-local; the
            # expert einsum consumes it against tp-sharded expert weights
            # with no resharding (each device computes its E-shard).
            spec = P(_div(mesh, sh[0], dp), None, None, None)
        elif kind == "moe_ghidden":         # (G, E, C, F)
            e_sharded = sh[1] % axis_size(mesh, tp) == 0
            spec = P(_div(mesh, sh[0], dp), _div(mesh, sh[1], tp), None,
                     None if e_sharded else _div(mesh, sh[3], tp))
        elif kind == "moe_gout":            # (G, E, C, D): expert outputs
            # gathered across the tp expert shards exactly once, here
            spec = P(_div(mesh, sh[0], dp), None, None, None)
        elif kind == "rec_state":           # (B, W)
            spec = P(_div(mesh, sh[0], dp), _div(mesh, sh[1], tp))
        else:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return policy


# ---------------------------------------------------------------------------
# input / cache shardings
# ---------------------------------------------------------------------------

def batch_pspecs(batch_shape: dict, mesh: Mesh) -> dict:
    """tokens (B, S) and stub-frontend embeddings shard batch over dp."""
    dp = dp_axes(mesh)
    out = {}
    for k, v in batch_shape.items():
        spec = [None] * len(v.shape)
        spec[0] = _div(mesh, v.shape[0], dp)
        out[k] = P(*spec)
    return out


def cache_pspecs(cache_shape: Params, mesh: Mesh, cfg) -> Params:
    """Decode-state shardings.

    KV caches (stacked: (L, B, S, Hk, Dh)) shard batch over dp and the
    *sequence* dim over ``model`` — kv_heads (1..16) mostly cannot shard
    16-way, sequence always can (32 k / 512 k cells).  Recurrent states
    shard their feature dim over ``model``.
    """
    dp = dp_axes(mesh)
    tp = "model"

    def one(path, leaf):
        names = _path_names(path)
        sh = leaf.shape
        stacked = names[0] == "layers"
        lead: tuple = (None,) if stacked else ()
        body = sh[1:] if stacked else sh
        leafname = names[-1]
        if leafname in ("k", "v") and len(body) == 4:      # (B, S, Hk, Dh)
            return P(*lead, _div(mesh, body[0], dp), _div(mesh, body[1], tp),
                     None, None)
        if leafname == "C" and len(body) == 4:             # (B, H, Dh, Dh)
            return P(*lead, _div(mesh, body[0], dp), None, None,
                     _div(mesh, body[3], tp))
        if leafname in ("n",) and len(body) == 3:          # (B, H, Dh)
            return P(*lead, _div(mesh, body[0], dp), None,
                     _div(mesh, body[2], tp))
        if leafname == "conv" and len(body) == 3:          # (B, K-1, W)
            return P(*lead, _div(mesh, body[0], dp), None,
                     _div(mesh, body[2], tp))
        if len(body) == 2:                                 # (B, W) rec/slstm
            return P(*lead, _div(mesh, body[0], dp),
                     _div(mesh, body[1], tp))
        if len(body) == 1:                                 # (B,) scalars/m
            return P(*lead, _div(mesh, body[0], dp))
        return P(*lead, *([None] * len(body)))

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def to_shardings(pspecs, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), pspecs,
        is_leaf=lambda x: isinstance(x, P))
