"""Gradient compression for the data-parallel all-reduce.

int8 uniform quantization with **error feedback** (Seide et al. '14,
Karimireddy et al. '19): each step all-reduces ``Q(g + e)`` and carries the
quantization residual ``e`` forward, which restores convergence to the
uncompressed trajectory (tested: tests/test_compression.py).

Where it applies on the production mesh: the ``pod`` axis — parameters are
pod-replicated (DESIGN.md §6), so the cross-pod gradient all-reduce is pure
DP traffic at the slowest link of the system.  int8 cuts those bytes 4×
(vs fp32 master grads) / 2× (vs bf16).

Two entry points:

* ``quantize``/``dequantize`` + ``ef_compress`` — pure functions usable
  inside any step (the error-feedback state lives in the train state).
* ``compressed_psum`` — shard_map building block doing the actual int8
  ``lax.psum`` over a named axis, for explicit-collective steps.  Wrap
  it with ``repro.distributed.shard_map`` (the version shim — the
  pinned jax 0.4.37 has no public ``jax.shard_map``).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Params = dict[str, Any]

_Q = 127.0


def quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8; returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / _Q, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -_Q, _Q
                 ).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress(grads: Params, error: Params) -> tuple[Params, Params]:
    """Error-feedback compression of a gradient pytree.

    Returns (decompressed grads to apply, new error state).  The round trip
    models exactly what the wire sees; the residual is carried so no signal
    is lost across steps.
    """

    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, s = quantize(target)
        dq = dequantize(q, s)
        return dq.astype(g.dtype), target - dq

    flat = jax.tree.map(one, grads, error)
    new_g = jax.tree.map(lambda t: t[0], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_e = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_g, new_e


def init_error(params: Params) -> Params:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8 all-reduce over ``axis_name`` (use inside
    ``repro.distributed.shard_map``).

    Quantizes locally, sums int32 (no overflow up to ~2^24 shards), then
    averages the per-shard dequantized values.  Scales are all-gathered
    implicitly via a second (tiny) psum of scale-weighted contributions.
    """
    n = jax.lax.psum(1, axis_name)
    q, s = quantize(x)
    # each shard contributes dequantized int8 -> exact sum of quantized vals
    summed = jax.lax.psum(dequantize(q, s), axis_name)
    return (summed / n).astype(x.dtype)


def compressed_psum_tree(grads: Params, axis_name: str,
                         error: Params) -> tuple[Params, Params]:
    """Error-feedback int8 psum over a gradient pytree (shard_map body)."""

    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, s = quantize(target)
        local_dq = dequantize(q, s)
        n = jax.lax.psum(1, axis_name)
        avg = jax.lax.psum(local_dq, axis_name) / n
        return avg.astype(g.dtype), target - local_dq

    pairs = jax.tree.map(one, grads, error)
    new_g = jax.tree.map(lambda t: t[0], pairs,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_e = jax.tree.map(lambda t: t[1], pairs,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_g, new_e
