"""Capture a tensor-parallel-sharded transformer block as an OpGraph
with first-class collectives — the bridge from the runtime sharding
rules (``distributed/sharding.py``) into the FTL planning stack.

``capture_block(cfg, m=..., mesh_size=N)`` lowers the per-chip slice of
one block under the repo's Megatron-style tensor-parallel layout and
inserts :class:`~repro.core.ftl.ir.CollectiveNode`\\s where the layout
requires communication, so the fusion-partition DP prices "fuse and
overlap the all-reduce with this segment's memory traffic" against "cut
here and materialize first" on the real max-over-ports transfer model.

The shard layout mirrors ``sharding._param_spec`` /
``sharding.make_activation_policy`` exactly (this module stays jax-free
so the planner needs no devices):

* attention heads shard over the mesh when divisible (``heads_q`` /
  ``heads_kv`` activation rule): wq/wk/wv are column-parallel, the
  per-head core runs ``n_heads/N`` heads, and the row-parallel ``wo``
  leaves a partial sum → **all_reduce** on ``attn_out``;
* the MLP hidden ``d_ff`` shards when divisible (``ffn_hidden`` rule):
  w1/wg column-parallel, the row-parallel ``w2`` leaves a partial sum
  → **all_reduce** on ``mlp_y``;
* everything else (token dim, ``d_model``) is replicated, matching
  ``_div``'s shard-only-when-divisible rule.

``mesh_size=1`` (or a config nothing divides) returns the plain
``graph.block_graph`` capture bit-identically — single-chip plans are
untouched.

``strip_collectives`` / ``plan_collective_blind`` give the baseline the
benchmarks gate against: plan the same per-chip graph with the
collectives invisible, then re-price the chosen cuts on the full graph
— the cost of partitioning as if communication were free.
"""
from __future__ import annotations

import dataclasses

from repro.core import hw as hwlib
from repro.core.ftl import graph as graphlib
from repro.core.ftl import ir, partition
from repro.core.ftl.graph import OpGraph


@dataclasses.dataclass(frozen=True)
class BlockShardSpec:
    """Which block dims a ``mesh_size``-way tensor-parallel layout
    shards for a given config — the divisibility decisions of
    ``sharding._div`` restated for the planner."""

    mesh_size: int
    heads: bool          # q/kv heads shard over the mesh
    d_ff: bool           # MLP hidden shards over the mesh

    @property
    def any(self) -> bool:
        return self.mesh_size > 1 and (self.heads or self.d_ff)


def shard_spec(cfg, mesh_size: int) -> BlockShardSpec:
    """The tensor-parallel shard decisions for ``cfg`` at ``mesh_size``:
    a dim shards iff the mesh divides it (``sharding._div``), heads only
    when *both* query and kv head counts divide (GQA groups must not be
    split across chips)."""
    if mesh_size < 1:
        raise ValueError(f"mesh_size must be >= 1, got {mesh_size}")
    has_attn = cfg.block_kind(0) in ("attn", "cross", "local")
    d_ff = cfg.moe_d_ff if cfg.is_moe else cfg.d_ff
    heads = bool(
        has_attn and mesh_size > 1
        and cfg.n_heads % mesh_size == 0
        and cfg.n_kv_heads % mesh_size == 0
    )
    ff = bool(mesh_size > 1 and d_ff > 0 and d_ff % mesh_size == 0)
    return BlockShardSpec(mesh_size=mesh_size, heads=heads, d_ff=ff)


def _insert_collective_after(
    g: OpGraph, op_name: str, comm: str, mesh_size: int
) -> OpGraph:
    """Splice ``comm(output of op_name)`` into the chain right after the
    named op, rewiring every later consumer (inputs *and* dim links) to
    the collective's output tensor."""
    idx = next(i for i, op in enumerate(g.ops) if op.name == op_name)
    t_in = g.ops[idx].output
    t_out = dataclasses.replace(t_in, name=t_in.name + "_red")
    node = ir.collective(
        f"comm.{op_name}", comm, t_in, t_out, mesh_size)
    ops = list(g.ops)
    reps = list(g.repeats)
    ops.insert(idx + 1, node)
    reps.insert(idx + 1, reps[idx])
    for j in range(idx + 2, len(ops)):
        op = ops[j]
        if not any(t.name == t_in.name for t in op.inputs):
            continue
        ops[j] = dataclasses.replace(
            op,
            inputs=tuple(t_out if t.name == t_in.name else t
                         for t in op.inputs),
            links=tuple(
                dataclasses.replace(l, input_tensor=t_out.name)
                if l.input_tensor == t_in.name else l
                for l in op.links),
        )
    # barriers re-derive from the repeats in __post_init__; the stale
    # pre-splice indices must not survive the replace
    return dataclasses.replace(
        g, ops=tuple(ops), repeats=tuple(reps), barriers=frozenset())


def capture_block(
    cfg,
    *,
    m: int,
    mesh_size: int = 1,
    dtype: str | None = None,
    residual: bool = True,
    name: str | None = None,
) -> OpGraph:
    """Lower the per-chip slice of one block of ``cfg`` under a
    ``mesh_size``-way tensor-parallel layout, collectives included.

    The returned graph's dims are the *local* shard sizes (``n_heads/N``
    heads, ``d_ff/N`` hidden) — exactly the tensors one chip touches —
    and the two row-parallel partial sums carry an ``all_reduce``
    CollectiveNode whose ring-formula wire bytes the cost model prices
    on the target's interconnect port.  ``mesh_size=1`` returns the
    plain single-chip ``block_graph`` unchanged.
    """
    spec = shard_spec(cfg, mesh_size)
    if not spec.any:
        return graphlib.block_graph(
            cfg, m=m, dtype=dtype, residual=residual, name=name)
    # pin head_dim before shrinking n_heads: resolved_head_dim defaults
    # to d_model // n_heads and must not double under the shard
    repl: dict = {"head_dim": cfg.resolved_head_dim}
    if spec.heads:
        repl["n_heads"] = cfg.n_heads // mesh_size
        repl["n_kv_heads"] = cfg.n_kv_heads // mesh_size
    if spec.d_ff:
        if cfg.is_moe:
            repl["moe_d_ff"] = cfg.moe_d_ff // mesh_size
        else:
            repl["d_ff"] = cfg.d_ff // mesh_size
    local = dataclasses.replace(cfg, **repl)
    g = graphlib.block_graph(
        local, m=m, dtype=dtype, residual=residual,
        name=name or f"mesh{mesh_size}.block.{cfg.name}")
    if spec.heads:
        g = _insert_collective_after(g, "proj.wo", "all_reduce", mesh_size)
    if spec.d_ff and any(op.name == "mlp.gemm2" for op in g.ops):
        g = _insert_collective_after(g, "mlp.gemm2", "all_reduce", mesh_size)
    g.validate()
    return g


# ---------------------------------------------------------------------------
# collective-blind baseline
# ---------------------------------------------------------------------------

def strip_collectives(g: OpGraph) -> OpGraph:
    """``g`` with every CollectiveNode removed and its consumers rewired
    back to the collective's operand — the chain a collective-blind
    partitioner sees."""
    rename: dict[str, ir.TensorSpec] = {}
    ops: list[ir.OpNode] = []
    reps: list[int] = []
    for op, r in zip(g.ops, g.repeats):
        if isinstance(op, ir.CollectiveNode):
            src = op.inputs[0]
            rename[op.output.name] = rename.get(src.name, src)
            continue
        if any(t.name in rename for t in op.inputs):
            op = dataclasses.replace(
                op,
                inputs=tuple(rename.get(t.name, t) for t in op.inputs),
                links=tuple(
                    dataclasses.replace(
                        l, input_tensor=rename[l.input_tensor].name)
                    if l.input_tensor in rename else l
                    for l in op.links),
            )
        ops.append(op)
        reps.append(r)
    if len(ops) == len(g.ops):
        return g
    return dataclasses.replace(
        g, name=g.name + ".blind", ops=tuple(ops), repeats=tuple(reps),
        barriers=frozenset())


def map_cuts(full: OpGraph, stripped: OpGraph,
             cuts: tuple[int, ...]) -> tuple[int, ...]:
    """Translate cut positions of the collective-stripped chain onto the
    full chain.  A cut before stripped op ``p`` lands before the same op
    in the full chain, so any collective sitting between two stripped
    ops stays attached to the *preceding* segment (where its producer
    ran)."""
    full_idx = [i for i, op in enumerate(full.ops)
                if not isinstance(op, ir.CollectiveNode)]
    if len(full_idx) != stripped.n_ops:
        raise ValueError(
            f"stripped graph {stripped.name} does not match {full.name}")
    return tuple(full_idx[c] for c in cuts)


def plan_collective_blind(
    graph: OpGraph,
    *,
    target: hwlib.Target | None = None,
) -> partition.ChainPlan:
    """Partition ``graph`` as if its collectives were free — plan the
    stripped chain, then re-price the chosen cuts on the real graph.
    This is the baseline the mesh benchmarks gate the collective-aware
    DP against: same machine, same collectives, only the cut decisions
    made blind."""
    target = target if target is not None else hwlib.default_target()
    stripped = strip_collectives(graph)
    if stripped is graph:
        return partition.plan_chain(graph, target=target)
    blind = partition.plan_chain(stripped, target=target)
    cuts = map_cuts(graph, stripped, blind.cuts())
    return partition.plan_fixed(graph, cuts, target=target)
