"""Exporters: merged Perfetto timeline, Prometheus text, JSON snapshot.

``merged_chrome_trace`` is the headline view: the **live** runtime spans
(from :mod:`repro.obs.spans`) and the **modeled/measured** tracks (from
``sim.to_chrome_trace``) on one Chrome-tracing timeline — pid 0 is the
simulated plan, pid 1 the live process — so "is the executed plan
honoring the modeled roofline" is a single Perfetto screenful.

``prometheus_text`` renders the metrics registry in the text exposition
format (``# HELP``/``# TYPE`` + samples), suitable for a file-based
scrape or `curl`-style inspection; ``metrics_snapshot`` is the same data
as plain JSON.
"""
from __future__ import annotations

import json
from typing import Sequence

from . import metrics as _metrics
from . import spans as _spans

__all__ = [
    "merged_chrome_trace",
    "write_merged_trace",
    "prometheus_text",
    "write_prometheus",
    "metrics_snapshot",
]

_LIVE_PID = 1


def _live_events(rows: Sequence[_spans.Span]) -> list[dict]:
    if not rows:
        return []
    t_base = min(s.t0 for s in rows)
    tids: dict[int, int] = {}
    events: list[dict] = []
    for s in rows:
        tid = tids.setdefault(s.tid, len(tids))
        events.append({
            "name": s.name, "ph": "X", "pid": _LIVE_PID, "tid": tid,
            "ts": 1e6 * (s.t0 - t_base),
            "dur": 1e6 * s.duration_s,
            "cat": s.cat,
            "args": {"depth": s.depth},
        })
    meta = [{"name": "process_name", "ph": "M", "pid": _LIVE_PID,
             "args": {"name": "live runtime"}}]
    meta += [{"name": "thread_name", "ph": "M", "pid": _LIVE_PID,
              "tid": tid, "args": {"name": f"thread:{raw}"}}
             for raw, tid in sorted(tids.items(), key=lambda kv: kv[1])]
    return meta + events


def merged_chrome_trace(*, spans=None, chain=None, measured=None,
                        registry: _metrics.MetricsRegistry | None = None,
                        ) -> dict:
    """Chrome-tracing JSON with up to three sources merged:

    * ``chain`` (a ``ChainPlan``/``BlockPlan``/``Schedule``) → the
      simulated timeline on pid 0, with optional ``measured`` spans as a
      second track (exactly ``sim.to_chrome_trace``);
    * ``spans`` → live runtime spans on pid 1 (an explicit list of
      :class:`~repro.obs.spans.Span`, a :class:`SpanRecorder`, or
      ``None`` to snapshot the default recorder);
    * ``registry`` → a metrics snapshot embedded under
      ``otherData.metrics`` (defaults to the global registry).
    """
    events: list[dict] = []
    if chain is not None:
        from repro import sim  # lazy: pulls jax via the DES imports

        events += sim.to_chrome_trace(chain, measured=measured,
                                      pid=0)["traceEvents"]
    if spans is None:
        rec = _spans.recorder()
        rows = rec.snapshot() if rec is not None else []
    elif isinstance(spans, _spans.SpanRecorder):
        rows = spans.snapshot()
    else:
        rows = list(spans)
    events += _live_events(rows)
    reg = registry if registry is not None else _metrics.REGISTRY
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {"metrics": reg.collect()},
    }


def write_merged_trace(path, *, spans=None, chain=None, measured=None,
                       registry: _metrics.MetricsRegistry | None = None,
                       ) -> None:
    with open(path, "w") as f:
        json.dump(merged_chrome_trace(spans=spans, chain=chain,
                                      measured=measured,
                                      registry=registry), f)


def _fmt_labels(lbl: dict) -> str:
    if not lbl:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in sorted(lbl.items()))
    return "{" + inner + "}"


def _fmt_value(v: float) -> str:
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(v)


def prometheus_text(registry: _metrics.MetricsRegistry | None = None) -> str:
    """Prometheus text exposition (version 0.0.4) of the registry."""
    reg = registry if registry is not None else _metrics.REGISTRY
    lines = []
    for name, m in reg.collect().items():
        if m["help"]:
            lines.append(f"# HELP {name} {m['help']}")
        lines.append(f"# TYPE {name} {m['kind']}")
        for lbl, v in m["samples"]:
            lbl = dict(lbl)
            if "__sum__" in lbl:
                lbl.pop("__sum__")
                lines.append(f"{name}_sum{_fmt_labels(lbl)} {_fmt_value(v)}")
            elif "__count__" in lbl:
                lbl.pop("__count__")
                lines.append(
                    f"{name}_count{_fmt_labels(lbl)} {_fmt_value(v)}")
            elif "le" in lbl:
                lines.append(
                    f"{name}_bucket{_fmt_labels(lbl)} {_fmt_value(v)}")
            else:
                lines.append(f"{name}{_fmt_labels(lbl)} {_fmt_value(v)}")
    return "\n".join(lines) + "\n"


def write_prometheus(path,
                     registry: _metrics.MetricsRegistry | None = None,
                     ) -> None:
    with open(path, "w") as f:
        f.write(prometheus_text(registry))


def metrics_snapshot(registry: _metrics.MetricsRegistry | None = None,
                     ) -> dict:
    """JSON-ready snapshot of every metric (collectors included)."""
    reg = registry if registry is not None else _metrics.REGISTRY
    return reg.collect()
