"""Named counters / gauges / histograms on a process-global registry.

Prometheus-shaped but dependency-free: a metric has a name, a help
string, and optional label names; ``labels(**kv)`` returns a child
whose ``inc``/``set``/``observe`` is a couple of float ops (hot call
sites should cache the child).  Unlike spans, metrics are always on —
an increment is too cheap to gate.

``register_collector`` hangs a callback that runs at ``collect()``
time, for surfaces that already keep their own counters (the PR-8
plan-cache ledger, ``ServeEngine.plan_report()``): the existing
bookkeeping stays canonical and is *re-expressed* as gauges on scrape
instead of being double-counted on the hot path.
"""
from __future__ import annotations

import math
import threading
from typing import Callable, Sequence

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "REGISTRY",
    "counter",
    "gauge",
    "histogram",
    "register_collector",
    "collect",
    "reset",
]

_DEFAULT_BUCKETS = (
    1e-5, 1e-4, 1e-3, 1e-2, 0.1, 0.5, 1.0, 5.0, 10.0, math.inf)


def _check_labels(labelnames: Sequence[str], kv: dict) -> tuple:
    if set(kv) != set(labelnames):
        raise ValueError(
            f"labels {sorted(kv)} do not match declared {sorted(labelnames)}")
    return tuple(str(kv[k]) for k in labelnames)


class _Metric:
    kind = "untyped"

    def __init__(self, name: str, help: str, labelnames: Sequence[str] = ()):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._children: dict[tuple, _Metric] = {}
        self._lock = threading.Lock()

    def labels(self, **kv) -> "_Metric":
        key = _check_labels(self.labelnames, kv)
        child = self._children.get(key)
        if child is None:
            with self._lock:
                child = self._children.setdefault(
                    key, type(self)(self.name, self.help))
        return child

    def samples(self) -> list[tuple[dict, float]]:
        raise NotImplementedError

    def _labelled_samples(self) -> list[tuple[dict, float]]:
        if not self.labelnames:
            return self.samples()
        out = []
        for key, child in sorted(self._children.items()):
            lbl = dict(zip(self.labelnames, key))
            out.extend((dict(lbl, **extra), v)
                       for extra, v in child.samples())
        return out

    def _reset(self) -> None:
        self._children.clear()


class Counter(_Metric):
    kind = "counter"

    def __init__(self, name: str, help: str = "",
                 labelnames: Sequence[str] = ()):
        super().__init__(name, help, labelnames)
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError("counters only go up")
        self.value += amount

    def samples(self):
        return [({}, self.value)]

    def _reset(self) -> None:
        super()._reset()
        self.value = 0.0


class Gauge(_Metric):
    kind = "gauge"

    def __init__(self, name: str, help: str = "",
                 labelnames: Sequence[str] = ()):
        super().__init__(name, help, labelnames)
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount

    def dec(self, amount: float = 1.0) -> None:
        self.value -= amount

    def samples(self):
        return [({}, self.value)]

    def _reset(self) -> None:
        super()._reset()
        self.value = 0.0


class Histogram(_Metric):
    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 labelnames: Sequence[str] = (),
                 buckets: Sequence[float] = _DEFAULT_BUCKETS):
        super().__init__(name, help, labelnames)
        bs = tuple(sorted(buckets))
        if not bs or bs[-1] != math.inf:
            bs = bs + (math.inf,)
        self.buckets = bs
        self._counts = [0] * len(bs)
        self.sum = 0.0
        self.count = 0

    def labels(self, **kv) -> "Histogram":
        key = _check_labels(self.labelnames, kv)
        child = self._children.get(key)
        if child is None:
            with self._lock:
                child = self._children.setdefault(
                    key, Histogram(self.name, self.help,
                                   buckets=self.buckets))
        return child  # type: ignore[return-value]

    def observe(self, value: float) -> None:
        self.sum += value
        self.count += 1
        for i, b in enumerate(self.buckets):
            if value <= b:
                self._counts[i] += 1
                break

    def samples(self):
        out, cum = [], 0
        for b, c in zip(self.buckets, self._counts):
            cum += c
            le = "+Inf" if b == math.inf else repr(b)
            out.append(({"le": le}, float(cum)))
        out.append(({"__sum__": ""}, self.sum))
        out.append(({"__count__": ""}, float(self.count)))
        return out

    def _reset(self) -> None:
        super()._reset()
        self._counts = [0] * len(self.buckets)
        self.sum = 0.0
        self.count = 0


class MetricsRegistry:
    """Get-or-create registry; re-registering with a different type or
    label set is an error (the Prometheus exposition would be garbage)."""

    def __init__(self) -> None:
        self._metrics: dict[str, _Metric] = {}
        self._collectors: list[Callable[["MetricsRegistry"], None]] = []
        self._lock = threading.Lock()

    def _get(self, cls, name: str, help: str, labelnames: Sequence[str],
             **kw) -> _Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, help, labelnames, **kw)
                self._metrics[name] = m
                return m
        if type(m) is not cls or m.labelnames != tuple(labelnames):
            raise ValueError(
                f"metric {name!r} already registered as {m.kind} with "
                f"labels {m.labelnames}")
        return m

    def counter(self, name: str, help: str = "",
                labelnames: Sequence[str] = ()) -> Counter:
        return self._get(Counter, name, help, labelnames)  # type: ignore

    def gauge(self, name: str, help: str = "",
              labelnames: Sequence[str] = ()) -> Gauge:
        return self._get(Gauge, name, help, labelnames)  # type: ignore

    def histogram(self, name: str, help: str = "",
                  labelnames: Sequence[str] = (),
                  buckets: Sequence[float] = _DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help, labelnames,  # type: ignore
                         buckets=buckets)

    def register_collector(
            self, fn: Callable[["MetricsRegistry"], None]) -> None:
        """Run ``fn(registry)`` at every ``collect()`` — pull-style
        metrics for surfaces that keep their own counters."""
        self._collectors.append(fn)

    def run_collectors(self) -> None:
        for fn in list(self._collectors):
            fn(self)

    def collect(self) -> dict[str, dict]:
        """{name: {kind, help, labelnames, samples}} snapshot."""
        self.run_collectors()
        out = {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            out[name] = {
                "kind": m.kind,
                "help": m.help,
                "labelnames": list(m.labelnames),
                "samples": [(lbl, v) for lbl, v in m._labelled_samples()],
            }
        return out

    def metrics(self) -> dict[str, _Metric]:
        return dict(self._metrics)

    def reset(self) -> None:
        """Zero every metric value (registrations and collectors stay)."""
        for m in self._metrics.values():
            m._reset()

    def clear(self) -> None:
        """Drop registrations *and* collectors (tests only)."""
        self._metrics.clear()
        self._collectors.clear()


REGISTRY = MetricsRegistry()


def counter(name: str, help: str = "",
            labelnames: Sequence[str] = ()) -> Counter:
    return REGISTRY.counter(name, help, labelnames)


def gauge(name: str, help: str = "",
          labelnames: Sequence[str] = ()) -> Gauge:
    return REGISTRY.gauge(name, help, labelnames)


def histogram(name: str, help: str = "",
              labelnames: Sequence[str] = (),
              buckets: Sequence[float] = _DEFAULT_BUCKETS) -> Histogram:
    return REGISTRY.histogram(name, help, labelnames, buckets)


def register_collector(fn: Callable[[MetricsRegistry], None]) -> None:
    REGISTRY.register_collector(fn)


def collect() -> dict[str, dict]:
    return REGISTRY.collect()


def reset() -> None:
    REGISTRY.reset()
