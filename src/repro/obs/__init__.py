"""Unified runtime telemetry: spans, metrics, exporters, drift.

The observability layer the ISSUE-10 tentpole asks for.  Four pieces:

* :mod:`repro.obs.spans` — monotonic-clock span recorder on a
  preallocated ring buffer (nesting + thread id, no allocation on the
  hot path).  Off by default; ``obs.enable()`` turns recording on.
* :mod:`repro.obs.metrics` — named counters / gauges / histograms on a
  process-global registry, always on, with pull-style collectors for
  surfaces that keep their own counters (the plan-cache ledger,
  ``ServeEngine.plan_report()``).
* :mod:`repro.obs.export` — the merged Perfetto timeline (live spans on
  pid 1 next to ``sim.to_chrome_trace``'s modeled/measured tracks on
  pid 0), Prometheus text exposition, JSON snapshot.
* :mod:`repro.obs.drift` — online modeled-vs-measured drift: executed
  segments become ``calib.Measurement`` rows with a rolling geomean
  ratio per (segment, target), flagged when it leaves the PR-9 band.

Importing this package never pulls jax — instrumented planner modules
stay importable in jax-free tooling.
"""
from . import drift, export, metrics, spans
from .drift import DEFAULT_BAND, DriftMonitor
from .export import (merged_chrome_trace, metrics_snapshot,
                     prometheus_text, write_merged_trace,
                     write_prometheus)
from .metrics import (REGISTRY, Counter, Gauge, Histogram,
                      MetricsRegistry, collect, counter, gauge,
                      histogram, register_collector)
from .spans import (Span, SpanRecorder, begin, disable, enable, enabled,
                    end, recorder, span)

__all__ = [
    "spans", "metrics", "export", "drift",
    # spans
    "Span", "SpanRecorder", "enable", "disable", "enabled", "recorder",
    "begin", "end", "span",
    # metrics
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "REGISTRY",
    "counter", "gauge", "histogram", "register_collector", "collect",
    # export
    "merged_chrome_trace", "write_merged_trace", "prometheus_text",
    "write_prometheus", "metrics_snapshot",
    # drift
    "DriftMonitor", "DEFAULT_BAND",
]
