"""Online modeled-vs-measured drift monitor.

PR 9's calibration loop established the metric that matters: the
geometric-mean modeled/measured ratio, gated inside a band
(``bench_calibrate.BAND`` = (0.3, 10/3)).  That check runs offline in
CI.  This monitor runs the *same* arithmetic continuously inside a live
process: executed-segment wall-clock observations become
``calib.Measurement`` rows, each is re-priced on the monitor's target
through the one shared roofline formula, and a rolling window of
log-ratios per (name, target) keeps the current geomean — flagged
through ``obs.metrics`` the moment it leaves the band.

Exactness contract (gated in ``benchmarks/bench_obs.py``): feeding the
monitor a set of observations and then computing the offline geomean
over ``monitor.measurements()`` with the PR-9 formula
(``exp(mean(log(modeled/measured)))``) reproduces
``monitor.geomean_ratio()`` bit-for-bit — the online view is the CI
gate, not an approximation of it.
"""
from __future__ import annotations

import math
from collections import deque
from typing import TYPE_CHECKING

from repro.calib.measure import (Measurement, features_from_chain,
                                 modeled_measurement_s)

from . import metrics as _metrics

if TYPE_CHECKING:  # pragma: no cover
    from repro.core import hw as hwlib

__all__ = ["DEFAULT_BAND", "DriftMonitor"]

# the PR-9 drift band (bench_calibrate.BAND): a model off by more than
# ~3x either way is mispricing plans outright.
DEFAULT_BAND = (0.3, 10 / 3)


class DriftMonitor:
    """Rolling modeled-vs-measured drift per (name, target).

    ``target`` is the machine every observation is priced on (use a
    ``Target.calibrated(...)`` fit for a meaningful band check — presets
    are *rankings*, not wall-clock predictors).  ``window`` bounds the
    per-name rolling deque; ``keep`` bounds the retained raw
    ``Measurement`` rows (for offline re-fitting / the exactness gate).
    """

    def __init__(self, target: "hwlib.Target | None" = None, *,
                 band: tuple[float, float] = DEFAULT_BAND,
                 window: int = 64, keep: int = 256,
                 registry: _metrics.MetricsRegistry | None = None):
        if target is None:
            from repro.core import hw as hwlib

            target = hwlib.default_target()
        self.target = target
        self.band = (float(band[0]), float(band[1]))
        self.window = window
        self._logs: dict[str, deque[float]] = {}
        self._rows: deque[Measurement] = deque(maxlen=keep)
        self.n_observed = 0
        reg = registry if registry is not None else _metrics.REGISTRY
        lbl = ("segment", "target")
        self._g_ratio = reg.gauge(
            "drift_geomean_ratio",
            "rolling geomean modeled/measured ratio", lbl)
        self._g_n = reg.gauge(
            "drift_window_observations",
            "observations in the rolling window", lbl)
        self._c_out = reg.counter(
            "drift_out_of_band_total",
            "observations that pushed a rolling geomean out of band", lbl)

    # -- feeding -----------------------------------------------------------

    def observe_measurement(self, m: Measurement, *,
                            scale: float = 1.0) -> float:
        """Record one observation; returns its modeled/measured ratio.

        ``scale`` multiplies the *modeled* side — pass ``n_layers`` when
        the measured seconds cover a full model pass of a per-block
        plan.
        """
        modeled = scale * modeled_measurement_s(self.target, m)
        ratio = modeled / m.measured_s
        dq = self._logs.get(m.name)
        if dq is None:
            dq = self._logs[m.name] = deque(maxlen=self.window)
        dq.append(math.log(ratio))
        self._rows.append(m)
        self.n_observed += 1
        g = math.exp(sum(dq) / len(dq))
        lbl = self._labels(m.name)
        self._g_ratio.labels(**lbl).set(g)
        self._g_n.labels(**lbl).set(len(dq))
        if not (self.band[0] <= g <= self.band[1]):
            self._c_out.labels(**lbl).inc()
        return ratio

    def observe(self, name: str, measured_s: float, segments, *,
                kind: str = "block", scale: float = 1.0) -> float:
        m = Measurement(name=name, kind=kind, measured_s=measured_s,
                        segments=tuple(segments))
        return self.observe_measurement(m, scale=scale)

    def observe_chain(self, chain, measured_s: float, *, name: str,
                      kind: str = "block", scale: float = 1.0) -> float:
        """Observe a wall-clock run of a planned chain / ``BlockPlan``."""
        return self.observe(name, measured_s, features_from_chain(chain),
                            kind=kind, scale=scale)

    def _labels(self, name: str) -> dict:
        return {"segment": name, "target": self.target.name}

    # -- reading -----------------------------------------------------------

    def geomean_ratio(self, name: str | None = None) -> float | None:
        """Rolling geomean ratio for one name, or pooled over all names
        (every windowed log-ratio weighted equally) when ``name`` is
        None.  ``None`` when nothing has been observed."""
        if name is not None:
            dq = self._logs.get(name)
            if not dq:
                return None
            return math.exp(sum(dq) / len(dq))
        logs = [v for dq in self._logs.values() for v in dq]
        if not logs:
            return None
        return math.exp(sum(logs) / len(logs))

    def in_band(self, name: str | None = None) -> bool | None:
        g = self.geomean_ratio(name)
        if g is None:
            return None
        return self.band[0] <= g <= self.band[1]

    def measurements(self) -> list[Measurement]:
        """Retained raw rows, oldest first — feedable straight into
        ``calib.calibrate`` for an offline re-fit."""
        return list(self._rows)

    def status(self) -> dict:
        """JSON-ready summary (the ``BENCH_obs.json`` drift block)."""
        per = {}
        for name, dq in sorted(self._logs.items()):
            g = math.exp(sum(dq) / len(dq))
            per[name] = {
                "geomean_ratio": g,
                "n_window": len(dq),
                "in_band": self.band[0] <= g <= self.band[1],
            }
        return {
            "target": self.target.name,
            "band": list(self.band),
            "n_observed": self.n_observed,
            "geomean_ratio": self.geomean_ratio(),
            "in_band": self.in_band(),
            "per_segment": per,
        }
