"""Monotonic-clock span recorder on a preallocated ring buffer.

A *span* is one timed region of the runtime (a ``plan_chain`` call, one
decode step, one executed stage).  The recorder is built for hot paths:

* ``begin``/``end`` write into preallocated parallel slot lists — no
  per-span object is allocated while recording (the ``Span`` dataclass
  only materializes at ``drain()``/``snapshot()`` time);
* nesting depth is tracked per thread on a preallocated stack, so spans
  render as a properly nested flame graph in Perfetto;
* the buffer is a fixed-capacity ring: when full, the oldest spans are
  overwritten and counted in ``dropped`` rather than growing memory.

Recording is **off by default**.  ``enable()`` flips a module-level flag
checked by every helper, so an un-instrumented process pays one dict
lookup + one ``if`` per call site.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Iterator

__all__ = [
    "Span",
    "SpanRecorder",
    "enable",
    "disable",
    "enabled",
    "recorder",
    "begin",
    "end",
    "span",
]

_MAX_DEPTH = 64


@dataclass(frozen=True)
class Span:
    """One completed span (materialized only on drain/snapshot)."""

    name: str
    cat: str
    t0: float  # perf_counter seconds
    t1: float
    depth: int
    tid: int

    @property
    def duration_s(self) -> float:
        return self.t1 - self.t0


class _ThreadState(threading.local):
    def __init__(self) -> None:
        # preallocated per-thread begin stack: (name, cat, t0) slots
        self.names: list[str | None] = [None] * _MAX_DEPTH
        self.cats: list[str | None] = [None] * _MAX_DEPTH
        self.t0s: list[float] = [0.0] * _MAX_DEPTH
        self.depth = 0


class SpanRecorder:
    """Fixed-capacity ring buffer of completed spans."""

    def __init__(self, capacity: int = 4096):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._names: list[str | None] = [None] * capacity
        self._cats: list[str | None] = [None] * capacity
        self._t0s = [0.0] * capacity
        self._t1s = [0.0] * capacity
        self._depths = [0] * capacity
        self._tids = [0] * capacity
        self._recorded = 0  # total spans ever committed (monotone)
        self.dropped = 0  # spans overwritten before being drained
        self._lock = threading.Lock()
        self._tls = _ThreadState()

    # -- hot path ----------------------------------------------------------

    def begin(self, name: str, cat: str = "runtime") -> None:
        tls = self._tls
        d = tls.depth
        if d < _MAX_DEPTH:
            tls.names[d] = name
            tls.cats[d] = cat
            tls.t0s[d] = time.perf_counter()
        tls.depth = d + 1

    def end(self) -> None:
        t1 = time.perf_counter()
        tls = self._tls
        d = tls.depth - 1
        if d < 0:  # unmatched end() (e.g. toggled mid-span): ignore
            return
        tls.depth = d
        if d >= _MAX_DEPTH:  # was too deep to record; just unwind
            return
        with self._lock:
            i = self._recorded % self.capacity
            if self._recorded >= self.capacity:
                self.dropped += 1
            self._names[i] = tls.names[d]
            self._cats[i] = tls.cats[d]
            self._t0s[i] = tls.t0s[d]
            self._t1s[i] = t1
            self._depths[i] = d
            self._tids[i] = threading.get_ident()
            self._recorded += 1

    def span(self, name: str, cat: str = "runtime") -> "_SpanCM":
        return _SpanCM(self, name, cat)

    # -- cold path ---------------------------------------------------------

    def __len__(self) -> int:
        return min(self._recorded, self.capacity)

    def _rows(self) -> Iterator[Span]:
        n = min(self._recorded, self.capacity)
        start = self._recorded - n
        for k in range(start, self._recorded):
            i = k % self.capacity
            yield Span(
                name=self._names[i] or "",
                cat=self._cats[i] or "",
                t0=self._t0s[i],
                t1=self._t1s[i],
                depth=self._depths[i],
                tid=self._tids[i],
            )

    def snapshot(self) -> list[Span]:
        """Completed spans, oldest first, without resetting the buffer."""
        with self._lock:
            return list(self._rows())

    def drain(self) -> list[Span]:
        """Return completed spans (oldest first) and reset the buffer.

        ``dropped`` keeps accumulating across drains so overflow is
        visible even if every drain arrives late.
        """
        with self._lock:
            out = list(self._rows())
            self._recorded = 0
            return out


class _SpanCM:
    __slots__ = ("_rec", "_name", "_cat")

    def __init__(self, rec: SpanRecorder, name: str, cat: str):
        self._rec = rec
        self._name = name
        self._cat = cat

    def __enter__(self) -> "_SpanCM":
        self._rec.begin(self._name, self._cat)
        return self

    def __exit__(self, *exc) -> None:
        self._rec.end()


# -- module-level default recorder (disabled until enable()) ---------------

_DEFAULT: SpanRecorder | None = None


def enable(capacity: int | None = None) -> SpanRecorder:
    """Turn on span recording; idempotent unless ``capacity`` changes."""
    global _DEFAULT
    if _DEFAULT is None or (capacity is not None
                            and capacity != _DEFAULT.capacity):
        _DEFAULT = SpanRecorder(capacity or 4096)
    return _DEFAULT


def disable() -> None:
    global _DEFAULT
    _DEFAULT = None


def enabled() -> bool:
    return _DEFAULT is not None


def recorder() -> SpanRecorder | None:
    return _DEFAULT


def begin(name: str, cat: str = "runtime") -> None:
    rec = _DEFAULT
    if rec is not None:
        rec.begin(name, cat)


def end() -> None:
    rec = _DEFAULT
    if rec is not None:
        rec.end()


class _MaybeSpan:
    """Context manager over the *default* recorder; no-op when disabled.

    The recorder is looked up at ``__enter__`` and pinned, so an
    enable/disable flip mid-span cannot unbalance a stack.
    """

    __slots__ = ("_name", "_cat", "_rec")

    def __init__(self, name: str, cat: str):
        self._name = name
        self._cat = cat
        self._rec: SpanRecorder | None = None

    def __enter__(self) -> "_MaybeSpan":
        rec = _DEFAULT
        self._rec = rec
        if rec is not None:
            rec.begin(self._name, self._cat)
        return self

    def __exit__(self, *exc) -> None:
        if self._rec is not None:
            self._rec.end()


def span(name: str, cat: str = "runtime") -> _MaybeSpan:
    return _MaybeSpan(name, cat)
