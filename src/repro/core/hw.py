"""First-class memory-hierarchy targets for the FTL planning stack.

The paper's claim is about a *multi-level* software-managed hierarchy:
fusion on Siracusa trades L2/L3 (off-chip) transfers against L1 residency,
with DMA setup cost a second-order term.  Everything that prices a plan —
the tile solver, the fusion partitioner, the executor registry, the
roofline — therefore takes a :class:`Target` instead of a bare VMEM-budget
int, so the whole stack agrees about the machine and re-planning for a
different hierarchy is one argument, not a constant hunt.

A :class:`Target` is an ordered fast→backing list of :class:`MemoryLevel`s
plus a peak-FLOP/s figure:

* ``levels[0]`` is the software-managed fast memory the planner tiles for
  (VMEM on TPU, L1 TCDM on Siracusa).  Its ``capacity_bytes`` is the tile
  budget and its ``buffer_depth`` the pipeline multiplier every streamed
  tile is charged at (1 for a cache-backed level, 2 for DMA
  double-buffering); its bandwidth/DMA fields describe the core↔fast
  path and are not used by the boundary cost model.
* ``levels[1:]`` are the backing tiers, shallow→deep.  Each level's
  ``bw_bytes_per_s`` / ``dma_setup_s`` describe the DMA path between that
  level and the fast memory.  The cost model assigns every streamed
  tensor a *home level* (smallest-first first-fit, so a big intermediate
  spills past a full L2 exactly like the paper's Fig. 3 regime) and
  prices its traffic at that level's bandwidth.

A :class:`Target` may additionally carry :class:`Engine` entries — named
compute units with a per-op-kind FLOP/s rate map (the Siracusa NPU runs
GEMMs while the RV32 cluster runs GeLU).  Work of different engines
overlaps; work on one engine serializes, so a multi-engine target's
compute time is ``max`` over engines of each engine's serialized time.
An engine-less target keeps the single ``Target.flops`` rate for every
kind (all existing presets are unchanged).

Presets: :data:`TPU_V5E` (the repo's serving target), :data:`CPU_CACHE`
(a cache-blocked x86 core), :data:`RV32_L1_L2` (Siracusa-like RV32
cluster: L1 TCDM fast level with L2/L3 backing — the paper's platform),
and :data:`RV32_NPU` (the same hierarchy plus the N-EUREKA NPU as a
separate GEMM engine — the paper's cluster+NPU overlap regime).

The process-wide default is :func:`default_target` (``set_default_target``
override, then the ``FTL_TARGET`` env var, then :func:`detect_target`'s
reading of ``jax.devices()``); planners resolve ``target=None`` through
it and carry the resolved target in their plan-cache keys, so switching
targets can never serve a stale plan.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Iterable, Mapping, Sequence

KB = 1 << 10
MB = 1 << 20
GB = 1 << 30


@dataclasses.dataclass(frozen=True)
class MemoryLevel:
    """One tier of a software-managed memory hierarchy.

    For backing levels (``Target.levels[1:]``), ``bw_bytes_per_s`` and
    ``dma_setup_s`` describe the DMA path between this level and the fast
    level — the boundary the planner's traffic crosses.

    ``buffer_depth`` is the number of in-flight tile buffers a streamed
    tensor occupies when this level is the planner's *fast* memory: 1 for
    a hardware-cache-backed level (the cache prefetches; no software
    staging copies), 2 for classic DMA double-buffering (VMEM, L1 TCDM),
    3 for deeper prefetch pipelines.  The cost model charges it per
    streamed tensor instead of a hard-coded ×2, so the solver trades
    pipeline depth against tile size per hierarchy.

    On a *backing* level the field is a staging requirement for tensors
    homed there: a streamed tensor is charged
    ``max(fast.buffer_depth, home.buffer_depth)`` buffers
    (``Target.staging_depth``), so deepening a slow tier buys its
    tensors a longer prefetch runway at a footprint cost.  Presets
    declare backing depth 1 (no extra requirement), which makes the max
    degenerate to the fast depth — the pre-per-level behaviour.

    ``dma_port`` names the physical DMA engine/link that moves this
    level's traffic.  Levels sharing a port serialize against each
    other; traffic on distinct ports overlaps (``Target.transfer_time``
    is a max over ports).  Every on-package tier keeps the default
    ``"dma"`` port — a single port in play degenerates the max to the
    old Σ-over-levels model bit-exactly — while interconnect tiers
    (``ici``, ``noc``) declare their own port, which is what lets a
    collective stream overlap the same segment's HBM traffic.
    """

    name: str
    capacity_bytes: int
    bw_bytes_per_s: float
    dma_setup_s: float = 0.0
    buffer_depth: int = 2
    dma_port: str = "dma"

    @property
    def is_interconnect(self) -> bool:
        """Interconnect-class tier (chip-to-chip link, not a memory): the
        ``1 << 50`` capacity sentinel presets use for ici/noc levels.
        Such a level prices collective traffic but is never a spill home
        — remote HBM has no business backing a local streamed tensor."""
        return self.capacity_bytes >= 1 << 48

    def __post_init__(self):
        if self.capacity_bytes <= 0:
            raise ValueError(f"level {self.name}: capacity must be positive")
        if self.bw_bytes_per_s <= 0:
            raise ValueError(f"level {self.name}: bandwidth must be positive")
        if self.buffer_depth < 1:
            raise ValueError(
                f"level {self.name}: buffer_depth must be >= 1, got "
                f"{self.buffer_depth}"
            )


@dataclasses.dataclass(frozen=True)
class Engine:
    """One compute unit of a :class:`Target` with per-op-kind rates.

    ``rates`` maps an op kind (``'gemm'``, ``'elementwise'``, ...) to the
    FLOP/s this engine sustains for that kind; the pseudo-kind ``'*'`` is
    a catch-all rate for any kind not named by *any* engine (a scalar
    cluster runs whatever the accelerator cannot).  Work assigned to one
    engine serializes; distinct engines run concurrently — that is the
    paper's cluster+NPU overlap, and what the discrete-event simulator
    (``repro.sim``) replays per tile step.

    Frozen and tuple-backed so an engine-carrying Target stays hashable
    (plan-cache keys).
    """

    name: str
    rates: tuple[tuple[str, float], ...]

    def __post_init__(self):
        for kind, rate in self.rates:
            if rate <= 0:
                raise ValueError(
                    f"engine {self.name}: rate for {kind!r} must be "
                    f"positive, got {rate}"
                )


@dataclasses.dataclass(frozen=True)
class Target:
    """A machine the planner prices plans for: memory levels + peak FLOPs
    (+ optionally named per-op-kind :class:`Engine`\\s).

    Hashable (all-frozen), so it participates directly in every plan
    cache key.
    """

    name: str
    levels: tuple[MemoryLevel, ...]
    flops: float
    engines: tuple[Engine, ...] = ()

    def __post_init__(self):
        if len(self.levels) < 2:
            raise ValueError(
                f"target {self.name}: need a fast level and at least one "
                f"backing level, got {len(self.levels)}"
            )
        for shallow, deep in zip(self.levels, self.levels[1:]):
            if deep.capacity_bytes < shallow.capacity_bytes:
                raise ValueError(
                    f"target {self.name}: level {deep.name} "
                    f"({deep.capacity_bytes} B) smaller than the level "
                    f"above it ({shallow.name}, {shallow.capacity_bytes} B)"
                )
        names = [e.name for e in self.engines]
        if len(set(names)) != len(names):
            raise ValueError(
                f"target {self.name}: duplicate engine names {names}"
            )

    # ------------------------------------------------------------------
    @property
    def fast(self) -> MemoryLevel:
        """The software-managed fast level the solver tiles for."""
        return self.levels[0]

    @property
    def backing(self) -> tuple[MemoryLevel, ...]:
        return self.levels[1:]

    @property
    def fast_capacity(self) -> int:
        """The tile budget (bytes) — what `vmem_budget` used to be."""
        return self.fast.capacity_bytes

    @property
    def interconnect(self) -> MemoryLevel | None:
        """The chip-to-chip interconnect tier (ici/noc), if this target
        has one — the level collective traffic is priced against."""
        for lv in self.backing:
            if lv.is_interconnect:
                return lv
        return None

    # ------------------------------------------------------------------
    def with_fast_capacity(self, capacity_bytes: int) -> "Target":
        """This target with the fast level resized — the budget-sweep hook
        tests and benchmarks use instead of raw ints.

        A backing level the new fast level outgrows is *dropped* (its
        traffic reprices at the next deeper tier), never silently
        inflated: a scratchpad larger than L2 cannot be backed by that
        L2, and inflating it would misprice spill traffic at the shallow
        tier's bandwidth.  The deepest level is always kept.
        """
        fast = dataclasses.replace(
            self.fast, capacity_bytes=int(capacity_bytes)
        )
        kept = tuple(lv for lv in self.backing[:-1]
                     if lv.capacity_bytes >= capacity_bytes)
        deep = self.backing[-1]
        if deep.capacity_bytes < capacity_bytes:
            deep = dataclasses.replace(
                deep, capacity_bytes=int(capacity_bytes)
            )
        return dataclasses.replace(
            self, name=f"{self.name}@{capacity_bytes}B",
            levels=(fast,) + kept + (deep,)
        )

    def with_buffer_depth(self, depth: int) -> "Target":
        """This target with the fast level's pipeline depth replaced —
        the hook tests/benchmarks use to sweep staging depth.  A changed
        depth produces a distinct (differently named, differently
        hashed) target, so plan caches keyed on the target can never
        serve a plan made for a different depth; the current depth
        returns ``self`` (no duplicate cache entries for the identical
        machine), and re-sweeping replaces a previous ``@depthN`` suffix
        instead of stacking another."""
        depth = int(depth)
        if depth == self.fast.buffer_depth:
            return self
        fast = dataclasses.replace(self.fast, buffer_depth=depth)
        base = self.name.split("@depth")[0]
        return dataclasses.replace(
            self, name=f"{base}@depth{depth}",
            levels=(fast,) + self.backing
        )

    def with_level_buffer_depth(self, level: str, depth: int) -> "Target":
        """This target with the *named* level's pipeline depth replaced —
        the autotuner's per-level depth knob (``repro.tune``).  For the
        fast level the depth is the staging-pipeline multiplier; for a
        backing level it deepens the staging of tensors *homed* there
        (the cost model charges ``max(fast.depth, home.depth)`` buffers
        per streamed tensor).  Like :meth:`with_buffer_depth`, a changed
        depth yields a distinct (differently named, differently hashed)
        target; the current depth returns ``self``, and re-sweeping the
        same level replaces its previous ``@<level>dN`` suffix instead of
        stacking another."""
        depth = int(depth)
        by_name = {lv.name: lv for lv in self.levels}
        if level not in by_name:
            raise KeyError(
                f"target {self.name}: no level named {level!r}; levels: "
                f"{[lv.name for lv in self.levels]}"
            )
        if depth == by_name[level].buffer_depth:
            return self
        new_levels = tuple(
            dataclasses.replace(lv, buffer_depth=depth)
            if lv.name == level else lv
            for lv in self.levels
        )
        parts = [p for p in self.name.split("@")
                 if not (p.startswith(f"{level}d")
                         and p[len(level) + 1:].isdigit())]
        name = "@".join(parts) + f"@{level}d{depth}"
        return dataclasses.replace(self, name=name, levels=new_levels)

    def staging_depth(self, home: "MemoryLevel") -> int:
        """Buffers a streamed tensor homed at ``home`` is charged: the
        deeper of the fast level's pipeline and the home level's staging
        depth.  A deepened backing level (``with_level_buffer_depth``)
        buys its tensors a longer prefetch runway; it can never *reduce*
        the fast level's own pipeline, so with all-default depths this is
        exactly ``fast.buffer_depth`` (every preset ships backing depths
        ≤ the fast depth — bit-identical costs)."""
        return max(self.fast.buffer_depth, home.buffer_depth)

    # ------------------------------------------------------------------
    def assign_homes(
        self, footprints: Mapping[str, int]
    ) -> dict[str, MemoryLevel]:
        """Home backing level per tensor: smallest-first first-fit.

        Small tensors claim the shallow tiers; whatever no longer fits
        spills deeper (the deepest *memory* level always accepts).  This
        is the paper's L2-overflow mechanism: a big fused-away
        intermediate that *would* have streamed now never competes for
        L2 at all, while the unfused schedule's intermediate spills to
        L3.

        Interconnect-class levels (``MemoryLevel.is_interconnect``: the
        ``1 << 50`` ici/noc sentinels) are excluded from both the
        first-fit and the spill fallback — their "capacity" is remote
        memory reachable over the link, not a home for a locally
        streamed tensor, and their sentinel size would otherwise win
        every overflow.  Spills land on the deepest memory tier (hbm on
        ``tpu_v5e``, l3 on the rv32 presets) instead.
        """
        memory = [lv for lv in self.backing if not lv.is_interconnect]
        if not memory:                # all-interconnect hierarchy: degenerate
            memory = list(self.backing)
        free = {lv.name: lv.capacity_bytes for lv in memory}
        homes: dict[str, MemoryLevel] = {}
        for tname in sorted(footprints, key=lambda n: (footprints[n], n)):
            placed = None
            for lv in memory[:-1]:
                if footprints[tname] <= free[lv.name]:
                    free[lv.name] -= footprints[tname]
                    placed = lv
                    break
            homes[tname] = placed if placed is not None else memory[-1]
        return homes

    def transfer_time_by_port(
        self,
        bytes_by_level: Mapping[str, int],
        transfers_by_level: Mapping[str, int],
    ) -> dict[str, float]:
        """Serialized DMA time per port:
        ``Σ_{level on port} bytes/bw + transfers·dma_setup``."""
        by_name = {lv.name: lv for lv in self.backing}
        per_port: dict[str, float] = {}
        for name, b in bytes_by_level.items():
            lv = by_name[name]
            per_port[lv.dma_port] = per_port.get(lv.dma_port, 0.0) \
                + b / lv.bw_bytes_per_s
        for name, n in transfers_by_level.items():
            lv = by_name[name]
            per_port[lv.dma_port] = per_port.get(lv.dma_port, 0.0) \
                + n * lv.dma_setup_s
        return per_port

    def transfer_time(
        self,
        bytes_by_level: Mapping[str, int],
        transfers_by_level: Mapping[str, int],
    ) -> float:
        """Modeled DMA time: levels sharing a ``dma_port`` serialize
        (Σ bytes/bw + transfers·dma_setup within the port); distinct
        ports overlap, so the total is the ``max`` over ports.  With a
        single port in play this is bit-identical to the old
        Σ-over-levels model; it diverges only when interconnect traffic
        (collectives on ici/noc) runs alongside memory traffic."""
        per_port = self.transfer_time_by_port(
            bytes_by_level, transfers_by_level)
        return max(per_port.values(), default=0.0)

    def transfer_time_serialized(
        self,
        bytes_by_level: Mapping[str, int],
        transfers_by_level: Mapping[str, int],
    ) -> float:
        """The pre-multi-port model — Σ over *all* levels regardless of
        port, as if one DMA engine moved everything.  Kept as the
        no-overlap baseline bench_mesh gates the simulated overlap
        against."""
        per_port = self.transfer_time_by_port(
            bytes_by_level, transfers_by_level)
        return sum(per_port.values())

    def compute_time_s(self, flops: float) -> float:
        """Modeled compute time of ``flops`` at this target's peak rate
        (:func:`compute_time` — shared with the roofline's HW view, so
        the planner and the roofline can never disagree about how long
        an op's arithmetic takes on the same machine)."""
        return compute_time(flops, self.flops)

    # ------------------------------------------------------------------
    # per-engine compute
    # ------------------------------------------------------------------
    def engine_rate(self, kind: str) -> tuple[str, float]:
        """(engine name, FLOP/s) that runs ops of ``kind``.

        Engine-less targets run everything on an implicit ``'core'``
        engine at ``Target.flops``.  With engines, an exact-kind rate
        wins over a catch-all ``'*'`` rate; among several matches the
        fastest engine takes the work (a GEMM never runs on the scalar
        cluster while an NPU is present).
        """
        if not self.engines:
            return ("core", self.flops)
        exact = [(e.name, r) for e in self.engines
                 for k, r in e.rates if k == kind]
        if exact:
            return max(exact, key=lambda nr: nr[1])
        wild = [(e.name, r) for e in self.engines
                for k, r in e.rates if k == "*"]
        if wild:
            return max(wild, key=lambda nr: nr[1])
        raise ValueError(
            f"target {self.name}: no engine runs op kind {kind!r} and "
            f"none advertises a '*' catch-all rate"
        )

    def engines_for_kind(self, kind: str) -> tuple[str, ...]:
        """Names of every engine that *can* run ops of ``kind`` (an exact
        rate or a ``'*'`` catch-all) — the autotuner's assignment domain.
        Engine-less targets expose the implicit ``'core'`` engine."""
        if not self.engines:
            return ("core",)
        return tuple(
            e.name for e in self.engines
            if any(k in (kind, "*") for k, _ in e.rates)
        )

    def engine_rate_for(self, kind: str, engine: str) -> float:
        """FLOP/s of ``engine`` running ops of ``kind`` (exact-kind rate
        wins over its ``'*'`` catch-all).  Raises if the engine cannot
        run the kind — the autotuner only proposes assignments drawn from
        :meth:`engines_for_kind`."""
        if not self.engines:
            if engine != "core":
                raise ValueError(
                    f"target {self.name}: no engine named {engine!r} "
                    f"(engine-less targets expose only 'core')"
                )
            return self.flops
        for e in self.engines:
            if e.name != engine:
                continue
            rates = dict(e.rates)
            if kind in rates:
                return rates[kind]
            if "*" in rates:
                return rates["*"]
            raise ValueError(
                f"target {self.name}: engine {engine!r} has no rate for "
                f"op kind {kind!r}"
            )
        raise ValueError(
            f"target {self.name}: no engine named {engine!r}; engines: "
            f"{[e.name for e in self.engines]}"
        )

    def engine_times(self, flops_by_kind: Mapping[str, float]
                     ) -> dict[str, float]:
        """Serialized busy time per engine for the given work mix."""
        times: dict[str, float] = {e.name: 0.0 for e in self.engines} \
            or {"core": 0.0}
        for kind, flops in flops_by_kind.items():
            name, rate = self.engine_rate(kind)
            times[name] += flops / rate
        return times

    def compute_time_by_kind(self, flops_by_kind: Mapping[str, float]
                             ) -> float:
        """Compute time of a work mix: engines overlap, each serializes.

        Engine-less targets reduce to the single-rate
        ``compute_time(Σ flops, Target.flops)`` (bit-identical to the
        legacy formula so existing plan pins survive); with engines the
        mix is split by kind and the slowest engine's serialized time is
        the floor — fusing a cluster-side epilogue under an NPU GEMM
        then genuinely hides it, the paper's −60.1 % regime.
        """
        if not self.engines:
            return compute_time(float(sum(flops_by_kind.values())),
                                self.flops)
        return max(self.engine_times(flops_by_kind).values(), default=0.0)

    # ------------------------------------------------------------------
    @staticmethod
    def calibrated(measurements, base: "Target | None" = None) -> "Target":
        """A preset-shaped target with constants *fitted from measured
        wall-clock runs* (``repro.calib``): same level names, capacities,
        ports and engine structure as ``base`` (default: the process
        default target), but effective per-level bandwidth / DMA setup
        and per-engine FLOP/s solved by non-negative least squares over
        the shared roofline model.  ``measurements`` is a sequence of
        :class:`repro.calib.Measurement` (see
        ``repro.calib.microbench_sweep``).  For the fit diagnostics —
        per-measurement residuals, the drift-gate statistics — call
        :func:`repro.calib.calibrate` directly; this returns only the
        target."""
        from repro.calib import calibrate

        return calibrate(measurements, base=base).target

    # ------------------------------------------------------------------
    def describe(self) -> str:
        parts = [
            f"{lv.name} {_fmt_bytes(lv.capacity_bytes)}"
            + (f" @{lv.bw_bytes_per_s / 1e9:g} GB/s" if i else "")
            for i, lv in enumerate(self.levels)
        ]
        return f"{self.name}: " + " <- ".join(parts) + \
            f", {self.flops / 1e12:g} TFLOP/s"


def compute_time(flops: float, peak_flops: float) -> float:
    """The repo's one compute-time formula: ``flops / peak rate``.
    ``Target.compute_time_s`` (the FTL planner) and
    ``repro.roofline.analysis.HW.compute_time_s`` both delegate here, so
    a change to the compute model lands on both consumers at once."""
    return flops / peak_flops


def modeled_runtime(compute_s: float, transfer_s: float) -> float:
    """The repo's one overlap rule: double-buffered DMA hides behind
    compute (and vice versa), so a segment's modeled runtime is
    ``max(compute_time, transfer_time)``.  The FTL solver/partition-DP
    objective, the roofline bound and the benchmark runtime models all
    call this instead of restating the max()."""
    return max(compute_s, transfer_s)


def round_time(t: float) -> float:
    """Canonicalize a modeled time for *objective comparisons*: round to
    12 significant digits.

    Partition runtimes that are mathematically equal can differ by a
    float ulp (an all-compute-bound chain prices ``Σ_i flops_i / F``
    against ``(Σ_i flops_i) / F``); comparing raw floats would then break
    such ties by rounding noise instead of falling through to the
    deterministic traffic/DMA tie-breaks.  12 significant digits is far
    below any modeling fidelity and far above accumulated double
    rounding error for the ≤ dozens of segments a chain has."""
    if t == 0.0:
        return 0.0
    return float(f"{t:.12g}")


def _fmt_bytes(n: int) -> str:
    if n >= 1 << 48:
        return "unbounded"
    for unit, tag in ((1 << 30, "GiB"), (1 << 20, "MiB"), (1 << 10, "KiB")):
        if n >= unit:
            return f"{n / unit:.3g} {tag}"
    return f"{n} B"


# ---------------------------------------------------------------------------
# presets
# ---------------------------------------------------------------------------

# TPU v5e class (task-specified constants).  The fast level is the 96 MiB
# the planner may claim — the physical 128 MiB VMEM minus the headroom the
# Pallas pipeline machinery / semaphores need.  VMEM is DMA-fed: the
# Pallas pipeline double-buffers every streamed tile.  ICI-reachable
# remote HBM plays the deep-tier role for the roofline's collective term.
TPU_V5E = Target(
    name="tpu_v5e",
    levels=(
        MemoryLevel("vmem", 96 * MB, 2.0e13, buffer_depth=2),
        MemoryLevel("hbm", int(16e9), 819e9, dma_setup_s=1e-6,
                    buffer_depth=1),
        MemoryLevel("ici", 1 << 50, 50e9, dma_setup_s=5e-6,
                    buffer_depth=1, dma_port="ici"),
    ),
    flops=197e12,
)

# Cache-blocked x86 core: the "software-managed" fast level is the slice
# of private L2 a blocked kernel keeps hot; hardware prefetch makes the
# per-transfer setup effectively zero and the cache itself stages the
# incoming lines — no software double-buffer copies (buffer_depth=1).
CPU_CACHE = Target(
    name="cpu_cache",
    levels=(
        MemoryLevel("l2", 1 * MB, 150e9, buffer_depth=1),
        MemoryLevel("llc", 32 * MB, 80e9, buffer_depth=1),
        MemoryLevel("dram", 64 * GB, 25e9, buffer_depth=1),
    ),
    flops=1e12,
)

# Siracusa-like RV32 cluster (the paper's platform): 256 KiB L1 TCDM fed
# by DMA from 2 MiB on-chip L2 (double-buffered, the paper's pipeline),
# off-chip L3 behind a HyperBus-class link.  Constants match
# benchmarks/hw_profiles.py (order-of-magnitude estimates from the
# Siracusa/PULP literature).
RV32_L1_L2 = Target(
    name="rv32_l1_l2",
    levels=(
        MemoryLevel("l1", 256 * KB, 8e9, buffer_depth=2),
        MemoryLevel("l2", 2 * MB, 2.0e9, dma_setup_s=2e-6, buffer_depth=1),
        MemoryLevel("l3", 512 * MB, 0.35e9, dma_setup_s=2e-6,
                    buffer_depth=1),
    ),
    flops=6e9,
)

# Siracusa with the N-EUREKA NPU enabled (the paper's cluster+NPU
# −60.1 % regime): same L1/L2/L3 hierarchy, but GEMMs run on the NPU
# (~64 GMAC/s int8 → 128 GFLOP/s) while everything else — GeLU,
# softmax, residual adds — stays on the 8-core scalar cluster
# (~0.3 G elem/s).  The two engines overlap, so a fused elementwise
# epilogue hides under the NPU's next tile instead of serializing.
# Constants absorbed from benchmarks/hw_profiles.py's SIRACUSA_NPU
# (macs_per_s / ew_per_s), which now derives its planning target from
# this shared model.
RV32_NPU = Target(
    name="rv32_npu",
    levels=RV32_L1_L2.levels,
    flops=128e9,
    engines=(
        Engine("npu", (("gemm", 128e9),)),
        Engine("cluster", (("*", 0.3e9),)),
    ),
)

# Multi-cluster Siracusa-like SoC: several RV32+NPU clusters on one die
# joined by an on-chip NoC (chip-to-chip extension of the same link class
# for >1-die meshes).  The per-cluster hierarchy and engines are exactly
# RV32_NPU — with no collectives in a graph the plans are identical —
# but the NoC level (interconnect sentinel capacity, its own DMA port)
# lets the planner price all-reduce/all-gather streams for a
# tensor-parallel block and overlap them with the L2/L3 DMA traffic.
# ~8 GB/s NoC with a per-message setup in the µs class (PULP cluster-
# to-cluster DMA literature, order of magnitude).
RV32_MESH = Target(
    name="rv32_mesh",
    levels=RV32_NPU.levels + (
        MemoryLevel("noc", 1 << 50, 8e9, dma_setup_s=2e-6,
                    buffer_depth=1, dma_port="noc"),
    ),
    flops=RV32_NPU.flops,
    engines=RV32_NPU.engines,
)

PRESETS: dict[str, Target] = {
    t.name: t for t in (TPU_V5E, CPU_CACHE, RV32_L1_L2, RV32_NPU,
                        RV32_MESH)
}


def get_target(name: str) -> Target:
    try:
        return PRESETS[name]
    except KeyError:
        raise KeyError(
            f"unknown target {name!r}; presets: {sorted(PRESETS)}"
        ) from None


def presets() -> Iterable[Target]:
    return tuple(PRESETS.values())


# ---------------------------------------------------------------------------
# target auto-detection
# ---------------------------------------------------------------------------

# TPU generations the detector recognizes (substring of
# ``device.device_kind``, checked longest-first): fast-level capacity the
# planner may claim (physical VMEM minus Pallas pipeline headroom), peak
# bf16 FLOP/s, HBM bytes/s and capacity.  v5e stays the preset; the
# others are order-of-magnitude public figures — relative plan decisions,
# not absolute times, are what the planner consumes.
_TPU_GENERATIONS: tuple[tuple[str, tuple[int, float, float, float]], ...] = (
    ("v5 lite", (96 * MB, 197e12, 819e9, 16e9)),
    ("v5e", (96 * MB, 197e12, 819e9, 16e9)),
    ("v5p", (96 * MB, 459e12, 2765e9, 95e9)),
    ("v5", (96 * MB, 459e12, 2765e9, 95e9)),
    ("v6 lite", (96 * MB, 918e12, 1640e9, 32e9)),
    ("v6e", (96 * MB, 918e12, 1640e9, 32e9)),
    ("v4", (96 * MB, 275e12, 1228e9, 32e9)),
    ("v3", (96 * MB, 123e12, 900e9, 32e9)),
    ("v2", (96 * MB, 46e12, 700e9, 16e9)),
)


def _tpu_target(device_kind: str) -> Target:
    kind = device_kind.lower()
    for tag, (vmem, flops, hbm_bw, hbm_bytes) in _TPU_GENERATIONS:
        if tag in kind:
            if tag in ("v5 lite", "v5e"):
                return TPU_V5E
            name = "tpu_" + tag.replace(" lite", "e").replace(" ", "")
            return Target(
                name=name,
                levels=(
                    MemoryLevel("vmem", vmem, 2.0e13, buffer_depth=2),
                    MemoryLevel("hbm", int(hbm_bytes), hbm_bw,
                                dma_setup_s=1e-6, buffer_depth=1),
                    MemoryLevel("ici", 1 << 50, 50e9, dma_setup_s=5e-6,
                                buffer_depth=1, dma_port="ici"),
                ),
                flops=flops,
            )
    return TPU_V5E


def detect_target(devices: Sequence | None = None) -> Target:
    """Derive a planning target from the JAX device list.

    TPU hosts map their generation (``device_kind``) to VMEM size / peak
    FLOP/s / HBM bandwidth; CPU hosts get the cache-blocked
    :data:`CPU_CACHE` preset.  Anything else (GPU, or a host where jax
    itself is unavailable) falls back to :data:`TPU_V5E` — the repo's
    serving target — until a dedicated hierarchy lands.  ``devices``
    is injectable for tests; None reads ``jax.devices()``.
    """
    if devices is None:
        try:
            import jax
            devices = jax.devices()
        except Exception:  # jax missing/uninitializable: planner-only use
            return TPU_V5E
    if not devices:
        return TPU_V5E
    dev = devices[0]
    platform = getattr(dev, "platform", "")
    if platform == "tpu":
        return _tpu_target(getattr(dev, "device_kind", ""))
    if platform == "cpu":
        return CPU_CACHE
    return TPU_V5E


# ---------------------------------------------------------------------------
# process-wide default
# ---------------------------------------------------------------------------

_DEFAULT: list[Target | None] = [None]
# Resolution memo, keyed by the FTL_TARGET env value in effect when the
# resolution was made (None = device detection).  Keying on the env state
# is what makes flipping FTL_TARGET mid-process take effect immediately
# instead of being shadowed by a first-answer memo; set_default_target
# clears it outright so an override can never be answered stale either.
_RESOLVED: dict[str | None, Target] = {}


def default_target() -> Target:
    """The target planners resolve ``target=None`` through.

    Order: :func:`set_default_target` override, then the ``FTL_TARGET``
    env var (a preset name), then :func:`detect_target` on the process's
    JAX device list.  The resolution is memoized *per env state*
    (``_RESOLVED``), so detection runs once per process but a changed
    ``FTL_TARGET`` or :func:`set_default_target` call is honored on the
    very next lookup — never silently ignored.
    """
    if _DEFAULT[0] is not None:
        return _DEFAULT[0]
    env = os.environ.get("FTL_TARGET") or None
    got = _RESOLVED.get(env)
    if got is None:
        got = get_target(env) if env else detect_target()
        _RESOLVED[env] = got
    return got


def set_default_target(target: Target | str | None) -> None:
    """Set (or with ``None`` clear) the process-wide default target.
    Clears the resolution memo so later lookups re-resolve against the
    current override/env state."""
    if isinstance(target, str):
        target = get_target(target)
    _DEFAULT[0] = target
    _RESOLVED.clear()
