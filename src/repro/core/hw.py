"""First-class memory-hierarchy targets for the FTL planning stack.

The paper's claim is about a *multi-level* software-managed hierarchy:
fusion on Siracusa trades L2/L3 (off-chip) transfers against L1 residency,
with DMA setup cost a second-order term.  Everything that prices a plan —
the tile solver, the fusion partitioner, the executor registry, the
roofline — therefore takes a :class:`Target` instead of a bare VMEM-budget
int, so the whole stack agrees about the machine and re-planning for a
different hierarchy is one argument, not a constant hunt.

A :class:`Target` is an ordered fast→backing list of :class:`MemoryLevel`s
plus a peak-FLOP/s figure:

* ``levels[0]`` is the software-managed fast memory the planner tiles for
  (VMEM on TPU, L1 TCDM on Siracusa).  Its ``capacity_bytes`` is the tile
  budget and its ``buffer_depth`` the pipeline multiplier every streamed
  tile is charged at (1 for a cache-backed level, 2 for DMA
  double-buffering); its bandwidth/DMA fields describe the core↔fast
  path and are not used by the boundary cost model.
* ``levels[1:]`` are the backing tiers, shallow→deep.  Each level's
  ``bw_bytes_per_s`` / ``dma_setup_s`` describe the DMA path between that
  level and the fast memory.  The cost model assigns every streamed
  tensor a *home level* (smallest-first first-fit, so a big intermediate
  spills past a full L2 exactly like the paper's Fig. 3 regime) and
  prices its traffic at that level's bandwidth.

Presets: :data:`TPU_V5E` (the repo's serving target), :data:`CPU_CACHE`
(a cache-blocked x86 core), and :data:`RV32_L1_L2` (Siracusa-like RV32
cluster: L1 TCDM fast level with L2/L3 backing — the paper's platform).

The process-wide default is :func:`default_target` (``FTL_TARGET`` env
var, else ``tpu_v5e``); planners resolve ``target=None`` through it and
carry the resolved target in their plan-cache keys, so switching targets
can never serve a stale plan.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Iterable, Mapping

KB = 1 << 10
MB = 1 << 20
GB = 1 << 30


@dataclasses.dataclass(frozen=True)
class MemoryLevel:
    """One tier of a software-managed memory hierarchy.

    For backing levels (``Target.levels[1:]``), ``bw_bytes_per_s`` and
    ``dma_setup_s`` describe the DMA path between this level and the fast
    level — the boundary the planner's traffic crosses.

    ``buffer_depth`` is the number of in-flight tile buffers a streamed
    tensor occupies when this level is the planner's *fast* memory: 1 for
    a hardware-cache-backed level (the cache prefetches; no software
    staging copies), 2 for classic DMA double-buffering (VMEM, L1 TCDM),
    3 for deeper prefetch pipelines.  The cost model charges it per
    streamed tensor instead of a hard-coded ×2, so the solver trades
    pipeline depth against tile size per hierarchy.
    """

    name: str
    capacity_bytes: int
    bw_bytes_per_s: float
    dma_setup_s: float = 0.0
    buffer_depth: int = 2

    def __post_init__(self):
        if self.capacity_bytes <= 0:
            raise ValueError(f"level {self.name}: capacity must be positive")
        if self.bw_bytes_per_s <= 0:
            raise ValueError(f"level {self.name}: bandwidth must be positive")
        if self.buffer_depth < 1:
            raise ValueError(
                f"level {self.name}: buffer_depth must be >= 1, got "
                f"{self.buffer_depth}"
            )


@dataclasses.dataclass(frozen=True)
class Target:
    """A machine the planner prices plans for: memory levels + peak FLOPs.

    Hashable (all-frozen), so it participates directly in every plan
    cache key.
    """

    name: str
    levels: tuple[MemoryLevel, ...]
    flops: float

    def __post_init__(self):
        if len(self.levels) < 2:
            raise ValueError(
                f"target {self.name}: need a fast level and at least one "
                f"backing level, got {len(self.levels)}"
            )
        for shallow, deep in zip(self.levels, self.levels[1:]):
            if deep.capacity_bytes < shallow.capacity_bytes:
                raise ValueError(
                    f"target {self.name}: level {deep.name} "
                    f"({deep.capacity_bytes} B) smaller than the level "
                    f"above it ({shallow.name}, {shallow.capacity_bytes} B)"
                )

    # ------------------------------------------------------------------
    @property
    def fast(self) -> MemoryLevel:
        """The software-managed fast level the solver tiles for."""
        return self.levels[0]

    @property
    def backing(self) -> tuple[MemoryLevel, ...]:
        return self.levels[1:]

    @property
    def fast_capacity(self) -> int:
        """The tile budget (bytes) — what `vmem_budget` used to be."""
        return self.fast.capacity_bytes

    # ------------------------------------------------------------------
    def with_fast_capacity(self, capacity_bytes: int) -> "Target":
        """This target with the fast level resized — the budget-sweep hook
        tests and benchmarks use instead of raw ints.

        A backing level the new fast level outgrows is *dropped* (its
        traffic reprices at the next deeper tier), never silently
        inflated: a scratchpad larger than L2 cannot be backed by that
        L2, and inflating it would misprice spill traffic at the shallow
        tier's bandwidth.  The deepest level is always kept.
        """
        fast = dataclasses.replace(
            self.fast, capacity_bytes=int(capacity_bytes)
        )
        kept = tuple(lv for lv in self.backing[:-1]
                     if lv.capacity_bytes >= capacity_bytes)
        deep = self.backing[-1]
        if deep.capacity_bytes < capacity_bytes:
            deep = dataclasses.replace(
                deep, capacity_bytes=int(capacity_bytes)
            )
        return dataclasses.replace(
            self, name=f"{self.name}@{capacity_bytes}B",
            levels=(fast,) + kept + (deep,)
        )

    def with_buffer_depth(self, depth: int) -> "Target":
        """This target with the fast level's pipeline depth replaced —
        the hook tests/benchmarks use to sweep staging depth.  A changed
        depth produces a distinct (differently named, differently
        hashed) target, so plan caches keyed on the target can never
        serve a plan made for a different depth; the current depth
        returns ``self`` (no duplicate cache entries for the identical
        machine), and re-sweeping replaces a previous ``@depthN`` suffix
        instead of stacking another."""
        depth = int(depth)
        if depth == self.fast.buffer_depth:
            return self
        fast = dataclasses.replace(self.fast, buffer_depth=depth)
        base = self.name.split("@depth")[0]
        return dataclasses.replace(
            self, name=f"{base}@depth{depth}",
            levels=(fast,) + self.backing
        )

    # ------------------------------------------------------------------
    def assign_homes(
        self, footprints: Mapping[str, int]
    ) -> dict[str, MemoryLevel]:
        """Home backing level per tensor: smallest-first first-fit.

        Small tensors claim the shallow tiers; whatever no longer fits
        spills deeper (the deepest level always accepts).  This is the
        paper's L2-overflow mechanism: a big fused-away intermediate that
        *would* have streamed now never competes for L2 at all, while the
        unfused schedule's intermediate spills to L3.
        """
        free = {lv.name: lv.capacity_bytes for lv in self.backing}
        homes: dict[str, MemoryLevel] = {}
        for tname in sorted(footprints, key=lambda n: (footprints[n], n)):
            placed = None
            for lv in self.backing[:-1]:
                if footprints[tname] <= free[lv.name]:
                    free[lv.name] -= footprints[tname]
                    placed = lv
                    break
            homes[tname] = placed if placed is not None else self.backing[-1]
        return homes

    def transfer_time(
        self,
        bytes_by_level: Mapping[str, int],
        transfers_by_level: Mapping[str, int],
    ) -> float:
        """Modeled DMA time: Σ_level bytes/bw + transfers·dma_setup."""
        by_name = {lv.name: lv for lv in self.backing}
        t = 0.0
        for name, b in bytes_by_level.items():
            t += b / by_name[name].bw_bytes_per_s
        for name, n in transfers_by_level.items():
            t += n * by_name[name].dma_setup_s
        return t

    def compute_time_s(self, flops: float) -> float:
        """Modeled compute time of ``flops`` at this target's peak rate
        (:func:`compute_time` — shared with the roofline's HW view, so
        the planner and the roofline can never disagree about how long
        an op's arithmetic takes on the same machine)."""
        return compute_time(flops, self.flops)

    # ------------------------------------------------------------------
    def describe(self) -> str:
        parts = [
            f"{lv.name} {_fmt_bytes(lv.capacity_bytes)}"
            + (f" @{lv.bw_bytes_per_s / 1e9:g} GB/s" if i else "")
            for i, lv in enumerate(self.levels)
        ]
        return f"{self.name}: " + " <- ".join(parts) + \
            f", {self.flops / 1e12:g} TFLOP/s"


def compute_time(flops: float, peak_flops: float) -> float:
    """The repo's one compute-time formula: ``flops / peak rate``.
    ``Target.compute_time_s`` (the FTL planner) and
    ``repro.roofline.analysis.HW.compute_time_s`` both delegate here, so
    a change to the compute model lands on both consumers at once."""
    return flops / peak_flops


def modeled_runtime(compute_s: float, transfer_s: float) -> float:
    """The repo's one overlap rule: double-buffered DMA hides behind
    compute (and vice versa), so a segment's modeled runtime is
    ``max(compute_time, transfer_time)``.  The FTL solver/partition-DP
    objective, the roofline bound and the benchmark runtime models all
    call this instead of restating the max()."""
    return max(compute_s, transfer_s)


def round_time(t: float) -> float:
    """Canonicalize a modeled time for *objective comparisons*: round to
    12 significant digits.

    Partition runtimes that are mathematically equal can differ by a
    float ulp (an all-compute-bound chain prices ``Σ_i flops_i / F``
    against ``(Σ_i flops_i) / F``); comparing raw floats would then break
    such ties by rounding noise instead of falling through to the
    deterministic traffic/DMA tie-breaks.  12 significant digits is far
    below any modeling fidelity and far above accumulated double
    rounding error for the ≤ dozens of segments a chain has."""
    if t == 0.0:
        return 0.0
    return float(f"{t:.12g}")


def _fmt_bytes(n: int) -> str:
    if n >= 1 << 48:
        return "unbounded"
    for unit, tag in ((1 << 30, "GiB"), (1 << 20, "MiB"), (1 << 10, "KiB")):
        if n >= unit:
            return f"{n / unit:.3g} {tag}"
    return f"{n} B"


# ---------------------------------------------------------------------------
# presets
# ---------------------------------------------------------------------------

# TPU v5e class (task-specified constants).  The fast level is the 96 MiB
# the planner may claim — the physical 128 MiB VMEM minus the headroom the
# Pallas pipeline machinery / semaphores need.  VMEM is DMA-fed: the
# Pallas pipeline double-buffers every streamed tile.  ICI-reachable
# remote HBM plays the deep-tier role for the roofline's collective term.
TPU_V5E = Target(
    name="tpu_v5e",
    levels=(
        MemoryLevel("vmem", 96 * MB, 2.0e13, buffer_depth=2),
        MemoryLevel("hbm", int(16e9), 819e9, dma_setup_s=1e-6),
        MemoryLevel("ici", 1 << 50, 50e9, dma_setup_s=5e-6),
    ),
    flops=197e12,
)

# Cache-blocked x86 core: the "software-managed" fast level is the slice
# of private L2 a blocked kernel keeps hot; hardware prefetch makes the
# per-transfer setup effectively zero and the cache itself stages the
# incoming lines — no software double-buffer copies (buffer_depth=1).
CPU_CACHE = Target(
    name="cpu_cache",
    levels=(
        MemoryLevel("l2", 1 * MB, 150e9, buffer_depth=1),
        MemoryLevel("llc", 32 * MB, 80e9, buffer_depth=1),
        MemoryLevel("dram", 64 * GB, 25e9, buffer_depth=1),
    ),
    flops=1e12,
)

# Siracusa-like RV32 cluster (the paper's platform): 256 KiB L1 TCDM fed
# by DMA from 2 MiB on-chip L2 (double-buffered, the paper's pipeline),
# off-chip L3 behind a HyperBus-class link.  Constants match
# benchmarks/hw_profiles.py (order-of-magnitude estimates from the
# Siracusa/PULP literature).
RV32_L1_L2 = Target(
    name="rv32_l1_l2",
    levels=(
        MemoryLevel("l1", 256 * KB, 8e9, buffer_depth=2),
        MemoryLevel("l2", 2 * MB, 2.0e9, dma_setup_s=2e-6),
        MemoryLevel("l3", 512 * MB, 0.35e9, dma_setup_s=2e-6),
    ),
    flops=6e9,
)

PRESETS: dict[str, Target] = {
    t.name: t for t in (TPU_V5E, CPU_CACHE, RV32_L1_L2)
}


def get_target(name: str) -> Target:
    try:
        return PRESETS[name]
    except KeyError:
        raise KeyError(
            f"unknown target {name!r}; presets: {sorted(PRESETS)}"
        ) from None


def presets() -> Iterable[Target]:
    return tuple(PRESETS.values())


# ---------------------------------------------------------------------------
# process-wide default
# ---------------------------------------------------------------------------

_DEFAULT: list[Target | None] = [None]


def default_target() -> Target:
    """The target planners resolve ``target=None`` through.

    Order: :func:`set_default_target` override, then the ``FTL_TARGET``
    env var (a preset name), then :data:`TPU_V5E`.
    """
    if _DEFAULT[0] is not None:
        return _DEFAULT[0]
    env = os.environ.get("FTL_TARGET")
    if env:
        return get_target(env)
    return TPU_V5E


def set_default_target(target: Target | str | None) -> None:
    """Set (or with ``None`` clear) the process-wide default target."""
    if isinstance(target, str):
        target = get_target(target)
    _DEFAULT[0] = target
