"""Plan-driven execution of a whole transformer block.

:func:`run_block` is the runtime counterpart of ``registry.plan_block``:
it walks the planned segments in chain order and dispatches each one to
its bound executor — the GEMM executors for the QKV/output projections,
flash attention (Pallas on TPU, the jnp oracle elsewhere) for the
attention core, and the fused/partial/scan MLP executors for the MLP —
stitching the pre-norm residual structure (norms + residual adds) between
segments exactly like the hand-sequenced ``models/layers.py`` path.

Fallback contract: every binding is *requalified* at run time against the
actual platform and shapes (``ExecContext``).  A plan made on TPU runs
unchanged on CPU because each disqualified binding falls back, per
segment, to the highest-priority executor that does qualify — the XLA
reference path in the worst case.  Numerics: with every stage on its
reference executor the output is bitwise identical to the layer-per-layer
path; planned executors (scan tiling, Pallas kernels) agree within fp32
tolerance (pinned by ``tests/test_block_exec.py``).

Model-side imports (norm/rope live in ``repro.models.layers``) are lazy so
the planning half of ``repro.core.ftl`` stays importable on its own.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax.numpy as jnp

from repro import obs

from . import registry

# runtime-fallback telemetry: how often a plan's binding had to be
# rebound at run time, and how often the rebind landed on an XLA
# reference executor (the worst-case fallback the contract promises)
_C_REQUALIFIED = obs.counter(
    "ftl_requalified_total",
    "bindings rebound at run time (plan executor no longer qualified)",
    ("kind",))
_C_XLA_FALLBACK = obs.counter(
    "ftl_xla_fallback_total",
    "runtime rebinds that landed on an XLA reference executor", ("kind",))


def _runtime_ctx(
    plan: registry.BlockPlan,
    kind: str,
    schedule: str,
    m: int,
    dtype: str,
) -> registry.ExecContext:
    cfg = plan.cfg
    return registry.ExecContext(
        kind=kind,
        platform=registry.platform(),
        schedule=schedule,
        m=m,
        d_model=cfg.d_model,
        d_ff=cfg.moe_d_ff if cfg.is_moe else cfg.d_ff,
        dtype=dtype,
        gated=cfg.mlp_gated,
        act=cfg.mlp_act,
        target=plan.target,
        head_dim=cfg.resolved_head_dim,
        # requalification keeps the plan's regime: a decode plan must not
        # rebind a Pallas kernel just because the runtime shape probe ran
        phase=plan.phase,
    )


def _sub_schedule(plan: registry.BlockPlan, kind: str) -> str:
    if kind == "attention":
        sched = plan.attention_schedule
    elif kind == "mlp":
        sched = plan.mlp_schedule
    else:
        sched = plan.schedule
    if sched == "none":
        # the plan has no ops of this kind (e.g. an MLP-only block graph
        # asked for its attention stage): the attention core is always
        # executable fused (flash streams KV); the MLP conservatively
        # falls back to the layer-per-layer baseline
        return "fused" if kind == "attention" else "unfused"
    return sched


def _stage_executor(
    plan: registry.BlockPlan,
    kind: str,
    ctx: registry.ExecContext,
) -> registry.Executor:
    """The plan's bound executor for ``kind``, or the runtime fallback.

    All bindings of one kind share a single executor (qualification used
    the sub-chain schedule at plan time), so the first binding decides;
    when it no longer qualifies — planned on another platform, shapes
    changed — ``registry.find`` rebinds the best qualifying executor.
    """
    bound = None
    for b in plan.bindings:
        if b.kind == kind:
            ex = registry.get(b.executor)
            if ex.qualifies(ctx):
                return ex
            bound = b.executor
            break
    fb = registry.find(kind, ctx)
    if bound is not None and fb.name != bound:
        _C_REQUALIFIED.labels(kind=kind).inc()
        if fb.backend == "xla":
            _C_XLA_FALLBACK.labels(kind=kind).inc()
    return fb


def _bind_target(ex: registry.Executor, target) -> registry.Executor:
    """Pin the executor to the plan's own target: every run function (the
    Pallas kernels' block-size planning, the scan executors' token-tile
    choice) must price itself against the machine the plan was made for,
    not whatever the process default happens to be at run time."""
    return dataclasses.replace(
        ex,
        run=functools.partial(ex.run, target=target),
    )


def _resolve_gemm(plan, mode, m, dtype) -> registry.Executor:
    if mode == "off":
        return _bind_target(registry.get("xla_gemm"), plan.target)
    ctx = _runtime_ctx(plan, "gemm", plan.schedule, m, dtype)
    return _bind_target(_stage_executor(plan, "gemm", ctx), plan.target)


def _resolve_attention(plan, mode, m, dtype) -> registry.Executor:
    if mode == "off":
        # the baseline attention path was backend='auto': flash on TPU,
        # the jnp oracle elsewhere — exactly what a 'fused' qualification
        # resolves to
        ctx = _runtime_ctx(plan, "attention", "fused", m, dtype)
        return _bind_target(registry.find("attention", ctx), plan.target)
    ctx = _runtime_ctx(
        plan,
        "attention",
        _sub_schedule(plan, "attention"),
        m,
        dtype,
    )
    return _bind_target(_stage_executor(plan, "attention", ctx), plan.target)


def _resolve_mlp(
    plan,
    mode,
    m,
    dtype,
    *,
    d_model=None,
    d_ff=None,
    gated=None,
) -> registry.Executor:
    cfg = plan.cfg
    if mode in ("off", "fused", "scan"):
        # explicit override modes keep their historical meaning; the plan
        # stays authoritative only for 'auto'
        if d_model is None:
            d_model = cfg.d_model
        if d_ff is None:
            d_ff = cfg.moe_d_ff if cfg.is_moe else cfg.d_ff
        if gated is None:
            gated = cfg.mlp_gated
        return registry.mlp_executor(
            mode,
            m=m,
            d_model=d_model,
            d_ff=d_ff,
            dtype=dtype,
            gated=gated,
            act=cfg.mlp_act,
            target=plan.target,
        )
    ctx = _runtime_ctx(plan, "mlp", _sub_schedule(plan, "mlp"), m, dtype)
    return _bind_target(_stage_executor(plan, "mlp", ctx), plan.target)


# public names for the per-stage resolvers: the serving path
# (models.layers.mlp_layer with plan=) dispatches its MLP through the
# plan's binding exactly as run_block would, without running run_block
resolve_mlp = _resolve_mlp
resolve_attention = _resolve_attention
resolve_gemm = _resolve_gemm


def resolved_executors(
    plan: registry.BlockPlan,
    *,
    m: int | None = None,
    dtype: str | None = None,
) -> dict[str, str]:
    """Executor names :func:`run_block` would dispatch to right now.

    Reporting/diagnostics hook (serve stats, benchmarks): resolves each
    stage exactly as :func:`run_block` does — honoring ``cfg.ftl_mode``
    and requalifying the plan's bindings against the current platform at
    shape ``m``/``dtype`` (defaulting to the planned ones) — without
    executing anything.
    """
    m = m if m is not None else plan.m
    dtype = dtype or plan.dtype
    mode = plan.cfg.ftl_mode
    return {
        "gemm": _resolve_gemm(plan, mode, m, dtype).name,
        "attention": _resolve_attention(plan, mode, m, dtype).name,
        "mlp": _resolve_mlp(plan, mode, m, dtype).name,
    }


def _project(ex: registry.Executor, x, p: dict[str, Any]):
    """One planned projection GEMM (``linear`` routed through a binding)."""
    w = p["w"]
    if ex.backend == "pallas":
        # the Pallas GEMM kernel is 2-D; flatten leading dims around it
        *lead, k = x.shape
        y = ex.run(x.reshape(-1, k), w).reshape(*lead, w.shape[1])
    else:
        y = ex.run(x, w)
    if "b" in p:
        y = y + p["b"]
    return y


def run_block(
    plan: registry.BlockPlan,
    params: dict[str, Any],
    x,  # (B, S, D)
    *,
    positions=None,  # (S,) — defaults to arange(S)
    causal: bool = True,
    window: int | None = None,
    use_rope: bool = True,
    ftl_mode: str | None = None,  # overrides plan.cfg.ftl_mode
):
    """Execute one pre-norm transformer block per its :class:`BlockPlan`.

    ``params`` is one layer's parameter dict from ``models/model.py``
    (``ln1``/``attn``/``ln2``/``mlp``).  Stages present in ``params`` but
    absent from the plan (e.g. local attention of a hybrid config whose
    plannable block is MLP-only) execute through the runtime-fallback
    executor for their kind, so the block always runs end to end.

    ``cfg.ftl_mode`` (overridable per call via ``ftl_mode=``) keeps its
    pre-plan meaning as the escape hatch: with
    ``'off'`` every stage is pinned to the executors the hand-sequenced
    baseline used (plain XLA projections, unfused MLP, the platform's
    default attention kernel), so the compute graph is exactly the
    pre-plan one; ``'fused'``/``'scan'`` force that MLP executor; any
    other mode (``'auto'``) makes the plan's bindings authoritative.
    """
    from repro.distributed.act_sharding import constrain  # lazy: no cycle
    from repro.models import layers as L  # lazy: no cycle

    cfg = plan.cfg
    b, s, _ = x.shape
    dtype = str(x.dtype)
    mode = ftl_mode if ftl_mode is not None else cfg.ftl_mode

    # Per-stage spans carry the *resolved* executor in the name.  Under
    # jax.jit these time the trace/lowering of the stage, not device
    # execution (XLA fuses across stage boundaries); on the eager path
    # (and on every re-trace) they are the stage's wall-clock.
    if "attn" in params:
        nh, hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
        if positions is None:
            positions = jnp.arange(s)
        gemm_ex = _resolve_gemm(plan, mode, s, dtype)
        attn_ex = _resolve_attention(plan, mode, s, dtype)
        with obs.span(f"seg:attn:{attn_ex.name}", "exec"):
            ap = params["attn"]
            h = L.norm(params["ln1"], x, cfg.norm)
            q = L._split_heads(_project(gemm_ex, h, ap["wq"]), nh)
            k = L._split_heads(_project(gemm_ex, h, ap["wk"]), hk)
            v = L._split_heads(_project(gemm_ex, h, ap["wv"]), hk)
            if use_rope:
                q = L.rope(q, positions, cfg.rope_theta)
                k = L.rope(k, positions, cfg.rope_theta)
            q = constrain(q.transpose(0, 2, 1, 3), "heads_q")
            k = constrain(k.transpose(0, 2, 1, 3), "heads_kv")
            v = constrain(v.transpose(0, 2, 1, 3), "heads_kv")
            o = attn_ex.run(q, k, v, causal=causal, window=window)
            o = o.transpose(0, 2, 1, 3).reshape(b, s, nh * dh)
            x = constrain(x + _project(gemm_ex, o, ap["wo"]), "residual")

    if "mlp" in params:
        mp = params["mlp"]
        w1, w2 = mp["w1"]["w"], mp["w2"]["w"]
        mlp_ex = _resolve_mlp(
            plan,
            mode,
            s,
            dtype,
            d_model=w1.shape[0],
            d_ff=w1.shape[1],
            gated=mp.get("wg", {}).get("w") is not None,
        )
        with obs.span(f"seg:mlp:{mlp_ex.name}", "exec"):
            wg = mp.get("wg", {}).get("w")
            h = L.norm(params["ln2"], x, cfg.norm)
            y = mlp_ex.run(
                h,
                w1,
                w2,
                wg,
                mp["w1"].get("b"),
                mp["w2"].get("b"),
                act=cfg.mlp_act,
            )
            x = constrain(x + y, "residual")

    return x
