"""Graph-level op capture (paper step 3, generalized to whole blocks).

The seed planned three hand-built chains (MLP, attention, gemm_chain) with
per-chain entry points.  This module is the single capture layer above
them: an :class:`OpGraph` is a topologically ordered *op chain* over the
existing :class:`~repro.core.ftl.ir.OpNode` IR, lowered from any model in
the config zoo.  The fusion-partition optimizer (``partition.py``) then
chooses where to cut the chain; each contiguous segment becomes one
:class:`~repro.core.ftl.ir.FusionGroup` solved by the branch-and-bound
tile solver.

Two pieces of structure beyond a bare op list:

* ``repeats`` — per-op multiplicity.  The attention core (QKᵀ → softmax →
  ·V) is captured per head and planned once; its segment traffic/DMA
  scale by ``n_heads`` while its VMEM footprint does not (heads are an
  outer grid loop).
* ``barriers`` — chain positions where a cut is mandatory: head-split /
  head-merge reshapes (the tiling model cannot fuse through a layout
  change) and any position where the repeat factor changes.  They are
  derived automatically from ``repeats`` plus explicit reshape marks.

``block_graph`` lowers a full transformer block — QKV projections,
per-head attention core, output projection, and the (gated or plain) MLP
with an optional residual epilogue — from any ``configs/*`` entry.  The
output projection and the MLP live in the same token space with no
barrier between them, so the partitioner is free to fuse across the
attention/MLP boundary: a schedule no per-chain planner could express.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from . import fusion
from .fusion import GEMM_POLICY, HEADDIM_WHOLE
from .ir import (
    CollectiveNode,
    Dim,
    FusionGroup,
    OpNode,
    Role,
    TensorSpec,
    collective,
    elementwise,
    gemm,
)

__all__ = [
    "CollectiveNode", "OpGraph", "attention_graph", "block_graph",
    "collective", "gemm_act_graph", "gemm_chain_graph", "mlp_graph",
]


@dataclasses.dataclass(frozen=True)
class OpGraph:
    """An op chain ready for fusion partitioning.

    ``ops`` are in topological (execution) order.  ``repeats[i]`` is the
    multiplicity of ``ops[i]`` (1 for token-space ops, ``n_heads`` for the
    per-head attention core).  ``barriers`` are the cut positions
    ``1 <= b < len(ops)`` where a segment boundary is mandatory.
    """

    name: str
    ops: tuple[OpNode, ...]
    dims: tuple[Dim, ...]
    repeats: tuple[int, ...] = ()
    barriers: frozenset[int] = frozenset()

    def __post_init__(self):
        if not self.ops:
            raise ValueError(f"graph {self.name}: empty op chain")
        if not self.repeats:
            object.__setattr__(self, "repeats", (1,) * len(self.ops))
        if len(self.repeats) != len(self.ops):
            raise ValueError(
                f"graph {self.name}: {len(self.repeats)} repeats for "
                f"{len(self.ops)} ops"
            )
        # a repeat change is always a layout boundary -> mandatory cut
        derived = {
            i
            for i in range(1, len(self.ops))
            if self.repeats[i] != self.repeats[i - 1]
        }
        object.__setattr__(self, "barriers", frozenset(self.barriers) | derived)

    # ------------------------------------------------------------------
    @property
    def n_ops(self) -> int:
        return len(self.ops)

    def dim_map(self) -> dict[str, Dim]:
        return {d.name: d for d in self.dims}

    def total_flops(self) -> int:
        """Modeled FLOPs of the whole chain, multiplicity included:
        Σ_i repeats[i] · ops[i].flops.  Partition-invariant — every
        partition of this graph runs exactly this much arithmetic, so
        chains only differ in how much of it each segment's transfer
        time hides."""
        sizes = {d.name: d.size for d in self.dims}
        return sum(r * op.flops(sizes)
                   for r, op in zip(self.repeats, self.ops))

    def repeat(self, lo: int, hi: int) -> int:
        """Uniform multiplicity of segment ``ops[lo:hi]``."""
        reps = set(self.repeats[lo:hi])
        if len(reps) != 1:
            raise ValueError(
                f"graph {self.name}: segment [{lo}, {hi}) mixes repeats {reps}"
            )
        return reps.pop()

    def crosses_barrier(self, lo: int, hi: int) -> bool:
        return any(lo < b < hi for b in self.barriers)

    # ------------------------------------------------------------------
    def group(self, lo: int, hi: int) -> FusionGroup:
        """Bind ``ops[lo:hi]`` into one :class:`FusionGroup`.

        Role rebinding generalizes ``fusion._collect``: a tensor produced
        and consumed inside the segment is fused away (INTERMEDIATE); one
        produced inside but consumed later (or never) streams out
        (OUTPUT); one consumed but produced in an earlier segment streams
        in (INPUT).  Weights stay WEIGHT.
        """
        if not (0 <= lo < hi <= self.n_ops):
            raise ValueError(f"bad segment [{lo}, {hi})")
        if self.crosses_barrier(lo, hi):
            raise ValueError(
                f"graph {self.name}: segment [{lo}, {hi}) spans a barrier"
            )
        seg = self.ops[lo:hi]
        produced = {op.output.name for op in seg}
        consumed = {t.name for op in seg for t in op.inputs}
        # a tensor read by any op outside the segment must still stream to
        # HBM even if a consumer inside the segment exists — only tensors
        # whose every consumer is inside the segment fuse away
        consumed_outside = {
            t.name
            for op in self.ops[:lo] + self.ops[hi:]
            for t in op.inputs
        }
        tensors: dict[str, TensorSpec] = {}
        for op in seg:
            for t in op.tensors():
                if (t.name in produced and t.name in consumed
                        and t.name not in consumed_outside):
                    t = dataclasses.replace(t, role=Role.INTERMEDIATE)
                elif t.name in produced:
                    t = dataclasses.replace(t, role=Role.OUTPUT)
                elif t.role is not Role.WEIGHT:
                    t = dataclasses.replace(t, role=Role.INPUT)
                tensors[t.name] = t
        used = {d for op in seg for t in op.tensors() for d in t.dims}
        dim_map = {k: v for k, v in self.dim_map().items() if k in used}
        name = self.name if (lo, hi) == (0, self.n_ops) else (
            f"{self.name}[{lo}:{hi}]"
        )
        g = FusionGroup(name=name, ops=list(seg), dims=dim_map,
                        tensors=tensors)
        g.validate()
        return g

    def validate(self) -> None:
        """Chain sanity: dims known, producers precede consumers."""
        known = {d.name for d in self.dims}
        all_outputs = {op.output.name for op in self.ops}
        seen_outputs: set[str] = set()
        for op in self.ops:
            for t in op.tensors():
                for d in t.dims:
                    if d not in known:
                        raise ValueError(
                            f"graph {self.name}: op {op.name} uses unknown "
                            f"dim {d}"
                        )
            for t in op.inputs:
                # inputs produced inside the chain must come from an
                # earlier op; anything else is an external tensor
                if t.name in all_outputs and t.name not in seen_outputs:
                    raise ValueError(
                        f"graph {self.name}: op {op.name} consumes "
                        f"{t.name} before it is produced"
                    )
            seen_outputs.add(op.output.name)


# ---------------------------------------------------------------------------
# chain capture: the hand-built chains, now as graphs
# ---------------------------------------------------------------------------

def mlp_graph(
    *,
    m: int,
    d_model: int,
    d_ff: int,
    dtype: str = "bfloat16",
    gated: bool = False,
    act: str = "gelu",
    residual: bool = False,
    name: str = "mlp",
) -> OpGraph:
    """Transformer MLP as an op chain; optional residual-add epilogue."""
    ops, dims = fusion.mlp_ops(m=m, d_model=d_model, d_ff=d_ff, dtype=dtype,
                               gated=gated, act=act)
    if residual:
        res = TensorSpec("res", ("M", "N"), dtype, Role.INPUT)
        out = TensorSpec("y_res", ("M", "N"), dtype, Role.OUTPUT)
        ops.append(elementwise("residual", [ops[-1].output, res], out))
    return OpGraph(name=name, ops=tuple(ops), dims=tuple(dims))


def gemm_act_graph(
    *, m: int, k: int, n: int, dtype: str = "bfloat16", act: str = "gelu",
    name: str = "gemm_act",
) -> OpGraph:
    """The paper's ViT-MLP benchmark chain: GEMM → activation."""
    ops, dims = fusion.gemm_act_ops(m=m, k=k, n=n, dtype=dtype, act=act)
    return OpGraph(name=name, ops=tuple(ops), dims=tuple(dims))


def attention_graph(
    *, q_len: int, kv_len: int, head_dim: int, dtype: str = "bfloat16",
    heads: int = 1, name: str = "attention",
) -> OpGraph:
    """One-head attention core chain (multiplicity ``heads``)."""
    ops, dims = fusion.attention_ops(q_len=q_len, kv_len=kv_len,
                                     head_dim=head_dim, dtype=dtype)
    return OpGraph(name=name, ops=tuple(ops), dims=tuple(dims),
                   repeats=(heads,) * len(ops))


def gemm_chain_graph(
    *, m: int, dims_kn: Sequence[int], dtype: str = "bfloat16",
    name: str = "gemm_chain",
) -> OpGraph:
    """Generic back-to-back GEMM chain."""
    ops, dims = fusion.gemm_chain_ops(m=m, dims_kn=dims_kn, dtype=dtype)
    return OpGraph(name=name, ops=tuple(ops), dims=tuple(dims))


# ---------------------------------------------------------------------------
# whole-block capture from a ModelConfig
# ---------------------------------------------------------------------------

def block_graph(
    cfg,
    *,
    m: int,
    dtype: str | None = None,
    residual: bool = True,
    name: str | None = None,
) -> OpGraph:
    """Lower one transformer block of ``cfg`` into a single op chain.

    Chain: QKV projections → [barrier] → per-head attention core
    (repeat = n_heads) → [barrier] → output projection → MLP (gated or
    plain, per-expert dims for MoE) → optional residual epilogue.

    Barriers sit at the head-split/head-merge reshapes; everything in
    token space (projections, MLP) is fair game for the partitioner,
    including fusing the output projection into the MLP up-GEMM.

    Families without a standard attention block (``ssm``) lower only the
    MLP part; configs with neither attention nor an MLP raise
    ``ValueError``.
    """
    dt = dtype or cfg.dtype
    d = cfg.d_model
    if cfg.is_moe:
        d_ff, gated = cfg.moe_d_ff, cfg.mlp_gated
    else:
        d_ff, gated = cfg.d_ff, cfg.mlp_gated
    has_attn = cfg.block_kind(0) in ("attn", "cross", "local")
    has_mlp = d_ff > 0

    ops: list[OpNode] = []
    repeats: list[int] = []
    dims: list[Dim] = []
    mlp_in: TensorSpec | None = None

    if has_attn:
        h, hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
        dims += [
            Dim("M", m), Dim("K", d), Dim("DQ", h * dh), Dim("DKV", hk * dh),
            Dim("Tk", m), Dim("Dh", dh), Dim("O", h * dh), Dim("N", d),
        ]
        x = TensorSpec("x", ("M", "K"), dt, Role.INPUT)
        wq = TensorSpec("wq", ("K", "DQ"), dt, Role.WEIGHT)
        wk = TensorSpec("wk", ("K", "DKV"), dt, Role.WEIGHT)
        wv = TensorSpec("wv", ("K", "DKV"), dt, Role.WEIGHT)
        q = TensorSpec("q", ("M", "DQ"), dt, Role.OUTPUT)
        kp = TensorSpec("k_proj", ("M", "DKV"), dt, Role.OUTPUT)
        vp = TensorSpec("v_proj", ("M", "DKV"), dt, Role.OUTPUT)
        ops += [
            gemm("proj.wq", x, wq, q, contract="K", policy=GEMM_POLICY),
            gemm("proj.wk", x, wk, kp, contract="K", policy=GEMM_POLICY),
            gemm("proj.wv", x, wv, vp, contract="K", policy=GEMM_POLICY),
        ]
        repeats += [1, 1, 1]
        # --- head-split reshape boundary; the core is planned per head ----
        qh = TensorSpec("q_head", ("M", "Dh"), dt, Role.INPUT)
        kh = TensorSpec("k_head", ("Tk", "Dh"), dt, Role.INPUT)
        vh = TensorSpec("v_head", ("Tk", "Dh"), dt, Role.INPUT)
        s = TensorSpec("s", ("M", "Tk"), "float32", Role.OUTPUT)
        p = TensorSpec("p", ("M", "Tk"), dt, Role.OUTPUT)
        oh = TensorSpec("o_head", ("M", "Dh"), dt, Role.OUTPUT)
        ops += [
            gemm("attn.qk", qh, kh, s, contract="Dh", policy=HEADDIM_WHOLE),
            elementwise("attn.softmax", [s], p),
            gemm("attn.pv", p, vh, oh, contract="Tk", policy=GEMM_POLICY),
        ]
        repeats += [h, h, h]
        # --- head-merge reshape boundary; back to token space -------------
        o = TensorSpec("o", ("M", "O"), dt, Role.INPUT)
        wo = TensorSpec("wo", ("O", "N"), dt, Role.WEIGHT)
        ao = TensorSpec("attn_out", ("M", "N"), dt, Role.OUTPUT)
        ops.append(gemm("proj.wo", o, wo, ao, contract="O",
                        policy=GEMM_POLICY))
        repeats.append(1)
        mlp_in = ao
    elif has_mlp:
        dims += [Dim("M", m), Dim("N", d)]
        mlp_in = TensorSpec("x", ("M", "N"), dt, Role.INPUT)

    if has_mlp:
        dims += [Dim("F", d_ff), Dim("N2", d)]
        w1 = TensorSpec("w1", ("N", "F"), dt, Role.WEIGHT)
        w2 = TensorSpec("w2", ("F", "N2"), dt, Role.WEIGHT)
        h1 = TensorSpec("mlp_h1", ("M", "F"), dt, Role.OUTPUT)
        hmid = TensorSpec("mlp_h", ("M", "F"), dt, Role.OUTPUT)
        y = TensorSpec("mlp_y", ("M", "N2"), dt, Role.OUTPUT)
        ops.append(gemm("mlp.gemm1", mlp_in, w1, h1, contract="N",
                        policy=GEMM_POLICY))
        repeats.append(1)
        if gated:
            wg = TensorSpec("wg", ("N", "F"), dt, Role.WEIGHT)
            hg = TensorSpec("mlp_hg", ("M", "F"), dt, Role.OUTPUT)
            ops.append(gemm("mlp.gemm_gate", mlp_in, wg, hg, contract="N",
                            policy=GEMM_POLICY))
            ops.append(elementwise(f"mlp.{cfg.mlp_act}_mul", [h1, hg], hmid))
            repeats += [1, 1]
        else:
            ops.append(elementwise(f"mlp.{cfg.mlp_act}", [h1], hmid))
            repeats.append(1)
        ops.append(gemm("mlp.gemm2", hmid, w2, y, contract="F",
                        policy=GEMM_POLICY))
        repeats.append(1)
        if residual:
            res = TensorSpec("res", ("M", "N2"), dt, Role.INPUT)
            out = TensorSpec("block_out", ("M", "N2"), dt, Role.OUTPUT)
            ops.append(elementwise("mlp.residual", [y, res], out))
            repeats.append(1)

    if not ops:
        raise ValueError(
            f"config {cfg.name}: no plannable block (no attention, no MLP)"
        )
    g = OpGraph(name=name or f"block.{cfg.name}", ops=tuple(ops),
                dims=tuple(dims), repeats=tuple(repeats))
    g.validate()
    return g
