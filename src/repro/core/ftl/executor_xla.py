"""Backend-agnostic execution of FTL plans via XLA scan tiling.

The Pallas kernels (src/repro/kernels) are the TPU-native executors of a
:class:`TilePlan`.  This module is the portable fallback: it executes the
same fused schedule with ``lax.scan`` over token tiles, so the intermediate
``(tile_m, d_ff)`` block is the only live instance of the MLP hidden state.

What this buys on any backend (visible in ``memory_analysis()``):
  * peak activation memory drops from O(M·d_ff) to O(tile_m·d_ff);
  * at 32 k-token prefill of the large configs the full intermediate would
    not even fit HBM per device (DESIGN.md §2's "L2 overflow" analogue).

What it cannot buy (and the Pallas kernels can): XLA still spills each
per-tile intermediate to HBM between the two GEMMs inside the loop body, so
*traffic* is unchanged — exactly the paper's argument for explicit fusion
on software-managed memories.  See DESIGN.md §9 for how this is reported.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from .plan import TilePlan

_ACTS: dict[str, Callable] = {
    "gelu": partial(jax.nn.gelu, approximate=True),
    "gelu_exact": partial(jax.nn.gelu, approximate=False),
    "silu": jax.nn.silu,
    "relu": jax.nn.relu,
    "identity": lambda x: x,
}


def activation(name: str) -> Callable:
    try:
        return _ACTS[name]
    except KeyError as e:
        raise ValueError(f"unknown activation {name!r}") from e


def mlp_scan(
    x: jax.Array,
    w1: jax.Array,
    w2: jax.Array,
    wg: jax.Array | None = None,
    b1: jax.Array | None = None,
    b2: jax.Array | None = None,
    *,
    act: str = "gelu",
    tile_m: int,
    precision=jax.lax.Precision.DEFAULT,
) -> jax.Array:
    """Fused-schedule MLP: scan over tiles of the token dim.

    ``x``: (..., M, K);  ``w1``/``wg``: (K, F);  ``w2``: (F, N).
    ``tile_m`` must divide M (the FTL solver only emits divisors).
    """
    *lead, m, k = x.shape
    if m % tile_m != 0:
        raise ValueError(f"tile_m={tile_m} does not divide M={m}")
    n_tiles = m // tile_m
    act_fn = activation(act)

    xt = x.reshape(*lead, n_tiles, tile_m, k)
    # scan over the tile axis; moveaxis so scan's carry axis is leading.
    xt = jnp.moveaxis(xt, -3, 0)

    def body(_, xm):
        h = jnp.matmul(xm, w1, precision=precision)
        if b1 is not None:
            h = h + b1
        h = act_fn(h)
        if wg is not None:
            h = h * jnp.matmul(xm, wg, precision=precision)
        y = jnp.matmul(h, w2, precision=precision)
        if b2 is not None:
            y = y + b2
        return None, y.astype(x.dtype)

    _, yt = jax.lax.scan(body, None, xt)
    yt = jnp.moveaxis(yt, 0, -3)
    return yt.reshape(*lead, m, w2.shape[-1])


def mlp_partial_scan(
    x: jax.Array,
    w1: jax.Array,
    w2: jax.Array,
    wg: jax.Array | None = None,
    b1: jax.Array | None = None,
    b2: jax.Array | None = None,
    *,
    act: str = "gelu",
    tile_m: int,
    precision=jax.lax.Precision.DEFAULT,
) -> jax.Array:
    """Partial-schedule MLP: up-projection (+gate+act) scanned over token
    tiles, hidden tensor materialized once, down GEMM un-tiled.

    The portable analogue of the planner's 'partial' schedule: GEMM2's
    tiling is unconstrained by GEMM1's, at the cost of one full (M, F)
    round trip."""
    *lead, m, k = x.shape
    if m % tile_m != 0:
        raise ValueError(f"tile_m={tile_m} does not divide M={m}")
    n_tiles = m // tile_m
    act_fn = activation(act)

    xt = jnp.moveaxis(x.reshape(*lead, n_tiles, tile_m, k), -3, 0)

    def up(_, xm):
        h = jnp.matmul(xm, w1, precision=precision)
        if b1 is not None:
            h = h + b1
        h = act_fn(h)
        if wg is not None:
            h = h * jnp.matmul(xm, wg, precision=precision)
        return None, h.astype(x.dtype)

    _, ht = jax.lax.scan(up, None, xt)
    h = jnp.moveaxis(ht, 0, -3).reshape(*lead, m, w1.shape[-1])
    y = jnp.matmul(h, w2, precision=precision)
    if b2 is not None:
        y = y + b2
    return y.astype(x.dtype)


def mlp_from_plan(
    plan: TilePlan,
    x: jax.Array,
    w1: jax.Array,
    w2: jax.Array,
    wg: jax.Array | None = None,
    b1: jax.Array | None = None,
    b2: jax.Array | None = None,
    *,
    act: str = "gelu",
) -> jax.Array:
    """Execute an ``fusion.mlp`` plan with the scan executor (M tiling)."""
    return mlp_scan(x, w1, w2, wg, b1, b2, act=act, tile_m=plan.tile("M"))
