"""Fusion-partition optimization over an op chain (beyond-paper step 3½).

The paper fuses one manually chosen pair of layers; the seed generalized
that to a three-way MLP choice (fused / partial / unfused).  This module
subsumes both: given an :class:`~repro.core.ftl.graph.OpGraph`, it
enumerates every *contiguous partition* of the chain (LoopTree-style), has
the branch-and-bound tile solver price each candidate segment on the
planning :class:`~repro.core.hw.Target`, and runs a dynamic program over
cut points to pick the globally runtime-minimal schedule.

For an ``n``-op chain there are ``2^(n-1)`` partitions but only
``n·(n+1)/2`` distinct segments, so the DP solves each segment once and
composes:

    best[i] = min over j < i of  best[j] + cost(segment ops[j:i])

Segments that violate a barrier (head-split reshape, repeat change) or
whose tiling problem is infeasible on the target are skipped.  The cost
of a segment is its solved modeled *roofline runtime* —
``max(compute_time, transfer_time)``, compute from ``Target.flops``,
transfer per-level bytes/bw + transfers·dma_setup — times its
multiplicity (per-head segments run once per head), with (traffic, DMA
count, segment count) as the tie-break.  The tie-break is load-bearing:
fusing a compute-bound segment buys no runtime, so the DP only keeps a
fusion there when it also does not cost bytes.

``plan_fixed`` prices one specific partition — the hook the benchmarks
use to reproduce the paper's fused-vs-unfused table regardless of which
schedule the DP prefers.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Iterable, Mapping

from repro import obs
from repro.core import hw as hwlib

from .graph import OpGraph
from .plan import TilePlan
from .solver import InfeasibleError, solve

# planner telemetry (repro.obs): spans around the DP entry points (they
# also time cache hits — a hit is a few-µs span, a miss a solver run)
# and candidate-segment counters inside the pricing loop.
_C_PRICED = obs.counter(
    "ftl_planner_segments_priced_total",
    "candidate segments priced by the tile solver", ("graph",))
_C_INFEASIBLE = obs.counter(
    "ftl_planner_segments_infeasible_total",
    "candidate segments rejected as infeasible", ("graph",))


@dataclasses.dataclass(frozen=True)
class Segment:
    """One planned contiguous piece of the chain."""

    lo: int
    hi: int
    repeat: int
    plan: TilePlan

    @property
    def traffic_bytes(self) -> int:
        return self.plan.traffic_bytes * self.repeat

    @property
    def dma_transfers(self) -> int:
        return self.plan.dma_transfers * self.repeat

    @property
    def vmem_bytes(self) -> int:
        return self.plan.vmem_bytes

    @property
    def transfer_time_s(self) -> float:
        return self.plan.transfer_time_s * self.repeat

    @property
    def compute_time_s(self) -> float:
        return self.plan.compute_time_s * self.repeat

    @property
    def modeled_runtime_s(self) -> float:
        """max(compute, transfer) per run, times the multiplicity."""
        return self.plan.modeled_runtime_s * self.repeat

    @property
    def compute_bound(self) -> bool:
        return self.plan.report.compute_bound

    @property
    def n_steps(self) -> int:
        """Tile steps of one run of this segment's schedule."""
        return self.plan.n_steps

    @property
    def per_engine_compute_s(self) -> dict[str, float]:
        """Engine-serialized compute seconds, multiplicity included."""
        return {e: t * self.repeat
                for e, t in self.plan.per_engine_compute_s.items()}

    @property
    def per_level_traffic(self) -> dict[str, int]:
        return {name: b * self.repeat
                for name, b in self.plan.per_level_traffic.items()}

    def op_names(self) -> tuple[str, ...]:
        return tuple(op.name for op in self.plan.group.ops)


@dataclasses.dataclass(frozen=True)
class ChainPlan:
    """A fully planned partition of an op chain."""

    graph: OpGraph
    segments: tuple[Segment, ...]
    target: hwlib.Target

    @property
    def vmem_budget(self) -> int:
        return self.target.fast_capacity

    @property
    def traffic_bytes(self) -> int:
        return sum(s.traffic_bytes for s in self.segments)

    @property
    def dma_transfers(self) -> int:
        return sum(s.dma_transfers for s in self.segments)

    @property
    def transfer_time_s(self) -> float:
        return sum(s.transfer_time_s for s in self.segments)

    @property
    def compute_time_s(self) -> float:
        return sum(s.compute_time_s for s in self.segments)

    @property
    def modeled_runtime_s(self) -> float:
        """The DP's objective: Σ_segment max(compute, transfer) — segments
        execute sequentially, each overlapping its own DMA."""
        return sum(s.modeled_runtime_s for s in self.segments)

    @property
    def compute_bound(self) -> bool:
        """True when compute dominates every segment of the plan."""
        return all(s.compute_bound for s in self.segments)

    @property
    def per_engine_compute_s(self) -> dict[str, float]:
        """Engine-serialized compute seconds, summed over segments."""
        out: dict[str, float] = {}
        for s in self.segments:
            for e, t in s.per_engine_compute_s.items():
                out[e] = out.get(e, 0.0) + t
        return out

    @property
    def per_level_traffic(self) -> dict[str, int]:
        """Modeled traffic per backing level, summed over segments."""
        out: dict[str, int] = {}
        for s in self.segments:
            for name, b in s.per_level_traffic.items():
                out[name] = out.get(name, 0) + b
        return out

    @property
    def vmem_bytes(self) -> int:
        """Peak fast-memory use: segments execute sequentially."""
        return max(s.vmem_bytes for s in self.segments)

    def cuts(self) -> tuple[int, ...]:
        return tuple(s.lo for s in self.segments[1:])

    @property
    def schedule(self) -> str:
        """Three-way label compatible with the seed's MLP auto-planner."""
        if len(self.segments) == 1:
            return "fused"
        if len(self.segments) == self.graph.n_ops:
            return "unfused"
        return "partial"

    def segment_of(self, op_name: str) -> Segment:
        for s in self.segments:
            if op_name in s.op_names():
                return s
        raise KeyError(op_name)

    def summary(self) -> str:
        MB = 1 << 20
        per_level = ", ".join(
            f"{name}={b / MB:.2f} MiB"
            for name, b in self.per_level_traffic.items()
        )
        lines = [
            f"FTL chain plan '{self.graph.name}' on target "
            f"'{self.target.name}': {self.schedule} "
            f"({len(self.segments)} segment(s), cuts at {self.cuts()})",
            f"  traffic : {self.traffic_bytes / MB:.2f} MiB over "
            f"{self.dma_transfers} DMA transfers ({per_level})",
            f"  time    : {1e3 * self.modeled_runtime_s:.3f} ms modeled "
            f"runtime (compute {1e3 * self.compute_time_s:.3f} ms, "
            f"transfer {1e3 * self.transfer_time_s:.3f} ms; "
            f"{'compute' if self.compute_bound else 'transfer'}-bound)",
            f"  {self.target.fast.name:7s} : "
            f"{self.vmem_bytes / MB:.2f} MiB peak / "
            f"{self.vmem_budget / MB:.2f} MiB budget",
        ]
        for s in self.segments:
            rep = f" x{s.repeat}" if s.repeat > 1 else ""
            lines.append(
                f"  [{s.lo}:{s.hi}]{rep} {'+'.join(s.op_names())}: "
                f"{s.traffic_bytes / MB:.2f} MiB"
            )
        return "\n".join(lines)


def _freeze(d: Mapping[str, int] | None) -> tuple | None:
    return tuple(sorted(d.items())) if d else None


def _solve_segment(
    graph: OpGraph,
    lo: int,
    hi: int,
    target: hwlib.Target,
    sharded: tuple | None,
) -> Segment | None:
    """Price one segment; None when infeasible on the target."""
    _C_PRICED.labels(graph=graph.name).inc()
    try:
        with obs.span(f"solve[{lo}:{hi}]", "planner"):
            plan = solve(
                graph.group(lo, hi),
                target=target,
                sharded_sizes=dict(sharded) if sharded else None,
            )
    except InfeasibleError:
        _C_INFEASIBLE.labels(graph=graph.name).inc()
        return None
    return Segment(lo=lo, hi=hi, repeat=graph.repeat(lo, hi), plan=plan)


@functools.lru_cache(maxsize=256)
def _plan_chain_cached(
    graph: OpGraph, target: hwlib.Target, sharded: tuple | None
) -> ChainPlan:
    n = graph.n_ops
    seg: dict[tuple[int, int], Segment | None] = {}
    for lo in range(n):
        for hi in range(lo + 1, n + 1):
            if graph.crosses_barrier(lo, hi):
                continue
            seg[(lo, hi)] = _solve_segment(graph, lo, hi, target, sharded)

    # DP over cut points; key = (runtime, traffic, dma, n_segments) so
    # the objective matches the solver's and ties resolve
    # deterministically — in particular an all-compute-bound chain ties
    # on runtime and the partition moving the fewest bytes wins.  The
    # runtime component is compared through hw.round_time so partitions
    # of mathematically equal runtime (Σ flops_i/F vs (Σ flops_i)/F)
    # actually reach the tie-breaks instead of being split by float ulps.
    def ckey(k: tuple) -> tuple:
        return (hwlib.round_time(k[0]),) + k[1:]

    best: list[tuple[tuple, tuple[Segment, ...]] | None]
    best = [None] * (n + 1)
    best[0] = ((0.0, 0, 0, 0), ())
    for hi in range(1, n + 1):
        for lo in range(hi):
            prev = best[lo]
            s = seg.get((lo, hi))
            if prev is None or s is None:
                continue
            (pt, ptr, pd, pn), psegs = prev
            key = (pt + s.modeled_runtime_s, ptr + s.traffic_bytes,
                   pd + s.dma_transfers, pn + 1)
            if best[hi] is None or ckey(key) < ckey(best[hi][0]):
                best[hi] = (key, psegs + (s,))
    if best[n] is None:
        raise InfeasibleError(
            f"graph {graph.name}: no partition fits the "
            f"{target.fast_capacity} B {target.fast.name} of target "
            f"{target.name}"
        )
    return ChainPlan(graph=graph, segments=best[n][1], target=target)


def plan_chain(
    graph: OpGraph,
    *,
    target: hwlib.Target | None = None,
    sharded_sizes: Mapping[str, int] | None = None,
) -> ChainPlan:
    """Globally runtime-minimal fusion partition of ``graph`` on
    ``target`` (None → the default target): minimizes
    Σ_segment max(compute_time, transfer_time) with (traffic, DMA count,
    segment count) tie-breaks."""
    target = target if target is not None else hwlib.default_target()
    with obs.span("plan_chain", "planner"):
        return _plan_chain_cached(graph, target, _freeze(sharded_sizes))


@functools.lru_cache(maxsize=64)
def _plan_chain_top_k_cached(
    graph: OpGraph, target: hwlib.Target, sharded: tuple | None, k: int
) -> tuple[ChainPlan, ...]:
    n = graph.n_ops
    seg: dict[tuple[int, int], Segment | None] = {}
    for lo in range(n):
        for hi in range(lo + 1, n + 1):
            if graph.crosses_barrier(lo, hi):
                continue
            seg[(lo, hi)] = _solve_segment(graph, lo, hi, target, sharded)

    def ckey(key: tuple) -> tuple:
        return (hwlib.round_time(key[0]),) + key[1:]

    # k-best DP: best[i] holds up to k (key, segments) entries for the
    # prefix ops[0:i], ordered by the same rounded-runtime key as
    # plan_chain.  Per-prefix truncation is exact for an additive
    # objective (the j-th best plan of a prefix extends an ≤ j-th best
    # plan of a shorter prefix).  Candidates are generated rank-major
    # (every prefix-entry-0 composition, lo-ascending, before any
    # entry-1 composition) and ranked with a *stable* sort, so entry 0
    # ties exactly like plan_chain's lo-ascending strict-< incumbent
    # rule — entry 0 of the result is always the plan plan_chain
    # returns.  Entries of one prefix have pairwise-distinct cut sets by
    # construction (distinct (lo, prefix-entry) pairs extend to distinct
    # cut sets).
    best: list[list[tuple[tuple, tuple[Segment, ...]]]]
    best = [[] for _ in range(n + 1)]
    best[0] = [((0.0, 0, 0, 0), ())]
    for hi in range(1, n + 1):
        cands: list[tuple[tuple, tuple[Segment, ...]]] = []
        for rank in range(k):
            for lo in range(hi):
                s = seg.get((lo, hi))
                if s is None or rank >= len(best[lo]):
                    continue
                (pt, ptr, pd, pn), psegs = best[lo][rank]
                key = (pt + s.modeled_runtime_s, ptr + s.traffic_bytes,
                       pd + s.dma_transfers, pn + 1)
                cands.append((key, psegs + (s,)))
        cands.sort(key=lambda e: ckey(e[0]))
        best[hi] = cands[:k]
    if not best[n]:
        raise InfeasibleError(
            f"graph {graph.name}: no partition fits the "
            f"{target.fast_capacity} B {target.fast.name} of target "
            f"{target.name}"
        )
    return tuple(
        ChainPlan(graph=graph, segments=segs, target=target)
        for _, segs in best[n]
    )


def plan_chain_top_k(
    graph: OpGraph,
    *,
    target: hwlib.Target | None = None,
    sharded_sizes: Mapping[str, int] | None = None,
    k: int = 1,
) -> tuple[ChainPlan, ...]:
    """The ``k`` best fusion partitions of ``graph`` on ``target``,
    best-first under :func:`plan_chain`'s exact objective — the
    autotuner's analytic shortlist.  Entry 0 is always the partition
    :func:`plan_chain` returns; fewer than ``k`` feasible partitions
    return them all."""
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    target = target if target is not None else hwlib.default_target()
    with obs.span("plan_chain_top_k", "planner"):
        return _plan_chain_top_k_cached(graph, target,
                                        _freeze(sharded_sizes), k)


def plan_fixed(
    graph: OpGraph,
    cuts: Iterable[int],
    *,
    target: hwlib.Target | None = None,
    sharded_sizes: Mapping[str, int] | None = None,
) -> ChainPlan:
    """Price one specific partition given by ``cuts`` (positions 1..n-1).

    Mandatory barriers are added automatically.  Raises
    :class:`InfeasibleError` if any segment has no feasible tiling.
    """
    target = target if target is not None else hwlib.default_target()
    n = graph.n_ops
    cut_set = set(cuts) | set(graph.barriers)
    if any(c < 1 or c >= n for c in cut_set):
        raise ValueError(f"cuts {sorted(cut_set)} out of range for {n} ops")
    bounds = [0] + sorted(cut_set) + [n]
    sharded = _freeze(sharded_sizes)
    segments = []
    for lo, hi in zip(bounds, bounds[1:]):
        s = _solve_segment(graph, lo, hi, target, sharded)
        if s is None:
            raise InfeasibleError(
                f"graph {graph.name}: segment [{lo}, {hi}) does not fit "
                f"the {target.fast_capacity} B {target.fast.name} of "
                f"target {target.name}"
            )
        segments.append(s)
    return ChainPlan(graph=graph, segments=tuple(segments), target=target)


def all_cuts(graph: OpGraph) -> tuple[int, ...]:
    """The layer-per-layer partition of ``graph``."""
    return tuple(range(1, graph.n_ops))
