"""FTL constraint construction (paper step 2).

Three constraint families from the paper, plus the sharding family we add
for the multi-chip setting (DESIGN.md §2):

* geometric      — dim variables linked across tensors of one op (handled
                   structurally by the IR: linked dims share one name).
* kernel-policy  — what the kernel dataflow permits: whole-vs-accumulated
                   contractions, VREG/MXU alignment lattice.
* performance    — minimum tile sizes that keep the MXU fed.
* sharding       — tile domains restricted to the per-shard dim sizes.

The output of this module is, per dim, a *candidate tile domain* plus flags
the solver/cost model needs (is-contract, needs-accumulator).
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

from .ir import (
    FusionGroup,
    Role,
    TensorSpec,
    aligned_divisors,
    dtype_bytes,
)

# Max candidates per dim fed to the solver (log-spaced thin-out beyond this).
_MAX_CANDIDATES = 14


@dataclasses.dataclass
class DimConstraint:
    """Solved-out constraint record for one dim variable."""

    name: str
    size: int
    candidates: tuple[int, ...]      # legal tile sizes (ascending)
    is_contract: bool                # reduced by at least one op
    contract_whole: bool             # some op forbids tiling this contraction
    alignment: int                   # lattice the candidates respect
    min_tile: int


def _dim_alignment(group: FusionGroup, dim: str) -> tuple[int, int]:
    """(alignment, min_tile) for ``dim`` = strictest requirement over every
    tensor position it occupies.

    Last-axis occurrences demand lane alignment (128); second-minor demand
    sublane alignment (8 for 4-byte dtypes, 16 for 2-byte, 32 for 1-byte).
    """
    align = 1
    min_tile = 1
    for op in group.ops:
        pol = op.policy
        for t in op.tensors():
            if dim not in t.dims:
                continue
            pos = len(t.dims) - 1 - t.dims[::-1].index(dim)
            if pos == len(t.dims) - 1:
                align = max(align, pol.lane_align)
                min_tile = max(min_tile, pol.min_tile)
            elif pos == len(t.dims) - 2:
                # 4-byte -> 8, 2-byte -> 16, 1-byte -> 32 sublanes
                sub = {4: 8, 2: 16, 1: 32}.get(dtype_bytes(t.dtype), 8)
                sub = max(sub, pol.sublane_align)
                align = max(align, sub)
                min_tile = max(min_tile, pol.min_tile)
    return align, min_tile


def _thin(cands: list[int], limit: int = _MAX_CANDIDATES) -> tuple[int, ...]:
    if len(cands) <= limit:
        return tuple(cands)
    # keep endpoints, log-space the middle
    keep = {cands[0], cands[-1]}
    n = len(cands)
    for i in range(limit):
        keep.add(cands[min(n - 1, int(round(i * (n - 1) / (limit - 1))))])
    return tuple(sorted(keep))


def build_dim_constraints(
    group: FusionGroup,
    *,
    sharded_sizes: Mapping[str, int] | None = None,
    whole_dims: set[str] | frozenset[str] = frozenset(),
) -> dict[str, DimConstraint]:
    """Compute per-dim tile domains for a fusion group.

    ``sharded_sizes`` overrides the full size of dims that are split across
    a mesh axis (the planner then plans the *per-shard* problem — the
    sharding constraint family).  ``whole_dims`` pins extra dims to their
    full size (a kernel-policy constraint supplied by a specific kernel's
    dataflow, e.g. the fused-MLP kernel keeps K and N un-tiled).
    """
    sharded_sizes = dict(sharded_sizes or {})
    out: dict[str, DimConstraint] = {}

    contract_dims: set[str] = set()
    whole_dims = set(whole_dims)
    for op in group.ops:
        for d in op.contract_dims():
            contract_dims.add(d)
            if op.policy.contract_whole:
                whole_dims.add(d)

    for name, dim in group.dims.items():
        size = sharded_sizes.get(name, dim.size)
        if size <= 0 or dim.size % size != 0:
            raise ValueError(
                f"sharded size {size} does not divide dim {name}={dim.size}"
            )
        align, min_tile = _dim_alignment(group, name)
        if name in whole_dims:
            cands: tuple[int, ...] = (size,)
        else:
            cands = _thin(
                [c for c in aligned_divisors(size, align) if c >= min(min_tile, size)]
            )
        out[name] = DimConstraint(
            name=name,
            size=size,
            candidates=cands,
            is_contract=name in contract_dims,
            contract_whole=name in whole_dims,
            alignment=align,
            min_tile=min_tile,
        )
    return out


def accumulator_tensors(group: FusionGroup, tiles: Mapping[str, int],
                        cons: Mapping[str, DimConstraint]) -> list[TensorSpec]:
    """fp32 VMEM accumulators required when a contraction dim is tiled.

    One accumulator per GEMM whose contract dim has n_tiles > 1; its shape is
    the op's output tile, dtype fp32 (kernel-policy constraint:
    ``contract_accumulate`` must be allowed, else the assignment is illegal
    — the solver filters that case via ``contract_whole`` domains already).
    """
    accs = []
    for op in group.ops:
        if op.kind != "gemm":
            continue
        tiled_contract = any(
            tiles[d] < cons[d].size for d in op.contract_dims()
        )
        if tiled_contract:
            accs.append(
                TensorSpec(
                    name=f"{op.name}__acc",
                    dims=op.output.dims,
                    dtype="float32",
                    role=Role.ACCUMULATOR,
                )
            )
    return accs
