"""Executor registry: planned fusion groups → concrete implementations.

The planner (graph → partition) decides *what* to fuse; this module
decides *who runs it*.  Every executor advertises the pattern it
implements (``kind``), its backend, and a qualification predicate over an
:class:`ExecContext`; ``find`` returns the highest-priority qualifying
executor.  Consumers get two entry points:

* :func:`plan_block` — plan a whole transformer block for a config and
  bind every planned segment to an executor (the one API
  ``models/layers.py``, ``launch/*`` and the benchmarks consume).
* :func:`mlp_executor` — resolve an MLP execution callable for a given
  ``ftl_mode``; ``'auto'`` is plan-driven: the partitioner's chosen
  schedule selects between the Pallas fused kernel, the portable scan
  executor, and the layer-per-layer baseline.

Adding a new layer kind = one IR builder (graph.py) + one registry entry
here — no per-consumer wiring.

Kernel imports are lazy (inside the run functions) so the planning side
of ``repro.core.ftl`` stays importable without pulling in Pallas.
"""
from __future__ import annotations

import dataclasses
import functools
import weakref
from typing import Callable, Mapping

import jax
import jax.numpy as jnp

from repro import obs
from repro.core import hw as hwlib

from . import executor_xla, graph, partition
from .partition import ChainPlan
from .solver import InfeasibleError, solve

_C_PLAN_BLOCK = obs.counter(
    "ftl_plan_block_total", "plan_block calls", ("phase",))


# ---------------------------------------------------------------------------
# registry core
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ExecContext:
    """Everything an executor needs to decide whether it qualifies."""

    kind: str                    # 'mlp' | 'attention' | 'gemm'
    platform: str                # 'tpu' | 'cpu' | 'gpu'
    schedule: str                # 'fused' | 'partial' | 'unfused'
    m: int = 0
    d_model: int = 0
    d_ff: int = 0
    dtype: str = "bfloat16"
    gated: bool = False
    act: str = "gelu"
    target: hwlib.Target | None = None   # the plan's memory hierarchy
    head_dim: int = 0            # attention kernels' footprint probe
    # 'prefill' (full-sequence, compute-heavy) vs 'decode' (m=1 against a
    # cache, memory-bound).  Decode shapes never fill an MXU lane tile, so
    # the Pallas kernels disqualify themselves there and the registry
    # binds the XLA executors instead (decode-shape qualification).
    phase: str = "prefill"


def _vmem_class(target: hwlib.Target | None) -> bool:
    """Capacity-class fallback for *shape-less* contexts: can the
    target's fast level plausibly host a Pallas double-buffered
    pipeline at all?  When the context carries shapes, qualification
    uses the kernel's actual tile footprint instead
    (:func:`_mlp_kernel_fits` / :func:`_attention_kernel_fits`)."""
    return target is None or target.fast.capacity_bytes >= 4 * (1 << 20)


@functools.lru_cache(maxsize=1024)
def _mlp_kernel_footprint_fits(m: int, d_model: int, d_ff: int, dtype: str,
                               gated: bool, act: str,
                               target: hwlib.Target) -> bool:
    """True when the fused-MLP Pallas kernel's own dataflow (K and N
    whole — weight panels resident, M/F tiled) has a tile assignment
    whose double-buffered footprint fits the target's fast level: the
    same solve ``ops.plan_mlp_blocks`` runs to pick the kernel's block
    sizes, so an executor qualifies iff its kernel is actually
    plannable at this shape on this machine."""
    g = graph.mlp_graph(m=m, d_model=d_model, d_ff=d_ff, dtype=dtype,
                        gated=gated, act=act)
    try:
        solve(g.group(0, g.n_ops), target=target,
              whole_dims=frozenset({"K", "N"}))
        return True
    except InfeasibleError:
        return False


@functools.lru_cache(maxsize=1024)
def _partial_mlp_footprint_fits(m: int, d_model: int, d_ff: int,
                                dtype: str, act: str,
                                target: hwlib.Target) -> bool:
    """The *partial* Pallas path runs two separate kernels (gemm_act for
    the up projection, gemm for the down projection), so each GEMM needs
    only its own weight panel resident — probe them independently
    (matching ``ops.plan_gemm_blocks``), not the fused whole-K/N solve."""
    try:
        solve(graph.gemm_act_graph(m=m, k=d_model, n=d_ff, dtype=dtype,
                                   act=act).group(0, 2), target=target)
        solve(graph.gemm_chain_graph(m=m, dims_kn=[d_ff, d_model],
                                     dtype=dtype).group(0, 1),
              target=target)
        return True
    except InfeasibleError:
        return False


@functools.lru_cache(maxsize=1024)
def _attention_kernel_footprint_fits(m: int, head_dim: int, dtype: str,
                                     target: hwlib.Target) -> bool:
    """Flash-attention analogue: head dim whole (the kernel's online
    softmax streams Tk), q/k tiles solved against the fast level."""
    g = graph.attention_graph(q_len=m, kv_len=m, head_dim=head_dim,
                              dtype=dtype)
    try:
        partition.plan_fixed(g, (), target=target)
        return True
    except InfeasibleError:
        return False


def _mlp_kernel_fits(c: ExecContext) -> bool:
    """Per-target Pallas MLP qualification (ROADMAP item): the kernel's
    *actual tile footprint* at the context's shapes must be plannable on
    the target — a weight panel that cannot fit the fast level
    disqualifies the kernel no matter how roomy the capacity class says
    the scratchpad is.  The VMEM-class floor stays as a conjunct: the
    Pallas pipeline machinery itself needs TPU-VMEM-scale headroom, and
    a plan made for a KiB-scale scratchpad must not bind these kernels
    even when its (tiny) tiles would technically fit."""
    if c.target is None:
        return True
    if not _vmem_class(c.target):
        return False
    if not (c.m and c.d_model and c.d_ff):
        return True
    return _mlp_kernel_footprint_fits(c.m, c.d_model, c.d_ff, c.dtype,
                                      c.gated, c.act, c.target)


def _partial_mlp_kernel_fits(c: ExecContext) -> bool:
    """Footprint probe for the partial Pallas MLP: per-GEMM, since its
    kernels run sequentially and never co-reside both weight panels."""
    if c.target is None:
        return True
    if not _vmem_class(c.target):
        return False
    if not (c.m and c.d_model and c.d_ff):
        return True
    return _partial_mlp_footprint_fits(c.m, c.d_model, c.d_ff, c.dtype,
                                       c.act, c.target)


def _attention_kernel_fits(c: ExecContext) -> bool:
    if c.target is None:
        return True
    if not _vmem_class(c.target):
        return False
    if not (c.m and c.head_dim):
        return True
    return _attention_kernel_footprint_fits(c.m, c.head_dim, c.dtype,
                                            c.target)


@dataclasses.dataclass(frozen=True)
class Executor:
    """A registered implementation of one planned-group pattern."""

    name: str
    kind: str
    backend: str                 # 'pallas' | 'xla'
    priority: int
    qualifies: Callable[[ExecContext], bool]
    run: Callable | None = None


_REGISTRY: dict[str, Executor] = {}


def register(ex: Executor, *, override: bool = False) -> Executor:
    if ex.name in _REGISTRY and not override:
        raise ValueError(f"executor {ex.name!r} already registered")
    _REGISTRY[ex.name] = ex
    return ex


def get(name: str) -> Executor:
    return _REGISTRY[name]


def executors(kind: str | None = None) -> list[Executor]:
    exs = [e for e in _REGISTRY.values() if kind is None or e.kind == kind]
    return sorted(exs, key=lambda e: -e.priority)


def find(kind: str, ctx: ExecContext) -> Executor:
    """Highest-priority executor of ``kind`` that qualifies for ``ctx``.

    Raises :class:`LookupError` spelling out the full qualification
    context and every executor that was considered (name, backend,
    priority), so a failed binding is diagnosable from the message alone.
    """
    considered = executors(kind)
    for ex in considered:
        if ex.qualifies(ctx):
            return ex
    fields = ", ".join(
        f"{f.name}={getattr(ctx, f.name)!r}"
        for f in dataclasses.fields(ctx)
    )
    tried = ", ".join(
        f"{e.name} (backend={e.backend}, priority={e.priority})"
        for e in considered
    ) or "<none registered for this kind>"
    raise LookupError(
        f"no executor of kind={kind!r} qualifies for "
        f"ExecContext({fields}); considered in priority order: {tried}"
    )


def platform() -> str:
    return jax.default_backend()


# ---------------------------------------------------------------------------
# built-in MLP executors
# ---------------------------------------------------------------------------

def _run_pallas_fused_mlp(x, w1, w2, wg, b1, b2, *, act, target=None):
    from repro.kernels import ops  # lazy: Pallas stack
    return ops.fused_mlp(x, w1, w2, wg, b1, b2, act=act, backend="pallas",
                         target=target)


def _run_pallas_partial_mlp(x, w1, w2, wg, b1, b2, *, act, target=None):
    """Partial schedule on the Pallas kernels: the paper's fused
    GEMM+activation kernel for the up projection, a plain GEMM kernel for
    the down projection (non-gated only — the gated epilogue has no
    dedicated kernel yet)."""
    from repro.kernels import ops
    *lead, m, k = x.shape
    xf = x.reshape(-1, k)
    h = ops.gemm_act(xf, w1, b1, act=act, backend="pallas", target=target)
    y = ops.gemm(h, w2, backend="pallas", target=target)
    if b2 is not None:
        y = y + b2
    return y.reshape(*lead, m, w2.shape[1])


@functools.lru_cache(maxsize=512)
def _scan_tile(m: int, d_model: int, d_ff: int, dtype: str, gated: bool,
               act: str, target: hwlib.Target) -> int:
    """Token-tile for the scan executor from its own kernel policy: the
    scan tiles M only, so K/F/N stay whole and the solver picks the
    largest M tile that fits the target's fast level.  Falls back to a
    power-of-two divisor when even the smallest tile does not fit (XLA
    will still run — the budget is a planning target, not a hard limit on
    this backend)."""
    g = graph.mlp_graph(m=m, d_model=d_model, d_ff=d_ff, dtype=dtype,
                        gated=gated, act=act)
    try:
        plan = solve(g.group(0, g.n_ops), target=target,
                     whole_dims=frozenset({"K", "F", "N"}))
        return plan.tile("M")
    except InfeasibleError:
        for cand in (1024, 512, 256, 128):
            if m % cand == 0 and cand < m:
                return cand
        return m


def _run_xla_scan_mlp(x, w1, w2, wg, b1, b2, *, act, target=None):
    m = x.shape[-2]
    tile = _scan_tile(m, w1.shape[0], w1.shape[1], str(x.dtype),
                      wg is not None, act,
                      target if target is not None
                      else hwlib.default_target())
    return executor_xla.mlp_scan(x, w1, w2, wg, b1, b2, act=act, tile_m=tile)


def _run_xla_partial_mlp(x, w1, w2, wg, b1, b2, *, act, target=None):
    m = x.shape[-2]
    tile = _scan_tile(m, w1.shape[0], w1.shape[1], str(x.dtype),
                      wg is not None, act,
                      target if target is not None
                      else hwlib.default_target())
    return executor_xla.mlp_partial_scan(x, w1, w2, wg, b1, b2, act=act,
                                         tile_m=tile)


def _run_xla_unfused_mlp(x, w1, w2, wg, b1, b2, *, act, target=None):
    from repro.distributed.act_sharding import constrain  # lazy: no cycle
    from repro.kernels import ref
    h = x @ w1
    if b1 is not None:
        h = h + b1
    h = ref.act_fn(act)(h.astype(jnp.float32)).astype(x.dtype)
    if wg is not None:
        h = h * (x @ wg)
    h = constrain(h, "ffn_hidden")
    y = h @ w2
    if b2 is not None:
        y = y + b2
    return y


def _run_pallas_attention(q, k, v, *, target=None, **kw):
    from repro.kernels import ops
    return ops.attention(q, k, v, backend="pallas", target=target, **kw)


def _run_ref_attention(q, k, v, *, target=None, **kw):
    from repro.kernels import ops
    return ops.attention(q, k, v, backend="ref", target=target, **kw)


def _run_pallas_gemm(x, w, *, target=None):
    from repro.kernels import ops
    return ops.gemm(x, w, backend="pallas", target=target)


def _run_xla_gemm(x, w, *, target=None):
    return x @ w


register(Executor(
    name="pallas_fused_mlp", kind="mlp", backend="pallas", priority=100,
    qualifies=lambda c: (c.platform == "tpu" and c.schedule == "fused"
                         and c.phase != "decode" and _mlp_kernel_fits(c)),
    run=_run_pallas_fused_mlp))
register(Executor(
    name="pallas_partial_mlp", kind="mlp", backend="pallas", priority=90,
    qualifies=lambda c: (c.platform == "tpu" and c.schedule == "partial"
                         and c.phase != "decode"
                         and not c.gated and _partial_mlp_kernel_fits(c)),
    run=_run_pallas_partial_mlp))
register(Executor(
    name="xla_scan_mlp", kind="mlp", backend="xla", priority=50,
    qualifies=lambda c: c.schedule == "fused",
    run=_run_xla_scan_mlp))
register(Executor(
    name="xla_partial_scan_mlp", kind="mlp", backend="xla", priority=40,
    qualifies=lambda c: c.schedule == "partial",
    run=_run_xla_partial_mlp))
register(Executor(
    name="xla_unfused_mlp", kind="mlp", backend="xla", priority=10,
    qualifies=lambda c: True,
    run=_run_xla_unfused_mlp))
register(Executor(
    name="pallas_flash_attention", kind="attention", backend="pallas",
    priority=100,
    qualifies=lambda c: (c.platform == "tpu" and c.schedule != "unfused"
                         and c.phase != "decode"
                         and _attention_kernel_fits(c)),
    run=_run_pallas_attention))
register(Executor(
    name="xla_ref_attention", kind="attention", backend="xla", priority=10,
    qualifies=lambda c: True,
    run=_run_ref_attention))
register(Executor(
    name="pallas_gemm", kind="gemm", backend="pallas", priority=100,
    qualifies=lambda c: c.platform == "tpu" and c.phase != "decode",
    run=_run_pallas_gemm))
register(Executor(
    name="xla_gemm", kind="gemm", backend="xla", priority=10,
    qualifies=lambda c: True,
    run=_run_xla_gemm))


# ---------------------------------------------------------------------------
# block-level planning: the one API every consumer goes through
# ---------------------------------------------------------------------------

def _segment_kind(seg: partition.Segment) -> str:
    names = seg.op_names()
    if any(n.startswith("attn.") for n in names):
        return "attention"
    if any(n.startswith("mlp.") or n.startswith("gemm") for n in names):
        return "mlp" if any(n.startswith("mlp.") for n in names) else "gemm"
    return "gemm"


@dataclasses.dataclass(frozen=True)
class GroupBinding:
    segment: partition.Segment
    kind: str
    executor: str


@dataclasses.dataclass(frozen=True)
class BlockPlan:
    """A planned transformer block with per-segment executor bindings.

    Carries the config, planning shape and memory-hierarchy target it was
    made for so :func:`run_block` can execute it (and requalify bindings)
    without any side-channel state.
    """

    chain: ChainPlan
    bindings: tuple[GroupBinding, ...]
    platform: str
    cfg: object = None
    m: int = 0
    dtype: str = ""
    # set when the plan came out of the DES-scored autotuner
    # (repro.tune.TuneResult); the chain above is then the tuned chain
    # and chain.target may be a depth-modified variant of the request's
    # target.
    tune: object = None
    # serving regime the plan was made for: 'prefill' (full-sequence) or
    # 'decode' (m=1 against a cache).  Part of every plan-cache key; the
    # bindings were qualified with this phase in their ExecContext.
    phase: str = "prefill"

    @property
    def target(self) -> hwlib.Target:
        return self.chain.target

    @property
    def graph(self) -> graph.OpGraph:
        return self.chain.graph

    @property
    def schedule(self) -> str:
        return self.chain.schedule

    @property
    def traffic_bytes(self) -> int:
        return self.chain.traffic_bytes

    @property
    def per_level_traffic(self) -> dict[str, int]:
        return self.chain.per_level_traffic

    def _sub_schedule(self, prefix: str) -> str:
        ops = [op.name for op in self.graph.ops
               if op.name.startswith(prefix)]
        segs = [s for s in self.chain.segments
                if any(n.startswith(prefix) for n in s.op_names())]
        if not ops or not segs:
            return "none"
        if len(segs) == 1:
            return "fused"
        if len(segs) == len(ops):
            return "unfused"
        return "partial"

    @property
    def mlp_schedule(self) -> str:
        return self._sub_schedule("mlp.")

    @property
    def attention_schedule(self) -> str:
        return self._sub_schedule("attn.")

    def summary(self) -> str:
        lines = [self.chain.summary(),
                 f"  executors ({self.platform}, planned for "
                 f"{self.target.name}):"]
        for b in self.bindings:
            lines.append(
                f"    [{b.segment.lo}:{b.segment.hi}] {b.kind:9s} -> "
                f"{b.executor}"
            )
        return "\n".join(lines)


def _freeze(d: Mapping[str, int] | None):
    return tuple(sorted(d.items())) if d else None


@functools.lru_cache(maxsize=128)
def _plan_block_cached(cfg, m: int, dtype: str | None,
                       target: hwlib.Target, sharded: tuple | None,
                       plat: str, residual: bool,
                       autotune=None, phase: str = "prefill") -> BlockPlan:
    g = graph.block_graph(cfg, m=m, dtype=dtype, residual=residual)
    sharded_d = dict(sharded) if sharded else None
    tune_result = None
    if autotune is not None:
        from repro.tune import autotune_chain  # lazy: pulls in repro.sim
        tune_result = autotune_chain(g, target=target, config=autotune,
                                     sharded_sizes=sharded_d)
        chain = tune_result.chain
        # bindings qualify against the tuned hierarchy (possibly
        # depth-modified), the one the chain was scored on
        target = chain.target
    else:
        chain = partition.plan_chain(g, target=target,
                                     sharded_sizes=sharded_d)
    shell = BlockPlan(chain=chain, bindings=(), platform=plat, cfg=cfg,
                      m=m, dtype=dtype or cfg.dtype, phase=phase)
    sub = {"mlp": shell.mlp_schedule, "attention": shell.attention_schedule}
    bindings = []
    for seg in chain.segments:
        kind = _segment_kind(seg)
        # qualification uses the sub-chain's own fusion state: a split
        # attention core must not bind to the flash kernel, etc.
        sched = sub.get(kind, chain.schedule)
        sched = chain.schedule if sched == "none" else sched
        ctx = ExecContext(
            kind=kind, platform=plat, schedule=sched,
            m=m, d_model=cfg.d_model,
            d_ff=cfg.moe_d_ff if cfg.is_moe else cfg.d_ff,
            dtype=dtype or cfg.dtype, gated=cfg.mlp_gated, act=cfg.mlp_act,
            target=target, head_dim=cfg.resolved_head_dim, phase=phase)
        bindings.append(GroupBinding(segment=seg, kind=kind,
                                     executor=find(kind, ctx).name))
    return BlockPlan(chain=chain, bindings=tuple(bindings), platform=plat,
                     cfg=cfg, m=m, dtype=dtype or cfg.dtype,
                     tune=tune_result, phase=phase)


def plan_block(
    cfg,
    *,
    m: int,
    dtype: str | None = None,
    target: hwlib.Target | None = None,
    sharded_sizes: Mapping[str, int] | None = None,
    residual: bool = True,
    autotune=None,
    phase: str = "prefill",
) -> BlockPlan:
    """Plan one transformer block of ``cfg`` at ``m`` tokens on ``target``
    (None → the default target) and bind every planned fusion group to the
    best qualifying executor.

    ``autotune`` (a :class:`repro.tune.AutotuneConfig`) swaps the analytic
    argmin for the simulator-scored search: the returned plan's chain is
    the DES-runtime-optimal candidate (simulated runtime ≤ the analytic
    plan's, by construction) and ``BlockPlan.tune`` carries the full
    :class:`~repro.tune.TuneResult`.  The config is part of the plan
    cache key — tuned and untuned plans never alias.

    ``phase`` ('prefill' | 'decode') runs the same partition DP at the
    regime's own shape: decode plans (``m=1`` against a cache) are
    memory-bound, so the max(compute, transfer) objective generally picks
    different cuts than prefill, and their bindings never qualify the
    Pallas kernels (decode-shape qualification).  Phase is part of the
    plan-cache key — a decode plan and a prefill plan for the same shapes
    never alias."""
    if phase not in ("prefill", "decode"):
        raise ValueError(f"phase must be 'prefill' or 'decode', "
                         f"got {phase!r}")
    target = target if target is not None else hwlib.default_target()
    _C_PLAN_BLOCK.labels(phase=phase).inc()
    with obs.span(f"plan_block:{phase}", "planner"):
        return _plan_block_cached(cfg, m, dtype, target,
                                  _freeze(sharded_sizes), platform(),
                                  residual, autotune, phase)


# ---------------------------------------------------------------------------
# MLP mode resolution for models/layers.py
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=1024)
def _mlp_executor_cached(mode: str, m: int, d_model: int, d_ff: int,
                         dtype: str, gated: bool, act: str,
                         target: hwlib.Target, plat: str) -> Executor:
    if mode == "off":
        ex = get("xla_unfused_mlp")
    elif mode == "fused":
        # explicit request for the Pallas kernel (interpret mode off-TPU)
        ex = get("pallas_fused_mlp")
    elif mode == "scan":
        ex = get("xla_scan_mlp")
    elif mode == "auto":
        g = graph.mlp_graph(m=m, d_model=d_model, d_ff=d_ff, dtype=dtype,
                            gated=gated, act=act)
        try:
            schedule = partition.plan_chain(g, target=target).schedule
        except InfeasibleError:
            schedule = "unfused"
        ctx = ExecContext(kind="mlp", platform=plat, schedule=schedule,
                          m=m, d_model=d_model, d_ff=d_ff, dtype=dtype,
                          gated=gated, act=act, target=target)
        ex = find("mlp", ctx)
    else:
        raise ValueError(f"unknown ftl_mode {mode!r}")
    # run under the target the schedule was resolved with, not whatever the
    # process default happens to be at run time (affects the scan
    # executors' token-tile choice)
    return dataclasses.replace(
        ex, run=functools.partial(ex.run, target=target))


def mlp_executor(
    mode: str,
    *,
    m: int,
    d_model: int,
    d_ff: int,
    dtype: str,
    gated: bool,
    act: str,
    target: hwlib.Target | None = None,
) -> Executor:
    """Resolve the MLP executor for ``ftl_mode`` at the given shapes on
    ``target`` (None → the default target).

    ``'auto'`` is plan-driven: the fusion partitioner's chosen schedule
    picks the implementation (Pallas fused kernel on TPU, scan executor
    for a fused/partial schedule elsewhere, layer-per-layer baseline when
    the planner rejects fusion)."""
    target = target if target is not None else hwlib.default_target()
    return _mlp_executor_cached(mode, m, d_model, d_ff, dtype, gated, act,
                                target, platform())


# ---------------------------------------------------------------------------
# planner-cache registry: one ledger over every memoized planning entry
# ---------------------------------------------------------------------------

_PLAN_CACHES: dict[str, Callable] = {}
# Stat-keeping consumers of the caches (ServeEngine plan caches and the
# engines themselves): anything enrolled here has its ``reset_counters``
# called by :func:`clear_plan_caches`, so reuse counters can never claim
# cache hits that a clear just invalidated.  Weak references — a
# registered engine dies with its last real owner, not with the ledger.
_COUNTER_RESETS: "weakref.WeakSet" = weakref.WeakSet()


def register_counter_reset(obj) -> Callable | object:
    """Enroll an object exposing ``reset_counters()`` to be reset
    whenever :func:`clear_plan_caches` drops the underlying caches.
    Held weakly; returns ``obj``."""
    _COUNTER_RESETS.add(obj)
    return obj


def register_plan_cache(name: str, fn: Callable) -> Callable:
    """Enroll an ``lru_cache``-wrapped planner in the plan-cache ledger.

    Higher layers (``repro.models.model``, ``repro.tune``) self-register
    at import, so :func:`plan_cache_stats` covers every *imported*
    planner cache without this module depending on them.  Returns ``fn``
    so the call composes as a decorator-style tail."""
    _PLAN_CACHES[name] = fn
    return fn


def plan_cache_stats() -> dict[str, dict[str, int]]:
    """Hit/miss/size counters for every registered planner cache —
    surfaced by ``ServeEngine.plan_report()`` so a serving run can show
    its plans came from cache, not replanning."""
    return {
        name: {
            "hits": info.hits,
            "misses": info.misses,
            "size": info.currsize,
            "maxsize": info.maxsize,
        }
        for name, fn in sorted(_PLAN_CACHES.items())
        for info in (fn.cache_info(),)
    }


def clear_plan_caches() -> None:
    """Drop every registered planner cache (tests; target registry
    edits that would otherwise serve stale plans) — and reset the
    counters of every registered stat keeper (``ServeEngine`` plan
    caches), so ``plan_report()`` after a clear reports the reuse that
    actually happened, not hit/replan totals from before the plans were
    invalidated."""
    for fn in _PLAN_CACHES.values():
        fn.cache_clear()
    for obj in list(_COUNTER_RESETS):
        obj.reset_counters()


for _fn in (_mlp_kernel_footprint_fits, _partial_mlp_footprint_fits,
            _attention_kernel_footprint_fits, _scan_tile,
            _plan_block_cached, _mlp_executor_cached):
    register_plan_cache(f"registry.{_fn.__name__}", _fn)
for _fn in (partition._plan_chain_cached, partition._plan_chain_top_k_cached):
    register_plan_cache(f"partition.{_fn.__name__}", _fn)
del _fn


def _collect_plan_caches(reg) -> None:
    """Pull-style re-expression of the PR-8 plan-cache ledger on the
    metrics registry: :func:`plan_cache_stats` stays the canonical
    bookkeeping (lru_cache's own counters), re-read at scrape time as
    gauges — never double-counted on the hot path, and automatically in
    sync with :func:`clear_plan_caches` resets."""
    g_hits = reg.gauge("ftl_plan_cache_hits",
                       "plan-cache hits (ledger snapshot)", ("cache",))
    g_miss = reg.gauge("ftl_plan_cache_misses",
                       "plan-cache misses (ledger snapshot)", ("cache",))
    g_size = reg.gauge("ftl_plan_cache_size",
                       "plan-cache entries (ledger snapshot)", ("cache",))
    for name, row in plan_cache_stats().items():
        g_hits.labels(cache=name).set(row["hits"])
        g_miss.labels(cache=name).set(row["misses"])
        g_size.labels(cache=name).set(row["size"])


obs.register_collector(_collect_plan_caches)


# ---------------------------------------------------------------------------
# block execution: walk the plan, dispatch every segment
# ---------------------------------------------------------------------------

def run_block(plan: BlockPlan, params, x, **kwargs):
    """Execute one transformer block through its :class:`BlockPlan`.

    Walks the planned segments in order and dispatches each one to its
    bound executor (Pallas flash attention / fused MLP kernels on TPU,
    the XLA scan executors elsewhere), stitching the norms and residual
    adds between segments.  Bindings are requalified against the runtime
    shapes/platform; a binding that no longer qualifies falls back,
    per segment, to the best qualifying (ultimately XLA reference)
    executor.  See :mod:`repro.core.ftl.executor_block`.
    """
    from . import executor_block  # lazy: keeps planning importable alone
    return executor_block.run_block(plan, params, x, **kwargs)
