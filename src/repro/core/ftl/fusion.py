"""FTL fusion-group construction (paper step 3).

``make_group`` performs the paper's *binding*: ops are written against
shared dim names; any tensor produced by one op and consumed by another
inside the group is re-classed ``INTERMEDIATE`` (fused away — zero HBM
traffic, single VMEM buffer).  With ``fuse=False`` the same chain is split
into one group per op, producer outputs / consumer inputs stay in HBM —
the layer-per-layer baseline the paper compares against.

Builders cover the layer chains our model zoo plans:

* ``gemm_act``    — the paper's exact ViT-MLP benchmark (GEMM → GeLU)
* ``mlp``         — full MLP: GEMM → act [⊙ gate GEMM] → GEMM
* ``attention``   — fused-tiled QKᵀ → softmax → ·V (flash-style)
* ``gemm_chain``  — generic back-to-back GEMMs
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from .ir import (
    Dim,
    FusionGroup,
    KernelPolicy,
    OpNode,
    Role,
    TensorSpec,
    elementwise,
    gemm,
)

# Policies -----------------------------------------------------------------
# GEMMs on the MXU accumulate fine in fp32 scratch -> contraction may tile.
GEMM_POLICY = KernelPolicy(contract_accumulate=True, min_tile=8)
# The flash-attention inner GEMM row-softmax needs whole head_dim.
HEADDIM_WHOLE = KernelPolicy(contract_whole=True)


def _collect(
    name: str, ops: Sequence[OpNode], dims: Sequence[Dim], fuse: bool
) -> FusionGroup | list[FusionGroup]:
    dim_map = {d.name: d for d in dims}
    if fuse:
        produced = {op.output.name: op.output for op in ops}
        consumed = {t.name for op in ops for t in op.inputs}
        tensors: dict[str, TensorSpec] = {}
        for op in ops:
            for t in op.tensors():
                if t.name in produced and t.name in consumed:
                    t = dataclasses.replace(t, role=Role.INTERMEDIATE)
                elif t.name in produced:
                    t = dataclasses.replace(t, role=Role.OUTPUT)
                tensors[t.name] = t
        g = FusionGroup(name=name, ops=list(ops), dims=dim_map, tensors=tensors)
        g.validate()
        return g
    groups = []
    for op in ops:
        tensors = {}
        for t in op.inputs:
            # In the layer-per-layer schedule every op input streams from HBM.
            role = Role.WEIGHT if t.role is Role.WEIGHT else Role.INPUT
            tensors[t.name] = dataclasses.replace(t, role=role)
        tensors[op.output.name] = dataclasses.replace(
            op.output, role=Role.OUTPUT
        )
        used = {d for t in op.tensors() for d in t.dims}
        g = FusionGroup(
            name=f"{name}.{op.name}",
            ops=[op],
            dims={k: v for k, v in dim_map.items() if k in used},
            tensors=tensors,
        )
        g.validate()
        groups.append(g)
    return groups


# ---------------------------------------------------------------------------
# builders
# ---------------------------------------------------------------------------

def gemm_act_ops(
    *, m: int, k: int, n: int, dtype: str = "bfloat16", act: str = "gelu",
) -> tuple[list[OpNode], list[Dim]]:
    """Raw op chain of the paper's ViT-MLP benchmark (see :func:`gemm_act`)."""
    dims = [Dim("M", m), Dim("K", k), Dim("F", n)]
    x = TensorSpec("x", ("M", "K"), dtype, Role.INPUT)
    w1 = TensorSpec("w1", ("K", "F"), dtype, Role.WEIGHT)
    h_raw = TensorSpec("h_raw", ("M", "F"), dtype, Role.OUTPUT)
    h = TensorSpec("h", ("M", "F"), dtype, Role.OUTPUT)
    ops = [
        gemm("gemm1", x, w1, h_raw, contract="K", policy=GEMM_POLICY),
        elementwise(act, [h_raw], h),
    ]
    return ops, dims


def gemm_act(
    *,
    m: int,
    k: int,
    n: int,
    dtype: str = "bfloat16",
    act: str = "gelu",
    fuse: bool = True,
    name: str = "gemm_act",
):
    """The paper's ViT-MLP benchmark: ``H = act(X @ W1)``."""
    ops, dims = gemm_act_ops(m=m, k=k, n=n, dtype=dtype, act=act)
    return _collect(name, ops, dims, fuse)


def mlp_ops(
    *,
    m: int,
    d_model: int,
    d_ff: int,
    dtype: str = "bfloat16",
    gated: bool = False,
    act: str = "gelu",
) -> tuple[list[OpNode], list[Dim]]:
    """Raw op chain of the full transformer MLP (see :func:`mlp`)."""
    dims = [Dim("M", m), Dim("K", d_model), Dim("F", d_ff), Dim("N", d_model)]
    x = TensorSpec("x", ("M", "K"), dtype, Role.INPUT)
    w1 = TensorSpec("w1", ("K", "F"), dtype, Role.WEIGHT)
    w2 = TensorSpec("w2", ("F", "N"), dtype, Role.WEIGHT)
    h1 = TensorSpec("h1", ("M", "F"), dtype, Role.OUTPUT)
    h = TensorSpec("h", ("M", "F"), dtype, Role.OUTPUT)
    y = TensorSpec("y", ("M", "N"), dtype, Role.OUTPUT)
    ops = [gemm("gemm1", x, w1, h1, contract="K", policy=GEMM_POLICY)]
    if gated:
        wg = TensorSpec("wg", ("K", "F"), dtype, Role.WEIGHT)
        hg = TensorSpec("hg", ("M", "F"), dtype, Role.OUTPUT)
        ops.append(gemm("gemm_gate", x, wg, hg, contract="K", policy=GEMM_POLICY))
        ops.append(elementwise(f"{act}_mul", [h1, hg], h))
    else:
        ops.append(elementwise(act, [h1], h))
    ops.append(gemm("gemm2", h, w2, y, contract="F", policy=GEMM_POLICY))
    return ops, dims


def mlp(
    *,
    m: int,
    d_model: int,
    d_ff: int,
    dtype: str = "bfloat16",
    gated: bool = False,
    act: str = "gelu",
    fuse: bool = True,
    name: str = "mlp",
):
    """Full transformer MLP: ``Y = act(X@W1)[⊙ (X@Wg)] @ W2``.

    Fused, the (M, d_ff) intermediate(s) never reach HBM — the exact
    failure mode the paper showcases (intermediate exceeding L2 → L3 spill;
    here: huge HBM round-trips at long sequence length).
    """
    ops, dims = mlp_ops(m=m, d_model=d_model, d_ff=d_ff, dtype=dtype,
                        gated=gated, act=act)
    return _collect(name, ops, dims, fuse)


def mlp_partial(
    *,
    m: int,
    d_model: int,
    d_ff: int,
    dtype: str = "bfloat16",
    gated: bool = False,
    act: str = "gelu",
    name: str = "mlp_partial",
) -> list[FusionGroup]:
    """Partial fusion: [GEMM1+act(+gate) fused] + [GEMM2 separate].

    The beyond-paper middle schedule: the activation epilogue fuses for
    free (the paper's exact benchmark), while the hidden tensor IS
    materialized once so GEMM2's tiling is unconstrained by GEMM1's —
    wins when joint tiling of both GEMMs would force weight revisits
    (qwen2-72b-class dims at 96 MiB VMEM, see bench_tpu_mlp).
    """
    dims1 = [Dim("M", m), Dim("K", d_model), Dim("F", d_ff)]
    x = TensorSpec("x", ("M", "K"), dtype, Role.INPUT)
    w1 = TensorSpec("w1", ("K", "F"), dtype, Role.WEIGHT)
    h1 = TensorSpec("h1", ("M", "F"), dtype, Role.OUTPUT)
    h = TensorSpec("h", ("M", "F"), dtype, Role.OUTPUT)
    ops1 = [gemm("gemm1", x, w1, h1, contract="K", policy=GEMM_POLICY)]
    if gated:
        wg = TensorSpec("wg", ("K", "F"), dtype, Role.WEIGHT)
        hg = TensorSpec("hg", ("M", "F"), dtype, Role.OUTPUT)
        ops1.append(gemm("gemm_gate", x, wg, hg, contract="K",
                         policy=GEMM_POLICY))
        ops1.append(elementwise(f"{act}_mul", [h1, hg], h))
    else:
        ops1.append(elementwise(act, [h1], h))
    g1 = _collect(f"{name}.up", ops1, dims1, fuse=True)

    dims2 = [Dim("M", m), Dim("F", d_ff), Dim("N", d_model)]
    h_in = TensorSpec("h", ("M", "F"), dtype, Role.INPUT)
    w2 = TensorSpec("w2", ("F", "N"), dtype, Role.WEIGHT)
    y = TensorSpec("y", ("M", "N"), dtype, Role.OUTPUT)
    g2 = _collect(f"{name}.down",
                  [gemm("gemm2", h_in, w2, y, contract="F",
                        policy=GEMM_POLICY)], dims2, fuse=True)
    return [g1, g2]


def attention_ops(
    *, q_len: int, kv_len: int, head_dim: int, dtype: str = "bfloat16",
) -> tuple[list[OpNode], list[Dim]]:
    """Raw op chain of one attention head (see :func:`attention`)."""
    dims = [Dim("Tq", q_len), Dim("Tk", kv_len), Dim("Dh", head_dim)]
    q = TensorSpec("q", ("Tq", "Dh"), dtype, Role.INPUT)
    k = TensorSpec("k", ("Tk", "Dh"), dtype, Role.INPUT)
    v = TensorSpec("v", ("Tk", "Dh"), dtype, Role.INPUT)
    s = TensorSpec("s", ("Tq", "Tk"), "float32", Role.OUTPUT)
    p = TensorSpec("p", ("Tq", "Tk"), dtype, Role.OUTPUT)
    o = TensorSpec("o", ("Tq", "Dh"), dtype, Role.OUTPUT)
    ops = [
        # S = Q @ Kᵀ : contract over head dim, which stays whole (row softmax
        # needs complete rows of S over Dh-contracted values).
        gemm("qk", q, k, s, contract="Dh", policy=HEADDIM_WHOLE),
        elementwise("softmax", [s], p),
        # O = P @ V : contract over Tk — tiled with accumulation = the online
        # softmax rescale trick (kernel-policy: accumulate allowed).
        gemm("pv", p, v, o, contract="Tk", policy=GEMM_POLICY),
    ]
    return ops, dims


def attention(
    *,
    q_len: int,
    kv_len: int,
    head_dim: int,
    dtype: str = "bfloat16",
    fuse: bool = True,
    name: str = "attention",
):
    """Fused-tiled attention for ONE head: S = Q@Kᵀ; P = softmax(S); O = P@V.

    The (q_len, kv_len) score matrix is the intermediate being fused away —
    flash attention is exactly an FTL instance (DESIGN.md §5).
    """
    ops, dims = attention_ops(q_len=q_len, kv_len=kv_len, head_dim=head_dim,
                              dtype=dtype)
    return _collect(name, ops, dims, fuse)


def gemm_chain_ops(
    *, m: int, dims_kn: Sequence[int], dtype: str = "bfloat16",
) -> tuple[list[OpNode], list[Dim]]:
    """Raw op chain of back-to-back GEMMs (see :func:`gemm_chain`)."""
    dim_objs = [Dim("M", m)] + [Dim(f"K{i}", s) for i, s in enumerate(dims_kn)]
    tensors = [TensorSpec("x", ("M", "K0"), dtype, Role.INPUT)]
    ops = []
    for i in range(1, len(dims_kn)):
        w = TensorSpec(f"w{i}", (f"K{i-1}", f"K{i}"), dtype, Role.WEIGHT)
        out = TensorSpec(f"t{i}", ("M", f"K{i}"), dtype, Role.OUTPUT)
        ops.append(
            gemm(f"gemm{i}", tensors[-1], w, out, contract=f"K{i-1}",
                 policy=GEMM_POLICY)
        )
        tensors.append(out)
    return ops, dim_objs


def gemm_chain(
    *,
    m: int,
    dims_kn: Sequence[int],
    dtype: str = "bfloat16",
    fuse: bool = True,
    name: str = "gemm_chain",
):
    """X(M,K0) @ W1(K0,K1) @ W2(K1,K2) @ ... — generic FTL chain."""
    ops, dim_objs = gemm_chain_ops(m=m, dims_kn=dims_kn, dtype=dtype)
    return _collect(name, ops, dim_objs, fuse)
