"""Automatic fusion selection — beyond-paper extension.

The paper fuses a manually chosen chain.  At framework level we plan BOTH
schedules (fused, layer-per-layer) with the same solver and pick the one
with lower modeled HBM traffic.  This matters because fusion is *not*
always a win: when weights dominate and VMEM is scarce, the joint tiling
constraints can force weight revisits that exceed the intermediate savings
(see tests/test_ftl_solver.py::test_fusion_not_always_wins).

Plans are cached per (shape, dtype, budget, sharding) — they are static
compile-time artifacts, exactly like Deeploy's generated schedules.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Mapping

from . import fusion
from .plan import FusionComparison, TilePlan, compare
from .solver import DEFAULT_VMEM_BUDGET, InfeasibleError, solve


@dataclasses.dataclass(frozen=True)
class MLPPlanOutcome:
    fused: TilePlan | None
    unfused: tuple[TilePlan, ...]
    comparison: FusionComparison | None
    use_fused: bool
    partial: tuple[TilePlan, ...] = ()
    schedule: str = ""               # 'fused' | 'partial' | 'unfused'

    @property
    def chosen_traffic(self) -> int:
        if self.schedule == "fused" or (not self.schedule and self.use_fused):
            return self.fused.traffic_bytes
        if self.schedule == "partial":
            return sum(p.traffic_bytes for p in self.partial)
        return sum(p.traffic_bytes for p in self.unfused)


def _freeze(d: Mapping[str, int] | None):
    return tuple(sorted(d.items())) if d else None


@functools.lru_cache(maxsize=512)
def _plan_mlp_cached(
    m: int,
    d_model: int,
    d_ff: int,
    dtype: str,
    gated: bool,
    act: str,
    vmem_budget: int,
    sharded: tuple | None,
) -> MLPPlanOutcome:
    sharded_sizes = dict(sharded) if sharded else None
    kw = dict(m=m, d_model=d_model, d_ff=d_ff, dtype=dtype, gated=gated, act=act)
    unfused = tuple(
        solve(g, vmem_budget=vmem_budget, sharded_sizes=sharded_sizes)
        for g in fusion.mlp(fuse=False, **kw)
    )
    # partial schedule: GEMM+act fused (the paper's op), GEMM2 separate
    try:
        partial = tuple(
            solve(g, vmem_budget=vmem_budget, sharded_sizes=sharded_sizes)
            for g in fusion.mlp_partial(**kw)
        )
    except InfeasibleError:
        partial = ()
    try:
        fused = solve(
            fusion.mlp(fuse=True, **kw),
            vmem_budget=vmem_budget,
            sharded_sizes=sharded_sizes,
        )
    except InfeasibleError:
        fused = None
    cands: dict[str, int] = {
        "unfused": sum(p.traffic_bytes for p in unfused)}
    if partial:
        cands["partial"] = sum(p.traffic_bytes for p in partial)
    if fused is not None:
        cands["fused"] = fused.traffic_bytes
    schedule = min(cands, key=cands.get)
    cmp = compare(fused, unfused) if fused is not None else None
    return MLPPlanOutcome(fused, unfused, cmp,
                          use_fused=schedule == "fused",
                          partial=partial, schedule=schedule)


def plan_mlp(
    *,
    m: int,
    d_model: int,
    d_ff: int,
    dtype: str = "bfloat16",
    gated: bool = False,
    act: str = "gelu",
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    sharded_sizes: Mapping[str, int] | None = None,
) -> MLPPlanOutcome:
    """Plan an MLP; returns fused + baseline plans and the auto decision."""
    return _plan_mlp_cached(
        m, d_model, d_ff, dtype, gated, act, vmem_budget, _freeze(sharded_sizes)
    )


@functools.lru_cache(maxsize=512)
def plan_attention(
    *,
    q_len: int,
    kv_len: int,
    head_dim: int,
    dtype: str = "bfloat16",
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
) -> TilePlan:
    return solve(
        fusion.attention(q_len=q_len, kv_len=kv_len, head_dim=head_dim,
                         dtype=dtype),
        vmem_budget=vmem_budget,
    )
