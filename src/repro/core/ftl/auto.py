"""Automatic fusion selection — thin wrappers over the graph partitioner.

Historically this module hard-coded a three-way MLP choice (fused /
partial / unfused).  The general mechanism now lives in ``graph.py`` +
``partition.py``: any op chain gets a globally traffic-minimal fusion
partition from a dynamic program over cut points.  ``plan_mlp`` and
``plan_attention`` remain the stable cached entry points; the three
canonical MLP schedules are still priced explicitly (via
``partition.plan_fixed``) because the benchmarks and the fused-vs-unfused
comparison report all of them, but the *decision* is the partitioner's.

Plans are cached per (shape, dtype, budget, sharding) — they are static
compile-time artifacts, exactly like Deeploy's generated schedules.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Mapping

from . import graph, partition
from .partition import ChainPlan
from .plan import FusionComparison, TilePlan, compare
from .solver import DEFAULT_VMEM_BUDGET, InfeasibleError


@dataclasses.dataclass(frozen=True)
class MLPPlanOutcome:
    fused: TilePlan | None
    unfused: tuple[TilePlan, ...]
    comparison: FusionComparison | None
    use_fused: bool
    partial: tuple[TilePlan, ...] = ()
    schedule: str = ""               # 'fused' | 'partial' | 'unfused'
    chain: ChainPlan | None = None   # the partitioner's chosen schedule

    @property
    def chosen_traffic(self) -> int:
        if self.chain is not None:
            return self.chain.traffic_bytes
        if self.schedule == "fused" or (not self.schedule and self.use_fused):
            return self.fused.traffic_bytes
        if self.schedule == "partial":
            return sum(p.traffic_bytes for p in self.partial)
        return sum(p.traffic_bytes for p in self.unfused)


def _freeze(d: Mapping[str, int] | None):
    return tuple(sorted(d.items())) if d else None


@functools.lru_cache(maxsize=512)
def _plan_mlp_cached(
    m: int,
    d_model: int,
    d_ff: int,
    dtype: str,
    gated: bool,
    act: str,
    vmem_budget: int,
    sharded: tuple | None,
) -> MLPPlanOutcome:
    sharded_sizes = dict(sharded) if sharded else None
    g = graph.mlp_graph(m=m, d_model=d_model, d_ff=d_ff, dtype=dtype,
                        gated=gated, act=act)
    kw = dict(vmem_budget=vmem_budget, sharded_sizes=sharded_sizes)
    # the partitioner's decision over every contiguous cut of the chain
    chain = partition.plan_chain(g, **kw)
    # canonical three schedules, still priced for comparison/reporting
    unfused = tuple(
        s.plan for s in partition.plan_fixed(g, partition.all_cuts(g),
                                             **kw).segments
    )
    try:
        partial = tuple(
            s.plan
            for s in partition.plan_fixed(g, (g.n_ops - 1,), **kw).segments
        )
    except InfeasibleError:
        partial = ()
    try:
        fused = partition.plan_fixed(g, (), **kw).segments[0].plan
    except InfeasibleError:
        fused = None
    cmp = compare(fused, unfused) if fused is not None else None
    return MLPPlanOutcome(fused, unfused, cmp,
                          use_fused=chain.schedule == "fused",
                          partial=partial, schedule=chain.schedule,
                          chain=chain)


def plan_mlp(
    *,
    m: int,
    d_model: int,
    d_ff: int,
    dtype: str = "bfloat16",
    gated: bool = False,
    act: str = "gelu",
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    sharded_sizes: Mapping[str, int] | None = None,
) -> MLPPlanOutcome:
    """Plan an MLP; returns fused + baseline plans and the auto decision."""
    return _plan_mlp_cached(
        m, d_model, d_ff, dtype, gated, act, vmem_budget, _freeze(sharded_sizes)
    )


@functools.lru_cache(maxsize=512)
def plan_attention(
    *,
    q_len: int,
    kv_len: int,
    head_dim: int,
    dtype: str = "bfloat16",
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
) -> TilePlan:
    g = graph.attention_graph(q_len=q_len, kv_len=kv_len, head_dim=head_dim,
                              dtype=dtype)
    return partition.plan_fixed(g, (), vmem_budget=vmem_budget).segments[0].plan
