"""FTL traffic / memory cost model.

Models exactly what the paper's Fig. 3 measures on Siracusa: total bytes
moved between the software-managed fast memory (VMEM here, L1 there) and
the backing store (HBM here, L2/L3 there), plus the DMA-transfer count.

Traffic model
-------------
Given a tile assignment and a *grid order* (outermost → innermost), a tensor
``T`` is re-fetched every time a grid dim **outside** ``dims(T)`` that is
**outer** than T's innermost grid dim advances (the Pallas pipeline — like
Deeploy's DMA scheduler — skips the copy while T's block index is
unchanged).  Hence::

    fetches(T) = Π_{g ∈ dims(T)∩grid} n(g) · Π_{g ∉ dims(T), g outer than
                 innermost grid dim of T} n(g)
    traffic(T) = bytes_tile(T) · fetches(T)
               = bytes_full(T) · revisit(T)

Contraction grid dims are forced innermost so outputs accumulate in VMEM and
are written exactly once (kernel-policy: ``contract_accumulate``).

Intermediates of a fused group contribute **zero** HBM traffic — that is the
paper's entire point — but do occupy VMEM (single-buffered: they are
produced and consumed in-core).  Streamed HBM tensors are double-buffered.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Mapping, Sequence

from .constraints import DimConstraint, accumulator_tensors
from .ir import FusionGroup, Role, TensorSpec


@dataclasses.dataclass(frozen=True)
class CostReport:
    traffic_bytes: int           # HBM<->VMEM total
    dma_transfers: int           # number of block copies
    vmem_bytes: int              # peak VMEM footprint (with double buffering)
    grid: tuple[tuple[str, int], ...]   # (dim, n_tiles) outer->inner
    per_tensor_traffic: dict[str, int]
    macs: int

    @property
    def arithmetic_intensity(self) -> float:
        return (2.0 * self.macs) / max(1, self.traffic_bytes)


def n_tiles(size: int, tile: int) -> int:
    return -(-size // tile)


def vmem_usage(
    group: FusionGroup,
    tiles: Mapping[str, int],
    cons: Mapping[str, DimConstraint],
    *,
    double_buffer: bool = True,
) -> int:
    total = 0
    for t in group.tensors.values():
        b = t.bytes_tile(tiles)
        if t.role in (Role.INPUT, Role.WEIGHT, Role.OUTPUT):
            total += b * (2 if double_buffer else 1)
        elif t.role is Role.INTERMEDIATE:
            total += b
    for acc in accumulator_tensors(group, tiles, cons):
        total += acc.bytes_tile(tiles)
    return total


def _revisit(
    tensor: TensorSpec,
    order: Sequence[str],
    counts: Mapping[str, int],
) -> int:
    """Revisit factor for ``tensor`` under grid ``order`` (outer→inner)."""
    tdims = set(tensor.dims)
    # innermost grid position occupied by one of T's dims
    inner_pos = -1
    for i, g in enumerate(order):
        if g in tdims:
            inner_pos = i
    rev = 1
    for i, g in enumerate(order):
        if g not in tdims and i < inner_pos:
            rev *= counts[g]
    return rev


def evaluate(
    group: FusionGroup,
    tiles: Mapping[str, int],
    cons: Mapping[str, DimConstraint],
    *,
    order: Sequence[str] | None = None,
    double_buffer: bool = True,
) -> CostReport:
    """Cost of an assignment; if ``order`` is None the best grid order is
    chosen by enumeration over the tiled dims (contract dims pinned inner).
    """
    counts = {d: n_tiles(cons[d].size, tiles[d]) for d in tiles}
    tiled = [d for d, c in counts.items() if c > 1]
    contract = [d for d in tiled if cons[d].is_contract]
    free = [d for d in tiled if not cons[d].is_contract]

    hbm = group.hbm_tensors()

    def traffic_for(ordr: Sequence[str]) -> tuple[int, int, dict[str, int]]:
        per = {}
        tot = 0
        dma = 0
        for t in hbm:
            if t.role is Role.OUTPUT:
                # accumulated in VMEM; written once per output block
                rev = 1
                fetches = 1
                for d in t.dims:
                    fetches *= counts.get(d, 1)
            else:
                rev = _revisit(t, ordr, counts)
                fetches = rev
                for d in t.dims:
                    fetches *= counts.get(d, 1)
            b = t.bytes_full({d: cons[d].size for d in t.dims}) * rev
            per[t.name] = b
            tot += b
            dma += fetches
        return tot, dma, per

    if order is None:
        best = None
        # contract dims innermost (any relative order); permute free dims.
        for perm in itertools.permutations(free) if free else [()]:
            for cperm in itertools.permutations(contract) if contract else [()]:
                ordr = list(perm) + list(cperm)
                tot, dma, per = traffic_for(ordr)
                key = (tot, dma)
                if best is None or key < best[0]:
                    best = (key, ordr, per)
        (tot, dma), ordr, per = best
    else:
        ordr = list(order)
        tot, dma, per = traffic_for(ordr)

    return CostReport(
        traffic_bytes=tot,
        dma_transfers=dma,
        vmem_bytes=vmem_usage(group, tiles, cons, double_buffer=double_buffer),
        grid=tuple((d, counts[d]) for d in ordr),
        per_tensor_traffic=per,
        macs=group.total_macs(),
    )


def min_traffic_bound(group: FusionGroup, cons: Mapping[str, DimConstraint]) -> int:
    """Optimistic lower bound: every HBM tensor moved exactly once."""
    sizes = {d: c.size for d, c in cons.items()}
    return sum(t.bytes_full(sizes) for t in group.hbm_tensors())
