"""FTL traffic / memory / roofline-runtime cost model.

Models what the paper's Fig. 3 measures on Siracusa: total bytes moved
between the software-managed fast memory (VMEM here, L1 there) and the
backing tiers (HBM here, L2/L3 there), the DMA-transfer count, the
modeled *transfer time* those moves cost, and — since the FTL paper
reports runtime (not bytes) as the win, and LoopTree shows compute-bound
segments must be priced with a joint latency model — the **modeled
runtime** that is the solver's objective:

    port(p)  = Σ_{level on p}  bytes(level) / bw(level)  +  transfers(level) · dma_setup(level)
    transfer = max_port  port(p)               (Target.transfer_time)
    compute  = per-engine roofline over the group's op kinds
    runtime  = max(compute, transfer)          (hw.modeled_runtime)

Levels sharing a DMA port serialize; distinct ports (hbm vs the ici/noc
interconnect) overlap, so a segment's collective stream hides under its
memory traffic — and vice versa — exactly as the DES replays it.  With
one port in play the max degenerates to the old Σ-over-levels model
bit-exactly, which keeps every single-chip plan identical.  Collectives
(:class:`~repro.core.ftl.ir.CollectiveNode`) price their ring-formula
wire bytes against the target's interconnect level on that level's port
(:class:`CollectiveCost` entries on the report), independent of the tile
assignment.

The compute term is priced per op: each op's FLOPs run on the engine
``Target.engine_rate`` assigns its kind (the implicit single ``core``
engine at ``Target.flops`` when the target declares none — the legacy
single-rate model, bit-identical), divided by an **MXU lane-utilization
factor**: a GEMM whose output-lane (last-axis) tile is narrower than the
kernel's ``mxu_preferred`` feeds only that fraction of the systolic
array's columns, so its effective rate drops by ``min(1, tile/preferred)``
(:func:`lane_utilization`).  The factor is 1 for any lane tile ≥ the
preferred width and monotone non-decreasing in the tile size, so the
solver's optimistic full-size prune stays a valid lower bound and
aligned plans price exactly as before.  Engines overlap; work within one
engine serializes — ``Target.compute_time_by_kind`` semantics.

Compute time therefore depends on tile sizes only through utilization
(never increasing as tiles grow), so within one group the runtime
objective still reduces to: minimize transfer time while it dominates,
and break pure-compute-bound ties by (traffic, DMA count) — fusion that
buys no runtime must still not cost bytes.

Each streamed tensor is assigned a *home* backing level by the target
(smallest-first first-fit over level capacities — ``Target.assign_homes``),
so a large intermediate spills past a full L2 to L3 exactly like the
paper's overflow regime, and its traffic is priced at the deep level's
bandwidth.

Traffic model
-------------
Given a tile assignment and a *grid order* (outermost → innermost), a tensor
``T`` is re-fetched every time a grid dim **outside** ``dims(T)`` that is
**outer** than T's innermost grid dim advances (the Pallas pipeline — like
Deeploy's DMA scheduler — skips the copy while T's block index is
unchanged).  Hence::

    fetches(T) = Π_{g ∈ dims(T)∩grid} n(g) · Π_{g ∉ dims(T), g outer than
                 innermost grid dim of T} n(g)
    traffic(T) = bytes_tile(T) · fetches(T)
               = bytes_full(T) · revisit(T)

Contraction grid dims are forced innermost so outputs accumulate in VMEM and
are written exactly once (kernel-policy: ``contract_accumulate``).

Intermediates of a fused group contribute **zero** backing-store traffic —
that is the paper's entire point — but do occupy fast memory
(single-buffered: they are produced and consumed in-core).  Streamed
tensors are charged the fast level's ``buffer_depth`` (1 for a
cache-backed fast level, 2 for a DMA double-buffered pipeline, 3+ for
deeper prefetch) instead of a hard-coded ×2.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Mapping, Sequence

from repro.core import hw as hwlib

from .constraints import DimConstraint, accumulator_tensors
from .ir import CollectiveNode, FusionGroup, OpNode, Role, TensorSpec


@dataclasses.dataclass(frozen=True)
class OpCompute:
    """Compute pricing of one op of the group — the per-engine partition
    the schedule lowering (``repro.sim.schedule``) consumes."""

    name: str
    kind: str
    engine: str            # Target engine the kind is assigned to
    flops: int             # raw modeled FLOPs of the op
    utilization: float     # MXU lane-utilization factor in (0, 1]
    seconds: float         # flops / (engine rate · utilization)


@dataclasses.dataclass(frozen=True)
class CollectiveCost:
    """Wire pricing of one :class:`~repro.core.ftl.ir.CollectiveNode` in
    the group — what the schedule lowering turns into per-step ``Comm``
    events on the interconnect's DMA port.

    ``pre`` marks a collective whose operand streams *into* the segment
    (its input tensor's bound role is INPUT): the link traffic can run
    ahead of the consuming compute like a prefetch.  A collective fed by
    an in-segment producer (``pre=False``, ``producer`` names the op)
    starts behind that producer's compute; if its output is also
    consumed inside the segment (``blocking``) the rest of the step's
    compute chain waits for the wire — the real serialization cost of
    fusing a collective mid-chain, which per-step chunking then hides
    across the tile pipeline."""

    name: str
    comm: str                # all_gather | reduce_scatter | all_reduce
    level: str               # interconnect level name (ici / noc)
    bytes: int               # wire bytes per chip (ring formula)
    transfers: int           # link messages per chip
    pre: bool
    producer: str = ""       # in-segment producer op ("" when streamed)
    blocking: bool = False   # output consumed later in the segment


@dataclasses.dataclass(frozen=True)
class CostReport:
    traffic_bytes: int           # fast<->backing total
    dma_transfers: int           # number of block copies
    vmem_bytes: int              # peak fast-memory footprint (pipelined)
    grid: tuple[tuple[str, int], ...]   # (dim, n_tiles) outer->inner
    per_tensor_traffic: dict[str, int]
    macs: int
    transfer_time_s: float = 0.0        # modeled DMA time
    compute_time_s: float = 0.0         # per-engine roofline (max/engine)
    flops: int = 0                      # modeled group FLOPs
    per_level_traffic: dict[str, int] = dataclasses.field(
        default_factory=dict)           # level name -> bytes
    per_level_transfers: dict[str, int] = dataclasses.field(
        default_factory=dict)           # level name -> DMA count
    tensor_homes: dict[str, str] = dataclasses.field(
        default_factory=dict)           # tensor name -> home level name
    tensor_depths: dict[str, int] = dataclasses.field(
        default_factory=dict)           # tensor name -> staging depth
    op_compute: tuple[OpCompute, ...] = ()
    per_engine_compute_s: dict[str, float] = dataclasses.field(
        default_factory=dict)           # engine name -> serialized seconds
    collectives: tuple[CollectiveCost, ...] = ()

    @property
    def modeled_runtime_s(self) -> float:
        """The solver's objective: compute and DMA overlap, the segment
        takes whichever dominates (``hw.modeled_runtime``)."""
        return hwlib.modeled_runtime(self.compute_time_s,
                                     self.transfer_time_s)

    @property
    def compute_bound(self) -> bool:
        return self.compute_time_s >= self.transfer_time_s

    @property
    def n_steps(self) -> int:
        """Tile steps of the schedule: the grid's total block count (1
        for a single-block plan) — what the schedule IR replays."""
        steps = 1
        for _, c in self.grid:
            steps *= c
        return steps

    @property
    def mxu_utilization(self) -> float:
        """FLOP-weighted lane utilization of the assignment (1.0 = every
        GEMM tile feeds full MXU columns)."""
        if not self.op_compute:
            return 1.0
        eff = sum(oc.flops / oc.utilization for oc in self.op_compute)
        return self.flops / eff if eff else 1.0

    @property
    def arithmetic_intensity(self) -> float:
        return (2.0 * self.macs) / max(1, self.traffic_bytes)


def n_tiles(size: int, tile: int) -> int:
    return -(-size // tile)


def vmem_usage(
    group: FusionGroup,
    tiles: Mapping[str, int],
    cons: Mapping[str, DimConstraint],
    *,
    buffer_depth: int = 2,
    depths: Mapping[str, int] | None = None,
) -> int:
    """Peak fast-memory footprint of a tile assignment.

    Streamed tensors (inputs/weights/outputs) are charged
    ``buffer_depth`` tile buffers — the staging pipeline of the target's
    fast level (``Target.fast.buffer_depth``): 1 when a hardware cache
    does the prefetching, 2 for classic DMA double-buffering, 3+ for
    deeper pipelines.  ``depths`` overrides the charge per tensor name —
    the backing-level-aware ``max(fast.depth, home.depth)`` staging
    (:func:`staging_depths`); tensors it does not name fall back to
    ``buffer_depth``.  Fused-away intermediates and accumulators live
    single-buffered (produced and consumed in-core).
    """
    if buffer_depth < 1:
        raise ValueError(f"buffer_depth must be >= 1, got {buffer_depth}")
    depths = depths or {}
    total = 0
    for t in group.tensors.values():
        b = t.bytes_tile(tiles)
        if t.role in (Role.INPUT, Role.WEIGHT, Role.OUTPUT):
            total += b * depths.get(t.name, buffer_depth)
        elif t.role is Role.INTERMEDIATE:
            total += b
    for acc in accumulator_tensors(group, tiles, cons):
        total += acc.bytes_tile(tiles)
    return total


def staging_depths(
    group: FusionGroup,
    cons: Mapping[str, DimConstraint],
    target: hwlib.Target,
) -> dict[str, int]:
    """Per-streamed-tensor staging depth: ``max(fast.depth, home.depth)``
    (``Target.staging_depth``) at the tensor's home backing level.

    Home levels depend only on the *full* tensor footprints — never on
    the tile assignment — so the depths are one fixed map per
    (group, target): the solver computes them once, the feasibility
    prune stays monotone in tile sizes, and the schedule lowering
    (``repro.sim.schedule``) reuses the identical map for its buffer-slot
    hazards.
    """
    full_sizes = {d: cons[d].size for d in cons}
    footprints = {t.name: t.bytes_full(full_sizes)
                  for t in group.hbm_tensors()}
    homes = target.assign_homes(footprints)
    return {n: target.staging_depth(lv) for n, lv in homes.items()}


def lane_utilization(op: OpNode, tiles: Mapping[str, int]) -> float:
    """MXU lane-utilization of one op's tile assignment.

    A GEMM whose output lane (last-axis) tile is narrower than the
    kernel policy's ``mxu_preferred`` width occupies only
    ``tile/preferred`` of the systolic array's columns — a head-dim-64
    PV product on a 128-lane MXU runs at half rate no matter how the
    other dims tile.  ``min(1, tile/preferred)`` is monotone
    non-decreasing in the tile size (a ≥-preferred tile always prices at
    peak), which the solver's optimistic full-size prune relies on.
    Non-GEMM ops are not discounted: the VPU consumes whole vregs
    regardless and their compute term is second-order.
    """
    if op.kind != "gemm":
        return 1.0
    lane = op.output.dims[-1]
    tile = tiles.get(lane)
    if tile is None:
        return 1.0
    return min(1.0, tile / op.policy.mxu_preferred)


def compute_costs(
    group: FusionGroup,
    tiles: Mapping[str, int],
    full_sizes: Mapping[str, int],
    target: hwlib.Target,
    engine_overrides: Mapping[str, str] | None = None,
) -> tuple[tuple[OpCompute, ...], dict[str, float], float]:
    """Per-op / per-engine compute pricing of an assignment.

    Returns ``(op_compute, per_engine_seconds, compute_time_s)``.  Each
    op's FLOPs (at the constraint sizes) run on the engine its kind maps
    to, rate-discounted by :func:`lane_utilization`; engines overlap, so
    the group's compute time is the busiest engine's serialized time.
    Engine-less targets collapse to the legacy single-rate formula via
    effective FLOPs (``Σ flops/utilization``), bit-identical to
    ``Target.compute_time_s`` when every tile is lane-aligned.

    ``engine_overrides`` (op kind → engine name, entries drawn from
    ``Target.engines_for_kind``) pins kinds to specific engines instead
    of the default fastest-match rule — the autotuner's load-balancing
    knob: analytically never better than the default (the default picks
    the fastest engine per kind), but a deliberate slower-engine
    assignment can win simulated runtime by overlapping with the
    bottleneck engine.
    """
    overrides = engine_overrides or {}
    ops: list[OpCompute] = []
    per_engine: dict[str, float] = {}
    eff_total = 0.0
    for op in group.ops:
        f = op.flops(full_sizes)
        if f == 0:
            # collectives (flops_per_macs=0) are pure wire traffic: they
            # occupy no engine, so they never appear in the compute chain
            continue
        util = lane_utilization(op, tiles)
        if op.kind in overrides:
            engine = overrides[op.kind]
            rate = target.engine_rate_for(op.kind, engine)
        else:
            engine, rate = target.engine_rate(op.kind)
        secs = f / (rate * util)
        ops.append(OpCompute(name=op.name, kind=op.kind, engine=engine,
                             flops=f, utilization=util, seconds=secs))
        per_engine[engine] = per_engine.get(engine, 0.0) + secs
        eff_total += f if util == 1.0 else f / util
    if target.engines:
        compute_s = max(per_engine.values(), default=0.0)
    else:
        compute_s = hwlib.compute_time(eff_total, target.flops)
    return tuple(ops), per_engine, compute_s


def _revisit(
    tensor: TensorSpec,
    order: Sequence[str],
    counts: Mapping[str, int],
) -> int:
    """Revisit factor for ``tensor`` under grid ``order`` (outer→inner)."""
    tdims = set(tensor.dims)
    # innermost grid position occupied by one of T's dims
    inner_pos = -1
    for i, g in enumerate(order):
        if g in tdims:
            inner_pos = i
    rev = 1
    for i, g in enumerate(order):
        if g not in tdims and i < inner_pos:
            rev *= counts[g]
    return rev


def evaluate(
    group: FusionGroup,
    tiles: Mapping[str, int],
    cons: Mapping[str, DimConstraint],
    *,
    target: hwlib.Target | None = None,
    order: Sequence[str] | None = None,
    engine_overrides: Mapping[str, str] | None = None,
) -> CostReport:
    """Cost of an assignment on ``target`` (None → the default target).

    If ``order`` is None the best grid order is chosen by enumeration
    over the tiled dims (contract dims pinned inner), minimizing modeled
    runtime with (traffic, DMA count) as the tie-break — compute time is
    order-invariant, so in the compute-bound regime the order with the
    fewest bytes wins.  ``engine_overrides`` pins op kinds to specific
    engines (see :func:`compute_costs`).
    """
    target = target if target is not None else hwlib.default_target()
    counts = {d: n_tiles(cons[d].size, tiles[d]) for d in tiles}
    tiled = [d for d, c in counts.items() if c > 1]
    contract = [d for d in tiled if cons[d].is_contract]
    free = [d for d in tiled if not cons[d].is_contract]

    hbm = group.hbm_tensors()
    full_sizes = {d: cons[d].size for d in cons}
    footprints = {t.name: t.bytes_full(full_sizes) for t in hbm}
    homes = target.assign_homes(footprints)
    depths = {n: target.staging_depth(lv) for n, lv in homes.items()}
    # fixed per-tensor weights: home levels depend only on full tensor
    # sizes, so the modeled time stays monotone in tile sizes and the
    # solver's optimistic full-size prune remains a valid lower bound.
    w_bytes = {n: 1.0 / homes[n].bw_bytes_per_s for n in homes}
    w_dma = {n: homes[n].dma_setup_s for n in homes}
    w_port = {n: homes[n].dma_port for n in homes}

    # collectives: a fixed wire cost per segment run, priced against the
    # interconnect level's bandwidth/setup on its own DMA port.  Tile-
    # independent (the whole payload crosses the link however the grid
    # tiles), so per-port times stay monotone non-increasing in tile
    # sizes and the solver's prunes survive.
    colls = [op for op in group.ops
             if isinstance(op, CollectiveNode) and op.mesh_size > 1]
    comm_costs: tuple[CollectiveCost, ...] = ()
    comm_time_s = 0.0
    comm_port = None
    if colls:
        icl = target.interconnect
        if icl is None:
            raise ValueError(
                f"group {group.name} contains collectives but target "
                f"{target.name} has no interconnect level to price them on"
            )
        comm_port = icl.dma_port
        costs = []
        for op in colls:
            cb = op.comm_bytes(full_sizes)
            ct = op.comm_transfers(full_sizes)
            role = group.tensors[op.inputs[0].name].role
            producer = next(
                (o.name for o in group.ops
                 if o is not op and o.output.name == op.inputs[0].name),
                "")
            consumed = any(
                op.output.name in (t.name for t in o.inputs)
                for o in group.ops if o is not op)
            costs.append(CollectiveCost(
                name=op.name, comm=op.comm, level=icl.name,
                bytes=cb, transfers=ct, pre=role is Role.INPUT,
                producer=producer, blocking=consumed))
            comm_time_s += cb / icl.bw_bytes_per_s + ct * icl.dma_setup_s
        comm_costs = tuple(costs)

    def traffic_for(
        ordr: Sequence[str],
    ) -> tuple[float, int, int, dict[str, int], dict[str, int]]:
        per = {}
        fetches_per = {}
        tot = 0
        dma = 0
        port_time: dict[str, float] = {}
        if comm_port is not None:
            port_time[comm_port] = comm_time_s
        for t in hbm:
            if t.role is Role.OUTPUT:
                # accumulated in fast memory; written once per output block
                rev = 1
                fetches = 1
                for d in t.dims:
                    fetches *= counts.get(d, 1)
            else:
                rev = _revisit(t, ordr, counts)
                fetches = rev
                for d in t.dims:
                    fetches *= counts.get(d, 1)
            b = footprints[t.name] * rev
            per[t.name] = b
            fetches_per[t.name] = fetches
            tot += b
            dma += fetches
            p = w_port[t.name]
            port_time[p] = port_time.get(p, 0.0) \
                + b * w_bytes[t.name] + fetches * w_dma[t.name]
        # ports overlap: the ranking time is the busiest port's, matching
        # Target.transfer_time's max-over-ports model
        time_s = max(port_time.values(), default=0.0)
        return time_s, tot, dma, per, fetches_per

    # FLOPs at the *constraint* sizes, not group.total_flops(): under
    # sharded_sizes the solver prices the per-shard problem, and the
    # compute term must cover the same per-shard work the transfer term
    # does or sharded plans would look spuriously compute-bound.
    op_costs, per_engine, compute_s = compute_costs(
        group, tiles, full_sizes, target, engine_overrides)
    flops = sum(oc.flops for oc in op_costs)

    if order is None:
        best = None
        # contract dims innermost (any relative order); permute free dims.
        for perm in itertools.permutations(free) if free else [()]:
            for cperm in itertools.permutations(contract) if contract else [()]:
                ordr = list(perm) + list(cperm)
                time_s, tot, dma, per, fper = traffic_for(ordr)
                key = (hwlib.modeled_runtime(compute_s, time_s), tot, dma)
                if best is None or key < best[0]:
                    best = (key, time_s, ordr, per, fper)
        _, time_s, ordr, per, fper = best
    else:
        ordr = list(order)
        time_s, tot, dma, per, fper = traffic_for(ordr)

    lvl_bytes: dict[str, int] = {}
    lvl_dma: dict[str, int] = {}
    for n, b in per.items():
        lname = homes[n].name
        lvl_bytes[lname] = lvl_bytes.get(lname, 0) + b
        lvl_dma[lname] = lvl_dma.get(lname, 0) + fper[n]
    for cc in comm_costs:
        lvl_bytes[cc.level] = lvl_bytes.get(cc.level, 0) + cc.bytes
        lvl_dma[cc.level] = lvl_dma.get(cc.level, 0) + cc.transfers
    tot = sum(lvl_bytes.values())
    dma = sum(lvl_dma.values())

    return CostReport(
        traffic_bytes=tot,
        dma_transfers=dma,
        vmem_bytes=vmem_usage(
            group, tiles, cons,
            buffer_depth=target.fast.buffer_depth,
            depths=depths),
        grid=tuple((d, counts[d]) for d in ordr),
        per_tensor_traffic=per,
        macs=group.total_macs(),
        # Target.transfer_time / compute_time_s are the canonical
        # formulas; the per-tensor weights inside traffic_for are their
        # factored-out form used only to rank grid orders cheaply.
        transfer_time_s=target.transfer_time(lvl_bytes, lvl_dma),
        compute_time_s=compute_s,
        flops=flops,
        per_level_traffic=lvl_bytes,
        per_level_transfers=lvl_dma,
        tensor_homes={n: lv.name for n, lv in homes.items()},
        tensor_depths=depths,
        op_compute=op_costs,
        per_engine_compute_s=per_engine,
        collectives=comm_costs,
    )


def min_traffic_bound(group: FusionGroup, cons: Mapping[str, DimConstraint]) -> int:
    """Optimistic lower bound: every streamed tensor moved exactly once."""
    sizes = {d: c.size for d, c in cons.items()}
    return sum(t.bytes_full(sizes) for t in group.hbm_tensors())
