"""TilePlan — the solver's output artifact.

A plan carries everything downstream consumers need:

* the tile size per dim variable (→ Pallas ``BlockSpec`` block shapes),
* the grid (outer→inner) with per-dim tile counts,
* the cost report (HBM traffic, DMA count, VMEM bytes) — the paper's
  reported metrics,
* helpers to compare a fused plan against the layer-per-layer baseline
  (reproduces the paper's "-47.1 % DMA transfers" table).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core import hw as hwlib

from .constraints import DimConstraint
from .cost import CostReport
from .ir import FusionGroup


@dataclasses.dataclass
class TilePlan:
    group: FusionGroup
    tiles: dict[str, int]
    constraints: dict[str, DimConstraint]
    report: CostReport
    target: hwlib.Target
    nodes_explored: int = 0

    @property
    def vmem_budget(self) -> int:
        """Fast-level capacity of the planning target (back-compat name)."""
        return self.target.fast_capacity

    # ------------------------------------------------------------------
    # accessors used by the kernels
    # ------------------------------------------------------------------
    def tile(self, dim: str) -> int:
        return self.tiles[dim]

    def size(self, dim: str) -> int:
        return self.constraints[dim].size

    def grid_dims(self) -> tuple[str, ...]:
        """Grid dims outer→inner (only dims with >1 tile)."""
        return tuple(d for d, _ in self.report.grid)

    def grid_shape(self) -> tuple[int, ...]:
        return tuple(c for _, c in self.report.grid)

    def block_shape(self, tensor: str) -> tuple[int, ...]:
        t = self.group.tensors[tensor]
        return tuple(self.tiles[d] for d in t.dims)

    # ------------------------------------------------------------------
    # reporting
    # ------------------------------------------------------------------
    @property
    def traffic_bytes(self) -> int:
        return self.report.traffic_bytes

    @property
    def dma_transfers(self) -> int:
        return self.report.dma_transfers

    @property
    def vmem_bytes(self) -> int:
        return self.report.vmem_bytes

    @property
    def transfer_time_s(self) -> float:
        return self.report.transfer_time_s

    @property
    def compute_time_s(self) -> float:
        return self.report.compute_time_s

    @property
    def modeled_runtime_s(self) -> float:
        """The solver's objective: max(compute, transfer)."""
        return self.report.modeled_runtime_s

    @property
    def compute_bound(self) -> bool:
        return self.report.compute_bound

    @property
    def n_steps(self) -> int:
        """Tile steps of the schedule (grid block count) — what the
        schedule IR in ``repro.sim`` replays event by event."""
        return self.report.n_steps

    @property
    def per_engine_compute_s(self) -> dict[str, float]:
        """Serialized compute seconds per Target engine (the implicit
        ``core`` engine for engine-less targets)."""
        return self.report.per_engine_compute_s

    @property
    def per_level_traffic(self) -> dict[str, int]:
        return self.report.per_level_traffic

    def intermediate_bytes_avoided(self) -> int:
        """HBM bytes the fusion avoids: every intermediate is written once
        and read once in the layer-per-layer schedule (at minimum)."""
        sizes = {d: c.size for d, c in self.constraints.items()}
        return sum(
            2 * t.bytes_full(sizes) for t in self.group.intermediate_tensors()
        )

    def summary(self) -> str:
        per_level = ", ".join(
            f"{name}={b / 2**20:.2f} MiB"
            for name, b in self.report.per_level_traffic.items()
        )
        lines = [
            f"FTL plan '{self.group.name}' on target '{self.target.name}':",
            f"  tiles   : "
            + ", ".join(f"{d}={self.tiles[d]}/{self.constraints[d].size}"
                        for d in sorted(self.tiles)),
            f"  grid    : "
            + " > ".join(f"{d}x{c}" for d, c in self.report.grid)
            + (" (single block)" if not self.report.grid else ""),
            f"  {self.target.fast.name:7s} : "
            f"{self.vmem_bytes/2**20:.2f} MiB / "
            f"{self.vmem_budget/2**20:.2f} MiB budget",
            f"  traffic : {self.traffic_bytes/2**20:.2f} MiB over "
            f"{self.dma_transfers} DMA transfers ({per_level})",
            f"  time    : {1e3 * self.modeled_runtime_s:.3f} ms modeled "
            f"runtime (compute {1e3 * self.compute_time_s:.3f} ms, "
            f"transfer {1e3 * self.transfer_time_s:.3f} ms; "
            f"{'compute' if self.compute_bound else 'transfer'}-bound)",
            f"  AI      : {self.report.arithmetic_intensity:.1f} FLOP/B",
        ]
        return "\n".join(lines)


@dataclasses.dataclass(frozen=True)
class FusionComparison:
    """Fused-vs-unfused metrics — the paper's headline numbers."""

    fused_traffic: int
    unfused_traffic: int
    fused_dma: int
    unfused_dma: int
    fused_vmem: int
    unfused_vmem: int

    @property
    def traffic_reduction(self) -> float:
        return 1.0 - self.fused_traffic / max(1, self.unfused_traffic)

    @property
    def dma_reduction(self) -> float:
        return 1.0 - self.fused_dma / max(1, self.unfused_dma)

    def summary(self) -> str:
        return (
            f"traffic {self.unfused_traffic/2**20:.2f} MiB -> "
            f"{self.fused_traffic/2**20:.2f} MiB "
            f"({100*self.traffic_reduction:.1f} % reduction); "
            f"DMA {self.unfused_dma} -> {self.fused_dma} "
            f"({100*self.dma_reduction:.1f} % reduction)"
        )


def compare(fused: TilePlan, unfused: Sequence[TilePlan]) -> FusionComparison:
    return FusionComparison(
        fused_traffic=fused.traffic_bytes,
        unfused_traffic=sum(p.traffic_bytes for p in unfused),
        fused_dma=fused.dma_transfers,
        unfused_dma=sum(p.dma_transfers for p in unfused),
        fused_vmem=fused.vmem_bytes,
        unfused_vmem=max(p.vmem_bytes for p in unfused),
    )
