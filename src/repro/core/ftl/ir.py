"""FTL intermediate representation (paper step 1).

Every operator participating in Fused-Tiled-Layer planning is described by
an :class:`OpNode` over named :class:`Dim` variables.  A tensor is a tuple
of dims; an op declares how its output dims relate to its input dims via
:class:`DimLink`.  Dimension *names* are the constraint variables of the
paper: fusing two ops binds the shared tensor's names together (step 3),
after which one joint constraint problem is solved (step 4).

The IR is deliberately tiny — GEMM-like contractions, elementwise maps and
reductions cover every layer the paper (and our model zoo) fuses.  Window
(conv-like) links are included for the whisper/frontend family.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Mapping, Sequence

# ---------------------------------------------------------------------------
# dtypes: we avoid importing jax here so the solver is usable standalone.
# ---------------------------------------------------------------------------
_DTYPE_BYTES = {
    "float32": 4,
    "bfloat16": 2,
    "float16": 2,
    "int8": 1,
    "uint8": 1,
    "int32": 4,
}


def dtype_bytes(dtype: str) -> int:
    try:
        return _DTYPE_BYTES[dtype]
    except KeyError as e:
        raise ValueError(f"unknown dtype {dtype!r}") from e


class Role(enum.Enum):
    """Where a tensor lives for the planner."""

    INPUT = "input"            # streamed HBM -> VMEM
    WEIGHT = "weight"          # streamed HBM -> VMEM (revisited across grid)
    OUTPUT = "output"          # streamed VMEM -> HBM
    INTERMEDIATE = "intermediate"  # fused away: VMEM-resident tile only
    ACCUMULATOR = "accumulator"    # fp32 VMEM scratch (contraction tiling)


class LinkKind(enum.Enum):
    EQ = "eq"               # output dim == input dim (same variable)
    CONTRACT = "contract"   # input dim reduced away by this op
    WINDOW = "window"       # input dim = stride*out + (k - stride)  (conv)
    BROADCAST = "broadcast"  # input lacks this output dim


@dataclasses.dataclass(frozen=True)
class Dim:
    """A named dimension variable with its full (untiled) size."""

    name: str
    size: int

    def __post_init__(self):
        if self.size <= 0:
            raise ValueError(f"dim {self.name} has nonpositive size {self.size}")


@dataclasses.dataclass(frozen=True)
class TensorSpec:
    """A tensor = ordered dims + dtype + role."""

    name: str
    dims: tuple[str, ...]          # dim variable names, row-major
    dtype: str = "bfloat16"
    role: Role = Role.INPUT

    @property
    def rank(self) -> int:
        return len(self.dims)

    def bytes_full(self, sizes: Mapping[str, int]) -> int:
        n = dtype_bytes(self.dtype)
        for d in self.dims:
            n *= sizes[d]
        return n

    def bytes_tile(self, tiles: Mapping[str, int]) -> int:
        n = dtype_bytes(self.dtype)
        for d in self.dims:
            n *= tiles[d]
        return n


@dataclasses.dataclass(frozen=True)
class DimLink:
    """Relation between an op's input dim and a (possibly absent) output dim."""

    input_tensor: str
    input_dim: str
    kind: LinkKind
    output_dim: str | None = None   # None for CONTRACT
    window: int = 1                 # conv kernel size (WINDOW only)
    stride: int = 1                 # conv stride (WINDOW only)


@dataclasses.dataclass(frozen=True)
class KernelPolicy:
    """Paper step-2 'kernel policy constraints' — the dataflow a kernel
    implementation permits, plus hardware alignment.

    * ``contract_whole``: the contraction dim(s) must be un-tiled (classic
      output-stationary GEMM without a K loop).
    * ``contract_accumulate``: contraction dims may be tiled, requiring an
      fp32 accumulator buffer in VMEM for the output tile.
    * ``lane_align`` / ``sublane_align``: the TPU VREG lattice — last dim in
      multiples of 128 lanes, second-minor in multiples of 8 (fp32) or 16
      (bf16) sublanes.  (The paper's analogue: SIMD width / NPU systolic
      geometry.)
    """

    contract_whole: bool = False
    contract_accumulate: bool = True
    lane_align: int = 128
    sublane_align: int = 8
    min_tile: int = 1               # performance constraint floor
    mxu_preferred: int = 128        # prefer tiles that feed full MXU blocks


@dataclasses.dataclass(frozen=True)
class OpNode:
    """One operator in a fusion group."""

    name: str
    kind: str                       # 'gemm' | 'elementwise' | 'reduce' | ...
    inputs: tuple[TensorSpec, ...]
    output: TensorSpec
    links: tuple[DimLink, ...]
    policy: KernelPolicy = KernelPolicy()
    # FLOPs per output element *per contraction element* for cost reporting.
    flops_per_macs: int = 2

    def contract_dims(self) -> tuple[str, ...]:
        seen: list[str] = []
        for l in self.links:
            if l.kind is LinkKind.CONTRACT and l.input_dim not in seen:
                seen.append(l.input_dim)
        return tuple(seen)

    def tensors(self) -> tuple[TensorSpec, ...]:
        return self.inputs + (self.output,)

    def flops(self, sizes: Mapping[str, int]) -> int:
        """Modeled FLOPs of this op at the given full dim sizes:
        output elements × contraction elements × ``flops_per_macs``
        (2 for a GEMM's multiply-accumulate, 1 for elementwise maps).
        This is the per-op compute term the planner's roofline objective
        and ``repro.roofline`` both price through ``Target.flops``."""
        n = self.flops_per_macs
        for d in self.output.dims:
            n *= sizes[d]
        for d in self.contract_dims():
            n *= sizes[d]
        return n


@dataclasses.dataclass(frozen=True)
class CollectiveNode(OpNode):
    """A mesh collective as a first-class op in the chain.

    ``comm`` is one of ``'all_gather'`` / ``'reduce_scatter'`` /
    ``'all_reduce'``; ``mesh_size`` the number of participating chips.
    The node carries zero FLOPs (``flops_per_macs=0`` — the per-element
    reduce adds are noise next to the link time) and its payload moves
    over the target's *interconnect* level's DMA port, so the cost model
    prices it on a port that overlaps the segment's memory traffic
    instead of folding it into compute or HBM time.

    Bytes on the wire follow the standard ring formulas over the tensor
    the shards reassemble into (``(N-1)/N ×`` the full payload per
    direction, doubled for all-reduce's reduce-scatter + all-gather
    phases); :meth:`comm_bytes` / :meth:`comm_transfers` evaluate them
    so `cost.evaluate` and the DES agree on the wire traffic."""

    comm: str = "all_reduce"
    mesh_size: int = 1

    def __post_init__(self):
        if self.comm not in ("all_gather", "reduce_scatter", "all_reduce"):
            raise ValueError(
                f"collective {self.name}: unknown comm {self.comm!r}")
        if self.mesh_size < 1:
            raise ValueError(
                f"collective {self.name}: mesh_size must be >= 1, got "
                f"{self.mesh_size}")

    def _payload(self, sizes: Mapping[str, int]) -> int:
        # all_gather reassembles its *output*; reduce_scatter and
        # all_reduce reduce over their full-size *input*.
        t = self.output if self.comm == "all_gather" else self.inputs[0]
        return t.bytes_full(sizes)

    def comm_bytes(self, sizes: Mapping[str, int]) -> int:
        """Bytes each chip moves over the link (ring algorithm)."""
        n = self.mesh_size
        if n <= 1:
            return 0
        phases = 2 if self.comm == "all_reduce" else 1
        return phases * self._payload(sizes) * (n - 1) // n

    def comm_transfers(self, sizes: Mapping[str, int]) -> int:
        """Link messages per chip: one per ring step (and phase)."""
        n = self.mesh_size
        if n <= 1:
            return 0
        phases = 2 if self.comm == "all_reduce" else 1
        return phases * (n - 1)


def collective(
    name: str,
    comm: str,
    x: TensorSpec,
    out: TensorSpec,
    mesh_size: int,
) -> CollectiveNode:
    """Build a :class:`CollectiveNode` ``out = comm(x)`` (same dims —
    the shard spec is carried by the *sizes* the capture shrank, so the
    planner's tiling constraints bind through plain EQ links)."""
    links = tuple(
        DimLink(x.name, d, LinkKind.EQ, d) for d in x.dims
    )
    return CollectiveNode(
        name=name,
        kind="collective",
        inputs=(x,),
        output=out,
        links=links,
        flops_per_macs=0,
        comm=comm,
        mesh_size=mesh_size,
    )


@dataclasses.dataclass
class FusionGroup:
    """A chain of ops being planned together (paper step 3 output).

    ``dims`` maps variable name -> Dim (full size).  ``tensors`` maps tensor
    name -> TensorSpec with the *post-binding* role (shared intermediates
    are Role.INTERMEDIATE).
    """

    name: str
    ops: list[OpNode]
    dims: dict[str, Dim]
    tensors: dict[str, TensorSpec]

    def dim_sizes(self) -> dict[str, int]:
        return {d.name: d.size for d in self.dims.values()}

    def hbm_tensors(self) -> list[TensorSpec]:
        return [
            t
            for t in self.tensors.values()
            if t.role in (Role.INPUT, Role.WEIGHT, Role.OUTPUT)
        ]

    def intermediate_tensors(self) -> list[TensorSpec]:
        return [
            t for t in self.tensors.values() if t.role is Role.INTERMEDIATE
        ]

    def validate(self) -> None:
        for op in self.ops:
            for t in op.tensors():
                for d in t.dims:
                    if d not in self.dims:
                        raise ValueError(
                            f"op {op.name}: tensor {t.name} uses unknown dim {d}"
                        )
        # Each intermediate must be produced exactly once and consumed >= once.
        produced = {op.output.name for op in self.ops}
        for t in self.intermediate_tensors():
            if t.name not in produced:
                raise ValueError(f"intermediate {t.name} never produced")

    def total_macs(self) -> int:
        """MAC count of the whole group (for utilization reporting)."""
        total = 0
        sizes = self.dim_sizes()
        for op in self.ops:
            if op.kind != "gemm":
                continue
            n = 1
            for d in op.output.dims:
                n *= sizes[d]
            for d in op.contract_dims():
                n *= sizes[d]
            total += n
        return total

    def total_flops(self) -> int:
        """Modeled FLOPs of the whole group: Σ_op ``op.flops`` — GEMMs at
        2 FLOPs/MAC, elementwise ops at 1 FLOP/element.  Partition-
        invariant over a chain (fusion never changes the arithmetic), so
        the DP's compute term differs between partitions only through
        each segment's max() against its own transfer time."""
        sizes = self.dim_sizes()
        return sum(op.flops(sizes) for op in self.ops)


# ---------------------------------------------------------------------------
# Builders for the op kinds the model zoo uses.
# ---------------------------------------------------------------------------

def gemm(
    name: str,
    x: TensorSpec,
    w: TensorSpec,
    out: TensorSpec,
    contract: str,
    policy: KernelPolicy | None = None,
) -> OpNode:
    """out[M.., N] = sum_K x[M.., K] * w[K, N]  (row-major conventions)."""
    links = []
    for d in x.dims:
        if d == contract:
            links.append(DimLink(x.name, d, LinkKind.CONTRACT))
        else:
            links.append(DimLink(x.name, d, LinkKind.EQ, d))
    for d in w.dims:
        if d == contract:
            links.append(DimLink(w.name, d, LinkKind.CONTRACT))
        else:
            links.append(DimLink(w.name, d, LinkKind.EQ, d))
    return OpNode(
        name=name,
        kind="gemm",
        inputs=(x, w),
        output=out,
        links=tuple(links),
        policy=policy or KernelPolicy(),
    )


def elementwise(
    name: str,
    inputs: Sequence[TensorSpec],
    out: TensorSpec,
    policy: KernelPolicy | None = None,
) -> OpNode:
    links = []
    for t in inputs:
        for d in t.dims:
            links.append(DimLink(t.name, d, LinkKind.EQ, d))
    return OpNode(
        name=name,
        kind="elementwise",
        inputs=tuple(inputs),
        output=out,
        links=tuple(links),
        policy=policy or KernelPolicy(),
        flops_per_macs=1,
    )


def aligned_divisors(n: int, align: int, *, include_full: bool = True) -> list[int]:
    """Candidate tile sizes for a dim of size ``n``: divisors of n that are
    multiples of ``align`` (or equal to n itself — a whole dim never needs
    alignment since there is no partial tile)."""
    cands = set()
    for d in range(1, int(math.isqrt(n)) + 1):
        if n % d == 0:
            for c in (d, n // d):
                if c % align == 0 or c == n:
                    cands.add(c)
    if include_full:
        cands.add(n)
    if not cands:
        # dim smaller than alignment: only the whole dim is legal.
        cands.add(n)
    return sorted(cands)
