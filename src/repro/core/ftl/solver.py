"""FTL tile-size solver (paper step 4).

Exact branch-and-bound over the aligned-divisor lattice of every dim
variable in a (possibly fused) group, minimizing the *modeled roofline
runtime* of the cost model on the planning :class:`~repro.core.hw.Target`
— ``max(compute_time, transfer_time)`` with (traffic, DMA count, grid
steps) as tie-breaks — subject to the fast level's capacity constraint
at its pipeline ``buffer_depth``.

Pruning relies on two monotonicities:
  * fast-memory footprint grows with tile sizes -> feasibility prune from
    below,
  * per-tensor traffic, DMA count AND compute time shrink (or stay) with
    tile sizes — the per-tensor level weights are tile-independent and
    the compute term depends on tiles only through the lane-utilization
    factor, which is monotone non-decreasing in the lane tile
    (``cost.lane_utilization``) — so the full cost key with the
    remaining dims at full size is a component-wise (hence
    lexicographic) lower bound over the subtree.  Bounding the whole key (not just the time term) keeps the
    prune biting in the compute-bound regime, where every assignment ties
    on runtime and the search would otherwise degenerate to exhaustive.

Groups have <= ~8 dims with <= 14 candidates each; with the two prunes the
search visits a few thousand nodes in practice (tested up to production
GEMM shapes, see tests/test_ftl_solver.py).
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

from repro.core import hw as hwlib

from .constraints import build_dim_constraints
from .cost import (
    CostReport,
    evaluate,
    min_traffic_bound,
    staging_depths,
    vmem_usage,
)
from .ir import FusionGroup
from .plan import TilePlan


class InfeasibleError(RuntimeError):
    """No tile assignment fits the target's fast memory."""


@dataclasses.dataclass
class _SearchState:
    # up to k incumbents, sorted ascending by (key, seq); seq is the
    # insertion counter, so ties keep the earlier-found assignment —
    # exactly the strict-< incumbent rule of the k=1 search.
    best: list[tuple[tuple, int, dict, CostReport]] = \
        dataclasses.field(default_factory=list)
    nodes: int = 0
    seq: int = 0


def solve_top_k(
    group: FusionGroup,
    *,
    target: hwlib.Target | None = None,
    sharded_sizes: Mapping[str, int] | None = None,
    whole_dims: frozenset[str] = frozenset(),
    k: int = 1,
) -> list[TilePlan]:
    """The ``k`` best tile assignments for ``group`` on ``target``,
    best-first (the autotuner's analytic shortlist).

    Same exact branch-and-bound as :func:`solve` — the optimality prune
    merely compares the optimistic subtree bound against the *worst*
    incumbent once ``k`` plans are held, so entry 0 is always the plan
    :func:`solve` returns and the list is the true top-k (no heuristic
    truncation).  Fewer than ``k`` feasible assignments return them all;
    zero raises :class:`InfeasibleError`.
    """
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    target = target if target is not None else hwlib.default_target()
    budget = target.fast_capacity
    group.validate()
    cons = build_dim_constraints(
        group, sharded_sizes=sharded_sizes, whole_dims=whole_dims
    )
    # Per-tensor staging depths are tile-independent (homes follow full
    # footprints only), so one map serves every probe and the prunes
    # stay exact.
    depths = staging_depths(group, cons, target)
    names = sorted(
        cons,
        # Put large dims first: their candidate choice constrains the fast
        # footprint most, so pruning bites early.
        key=lambda n: -cons[n].size,
    )
    state = _SearchState()

    def leaf(tiles: dict[str, int]) -> None:
        rep = evaluate(group, tiles, cons, target=target)
        if rep.vmem_bytes > budget:
            return
        key = (rep.modeled_runtime_s, rep.traffic_bytes, rep.dma_transfers,
               rep.n_steps)
        if len(state.best) == k and key >= state.best[-1][0]:
            return
        state.seq += 1
        state.best.append((key, state.seq, dict(tiles), rep))
        state.best.sort(key=lambda e: (e[0], e[1]))
        del state.best[k:]

    def dfs(i: int, tiles: dict[str, int]) -> None:
        state.nodes += 1
        if i == len(names):
            leaf(tiles)
            return
        name = names[i]
        cands = cons[name].candidates
        for c in cands:
            tiles[name] = c
            # --- feasibility prune: remaining dims at their MIN candidate.
            probe = dict(tiles)
            for j in range(i + 1, len(names)):
                probe[names[j]] = cons[names[j]].candidates[0]
            if vmem_usage(group, probe, cons, depths=depths) > budget:
                # candidates ascend; larger c only makes it worse.
                del tiles[name]
                break
            # --- optimality prune: remaining dims at FULL size (optimistic).
            if len(state.best) == k:
                opt = dict(tiles)
                for j in range(i + 1, len(names)):
                    opt[names[j]] = cons[names[j]].size
                rep = evaluate(group, opt, cons, target=target)
                # runtime, traffic and DMA count all shrink (or stay) as
                # tiles grow and steps >= 1, so the optimistic full-size
                # key bounds every leaf's key from below component-wise —
                # hence lexicographically.  A subtree whose bound cannot
                # strictly beat the worst held incumbent is dead (ties
                # keep the earlier incumbent anyway).
                opt_key = (rep.modeled_runtime_s, rep.traffic_bytes,
                           rep.dma_transfers, 1)
                if opt_key >= state.best[-1][0]:
                    continue
            dfs(i + 1, tiles)
        tiles.pop(name, None)

    dfs(0, {})
    if not state.best:
        raise InfeasibleError(
            f"group {group.name}: no tile assignment fits the {budget} B "
            f"{target.fast.name} of target {target.name} "
            f"(lower bound traffic {min_traffic_bound(group, cons)} B)"
        )
    return [
        TilePlan(
            group=group,
            tiles=tiles,
            constraints=cons,
            report=rep,
            target=target,
            nodes_explored=state.nodes,
        )
        for _, _, tiles, rep in state.best
    ]


def solve(
    group: FusionGroup,
    *,
    target: hwlib.Target | None = None,
    sharded_sizes: Mapping[str, int] | None = None,
    whole_dims: frozenset[str] = frozenset(),
) -> TilePlan:
    """Plan tiling for ``group`` on ``target`` (None → the default target);
    returns the optimal :class:`TilePlan`."""
    return solve_top_k(
        group, target=target, sharded_sizes=sharded_sizes,
        whole_dims=whole_dims, k=1,
    )[0]
