"""FTL tile-size solver (paper step 4).

Exact branch-and-bound over the aligned-divisor lattice of every dim
variable in a (possibly fused) group, minimizing the HBM<->VMEM traffic of
the cost model subject to the VMEM capacity constraint.

Pruning relies on two monotonicities:
  * VMEM footprint grows with tile sizes  -> feasibility prune from below,
  * traffic shrinks with tile sizes       -> optimistic bound with the
    remaining dims at full size is a valid lower bound.

Groups have <= ~8 dims with <= 14 candidates each; with the two prunes the
search visits a few thousand nodes in practice (tested up to production
GEMM shapes, see tests/test_ftl_solver.py).
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

from .constraints import build_dim_constraints
from .cost import CostReport, evaluate, min_traffic_bound, vmem_usage
from .ir import FusionGroup
from .plan import TilePlan

# TPU v5e-class VMEM budget (bytes).  The planner leaves headroom for the
# pipeline machinery / semaphores, matching what pallas itself can claim.
DEFAULT_VMEM_BUDGET = 96 * 1024 * 1024


class InfeasibleError(RuntimeError):
    """No tile assignment fits the memory budget."""


@dataclasses.dataclass
class _SearchState:
    best_key: tuple | None = None
    best_tiles: dict | None = None
    best_report: CostReport | None = None
    nodes: int = 0


def solve(
    group: FusionGroup,
    *,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    sharded_sizes: Mapping[str, int] | None = None,
    whole_dims: frozenset[str] = frozenset(),
    double_buffer: bool = True,
) -> TilePlan:
    """Plan tiling for ``group``; returns the optimal :class:`TilePlan`."""
    group.validate()
    cons = build_dim_constraints(
        group, sharded_sizes=sharded_sizes, whole_dims=whole_dims
    )
    names = sorted(
        cons,
        # Put large dims first: their candidate choice constrains VMEM most,
        # so pruning bites early.
        key=lambda n: -cons[n].size,
    )
    state = _SearchState()

    def leaf(tiles: dict[str, int]) -> None:
        rep = evaluate(group, tiles, cons, double_buffer=double_buffer)
        if rep.vmem_bytes > vmem_budget:
            return
        steps = 1
        for _, c in rep.grid:
            steps *= c
        key = (rep.traffic_bytes, rep.dma_transfers, steps)
        if state.best_key is None or key < state.best_key:
            state.best_key = key
            state.best_tiles = dict(tiles)
            state.best_report = rep

    def dfs(i: int, tiles: dict[str, int]) -> None:
        state.nodes += 1
        if i == len(names):
            leaf(tiles)
            return
        name = names[i]
        cands = cons[name].candidates
        for c in cands:
            tiles[name] = c
            # --- feasibility prune: remaining dims at their MIN candidate.
            probe = dict(tiles)
            for j in range(i + 1, len(names)):
                probe[names[j]] = cons[names[j]].candidates[0]
            if vmem_usage(group, probe, cons, double_buffer=double_buffer) > vmem_budget:
                # candidates ascend; larger c only makes it worse.
                del tiles[name]
                break
            # --- optimality prune: remaining dims at FULL size (optimistic).
            if state.best_key is not None:
                opt = dict(tiles)
                for j in range(i + 1, len(names)):
                    opt[names[j]] = cons[names[j]].size
                rep = evaluate(group, opt, cons, double_buffer=double_buffer)
                # (t, 0, 0) >= best_key can only hold via t > best traffic
                # (dma >= 1 always), so the compound test reduces to this:
                if rep.traffic_bytes > state.best_key[0]:
                    continue
            dfs(i + 1, tiles)
        tiles.pop(name, None)

    dfs(0, {})
    if state.best_tiles is None:
        raise InfeasibleError(
            f"group {group.name}: no tile assignment fits {vmem_budget} B VMEM "
            f"(lower bound traffic {min_traffic_bound(group, cons)} B)"
        )
    return TilePlan(
        group=group,
        tiles=state.best_tiles,
        constraints=cons,
        report=state.best_report,
        vmem_budget=vmem_budget,
        nodes_explored=state.nodes,
    )
