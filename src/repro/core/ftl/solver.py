"""FTL tile-size solver (paper step 4).

Exact branch-and-bound over the aligned-divisor lattice of every dim
variable in a (possibly fused) group, minimizing the *modeled roofline
runtime* of the cost model on the planning :class:`~repro.core.hw.Target`
— ``max(compute_time, transfer_time)`` with (traffic, DMA count, grid
steps) as tie-breaks — subject to the fast level's capacity constraint
at its pipeline ``buffer_depth``.

Pruning relies on two monotonicities:
  * fast-memory footprint grows with tile sizes -> feasibility prune from
    below,
  * per-tensor traffic, DMA count AND compute time shrink (or stay) with
    tile sizes — the per-tensor level weights are tile-independent and
    the compute term depends on tiles only through the lane-utilization
    factor, which is monotone non-decreasing in the lane tile
    (``cost.lane_utilization``) — so the full cost key with the
    remaining dims at full size is a component-wise (hence
    lexicographic) lower bound over the subtree.  Bounding the whole key (not just the time term) keeps the
    prune biting in the compute-bound regime, where every assignment ties
    on runtime and the search would otherwise degenerate to exhaustive.

Groups have <= ~8 dims with <= 14 candidates each; with the two prunes the
search visits a few thousand nodes in practice (tested up to production
GEMM shapes, see tests/test_ftl_solver.py).
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

from repro.core import hw as hwlib

from .constraints import build_dim_constraints
from .cost import CostReport, evaluate, min_traffic_bound, vmem_usage
from .ir import FusionGroup
from .plan import TilePlan


class InfeasibleError(RuntimeError):
    """No tile assignment fits the target's fast memory."""


@dataclasses.dataclass
class _SearchState:
    best_key: tuple | None = None
    best_tiles: dict | None = None
    best_report: CostReport | None = None
    nodes: int = 0


def solve(
    group: FusionGroup,
    *,
    target: hwlib.Target | None = None,
    sharded_sizes: Mapping[str, int] | None = None,
    whole_dims: frozenset[str] = frozenset(),
) -> TilePlan:
    """Plan tiling for ``group`` on ``target`` (None → the default target);
    returns the optimal :class:`TilePlan`."""
    target = target if target is not None else hwlib.default_target()
    budget = target.fast_capacity
    depth = target.fast.buffer_depth
    group.validate()
    cons = build_dim_constraints(
        group, sharded_sizes=sharded_sizes, whole_dims=whole_dims
    )
    names = sorted(
        cons,
        # Put large dims first: their candidate choice constrains the fast
        # footprint most, so pruning bites early.
        key=lambda n: -cons[n].size,
    )
    state = _SearchState()

    def leaf(tiles: dict[str, int]) -> None:
        rep = evaluate(group, tiles, cons, target=target)
        if rep.vmem_bytes > budget:
            return
        key = (rep.modeled_runtime_s, rep.traffic_bytes, rep.dma_transfers,
               rep.n_steps)
        if state.best_key is None or key < state.best_key:
            state.best_key = key
            state.best_tiles = dict(tiles)
            state.best_report = rep

    def dfs(i: int, tiles: dict[str, int]) -> None:
        state.nodes += 1
        if i == len(names):
            leaf(tiles)
            return
        name = names[i]
        cands = cons[name].candidates
        for c in cands:
            tiles[name] = c
            # --- feasibility prune: remaining dims at their MIN candidate.
            probe = dict(tiles)
            for j in range(i + 1, len(names)):
                probe[names[j]] = cons[names[j]].candidates[0]
            if vmem_usage(group, probe, cons, buffer_depth=depth) > budget:
                # candidates ascend; larger c only makes it worse.
                del tiles[name]
                break
            # --- optimality prune: remaining dims at FULL size (optimistic).
            if state.best_key is not None:
                opt = dict(tiles)
                for j in range(i + 1, len(names)):
                    opt[names[j]] = cons[names[j]].size
                rep = evaluate(group, opt, cons, target=target)
                # runtime, traffic and DMA count all shrink (or stay) as
                # tiles grow and steps >= 1, so the optimistic full-size
                # key bounds every leaf's key from below component-wise —
                # hence lexicographically.  A subtree whose bound cannot
                # strictly beat the incumbent is dead (ties keep the
                # earlier incumbent anyway).
                opt_key = (rep.modeled_runtime_s, rep.traffic_bytes,
                           rep.dma_transfers, 1)
                if opt_key >= state.best_key:
                    continue
            dfs(i + 1, tiles)
        tiles.pop(name, None)

    dfs(0, {})
    if state.best_tiles is None:
        raise InfeasibleError(
            f"group {group.name}: no tile assignment fits the {budget} B "
            f"{target.fast.name} of target {target.name} "
            f"(lower bound traffic {min_traffic_bound(group, cons)} B)"
        )
    return TilePlan(
        group=group,
        tiles=state.best_tiles,
        constraints=cons,
        report=state.best_report,
        target=target,
        nodes_explored=state.nodes,
    )
