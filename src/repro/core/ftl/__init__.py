"""Fused-Tiled Layers (FTL) — the paper's contribution as a JAX library.

Pipeline (paper Fig. 1):
  step 1  ir.py          dim variables per tensor dimension
  step 2  constraints.py geometric / kernel-policy / performance constraints
  step 3  fusion.py      select consecutive layers, bind shared dims
  step 4  solver.py      solve the joint constraint-optimization problem

Artifacts: plan.TilePlan (tiles + grid + cost report) consumed by
  * src/repro/kernels/*  — Pallas TPU kernels (BlockSpecs from the plan)
  * executor_xla.py      — portable lax.scan tiling executor
"""
from . import auto, constraints, cost, executor_xla, fusion, ir, plan, solver
from .auto import MLPPlanOutcome, plan_attention, plan_mlp
from .constraints import build_dim_constraints
from .cost import CostReport, evaluate
from .fusion import attention, gemm_act, gemm_chain, mlp
from .ir import Dim, FusionGroup, KernelPolicy, OpNode, Role, TensorSpec
from .plan import FusionComparison, TilePlan, compare
from .solver import DEFAULT_VMEM_BUDGET, InfeasibleError, solve

__all__ = [
    "Dim", "FusionGroup", "KernelPolicy", "OpNode", "Role", "TensorSpec",
    "CostReport", "TilePlan", "FusionComparison",
    "attention", "gemm_act", "gemm_chain", "mlp",
    "build_dim_constraints", "evaluate", "solve", "compare",
    "DEFAULT_VMEM_BUDGET", "InfeasibleError",
    "MLPPlanOutcome", "plan_attention", "plan_mlp",
    "auto", "constraints", "cost", "executor_xla", "fusion", "ir", "plan",
    "solver",
]
