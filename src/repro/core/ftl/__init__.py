"""Fused-Tiled Layers (FTL) — the paper's contribution as a JAX library.

Pipeline (paper Fig. 1, extended to whole-model planning):
  step 0  core/hw.py      the machine: a Target (ordered fast→backing
                          MemoryLevels + peak FLOPs) every planner prices
                          against — presets tpu_v5e / cpu_cache /
                          rv32_l1_l2
  step 1  ir.py           dim variables per tensor dimension
  step 2  constraints.py  geometric / kernel-policy / performance constraints
  step 3  graph.py        capture a whole block (or any layer chain) as an
                          op chain — fusion.py keeps the hand-built chains
  step 4  partition.py    fusion-partition optimizer: enumerate contiguous
                          cuts, price each segment with the solver, DP over
                          cut points for the transfer-time-minimal schedule
  step 5  solver.py       branch-and-bound tile solver per fusion group
  step 6  registry.py     executor registry: planned groups → Pallas
                          kernels when shapes qualify, XLA scan fallback

Artifacts: plan.TilePlan (tiles + grid + cost report) per fusion group and
partition.ChainPlan / registry.BlockPlan per chain, consumed by
  * src/repro/kernels/*  — Pallas TPU kernels (BlockSpecs from the plan)
  * executor_xla.py      — portable lax.scan tiling executors
  * registry.plan_block  — the one entry point models/launch/benchmarks use

``plan_mlp`` / ``plan_attention`` / ``MLPPlanOutcome`` are deprecation
shims for the retired ``auto`` module (PR 1 noted ``partition.py``
subsumes its 3-way MLP choice) — new code should use
``partition.plan_chain`` / ``partition.plan_fixed`` directly.
"""
from __future__ import annotations

import dataclasses as _dataclasses
import functools as _functools
import warnings as _warnings
from typing import Mapping as _Mapping

from repro.core.hw import MemoryLevel, Target, default_target, get_target

from . import (constraints, cost, executor_block, executor_xla,
               fusion, graph, ir, partition, plan, registry, solver)
from .constraints import build_dim_constraints
from .cost import CostReport, evaluate
from .fusion import attention, gemm_act, gemm_chain, mlp
from .graph import OpGraph, attention_graph, block_graph, gemm_act_graph, \
    gemm_chain_graph, mlp_graph
from .ir import Dim, FusionGroup, KernelPolicy, OpNode, Role, TensorSpec
from .partition import ChainPlan, Segment, all_cuts, plan_chain, plan_fixed
from .plan import FusionComparison, TilePlan, compare
from .registry import BlockPlan, ExecContext, Executor, \
    clear_plan_caches, mlp_executor, plan_block, plan_cache_stats, \
    register_plan_cache, run_block
from .solver import InfeasibleError, solve

__all__ = [
    "Dim", "FusionGroup", "KernelPolicy", "OpNode", "Role", "TensorSpec",
    "CostReport", "TilePlan", "FusionComparison",
    "MemoryLevel", "Target", "default_target", "get_target",
    "attention", "gemm_act", "gemm_chain", "mlp",
    "OpGraph", "attention_graph", "block_graph", "gemm_act_graph",
    "gemm_chain_graph", "mlp_graph",
    "ChainPlan", "Segment", "all_cuts", "plan_chain", "plan_fixed",
    "BlockPlan", "ExecContext", "Executor", "mlp_executor", "plan_block",
    "run_block",
    "plan_cache_stats", "clear_plan_caches", "register_plan_cache",
    "build_dim_constraints", "evaluate", "solve", "compare",
    "InfeasibleError",
    "MLPPlanOutcome", "plan_attention", "plan_mlp",
    "constraints", "cost", "executor_block", "executor_xla",
    "fusion", "graph", "ir", "partition", "plan", "registry", "solver",
]


# ---------------------------------------------------------------------------
# deprecation shims for the retired core/ftl/auto.py (kept one release)
# ---------------------------------------------------------------------------

@_dataclasses.dataclass(frozen=True)
class MLPPlanOutcome:
    """Deprecated: the retired auto-planner's result record.

    ``partition.plan_chain`` is the decision authority; this shim prices
    the three canonical MLP schedules via ``partition.plan_fixed`` for
    callers that still report them side by side.
    """

    fused: TilePlan | None
    unfused: tuple[TilePlan, ...]
    comparison: FusionComparison | None
    use_fused: bool
    partial: tuple[TilePlan, ...] = ()
    schedule: str = ""               # 'fused' | 'partial' | 'unfused'
    chain: ChainPlan | None = None   # the partitioner's chosen schedule

    @property
    def chosen_traffic(self) -> int:
        if self.chain is not None:
            return self.chain.traffic_bytes
        if self.schedule == "fused" or (not self.schedule and self.use_fused):
            return self.fused.traffic_bytes
        if self.schedule == "partial":
            return sum(p.traffic_bytes for p in self.partial)
        return sum(p.traffic_bytes for p in self.unfused)


def _deprecated(name: str) -> None:
    _warnings.warn(
        f"repro.core.ftl.{name} is deprecated (auto.py retired); use "
        f"partition.plan_chain / partition.plan_fixed with a hw.Target",
        DeprecationWarning, stacklevel=3)


def _freeze(d: _Mapping[str, int] | None):
    return tuple(sorted(d.items())) if d else None


@_functools.lru_cache(maxsize=512)
def _plan_mlp_cached(
    m: int, d_model: int, d_ff: int, dtype: str, gated: bool, act: str,
    target: Target, sharded: tuple | None,
) -> MLPPlanOutcome:
    sharded_sizes = dict(sharded) if sharded else None
    g = graph.mlp_graph(m=m, d_model=d_model, d_ff=d_ff, dtype=dtype,
                        gated=gated, act=act)
    kw = dict(target=target, sharded_sizes=sharded_sizes)
    # the partitioner's decision over every contiguous cut of the chain
    chain = partition.plan_chain(g, **kw)
    # canonical three schedules, still priced for comparison/reporting
    unfused = tuple(
        s.plan for s in partition.plan_fixed(g, partition.all_cuts(g),
                                             **kw).segments
    )
    try:
        partial = tuple(
            s.plan
            for s in partition.plan_fixed(g, (g.n_ops - 1,), **kw).segments
        )
    except InfeasibleError:
        partial = ()
    try:
        fused = partition.plan_fixed(g, (), **kw).segments[0].plan
    except InfeasibleError:
        fused = None
    cmp = compare(fused, unfused) if fused is not None else None
    return MLPPlanOutcome(fused, unfused, cmp,
                          use_fused=chain.schedule == "fused",
                          partial=partial, schedule=chain.schedule,
                          chain=chain)


def plan_mlp(
    *,
    m: int,
    d_model: int,
    d_ff: int,
    dtype: str = "bfloat16",
    gated: bool = False,
    act: str = "gelu",
    target: Target | None = None,
    sharded_sizes: _Mapping[str, int] | None = None,
) -> MLPPlanOutcome:
    """Deprecated shim: plan an MLP, pricing the canonical schedules."""
    _deprecated("plan_mlp")
    target = target if target is not None else default_target()
    return _plan_mlp_cached(m, d_model, d_ff, dtype, gated, act, target,
                            _freeze(sharded_sizes))


@_functools.lru_cache(maxsize=512)
def _plan_attention_cached(q_len: int, kv_len: int, head_dim: int,
                           dtype: str, target: Target) -> TilePlan:
    g = graph.attention_graph(q_len=q_len, kv_len=kv_len, head_dim=head_dim,
                              dtype=dtype)
    return partition.plan_fixed(g, (), target=target).segments[0].plan


def plan_attention(
    *,
    q_len: int,
    kv_len: int,
    head_dim: int,
    dtype: str = "bfloat16",
    target: Target | None = None,
) -> TilePlan:
    """Deprecated shim: the fused attention plan for one head."""
    _deprecated("plan_attention")
    target = target if target is not None else default_target()
    return _plan_attention_cached(q_len, kv_len, head_dim, dtype, target)


registry.register_plan_cache("ftl._plan_mlp_cached", _plan_mlp_cached)
registry.register_plan_cache("ftl._plan_attention_cached",
                             _plan_attention_cached)
