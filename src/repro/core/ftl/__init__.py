"""Fused-Tiled Layers (FTL) — the paper's contribution as a JAX library.

Pipeline (paper Fig. 1, extended to whole-model planning):
  step 1  ir.py          dim variables per tensor dimension
  step 2  constraints.py geometric / kernel-policy / performance constraints
  step 3  graph.py       capture a whole block (or any layer chain) as an
                         op chain — fusion.py keeps the hand-built chains
  step 4  partition.py   fusion-partition optimizer: enumerate contiguous
                         cuts, price each segment with the solver, DP over
                         cut points for the traffic-minimal schedule
  step 5  solver.py      branch-and-bound tile solver per fusion group
  step 6  registry.py    executor registry: planned groups → Pallas
                         kernels when shapes qualify, XLA scan fallback

Artifacts: plan.TilePlan (tiles + grid + cost report) per fusion group and
partition.ChainPlan / registry.BlockPlan per chain, consumed by
  * src/repro/kernels/*  — Pallas TPU kernels (BlockSpecs from the plan)
  * executor_xla.py      — portable lax.scan tiling executors
  * registry.plan_block  — the one entry point models/launch/benchmarks use

auto.plan_mlp / auto.plan_attention remain as thin cached wrappers over
the graph → partition path.
"""
from . import (auto, constraints, cost, executor_block, executor_xla,
               fusion, graph, ir, partition, plan, registry, solver)
from .auto import MLPPlanOutcome, plan_attention, plan_mlp
from .constraints import build_dim_constraints
from .cost import CostReport, evaluate
from .fusion import attention, gemm_act, gemm_chain, mlp
from .graph import OpGraph, attention_graph, block_graph, gemm_act_graph, \
    gemm_chain_graph, mlp_graph
from .ir import Dim, FusionGroup, KernelPolicy, OpNode, Role, TensorSpec
from .partition import ChainPlan, Segment, all_cuts, plan_chain, plan_fixed
from .plan import FusionComparison, TilePlan, compare
from .registry import BlockPlan, ExecContext, Executor, mlp_executor, \
    plan_block, run_block
from .solver import DEFAULT_VMEM_BUDGET, InfeasibleError, solve

__all__ = [
    "Dim", "FusionGroup", "KernelPolicy", "OpNode", "Role", "TensorSpec",
    "CostReport", "TilePlan", "FusionComparison",
    "attention", "gemm_act", "gemm_chain", "mlp",
    "OpGraph", "attention_graph", "block_graph", "gemm_act_graph",
    "gemm_chain_graph", "mlp_graph",
    "ChainPlan", "Segment", "all_cuts", "plan_chain", "plan_fixed",
    "BlockPlan", "ExecContext", "Executor", "mlp_executor", "plan_block",
    "run_block",
    "build_dim_constraints", "evaluate", "solve", "compare",
    "DEFAULT_VMEM_BUDGET", "InfeasibleError",
    "MLPPlanOutcome", "plan_attention", "plan_mlp",
    "auto", "constraints", "cost", "executor_block", "executor_xla",
    "fusion", "graph", "ir", "partition", "plan", "registry", "solver",
]
