"""Core: the paper's primary contribution (Fused-Tiled Layers) and the
memory-hierarchy targets every planner prices against."""
from . import hw  # noqa: F401  (import order: hw has no ftl dependency)
from . import ftl

__all__ = ["ftl", "hw"]
