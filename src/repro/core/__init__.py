"""Core: the paper's primary contribution (Fused-Tiled Layers)."""
from . import ftl

__all__ = ["ftl"]
