"""Core: the paper's primary contribution (Fused-Tiled Layers) and the
memory-hierarchy targets every planner prices against.

``ftl`` is re-exported lazily (PEP 562): it transitively imports jax,
and jax-free consumers — ``repro.obs``, ``repro.calib``'s record types,
offline tooling — must be able to reach ``repro.core.hw`` without
paying (or requiring) the jax import.
"""
from . import hw  # noqa: F401  (import order: hw has no ftl dependency)

__all__ = ["ftl", "hw"]


def __getattr__(name):
    if name == "ftl":
        import importlib

        return importlib.import_module(".ftl", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
