"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (the dry-run sets the 512-device XLA flag before
any jax initialization; tests/benches see 1 device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single pod (256 chips) or 2×16×16 dual-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (tests, elastic restarts, PP experiments)."""
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_data: int | None = None, n_model: int = 1):
    """Small mesh over however many (host) devices exist — test helper."""
    n = jax.device_count()
    if n_data is None:
        n_data = n // n_model
    return jax.make_mesh((n_data, n_model), ("data", "model"))
