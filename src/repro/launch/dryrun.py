"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture × input-shape) cell, on the single-pod 16×16 mesh
and the dual-pod 2×16×16 mesh:

  1. build the step function (train / prefill / decode per the cell kind),
  2. ``jax.jit(step, in_shardings, out_shardings).lower(**ShapeDtypeStructs)``,
  3. ``.compile()`` — sharding mismatches, compile-time OOM or unsupported
     collectives fail HERE, which is the point,
  4. record ``memory_analysis()`` (fits per chip?), ``cost_analysis()``
     (FLOPs/bytes), and the collective schedule parsed from the HLO —
     the roofline inputs (EXPERIMENTS.md §Dry-run / §Roofline).

Artifacts: results/dryrun/<arch>__<shape>__<mesh>.json

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]
"""
# The container has ONE real CPU device; the dry-run needs 512 placeholder
# devices.  Must run before ANY other import that touches jax.
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import configs                              # noqa: E402
from repro.configs import SHAPES, get_config, get_shape  # noqa: E402
from repro.data.pipeline import make_batch_shapes      # noqa: E402
from repro.distributed.sharding import (               # noqa: E402
    batch_pspecs, dp_axes, param_pspecs, to_shardings)
from repro.launch.mesh import make_production_mesh     # noqa: E402
from repro.core import hw as hw_targets                # noqa: E402
from repro.models import model as M                    # noqa: E402
from repro.optim import OptConfig                      # noqa: E402
from repro.roofline import model_flops, roofline  # noqa: E402
from repro.roofline.analysis import HW                 # noqa: E402
from repro.roofline.hlo_cost import analyze as hlo_analyze  # noqa: E402
from repro.roofline.hlo_cost import xla_cost_analysis  # noqa: E402
from repro.train import steps as S                     # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "..", "..", "..", "results", "dryrun")


# ---------------------------------------------------------------------------
# cell enumeration (skip rules from DESIGN.md §7)
# ---------------------------------------------------------------------------

def cell_status(arch: str, shape_name: str) -> str:
    """'run' or the documented skip reason."""
    cfg = get_config(arch)
    if shape_name == "long_500k" and not cfg.sub_quadratic():
        return "skip: full quadratic attention at 512k (task rule)"
    return "run"


def all_cells() -> list[tuple[str, str, str]]:
    out = []
    for arch in configs.ARCHS:
        for shape_name in SHAPES:
            out.append((arch, shape_name, cell_status(arch, shape_name)))
    return out


# ---------------------------------------------------------------------------
# lowering one cell
# ---------------------------------------------------------------------------

def _accum_for(cfg, shape, mesh) -> int:
    """Grad-accum depth: 1 token-microbatch per data shard per step."""
    dp = 1
    for a in dp_axes(mesh):
        dp *= mesh.shape[a]
    per_shard = max(1, shape.global_batch // dp)
    # large models: microbatch 1; small (<8B): microbatch 2
    micro = 1
    return max(1, per_shard // micro)


def apply_opt_level(cfg, opt: bool):
    """§Perf optimized configuration: blockwise (FTL-scheduled) attention
    on the XLA path, grouped MoE dispatch, chunked-remat mLSTM."""
    if not opt:
        return cfg
    import dataclasses

    from repro.kernels import ops
    # 8k threshold: at 4k the naive path measured BETTER (scan-carry
    # traffic + bwd recompute exceed the score-tile saving — §Perf log)
    ops.set_xla_attention("blockwise", min_len=8192)
    repl = {}
    if cfg.is_moe:
        repl.update(moe_dispatch="grouped", moe_groups=16)
    if cfg.family == "ssm":
        repl.update(mlstm_chunk=256)
    return dataclasses.replace(cfg, **repl) if repl else cfg


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               opt: bool = False):
    """Returns (record dict, lowered, compiled)."""
    cfg = apply_opt_level(get_config(arch), opt)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_shape = tuple(mesh.shape[a] for a in mesh.axis_names)
    t0 = time.time()

    batch_sds = make_batch_shapes(cfg, shape)

    if shape.kind == "train":
        state_sds = S.train_state_shapes(cfg)
        accum = _accum_for(cfg, shape, mesh)
        step = S.make_train_step(cfg, mesh, OptConfig(), accum=accum)
        in_sh, out_sh = S.train_step_shardings(cfg, mesh, state_sds,
                                               batch_sds)
        with mesh:
            lowered = jax.jit(step, in_shardings=in_sh,
                              out_shardings=out_sh).lower(
                                  state_sds, batch_sds)
    elif shape.kind == "prefill":
        params_sds = M.param_shapes(cfg)
        step = S.make_prefill_step(cfg, mesh)
        pspec = param_pspecs(params_sds, mesh, cfg)
        bspec = batch_pspecs(batch_sds, mesh)
        in_sh = (S.to_shardings_tree(pspec, mesh), to_shardings(bspec, mesh))
        with mesh:
            lowered = jax.jit(step, in_shardings=in_sh).lower(
                params_sds, batch_sds)
    else:  # decode
        params_sds = M.param_shapes(cfg)
        cache_sds = jax.eval_shape(
            lambda: M.init_cache(cfg, shape.global_batch, shape.seq_len))
        step = S.make_decode_step(cfg, mesh)
        in_sh = S.decode_shardings(cfg, mesh, params_sds, cache_sds,
                                   shape.global_batch)
        token = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        with mesh:
            lowered = jax.jit(step, in_shardings=in_sh).lower(
                params_sds, cache_sds, token, pos)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    xla_cost = xla_cost_analysis(compiled)
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    # trip-count-aware cost over the compiled HLO (roofline/hlo_cost.py);
    # XLA's own cost_analysis counts while bodies once — kept for reference.
    hc = hlo_analyze(hlo)
    cost = {"flops": hc["flops"], "bytes accessed": hc["bytes"]}
    # the roofline machine is the same Target the FTL planner priced its
    # plans against (hw.default_target / FTL_TARGET), recorded per cell
    target = hw_targets.default_target()
    rep = roofline(arch=arch, shape=shape, mesh_shape=mesh_shape,
                   cost=cost, hlo_text=None,
                   coll_bytes=int(hc["collective_bytes"]),
                   model_flops_total=model_flops(cfg, shape),
                   hw=HW.from_target(target))

    mem_rec = {}
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            mem_rec[attr] = int(v)

    record = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(map(str, mesh_shape)), "chips": rep.chips,
        "kind": shape.kind,
        "ftl_target": target.name,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "cost": {"flops_per_chip": hc["flops"],
                 "bytes_per_chip": hc["bytes"],
                 "transcendentals": hc["transcendentals"],
                 "xla_flops_raw": xla_cost.get("flops", 0.0),
                 "xla_bytes_raw": xla_cost.get("bytes accessed", 0.0)},
        "memory": mem_rec,
        "collectives": {"total_bytes": int(hc["collective_bytes"]),
                        "count": hc["collective_count"],
                        "by_kind": {k: int(v) for k, v in
                                    hc["collectives_by_kind"].items()}},
        "roofline": rep.row(),
    }
    return record, lowered, compiled


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             out_dir: str, skip_existing: bool = False,
             opt: bool = False) -> dict:
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    os.makedirs(out_dir, exist_ok=True)
    fn = os.path.join(out_dir,
                      f"{arch}__{shape_name}__{mesh_tag}.json")
    if skip_existing and os.path.exists(fn):
        with open(fn) as f:
            return json.load(f)
    status = cell_status(arch, shape_name)
    if status != "run":
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
               "status": status}
    else:
        try:
            rec, _, _ = lower_cell(arch, shape_name, multi_pod=multi_pod,
                                   opt=opt)
            rec["status"] = "ok"
        except Exception as e:            # noqa: BLE001 — recorded, not hidden
            rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
                   "status": f"FAIL: {type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
    with open(fn, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--opt", action="store_true",
                    help="§Perf optimized config (blockwise attention, "
                         "grouped MoE, chunked mLSTM)")
    ap.add_argument("--out", default=os.path.abspath(RESULTS_DIR))
    args = ap.parse_args()
    if args.opt and args.out == os.path.abspath(RESULTS_DIR):
        args.out = args.out + "_opt"

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    fails = 0
    if args.all:
        for arch, shape_name, status in all_cells():
            for mp in meshes:
                rec = run_cell(arch, shape_name, multi_pod=mp,
                               out_dir=args.out,
                               skip_existing=args.skip_existing,
                               opt=args.opt)
                line = rec.get("status", "?")
                print(f"[{rec['mesh']:8s}] {arch:24s} {shape_name:12s} "
                      f"{line[:100]}", flush=True)
                fails += line.startswith("FAIL")
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        for mp in meshes:
            rec = run_cell(args.arch, args.shape, multi_pod=mp,
                           out_dir=args.out,
                           skip_existing=args.skip_existing,
                           opt=args.opt)
            print(json.dumps(rec, indent=1))
            fails += rec.get("status", "").startswith("FAIL")
    return 1 if fails else 0


if __name__ == "__main__":
    raise SystemExit(main())
