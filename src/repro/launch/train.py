"""Training driver.

Single-host it runs real steps on whatever devices exist (CPU smoke:
``--arch yi-6b --reduced``); on a pod slice the same code path pjits over
the production mesh.  Fault tolerance wiring: auto-resume from the latest
checkpoint, async saves every N steps, SIGTERM-preemption checkpointing,
straggler flagging — all via runtime.TrainLoop.

Examples
--------
CPU end-to-end (reduced config, synthetic bigram data)::

  python -m repro.launch.train --arch yi-6b --reduced --steps 100 \\
      --batch 8 --seq 128 --ckpt-dir /tmp/ck

Production (pod slice)::

  python -m repro.launch.train --arch qwen2-72b --steps 10000 \\
      --batch 256 --seq 4096 --mesh 16x16 --ckpt-dir gs://...
"""
from __future__ import annotations

import argparse
import dataclasses
import logging

import jax
import jax.numpy as jnp

from repro import obs as obslib
from repro.configs import get_config
from repro.core.ftl import InfeasibleError, executor_block
from repro.core.ftl import registry as ftl_registry
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.mesh import make_mesh
from repro.optim import OptConfig
from repro.runtime import LoopConfig, TrainLoop
from repro.runtime.monitor import HeartbeatMonitor
from repro.train import steps as S


def build(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.ftl_mode:
        cfg = dataclasses.replace(cfg, ftl_mode=args.ftl_mode)

    # graph-level FTL plan of one block at the training token count.
    # This is not just a report: model.forward resolves the same cached
    # plan (per cfg/m/dtype) and executes every block through
    # registry.run_block, so the schedule logged here is the schedule the
    # train step actually runs.
    bp = None
    try:
        bp = ftl_registry.plan_block(cfg, m=args.seq)
        execs = executor_block.resolved_executors(bp, m=args.seq)
        state = ("executed by every forward block"
                 if cfg.ftl_mode != "off" else
                 "report only — ftl_mode='off' runs the baseline; pass "
                 "--ftl-mode auto to execute it")
        logging.info("FTL block plan (m=%d, target=%s, %s):\n%s\n"
                     "  runtime executors: %s",
                     args.seq, bp.target.name, state, bp.summary(), execs)
    except (ValueError, InfeasibleError) as e:
        logging.info("FTL block plan unavailable (layer-per-layer path): "
                     "%s", e)

    mesh = None
    in_sh = out_sh = None
    state = S.init_train_state(cfg, jax.random.PRNGKey(args.seed))
    opt = OptConfig(peak_lr=args.lr, warmup_steps=args.warmup,
                    decay_steps=args.steps)
    step = S.make_train_step(cfg, mesh, opt, accum=args.accum,
                             compress=args.compress)
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("pod", "data", "model")[-len(shape):]
        mesh = make_mesh(shape, axes)
        state_sds = jax.eval_shape(lambda: state)
        batch_sds = {"tokens": jax.ShapeDtypeStruct(
            (args.batch, args.seq), jnp.int32)}
        step = S.make_train_step(cfg, mesh, opt, accum=args.accum,
                                 compress=args.compress)
        in_sh, out_sh = S.train_step_shardings(cfg, mesh, state_sds,
                                               batch_sds)
        sspec = in_sh[0]
        state = jax.device_put(state, sspec)
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
    else:
        jitted = jax.jit(step)

    data = SyntheticLM(DataConfig(
        vocab_size=cfg.vocab_size, global_batch=args.batch,
        seq_len=args.seq, seed=args.seed, kind=args.data))

    # liveness: stamp a heartbeat at the top of every step (make_batch is
    # the first per-step call) so peers on a shared filesystem can spot a
    # hung process even when on_metrics only fires every log_every steps
    hb = (HeartbeatMonitor(args.heartbeat_dir, jax.process_index())
          if getattr(args, "heartbeat_dir", None) else None)

    def make_batch(i: int):
        if hb is not None:
            hb.stamp()
        return {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}

    loop = TrainLoop(
        LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                   ckpt_every=args.ckpt_every, log_every=args.log_every),
        jitted, make_batch, state,
        state_shardings=in_sh[0] if in_sh else None,
        on_metrics=lambda s, m: print(
            f"step {s:6d} loss {m.get('loss', float('nan')):.4f} "
            f"gnorm {m.get('grad_norm', 0):.3f} lr {m.get('lr', 0):.2e}",
            flush=True),
    )
    loop.block_plan = bp          # surfaced for tooling/tests
    loop.heartbeat = hb
    return loop


def main() -> None:
    logging.basicConfig(level=logging.INFO)
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--data", default="bigram", choices=["bigram", "random"])
    ap.add_argument("--mesh", default=None, help="e.g. 16x16")
    ap.add_argument("--ftl-mode", default=None,
                    choices=["off", "fused", "scan", "auto"])
    ap.add_argument("--compress", action="store_true",
                    help="int8 error-feedback gradient compression")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--obs", action="store_true",
                    help="runtime telemetry: train_step spans + straggler/"
                         "heartbeat metrics on the repro.obs registry")
    ap.add_argument("--obs-trace", default=None,
                    help="merged live+modeled Chrome-tracing JSON "
                         "(implies --obs)")
    ap.add_argument("--obs-metrics", default=None,
                    help="Prometheus text exposition written post-run "
                         "(implies --obs)")
    ap.add_argument("--heartbeat-dir", default=None,
                    help="shared dir for per-process heartbeat stamps")
    args = ap.parse_args()
    if args.obs_trace or args.obs_metrics:
        args.obs = True
    if args.obs:
        obslib.enable()

    loop = build(args)
    loop.run()
    if loop.metrics_log:
        last = loop.metrics_log[-1]
        print(f"final: step {last['step']} loss {last.get('loss'):.4f}")

    # straggler summary: TrainLoop's monitor flagged these live (and the
    # obs registry carries the counters); echo them so a smoke run shows
    # the wiring without scraping
    flagged = loop.monitor.flagged_steps
    if flagged:
        worst = max(flagged, key=lambda s: s.seconds)
        print(f"stragglers: {len(flagged)} flagged step(s), worst "
              f"step {worst.step} at {worst.seconds:.3f}s "
              f"(ema {loop.monitor.ema:.3f}s)")
    elif args.obs:
        print(f"stragglers: none flagged over {len(loop.monitor.history)} "
              f"steps (ema {loop.monitor.ema:.3f}s)"
              if loop.monitor.ema is not None else "stragglers: no steps ran")

    if args.obs_trace:
        obslib.write_merged_trace(args.obs_trace, chain=loop.block_plan)
        print(f"wrote merged trace to {args.obs_trace}")
    if args.obs_metrics:
        obslib.write_prometheus(args.obs_metrics)
        print(f"wrote metrics to {args.obs_metrics}")


if __name__ == "__main__":
    main()
