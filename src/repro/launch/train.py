"""Training driver.

Single-host it runs real steps on whatever devices exist (CPU smoke:
``--arch yi-6b --reduced``); on a pod slice the same code path pjits over
the production mesh.  Fault tolerance wiring: auto-resume from the latest
checkpoint, async saves every N steps, SIGTERM-preemption checkpointing,
straggler flagging — all via runtime.TrainLoop.

Examples
--------
CPU end-to-end (reduced config, synthetic bigram data)::

  python -m repro.launch.train --arch yi-6b --reduced --steps 100 \\
      --batch 8 --seq 128 --ckpt-dir /tmp/ck

Production (pod slice)::

  python -m repro.launch.train --arch qwen2-72b --steps 10000 \\
      --batch 256 --seq 4096 --mesh 16x16 --ckpt-dir gs://...
"""
from __future__ import annotations

import argparse
import dataclasses
import logging

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.ftl import InfeasibleError, executor_block
from repro.core.ftl import registry as ftl_registry
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.mesh import make_mesh
from repro.optim import OptConfig
from repro.runtime import LoopConfig, TrainLoop
from repro.train import steps as S


def build(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.ftl_mode:
        cfg = dataclasses.replace(cfg, ftl_mode=args.ftl_mode)

    # graph-level FTL plan of one block at the training token count.
    # This is not just a report: model.forward resolves the same cached
    # plan (per cfg/m/dtype) and executes every block through
    # registry.run_block, so the schedule logged here is the schedule the
    # train step actually runs.
    bp = None
    try:
        bp = ftl_registry.plan_block(cfg, m=args.seq)
        execs = executor_block.resolved_executors(bp, m=args.seq)
        state = ("executed by every forward block"
                 if cfg.ftl_mode != "off" else
                 "report only — ftl_mode='off' runs the baseline; pass "
                 "--ftl-mode auto to execute it")
        logging.info("FTL block plan (m=%d, target=%s, %s):\n%s\n"
                     "  runtime executors: %s",
                     args.seq, bp.target.name, state, bp.summary(), execs)
    except (ValueError, InfeasibleError) as e:
        logging.info("FTL block plan unavailable (layer-per-layer path): "
                     "%s", e)

    mesh = None
    in_sh = out_sh = None
    state = S.init_train_state(cfg, jax.random.PRNGKey(args.seed))
    opt = OptConfig(peak_lr=args.lr, warmup_steps=args.warmup,
                    decay_steps=args.steps)
    step = S.make_train_step(cfg, mesh, opt, accum=args.accum,
                             compress=args.compress)
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("pod", "data", "model")[-len(shape):]
        mesh = make_mesh(shape, axes)
        state_sds = jax.eval_shape(lambda: state)
        batch_sds = {"tokens": jax.ShapeDtypeStruct(
            (args.batch, args.seq), jnp.int32)}
        step = S.make_train_step(cfg, mesh, opt, accum=args.accum,
                                 compress=args.compress)
        in_sh, out_sh = S.train_step_shardings(cfg, mesh, state_sds,
                                               batch_sds)
        sspec = in_sh[0]
        state = jax.device_put(state, sspec)
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
    else:
        jitted = jax.jit(step)

    data = SyntheticLM(DataConfig(
        vocab_size=cfg.vocab_size, global_batch=args.batch,
        seq_len=args.seq, seed=args.seed, kind=args.data))

    def make_batch(i: int):
        return {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}

    loop = TrainLoop(
        LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                   ckpt_every=args.ckpt_every, log_every=args.log_every),
        jitted, make_batch, state,
        state_shardings=in_sh[0] if in_sh else None,
        on_metrics=lambda s, m: print(
            f"step {s:6d} loss {m.get('loss', float('nan')):.4f} "
            f"gnorm {m.get('grad_norm', 0):.3f} lr {m.get('lr', 0):.2e}",
            flush=True),
    )
    loop.block_plan = bp          # surfaced for tooling/tests
    return loop


def main() -> None:
    logging.basicConfig(level=logging.INFO)
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--data", default="bigram", choices=["bigram", "random"])
    ap.add_argument("--mesh", default=None, help="e.g. 16x16")
    ap.add_argument("--ftl-mode", default=None,
                    choices=["off", "fused", "scan", "auto"])
    ap.add_argument("--compress", action="store_true",
                    help="int8 error-feedback gradient compression")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    loop = build(args)
    loop.run()
    if loop.metrics_log:
        last = loop.metrics_log[-1]
        print(f"final: step {last['step']} loss {last.get('loss'):.4f}")


if __name__ == "__main__":
    main()
