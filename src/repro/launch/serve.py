"""Serving driver: batched prefill + decode with continuous batching.

A minimal but real serving loop: requests (prompt token arrays) are
admitted into fixed batch slots; each engine step decodes one token for
every active slot; finished slots (EOS or max-len) are refilled from the
queue.  Prefill runs per-admission (prefix cache insertion), decode is the
steady-state batched step — the two steps the decode/prefill dry-run cells
lower at production shapes.

CPU demo::

  python -m repro.launch.serve --arch yi-6b --reduced --requests 8 \\
      --max-new 32
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import hw
from repro.core.ftl import InfeasibleError
from repro.core.ftl import registry as ftl_registry
from repro.models import model as M
from repro.train import steps as S


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new: int
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Fixed-slot continuous batching engine (single host)."""

    def __init__(self, cfg, params, *, batch_slots: int, max_seq: int,
                 eos_id: int = 1):
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_seq = max_seq
        self.eos = eos_id
        self.prefill = jax.jit(S.make_prefill_step(cfg, None))
        self.decode = jax.jit(S.make_decode_step(cfg, None))
        self.active: list[Request | None] = [None] * batch_slots
        self.cache = M.init_cache(cfg, batch_slots, max_seq)
        self.pos = np.zeros(batch_slots, np.int32)
        # Graph-level FTL plan for the steady-state prefill shape: the
        # whole block (projections + attention core + MLP) goes through
        # one partitioner and the executor registry binds each planned
        # fusion group.  Families without a plannable block (pure SSM)
        # serve without one.  The plan is priced for the process-default
        # memory-hierarchy target; stats record which one so a plan made
        # for the wrong machine is visible in serving logs.
        try:
            self.block_plan = ftl_registry.plan_block(cfg, m=max_seq)
        except (ValueError, InfeasibleError):
            self.block_plan = None
        self.stats = {
            "prefills": 0, "decode_steps": 0, "tokens": 0,
            "ftl_schedule": (self.block_plan.schedule
                             if self.block_plan else "n/a"),
            "ftl_target": (self.block_plan.target.name
                           if self.block_plan else hw.default_target().name),
            "block_exec": "n/a",
        }

    # ------------------------------------------------------------------
    def execute_block_plan(self):
        """Run the stored BlockPlan for real at the serving shape.

        Executes one transformer block of the engine's own parameters
        through ``registry.run_block`` on a (1, max_seq, d_model)
        activation — the steady-state prefill shape the plan was made
        for.  This is where every binding is requalified on the serving
        host (per-segment fallback), and it prices the plan in wall-clock
        terms instead of only reporting modeled traffic.  Records the
        resolved executors and timing in ``stats``; returns the stats
        entry (None when the model has no plan or no plannable layer).
        """
        if self.block_plan is None:
            return None
        p, kind = self._first_block_params()
        if p is None or ("attn" not in p and "mlp" not in p):
            return None
        from repro.core.ftl import executor_block
        cfg = self.cfg
        window = cfg.local_window if kind == "local" else None
        x = jax.random.normal(
            jax.random.PRNGKey(0), (1, self.max_seq, cfg.d_model)
        ).astype(cfg.dtype)
        positions = jnp.arange(self.max_seq)
        run = jax.jit(lambda xx: ftl_registry.run_block(
            self.block_plan, p, xx, positions=positions, window=window))
        run(x).block_until_ready()              # compile
        t0 = time.perf_counter()
        y = run(x)
        y.block_until_ready()
        dt = time.perf_counter() - t0
        entry = {
            "ms": round(1e3 * dt, 3),
            "executors": executor_block.resolved_executors(
                self.block_plan, m=self.max_seq, dtype=str(x.dtype)),
            "finite": bool(jnp.isfinite(y).all()),
        }
        self.stats["block_exec"] = entry
        return entry

    def _first_block_params(self):
        """(params, mixer kind) of the first plan-executable layer.

        Prefers a full attention(+MLP) layer; hybrid configs whose leading
        positions are recurrent fall back to any MLP-bearing one (the plan
        is MLP-only there and run_block executes just that stage).
        Returns (None, None) when no layer can execute the plan.
        """
        kinds, n_full, rem_kinds = M._layer_split(self.cfg)
        if n_full:
            pool = [(k, f"pos{i}") for i, k in enumerate(kinds)]

            def get(key):
                # slice only this position's subtree, not the whole stack
                return jax.tree.map(lambda a: a[0],
                                    self.params["layers"][key])
        elif rem_kinds:
            pool = [(k, f"rem{i}") for i, k in enumerate(rem_kinds)]

            def get(key):
                return self.params["rem"][key]
        else:
            return None, None
        for kind, key in pool:
            if kind in ("attn", "local"):
                return get(key), kind
        # no attention layer: any MLP-bearing layer can run the
        # (MLP-only) plan
        if bool(self.cfg.d_ff) and not self.cfg.is_moe:
            kind, key = pool[0]
            return get(key), kind
        return None, None

    # ------------------------------------------------------------------
    def _admit(self, req: Request, slot: int, extras: dict[str, Any]):
        """Prefill one request and splice its cache into the batch cache."""
        toks = jnp.asarray(req.prompt)[None]
        batch = {"tokens": toks, **extras}
        logits, cache1 = self.prefill(self.params, batch)

        def splice(path, full, one):
            """Insert request-batch-1 state into this slot of the batch
            cache, padding the request's seq dims up to the engine max.

            The batch axis is structural, not inferred from extents
            (slot-count 1 made every axis look like batch): stacked
            'layers' caches carry a leading layer dim → batch is axis 1;
            remainder/unstacked caches → axis 0."""
            names = [str(k.key) for k in path
                     if isinstance(k, jax.tree_util.DictKey)]
            ax = 1 if names and names[0] == "layers" else 0
            if one.shape[ax + 1:] != full.shape[ax + 1:]:
                pads = [(0, 0)] * one.ndim
                for d in range(ax + 1, one.ndim):
                    pads[d] = (0, full.shape[d] - one.shape[d])
                one = jnp.pad(one, pads)
            return _dus_axis(full, jnp.take(one, 0, axis=ax), slot, ax)

        self.cache = jax.tree_util.tree_map_with_path(
            splice, self.cache, cache1)
        self.active[slot] = req
        self.pos[slot] = len(req.prompt)
        req.out.append(int(jnp.argmax(logits[0, -1])))
        self.stats["prefills"] += 1

    # ------------------------------------------------------------------
    def step(self):
        """One batched decode step for all active slots."""
        tok = np.zeros((self.slots, 1), np.int32)
        for i, r in enumerate(self.active):
            if r is not None and not r.done:
                tok[i, 0] = r.out[-1]
        pos = int(max((self.pos[i] for i, r in enumerate(self.active)
                       if r is not None), default=0))
        logits, self.cache = self.decode(
            self.params, self.cache, jnp.asarray(tok), jnp.int32(pos))
        nxt = np.asarray(jnp.argmax(logits[:, 0], -1))
        for i, r in enumerate(self.active):
            if r is None or r.done:
                continue
            t = int(nxt[i])
            r.out.append(t)
            self.pos[i] += 1
            self.stats["tokens"] += 1
            if t == self.eos or len(r.out) >= r.max_new \
                    or self.pos[i] >= self.max_seq - 1:
                r.done = True
        self.stats["decode_steps"] += 1

    def run(self, requests: list[Request], extras: dict[str, Any]):
        queue = list(requests)
        done: list[Request] = []
        while queue or any(r is not None for r in self.active):
            for i in range(self.slots):
                r = self.active[i]
                if r is not None and r.done:
                    done.append(r)
                    self.active[i] = None
                if self.active[i] is None and queue:
                    self._admit(queue.pop(0), i, extras)
            if not any(r is not None and not r.done for r in self.active):
                continue
            self.step()
        return done


def _dus_axis(full, val, idx, ax):
    return jax.lax.dynamic_update_index_in_dim(full, val, idx, ax)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    extras: dict[str, Any] = {}
    if cfg.family == "vlm":
        extras["image_embeds"] = jnp.zeros(
            (1, cfg.n_image_tokens, cfg.d_model), cfg.dtype)
    if cfg.is_encoder_decoder:
        extras["frames"] = jnp.zeros(
            (1, cfg.encoder_seq, cfg.d_model), cfg.dtype)

    rng = np.random.default_rng(args.seed)
    reqs = [Request(i, rng.integers(2, cfg.vocab_size,
                                    size=args.prompt_len).astype(np.int32),
                    args.max_new)
            for i in range(args.requests)]
    eng = ServeEngine(cfg, params, batch_slots=args.slots,
                      max_seq=args.max_seq)
    if eng.block_plan is not None:
        print(f"FTL plan target: {eng.block_plan.target.describe()}")
        print(eng.block_plan.summary())
        exec_stats = eng.execute_block_plan()
        if exec_stats is not None:
            print(f"block plan executed @ m={args.max_seq}: "
                  f"{exec_stats['ms']} ms, executors "
                  f"{exec_stats['executors']}")
    t0 = time.time()
    done = eng.run(reqs, extras)
    dt = time.time() - t0
    print(f"served {len(done)} requests, {eng.stats['tokens']} tokens "
          f"in {dt:.1f}s ({eng.stats['tokens']/max(dt,1e-9):.1f} tok/s); "
          f"{eng.stats['decode_steps']} decode steps, "
          f"{eng.stats['prefills']} prefills")
    for r in done[:3]:
        print(f"  req {r.rid}: {len(r.out)} tokens: {r.out[:10]}...")


if __name__ == "__main__":
    main()
