"""Serving driver: continuous batching on planned schedules.

A real serving loop on top of the FTL planning stack:

* **Paged KV cache** — pure-'attn' decoder-only configs back their cache
  with fixed-size sequence blocks allocated per slot
  (:mod:`repro.launch.kv_cache`); pages are allocated on demand as a
  slot's position grows and freed on eviction, so admission control can
  queue requests under memory pressure.  Other families (local windows,
  recurrent state, cross caches, enc-dec) keep the dense per-slot cache.
* **Mixed sequence lengths** — each slot decodes at its *own* position
  (vector ``pos`` through ``model.decode_step``): admission prefills at
  the request's bucketed length, decode appends per slot, eviction on
  EOS/max-len refills the slot from the queue.
* **Plan cache** — serving plans are keyed ``(cfg, bucketed m, dtype,
  target, phase)``.  Prompts bucket through the
  :data:`repro.models.model.PREFILL_BUCKETS` ladder (ahead-of-time
  warmed), so steady state replans exactly zero times; the CI bench
  gates on that.
* **Split prefill/decode plans** — decode plans at ``m=1`` run through
  the same partition DP as prefill; memory-bound, they generally pick
  different cuts (pinned on ``rv32_npu``), and their bindings never
  qualify the Pallas kernels (decode-shape qualification).  Serve logs
  ``resolved_executors`` for *both* regimes, mirroring train.

CPU demo (open-loop arrivals + decode-plan timeline)::

  python -m repro.launch.serve --arch yi-6b --reduced --requests 8 \\
      --max-new 32 --arrival-rate 4 --trace /tmp/decode_trace.json
"""
from __future__ import annotations

import argparse
import dataclasses
import time
import weakref
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs as obslib
from repro.configs import get_config
from repro.core import hw
from repro.core.ftl import registry as ftl_registry
from repro.launch import kv_cache as KV
from repro.models import model as M
from repro.train import steps as S

# how often an obs-enabled engine samples a decode step into the drift
# monitor (report-only rows; whole-block rows come from
# execute_block_plan and are the ones benches gate on)
_DRIFT_SAMPLE_EVERY = 16


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new: int
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    arrival_s: float = 0.0       # open-loop arrival offset from run start
    bucket: int = 0              # prefill bucket the prompt landed in
    t_arrival: float = 0.0       # absolute times (perf_counter)
    t_admitted: float = 0.0
    t_done: float = 0.0

    @property
    def latency_s(self) -> float:
        """Arrival → completion, including queueing for a slot."""
        return self.t_done - self.t_arrival


class PlanCache:
    """Serving plan cache keyed ``(cfg, bucketed m, dtype, target, phase)``.

    A thin counting wrapper over :func:`repro.models.model.serve_plan`:
    ``warmup`` pre-plans the whole prefill bucket ladder plus the decode
    plan, after which every lookup must hit — ``misses_after_warmup`` is
    the CI gate's "zero replans during steady-state decode" counter.
    """

    def __init__(self, cfg, *, dtype: str, target: hw.Target,
                 buckets: tuple[int, ...]):
        self.cfg = cfg
        self.dtype = dtype
        self.target = target
        self.buckets = tuple(buckets)
        self._plans: dict[tuple, object] = {}
        self.hits = 0
        self.misses = 0
        self.warmed = False
        self.misses_after_warmup: list[tuple[str, int]] = []
        # a global cache clear must not leave this wrapper claiming
        # hits/warmth for plans the clear just dropped
        ftl_registry.register_counter_reset(self)

    def get(self, m: int, phase: str):
        """(bucketed m, BlockPlan-or-None) for one lookup."""
        mb = 1 if phase == "decode" else M.bucket_m(m, self.buckets)
        key = (self.cfg, mb, self.dtype, self.target, phase)
        if key in self._plans:
            self.hits += 1
            return mb, self._plans[key]
        self.misses += 1
        if self.warmed:
            self.misses_after_warmup.append((phase, mb))
        _, plan = M.serve_plan(self.cfg, m=mb, dtype=self.dtype,
                               target=self.target, phase=phase,
                               buckets=self.buckets)
        self._plans[key] = plan
        return mb, plan

    def warmup(self) -> None:
        for b in self.buckets:
            self.get(b, "prefill")
        self.get(1, "decode")
        self.warmed = True

    def counters(self) -> dict:
        return {
            "plans": len(self._plans),
            "hits": self.hits,
            "misses": self.misses,
            "misses_after_warmup": len(self.misses_after_warmup),
        }

    def reset_counters(self) -> None:
        """Back to the just-constructed state — called by
        ``registry.clear_plan_caches``.  The held plans are dropped too
        (they were built by the caches the clear invalidated), so the
        next lookup genuinely replans and the counters say so."""
        self._plans.clear()
        self.hits = 0
        self.misses = 0
        self.warmed = False
        self.misses_after_warmup.clear()


def _default_buckets(max_seq: int, block_size: int) -> tuple[int, ...]:
    rungs = [b for b in M.PREFILL_BUCKETS if b <= max_seq]
    if not rungs or rungs[-1] < max_seq:
        rungs.append(max_seq)
    rungs = [b for b in rungs if b % block_size == 0] or [max_seq]
    return tuple(rungs)


class ServeEngine:
    """Fixed-slot continuous batching engine (single host).

    ``target`` picks the planning preset (None → the process default);
    ``block_size`` is the paged-KV page length (``paged=False`` forces
    the dense per-slot cache, ``kv_blocks`` shrinks the physical pool
    below ``slots * max_seq / block_size`` to exercise admission
    control)."""

    def __init__(self, cfg, params, *, batch_slots: int, max_seq: int,
                 eos_id: int = 1, target: hw.Target | None = None,
                 block_size: int = 8, paged: bool | None = None,
                 kv_blocks: int | None = None,
                 buckets: tuple[int, ...] | None = None,
                 obs: bool = False,
                 drift_target: hw.Target | None = None,
                 drift_band: tuple[float, float] | None = None):
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_seq = max_seq
        self.eos = eos_id
        self.target = target if target is not None else hw.default_target()
        self.block_size = block_size
        self.buckets = (tuple(buckets) if buckets is not None
                        else _default_buckets(max_seq, block_size))
        if any(b > max_seq for b in self.buckets):
            raise ValueError(f"bucket ladder {self.buckets} exceeds "
                             f"max_seq={max_seq}")
        self.active: list[Request | None] = [None] * batch_slots
        self.pos = np.zeros(batch_slots, np.int32)
        # mixed-length decode needs per-slot positions; enc-dec keeps the
        # scalar path (uniform sinusoidal offset)
        self._vector_pos = not cfg.is_encoder_decoder

        self.paged = (KV.paged_supported(cfg) if paged is None else paged)
        if self.paged and not KV.paged_supported(cfg):
            raise ValueError(f"{cfg.name!r} cannot use the paged KV cache")
        if self.paged:
            if max_seq % block_size:
                raise ValueError(f"max_seq={max_seq} must be a multiple "
                                 f"of block_size={block_size}")
            if any(b % block_size for b in self.buckets):
                raise ValueError(
                    f"every prefill bucket must be a multiple of "
                    f"block_size={block_size}, got {self.buckets}")
            self.kv = KV.PagedKVCache(cfg, slots=batch_slots,
                                      max_seq=max_seq,
                                      block_size=block_size,
                                      num_blocks=kv_blocks)
            self.cache = None
        else:
            self.kv = None
            self.cache = M.init_cache(cfg, batch_slots, max_seq)

        # AOT warmup of the bucket ladder + the decode plan: after this,
        # steady state never plans again (the bench gate).
        self.plans = PlanCache(cfg, dtype=cfg.dtype, target=self.target,
                               buckets=self.buckets)
        self.plans.warmup()
        _, self.decode_plan = self.plans.get(1, "decode")
        _, self.block_plan = self.plans.get(self.buckets[-1], "prefill")
        self._decode_fn = self._build_decode(self.decode_plan)
        self._decode_fn_plan = self.decode_plan
        self._prefill_fns: dict[int, Any] = {}

        self.stats = {
            "prefills": 0, "decode_steps": 0, "tokens": 0,
            "replans": 0,
            "bucket_admissions": {},
            "ftl_schedule": (self.block_plan.schedule
                             if self.block_plan else "n/a"),
            "ftl_target": self.target.name,
            "block_exec": "n/a",
        }
        ftl_registry.register_counter_reset(self)

        # telemetry (repro.obs): span recording + per-step gauges + the
        # online drift monitor, all opt-in — a bare engine pays nothing.
        self.obs = bool(obs)
        self.drift = None
        if self.obs:
            obslib.enable()
            self.drift = obslib.DriftMonitor(
                target=drift_target if drift_target is not None
                else self.target,
                **({"band": drift_band} if drift_band else {}))
            self._g_active = obslib.gauge(
                "serve_active_slots", "slots currently decoding")
            self._g_queue = obslib.gauge(
                "serve_queue_depth", "requests waiting for a slot")
            self._g_kv_free = obslib.gauge(
                "serve_kv_free_blocks", "paged-KV free physical blocks")
            self._g_kv_occ = obslib.gauge(
                "serve_kv_page_occupancy",
                "fraction of the paged-KV pool in use")
            self._c_evict = obslib.counter(
                "serve_evictions_total", "slots freed (EOS/max-len)")
            self._h_step = obslib.histogram(
                "serve_decode_step_seconds", "wall-clock per decode step")
            self._register_obs_collector()

    def _register_obs_collector(self) -> None:
        """Re-express ``plan_report()``/``stats`` on the metrics registry
        at collect time.  Weakly bound: a dead engine's collector is a
        no-op, never a leak."""
        ref = weakref.ref(self)

        def _collect(reg) -> None:
            eng = ref()
            if eng is None:
                return
            g_stat = reg.gauge("serve_stats",
                               "ServeEngine.stats re-expressed", ("stat",))
            for k in ("prefills", "decode_steps", "tokens", "replans"):
                g_stat.labels(stat=k).set(eng.stats[k])
            g_pc = reg.gauge("serve_plan_cache",
                             "serving PlanCache counters", ("field",))
            for k, v in eng.plans.counters().items():
                g_pc.labels(field=k).set(v)
            rep = eng.plan_report()
            g_plan = reg.gauge(
                "serve_plan_segments",
                "planned segments per serving regime (0 = no plan)",
                ("phase", "schedule"))
            for phase in ("prefill", "decode"):
                e = rep[phase]
                if e is not None:
                    g_plan.labels(phase=phase, schedule=e["schedule"]) \
                        .set(len(e["cuts"]) + 1)
            reg.gauge("serve_decode_differs_from_prefill",
                      "1 when the decode DP picked different cuts") \
                .set(float(rep["decode_differs_from_prefill"]))

        obslib.register_collector(_collect)

    def reset_counters(self) -> None:
        """Called by ``registry.clear_plan_caches``: the decode-replan
        counter tracks misses of the (just-reset) plan cache, so it must
        restart with it or ``plan_report`` would blame post-clear replans
        on steady-state serving."""
        self.stats["replans"] = 0

    # ------------------------------------------------------------------
    # plan-aware step builders
    # ------------------------------------------------------------------
    def _build_decode(self, plan):
        base = S.make_decode_step(self.cfg, None, plan=plan)
        if not self.paged:
            return jax.jit(base)

        def paged_step(params, pool, tables, tok, pos, wblk, woff):
            dense = KV.gather_dense(pool, tables)
            logits, new_dense = base(params, dense, tok, pos)
            return logits, KV.scatter_token(pool, new_dense, pos, wblk,
                                            woff)

        return jax.jit(paged_step)

    def _prefill_fn(self, bucket: int, plan):
        fn = self._prefill_fns.get(bucket)
        if fn is None:
            # paged caches splice page-aligned bucket-length caches; the
            # dense path right-pads to max_seq at splice time instead
            fn = jax.jit(S.make_prefill_step(self.cfg, None, plan=plan))
            self._prefill_fns[bucket] = fn
        return fn

    def plan_report(self) -> dict:
        """Resolved executors + cuts for *both* serving regimes (mirrors
        what train logs for its single shape)."""
        from repro.core.ftl import executor_block

        def entry(plan, m):
            if plan is None:
                return None
            return {
                "m": m,
                "schedule": plan.schedule,
                "cuts": list(plan.chain.cuts()),
                "executors": executor_block.resolved_executors(plan, m=m),
            }

        pre = entry(self.block_plan, self.buckets[-1])
        dec = entry(self.decode_plan, 1)
        return {
            "target": self.target.name,
            "buckets": list(self.buckets),
            "prefill": pre,
            "decode": dec,
            "decode_differs_from_prefill": bool(
                pre and dec and pre["cuts"] != dec["cuts"]),
            # every memoized planner the serving path leans on — shows
            # the plans above came out of cache, not replanning
            "plan_caches": ftl_registry.plan_cache_stats(),
        }

    def warmup_compile(self, extras: dict[str, Any] | None = None) -> None:
        """Compile every bucket's prefill step and the decode step ahead
        of time, so open-loop latency percentiles measure serving, not
        XLA compiles.  Pure: engine state is untouched."""
        extras = extras or {}
        for b in self.buckets:
            _, plan = self.plans.get(b, "prefill")
            fn = self._prefill_fn(b, plan)
            batch = {"tokens": jnp.zeros((1, b), jnp.int32), **extras}
            fn(self.params, batch, jnp.int32(b - 1))[0].block_until_ready()
        tok = jnp.zeros((self.slots, 1), jnp.int32)
        if self.paged:
            pos = jnp.zeros((self.slots,), jnp.int32)
            zero = jnp.zeros((self.slots,), jnp.int32)
            out = self._decode_fn(self.params, self.kv.pool,
                                  self.kv.table_array(), tok, pos, zero,
                                  zero)
        else:
            pos = (jnp.zeros((self.slots,), jnp.int32) if self._vector_pos
                   else jnp.int32(0))
            out = self._decode_fn(self.params, self.cache, tok, pos)
        out[0].block_until_ready()

    # ------------------------------------------------------------------
    def execute_block_plan(self):
        """Run the stored prefill BlockPlan for real at the serving shape.

        Executes one transformer block of the engine's own parameters
        through ``registry.run_block`` on a (1, max_seq, d_model)
        activation — the steady-state prefill shape regime.  This is
        where every binding is requalified on the serving host
        (per-segment fallback), and it prices the plan in wall-clock
        terms instead of only reporting modeled traffic.  Records the
        resolved executors and timing in ``stats``; returns the stats
        entry (None when the model has no plan or no plannable layer).
        """
        if self.block_plan is None:
            return None
        p, kind = self._first_block_params()
        if p is None or ("attn" not in p and "mlp" not in p):
            return None
        from repro.core.ftl import executor_block
        from repro.core.ftl import registry as ftl_registry
        cfg = self.cfg
        window = cfg.local_window if kind == "local" else None
        x = jax.random.normal(
            jax.random.PRNGKey(0), (1, self.max_seq, cfg.d_model)
        ).astype(cfg.dtype)
        positions = jnp.arange(self.max_seq)
        run = jax.jit(lambda xx: ftl_registry.run_block(
            self.block_plan, p, xx, positions=positions, window=window))
        run(x).block_until_ready()              # compile
        t0 = time.perf_counter()
        with obslib.span("serve:block_exec", "exec"):
            y = run(x)
            y.block_until_ready()
        dt = time.perf_counter() - t0
        entry = {
            "ms": round(1e3 * dt, 3),
            "executors": executor_block.resolved_executors(
                self.block_plan, m=self.max_seq, dtype=str(x.dtype)),
            "finite": bool(jnp.isfinite(y).all()),
        }
        self.stats["block_exec"] = entry
        if self.drift is not None:
            # the gated drift feed: a whole planned block, wall-clocked
            # at the serving shape — the same regime bench_calibrate's
            # block rows measure
            entry["drift_ratio"] = self.drift.observe_chain(
                self.block_plan, dt, name="block_exec", kind="block")
        return entry

    def _first_block_params(self):
        """(params, mixer kind) of the first plan-executable layer.

        Prefers a full attention(+MLP) layer; hybrid configs whose leading
        positions are recurrent fall back to any MLP-bearing one (the plan
        is MLP-only there and run_block executes just that stage).
        Returns (None, None) when no layer can execute the plan.
        """
        kinds, n_full, rem_kinds = M._layer_split(self.cfg)
        if n_full:
            pool = [(k, f"pos{i}") for i, k in enumerate(kinds)]

            def get(key):
                # slice only this position's subtree, not the whole stack
                return jax.tree.map(lambda a: a[0],
                                    self.params["layers"][key])
        elif rem_kinds:
            pool = [(k, f"rem{i}") for i, k in enumerate(rem_kinds)]

            def get(key):
                return self.params["rem"][key]
        else:
            return None, None
        for kind, key in pool:
            if kind in ("attn", "local"):
                return get(key), kind
        # no attention layer: any MLP-bearing layer can run the
        # (MLP-only) plan
        if bool(self.cfg.d_ff) and not self.cfg.is_moe:
            kind, key = pool[0]
            return get(key), kind
        return None, None

    # ------------------------------------------------------------------
    def _admit(self, req: Request, slot: int, extras: dict[str, Any]
               ) -> bool:
        """Prefill one request at its bucketed length and splice its
        cache into the slot.  Returns False (admitting nothing) when the
        paged pool cannot cover the bucket — the request stays queued."""
        plen = len(req.prompt)
        if plen > self.buckets[-1]:
            raise ValueError(f"request {req.rid}: prompt of {plen} tokens "
                             f"exceeds the largest bucket "
                             f"{self.buckets[-1]}")
        obslib.begin("serve:admit", "serve")
        bucket, plan = self.plans.get(plen, "prefill")
        req.bucket = bucket
        if self.paged and not self.kv.allocate(slot, bucket):
            obslib.end()
            return False

        padded = np.zeros(bucket, np.int32)
        padded[:plen] = req.prompt
        batch = {"tokens": jnp.asarray(padded)[None], **extras}
        fn = self._prefill_fn(bucket, plan)
        # bucket padding is on the right; the prompt's real last token
        # sits at plen-1 and decode overwrites the pad KV in place
        with obslib.span(f"serve:prefill:m{bucket}", "serve"):
            logits, cache1 = fn(self.params, batch, jnp.int32(plen - 1))
            logits.block_until_ready()

        if self.paged:
            self.kv.write_prefill(slot, cache1, bucket)
        else:
            def splice(path, full, one):
                """Insert request-batch-1 state into this slot of the
                batch cache, padding the request's seq dims up to the
                engine max.

                The batch axis is structural, not inferred from extents
                (slot-count 1 made every axis look like batch): stacked
                'layers' caches carry a leading layer dim → batch is
                axis 1; remainder/unstacked caches → axis 0."""
                names = [str(k.key) for k in path
                         if isinstance(k, jax.tree_util.DictKey)]
                ax = 1 if names and names[0] == "layers" else 0
                if one.shape[ax + 1:] != full.shape[ax + 1:]:
                    pads = [(0, 0)] * one.ndim
                    for d in range(ax + 1, one.ndim):
                        pads[d] = (0, full.shape[d] - one.shape[d])
                    one = jnp.pad(one, pads)
                return _dus_axis(full, jnp.take(one, 0, axis=ax), slot, ax)

            self.cache = jax.tree_util.tree_map_with_path(
                splice, self.cache, cache1)

        self.active[slot] = req
        self.pos[slot] = plen
        req.out.append(int(jnp.argmax(logits[0, -1])))
        req.t_admitted = time.perf_counter()
        self.stats["prefills"] += 1
        adm = self.stats["bucket_admissions"]
        adm[bucket] = adm.get(bucket, 0) + 1
        obslib.end()  # serve:admit
        return True

    # ------------------------------------------------------------------
    def _evict(self, slot: int) -> None:
        with obslib.span("serve:evict", "serve"):
            self.active[slot] = None
            self.pos[slot] = 0
            if self.paged:
                self.kv.release(slot)
        if self.obs:
            self._c_evict.inc()

    def step(self):
        """One batched decode step for all active slots (each at its own
        position)."""
        t_step = time.perf_counter() if self.obs else 0.0
        obslib.begin("serve:decode_step", "serve")
        # steady-state plan lookup: after warmup this always hits; a miss
        # (or a changed plan object) would force a re-jit — counted as a
        # replan, and gated to zero in bench_serve
        _, plan = self.plans.get(1, "decode")
        if plan is not self._decode_fn_plan:
            self._decode_fn = self._build_decode(plan)
            self._decode_fn_plan = plan
            self.decode_plan = plan
            self.stats["replans"] += 1

        tok = np.zeros((self.slots, 1), np.int32)
        live = np.zeros(self.slots, bool)
        for i, r in enumerate(self.active):
            if r is not None and not r.done:
                tok[i, 0] = r.out[-1]
                live[i] = True

        if self.paged:
            wblk = np.zeros(self.slots, np.int32)
            woff = np.zeros(self.slots, np.int32)
            for i in range(self.slots):
                if live[i]:
                    if not self.kv.allocate(i, int(self.pos[i]) + 1):
                        raise RuntimeError(
                            f"KV pool exhausted growing slot {i} at pos "
                            f"{int(self.pos[i])} "
                            f"({self.kv.free_blocks} free blocks)")
                    wblk[i], woff[i] = self.kv.write_coords(
                        i, int(self.pos[i]))
                # dead slots keep (0, 0): the scratch page
            logits, self.kv.pool = self._decode_fn(
                self.params, self.kv.pool, self.kv.table_array(),
                jnp.asarray(tok), jnp.asarray(self.pos),
                jnp.asarray(wblk), jnp.asarray(woff))
        else:
            if self._vector_pos:
                pos = jnp.asarray(self.pos)
            else:
                pos = jnp.int32(int(max(
                    (self.pos[i] for i, r in enumerate(self.active)
                     if r is not None), default=0)))
            logits, self.cache = self._decode_fn(
                self.params, self.cache, jnp.asarray(tok), pos)

        nxt = np.asarray(jnp.argmax(logits[:, 0], -1))
        now = time.perf_counter()
        for i, r in enumerate(self.active):
            if r is None or r.done:
                continue
            t = int(nxt[i])
            r.out.append(t)
            self.pos[i] += 1
            self.stats["tokens"] += 1
            if t == self.eos or len(r.out) >= r.max_new \
                    or self.pos[i] >= self.max_seq - 1:
                r.done = True
                r.t_done = now
        self.stats["decode_steps"] += 1
        obslib.end()  # serve:decode_step
        if self.obs:
            self._observe_step(time.perf_counter() - t_step)

    def _observe_step(self, dt: float) -> None:
        """Per-step gauges + a sampled drift row (obs-enabled engines)."""
        self._h_step.observe(dt)
        self._g_active.set(sum(1 for r in self.active
                               if r is not None and not r.done))
        if self.paged:
            free = self.kv.free_blocks
            self._g_kv_free.set(free)
            self._g_kv_occ.set(1.0 - free / max(self.kv.num_blocks, 1))
        if (self.drift is not None and self.decode_plan is not None
                and self.stats["decode_steps"] % _DRIFT_SAMPLE_EVERY == 0):
            # report-only row: a decode step runs the per-block plan
            # n_layers times (plus head/dispatch the model never charges
            # the block plan for), so scale the modeled side to match.
            # Whole-block rows from execute_block_plan are the gated ones.
            self.drift.observe_chain(
                self.decode_plan, dt, name="decode_step", kind="decode",
                scale=max(self.cfg.n_layers, 1))

    def run(self, requests: list[Request], extras: dict[str, Any],
            arrivals: list[float] | None = None):
        """Serve ``requests`` to completion.

        ``arrivals`` (seconds from run start, one per request, sorted)
        switches to an open-loop arrival process: request *i* only
        becomes admissible once its arrival time has passed, and
        ``Request.latency_s`` measures arrival → completion including
        queueing.  None keeps the closed-loop behavior (everything
        arrives at t=0)."""
        if arrivals is not None:
            if len(arrivals) != len(requests):
                raise ValueError("one arrival time per request")
            for r, a in zip(requests, arrivals):
                r.arrival_s = float(a)
        t0 = time.perf_counter()
        for r in requests:
            r.t_arrival = t0 + r.arrival_s
        queue = list(requests)
        done: list[Request] = []
        while queue or any(r is not None for r in self.active):
            now = time.perf_counter()
            if self.obs:
                self._g_queue.set(len(queue))
            admitted_any = False
            for i in range(self.slots):
                r = self.active[i]
                if r is not None and r.done:
                    done.append(r)
                    self._evict(i)
                if (self.active[i] is None and queue
                        and queue[0].t_arrival <= now):
                    if self._admit(queue[0], i, extras):
                        queue.pop(0)
                        admitted_any = True
                    else:
                        break       # paged pool full: wait for evictions
            have_live = any(r is not None and not r.done
                            for r in self.active)
            if not have_live:
                if admitted_any:
                    continue
                if queue:
                    wait = queue[0].t_arrival - time.perf_counter()
                    if wait > 0:
                        time.sleep(min(wait, 0.05))
                        continue
                    if all(r is None for r in self.active):
                        # head request arrived but cannot be admitted and
                        # nothing is running to free pages
                        raise RuntimeError(
                            "deadlock: KV pool too small to admit request "
                            f"{queue[0].rid} with every slot empty")
                continue
            self.step()
        return done


def _dus_axis(full, val, idx, ax):
    return jax.lax.dynamic_update_index_in_dim(full, val, idx, ax)


def poisson_arrivals(n: int, rate_per_s: float, seed: int = 0
                     ) -> list[float]:
    """Cumulative exponential inter-arrival times (open-loop process)."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / max(rate_per_s, 1e-9), size=n)
    return list(np.cumsum(gaps))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--target", default=None,
                    help="planning target preset (default: auto-detect)")
    ap.add_argument("--block-size", type=int, default=8,
                    help="paged-KV page length in tokens")
    ap.add_argument("--dense-kv", action="store_true",
                    help="force the dense per-slot cache")
    ap.add_argument("--arrival-rate", type=float, default=None,
                    help="open-loop Poisson arrival rate (req/s); "
                    "default: all requests arrive at t=0")
    ap.add_argument("--trace", default=None,
                    help="write a Chrome-tracing timeline of the decode "
                    "plan's simulated schedule to this path (with --obs: "
                    "the merged live+modeled timeline, written post-run)")
    ap.add_argument("--obs", action="store_true",
                    help="enable runtime telemetry (spans, gauges, the "
                    "online drift monitor)")
    ap.add_argument("--obs-trace", default=None,
                    help="write the merged live+modeled Perfetto "
                    "timeline to this path after the run (implies --obs)")
    ap.add_argument("--obs-metrics", default=None,
                    help="write a Prometheus text exposition of the "
                    "metrics registry to this path after the run "
                    "(implies --obs)")
    args = ap.parse_args()
    if args.obs_trace or args.obs_metrics:
        args.obs = True
    if args.trace and args.obs_trace and args.trace == args.obs_trace:
        ap.error("--trace and --obs-trace point at the same path "
                 f"({args.trace}); they would silently overwrite each "
                 "other — give them distinct paths")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    extras: dict[str, Any] = {}
    if cfg.family == "vlm":
        extras["image_embeds"] = jnp.zeros(
            (1, cfg.n_image_tokens, cfg.d_model), cfg.dtype)
    if cfg.is_encoder_decoder:
        extras["frames"] = jnp.zeros(
            (1, cfg.encoder_seq, cfg.d_model), cfg.dtype)

    rng = np.random.default_rng(args.seed)
    # mixed prompt lengths exercise the bucket ladder + per-slot decode
    lens = rng.integers(max(1, args.prompt_len // 2), args.prompt_len + 1,
                        size=args.requests)
    reqs = [Request(i, rng.integers(2, cfg.vocab_size,
                                    size=int(lens[i])).astype(np.int32),
                    args.max_new)
            for i in range(args.requests)]
    target = hw.get_target(args.target) if args.target else None
    eng = ServeEngine(cfg, params, batch_slots=args.slots,
                      max_seq=args.max_seq, target=target,
                      block_size=args.block_size,
                      paged=False if args.dense_kv else None,
                      obs=args.obs)
    report = eng.plan_report()
    print(f"FTL serving plans on {report['target']} "
          f"(buckets {report['buckets']}, "
          f"{'paged' if eng.paged else 'dense'} KV):")
    for phase in ("prefill", "decode"):
        e = report[phase]
        if e is None:
            print(f"  {phase}: no plannable block")
            continue
        print(f"  {phase} @ m={e['m']}: schedule={e['schedule']} "
              f"cuts={e['cuts']} executors={e['executors']}")
    if report["decode_differs_from_prefill"]:
        print("  decode cuts differ from prefill (memory-bound m=1 DP)")
    hot = {n: s for n, s in report["plan_caches"].items()
           if s["hits"] or s["misses"]}
    for n, s in hot.items():
        print(f"  plan cache {n}: {s['hits']} hits / {s['misses']} misses "
              f"({s['size']}/{s['maxsize']} entries)")
    if eng.block_plan is not None:
        exec_stats = eng.execute_block_plan()
        if exec_stats is not None:
            print(f"block plan executed @ m={args.max_seq}: "
                  f"{exec_stats['ms']} ms, executors "
                  f"{exec_stats['executors']}")
    if args.trace and not args.obs:
        # modeled-only timeline (pre-run: it needs no live spans); with
        # --obs the trace is written post-run as the merged live+modeled
        # view instead
        from repro.sim import write_chrome_trace
        plan = eng.decode_plan or eng.block_plan
        if plan is not None:
            write_chrome_trace(plan, args.trace)
            print(f"decode-plan timeline written to {args.trace}")

    eng.warmup_compile(extras)
    arrivals = (poisson_arrivals(args.requests, args.arrival_rate,
                                 args.seed)
                if args.arrival_rate else None)
    t0 = time.time()
    done = eng.run(reqs, extras, arrivals=arrivals)
    dt = time.time() - t0
    lat = sorted(r.latency_s for r in done)
    p50 = lat[len(lat) // 2] if lat else 0.0
    p99 = lat[min(len(lat) - 1, int(0.99 * len(lat)))] if lat else 0.0
    print(f"served {len(done)} requests, {eng.stats['tokens']} tokens "
          f"in {dt:.1f}s ({eng.stats['tokens']/max(dt,1e-9):.1f} tok/s); "
          f"{eng.stats['decode_steps']} decode steps, "
          f"{eng.stats['prefills']} prefills, "
          f"p50 {1e3*p50:.0f} ms / p99 {1e3*p99:.0f} ms")
    pc = eng.plans.counters()
    print(f"plan cache: {pc['plans']} plans, {pc['hits']} hits, "
          f"{pc['misses']} misses ({pc['misses_after_warmup']} after "
          f"warmup), {eng.stats['replans']} decode replans")
    for r in done[:3]:
        print(f"  req {r.rid}: {len(r.out)} tokens: {r.out[:10]}...")

    if args.obs:
        if eng.drift is not None and eng.drift.n_observed:
            st = eng.drift.status()
            print(f"drift monitor on {st['target']}: geomean "
                  f"modeled/measured {st['geomean_ratio']:.3f} "
                  f"({'in' if st['in_band'] else 'OUT OF'} band "
                  f"{tuple(st['band'])}, {st['n_observed']} observations)")
        plan = eng.decode_plan or eng.block_plan
        for path in (args.obs_trace, args.trace):
            if path:
                obslib.write_merged_trace(path, chain=plan)
                print(f"merged live+modeled timeline written to {path}")
        if args.obs_metrics:
            obslib.write_prometheus(args.obs_metrics)
            print(f"Prometheus metrics written to {args.obs_metrics}")


if __name__ == "__main__":
    main()
