"""Paged (blocked) KV cache for the continuous-batching serving engine.

The attention executor already tiles over the sequence, so the cache can
be backed by fixed-size sequence blocks ("pages") allocated per slot
instead of one dense ``(slots, max_seq, ...)`` tensor per layer:

* The physical pool is ``models.model.init_cache(cfg, num_blocks + 1,
  block_size)`` — the *batch* axis of every cache leaf plays the physical
  block index, so the pool reuses the model's exact cache structure
  (stacked ``layers`` leaves ``(L, NB+1, bs, Hk, Dh)``, remainder leaves
  ``(NB+1, bs, Hk, Dh)``).  Physical block 0 is a reserved scratch page:
  unmapped table entries and inactive slots point there, so a stray
  write can never corrupt a mapped page.
* Each slot owns a block table row (host-side numpy, ``(slots,
  blocks_per_slot)`` int32 of physical block ids).  Pages are allocated
  on demand as a slot's position crosses a block boundary and returned
  to the free list on eviction — the continuous-batching scheduler's
  admission control can therefore run the pool smaller than
  ``slots * blocks_per_slot`` and queue requests under memory pressure.
* :func:`gather_dense` / :func:`scatter_token` are the jit-traceable
  halves of a decode step: gather materializes the per-slot dense view
  ``(slots, max_seq, ...)`` from the pool (one ``take`` + reshape per
  leaf), and scatter writes each slot's single new KV token back to its
  ``(block, offset)`` coordinate.  The serving engine fuses
  gather → model.decode_step → scatter into one jitted function, so the
  dense view never round-trips to host memory.

Paging requires every decode-cache leaf to be a full-attention KV tensor
with the model's uniform ``(batch, seq, Hk, Dh)`` layout —
:func:`paged_supported` gates it to pure-``attn`` decoder-only configs
(ring-buffered local windows, recurrent states and cross caches keep the
dense per-slot path in the engine).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M


def paged_supported(cfg) -> bool:
    """True when every decode-cache leaf is a plain full-attention KV
    tensor (pure-'attn' decoder-only stacks)."""
    if cfg.is_encoder_decoder:
        return False
    kinds, _, rem_kinds = M._layer_split(cfg)
    return all(k == "attn" for k in [*kinds, *rem_kinds])


def _block_axis(path) -> int:
    """Physical-block axis of a pool leaf: stacked 'layers' leaves carry a
    leading layer dim → axis 1; remainder leaves → axis 0 (the same
    structural rule the engine's dense splice uses)."""
    names = [str(k.key) for k in path
             if isinstance(k, jax.tree_util.DictKey)]
    return 1 if names and names[0] == "layers" else 0


def gather_dense(pool, tables: jax.Array):
    """Pool → per-slot dense cache view.

    ``tables``: (slots, blocks_per_slot) int32 physical block ids.  Each
    leaf ``(..., NB+1, bs, ...)`` gathers its mapped pages and merges
    them into ``(..., slots, blocks_per_slot * bs, ...)`` — exactly the
    shape ``model.decode_step`` expects for ``max_seq =
    blocks_per_slot * bs``.  Unmapped entries read the scratch page;
    decode masks them out (position mask covers only ``<= pos``).
    """
    slots, w = tables.shape

    def g(path, leaf):
        ax = _block_axis(path)
        taken = jnp.take(leaf, tables.reshape(-1), axis=ax)
        sh = taken.shape
        bs = sh[ax + 1]
        return taken.reshape(sh[:ax] + (slots, w * bs) + sh[ax + 2:])

    return jax.tree_util.tree_map_with_path(g, pool)


def scatter_token(pool, dense, pos: jax.Array, wblk: jax.Array,
                  woff: jax.Array):
    """Write each slot's newly-decoded KV token back into the pool.

    ``dense`` is the post-decode dense view; ``pos`` (slots,) is each
    slot's write position inside its dense view, ``wblk``/``woff``
    (slots,) its physical (block, offset) coordinate — inactive slots
    point at the scratch page (block 0).
    """
    def s(path, pleaf, dleaf):
        ax = _block_axis(path)
        seq_ax = ax + 1
        idx_shape = [1] * dleaf.ndim
        idx_shape[ax] = pos.shape[0]
        idx = pos.reshape(idx_shape)
        tok = jnp.take_along_axis(dleaf, idx, axis=seq_ax)
        tok = jnp.squeeze(tok, axis=seq_ax)        # (..., slots, Hk, Dh)
        if ax == 1:
            return pleaf.at[:, wblk, woff].set(tok)
        return pleaf.at[wblk, woff].set(tok)

    return jax.tree_util.tree_map_with_path(s, pool, dense)


@jax.jit
def _write_pages(pool, cache1, blocks: jax.Array):
    """Write one request's prefill cache (batch-1, seq = n_pages * bs)
    into its allocated pages (jitted; retraces per page count)."""
    def s(path, pleaf, cleaf):
        ax = _block_axis(path)
        bs = pleaf.shape[ax + 1]
        c = jnp.squeeze(cleaf, axis=ax)            # drop request batch-1
        sh = c.shape
        c = c.reshape(sh[:ax] + (blocks.shape[0], bs) + sh[ax + 1:])
        if ax == 1:
            return pleaf.at[:, blocks].set(c)
        return pleaf.at[blocks].set(c)

    return jax.tree_util.tree_map_with_path(s, pool, cache1)


class PagedKVCache:
    """Block-pool KV cache with per-slot page tables (single host).

    ``num_blocks`` bounds the physical pool (default: enough for every
    slot at ``max_seq``, i.e. no admission pressure); one extra scratch
    page is always added on top.  All table/free-list bookkeeping is
    host-side numpy — only the pool itself lives on device.
    """

    def __init__(self, cfg, *, slots: int, max_seq: int, block_size: int,
                 num_blocks: int | None = None):
        if not paged_supported(cfg):
            raise ValueError(
                "paged KV cache needs a pure-'attn' decoder-only config; "
                f"{cfg.name!r} has other cache kinds")
        if max_seq % block_size:
            raise ValueError(
                f"max_seq={max_seq} must be a multiple of "
                f"block_size={block_size}")
        self.cfg = cfg
        self.slots = slots
        self.max_seq = max_seq
        self.block_size = block_size
        self.blocks_per_slot = max_seq // block_size
        self.num_blocks = (num_blocks if num_blocks is not None
                           else slots * self.blocks_per_slot)
        if self.num_blocks < self.blocks_per_slot:
            raise ValueError(
                f"pool of {self.num_blocks} blocks cannot hold even one "
                f"slot at max_seq ({self.blocks_per_slot} blocks)")
        # +1: physical block 0 is the reserved scratch page
        self.pool = M.init_cache(cfg, self.num_blocks + 1, block_size)
        self.tables = np.zeros((slots, self.blocks_per_slot), np.int32)
        self.n_alloc = np.zeros(slots, np.int32)
        self._free = list(range(self.num_blocks, 0, -1))

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    def can_allocate(self, slot: int, n_tokens: int) -> bool:
        need = self.blocks_for(n_tokens) - int(self.n_alloc[slot])
        return need <= len(self._free)

    def allocate(self, slot: int, n_tokens: int) -> bool:
        """Grow ``slot`` to cover ``n_tokens`` positions.  Returns False
        (allocating nothing) when the free list cannot cover the growth —
        the scheduler's admission-control signal."""
        need = self.blocks_for(n_tokens)
        have = int(self.n_alloc[slot])
        if need <= have:
            return True
        if need > self.blocks_per_slot:
            raise ValueError(
                f"slot {slot}: {n_tokens} tokens exceed max_seq "
                f"{self.max_seq}")
        if need - have > len(self._free):
            return False
        for j in range(have, need):
            self.tables[slot, j] = self._free.pop()
        self.n_alloc[slot] = need
        return True

    def release(self, slot: int) -> None:
        """Return the slot's pages to the free list (eviction)."""
        for j in range(int(self.n_alloc[slot])):
            self._free.append(int(self.tables[slot, j]))
        self.tables[slot, :] = 0
        self.n_alloc[slot] = 0

    def table_array(self) -> jax.Array:
        return jnp.asarray(self.tables)

    def write_coords(self, slot: int, pos: int) -> tuple[int, int]:
        """Physical (block, offset) of dense position ``pos`` in ``slot``."""
        j = pos // self.block_size
        return int(self.tables[slot, j]), pos % self.block_size

    def write_prefill(self, slot: int, cache1, n_tokens: int) -> None:
        """Splice one request's prefill cache (batch 1, seq a multiple of
        ``block_size``) into the slot's pages, allocating them first.
        The caller has already checked/established capacity via
        :meth:`allocate`."""
        if not self.allocate(slot, n_tokens):
            raise RuntimeError(
                f"KV pool exhausted admitting into slot {slot} "
                f"({self.free_blocks} free blocks)")
        nb = self.blocks_for(n_tokens)
        blocks = jnp.asarray(self.tables[slot, :nb])
        self.pool = _write_pages(self.pool, cache1, blocks)
