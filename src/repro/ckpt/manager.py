"""Checkpointing: atomic, async, retention-managed, **mesh-shape-agnostic**.

Format: one ``.npz`` per process holding this process's addressable data
(key = flattened pytree path) plus a JSON manifest with the step, global
shapes/dtypes and tree structure.  Restore reads the arrays and
``device_put``s them under the *caller's* shardings — which may belong to a
different mesh than the one that saved (elastic restart: a 512-chip job's
checkpoint restores onto 256 chips and vice versa, tested in
tests/test_ckpt.py).

Write protocol (crash-safe): write to ``step_<n>.tmp/`` → fsync → atomic
rename to ``step_<n>/``.  A partially-written checkpoint is never visible
to ``latest_step``.  Async mode snapshots device arrays to host on the
caller's thread (cheap d2h) and runs file I/O on a background thread so
training continues during the write.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any
_SEP = "/"


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(k.key) if isinstance(k, jax.tree_util.DictKey) else str(k)
            for k in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def save_tree(path: str, tree: PyTree) -> None:
    flat = _flatten(tree)
    np.savez(path, **flat)


def restore_tree(path: str, like: PyTree,
                 put: Callable[[np.ndarray, str], Any] | None = None
                 ) -> PyTree:
    """Rebuild ``like``-structured tree from ``path``.

    ``put(array, key)`` converts each numpy array (e.g. device_put with a
    sharding); default returns jnp arrays.
    """
    data = np.load(path)
    leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for p, leaf in leaves_paths:
        key = _SEP.join(
            str(k.key) if isinstance(k, jax.tree_util.DictKey) else str(k)
            for k in p)
        arr = data[key]
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        out.append(put(arr, key) if put else jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    """Directory layout::

        <root>/step_<n>/proc_<i>.npz
        <root>/step_<n>/manifest.json
    """

    def __init__(self, root: str, *, keep_n: int = 3):
        self.root = root
        self.keep_n = keep_n
        os.makedirs(root, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._pi = jax.process_index()

    # ------------------------------------------------------------------
    def latest_step(self) -> int | None:
        steps = []
        for name in os.listdir(self.root):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(
                    os.path.join(self.root, name, "manifest.json")):
                steps.append(int(m.group(1)))
        return max(steps) if steps else None

    def _dir(self, step: int, tmp: bool = False) -> str:
        return os.path.join(self.root, f"step_{step}" + (".tmp" if tmp else ""))

    # ------------------------------------------------------------------
    def save(self, state: PyTree, step: int, *, blocking: bool = True) -> None:
        """Snapshot to host, then write (optionally on a background thread)."""
        self.wait()                      # one in-flight async save at a time
        flat = _flatten(state)           # d2h on caller's thread
        shapes = {k: [list(v.shape), str(v.dtype)] for k, v in flat.items()}

        def write():
            tmp = self._dir(step, tmp=True)
            final = self._dir(step)
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, f"proc_{self._pi}.npz"), **flat)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump({"step": step, "shapes": shapes}, f)
            if os.path.isdir(final):      # re-save of the same step
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(
            int(m.group(1)) for name in os.listdir(self.root)
            if (m := re.fullmatch(r"step_(\d+)", name)))
        for s in steps[:-self.keep_n] if self.keep_n else []:
            shutil.rmtree(self._dir(s), ignore_errors=True)

    # ------------------------------------------------------------------
    def restore(self, like: PyTree, *, step: int | None = None,
                shardings: PyTree | None = None) -> tuple[PyTree, int]:
        """Restore into the current mesh (elastic re-shard).

        ``shardings``: optional tree of NamedShardings matching ``like``;
        arrays are device_put under them, regardless of the saving mesh.
        """
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoint under {self.root}")
        path = os.path.join(self._dir(step), f"proc_{self._pi}.npz")

        if shardings is None:
            return restore_tree(path, like), step

        flat_sh = jax.tree_util.tree_flatten_with_path(shardings)[0]
        sh_by_key = {
            _SEP.join(str(k.key) if isinstance(k, jax.tree_util.DictKey)
                      else str(k) for k in p): s
            for p, s in flat_sh}

        def put(arr, key):
            return jax.device_put(arr, sh_by_key[key])

        return restore_tree(path, like, put=put), step
