"""Optimizer built from scratch (no optax dependency)."""
from .adamw import (
    OptConfig,
    adamw_update,
    global_norm,
    init_opt_state,
    lr_schedule,
)

__all__ = ["OptConfig", "init_opt_state", "adamw_update", "lr_schedule",
           "global_norm"]
