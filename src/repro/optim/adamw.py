"""AdamW with decoupled weight decay, global-norm clipping and a
warmup+cosine schedule — implemented from scratch in pure JAX.

Optimizer moments are fp32 and inherit the parameter sharding (ZeRO-1
falls out of FSDP: each device holds the moments of its param shard).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    min_lr_ratio: float = 0.1
    warmup_steps: int = 100
    decay_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def lr_schedule(step: jax.Array, cfg: OptConfig) -> jax.Array:
    """Linear warmup then cosine decay to ``min_lr_ratio``·peak."""
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(1, cfg.warmup_steps)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.decay_steps - cfg.warmup_steps), 0.0, 1.0)
    floor = cfg.peak_lr * cfg.min_lr_ratio
    cos = floor + (cfg.peak_lr - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params: Params) -> Params:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def global_norm(tree: Params) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def _decay_mask(path) -> bool:
    """No weight decay on norms, biases, gates, 1-D params."""
    names = [str(k.key) for k in path
             if isinstance(k, jax.tree_util.DictKey)]
    leaf = names[-1] if names else ""
    if leaf in ("b", "scale", "bias", "xgate", "lam", "conv_b"):
        return False
    parent = names[-2] if len(names) > 1 else ""
    if parent in ("ln1", "ln2", "lnx", "norm", "final_norm", "enc_norm",
                  "head_norm"):
        return False
    return True


def adamw_update(
    grads: Params,
    opt_state: Params,
    params: Params,
    step: jax.Array,
    cfg: OptConfig,
) -> tuple[Params, Params, dict[str, jax.Array]]:
    """One AdamW step; returns (new_params, new_opt_state, metrics)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = lr_schedule(step, cfg)
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(path, g, m, v, p):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        if _decay_mask(path):
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
        return new_p, m, v

    triples = jax.tree_util.tree_map_with_path(
        upd, grads, opt_state["m"], opt_state["v"], params)
    new_params = jax.tree.map(lambda t3: t3[0], triples,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t3: t3[1], triples,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t3: t3[2], triples,
                         is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v}, metrics
