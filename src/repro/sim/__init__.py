"""Tile-level schedule IR + discrete-event DMA/engine simulator.

The planner's objective (``max(compute_time, transfer_time)`` per
segment) is a closed-form *claim* about overlap: double-buffered DMA
hides behind compute.  This package makes that claim falsifiable — and
unlocks the paper's cluster+NPU overlap regime — by lowering a plan into
an explicit per-tile-step event timeline and replaying it:

* :mod:`repro.sim.schedule` lowers a
  :class:`~repro.core.ftl.plan.TilePlan` /
  :class:`~repro.core.ftl.partition.ChainPlan` /
  :class:`~repro.core.ftl.registry.BlockPlan` into a :class:`Schedule`:
  one ``DmaIn`` per tensor re-fetch (the cost model's revisit rule,
  event by event), a per-engine ``Compute`` chain per tile step, one
  ``DmaOut`` per completed output block, and per-step ``Comm`` chunks
  for a segment's collectives — buffer slots from the fast level's
  ``buffer_depth``, tensor homes from ``cost.evaluate``'s per-level
  assignment, engines from the op-kind → ``hw.Engine`` map.
* :mod:`repro.sim.des` replays a schedule respecting buffer-slot
  hazards, DMA serialization per *port* (all memory tiers share the
  default port — the single fast-level DMA — while collective traffic
  runs on the interconnect's own port and genuinely overlaps), and
  per-engine concurrency, reporting simulated runtime, per-resource
  busy/stall time and overlap efficiency.
* :mod:`repro.sim.report` compares simulated against analytic runtime
  and renders event timelines (``benchmarks/bench_schedule.py`` turns
  the comparison into a CI gate).

The simulated runtime is always ≥ the analytic modeled runtime (both
charge identical total DMA and engine busy time; the DES adds only real
serialization) and converges to it when the pipeline is deep enough for
fill/drain to amortize — ``tests/test_sim.py`` pins both directions.
"""
from repro.core.hw import Engine  # noqa: F401  (re-export: sim's engine model)

from .des import ChainSimResult, SimResult, port_key, simulate, simulate_chain
from .engine import step_compute_chain
from .report import (
    chain_timeline,
    compare_plan,
    sim_rows,
    timeline,
    to_chrome_trace,
    write_chrome_trace,
)
from .schedule import (
    Comm,
    Compute,
    DmaIn,
    DmaOut,
    Schedule,
    lower_block,
    lower_chain,
    lower_plan,
)

__all__ = [
    "Engine",
    "Schedule", "DmaIn", "Compute", "DmaOut", "Comm", "port_key",
    "lower_plan", "lower_chain", "lower_block",
    "SimResult", "ChainSimResult", "simulate", "simulate_chain",
    "step_compute_chain",
    "compare_plan", "sim_rows", "timeline", "chain_timeline",
    "to_chrome_trace", "write_chrome_trace",
]
