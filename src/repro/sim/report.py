"""Simulated-vs-analytic reporting for lowered schedules.

Two consumers: ``benchmarks/bench_schedule.py`` (JSON rows + the CI
fused-≤-unfused gate) and humans (``timeline`` renders the first steps
of a replay as an event table — the README's "Simulating a schedule"
example).
"""
from __future__ import annotations

from typing import Sequence

from .des import ChainSimResult, simulate, simulate_chain
from .schedule import Compute, DmaIn, Schedule, lower_chain


def compare_plan(chain) -> dict:
    """Lower + replay a :class:`~repro.core.ftl.partition.ChainPlan`
    (or a ``BlockPlan`` via its ``.chain``) and compare against the
    analytic model.  Returns a JSON-ready dict."""
    chain = getattr(chain, "chain", chain)
    lowered = lower_chain(chain)
    sim = simulate_chain(lowered)
    return {
        "target": chain.target.name,
        "schedule": chain.schedule,
        "analytic_runtime_ms": 1e3 * chain.modeled_runtime_s,
        "sim_runtime_ms": 1e3 * sim.runtime_s,
        "sim_over_analytic": sim.sim_over_analytic,
        "overlap_efficiency": sim.overlap_efficiency,
        "busy_ms": {r: 1e3 * b for r, b in sim.busy_s.items()},
        "segments": [
            {
                "name": s.name,
                "repeat": rep,
                "n_steps": s.n_steps,
                "n_events": len(s.events),
                "analytic_runtime_ms": 1e3 * s.modeled_runtime_s,
                "sim_runtime_ms": 1e3 * r.runtime_s,
                "sim_over_analytic": r.sim_over_analytic,
                "overlap_efficiency": r.overlap_efficiency,
                "stall_ms": {k: 1e3 * v for k, v in r.stall_s.items()},
            }
            for (s, rep), (r, _) in zip(lowered, sim.segments)
        ],
    }


def sim_rows(chains: Sequence) -> list[dict]:
    """``compare_plan`` over several chains (one row each)."""
    return [compare_plan(c) for c in chains]


def _fmt_t(t: float) -> str:
    if t >= 1e-3:
        return f"{1e3 * t:8.3f}ms"
    return f"{1e6 * t:8.2f}us"


def timeline(schedule: Schedule, *, max_steps: int = 4) -> str:
    """Render the replayed event timeline of the first ``max_steps``
    tile steps (plus the schedule's tail) as an aligned text table."""
    res = simulate(schedule, trace=True)
    lines = [
        f"schedule '{schedule.name}' on {schedule.target.name}: "
        f"{schedule.n_steps} steps, depth {schedule.buffer_depth}, "
        f"{len(schedule.events)} events",
        f"simulated {_fmt_t(res.runtime_s).strip()} vs analytic "
        f"{_fmt_t(res.analytic_runtime_s).strip()} "
        f"(x{res.sim_over_analytic:.3f}, overlap eff "
        f"{res.overlap_efficiency:.2f})",
        f"{'start':>10} {'finish':>10}  {'step':>4}  event",
    ]
    tail = 0
    for ev, start, finish in res.trace:
        if ev.step >= max_steps and ev.step < schedule.n_steps - 1:
            tail += 1
            continue
        if tail:
            lines.append(f"{'...':>10} {'':>10}  {tail} events elided")
            tail = 0
        if isinstance(ev, DmaIn):
            desc = (f"DmaIn   {ev.tensor} <- {ev.level} "
                    f"({ev.bytes} B, fetch {ev.fetch}, slot {ev.slot})")
        elif isinstance(ev, Compute):
            desc = f"Compute [{ev.engine}] {'+'.join(ev.ops)}"
        else:
            desc = (f"DmaOut  {ev.tensor} -> {ev.level} "
                    f"({ev.bytes} B, block {ev.block}, slot {ev.slot})")
        lines.append(f"{_fmt_t(start)} {_fmt_t(finish)}  {ev.step:>4}  "
                     f"{desc}")
    return "\n".join(lines)


def chain_timeline(chain, *, max_steps: int = 4) -> str:
    """``timeline`` for every segment of a chain plan."""
    chain = getattr(chain, "chain", chain)
    parts = []
    for sched, rep in lower_chain(chain):
        head = f"[x{rep}] " if rep > 1 else ""
        parts.append(head + timeline(sched, max_steps=max_steps))
    return "\n\n".join(parts)


__all__ = ["compare_plan", "sim_rows", "timeline", "chain_timeline",
           "ChainSimResult"]
