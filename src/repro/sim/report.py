"""Simulated-vs-analytic reporting for lowered schedules.

Two consumers: ``benchmarks/bench_schedule.py`` (JSON rows + the CI
fused-≤-unfused gate) and humans (``timeline`` renders the first steps
of a replay as an event table — the README's "Simulating a schedule"
example).
"""
from __future__ import annotations

from typing import Sequence

from .des import ChainSimResult, port_key, simulate, simulate_chain
from .schedule import Comm, Compute, DmaIn, Schedule, lower_chain


def compare_plan(chain) -> dict:
    """Lower + replay a :class:`~repro.core.ftl.partition.ChainPlan`
    (or a ``BlockPlan`` via its ``.chain``) and compare against the
    analytic model.  Returns a JSON-ready dict."""
    chain = getattr(chain, "chain", chain)
    lowered = lower_chain(chain)
    sim = simulate_chain(lowered)
    return {
        "target": chain.target.name,
        "schedule": chain.schedule,
        "analytic_runtime_ms": 1e3 * chain.modeled_runtime_s,
        "sim_runtime_ms": 1e3 * sim.runtime_s,
        "sim_over_analytic": sim.sim_over_analytic,
        "overlap_efficiency": sim.overlap_efficiency,
        "busy_ms": {r: 1e3 * b for r, b in sim.busy_s.items()},
        "segments": [
            {
                "name": s.name,
                "repeat": rep,
                "n_steps": s.n_steps,
                "n_events": len(s.events),
                "analytic_runtime_ms": 1e3 * s.modeled_runtime_s,
                "sim_runtime_ms": 1e3 * r.runtime_s,
                "sim_over_analytic": r.sim_over_analytic,
                "overlap_efficiency": r.overlap_efficiency,
                "stall_ms": {k: 1e3 * v for k, v in r.stall_s.items()},
            }
            for (s, rep), (r, _) in zip(lowered, sim.segments)
        ],
    }


def sim_rows(chains: Sequence) -> list[dict]:
    """``compare_plan`` over several chains (one row each)."""
    return [compare_plan(c) for c in chains]


def _fmt_t(t: float) -> str:
    if t >= 1e-3:
        return f"{1e3 * t:8.3f}ms"
    return f"{1e6 * t:8.2f}us"


def timeline(schedule: Schedule, *, max_steps: int = 4) -> str:
    """Render the replayed event timeline of the first ``max_steps``
    tile steps (plus the schedule's tail) as an aligned text table."""
    res = simulate(schedule, trace=True)
    lines = [
        f"schedule '{schedule.name}' on {schedule.target.name}: "
        f"{schedule.n_steps} steps, depth {schedule.buffer_depth}, "
        f"{len(schedule.events)} events",
        f"simulated {_fmt_t(res.runtime_s).strip()} vs analytic "
        f"{_fmt_t(res.analytic_runtime_s).strip()} "
        f"(x{res.sim_over_analytic:.3f}, overlap eff "
        f"{res.overlap_efficiency:.2f})",
        f"{'start':>10} {'finish':>10}  {'step':>4}  event",
    ]
    tail = 0
    for ev, start, finish in res.trace:
        if ev.step >= max_steps and ev.step < schedule.n_steps - 1:
            tail += 1
            continue
        if tail:
            lines.append(f"{'...':>10} {'':>10}  {tail} events elided")
            tail = 0
        if isinstance(ev, DmaIn):
            desc = (f"DmaIn   {ev.tensor} <- {ev.level} "
                    f"({ev.bytes} B, fetch {ev.fetch}, slot {ev.slot})")
        elif isinstance(ev, Compute):
            desc = f"Compute [{ev.engine}] {'+'.join(ev.ops)}"
        elif isinstance(ev, Comm):
            arrow = "<-" if ev.pre else "->"
            desc = (f"Comm    {ev.op} {arrow} {ev.level} "
                    f"({ev.comm}, {ev.bytes} B)")
        else:
            desc = (f"DmaOut  {ev.tensor} -> {ev.level} "
                    f"({ev.bytes} B, block {ev.block}, slot {ev.slot})")
        lines.append(f"{_fmt_t(start)} {_fmt_t(finish)}  {ev.step:>4}  "
                     f"{desc}")
    return "\n".join(lines)


def chain_timeline(chain, *, max_steps: int = 4) -> str:
    """``timeline`` for every segment of a chain plan."""
    chain = getattr(chain, "chain", chain)
    parts = []
    for sched, rep in lower_chain(chain):
        head = f"[x{rep}] " if rep > 1 else ""
        parts.append(head + timeline(sched, max_steps=max_steps))
    return "\n\n".join(parts)


def to_chrome_trace(chain, *, measured=None, pid: int = 0) -> dict:
    """Replay a chain (or ``BlockPlan``, or a single :class:`Schedule`)
    and export the event timeline as Chrome-tracing JSON — loadable in
    Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``.

    One track (tid) per resource: ``dma`` (plus ``dma:<port>`` for
    interconnect-port collective streams) and one per engine.  Segments
    are laid out sequentially (each repeated segment is traced once; its
    remaining repeats are summarized by a counter in the event args).
    Timestamps/durations are microseconds, the format's native unit.

    ``measured`` adds a second **measured** track for calibration
    eyeballing: each entry — a ``repro.calib.Measurement`` or a plain
    ``(name, seconds)`` pair — is rendered as one span, laid out
    sequentially from t=0 alongside the simulated tracks, so the
    modeled-vs-measured residual is literally the length mismatch
    between the tracks in Perfetto.

    ``pid`` sets the Chrome-tracing process id of every emitted event,
    so callers merging this timeline with other event sources
    (``repro.obs.export.merged_chrome_trace`` puts live runtime spans on
    their own pid) get disjoint track namespaces.
    """
    if isinstance(chain, Schedule):
        lowered: tuple = ((chain, 1),)
        name = chain.name
        target = chain.target
    else:
        chain = getattr(chain, "chain", chain)
        lowered = lower_chain(chain)
        name = chain.graph.name
        target = chain.target

    tids: dict[str, int] = {"dma": 0}
    events: list[dict] = []
    t0 = 0.0
    for sched, rep in lowered:
        res = simulate(sched, trace=True)
        ports = {lv.name: lv.dma_port for lv in sched.target.backing}
        for ev, start, finish in res.trace:
            if isinstance(ev, DmaIn):
                track, nm = "dma", f"in:{ev.tensor}"
                args = {"step": ev.step, "bytes": ev.bytes,
                        "fetch": ev.fetch, "slot": ev.slot,
                        "level": ev.level}
            elif isinstance(ev, Compute):
                track, nm = f"engine:{ev.engine}", "+".join(ev.ops)
                args = {"step": ev.step}
            elif isinstance(ev, Comm):
                track = port_key(ports[ev.level])
                nm = f"{ev.comm}:{ev.op}"
                args = {"step": ev.step, "bytes": ev.bytes,
                        "level": ev.level, "pre": ev.pre}
            else:
                track, nm = "dma", f"out:{ev.tensor}"
                args = {"step": ev.step, "bytes": ev.bytes,
                        "block": ev.block, "slot": ev.slot,
                        "level": ev.level}
            tid = tids.setdefault(track, len(tids))
            args["segment"] = sched.name
            if rep > 1:
                args["repeat"] = rep
            events.append({
                "name": nm, "ph": "X", "pid": pid, "tid": tid,
                "ts": 1e6 * (t0 + start),
                "dur": 1e6 * (finish - start),
                "cat": track.split(":")[0],
                "args": args,
            })
        t0 += res.runtime_s * rep
    if measured:
        tid = tids.setdefault("measured", len(tids))
        tm = 0.0
        for entry in measured:
            if hasattr(entry, "measured_s"):     # calib.Measurement
                nm, secs = entry.name, float(entry.measured_s)
                args = {"kind": getattr(entry, "kind", "measured")}
            else:
                nm, secs = entry[0], float(entry[1])
                args = {}
            events.append({
                "name": nm, "ph": "X", "pid": pid, "tid": tid,
                "ts": 1e6 * tm, "dur": 1e6 * secs,
                "cat": "measured",
                "args": {**args, "measured_ms": 1e3 * secs,
                         "modeled_ms": 1e3 * t0},
            })
            tm += secs
    meta = [
        {"name": "process_name", "ph": "M", "pid": pid,
         "args": {"name": f"{name} on {target.name}"}},
    ] + [
        {"name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
         "args": {"name": track}}
        for track, tid in sorted(tids.items(), key=lambda kv: kv[1])
    ]
    return {"traceEvents": meta + events, "displayTimeUnit": "ms"}


def write_chrome_trace(chain, path, *, measured=None) -> None:
    """``to_chrome_trace`` serialized to ``path``."""
    import json

    with open(path, "w") as f:
        json.dump(to_chrome_trace(chain, measured=measured), f)


__all__ = ["compare_plan", "sim_rows", "timeline", "chain_timeline",
           "to_chrome_trace", "write_chrome_trace", "ChainSimResult"]
