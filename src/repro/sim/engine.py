"""Engine-side lowering helpers: op chain → per-step compute events.

The :class:`repro.core.hw.Engine` model itself lives in ``core/hw.py``
(the :class:`~repro.core.hw.Target` carries it into every plan-cache
key); this module owns the *schedule-side* view: how one tile step's
arithmetic splits into a chain of per-engine compute events.

``cost.evaluate`` prices each op on the engine its kind maps to
(``Target.engine_rate``) and records the per-op seconds in
``CostReport.op_compute``.  The lowering distributes each op's seconds
uniformly over the grid's tile steps — total engine busy time is exactly
the analytic per-engine compute time, so the simulator's floor matches
the planner's — and merges adjacent same-engine ops into one event.  The
chain order is the op (data-dependency) order: within a step the cluster
GeLU waits for the NPU GEMM, but the NPU is then free for step ``s+1``
while the cluster grinds step ``s`` — the software pipeline that makes
the paper's fused NPU+cluster schedule overlap.
"""
from __future__ import annotations

from repro.core.ftl.cost import CostReport, OpCompute


def engine_groups(
    report: CostReport,
) -> tuple[tuple[str, tuple[OpCompute, ...]], ...]:
    """The step chain's structure: adjacent same-engine ops merged into
    ``(engine, ops)`` groups, op (data-dependency) order preserved.  One
    grouping serves every tile step; only the per-step seconds vary (for
    edge tiles of non-divisor shapes)."""
    groups: list[tuple[str, tuple[OpCompute, ...]]] = []
    for oc in report.op_compute:
        if groups and groups[-1][0] == oc.engine:
            eng, ocs = groups[-1]
            groups[-1] = (eng, ocs + (oc,))
        else:
            groups.append((oc.engine, (oc,)))
    return tuple(groups)


def step_compute_chain(
    report: CostReport,
) -> tuple[tuple[str, float, tuple[str, ...]], ...]:
    """Per-tile-step compute chain of a solved assignment.

    Returns ``(engine, seconds_per_step, op_names)`` tuples in op order,
    adjacent same-engine ops merged.  ``Σ seconds · n_steps`` equals the
    analytic per-engine compute time (up to float rounding).  Uniform
    over steps — exact for divisor tiles; the schedule lowering
    (``repro.sim.schedule``) weights each step by its actual edge-tile
    work via :func:`engine_groups` instead when the grid has remainder
    tiles.
    """
    steps = report.n_steps
    return tuple(
        (eng, sum(oc.seconds / steps for oc in ocs),
         tuple(oc.name for oc in ocs))
        for eng, ocs in engine_groups(report)
    )
