"""Schedule IR: a plan lowered to explicit per-tile-step events.

A :class:`Schedule` is the execution timeline the planner's closed-form
cost model *implies*, made explicit (LoopTree-style): the solved grid is
walked step by step (outer→inner, exactly the cost model's order) and
every data movement and compute becomes one event:

* :class:`DmaIn` — a streamed INPUT/WEIGHT tile copied from its home
  backing level into a fast-memory buffer slot.  Emitted exactly when
  the cost model's revisit rule says the tile must be (re)fetched: the
  grid coordinates at positions outer than (or at) the tensor's
  innermost grid dim form a *fetch key*; a new key is a new fetch.  The
  per-tensor fetch count therefore reproduces
  ``CostReport.per_tensor_traffic`` / ``dma_transfers`` event by event.
* :class:`Compute` — one entry of the per-engine compute chain of a
  step (:func:`repro.sim.engine.step_compute_chain`): ops priced on the
  engine their kind maps to, chained in data-dependency order within
  the step, pipelined across steps.
* :class:`DmaOut` — a completed output block written back to its home
  level (outputs accumulate in fast memory and are written once per
  block, at the last step that touches the block).
* :class:`Comm` — one step's chunk of a collective's wire traffic
  (``CostReport.collectives``), spread evenly over the steps and
  replayed on the interconnect level's *own* DMA port, so ici/noc
  streams overlap the hbm/L2 traffic in the replay exactly as the
  max-over-ports analytic model prices them.

Buffer slots come from each tensor's *staging depth* —
``max(fast.buffer_depth, home.buffer_depth)``, the backing-level-aware
charge of ``cost.staging_depths`` (equal to the fast level's depth on
every stock target): fetch ``k`` of a tensor occupies slot
``k mod depth``, so depth 1 serializes load and compute while depth ≥ 2
lets the DMA run ahead — the hazard the discrete-event simulator
(:mod:`repro.sim.des`) enforces per tensor.

Edge tiles are exact: on a non-divisor dim the remainder step's DMA
bytes and compute seconds are scaled to the actual tile extent, so the
events sum to the cost model's totals (``bytes_full × revisit``,
full-size FLOPs) event by event instead of overcounting the edge.

Multiplicity (per-head attention segments) is not unrolled: a segment is
lowered once and its simulated runtime scales by ``Segment.repeat``,
mirroring the analytic model.
"""
from __future__ import annotations

import dataclasses
from typing import Union

from repro.core import hw as hwlib
from repro.core.ftl.ir import Role, dtype_bytes
from repro.core.ftl.partition import ChainPlan
from repro.core.ftl.plan import TilePlan

from .engine import engine_groups, step_compute_chain


@dataclasses.dataclass(frozen=True)
class DmaIn:
    """Fetch ``tensor``'s current tile from ``level`` into slot ``slot``."""

    step: int
    tensor: str
    level: str
    bytes: int
    fetch: int            # 0-based fetch index of this tensor
    slot: int             # fetch % the tensor's staging depth


@dataclasses.dataclass(frozen=True)
class Compute:
    """One engine's share of tile step ``step`` (chained in op order)."""

    step: int
    engine: str
    seconds: float
    ops: tuple[str, ...]
    seq: int              # position in the step's compute chain


@dataclasses.dataclass(frozen=True)
class DmaOut:
    """Write completed output block ``block`` of ``tensor`` to ``level``."""

    step: int
    tensor: str
    level: str
    bytes: int
    block: int            # 0-based completion index of this tensor
    slot: int             # block % the tensor's staging depth


@dataclasses.dataclass(frozen=True)
class Comm:
    """Tile step ``step``'s chunk of a collective's wire traffic.

    A segment's collectives (``CostReport.collectives``) move a fixed
    payload per segment run; the lowering spreads it evenly over the
    grid's tile steps (exact integer split — chunks sum to the analytic
    bytes/transfer totals) so the DES can interleave the link stream
    with the per-step memory DMA on its *own* port.  ``pre`` chunks feed
    step ``step``'s compute like a prefetch (the operand streamed in);
    post chunks start behind the in-segment compute that produced the
    operand (``after_op``), and when the reduced output is consumed
    later in the same segment (``blocking``) the rest of that step's
    chain waits for the wire — fusing across a collective costs real
    serialization per tile, hidden only by the cross-step pipeline.
    ``setups`` is this chunk's share of the ring messages (most chunks
    carry 0 — there are far fewer ring steps than tiles)."""

    step: int
    op: str               # CollectiveNode name (e.g. 'comm.proj.wo')
    comm: str             # all_gather | reduce_scatter | all_reduce
    level: str            # interconnect level (ici / noc)
    bytes: int
    setups: int
    pre: bool
    after_op: str = ""    # in-segment producer op ("" when streamed)
    blocking: bool = False  # output consumed later in the segment


Event = Union[DmaIn, Compute, DmaOut, Comm]


@dataclasses.dataclass(frozen=True)
class Schedule:
    """A lowered segment: events in program order + analytic reference."""

    name: str
    target: hwlib.Target
    n_steps: int
    buffer_depth: int
    events: tuple[Event, ...]
    # analytic reference (from the CostReport that produced the plan)
    compute_time_s: float
    transfer_time_s: float
    modeled_runtime_s: float
    per_engine_compute_s: dict[str, float]
    per_level_traffic: dict[str, int]
    # per-tensor staging depth (max(fast.depth, home.depth) — see
    # cost.staging_depths); tensors not named fall back to buffer_depth.
    tensor_depths: dict[str, int] = dataclasses.field(default_factory=dict)

    def dma_events(self) -> list[Union[DmaIn, DmaOut]]:
        return [e for e in self.events
                if not isinstance(e, (Compute, Comm))]

    def compute_events(self) -> list[Compute]:
        return [e for e in self.events if isinstance(e, Compute)]

    def comm_events(self) -> list[Comm]:
        return [e for e in self.events if isinstance(e, Comm)]


def _unflatten(s: int, counts: list[int]) -> tuple[int, ...]:
    """Flat step index → grid coordinates, outer→inner."""
    coords = [0] * len(counts)
    for i in range(len(counts) - 1, -1, -1):
        s, coords[i] = divmod(s, counts[i])
    return tuple(coords)


def lower_plan(plan: TilePlan, name: str | None = None) -> Schedule:
    """Lower one solved :class:`TilePlan` into its :class:`Schedule`."""
    rep = plan.report
    target = plan.target
    depth = target.fast.buffer_depth
    dims = [d for d, _ in rep.grid]
    counts = [c for _, c in rep.grid]
    steps = rep.n_steps
    group = plan.group

    streamed = group.hbm_tensors()
    ins = [t for t in streamed if t.role in (Role.INPUT, Role.WEIGHT)]
    outs = [t for t in streamed if t.role is Role.OUTPUT]
    homes = rep.tensor_homes
    tdepth = {t.name: rep.tensor_depths.get(t.name, depth)
              for t in streamed}

    # Edge-tile geometry: on a non-divisor dim the last tile is the
    # remainder, so per-event bytes and per-step compute are weighted by
    # the *actual* tile extent at the step's coordinates — the events
    # then sum exactly to the cost model's totals (which already price
    # ``bytes_full × revisit`` / full-size FLOPs), where uniform
    # full-tile charges would overcount every remainder step.
    sizes = {d: plan.constraints[d].size for d in plan.constraints}
    gtile = [min(plan.tiles[d], sizes[d]) for d in dims]
    exact = all(sizes[d] % gtile[i] == 0 for i, d in enumerate(dims))
    pos_of = {d: i for i, d in enumerate(dims)}

    def _extent(i: int, c: int) -> int:
        return min(gtile[i], sizes[dims[i]] - c * gtile[i])

    def _tile_bytes(t, coords) -> int:
        n = dtype_bytes(t.dtype)
        for d in t.dims:
            i = pos_of.get(d)
            n *= _extent(i, coords[i]) if i is not None \
                else min(plan.tiles[d], sizes[d])
        return n

    # Fetch key of an in-tensor = grid positions ≤ its innermost grid
    # dim — a *prefix* of the (outer→inner) coordinate tuple, since every
    # grid dim of the tensor sits at or above its innermost one.  The
    # cost model's revisit product over exactly these positions is then
    # literally the number of key changes along the walk.
    def _prefix_len(t) -> int:
        inner = -1
        for i, d in enumerate(dims):
            if d in t.dims:
                inner = i
        return inner + 1

    in_prefix = {t.name: _prefix_len(t) for t in ins}
    out_pos = {t.name: [i for i, d in enumerate(dims) if d in t.dims]
               for t in outs}

    # Last step touching each output block (outputs accumulate in fast
    # memory; the write-back happens when the block is complete).
    last_touch: dict[str, dict[tuple[int, ...], int]] = {
        t.name: {} for t in outs}
    for s in range(steps):
        coords = _unflatten(s, counts)
        for t in outs:
            key = tuple(coords[i] for i in out_pos[t.name])
            last_touch[t.name][key] = s

    # Per-step compute chain.  Divisor grids use the uniform chain
    # (bit-identical to the pre-edge-tile lowering); remainder grids
    # weight each op's seconds by the fraction of its work the step's
    # actual tile extents cover — an op's work dims are its output dims
    # plus its contract dims (exactly OpNode.flops' factors), any other
    # grid dim splits the op's work evenly.
    uniform = step_compute_chain(rep) if exact else None
    groups = engine_groups(rep)
    work_dims = {op.name: set(op.output.dims) | set(op.contract_dims())
                 for op in group.ops}

    def _chain_at(coords) -> tuple[tuple[str, float, tuple[str, ...]], ...]:
        if uniform is not None:
            return uniform
        out = []
        for engine, ocs in groups:
            secs = 0.0
            for oc in ocs:
                w = 1.0
                for i, d in enumerate(dims):
                    if d in work_dims[oc.name]:
                        w *= _extent(i, coords[i]) / sizes[d]
                    else:
                        w *= 1.0 / counts[i]
                secs += oc.seconds * w
            out.append((engine, secs, tuple(oc.name for oc in ocs)))
        return tuple(out)

    # Collective wire chunks: each CollectiveCost's payload split evenly
    # over the tile steps (exact integer split), interleaved with the
    # step's memory DMA so the DES can overlap the two ports.
    def _chunks(total: int) -> list[int]:
        base, rem = divmod(total, steps)
        return [base + (1 if s < rem else 0) for s in range(steps)]

    comm_chunks = [
        (cc, _chunks(cc.bytes), _chunks(cc.transfers))
        for cc in rep.collectives
    ]

    events: list[Event] = []
    prev_key: dict[str, tuple[int, ...]] = {}
    fetch_n = {t.name: 0 for t in ins}
    block_n = {t.name: 0 for t in outs}
    for s in range(steps):
        coords = _unflatten(s, counts)
        for cc, bts, sps in comm_chunks:
            if cc.pre and (bts[s] or sps[s]):
                events.append(Comm(
                    step=s, op=cc.name, comm=cc.comm, level=cc.level,
                    bytes=bts[s], setups=sps[s], pre=True))
        for t in ins:
            key = coords[: in_prefix[t.name]]
            if prev_key.get(t.name) != key:
                prev_key[t.name] = key
                f = fetch_n[t.name]
                fetch_n[t.name] = f + 1
                events.append(DmaIn(
                    step=s, tensor=t.name, level=homes[t.name],
                    bytes=_tile_bytes(t, coords), fetch=f,
                    slot=f % tdepth[t.name]))
        for seq, (engine, secs, op_names) in enumerate(_chain_at(coords)):
            events.append(Compute(step=s, engine=engine, seconds=secs,
                                  ops=op_names, seq=seq))
        for t in outs:
            key = tuple(coords[i] for i in out_pos[t.name])
            if last_touch[t.name][key] == s:
                b = block_n[t.name]
                block_n[t.name] = b + 1
                events.append(DmaOut(
                    step=s, tensor=t.name, level=homes[t.name],
                    bytes=_tile_bytes(t, coords), block=b,
                    slot=b % tdepth[t.name]))
        for cc, bts, sps in comm_chunks:
            if not cc.pre and (bts[s] or sps[s]):
                events.append(Comm(
                    step=s, op=cc.name, comm=cc.comm, level=cc.level,
                    bytes=bts[s], setups=sps[s], pre=False,
                    after_op=cc.producer, blocking=cc.blocking))

    return Schedule(
        name=name or group.name,
        target=target,
        n_steps=steps,
        buffer_depth=depth,
        events=tuple(events),
        compute_time_s=rep.compute_time_s,
        transfer_time_s=rep.transfer_time_s,
        modeled_runtime_s=rep.modeled_runtime_s,
        per_engine_compute_s=dict(rep.per_engine_compute_s),
        per_level_traffic=dict(rep.per_level_traffic),
        tensor_depths=tdepth,
    )


def lower_chain(chain: ChainPlan) -> tuple[tuple[Schedule, int], ...]:
    """Lower every segment of a :class:`ChainPlan`; returns
    ``(schedule, repeat)`` pairs in execution order."""
    return tuple(
        (lower_plan(s.plan, name=f"{chain.graph.name}[{s.lo}:{s.hi}]"),
         s.repeat)
        for s in chain.segments
    )


def lower_block(block_plan) -> tuple[tuple[Schedule, int], ...]:
    """Lower a :class:`~repro.core.ftl.registry.BlockPlan` (its chain)."""
    return lower_chain(block_plan.chain)
