"""Discrete-event replay of a :class:`~repro.sim.schedule.Schedule`.

Resources and hazards:

* **One unit per DMA port** (``MemoryLevel.dma_port``): every
  ``DmaIn``/``DmaOut``/``Comm`` serializes in program order against the
  other transfers on *its level's* port, each priced at that level
  (``bytes/bw + dma_setup``).  All memory tiers share the default
  ``"dma"`` port (Siracusa's single cluster DMA — with one port in
  play this is exactly the old single-cursor replay), while the
  interconnect (ici/noc) runs on its own port, so a collective stream
  overlaps the same segment's memory DMA instead of queueing behind it
  — the max-over-ports analytic model, replayed rather than asserted.
  Busy time is reported as ``'dma'`` for the default port and
  ``'dma:<port>'`` for others; per-level busy time stays separate.
* **One unit per engine**: compute events on the same engine serialize
  (in order); distinct engines overlap.  Within a step the compute
  chain respects op order (the cluster's GeLU waits for the NPU's GEMM
  of the *same* tile), so cross-engine overlap emerges as a software
  pipeline across steps rather than being assumed.
* **Buffer-slot hazards** from ``buffer_depth``: fetch ``k`` of a
  tensor may not start before the last compute consuming fetch
  ``k − depth`` finished (depth 1 ⇒ load/compute serialize; depth ≥ 2 ⇒
  prefetch runs ahead).  Symmetrically, a step may not start while its
  output block's slot still awaits the write-back of block
  ``b − depth``.
* A step's compute waits for every streamed tile it consumes (the
  Pallas/Deeploy contract: all copies for step ``s`` complete before
  the step body runs).

Every event's start time is a ``max`` over its dependencies, so the
event graph is monotone: relaxing any hazard (e.g. a deeper buffer) can
only move times earlier — the property ``tests/test_sim.py`` fuzzes.
The simulated runtime is consequently ≥ the analytic
``max(compute_time, transfer_time)`` (identical total busy time per
resource, plus real serialization) and converges to it once the
pipeline is deep enough to amortize fill/drain.
"""
from __future__ import annotations

import dataclasses

from .schedule import Comm, Compute, DmaIn, Schedule


def port_key(port: str) -> str:
    """Busy-dict key of a DMA port: the default port keeps the legacy
    ``'dma'`` key (every existing report/gate reads it); other ports
    (ici/noc) get ``'dma:<port>'``."""
    return "dma" if port == "dma" else f"dma:{port}"


@dataclasses.dataclass(frozen=True)
class SimResult:
    """Replay outcome of one schedule (one run of one segment)."""

    runtime_s: float
    busy_s: dict[str, float]   # 'dma[:<port>]' + 'engine:<name>' → busy
    per_level_busy_s: dict[str, float]
    analytic_runtime_s: float
    n_events: int
    trace: tuple[tuple[object, float, float], ...] = ()

    @property
    def stall_s(self) -> dict[str, float]:
        """Idle time per resource over the simulated span."""
        return {r: self.runtime_s - b for r, b in self.busy_s.items()}

    @property
    def overlap_efficiency(self) -> float:
        """Busy fraction of the *dominant* resource: 1.0 means the
        bottleneck never idles — the analytic max() was achieved."""
        if self.runtime_s <= 0.0:
            return 1.0
        return max(self.busy_s.values(), default=0.0) / self.runtime_s

    @property
    def sim_over_analytic(self) -> float:
        """Simulated / analytic runtime (≥ 1 up to float rounding)."""
        if self.analytic_runtime_s <= 0.0:
            return 1.0
        return self.runtime_s / self.analytic_runtime_s


@dataclasses.dataclass(frozen=True)
class ChainSimResult:
    """Replay of a whole chain: segments sequential, × multiplicity."""

    segments: tuple[tuple[SimResult, int], ...]   # (result, repeat)

    @property
    def runtime_s(self) -> float:
        return sum(r.runtime_s * rep for r, rep in self.segments)

    @property
    def analytic_runtime_s(self) -> float:
        return sum(r.analytic_runtime_s * rep for r, rep in self.segments)

    @property
    def sim_over_analytic(self) -> float:
        if self.analytic_runtime_s <= 0.0:
            return 1.0
        return self.runtime_s / self.analytic_runtime_s

    @property
    def busy_s(self) -> dict[str, float]:
        out: dict[str, float] = {}
        for r, rep in self.segments:
            for k, v in r.busy_s.items():
                out[k] = out.get(k, 0.0) + v * rep
        return out

    @property
    def overlap_efficiency(self) -> float:
        if self.runtime_s <= 0.0:
            return 1.0
        return max(self.busy_s.values(), default=0.0) / self.runtime_s


def simulate(
    schedule: Schedule,
    *,
    buffer_depth: int | None = None,
    share_ports: bool = False,
    trace: bool = False,
) -> SimResult:
    """Replay ``schedule``; ``buffer_depth`` overrides the lowered depth
    (same logical schedule, different slot hazards and prefetch
    distance — the depth-sweep hook).  ``share_ports`` replays the same
    events with every transfer serialized on the single default DMA
    cursor — the pre-multi-port model, the counterfactual baseline the
    mesh bench gates overlap wins against (merging cursors only adds
    constraints, so the shared-port replay is always ≥ the split one).

    The schedule's events are in *logical* step order (loads, computes,
    store-backs of step ``s`` together); the DES derives the DMA issue
    order from the depth: the loads for step ``s + depth − 1`` are
    issued before step ``s``'s write-backs, exactly the classic
    ``load(s+1); compute(s); store(s)`` double-buffer loop shape, so a
    transfer-bound pipeline keeps the DMA port saturated instead of
    queueing every prefetch behind the previous step's compute.
    """
    depth = buffer_depth if buffer_depth is not None \
        else schedule.buffer_depth
    if depth < 1:
        raise ValueError(f"buffer_depth must be >= 1, got {depth}")

    def _depth(tensor: str) -> int:
        # per-tensor staging depth (max(fast, home) — the lowering's
        # cost.staging_depths map); an explicit override is uniform,
        # replacing every per-tensor depth — the depth-sweep contract.
        if buffer_depth is not None:
            return buffer_depth
        return schedule.tensor_depths.get(tensor, schedule.buffer_depth)

    steps = schedule.n_steps
    levels = {lv.name: lv for lv in schedule.target.backing}

    comp_by: dict[int, list[Compute]] = {}
    outs_by: dict[int, list] = {}
    pcomm_by: dict[int, list[Comm]] = {}
    for ev in schedule.events:
        if isinstance(ev, Compute):
            comp_by.setdefault(ev.step, []).append(ev)
        elif isinstance(ev, Comm):
            if not ev.pre:
                pcomm_by.setdefault(ev.step, []).append(ev)
        elif not isinstance(ev, DmaIn):
            outs_by.setdefault(ev.step, []).append(ev)

    port_free: dict[str, float] = {}    # one cursor per DMA port
    engine_free: dict[str, float] = {}
    busy: dict[str, float] = {"dma": 0.0}
    level_busy: dict[str, float] = {}
    chain_finish = [0.0] * steps        # per-step compute-chain finish
    use_steps: dict[str, list[int]] = {}   # per in-tensor fetch use-steps
    ready_q: list[tuple[int, float]] = []  # (use_step, DmaIn finish) FIFO
    ready_head = 0
    out_finish: dict[str, list[float]] = {}   # DmaOut finishes per tensor
    out_emitted: dict[str, int] = {}
    last_finish = 0.0
    timeline: list[tuple[object, float, float]] = []

    def _note(ev, start, finish):
        nonlocal last_finish
        last_finish = max(last_finish, finish)
        if trace:
            timeline.append((ev, start, finish))

    def _dma(ev) -> tuple[str, float]:
        lv = levels[ev.level]
        if isinstance(ev, Comm):
            dur = ev.bytes / lv.bw_bytes_per_s + ev.setups * lv.dma_setup_s
        else:
            dur = ev.bytes / lv.bw_bytes_per_s + lv.dma_setup_s
        port = "dma" if share_ports else lv.dma_port
        key = port_key(port)
        busy[key] = busy.get(key, 0.0) + dur
        level_busy[ev.level] = level_busy.get(ev.level, 0.0) + dur
        return port, dur

    def _issue_in(ev) -> None:
        port, dur = _dma(ev)
        start = port_free.get(port, 0.0)
        if isinstance(ev, DmaIn):
            us = use_steps.setdefault(ev.tensor, [])
            us.append(ev.step)
            dt = _depth(ev.tensor)
            if ev.fetch >= dt:
                # slot hazard: this fetch overwrites the buffer that held
                # fetch f−depth, last consumed by the step before fetch
                # f−depth+1 arrived — whose chain is already scheduled
                # (fetch f is issued depth−1 steps ahead of its use at
                # most).
                lu = us[ev.fetch - dt + 1] - 1
                if lu >= 0:
                    start = max(start, chain_finish[lu])
        # pre-Comm chunks have no buffer slot: the link stream lands in
        # the operand's staging buffers like any other prefetch
        finish = start + dur
        port_free[port] = finish
        ready_q.append((ev.step, finish))
        _note(ev, start, finish)

    def _run_step(e: int) -> None:
        nonlocal ready_head
        # chain head: every streamed tile this step consumes is resident
        gate = 0.0
        while ready_head < len(ready_q) and ready_q[ready_head][0] <= e:
            gate = max(gate, ready_q[ready_head][1])
            ready_head += 1
        # ...and the output block's slot has drained its write-back
        for t, n in out_emitted.items():
            dt = _depth(t)
            if n >= dt:
                gate = max(gate, out_finish[t][n - dt])
        prev = gate
        comms = pcomm_by.get(e, [])
        ci = 0

        def _comm(c: Comm, at: float) -> float:
            # post-collective chunk: the reduce of this tile's partial
            # drains on the interconnect port, starting once its
            # producer's compute is done
            port, dur = _dma(c)
            start = max(port_free.get(port, 0.0), at)
            finish = start + dur
            port_free[port] = finish
            _note(c, start, finish)
            return finish

        for ev in comp_by.get(e, ()):
            eng = f"engine:{ev.engine}"
            start = max(engine_free.get(eng, 0.0), prev)
            finish = start + ev.seconds
            engine_free[eng] = finish
            busy[eng] = busy.get(eng, 0.0) + ev.seconds
            prev = finish
            _note(ev, start, finish)
            while ci < len(comms) and comms[ci].after_op in ev.ops:
                f = _comm(comms[ci], prev)
                if comms[ci].blocking:
                    # the reduced value feeds a later op in this chain:
                    # fusing across the collective serializes compute
                    # behind the wire for this tile (the pipeline hides
                    # it across steps, not within one)
                    prev = f
                ci += 1
        for c in comms[ci:]:
            # producer not in this step's chain (tail collective): the
            # chunk gates segment completion only, like a write-back
            _comm(c, prev)
        chain_finish[e] = prev
        for ev in outs_by.get(e, ()):
            port, dur = _dma(ev)
            start = max(port_free.get(port, 0.0), prev)
            finish = start + dur
            port_free[port] = finish
            out_finish.setdefault(ev.tensor, []).append(finish)
            out_emitted[ev.tensor] = out_emitted.get(ev.tensor, 0) + 1
            _note(ev, start, finish)

    # A tensor's fetch for step s is issued at step s − (depth − 1):
    # the prefetch distance its staging depth buys (depth 1 ⇒ issue at
    # the consuming step — load/compute serialize).  With uniform depths
    # this is exactly the classic prologue + steady-state issue loop;
    # per-tensor depths interleave deeper tensors' prefetches earlier.
    issue_at: dict[int, list] = {}
    for ev in schedule.events:
        if isinstance(ev, DmaIn):
            u = max(0, ev.step - (_depth(ev.tensor) - 1))
            issue_at.setdefault(u, []).append(ev)
        elif isinstance(ev, Comm) and ev.pre:
            # an inbound collective chunk prefetches like a streamed
            # tile, at the fast level's pipeline distance
            u = max(0, ev.step - (depth - 1))
            issue_at.setdefault(u, []).append(ev)
    for e in range(steps):
        for ev in issue_at.get(e, ()):
            _issue_in(ev)
        _run_step(e)

    return SimResult(
        runtime_s=last_finish,
        busy_s=busy,
        per_level_busy_s=level_busy,
        analytic_runtime_s=schedule.modeled_runtime_s,
        n_events=len(schedule.events),
        trace=tuple(timeline),
    )


def simulate_chain(
    schedules: tuple[tuple[Schedule, int], ...],
    *,
    buffer_depth: int | None = None,
    share_ports: bool = False,
) -> ChainSimResult:
    """Replay a lowered chain (``repro.sim.schedule.lower_chain`` output):
    segments run sequentially, each simulated once and scaled by its
    multiplicity — mirroring the analytic Σ-over-segments model."""
    return ChainSimResult(segments=tuple(
        (simulate(s, buffer_depth=buffer_depth, share_ports=share_ports),
         rep)
        for s, rep in schedules
    ))
