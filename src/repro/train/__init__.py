"""Training & serving steps: losses, grad-accum train_step, prefill/decode."""
from .losses import cross_entropy
from .steps import (
    TrainState,
    init_train_state,
    make_decode_step,
    make_prefill_step,
    make_train_step,
    train_state_shapes,
)

__all__ = [
    "cross_entropy", "TrainState", "init_train_state", "train_state_shapes",
    "make_train_step", "make_prefill_step", "make_decode_step",
]
