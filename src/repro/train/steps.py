"""Step builders: gradient-accumulation train_step and serve steps.

``make_train_step`` returns an un-jitted pure function plus the sharding
trees needed to pjit it; ``launch/dryrun.py`` lowers it AOT against
ShapeDtypeStructs, ``launch/train.py``/tests jit and run it.

Memory strategy for the big configs (DESIGN.md §6): the global batch is
split into ``accum`` microbatches consumed by ``lax.scan``; each microbatch
runs the remat'd model forward+backward, and fp32 gradients accumulate in
the scan carry (sharded like the params, so grad memory == one fp32 param
copy per device).  Compute/comm overlap: GSPMD overlaps the FSDP
all-gather of layer i+1's params with layer i's compute inside the scanned
layer body; the reduce-scatter of grads overlaps the backward pass.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import compression
from repro.distributed.act_sharding import use_policy
from repro.distributed.sharding import (
    batch_pspecs,
    cache_pspecs,
    dp_axes,
    make_activation_policy,
    param_pspecs,
    to_shardings,
)
from repro.models import model as M
from repro.optim import OptConfig, adamw_update, init_opt_state
from repro.train.losses import cross_entropy

Params = dict[str, Any]


# ===========================================================================
# train state
# ===========================================================================

@dataclasses.dataclass
class TrainState:
    params: Params
    opt: Params                 # {"m": ..., "v": ...}
    step: jax.Array             # int32 scalar
    ef_error: Params | None = None    # error-feedback state (compression)


jax.tree_util.register_dataclass(
    TrainState, data_fields=["params", "opt", "step", "ef_error"],
    meta_fields=[])


def init_train_state(cfg, key, *, compress: bool = False) -> TrainState:
    params = M.init_params(cfg, key)
    state = TrainState(
        params=params,
        opt=init_opt_state(params),
        step=jnp.zeros((), jnp.int32),
        ef_error=compression.init_error(params) if compress else None,
    )
    return state


def train_state_shapes(cfg, *, compress: bool = False):
    return jax.eval_shape(
        functools.partial(init_train_state, cfg, compress=compress),
        jax.random.PRNGKey(0))


def state_pspecs(state_shape, mesh: Mesh, cfg):
    """PartitionSpec tree for a TrainState: moments follow their params."""
    pspec = param_pspecs(state_shape.params, mesh, cfg)
    return TrainState(
        params=pspec,
        opt={"m": pspec, "v": pspec},
        step=P(),
        ef_error=None if state_shape.ef_error is None else pspec,
    )


# ===========================================================================
# train step
# ===========================================================================

def make_loss_fn(cfg):
    def loss_fn(params, batch):
        logits, moe_aux = M.forward(cfg, params, batch)
        labels = batch["tokens"][:, 1:]
        loss, aux = cross_entropy(logits[:, :-1], labels, z_loss=1e-4)
        if cfg.is_moe:
            loss = loss + cfg.router_aux_weight * moe_aux
            aux["moe_aux"] = moe_aux
        return loss, aux

    return loss_fn


def _split_microbatches(batch: dict, accum: int) -> dict:
    def sp(x):
        b = x.shape[0]
        assert b % accum == 0, (b, accum)
        return x.reshape(accum, b // accum, *x.shape[1:])

    return {k: sp(v) for k, v in batch.items()}


def make_train_step(
    cfg,
    mesh: Mesh | None,
    opt_cfg: OptConfig,
    *,
    accum: int = 1,
    compress: bool = False,
) -> Callable[[TrainState, dict], tuple[TrainState, dict]]:
    """Build the (un-jitted) train step.  ``mesh=None`` → no sharding
    constraints (CPU smoke tests)."""
    loss_fn = make_loss_fn(cfg)
    policy = make_activation_policy(mesh, cfg) if mesh is not None else None

    def step_fn(state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        with use_policy(policy):
            grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

            if accum == 1:
                (loss, aux), grads = grad_fn(state.params, batch)
                grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
            else:
                micro = _split_microbatches(batch, accum)
                zero = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), state.params)

                def accum_body(carry, mb):
                    acc, loss_acc = carry
                    (l, _a), g = grad_fn(state.params, mb)
                    acc = jax.tree.map(
                        lambda a, gi: a + gi.astype(jnp.float32) / accum,
                        acc, g)
                    return (acc, loss_acc + l / accum), None

                (grads, loss), _ = jax.lax.scan(
                    accum_body, (zero, jnp.float32(0.0)), micro)
                aux = {}

            ef_error = state.ef_error
            if compress:
                grads, ef_error = compression.ef_compress(grads, ef_error)

            new_params, new_opt, om = adamw_update(
                grads, state.opt, state.params, state.step, opt_cfg)
            metrics = {"loss": loss, **om,
                       **{k: v for k, v in aux.items() if v.ndim == 0}}
            return TrainState(new_params, new_opt, state.step + 1,
                              ef_error), metrics

    return step_fn


def train_step_shardings(cfg, mesh: Mesh, state_shape, batch_shape):
    """(in_shardings, out_shardings) for pjit'ing the train step.

    Metrics get a pytree-prefix replicated sharding (scalars)."""
    sspec = state_pspecs(state_shape, mesh, cfg)
    bspec = batch_pspecs(batch_shape, mesh)
    in_sh = (to_shardings_tree(sspec, mesh), to_shardings(bspec, mesh))
    out_sh = (to_shardings_tree(sspec, mesh), NamedSharding(mesh, P()))
    return in_sh, out_sh


def to_shardings_tree(spec_tree, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


# ===========================================================================
# serving steps
# ===========================================================================

def make_prefill_step(cfg, mesh: Mesh | None, *, max_seq: int | None = None,
                      plan=None):
    """Prefill step builder.  ``plan`` threads a (bucketed) prefill
    BlockPlan through the model's MLP dispatch; ``max_seq`` right-pads the
    returned caches for in-place decode appends.  The returned step takes
    an optional traced ``last_pos`` so bucket-padded prompts read their
    logits at the true last token, not the pad tail."""
    policy = make_activation_policy(mesh, cfg) if mesh is not None else None

    def step_fn(params: Params, batch: dict,
                last_pos: jax.Array | None = None):
        with use_policy(policy):
            return M.prefill(cfg, params, batch, max_seq=max_seq,
                             plan=plan, last_pos=last_pos)

    return step_fn


def make_decode_step(cfg, mesh: Mesh | None, *, plan=None):
    """serve_step for the decode cells: one token against a full cache.

    ``pos`` may be a scalar or a per-row ``(B,)`` vector (mixed sequence
    lengths under continuous batching); ``plan`` threads the m=1 decode
    BlockPlan through the model's MLP dispatch."""
    policy = make_activation_policy(mesh, cfg) if mesh is not None else None

    def step_fn(params: Params, cache: Params, token: jax.Array,
                pos: jax.Array):
        with use_policy(policy):
            logits, new_cache = M.decode_step(cfg, params, token, cache,
                                              pos, plan=plan)
            return logits, new_cache

    return step_fn


def decode_shardings(cfg, mesh: Mesh, params_shape, cache_shape,
                     batch: int):
    pspec = param_pspecs(params_shape, mesh, cfg)
    cspec = cache_pspecs(cache_shape, mesh, cfg)
    dp = dp_axes(mesh)
    from repro.distributed.sharding import _div
    return (
        to_shardings_tree(pspec, mesh),
        to_shardings_tree(cspec, mesh),
        NamedSharding(mesh, P(_div(mesh, batch, dp), None)),   # token (B, 1)
        NamedSharding(mesh, P()),                              # pos
    )
