"""Losses.  Cross entropy is computed in fp32 with an explicit logsumexp so
the (B, S, V) logits tensor can stay vocab-sharded over the ``model`` axis
(GSPMD turns max/sum over V into per-shard reductions + tiny collectives —
no all-gather of logits)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy(
    logits: jax.Array,        # (B, S, V)
    labels: jax.Array,        # (B, S) int32
    mask: jax.Array | None = None,    # (B, S) 0/1
    *,
    z_loss: float = 0.0,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Mean token NLL (+ optional z-loss stabilizer).  Returns (loss, aux)."""
    lg = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lg, axis=-1)          # (B, S)
    pick = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    nll = lse - pick
    if z_loss:
        nll = nll + z_loss * lse ** 2
    if mask is None:
        mask = jnp.ones_like(labels, jnp.float32)
    mask = mask.astype(jnp.float32)
    tot = jnp.maximum(mask.sum(), 1.0)
    loss = (nll * mask).sum() / tot
    acc = ((jnp.argmax(lg, -1) == labels) * mask).sum() / tot
    return loss, {"nll": loss, "accuracy": acc, "tokens": tot}
