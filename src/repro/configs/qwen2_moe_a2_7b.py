"""qwen2-moe-a2.7b [moe] — 24L, d2048, 16H MHA kv=16, per-expert ff 1408,
vocab 151936; 60 routed experts top-4 + 4 shared (shared hidden 5632).
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]

Note E=60 does not divide the 16-way model axis: the FTL sharding
constraint family selects per-expert TP (d_ff sharding) instead of EP for
this arch (DESIGN.md §6).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=0,
    vocab_size=151936,
    head_dim=128,
    mlp_act="silu",
    mlp_gated=True,
    qkv_bias=True,
    n_experts=60,
    n_experts_per_token=4,
    n_shared_experts=4,
    moe_d_ff=1408,
    shared_d_ff=5632,
)
