"""whisper-base [audio] — 6L enc + 6L dec, d512, 8H MHA, ff 2048,
vocab 51865; encoder-decoder with conv frontend STUB: ``input_specs()``
provides precomputed frame embeddings (batch, 1500, d_model).
[arXiv:2212.04356; unverified]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    head_dim=64,
    mlp_act="gelu",
    mlp_gated=False,
    mlp_bias=True,
    qkv_bias=True,
    norm="layernorm",
    is_encoder_decoder=True,
    n_encoder_layers=6,
    encoder_seq=1500,
)
