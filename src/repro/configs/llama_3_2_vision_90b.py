"""llama-3.2-vision-90b [vlm] — 100L, d8192, 64H GQA kv=8, ff 28672,
vocab 128256; cross-attention image layers every 5th layer.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]

The vision frontend is a STUB per the task spec: ``input_specs()`` provides
precomputed patch embeddings of shape (batch, n_image_tokens, d_model).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    head_dim=128,
    mlp_act="silu",
    mlp_gated=True,
    rope_theta=500_000.0,
    cross_attn_every=5,
    n_image_tokens=1600,
)
