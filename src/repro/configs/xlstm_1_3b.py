"""xlstm-1.3b [ssm] — 48L, d2048, 4 mLSTM heads, vocab 50304; sLSTM +
mLSTM blocks at the paper's 7:1 ratio (every 8th block is sLSTM).
No separate FFN (d_ff=0): the up/down projections live inside the block.
[arXiv:2405.04517; unverified]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    norm="layernorm",
    slstm_every=8,
    xlstm_expand=2,
)
