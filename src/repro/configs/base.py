"""Model/config schema for the architecture zoo.

One frozen dataclass describes every assigned architecture (dense GQA,
MoE, xLSTM, RG-LRU hybrid, encoder-decoder audio, cross-attn VLM).  Each
``src/repro/configs/<arch>.py`` exports ``CONFIG``; ``shapes.py`` defines
the four assigned input-shape cells.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int | None = None          # default d_model // n_heads
    mlp_act: str = "silu"
    mlp_gated: bool = True
    mlp_bias: bool = False
    qkv_bias: bool = False
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    n_experts_per_token: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0                    # per-expert hidden
    shared_d_ff: int = 0                 # shared-expert hidden
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # --- xLSTM (ssm) ---------------------------------------------------------
    slstm_every: int = 0                 # every Nth block is sLSTM (0 = none)
    xlstm_expand: int = 2                # mLSTM up-projection factor

    # --- hybrid (recurrentgemma) --------------------------------------------
    block_pattern: tuple[str, ...] = ("attn",)   # cycled over layers
    local_window: int | None = None      # local-attention window
    lru_width: int | None = None         # RG-LRU state width
    conv_width: int = 4                  # temporal conv in recurrent block

    # --- vlm ------------------------------------------------------------------
    cross_attn_every: int = 0            # every Nth layer is x-attn (0 = none)
    n_image_tokens: int = 0              # stub frontend: precomputed embeddings

    # --- encoder-decoder (audio) -----------------------------------------------
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 0                 # stub frontend frames

    # --- execution -----------------------------------------------------------
    dtype: str = "bfloat16"              # activation/param compute dtype
    ftl_mode: Literal["off", "fused", "scan", "auto"] = "off"
    remat: bool = True
    # MoE dispatch: 'scatter' (global rank scatter — baseline) or
    # 'grouped' (GShard-style per-group dispatch; ranks never cross data
    # shards, resharding lowers to all-to-all) — §Perf lever.
    moe_dispatch: Literal["scatter", "grouped"] = "scatter"
    moe_groups: int = 0                  # 0 = one group per data shard (16)
    # mLSTM time-chunked remat: 0 = plain scan (saves per-step state for
    # bwd), N = chunk size (saves only chunk boundaries) — §Perf lever.
    mlstm_chunk: int = 0

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def block_kind(self, layer: int) -> str:
        """Temporal-mixing kind of layer ``layer``: attn | cross | mlstm |
        slstm | rec | local."""
        if self.family == "ssm":
            if self.slstm_every and (layer + 1) % self.slstm_every == 0:
                return "slstm"
            return "mlstm"
        if self.family == "hybrid":
            return self.block_pattern[layer % len(self.block_pattern)]
        if self.family == "vlm" and self.cross_attn_every and (
            (layer + 1) % self.cross_attn_every == 0
        ):
            return "cross"
        return "attn"

    def attention_free(self) -> bool:
        """True if no layer does full quadratic attention (long_500k rule)."""
        kinds = {self.block_kind(i) for i in range(self.n_layers)}
        return "attn" not in kinds and "cross" not in kinds

    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k cell: recurrent and/or local-attn only."""
        kinds = {self.block_kind(i) for i in range(self.n_layers)}
        quad = {"attn", "cross"} & kinds
        if not quad:
            return True
        # local attention counts as sub-quadratic
        return kinds <= {"rec", "local", "mlstm", "slstm"}

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        scale = dict(
            n_layers=min(self.n_layers, 4 if self.family != "hybrid"
                         else max(3, len(self.block_pattern))),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads > 1 else 1,
            d_ff=256 if self.d_ff else 0,
            vocab_size=512,
            head_dim=32,
            dtype="float32",
            remat=False,
        )
        if self.is_moe:
            scale.update(n_experts=8, n_experts_per_token=2, moe_d_ff=64,
                         shared_d_ff=64 if self.shared_d_ff else 0)
        if self.family == "ssm":
            scale.update(n_heads=2, head_dim=None)
        if self.family == "hybrid":
            scale.update(local_window=32, lru_width=128)
        if self.family == "vlm":
            scale.update(n_image_tokens=16, cross_attn_every=2)
        if self.is_encoder_decoder:
            scale.update(n_encoder_layers=2, encoder_seq=64)
        return dataclasses.replace(self, **scale)
