"""recurrentgemma-9b [hybrid] — 38L, d4096, 16H MQA kv=1, ff 12288,
vocab 256000; RG-LRU recurrent blocks + local attention at 2:1
(pattern rec, rec, local; window 2048).  [arXiv:2402.19427; unverified]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    head_dim=256,
    mlp_act="gelu",
    mlp_gated=True,
    block_pattern=("rec", "rec", "local"),
    local_window=2048,
    lru_width=4096,
    conv_width=4,
)
