"""Architecture registry: ``get_config("<arch-id>")`` for every assigned
architecture, plus the shape cells.  Arch ids use the assignment's dashes;
module names use underscores.
"""
from __future__ import annotations

import importlib

from .base import ModelConfig
from .shapes import SHAPES, ShapeSpec, get_shape

_ARCH_MODULES = {
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
    "yi-6b": "yi_6b",
    "llama3.2-3b": "llama3_2_3b",
    "qwen2-72b": "qwen2_72b",
    "granite-20b": "granite_20b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "xlstm-1.3b": "xlstm_1_3b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "whisper-base": "whisper_base",
}

ARCHS: tuple[str, ...] = tuple(_ARCH_MODULES)


def get_config(name: str) -> ModelConfig:
    try:
        mod = _ARCH_MODULES[name]
    except KeyError as e:
        raise KeyError(
            f"unknown arch {name!r}; available: {', '.join(ARCHS)}"
        ) from e
    return importlib.import_module(f"repro.configs.{mod}").CONFIG


__all__ = [
    "ARCHS", "ModelConfig", "SHAPES", "ShapeSpec", "get_config", "get_shape",
]
