"""moonshot-v1-16b-a3b [moe] — 48L, d2048, 16H MHA kv=16, per-expert ff 1408,
vocab 163840; MoE 64 routed experts top-6 (+2 shared, Moonlight-style).
[hf:moonshotai/Moonlight-16B-A3B; hf]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=0,
    vocab_size=163840,
    head_dim=128,
    mlp_act="silu",
    mlp_gated=True,
    n_experts=64,
    n_experts_per_token=6,
    n_shared_experts=2,
    moe_d_ff=1408,
    shared_d_ff=2816,
    rope_theta=50_000.0,
)
