"""granite-20b [dense] — 52L, d6144, 48H MQA kv=1, ff 24576, vocab 49152.
Code model, GPT-BigCode-style: un-gated GeLU MLP with biases — this is the
paper's GEMM+GeLU benchmark at production scale (DESIGN.md §7).
[arXiv:2405.04324; hf]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    head_dim=128,
    mlp_act="gelu",
    mlp_gated=False,
    mlp_bias=True,
    qkv_bias=True,
    norm="layernorm",
)
