"""Assigned input-shape cells (the 4 shapes each architecture runs).

``kind`` selects which step gets lowered in the dry-run:
  * train   -> train_step  (fwd+bwd+optimizer update)
  * prefill -> serve prefill (forward, returns logits + KV cache)
  * decode  -> serve decode (one token against a seq_len-sized KV cache)
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str             # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def get_shape(name: str) -> ShapeSpec:
    return SHAPES[name]
