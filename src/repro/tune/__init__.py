"""Simulator-in-the-loop autotuning.

The analytic planner (``repro.core.ftl``) returns the roofline-optimal
plan; the simulator (``repro.sim``) knows which of the many near-ties
actually wins once DMA-port contention, buffer-slot hazards and
pipeline fill/drain are replayed.  This package closes the loop:

* :func:`autotune_chain` shortlists the top-k fusion partitions and
  per-segment tile assignments analytically, then beam-searches over
  tile sizes (including non-divisor edge tiles) × per-level buffer
  depths (``Target.with_level_buffer_depth``) × per-kind engine
  assignment, scoring every candidate by full discrete-event replay.
* The returned :class:`TuneResult` carries both the tuned and the
  analytic-best chain; since the analytic plan is always a seed, the
  tuned simulated runtime is ≤ the analytic one by construction — the
  invariant ``benchmarks/bench_autotune.py`` gates in CI.
* The search is deterministic (no RNG; fixed enumeration order,
  insertion-order tie-breaks): same inputs → same chosen plan.

``plan_block(..., autotune=AutotuneConfig(...))`` threads the tuner
through the registry/model path; the tuning config is part of the plan
cache key, so tuned and untuned plans never alias.
"""
from .autotune import AutotuneConfig, TuneResult, autotune_chain, tile_ladder

__all__ = ["AutotuneConfig", "TuneResult", "autotune_chain", "tile_ladder"]
