"""Simulator-in-the-loop autotuning (the LoopTree-style second stage).

The analytic planner (``repro.core.ftl``) optimizes the closed-form
``max(compute, transfer)`` roofline; the discrete-event simulator
(``repro.sim``) replays the implied schedule with the hazards the
closed form ignores — DMA-port contention, buffer-slot stalls, prefetch
distance, pipeline fill/drain.  Plans that tie analytically can differ
by real simulated runtime, and a deliberately *analytic-suboptimal*
move (a deeper staging pipeline bought with a smaller tile, a slower
engine that overlaps with the bottleneck one) can win the replay.

This module closes that loop with a two-stage search:

1. **Analytic shortlist** — the top-``k`` fusion partitions of the
   chain (``partition.plan_chain_top_k``) and, per segment, the
   top-``k`` tile assignments (``solver.solve_top_k``).  Both are exact
   k-best extensions of the existing branch-and-bound/DP, so seed 0 is
   always the analytic-best plan.
2. **DES-scored beam search** — from those seeds, a deterministic beam
   over four move families: switching a segment to another shortlisted
   tile assignment, nudging one dim's tile along an aligned ladder
   (including *non-divisor* sizes — the sharpened edge-tile lowering
   prices those exactly), re-depthing one memory level
   (``Target.with_level_buffer_depth``), and re-assigning one op kind
   to another capable engine.  Every candidate is lowered and replayed
   (``sim.simulate_chain``); infeasible footprints are discarded.

Because the seeds include the analytic-best chain and scoring is exact
replay, the tuned plan's simulated runtime is ≤ the analytic-best
plan's simulated runtime *by construction* — the CI gate
(``benchmarks/bench_autotune.py``) enforces it per preset.  The search
is RNG-free: candidate enumeration order is fixed, ties break by
insertion order, and repeated runs return the identical plan
(pinned in ``tests/test_tune.py``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Mapping

from repro import obs
from repro.core import hw as hwlib
from repro.core.ftl import cost as costlib
from repro.core.ftl import partition as partlib
from repro.core.ftl import registry
from repro.core.ftl import solver as solverlib
from repro.core.ftl.constraints import DimConstraint
from repro.core.ftl.graph import OpGraph
from repro.core.ftl.partition import ChainPlan
from repro.sim.des import simulate_chain
from repro.sim.schedule import lower_chain


@dataclasses.dataclass(frozen=True)
class AutotuneConfig:
    """Knobs of the DES-scored search (hashable — part of every plan
    cache key that holds a tuned plan).

    * ``top_k_partitions`` / ``top_k_tiles`` — analytic shortlist sizes
      (stage 1).  1 keeps only the argmin the planner already returns.
    * ``beam_width`` / ``max_rounds`` — beam search shape (stage 2).
      Width 4 × 3 rounds covers the presets well; deeper searches help
      only when many analytic ties exist.
    * ``max_sims`` — hard budget on DES replays (each is milliseconds
      on zoo blocks; the budget caps worst-case planning latency).
    * ``depth_candidates`` — per-level ``buffer_depth`` values the
      search may try (fast *and* backing levels).
    * ``tune_tiles`` / ``tune_depths`` / ``tune_engines`` — move-family
      switches.
    """

    top_k_partitions: int = 3
    top_k_tiles: int = 3
    beam_width: int = 4
    max_rounds: int = 3
    max_sims: int = 256
    depth_candidates: tuple[int, ...] = (1, 2, 3, 4)
    tune_tiles: bool = True
    tune_depths: bool = True
    tune_engines: bool = True

    def __post_init__(self):
        if min(self.top_k_partitions, self.top_k_tiles) < 1:
            raise ValueError("top_k_partitions/top_k_tiles must be >= 1")
        if self.beam_width < 1 or self.max_rounds < 0:
            raise ValueError("beam_width >= 1 and max_rounds >= 0 required")
        if self.max_sims < 1:
            raise ValueError("max_sims must be >= 1")
        if any(d < 1 for d in self.depth_candidates):
            raise ValueError("depth candidates must be >= 1")


@dataclasses.dataclass(frozen=True)
class TuneResult:
    """Outcome of one autotune run: the chosen chain + its provenance."""

    chain: ChainPlan                   # DES-optimal plan
    sim_runtime_s: float               # its simulated runtime
    baseline_chain: ChainPlan          # the analytic-best plan (seed 0)
    baseline_sim_runtime_s: float      # its simulated runtime
    n_scored: int                      # DES replays spent
    n_feasible: int                    # candidates that fit fast memory
    config: AutotuneConfig

    @property
    def improved(self) -> bool:
        """Strictly better than the analytic-best plan under replay
        (compared through ``hw.round_time``, like every objective)."""
        return hwlib.round_time(self.sim_runtime_s) < \
            hwlib.round_time(self.baseline_sim_runtime_s)

    @property
    def improvement(self) -> float:
        """Fractional simulated-runtime win over the analytic plan."""
        if self.baseline_sim_runtime_s <= 0.0:
            return 0.0
        return 1.0 - self.sim_runtime_s / self.baseline_sim_runtime_s

    @property
    def analytic_runtime_s(self) -> float:
        return self.chain.modeled_runtime_s

    def summary(self) -> str:
        pct = 100.0 * self.improvement
        return (
            f"autotune '{self.chain.graph.name}' on "
            f"{self.baseline_chain.target.name}: "
            f"{1e3 * self.sim_runtime_s:.3f} ms simulated vs "
            f"{1e3 * self.baseline_sim_runtime_s:.3f} ms analytic-best "
            f"({pct:+.2f} %), {self.n_scored} replays "
            f"({self.n_feasible} feasible); chosen cuts "
            f"{self.chain.cuts()}, target '{self.chain.target.name}'"
        )


def tile_ladder(c: DimConstraint) -> tuple[int, ...]:
    """Extended tile domain for the nudge move: the solver's aligned
    divisors plus one aligned midpoint between each adjacent pair —
    deliberately *non-divisor* sizes the analytic lattice never tries,
    priced exactly by the edge-tile-aware lowering.  Pinned dims (whole
    contractions, single-candidate domains) get no moves."""
    if len(c.candidates) <= 1:
        return c.candidates
    a = max(c.alignment, 1)
    pts = set(c.candidates)
    for lo, hi in zip(c.candidates, c.candidates[1:]):
        mid = ((lo + hi) // 2 // a) * a
        if lo < mid < hi:
            pts.add(mid)
    return tuple(sorted(pts))


# A candidate is a fully hashable description of one plan variant:
#   (partition index into the top-k shortlist,
#    per-segment tiles       ((dim, tile), ...) per segment,
#    per-segment overrides   ((kind, engine), ...) per segment,
#    per-level depths        ((level, depth), ...) — non-base only)
Candidate = tuple[int, tuple, tuple, tuple]

# beam telemetry: candidates generated by the move families, DES replays
# actually spent, and candidates pruned as infeasible footprints
_C_CANDIDATES = obs.counter(
    "tune_candidates_total", "candidates generated by the move families")
_C_REPLAYS = obs.counter(
    "tune_replays_total", "DES replays spent scoring candidates")
_C_INFEASIBLE = obs.counter(
    "tune_infeasible_total", "candidates pruned (footprint no longer fits)")


def _freeze_tiles(tiles: Mapping[str, int]) -> tuple:
    return tuple(sorted(tiles.items()))


class _Search:
    def __init__(self, graph: OpGraph, target: hwlib.Target,
                 config: AutotuneConfig, sharded: tuple | None):
        self.graph = graph
        self.target = target
        self.config = config
        self.sharded = dict(sharded) if sharded else None
        self.parts = partlib.plan_chain_top_k(
            graph, target=target, sharded_sizes=self.sharded,
            k=config.top_k_partitions)
        # per (partition, segment): the analytic top-k tile shortlist
        self.seg_tiles: dict[tuple[int, int], list[dict[str, int]]] = {}
        if config.tune_tiles and config.top_k_tiles > 1:
            for pi, part in enumerate(self.parts):
                for si, seg in enumerate(part.segments):
                    plans = solverlib.solve_top_k(
                        seg.plan.group, target=target,
                        sharded_sizes=self.sharded,
                        k=config.top_k_tiles)
                    self.seg_tiles[(pi, si)] = [p.tiles for p in plans]
        self.scored: dict[Candidate, tuple[int, float, ChainPlan | None]] \
            = {}
        self.n_feasible = 0
        self.seq = 0

    # -- candidate construction -------------------------------------
    def seed(self, pi: int) -> Candidate:
        part = self.parts[pi]
        return (
            pi,
            tuple(_freeze_tiles(s.plan.tiles) for s in part.segments),
            tuple(() for _ in part.segments),
            (),
        )

    def _target_for(self, depths: tuple) -> hwlib.Target:
        t = self.target
        for level, d in depths:
            t = t.with_level_buffer_depth(level, d)
        return t

    def _build(self, cand: Candidate) -> ChainPlan | None:
        """Re-price a candidate analytically; None when any segment's
        footprint no longer fits the (possibly re-depthed) fast level."""
        pi, seg_tiles, seg_engines, depths = cand
        part = self.parts[pi]
        t = self._target_for(depths)
        segs = []
        for s, tiles, overrides in zip(part.segments, seg_tiles,
                                       seg_engines):
            rep = costlib.evaluate(
                s.plan.group, dict(tiles), s.plan.constraints,
                target=t, engine_overrides=dict(overrides) or None)
            if rep.vmem_bytes > t.fast_capacity:
                return None
            plan = dataclasses.replace(
                s.plan, tiles=dict(tiles), report=rep, target=t)
            segs.append(dataclasses.replace(s, plan=plan))
        return ChainPlan(graph=self.graph, segments=tuple(segs), target=t)

    def score(self, cand: Candidate) -> float | None:
        """Simulated runtime of a candidate (cached; None = infeasible).
        Counts one DES replay against ``max_sims`` per new feasible
        candidate."""
        if cand in self.scored:
            return self.scored[cand][1]
        self.seq += 1
        chain = self._build(cand)
        if chain is None:
            _C_INFEASIBLE.inc()
            self.scored[cand] = (self.seq, None, None)
            return None
        _C_REPLAYS.inc()
        runtime = simulate_chain(lower_chain(chain)).runtime_s
        self.scored[cand] = (self.seq, runtime, chain)
        self.n_feasible += 1
        return runtime

    @property
    def n_scored(self) -> int:
        return len(self.scored)

    # -- move families ----------------------------------------------
    def moves(self, cand: Candidate) -> list[Candidate]:
        out = self._moves(cand)
        _C_CANDIDATES.inc(len(out))
        return out

    def _moves(self, cand: Candidate) -> list[Candidate]:
        pi, seg_tiles, seg_engines, depths = cand
        part = self.parts[pi]
        cfg = self.config
        out: list[Candidate] = []

        def with_seg_tiles(si: int, tiles: tuple) -> Candidate:
            new = seg_tiles[:si] + (tiles,) + seg_tiles[si + 1:]
            return (pi, new, seg_engines, depths)

        if cfg.tune_tiles:
            # (a) switch a segment to another shortlisted tile plan
            for si in range(len(part.segments)):
                for alt in self.seg_tiles.get((pi, si), ()):
                    frozen = _freeze_tiles(alt)
                    if frozen != seg_tiles[si]:
                        out.append(with_seg_tiles(si, frozen))
            # (b) nudge one dim along its aligned ladder
            for si, s in enumerate(part.segments):
                cur = dict(seg_tiles[si])
                for d, c in s.plan.constraints.items():
                    ladder = tile_ladder(c)
                    if len(ladder) <= 1 or cur[d] not in ladder:
                        continue
                    i = ladder.index(cur[d])
                    for j in (i - 1, i + 1):
                        if 0 <= j < len(ladder):
                            out.append(with_seg_tiles(
                                si, _freeze_tiles({**cur, d: ladder[j]})))

        if cfg.tune_depths:
            cur_depths = dict(depths)
            base = {lv.name: lv.buffer_depth for lv in self.target.levels}
            shrunk = self._shrunk_tiles(seg_tiles, part)
            for lv in self.target.levels:
                have = cur_depths.get(lv.name, base[lv.name])
                for d in cfg.depth_candidates:
                    if d == have:
                        continue
                    nd = dict(cur_depths)
                    if d == base[lv.name]:
                        nd.pop(lv.name, None)
                    else:
                        nd[lv.name] = d
                    frozen_d = tuple(sorted(nd.items()))
                    out.append((pi, seg_tiles, seg_engines, frozen_d))
                    if d > have and shrunk is not None:
                        # repair variant: a deeper pipeline costs
                        # footprint — pair it with one ladder step down
                        # on every dim so the move stays reachable when
                        # the current tiles leave no headroom.
                        out.append((pi, shrunk, seg_engines, frozen_d))

        if cfg.tune_engines and self.target.engines:
            for si, s in enumerate(part.segments):
                cur = dict(seg_engines[si])
                kinds = []
                for op in s.plan.group.ops:
                    if op.kind not in kinds:
                        kinds.append(op.kind)
                for kind in kinds:
                    have = cur.get(kind, self.target.engine_rate(kind)[0])
                    for ename in self.target.engines_for_kind(kind):
                        if ename == have:
                            continue
                        ne = dict(cur)
                        if ename == self.target.engine_rate(kind)[0]:
                            ne.pop(kind, None)
                        else:
                            ne[kind] = ename
                        frozen_e = tuple(sorted(ne.items()))
                        new = seg_engines[:si] + (frozen_e,) + \
                            seg_engines[si + 1:]
                        out.append((pi, seg_tiles, new, depths))
        return out

    def _shrunk_tiles(self, seg_tiles: tuple, part: ChainPlan
                      ) -> tuple | None:
        shrunk = []
        changed = False
        for si, s in enumerate(part.segments):
            cur = dict(seg_tiles[si])
            for d, c in s.plan.constraints.items():
                ladder = tile_ladder(c)
                if cur[d] in ladder:
                    i = ladder.index(cur[d])
                    if i > 0:
                        cur[d] = ladder[i - 1]
                        changed = True
            shrunk.append(_freeze_tiles(cur))
        return tuple(shrunk) if changed else None

    # -- the beam ----------------------------------------------------
    def run(self) -> TuneResult:
        cfg = self.config
        seeds = [self.seed(pi) for pi in range(len(self.parts))]
        baseline = seeds[0]
        for c in seeds:
            self.score(c)
        baseline_runtime = self.scored[baseline][1]
        assert baseline_runtime is not None  # seed 0 is the solved plan

        def rank(cand: Candidate) -> tuple:
            seq, runtime, _ = self.scored[cand]
            return (hwlib.round_time(runtime), seq)

        frontier = sorted(
            (c for c in seeds if self.scored[c][1] is not None), key=rank
        )[:cfg.beam_width]
        for rnd in range(cfg.max_rounds):
            if self.n_scored >= cfg.max_sims:
                break
            fresh: list[Candidate] = []
            with obs.span(f"autotune_round:{rnd}", "tune"):
                for cand in frontier:
                    for nxt in self.moves(cand):
                        if nxt in self.scored:
                            continue
                        if self.n_scored >= cfg.max_sims:
                            break
                        if self.score(nxt) is not None:
                            fresh.append(nxt)
            if not fresh:
                break
            frontier = sorted(set(frontier) | set(fresh), key=rank)
            frontier = frontier[:cfg.beam_width]

        best = min(
            (c for c, (_, r, _ch) in self.scored.items() if r is not None),
            key=rank,
        )
        _, best_runtime, best_chain = self.scored[best]
        return TuneResult(
            chain=best_chain,
            sim_runtime_s=best_runtime,
            baseline_chain=self.scored[baseline][2],
            baseline_sim_runtime_s=baseline_runtime,
            n_scored=self.n_scored,
            n_feasible=self.n_feasible,
            config=cfg,
        )


@functools.lru_cache(maxsize=64)
def _autotune_cached(graph: OpGraph, target: hwlib.Target,
                     config: AutotuneConfig,
                     sharded: tuple | None) -> TuneResult:
    return _Search(graph, target, config, sharded).run()


registry.register_plan_cache("tune._autotune_cached", _autotune_cached)


def autotune_chain(
    graph: OpGraph,
    *,
    target: hwlib.Target | None = None,
    config: AutotuneConfig | None = None,
    sharded_sizes: Mapping[str, int] | None = None,
) -> TuneResult:
    """DES-optimal plan for ``graph`` on ``target`` (None → the default
    target): analytic top-k shortlist, then a deterministic beam search
    over tile sizes × per-level buffer depths × engine assignment,
    every candidate scored by full schedule replay.  The result's
    simulated runtime is ≤ the analytic-best plan's simulated runtime
    by construction (the analytic plan is seed 0 and ties keep it).

    Cached per (graph, target, config, sharding) — the same key shape
    every other planner cache uses, so a tuned chain is never confused
    with an untuned one.
    """
    target = target if target is not None else hwlib.default_target()
    config = config if config is not None else AutotuneConfig()
    with obs.span("autotune_chain", "tune"):
        return _autotune_cached(graph, target, config,
                                partlib._freeze(sharded_sizes))


__all__ = ["AutotuneConfig", "TuneResult", "autotune_chain", "tile_ladder"]
