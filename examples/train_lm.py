"""End-to-end training driver example (deliverable b).

Trains a ~100M-param llama-style LM on the deterministic synthetic bigram
stream with the full production stack: grad-accum train step, AdamW +
warmup-cosine, async checkpointing, auto-resume, straggler monitoring.

The default preset is CPU-sized so this runs here; ``--preset 100m`` is
the real config (a few hundred steps on a v5e slice: point --mesh at it
via launch/train.py, which shares this code path).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 60]

``--obs-metrics train.prom`` turns on runtime telemetry (train_step
spans, straggler/heartbeat metrics — see README "Observability") and
writes the Prometheus exposition after the run.
"""
import argparse

import jax
import jax.numpy as jnp

from repro import obs
from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.optim import OptConfig
from repro.runtime import LoopConfig, TrainLoop
from repro.train import steps as S

PRESETS = {
    # ~100M params: the deliverable's end-to-end scale (for real hardware)
    "100m": dict(n_layers=12, d_model=512, n_heads=8, n_kv_heads=8,
                 d_ff=2048, vocab_size=50304, batch=32, seq=512),
    # CPU-sized smoke preset (~7M params)
    "cpu": dict(n_layers=4, d_model=192, n_heads=4, n_kv_heads=4,
                d_ff=512, vocab_size=8192, batch=8, seq=128),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="cpu", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--obs-metrics", default=None,
                    help="write Prometheus metrics here (enables spans)")
    args = ap.parse_args()
    if args.obs_metrics:
        obs.enable()

    p = PRESETS[args.preset]
    cfg = ModelConfig(
        name=f"example-{args.preset}", family="dense",
        n_layers=p["n_layers"], d_model=p["d_model"], n_heads=p["n_heads"],
        n_kv_heads=p["n_kv_heads"], d_ff=p["d_ff"],
        vocab_size=p["vocab_size"], mlp_act="silu", mlp_gated=True,
        tie_embeddings=True, dtype="float32", remat=False)

    from repro.models.model import count_params
    print(f"model: {count_params(cfg)/1e6:.1f}M params")

    data = SyntheticLM(
        DataConfig(vocab_size=cfg.vocab_size, global_batch=p["batch"],
                   seq_len=p["seq"], kind="bigram", noise=4),
        process_index=0, process_count=1)

    state = S.init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(S.make_train_step(
        cfg, None,
        OptConfig(peak_lr=3e-3, warmup_steps=10, decay_steps=args.steps),
        accum=2))

    loop = TrainLoop(
        LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                   ckpt_every=25, log_every=10),
        step,
        lambda i: {k: jnp.asarray(v) for k, v in data.batch_at(i).items()},
        state,
        on_metrics=lambda s, m: print(
            f"step {s:4d}  loss {m['loss']:.4f}  "
            f"gnorm {m['grad_norm']:.2f}  lr {m['lr']:.1e}", flush=True),
    )
    loop.run()
    last = loop.metrics_log[-1]
    print(f"\nfinal loss {last['loss']:.4f} "
          f"(entropy floor {data.optimal_nll():.4f}); "
          f"straggler flags: {len(loop.monitor.flagged_steps)}")
    if args.obs_metrics:
        obs.write_prometheus(args.obs_metrics)
        print(f"wrote metrics to {args.obs_metrics}")


if __name__ == "__main__":
    main()
