"""Explore FTL plans interactively: target sweeps, fusion decisions,
the sharding-constraint family, and the tile-level schedule replay.

Shows, for a chosen MLP, how the optimal schedule changes with the
memory-hierarchy target — across the presets (tpu_v5e / cpu_cache /
rv32_l1_l2 / rv32_npu) and across fast-level capacities of one target:
the paper's Fig. 3 regime (fusion wins) and the small-budget regime
where the partitioner rejects fusion (beyond-paper extension).  The
preset sweep also replays every chosen plan through the ``repro.sim``
discrete-event simulator (sim vs analytic runtime, overlap efficiency),
and ``--timeline`` prints the first tile steps of the replayed schedule
event by event.

``--autotune`` reruns the chosen plan through the simulator-in-the-loop
tuner (``repro.tune``); ``--trace out.json`` exports the replayed
timeline as Chrome-tracing JSON — open it at https://ui.perfetto.dev.

``--mesh N`` plans a tensor-parallel transformer block (``--arch``,
default llama3.2-3b) at mesh sizes 1→N with its all-reduces captured as
first-class collective ops, prints the modeled + simulated scaling
table, and makes the mesh-N plan the one ``--timeline`` / ``--trace``
render — the trace then shows the collective stream on its own
``dma:ici`` / ``dma:noc`` track overlapping the memory DMA.

Run:  PYTHONPATH=src python examples/ftl_explore.py [--m 8192] [--d 4096]
      [--f 11008] [--target rv32_npu] [--timeline] [--autotune]
      [--trace out.json] [--mesh 4]
"""
import argparse

from repro import sim
from repro.core import hw
from repro.core.ftl import graph, partition, registry

KB, MB = 1 << 10, 1 << 20


def _mlp_row(g, target):
    from repro.core.ftl import InfeasibleError
    chain = partition.plan_chain(g, target=target)
    unf = partition.plan_fixed(g, partition.all_cuts(g), target=target)
    try:
        fused = partition.plan_fixed(g, (), target=target)
    except InfeasibleError:
        fused = None
    return chain, fused, unf


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=8192)
    ap.add_argument("--d", type=int, default=4096)
    ap.add_argument("--f", type=int, default=11008)
    ap.add_argument("--gated", action="store_true")
    ap.add_argument("--target", default="tpu_v5e",
                    help="preset to sweep fast-level capacities of")
    ap.add_argument("--arch", default=None,
                    help="also show the whole-block graph plan for an arch")
    ap.add_argument("--timeline", action="store_true",
                    help="print the replayed event timeline of the chosen "
                         "plan on --target")
    ap.add_argument("--autotune", action="store_true",
                    help="DES-tune the chosen plan on --target "
                         "(tile sizes x buffer depths x engines)")
    ap.add_argument("--trace", metavar="OUT.json", default=None,
                    help="write the replayed timeline on --target as "
                         "Chrome-tracing JSON (Perfetto-viewable)")
    ap.add_argument("--mesh", type=int, default=1,
                    help="plan a tensor-parallel block (--arch) at mesh "
                         "sizes 1..N with collectives as first-class ops; "
                         "the mesh-N plan feeds --timeline/--trace")
    ap.add_argument("--calibrate", action="store_true",
                    help="fit effective Target constants from this "
                         "host's wall-clock microbenchmarks "
                         "(repro.calib) and explore on the calibrated "
                         "machine instead of the preset")
    args = ap.parse_args()

    g = graph.mlp_graph(m=args.m, d_model=args.d, d_ff=args.f,
                        gated=args.gated)
    print(f"MLP m={args.m} d_model={args.d} d_ff={args.f} "
          f"gated={args.gated}\n")

    # --- preset sweep: same chain, four machines, analytic + replayed ----
    print(f"{'target':>12} {'decision':>9} {'chosen MiB':>11} "
          f"{'unfused MiB':>12} {'runtime ms':>11} {'sim ms':>9} "
          f"{'eff':>5} {'bound':>8}  per-level")
    for t in hw.presets():
        chain, fused, unf = _mlp_row(g, t)
        per = ", ".join(f"{n}={b / MB:.1f}M"
                        for n, b in chain.per_level_traffic.items())
        bound = "compute" if chain.compute_bound else "transfer"
        replay = sim.simulate_chain(sim.lower_chain(chain))
        print(f"{t.name:>12} {chain.schedule:>9} "
              f"{chain.traffic_bytes / MB:11.1f} "
              f"{unf.traffic_bytes / MB:12.1f} "
              f"{1e3 * chain.modeled_runtime_s:11.2f} "
              f"{1e3 * replay.runtime_s:9.2f} "
              f"{replay.overlap_efficiency:5.2f} {bound:>8}  {per}")

    # --- capacity sweep on one target ------------------------------------
    base = hw.get_target(args.target)
    if args.calibrate:
        from repro import calib
        print(f"\ncalibrating {base.name} from this host's wall-clock "
              f"microbenchmarks...")
        result = calib.calibrate(calib.microbench_sweep(base=base),
                                 base=base)
        print(result.summary())
        base = result.target
        print(f"exploring on the calibrated machine: {base.describe()}")
    print(f"\nfast-level capacity sweep on {args.target}:")
    print(f"{'budget':>10} {'decision':>9} {'fused MiB':>10} "
          f"{'unfused MiB':>12} {'reduction':>10}")
    for budget in (512 * KB, 2 * MB, 8 * MB, 32 * MB, 96 * MB, 256 * MB):
        t = base.with_fast_capacity(budget)
        chain, fused, unf = _mlp_row(g, t)
        if fused is None:
            print(f"{budget / MB:9.1f}M {'infeasible':>9} {'-':>10} "
                  f"{unf.traffic_bytes / MB:11.1f} {'-':>10}")
            continue
        red = 1 - fused.traffic_bytes / unf.traffic_bytes
        print(f"{budget / MB:9.1f}M "
              f"{'FUSE' if chain.schedule == 'fused' else 'split':>9} "
              f"{fused.traffic_bytes / MB:10.1f} "
              f"{unf.traffic_bytes / MB:11.1f} {100 * red:9.1f}%")

    # sharding constraints: the same MLP on a 16-way TP shard
    print("\nwith d_ff sharded 16-way over the model axis "
          "(FTL sharding-constraint family):")
    if args.f % 16 == 0:
        gs = graph.mlp_graph(m=args.m, d_model=args.d, d_ff=args.f // 16,
                             gated=args.gated)
        chain, fused, unf = _mlp_row(gs, hw.TPU_V5E)
        print(f"  decision={chain.schedule}; "
              f"{chain.traffic_bytes / MB:.1f} MiB vs "
              f"{unf.traffic_bytes / MB:.1f} MiB unfused")
    else:
        print("  d_ff not divisible by 16 — planner keeps it whole")

    # the graph partitioner's own view of the same chain (DP over cuts)
    chain = partition.plan_chain(g, target=hw.TPU_V5E)
    print("\ngraph partitioner (tpu_v5e):")
    print(chain.summary())

    # --- mesh scaling: collectives as first-class ops --------------------
    chosen_graph = g
    if args.mesh > 1:
        from repro import configs
        from repro.distributed import mesh_capture
        cfg = configs.get_config(args.arch or "llama3.2-3b")
        meshes = sorted({1, *(n for n in (2, 4, 8, 16) if n < args.mesh),
                         args.mesh})
        print(f"\nmesh scaling for {cfg.name} block (m={args.m}) on "
              f"{base.name}:")
        print(f"{'mesh':>5} {'modeled ms':>11} {'sim ms':>9} "
              f"{'speedup':>8} {'eff':>5}  cuts")
        base_sim = None
        for n in meshes:
            gm = mesh_capture.capture_block(cfg, m=args.m, mesh_size=n)
            chain = partition.plan_chain(gm, target=base)
            replay = sim.simulate_chain(sim.lower_chain(chain))
            base_sim = base_sim if base_sim is not None else replay.runtime_s
            print(f"{n:>5} {1e3 * chain.modeled_runtime_s:11.3f} "
                  f"{1e3 * replay.runtime_s:9.3f} "
                  f"{base_sim / replay.runtime_s:7.2f}x "
                  f"{replay.overlap_efficiency:5.2f}  {chain.cuts()}")
            if n == args.mesh:
                chosen_graph = gm

    chosen = partition.plan_chain(chosen_graph, target=base)
    if args.autotune:
        from repro import tune
        res = tune.autotune_chain(chosen_graph, target=base)
        print(f"\n{res.summary()}")
        chosen = res.chain

    if args.timeline:
        print(f"\nreplayed schedule on {chosen.target.name} "
              f"(first steps, {chosen.schedule}):")
        print(sim.chain_timeline(chosen, max_steps=2))

    if args.trace:
        sim.write_chrome_trace(chosen, args.trace)
        print(f"\nwrote Chrome trace to {args.trace} "
              f"(open at https://ui.perfetto.dev)")

    if args.arch:
        from repro import configs
        cfg = configs.get_config(args.arch)
        bp = registry.plan_block(cfg, m=args.m, target=base)
        print(f"\nwhole-block plan for {args.arch} on {base.name}:")
        print(bp.summary())


if __name__ == "__main__":
    main()
