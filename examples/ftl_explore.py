"""Explore FTL plans interactively: budget sweeps, fusion decisions, and
the sharding-constraint family.

Shows, for a chosen MLP, how the optimal schedule changes with the VMEM
budget — the paper's Fig. 3 regime (fusion wins) and the small-budget
regime where the auto-planner rejects fusion (beyond-paper extension).

Run:  PYTHONPATH=src python examples/ftl_explore.py [--m 8192] [--d 4096]
      [--f 11008]
"""
import argparse

from repro.core import ftl
from repro.core.ftl import graph, partition, registry

KB, MB = 1 << 10, 1 << 20


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=8192)
    ap.add_argument("--d", type=int, default=4096)
    ap.add_argument("--f", type=int, default=11008)
    ap.add_argument("--gated", action="store_true")
    ap.add_argument("--arch", default=None,
                    help="also show the whole-block graph plan for an arch")
    args = ap.parse_args()

    print(f"MLP m={args.m} d_model={args.d} d_ff={args.f} "
          f"gated={args.gated}\n")
    print(f"{'budget':>10} {'decision':>9} {'fused MiB':>10} "
          f"{'unfused MiB':>12} {'reduction':>10} {'tile_m':>7} {'tile_f':>7}")
    for budget in (512 * KB, 2 * MB, 8 * MB, 32 * MB, 96 * MB, 256 * MB):
        out = ftl.plan_mlp(m=args.m, d_model=args.d, d_ff=args.f,
                           gated=args.gated, vmem_budget=budget)
        unf = sum(p.traffic_bytes for p in out.unfused)
        if out.fused is None:
            print(f"{budget/MB:9.1f}M {'infeasible':>9} {'-':>10} "
                  f"{unf/MB:11.1f} {'-':>10}")
            continue
        red = 1 - out.fused.traffic_bytes / unf
        print(f"{budget/MB:9.1f}M "
              f"{'FUSE' if out.use_fused else 'split':>9} "
              f"{out.fused.traffic_bytes/MB:10.1f} {unf/MB:11.1f} "
              f"{100*red:9.1f}% {out.fused.tile('M'):7d} "
              f"{out.fused.tile('F'):7d}")

    # sharding constraints: the same MLP on a 16-way TP shard
    print("\nwith d_ff sharded 16-way over the model axis "
          "(FTL sharding-constraint family):")
    if args.f % 16 == 0:
        out = ftl.plan_mlp(m=args.m, d_model=args.d, d_ff=args.f // 16,
                           gated=args.gated, vmem_budget=96 * MB)
        print(f"  decision={'FUSE' if out.use_fused else 'split'}; "
              f"{out.comparison.summary() if out.comparison else ''}")
    else:
        print("  d_ff not divisible by 16 — planner keeps it whole")

    # the graph partitioner's own view of the same chain (DP over cuts)
    g = graph.mlp_graph(m=args.m, d_model=args.d, d_ff=args.f,
                        gated=args.gated)
    chain = partition.plan_chain(g, vmem_budget=96 * MB)
    print("\ngraph partitioner (96 MiB):")
    print(chain.summary())

    if args.arch:
        from repro import configs
        cfg = configs.get_config(args.arch)
        bp = registry.plan_block(cfg, m=args.m)
        print(f"\nwhole-block plan for {args.arch}:")
        print(bp.summary())


if __name__ == "__main__":
    main()
